//! Interval-sampled memory-usage time series.

use crate::units::Seconds;

/// A memory-usage time series sampled at a fixed interval, as produced
/// by the cgroup monitoring pipeline (paper §IV-A: default 2 s).
///
/// Sample `i` is the usage over `[i*interval, (i+1)*interval)`; values
/// are MiB. The series of a run with runtime `r` has
/// `ceil(r / interval)` samples (the last one possibly covering a
/// partial interval).
#[derive(Debug, Clone, PartialEq)]
pub struct UsageSeries {
    interval_s: f64,
    samples: Vec<f64>,
}

impl UsageSeries {
    pub fn new(interval_s: f64, samples: Vec<f64>) -> Self {
        assert!(interval_s > 0.0, "non-positive monitoring interval");
        UsageSeries { interval_s, samples }
    }

    pub fn interval(&self) -> Seconds {
        Seconds(self.interval_s)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Duration covered by the samples (`j · f` in the paper's runtime
    /// model, §III-B).
    pub fn duration(&self) -> Seconds {
        Seconds(self.samples.len() as f64 * self.interval_s)
    }

    /// Global peak (MiB); 0 for an empty series.
    pub fn peak(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Usage at time `t` seconds (sample-and-hold; clamps to the ends).
    pub fn value_at(&self, t: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let idx = (t / self.interval_s).floor() as isize;
        let idx = idx.clamp(0, self.samples.len() as isize - 1) as usize;
        self.samples[idx]
    }

    /// Peak-preserving resample to exactly `t_max` buckets.
    ///
    /// This is the padding transform feeding the AOT fit artifact
    /// (fixed `[N_HIST, T_MAX]` shapes): each output bucket takes the
    /// **max** of its covered input samples, so no memory peak can be
    /// smoothed away (resampling with means would make every predictor
    /// look better than it is). Series shorter than `t_max` repeat
    /// samples (nearest); empty series give zeros.
    pub fn resample_peaks(&self, t_max: usize) -> Vec<f64> {
        assert!(t_max > 0);
        let n = self.samples.len();
        if n == 0 {
            return vec![0.0; t_max];
        }
        let mut out = Vec::with_capacity(t_max);
        for b in 0..t_max {
            // input range covered by bucket b: [b*n/t_max, (b+1)*n/t_max)
            let lo = b * n / t_max;
            let hi = (((b + 1) * n).div_ceil(t_max)).min(n).max(lo + 1);
            let m = self.samples[lo..hi].iter().copied().fold(f64::MIN, f64::max);
            out.push(m);
        }
        out
    }

    /// Iterate `(start_time_s, usage_mib)` pairs.
    pub fn iter_timed(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
        self.samples
            .iter()
            .enumerate()
            .map(move |(i, &v)| (i as f64 * self.interval_s, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: Vec<f64>) -> UsageSeries {
        UsageSeries::new(2.0, v)
    }

    #[test]
    fn peak_and_duration() {
        let u = s(vec![1.0, 9.0, 3.0]);
        assert_eq!(u.peak(), 9.0);
        assert_eq!(u.duration(), Seconds(6.0));
        assert_eq!(u.len(), 3);
    }

    #[test]
    fn empty_series() {
        let u = s(vec![]);
        assert_eq!(u.peak(), 0.0);
        assert_eq!(u.value_at(5.0), 0.0);
        assert_eq!(u.resample_peaks(4), vec![0.0; 4]);
    }

    #[test]
    fn value_at_sample_and_hold() {
        let u = s(vec![10.0, 20.0, 30.0]);
        assert_eq!(u.value_at(0.0), 10.0);
        assert_eq!(u.value_at(1.99), 10.0);
        assert_eq!(u.value_at(2.0), 20.0);
        assert_eq!(u.value_at(100.0), 30.0); // clamps to last
        assert_eq!(u.value_at(-1.0), 10.0); // clamps to first
    }

    #[test]
    fn resample_preserves_global_peak() {
        let u = s(vec![1.0, 2.0, 100.0, 3.0, 4.0, 5.0, 6.0]);
        for t_max in [1, 2, 3, 4, 7, 16] {
            let r = u.resample_peaks(t_max);
            assert_eq!(r.len(), t_max);
            assert_eq!(
                r.iter().copied().fold(f64::MIN, f64::max),
                100.0,
                "t_max={t_max}"
            );
        }
    }

    #[test]
    fn resample_identity_when_lengths_match() {
        let u = s(vec![5.0, 7.0, 6.0, 8.0]);
        assert_eq!(u.resample_peaks(4), vec![5.0, 7.0, 6.0, 8.0]);
    }

    #[test]
    fn upsample_repeats_values() {
        let u = s(vec![5.0, 9.0]);
        let r = u.resample_peaks(4);
        assert_eq!(r, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn downsample_buckets_are_maxes() {
        let u = s(vec![1.0, 4.0, 2.0, 8.0]);
        assert_eq!(u.resample_peaks(2), vec![4.0, 8.0]);
    }

    #[test]
    fn iter_timed_times() {
        let u = s(vec![1.0, 2.0]);
        let v: Vec<(f64, f64)> = u.iter_timed().collect();
        assert_eq!(v, vec![(0.0, 1.0), (2.0, 2.0)]);
    }

    #[test]
    #[should_panic]
    fn zero_interval_panics() {
        UsageSeries::new(0.0, vec![1.0]);
    }
}
