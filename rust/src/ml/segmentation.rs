//! The paper's §III-B change-point segmentation and per-segment peak
//! extraction (`Y* → Y**`) — the f64 mirror of the `segpeaks` Pallas
//! kernel.

/// Change points evenly distributed over a series of length `t`:
/// `i = floor(t/k)`; segment `s` is `[s·i, (s+1)·i)` for `s < k−1`, and
/// the last segment absorbs the remainder `[(k−1)·i, t)`.
///
/// Panics when `k == 0` or `t < k` (some segment would be empty).
pub fn segment_bounds(t: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k >= 1, "k must be >= 1");
    assert!(t >= k, "series length {t} shorter than k={k}");
    let i = t / k;
    let mut out = Vec::with_capacity(k);
    for s in 0..k - 1 {
        out.push((s * i, (s + 1) * i));
    }
    out.push(((k - 1) * i, t));
    out
}

/// Time boundaries of the k segments over a predicted runtime `r_e`,
/// mirrored from the index segmentation of a `t`-sample series: the
/// paper's change points sit at `(s+1)·⌊t/k⌋` samples (§III-B/§III-C —
/// the LAST segment absorbs the remainder), so in time the boundary of
/// segment `s < k−1` is `r_e · (s+1)·⌊t/k⌋ / t` and the last is `r_e`.
///
/// Using equal splits of `r_e` instead would misalign the predicted
/// values (trained on floor-segmented peaks) with the interval they
/// cover whenever `k ∤ t` — a systematic underprediction at segment
/// tails caught by the adaptive-k counterfactual tests.
pub fn segment_time_bounds(r_e: f64, t: usize, k: usize) -> Vec<f64> {
    assert!(r_e > 0.0, "non-positive runtime");
    segment_bounds(t, k)
        .into_iter()
        .map(|(_, hi)| r_e * hi as f64 / t as f64)
        .collect()
}

/// Per-segment peaks `Y** = (max(s_1), ..., max(s_k))` of one series.
pub fn seg_peaks(samples: &[f64], k: usize) -> Vec<f64> {
    segment_bounds(samples.len(), k)
        .into_iter()
        .map(|(lo, hi)| samples[lo..hi].iter().copied().fold(f64::MIN, f64::max))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split() {
        assert_eq!(segment_bounds(8, 4), vec![(0, 2), (2, 4), (4, 6), (6, 8)]);
    }

    #[test]
    fn remainder_to_last() {
        assert_eq!(segment_bounds(10, 4), vec![(0, 2), (2, 4), (4, 6), (6, 10)]);
    }

    #[test]
    fn k1_whole_series() {
        assert_eq!(segment_bounds(17, 1), vec![(0, 17)]);
    }

    #[test]
    fn covers_exactly_no_overlap() {
        for t in [4usize, 7, 16, 100, 256] {
            for k in 1..=t.min(16) {
                let b = segment_bounds(t, k);
                assert_eq!(b.len(), k);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[k - 1].1, t);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                assert!(b.iter().all(|(lo, hi)| hi > lo));
            }
        }
    }

    #[test]
    #[should_panic]
    fn k_zero_panics() {
        segment_bounds(10, 0);
    }

    #[test]
    #[should_panic]
    fn t_less_than_k_panics() {
        segment_bounds(3, 4);
    }

    #[test]
    fn peaks_known_values() {
        let y = [1.0, 5.0, 2.0, 3.0, 9.0, 0.0];
        assert_eq!(seg_peaks(&y, 3), vec![5.0, 3.0, 9.0]);
    }

    #[test]
    fn peaks_k1_is_global_max() {
        let y = [3.0, 7.0, 1.0];
        assert_eq!(seg_peaks(&y, 1), vec![7.0]);
    }

    #[test]
    fn peaks_match_python_reference_semantics() {
        // same as ref.segpeaks_ref: uneven split, remainder in last
        let y = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        // t=7, k=3 -> i=2: [0,2) [2,4) [4,7)
        assert_eq!(seg_peaks(&y, 3), vec![2.0, 4.0, 7.0]);
    }
}
