//! The streaming trace-source abstraction shared by every layer.
//!
//! The paper evaluates on nf-core traces captured by a Nextflow
//! monitoring extension; everything downstream consumes the
//! [`Trace`](crate::trace::Trace) data model. [`TraceSource`] is the
//! seam between the two: a chunked, rewindable iterator of
//! [`TaskRun`]s in arrival order, so no surface requires a trace to be
//! fully materialized in memory before anything can run.
//!
//! This module holds only the trait, the in-memory reference
//! implementation and the [`materialize`] bridge back to the batch
//! surfaces. The file-backed implementations (`JsonlReader`,
//! `NextflowDirSource`), the shape-sniffing `open_source` opener and
//! the streaming replay engine live in the serve layer
//! (`ksegments-serve::ingest`), which re-exports everything here so
//! the historical `ksegments::ingest::TraceSource` path still works.

use anyhow::Result;

use crate::trace::{TaskRun, Trace};
use crate::units::MemMiB;

/// Default [`TraceSource::next_chunk`] request size used by the CLI
/// and the replay surfaces.
pub const DEFAULT_CHUNK: usize = 256;

/// A streaming source of task runs in arrival order.
///
/// The contract every consumer relies on: runs of one task type are
/// yielded oldest-first (the online-learning order), and the
/// concatenation of all chunks is the full stream. Sources that read a
/// `ksegments ingest` output file (or any
/// [`crate::trace::write_trace_jsonl_ordered`] file) additionally
/// yield the *global* submission order, which is what the scheduler's
/// arrival stream consumes.
pub trait TraceSource: Send {
    /// Human-readable origin (a path, `"in-memory"`, ...).
    fn origin(&self) -> String;

    /// Developer-default allocations known for this source, sorted by
    /// task type (may be empty; Nextflow traces carry the requested
    /// `memory` per process).
    fn defaults(&self) -> Vec<(String, MemMiB)>;

    /// Pull the next chunk of at most `max` runs. An empty vector
    /// means the stream is exhausted.
    fn next_chunk(&mut self, max: usize) -> Result<Vec<TaskRun>>;

    /// Restart the stream from the beginning (re-opens files).
    fn rewind(&mut self) -> Result<()>;
}

/// A [`TraceSource`] over an already-materialized run list — the
/// adapter that lets every streaming consumer also accept an in-memory
/// [`Trace`] (and the reference implementation the streaming readers
/// are tested against).
#[derive(Debug, Clone)]
pub struct InMemorySource {
    defaults: Vec<(String, MemMiB)>,
    runs: Vec<TaskRun>,
    pos: usize,
}

impl InMemorySource {
    /// Stream a trace's runs in global submission (`seq`) order.
    pub fn from_trace(trace: &Trace) -> InMemorySource {
        let defaults = trace
            .task_types()
            .filter_map(|ty| trace.default_alloc(ty).map(|m| (ty.to_string(), m)))
            .collect();
        let runs = trace.all_runs_ordered().into_iter().cloned().collect();
        InMemorySource { defaults, runs, pos: 0 }
    }

    /// Stream an explicit run list in the order given.
    pub fn from_runs(defaults: Vec<(String, MemMiB)>, runs: Vec<TaskRun>) -> InMemorySource {
        InMemorySource { defaults, runs, pos: 0 }
    }

    pub fn len(&self) -> usize {
        self.runs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }
}

impl TraceSource for InMemorySource {
    fn origin(&self) -> String {
        format!("in-memory ({} runs)", self.runs.len())
    }

    fn defaults(&self) -> Vec<(String, MemMiB)> {
        self.defaults.clone()
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<TaskRun>> {
        let end = (self.pos + max.max(1)).min(self.runs.len());
        let chunk = self.runs[self.pos..end].to_vec();
        self.pos = end;
        Ok(chunk)
    }

    fn rewind(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }
}

/// Drain a source into a fully materialized [`Trace`] (defaults
/// applied, runs sorted per type) — the bridge back to the batch
/// surfaces (the evaluation grid, figure regeneration).
pub fn materialize(src: &mut dyn TraceSource) -> Result<Trace> {
    let mut trace = Trace::new();
    for (ty, mem) in src.defaults() {
        trace.set_default(&ty, mem);
    }
    loop {
        let chunk = src.next_chunk(DEFAULT_CHUNK)?;
        if chunk.is_empty() {
            break;
        }
        for run in chunk {
            trace.push(run);
        }
    }
    trace.sort();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn toy_trace() -> Trace {
        let mut t = Trace::new();
        t.set_default("w/a", MemMiB(1000.0));
        for seq in 0..5u64 {
            t.push(TaskRun {
                task_type: if seq % 2 == 0 { "w/a".into() } else { "w/b".into() },
                input_mib: 10.0 * seq as f64,
                runtime: Seconds(4.0),
                series: UsageSeries::new(2.0, vec![1.0, 2.0 + seq as f64]),
                seq,
            });
        }
        t.sort();
        t
    }

    #[test]
    fn in_memory_source_streams_in_seq_order() {
        let t = toy_trace();
        let mut src = InMemorySource::from_trace(&t);
        assert_eq!(src.defaults(), vec![("w/a".to_string(), MemMiB(1000.0))]);
        let mut seqs = Vec::new();
        loop {
            let chunk = src.next_chunk(2).unwrap();
            if chunk.is_empty() {
                break;
            }
            assert!(chunk.len() <= 2);
            seqs.extend(chunk.iter().map(|r| r.seq));
        }
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
        // exhausted stays exhausted until rewind
        assert!(src.next_chunk(8).unwrap().is_empty());
        src.rewind().unwrap();
        assert_eq!(src.next_chunk(8).unwrap().len(), 5);
    }

    #[test]
    fn materialize_round_trips_the_trace() {
        let t = toy_trace();
        let mut src = InMemorySource::from_trace(&t);
        let back = materialize(&mut src).unwrap();
        assert_eq!(back, t);
    }
}
