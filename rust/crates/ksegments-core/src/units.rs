//! Typed quantities used across the crate.
//!
//! The paper reports memory in MB/GB and **wastage in GB·s** (Fig. 7a).
//! Internally everything is f64 MiB / seconds; these newtypes keep unit
//! conversions at API boundaries explicit and impossible to mix up.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub, SubAssign};

/// Mebibytes of memory (f64).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct MemMiB(pub f64);

/// Seconds of wall-clock time (f64).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Seconds(pub f64);

/// Gigabyte-seconds of memory wastage — the paper's headline metric.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct GbSeconds(pub f64);

pub const MIB_PER_GB: f64 = 1e9 / (1024.0 * 1024.0); // 1 GB in MiB ≈ 953.67
pub const MIB_PER_MB: f64 = 1e6 / (1024.0 * 1024.0); // 1 MB in MiB ≈ 0.9537

impl MemMiB {
    pub const ZERO: MemMiB = MemMiB(0.0);

    pub fn from_gib(g: f64) -> Self {
        MemMiB(g * 1024.0)
    }
    pub fn from_gb(g: f64) -> Self {
        MemMiB(g * MIB_PER_GB)
    }
    /// Decimal megabytes → MiB (`const` so paper constants quoted in MB
    /// can be expressed in their original unit).
    pub const fn from_mb(m: f64) -> Self {
        MemMiB(m * MIB_PER_MB)
    }
    pub fn as_mb(self) -> f64 {
        self.0 / MIB_PER_MB
    }
    pub fn as_gb(self) -> f64 {
        self.0 / MIB_PER_GB
    }
    pub fn as_gib(self) -> f64 {
        self.0 / 1024.0
    }
    pub fn max(self, other: Self) -> Self {
        MemMiB(self.0.max(other.0))
    }
    pub fn min(self, other: Self) -> Self {
        MemMiB(self.0.min(other.0))
    }
    pub fn clamp(self, lo: Self, hi: Self) -> Self {
        MemMiB(self.0.clamp(lo.0, hi.0))
    }
    pub fn is_finite(self) -> bool {
        self.0.is_finite()
    }

    /// Parse a human-readable memory value with an optional unit
    /// suffix, as found in Nextflow `trace.txt` columns (`peak_rss`,
    /// `memory`, `rchar`, ...): `"0"`, `"512 KB"`, `"12.5 GB"`,
    /// `"1 GiB"`.
    ///
    /// Decimal suffixes (`KB`/`MB`/`GB`/`TB`) are powers of 1000,
    /// binary suffixes (`KiB`/`MiB`/`GiB`/`TiB`) powers of 1024, both
    /// case-insensitive, whitespace between number and unit optional.
    /// A bare number is **bytes** (what Nextflow's raw trace mode
    /// emits). Negative, non-finite and exponent-notation values are
    /// rejected.
    ///
    /// # Example
    ///
    /// ```
    /// use ksegments::units::MemMiB;
    ///
    /// assert_eq!(MemMiB::parse("1 GiB").unwrap(), MemMiB(1024.0));
    /// assert_eq!(MemMiB::parse("0").unwrap(), MemMiB(0.0));
    /// assert!(MemMiB::parse("twelve parsecs").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<MemMiB, String> {
        let t = s.trim();
        if t.is_empty() {
            return Err("empty memory value".to_string());
        }
        let split = t.find(|c: char| c.is_ascii_alphabetic()).unwrap_or(t.len());
        let (num, unit) = (t[..split].trim(), t[split..].trim());
        let v: f64 = num
            .parse()
            .map_err(|_| format!("bad number in memory value {s:?}"))?;
        if !v.is_finite() || v < 0.0 {
            return Err(format!("negative or non-finite memory value {s:?}"));
        }
        let bytes = match unit.to_ascii_uppercase().as_str() {
            "" | "B" => v,
            "KB" => v * 1e3,
            "MB" => v * 1e6,
            "GB" => v * 1e9,
            "TB" => v * 1e12,
            "KIB" => v * 1024.0,
            "MIB" => v * 1024.0 * 1024.0,
            "GIB" => v * 1024.0 * 1024.0 * 1024.0,
            "TIB" => v * 1024.0 * 1024.0 * 1024.0 * 1024.0,
            other => return Err(format!("unknown memory unit {other:?} in {s:?}")),
        };
        Ok(MemMiB(bytes / (1024.0 * 1024.0)))
    }
}

impl Seconds {
    pub const ZERO: Seconds = Seconds(0.0);

    pub fn from_minutes(m: f64) -> Self {
        Seconds(m * 60.0)
    }
    pub fn from_hours(h: f64) -> Self {
        Seconds(h * 3600.0)
    }
    pub fn max(self, other: Self) -> Self {
        Seconds(self.0.max(other.0))
    }
    pub fn min(self, other: Self) -> Self {
        Seconds(self.0.min(other.0))
    }
}

impl GbSeconds {
    pub const ZERO: GbSeconds = GbSeconds(0.0);

    /// Wastage accrued by holding `mem` for `dur`.
    pub fn accrue(mem: MemMiB, dur: Seconds) -> Self {
        GbSeconds(mem.as_gb() * dur.0)
    }
}

// --- arithmetic -----------------------------------------------------------

macro_rules! impl_linear_ops {
    ($t:ident) => {
        impl Add for $t {
            type Output = $t;
            fn add(self, rhs: $t) -> $t {
                $t(self.0 + rhs.0)
            }
        }
        impl Sub for $t {
            type Output = $t;
            fn sub(self, rhs: $t) -> $t {
                $t(self.0 - rhs.0)
            }
        }
        impl AddAssign for $t {
            fn add_assign(&mut self, rhs: $t) {
                self.0 += rhs.0;
            }
        }
        impl SubAssign for $t {
            fn sub_assign(&mut self, rhs: $t) {
                self.0 -= rhs.0;
            }
        }
        impl Mul<f64> for $t {
            type Output = $t;
            fn mul(self, rhs: f64) -> $t {
                $t(self.0 * rhs)
            }
        }
        impl Div<f64> for $t {
            type Output = $t;
            fn div(self, rhs: f64) -> $t {
                $t(self.0 / rhs)
            }
        }
        impl Neg for $t {
            type Output = $t;
            fn neg(self) -> $t {
                $t(-self.0)
            }
        }
        impl Sum for $t {
            fn sum<I: Iterator<Item = $t>>(iter: I) -> $t {
                $t(iter.map(|v| v.0).sum())
            }
        }
    };
}

impl_linear_ops!(MemMiB);
impl_linear_ops!(Seconds);
impl_linear_ops!(GbSeconds);

impl fmt::Display for MemMiB {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1024.0 {
            write!(f, "{:.2} GiB", self.as_gib())
        } else {
            write!(f, "{:.1} MiB", self.0)
        }
    }
}

impl fmt::Display for Seconds {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 3600.0 {
            write!(f, "{:.2} h", self.0 / 3600.0)
        } else if self.0 >= 60.0 {
            write!(f, "{:.1} min", self.0 / 60.0)
        } else {
            write!(f, "{:.1} s", self.0)
        }
    }
}

impl fmt::Display for GbSeconds {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.2} GB·s", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gib_gb_conversions() {
        let one_gib = MemMiB::from_gib(1.0);
        assert_eq!(one_gib.0, 1024.0);
        let one_gb = MemMiB::from_gb(1.0);
        assert!((one_gb.0 - 953.674).abs() < 1e-2);
        assert!((one_gb.as_gb() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mb_conversions() {
        // 100 MB (decimal) is NOT 100 MiB — it is ≈ 95.37 MiB. The §IV-A
        // allocation floor depends on this distinction.
        let floor = MemMiB::from_mb(100.0);
        assert!((floor.0 - 95.367431640625).abs() < 1e-9);
        assert!((floor.as_mb() - 100.0).abs() < 1e-12);
        // 1000 MB == 1 GB
        assert_eq!(MemMiB::from_mb(1000.0).0, MemMiB::from_gb(1.0).0);
    }

    #[test]
    fn wastage_accrual() {
        // Holding 2 GB for 10 s wastes 20 GB·s.
        let w = GbSeconds::accrue(MemMiB::from_gb(2.0), Seconds(10.0));
        assert!((w.0 - 20.0).abs() < 1e-9);
    }

    #[test]
    fn arithmetic_ops() {
        let a = MemMiB(100.0) + MemMiB(28.0) - MemMiB(28.0);
        assert_eq!(a, MemMiB(100.0));
        assert_eq!(MemMiB(100.0) * 2.0, MemMiB(200.0));
        assert_eq!(Seconds(120.0) / 2.0, Seconds(60.0));
        let total: GbSeconds = [GbSeconds(1.0), GbSeconds(2.5)].into_iter().sum();
        assert_eq!(total, GbSeconds(3.5));
    }

    #[test]
    fn clamping() {
        assert_eq!(
            MemMiB(5000.0).clamp(MemMiB(100.0), MemMiB(1024.0)),
            MemMiB(1024.0)
        );
        assert_eq!(
            MemMiB(5.0).clamp(MemMiB(100.0), MemMiB(1024.0)),
            MemMiB(100.0)
        );
    }

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", MemMiB(512.0)), "512.0 MiB");
        assert_eq!(format!("{}", MemMiB(2048.0)), "2.00 GiB");
        assert_eq!(format!("{}", Seconds(30.0)), "30.0 s");
        assert_eq!(format!("{}", Seconds(7200.0)), "2.00 h");
        assert_eq!(format!("{}", GbSeconds(1.234)), "1.23 GB·s");
    }

    #[test]
    fn time_constructors() {
        assert_eq!(Seconds::from_minutes(2.0).0, 120.0);
        assert_eq!(Seconds::from_hours(1.5).0, 5400.0);
    }

    #[test]
    fn parse_unit_suffixes() {
        // the satellite edge cases: "0", "12.5 GB", "512 KB"
        assert_eq!(MemMiB::parse("0").unwrap(), MemMiB(0.0));
        let twelve_and_a_half_gb = MemMiB::parse("12.5 GB").unwrap();
        assert!((twelve_and_a_half_gb.0 - 12.5e9 / (1024.0 * 1024.0)).abs() < 1e-9);
        assert_eq!(MemMiB::parse("512 KB").unwrap(), MemMiB(512e3 / (1024.0 * 1024.0)));
        // binary units, case-insensitivity, optional whitespace
        assert_eq!(MemMiB::parse("1 GiB").unwrap(), MemMiB(1024.0));
        assert_eq!(MemMiB::parse("2048KiB").unwrap(), MemMiB(2.0));
        assert_eq!(MemMiB::parse(" 3 mb ").unwrap(), MemMiB::from_mb(3.0));
        assert_eq!(MemMiB::parse("1 MiB").unwrap(), MemMiB(1.0));
        // bare numbers are bytes (Nextflow raw trace mode)
        assert_eq!(MemMiB::parse("1048576").unwrap(), MemMiB(1.0));
        assert_eq!(MemMiB::parse("1048576 B").unwrap(), MemMiB(1.0));
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["", "   ", "GB", "nope", "-1 MB", "1 XB", "1..5 GB", "1e3 MB", "NaN"] {
            assert!(MemMiB::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }
}
