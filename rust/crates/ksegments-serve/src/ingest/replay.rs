//! Streaming replay: drive a [`TraceSource`] through a predictor
//! online — the §IV evaluation protocol without a materialized trace,
//! parallel across task types, and resumable via [`Checkpoint`]s.
//!
//! ## Sharded execution model
//!
//! Every predictor in the zoo is a collection of independent
//! per-task-type models, so replay parallelism comes from partitioning
//! *task types* (with the service's FNV hash,
//! [`crate::coordinator::shard_of`]), never from splitting one type's
//! run sequence: the main thread pulls chunks from the source in
//! arrival order and routes each run to its owning shard thread, which
//! owns a private predictor instance and scores its types' runs
//! through the exact [`ksegments_core::scoring::score_run`] retry loop. A type's
//! run sequence — the only ordering the online contract cares about —
//! is identical for any worker count, and per-shard partial results
//! are merged in sorted task-type order, so a replay's
//! [`MethodReport`] and final [`Checkpoint`] are **bit-identical at
//! any worker count** (pinned by `tests/ingest_replay.rs`).
//!
//! ## Warm-up and warm start
//!
//! The first [`ReplayConfig::warmup_per_type`] runs of each previously
//! unseen type are folded into the model unscored (the streaming
//! analogue of the paper's training fraction). Passing a
//! [`Checkpoint`] restores each type's defaults and run window before
//! the stream starts and resumes its lifetime observation count, so a
//! replay split into N checkpointed sessions ends in the same
//! predictor state as one uninterrupted replay.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver};

use anyhow::Result;

use crate::coordinator::shard_of;
use ksegments_core::predictors::MemoryPredictor;
use ksegments_core::scoring::{score_run, SimConfig};
use ksegments_core::telemetry::{ArgValue, TraceEvent};
use ksegments_core::trace::TaskRun;
use ksegments_core::units::MemMiB;
use ksegments_core::wastage::{MethodReport, TaskReport};

use super::checkpoint::{Checkpoint, TypeState};
use super::{TraceSource, DEFAULT_CHUNK};

/// Thread-safe predictor constructor for the replay shards (the same
/// shape as the sim layer’s `PredictorFactory`, borrowed).
pub type MakePredictor = dyn Fn() -> Box<dyn MemoryPredictor> + Sync;

/// Streaming-replay parameters.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Leading runs of each unseen task type folded into the model
    /// unscored (warm-up). Checkpointed types resume their lifetime
    /// count, so already-warm types score immediately.
    pub warmup_per_type: usize,
    /// Source chunk size (I/O granularity; no effect on results).
    pub chunk: usize,
    /// Retry-loop safety valve, as in [`SimConfig::max_attempts`].
    pub max_attempts: u32,
    /// Node capacity: allocations above this are clamped.
    pub node_max: MemMiB,
    /// Per-type window of the emitted checkpoint.
    pub checkpoint_window: usize,
    /// Collect per-run trace events ([`ReplayOutcome::trace_events`]).
    /// Off by default; purely observational — scores, checkpoints and
    /// counters are bit-identical either way. Replay has no simulated
    /// clock, so events are stamped with the run's arrival `seq`
    /// (microsecond slot per run), which also makes the collected
    /// trace worker-count independent.
    pub collect_trace: bool,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            warmup_per_type: 2,
            chunk: DEFAULT_CHUNK,
            max_attempts: 40,
            node_max: MemMiB::from_gib(128.0),
            checkpoint_window: Checkpoint::DEFAULT_WINDOW,
            collect_trace: false,
        }
    }
}

impl ReplayConfig {
    fn sim_config(&self) -> SimConfig {
        SimConfig {
            training_frac: 0.0,
            max_attempts: self.max_attempts,
            min_runs: 0,
            node_max: self.node_max,
        }
    }
}

/// What a replay produces: the scored report, the final predictor
/// state, and stream accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayOutcome {
    /// Wastage/retries per task type, sorted by type
    /// (`training_frac` is reported as 0 — warm-up is count-based).
    pub report: MethodReport,
    /// Final predictor state (defaults + run windows), warm-start
    /// input for the next session.
    pub checkpoint: Checkpoint,
    /// Runs consumed from the source.
    pub runs_replayed: u64,
    /// Of those, runs folded in unscored as warm-up.
    pub runs_warmup: u64,
    /// Per-run trace events (only when [`ReplayConfig::collect_trace`]
    /// is set), merged across shards and sorted by `(ts, name)` —
    /// `seq`-stamped, so identical at any worker count.
    pub trace_events: Vec<TraceEvent>,
}

enum ShardMsg {
    /// Seed a type from a checkpoint (sent before any runs).
    Restore(String, TypeState),
    /// Prime a developer default.
    Prime(String, MemMiB),
    /// A batch of this shard's runs, in arrival order.
    Runs(Vec<TaskRun>),
}

struct ShardOut {
    tasks: BTreeMap<String, TaskReport>,
    checkpoint: Checkpoint,
    replayed: u64,
    warmup: u64,
    trace: Vec<TraceEvent>,
}

fn shard_loop(
    make: &MakePredictor,
    cfg: &ReplayConfig,
    sim_cfg: &SimConfig,
    rx: Receiver<ShardMsg>,
) -> ShardOut {
    let mut predictor = make();
    let mut checkpoint = Checkpoint::new(cfg.checkpoint_window);
    let mut tasks: BTreeMap<String, TaskReport> = BTreeMap::new();
    let mut seen: BTreeMap<String, u64> = BTreeMap::new();
    let (mut replayed, mut warmup) = (0u64, 0u64);
    let mut trace: Vec<TraceEvent> = Vec::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Restore(ty, st) => {
                if let Some(d) = st.default_mib {
                    predictor.prime(&ty, MemMiB(d));
                }
                for run in &st.runs {
                    predictor.observe(run);
                }
                seen.insert(ty.clone(), st.total_seen);
                checkpoint.insert_state(ty, st);
            }
            ShardMsg::Prime(ty, mem) => {
                predictor.prime(&ty, mem);
                checkpoint.record_default(&ty, mem);
            }
            ShardMsg::Runs(batch) => {
                for run in batch {
                    let n = seen.entry(run.task_type.clone()).or_insert(0);
                    if *n < cfg.warmup_per_type as u64 {
                        predictor.observe(&run);
                        warmup += 1;
                        if cfg.collect_trace {
                            trace.push(TraceEvent::instant(&run.task_type, "warmup", run.seq, 0));
                        }
                    } else {
                        let score = score_run(predictor.as_mut(), &run, sim_cfg);
                        if cfg.collect_trace {
                            let mut ev = TraceEvent::instant(&run.task_type, "replay", run.seq, 0);
                            ev.args = vec![
                                ("seq", ArgValue::U64(run.seq)),
                                ("wastage_gbs", ArgValue::F64(score.wastage.0)),
                                ("retries", ArgValue::U64(u64::from(score.retries))),
                            ];
                            trace.push(ev);
                        }
                        tasks
                            .entry(run.task_type.clone())
                            .or_insert_with(|| TaskReport::new(&run.task_type))
                            .record(score.wastage, score.retries);
                    }
                    *n += 1;
                    replayed += 1;
                    checkpoint.record(&run);
                }
            }
        }
    }
    ShardOut { tasks, checkpoint, replayed, warmup, trace }
}

/// Replay a source through `workers` type-sharded predictor instances;
/// see the module docs for the execution model and guarantees.
///
/// `start_from` warm-starts every shard from a prior session's
/// [`Checkpoint`]; the returned checkpoint always reflects the state
/// *after* this replay (restored state + this stream's runs).
pub fn replay_source(
    src: &mut dyn TraceSource,
    make: &MakePredictor,
    cfg: &ReplayConfig,
    workers: usize,
    start_from: Option<&Checkpoint>,
) -> Result<ReplayOutcome> {
    let workers = workers.max(1);
    let method = make().name();
    let sim_cfg = cfg.sim_config();

    let mut stream_err: Option<anyhow::Error> = None;
    let mut tasks: BTreeMap<String, TaskReport> = BTreeMap::new();
    let mut checkpoint = Checkpoint::new(cfg.checkpoint_window);
    let (mut runs_replayed, mut runs_warmup) = (0u64, 0u64);
    let mut trace_events: Vec<TraceEvent> = Vec::new();

    std::thread::scope(|scope| {
        let sim_ref = &sim_cfg;
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<ShardMsg>();
            txs.push(tx);
            handles.push(scope.spawn(move || shard_loop(make, cfg, sim_ref, rx)));
        }
        // 1. seed checkpointed state, then source defaults (overriding)
        if let Some(ck) = start_from {
            for (ty, st) in ck.types() {
                let _ = txs[shard_of(ty, workers)].send(ShardMsg::Restore(ty.clone(), st.clone()));
            }
        }
        for (ty, mem) in src.defaults() {
            let _ = txs[shard_of(&ty, workers)].send(ShardMsg::Prime(ty, mem));
        }
        // 2. stream chunks, routing each run to its type's shard
        loop {
            match src.next_chunk(cfg.chunk.max(1)) {
                Err(e) => {
                    stream_err = Some(e);
                    break;
                }
                Ok(batch) if batch.is_empty() => break,
                Ok(batch) => {
                    let mut per: Vec<Vec<TaskRun>> = (0..workers).map(|_| Vec::new()).collect();
                    for run in batch {
                        per[shard_of(&run.task_type, workers)].push(run);
                    }
                    for (s, part) in per.into_iter().enumerate() {
                        if !part.is_empty() {
                            let _ = txs[s].send(ShardMsg::Runs(part));
                        }
                    }
                }
            }
        }
        // 3. close the channels and merge shard partials (disjoint
        //    types; BTreeMaps keep everything in sorted-type order)
        drop(txs);
        for h in handles {
            let out = h.join().expect("replay shard panicked");
            tasks.extend(out.tasks);
            checkpoint.merge_disjoint(out.checkpoint);
            runs_replayed += out.replayed;
            runs_warmup += out.warmup;
            trace_events.extend(out.trace);
        }
    });
    if let Some(e) = stream_err {
        return Err(e.context("replaying trace source"));
    }
    // seq-stamped ts are unique per run, so this is a total order —
    // the merged trace is identical at any worker count
    trace_events.sort_by(|a, b| (a.ts_us, &a.name).cmp(&(b.ts_us, &b.name)));

    let report = MethodReport::new(&method, 0.0, tasks.into_values().collect());
    Ok(ReplayOutcome { report, checkpoint, runs_replayed, runs_warmup, trace_events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::InMemorySource;
    use ksegments_core::predictors::ppm::PpmPredictor;
    use ksegments_core::trace::{Trace, UsageSeries};
    use ksegments_core::units::Seconds;

    fn ramp_trace(types: &[&str], runs_per_type: usize) -> Trace {
        let mut t = Trace::new();
        let mut seq = 0u64;
        for i in 0..runs_per_type {
            for (k, ty) in types.iter().enumerate() {
                t.set_default(ty, MemMiB(1000.0 * (k + 1) as f64));
                let peak = 100.0 + 10.0 * i as f64 + 50.0 * k as f64;
                let samples: Vec<f64> = (0..8).map(|j| peak * (j + 1) as f64 / 8.0).collect();
                t.push(TaskRun {
                    task_type: ty.to_string(),
                    input_mib: 50.0 + 5.0 * i as f64,
                    runtime: Seconds(16.0),
                    series: UsageSeries::new(2.0, samples),
                    seq,
                });
                seq += 1;
            }
        }
        t.sort();
        t
    }

    fn make() -> Box<dyn MemoryPredictor> {
        Box::new(PpmPredictor::improved())
    }

    #[test]
    fn replay_is_worker_count_independent() {
        let trace = ramp_trace(&["w/a", "w/b", "w/c", "w/d", "w/e"], 12);
        let cfg = ReplayConfig { chunk: 7, ..ReplayConfig::default() };
        let mut src = InMemorySource::from_trace(&trace);
        let base = replay_source(&mut src, &make, &cfg, 1, None).unwrap();
        assert_eq!(base.runs_replayed, 60);
        assert_eq!(base.runs_warmup, 10);
        for workers in [2, 4, 8] {
            src.rewind().unwrap();
            let out = replay_source(&mut src, &make, &cfg, workers, None).unwrap();
            assert_eq!(out, base, "workers={workers} diverged");
        }
    }

    #[test]
    fn warm_start_matches_uninterrupted_replay() {
        let trace = ramp_trace(&["w/a", "w/b", "w/c"], 10);
        let cfg = ReplayConfig::default();
        // cold: one uninterrupted replay
        let mut cold_src = InMemorySource::from_trace(&trace);
        let cold = replay_source(&mut cold_src, &make, &cfg, 2, None).unwrap();
        // split: first half, checkpoint, then second half warm-started
        let all: Vec<TaskRun> = trace.all_runs_ordered().into_iter().cloned().collect();
        let defaults = InMemorySource::from_trace(&trace).defaults();
        let (a, b) = all.split_at(all.len() / 2);
        let mut src_a = InMemorySource::from_runs(defaults.clone(), a.to_vec());
        let first = replay_source(&mut src_a, &make, &cfg, 3, None).unwrap();
        let mut src_b = InMemorySource::from_runs(defaults, b.to_vec());
        let second = replay_source(&mut src_b, &make, &cfg, 1, Some(&first.checkpoint)).unwrap();
        // final predictor state identical to the uninterrupted run
        assert_eq!(second.checkpoint, cold.checkpoint);
        // and the split sessions scored exactly the cold run's tally
        assert_eq!(first.runs_replayed + second.runs_replayed, cold.runs_replayed);
        assert_eq!(first.runs_warmup + second.runs_warmup, cold.runs_warmup);
    }

    #[test]
    fn checkpointed_types_skip_warmup() {
        let trace = ramp_trace(&["w/a"], 6);
        let cfg = ReplayConfig { warmup_per_type: 4, ..ReplayConfig::default() };
        let mut src = InMemorySource::from_trace(&trace);
        let first = replay_source(&mut src, &make, &cfg, 1, None).unwrap();
        assert_eq!(first.runs_warmup, 4);
        assert_eq!(first.report.tasks[0].n_scored, 2);
        // replaying again from the checkpoint: the type is warm, every
        // run scores
        src.rewind().unwrap();
        let second = replay_source(&mut src, &make, &cfg, 1, Some(&first.checkpoint)).unwrap();
        assert_eq!(second.runs_warmup, 0);
        assert_eq!(second.report.tasks[0].n_scored, 6);
    }

    #[test]
    fn empty_source_gives_empty_outcome() {
        let mut src = InMemorySource::from_runs(Vec::new(), Vec::new());
        let out = replay_source(&mut src, &make, &ReplayConfig::default(), 4, None).unwrap();
        assert_eq!(out.runs_replayed, 0);
        assert!(out.report.tasks.is_empty());
        assert_eq!(out.checkpoint.n_types(), 0);
    }
}
