"""L2 fit-graph tests: ksegments_fit vs the pure-jnp oracle and vs a
straight numpy re-derivation of the paper's §III-B procedure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import fit_ref, segment_bounds
from compile.model import K_RANGE, N_HIST, T_MAX, ksegments_fit, make_fit_fn


def synth_case(seed, n=16, t=64, noise=0.05):
    """A workload-shaped case: input-size-linear ramp-to-peak series."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(100.0, 5000.0, size=n).astype(np.float32)
    runtime = (30.0 + 0.02 * x * (1 + rng.normal(0, noise, n))).astype(np.float32)
    base = 50.0 + 0.5 * x  # peak scales with input size
    tt = np.linspace(0.0, 1.0, t, dtype=np.float32)
    y = np.outer(base, np.sqrt(tt)) * (1 + rng.normal(0, noise, (n, t)))
    y = np.maximum(y, 0).astype(np.float32)
    valid = np.ones(n, dtype=np.float32)
    return x, y, runtime, valid


class TestFitGraph:
    @settings(max_examples=20, deadline=None)
    @given(
        st.integers(0, 2**31 - 1),
        st.sampled_from([1, 2, 4, 7, 13, 16]),
    )
    def test_matches_oracle(self, seed, k):
        x, y, runtime, valid = synth_case(seed)
        got = ksegments_fit(*map(jnp.asarray, (x, y, runtime, valid)), k=k)
        want = fit_ref(*map(jnp.asarray, (x, y, runtime, valid)), k=k)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4, atol=1e-2)

    def test_offsets_are_nonnegative(self):
        x, y, runtime, valid = synth_case(0)
        rt_coef, rt_off, seg_coef, seg_off = ksegments_fit(
            *map(jnp.asarray, (x, y, runtime, valid)), k=4
        )
        assert float(rt_off) >= 0.0
        assert np.all(np.asarray(seg_off) >= 0.0)

    def test_offset_covers_every_training_row(self):
        """Intercept + offset must make every historical segment peak
        non-underpredicted (the paper's 'avoid underpredictions')."""
        x, y, runtime, valid = synth_case(42)
        k = 4
        _, _, seg_coef, seg_off = map(
            np.asarray, ksegments_fit(*map(jnp.asarray, (x, y, runtime, valid)), k=k)
        )
        bounds = segment_bounds(y.shape[1], k)
        peaks = np.stack([y[:, lo:hi].max(axis=1) for lo, hi in bounds], axis=1)
        pred = seg_coef[:, 0][None] + seg_off[None] + seg_coef[:, 1][None] * x[:, None]
        assert np.all(pred >= peaks - 1e-2 * np.maximum(peaks, 1.0))

    def test_runtime_offset_makes_prediction_conservative(self):
        x, y, runtime, valid = synth_case(7)
        rt_coef, rt_off, _, _ = map(
            np.asarray, ksegments_fit(*map(jnp.asarray, (x, y, runtime, valid)), k=2)
        )
        pred = rt_coef[0] + rt_coef[1] * x - rt_off
        # after subtracting the worst overprediction, no training row is
        # overpredicted anymore
        assert np.all(pred <= runtime + 1e-2 * runtime)

    def test_padding_rows_are_inert(self):
        x, y, runtime, valid = synth_case(3, n=8)
        # embed in a padded batch with garbage in invalid rows
        xp = np.concatenate([x, np.full(8, 1e9, np.float32)])
        yp = np.concatenate([y, np.full((8, y.shape[1]), -1e9, np.float32)])
        rp = np.concatenate([runtime, np.full(8, 1e9, np.float32)])
        vp = np.concatenate([valid, np.zeros(8, np.float32)])
        got = ksegments_fit(*map(jnp.asarray, (xp, yp, rp, vp)), k=4)
        want = ksegments_fit(*map(jnp.asarray, (x, y, runtime, valid)), k=4)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-3)

    def test_jit_and_eager_agree(self):
        x, y, runtime, valid = synth_case(5)
        args = tuple(map(jnp.asarray, (x, y, runtime, valid)))
        eager = ksegments_fit(*args, k=4)
        jitted = jax.jit(make_fit_fn(4))(*args)
        for g, w in zip(jitted, eager):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-4)

    def test_aot_shapes_lower(self):
        """The exact padded shapes shipped to rust must trace."""
        vec = jax.ShapeDtypeStruct((N_HIST,), jnp.float32)
        mat = jax.ShapeDtypeStruct((N_HIST, T_MAX), jnp.float32)
        lowered = jax.jit(make_fit_fn(4)).lower(vec, mat, vec, vec)
        assert "func" in str(lowered.compiler_ir("stablehlo"))

    def test_k_range_is_sane(self):
        assert K_RANGE[0] == 1 and K_RANGE[-1] == 16
        assert all(k <= T_MAX for k in K_RANGE)
