#!/usr/bin/env python3
"""Compare a fresh `ksegments bench` snapshot against the committed trajectory.

Usage: bench_check.py BASELINE.json FRESH.json [--threshold 0.20]

Policy (mirrors rust/src/bench_harness/bench.rs):
  * schema + seed must match exactly (the counts are meaningless across
    either);
  * every count in the baseline must match the fresh run exactly --
    counts are deterministic functions of the seed, independent of
    worker count and wall clock;
  * throughput is wall-clock dependent and only gated within a noise
    threshold (default +/-20%), and only as a *regression* gate: a
    faster run always passes;
  * a baseline marked "provisional": true is a placeholder that has
    never been measured on a CI runner -- the fresh snapshot is printed
    for the log and the check passes (record-only mode). Replace the
    placeholder with a measured snapshot to arm the gate.

`workers` and `wall_s` are context, never compared.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench_check: cannot read {path}: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_<area>.json")
    ap.add_argument("fresh", help="freshly measured BENCH_<area>.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="allowed fractional throughput regression (default 0.20)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    fresh = load(args.fresh)
    area = base.get("bench", "?")

    if fresh.get("bench") != base.get("bench"):
        sys.exit(f"bench_check[{area}]: area mismatch: {base.get('bench')!r} vs "
                 f"{fresh.get('bench')!r}")

    if base.get("provisional"):
        print(f"bench_check[{area}]: baseline is provisional -- recording only, no gate.")
        print(f"bench_check[{area}]: measured snapshot:")
        print(json.dumps(fresh, indent=2, sort_keys=True))
        print(f"bench_check[{area}]: commit this as {args.baseline} (with "
              '"provisional": false) to arm the regression gate.')
        return

    failures = []
    for key in ("schema", "seed"):
        if base.get(key) != fresh.get(key):
            failures.append(f"{key} mismatch: committed {base.get(key)!r}, "
                            f"fresh {fresh.get(key)!r}")

    base_counts = base.get("counts", {})
    fresh_counts = fresh.get("counts", {})
    for name, want in sorted(base_counts.items()):
        got = fresh_counts.get(name)
        if got != want:
            failures.append(f"count {name}: committed {want}, fresh {got} "
                            "(counts are deterministic -- this is a behavior change, "
                            "not noise; recommit the snapshot if intended)")

    want_tp = base.get("throughput", 0.0)
    got_tp = fresh.get("throughput", 0.0)
    if want_tp > 0:
        drop = (want_tp - got_tp) / want_tp
        if drop > args.threshold:
            failures.append(
                f"throughput regressed {drop:.0%} (committed {want_tp:.0f}, fresh "
                f"{got_tp:.0f} {fresh.get('throughput_unit', '')}; "
                f"threshold {args.threshold:.0%})")
        else:
            print(f"bench_check[{area}]: throughput {got_tp:.0f} vs committed "
                  f"{want_tp:.0f} ({-drop:+.0%}) -- within threshold.")

    if failures:
        for f in failures:
            print(f"bench_check[{area}]: FAIL: {f}", file=sys.stderr)
        sys.exit(1)
    print(f"bench_check[{area}]: OK ({len(base_counts)} counts exact, "
          f"throughput within {args.threshold:.0%}).")


if __name__ == "__main__":
    main()
