//! Synthetic nf-core-like workload generation — the substitute for the
//! paper's eager/sarek trace recordings (DESIGN.md §3).
//!
//! The paper gathered traces by running two real bioinformatics
//! workflows for days on an EPYC 7282 box. We cannot re-run nf-core
//! here, so this module generates traces with the same *statistical
//! structure* the k-Segments method exploits:
//!
//! * per-task-type **temporal memory profiles** (ramp, plateau, bell,
//!   multi-phase, late-spike, sawtooth, ...) — usage varies over time
//!   within a task, which is the entire optimization potential of
//!   Fig. 1;
//! * **input-size-linear** runtime and peak scaling with
//!   heteroscedastic noise — the regression signal every learned
//!   method (LR-Witt, k-Segments) assumes;
//! * per-type execution counts, runtime ranges and peak ranges
//!   calibrated to the paper's §IV-B description (eager: 18 types,
//!   8 s–4 h, 19 MB–14 GB, ≤136 executions; sarek: 29 types, 2 s–1 h,
//!   10 MB–23 GB, ≤1512 executions; 33 evaluated types in total);
//! * developer **default allocations** that overprovision generously
//!   and never fail (the paper's sanity baseline shows zero retries).

mod catalog;
mod generate;
mod profiles;
mod spec;

pub use catalog::{eager_workflow, sarek_workflow, EVAL_MIN_RUNS};
pub use generate::{
    generate_paper_traces, generate_workflow_trace, ground_truth_curve, synth_execution,
    MONITOR_INTERVAL_S,
};
pub use profiles::ProfileShape;
pub use spec::{TaskTypeSpec, WorkflowSpec};
