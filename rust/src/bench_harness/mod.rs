//! Benchmark + figure-regeneration harness.
//!
//! * [`timer`] — minimal criterion-style measurement (offline cache has
//!   no criterion) and [`timer::Stopwatch`], the crate's only
//!   sanctioned wall-clock;
//! * [`bench`] — `ksegments bench`: one `BENCH_<area>.json` perf
//!   snapshot per area (sched / replay / grid / service), the
//!   committed perf trajectory CI diffs against;
//! * [`figures`] — one entry point per paper figure (Fig. 1, 4, 7a–c,
//!   8), shared by the CLI and the `cargo bench` targets;
//! * [`throughput`] — the scheduling sweeps: makespan / queue-wait /
//!   packing tables per (policy × predictor × arrival rate), the
//!   dependency-gated workflow tables per (policy × predictor ×
//!   concurrent-instance count), and the failure-domain adversity
//!   tables per (predictor × failure rate × autoscale lag) with the
//!   `BENCH_sched.json` scheduler-throughput snapshot.

pub mod ablation;
pub mod bench;
pub mod figures;
pub mod report;
pub mod throughput;
pub mod timer;

pub use bench::{run_bench_area, sched_snapshot, BenchSnapshot, BENCH_AREAS, BENCH_SCHEMA_VERSION};

pub use figures::{
    evaluate_method, fig7_makers, make_method, makers_for_keys, method_names, method_roster,
    paper_traces, resolve_methods, run_fig1, run_fig4, run_fig7, run_fig7_selected, run_fig8,
    Fig7Results, Fig8Results, FitterChoice, EXTRA_METHOD_KEYS, METHOD_KEYS,
};
pub use throughput::{
    bench_sched_json, run_dag_throughput, run_failure_sweep, run_failure_sweep_axes,
    run_throughput, throughput_makers, DagThroughputResults, FailureSweepResults,
    ThroughputResults, FAILURE_SWEEP_LAGS, FAILURE_SWEEP_RATES,
};
pub use timer::{bench, black_box, time_once, Measurement, Stopwatch};
