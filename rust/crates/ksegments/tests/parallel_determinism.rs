//! Concurrency/determinism lockdown for the parallel evaluation
//! engine: the `EvalGrid` must produce **bit-identical** reports for
//! any worker count — parallelism is a wall-clock optimisation, never
//! a source of numeric drift. Every figure in EXPERIMENTS.md depends
//! on this.

use ksegments::bench_harness::{
    fig7_makers, makers_for_keys, method_names, paper_traces, run_fig8, FitterChoice,
};
use ksegments::cluster::NodeSpec;
use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::ppm::PpmPredictor;
use ksegments::sched::{DagGrid, ReservationPolicy, SchedConfig, SchedGrid};
use ksegments::sim::{parallel_map, EvalGrid, PredictorFactory};
use ksegments::units::MemMiB;
use ksegments::workload::{eager_workflow, generate_workflow_trace};

/// The headline satellite: the full fig7 grid (9 methods × 3 fractions
/// × 2 workflows) at seed 42 is bit-identical at workers = 1 and
/// workers = 8 — same wastage, same retries, same task ordering.
#[test]
fn fig7_grid_bit_identical_across_worker_counts() {
    let traces = paper_traces(42);
    let fractions = vec![0.25, 0.5, 0.75];
    let grid = EvalGrid::new(fig7_makers(FitterChoice::Native), &traces, fractions);
    let seq = grid.run(1);
    let par = grid.run(8);

    // whole-structure equality first (MethodReport is PartialEq all
    // the way down to per-run wastage samples) ...
    assert_eq!(seq, par, "workers=8 diverged from workers=1");

    // ... then the paper-shaped spot checks, so a regression prints
    // something legible instead of a giant struct diff.
    assert_eq!(seq.by_fraction.len(), 3);
    for (f, (s_row, p_row)) in seq.by_fraction.iter().zip(&par.by_fraction).enumerate() {
        assert_eq!(s_row.len(), 9, "fraction {f} must cover the 9-method roster");
        for (s, p) in s_row.iter().zip(p_row) {
            assert_eq!(s.method, p.method);
            assert_eq!(s.total_wastage_gbs().to_bits(), p.total_wastage_gbs().to_bits());
            assert_eq!(s.total_retries(), p.total_retries());
            let s_types: Vec<&str> = s.tasks.iter().map(|t| t.task_type.as_str()).collect();
            let p_types: Vec<&str> = p.tasks.iter().map(|t| t.task_type.as_str()).collect();
            assert_eq!(s_types, p_types, "task ordering changed under parallelism");
        }
    }

    // method axis order must match the published roster order
    let grid_methods: Vec<String> =
        seq.by_fraction[0].iter().map(|r| r.method.clone()).collect();
    assert_eq!(grid_methods, method_names());
}

/// Focused lockdown for the two zoo methods this PR adds: an
/// ensemble+dynseg-only grid is bit-identical at workers = 1 and
/// workers = 8 (the full-roster test above covers them too, but this
/// isolates a regression to the new predictors).
#[test]
fn ensemble_and_dynseg_bit_identical_across_worker_counts() {
    let traces = paper_traces(42);
    let makers = makers_for_keys(&["ensemble", "dynseg"], FitterChoice::Native);
    let grid = EvalGrid::new(makers, &traces, vec![0.25, 0.5, 0.75]);
    let seq = grid.run(1);
    let par = grid.run(8);
    assert_eq!(seq, par, "zoo grid diverged under parallelism");
    for row in &seq.by_fraction {
        assert_eq!(row.len(), 2);
        assert_eq!(row[0].method, "Sizey Ensemble");
        assert_eq!(row[1].method, "KS+ DynSeg Selective");
        for rep in row {
            assert!(!rep.tasks.is_empty(), "{} scored no tasks", rep.method);
        }
    }
}

/// The fig8 k-sweep goes through the same pool and must be equally
/// scheduling-independent.
#[test]
fn fig8_sweep_identical_across_worker_counts() {
    let ks: Vec<usize> = (1..=8).collect();
    let seq = run_fig8(42, FitterChoice::Native, "eager/adapter_removal", &ks, 1);
    let par = run_fig8(42, FitterChoice::Native, "eager/adapter_removal", &ks, 8);
    assert_eq!(seq.task, par.task);
    assert_eq!(seq.sweep.len(), par.sweep.len());
    for ((k_s, w_s), (k_p, w_p)) in seq.sweep.iter().zip(&par.sweep) {
        assert_eq!(k_s, k_p);
        assert_eq!(w_s.to_bits(), w_p.to_bits(), "k={k_s} wastage differs by bits");
    }
}

/// parallel_map under a worker pool larger than the work list, odd
/// pool sizes, and heavy oversubscription keeps output order.
#[test]
fn parallel_map_order_under_contention() {
    let n = 500;
    let expect: Vec<usize> = (0..n).map(|i| i.wrapping_mul(2654435761)).collect();
    for workers in [1, 2, 3, 7, 16, 64] {
        let got = parallel_map(n, workers, |i| i.wrapping_mul(2654435761));
        assert_eq!(got, expect, "workers={workers}");
    }
}

/// The scheduling sweep rides the same pool: the full (policy ×
/// predictor × cluster × arrival) grid over the eager trace at seed 42
/// is bit-identical at workers = 1 and workers = 8 — every counter,
/// every float, every queue-wait sample.
#[test]
fn sched_grid_bit_identical_across_worker_counts() {
    let traces = vec![generate_workflow_trace(&eager_workflow(), 42)];
    let mut methods: Vec<PredictorFactory> = vec![
        Box::new(|| Box::new(DefaultConfigPredictor::new())),
        Box::new(|| Box::new(PpmPredictor::improved())),
    ];
    // the zoo methods ride the same deterministic sweep
    methods.extend(makers_for_keys(&["ensemble", "dynseg"], FitterChoice::Native));
    let grid = SchedGrid::new(
        vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise],
        methods,
        &traces,
        vec![2],
        vec![3.0, 9.0],
    )
    .with_base(
        SchedConfig { seed: 42, training_frac: 0.5, ..SchedConfig::default() },
        NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 },
    );
    let seq = grid.run(1);
    let par = grid.run(8);
    assert_eq!(seq, par, "sched grid diverged under parallelism");
    assert_eq!(seq.reports.len(), 2 * 4 * 2);
    for (cell, rep) in seq.cells.iter().zip(&seq.reports) {
        assert_eq!(rep.completed, rep.submitted, "cell {cell:?} lost tasks");
        assert_eq!(
            rep.admitted,
            rep.completed + rep.oom_kills + rep.grow_denials + rep.preempted + rep.node_lost,
            "cell {cell:?} accounting broken"
        );
    }
}

/// The failure-domain sweep rides the same pool: (predictor × failure
/// rate × autoscale lag) over the eager trace at seed 42 is
/// bit-identical at workers = 1 and workers = 8 — forked RNG streams
/// make the injected failures part of the cell, not of the schedule.
#[test]
fn failure_grid_bit_identical_across_worker_counts() {
    use ksegments::sched::FailureGrid;
    let traces = vec![generate_workflow_trace(&eager_workflow(), 42)];
    let mut methods: Vec<PredictorFactory> = vec![
        Box::new(|| Box::new(DefaultConfigPredictor::new())),
        Box::new(|| Box::new(PpmPredictor::improved())),
    ];
    methods.extend(makers_for_keys(&["condor"], FitterChoice::Native));
    let grid = FailureGrid::new(methods, &traces, vec![0.0, 0.01], vec![None, Some(30.0)])
        .with_base(
            SchedConfig { seed: 42, training_frac: 0.5, ..SchedConfig::default() },
            NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 },
            2,
        );
    let seq = grid.run(1);
    let par = grid.run(8);
    assert_eq!(seq, par, "failure grid diverged under parallelism");
    assert_eq!(seq.reports.len(), 3 * 2 * 2);
    let mut any_lost = false;
    for (cell, rep) in seq.cells.iter().zip(&seq.reports) {
        assert_eq!(rep.completed, rep.submitted, "cell {cell:?} lost tasks");
        assert_eq!(
            rep.admitted,
            rep.completed + rep.oom_kills + rep.grow_denials + rep.preempted + rep.node_lost,
            "cell {cell:?} accounting broken"
        );
        if cell.rate_idx == 0 {
            assert_eq!(rep.node_failures, 0, "cell {cell:?}: failures in the control column");
        }
        any_lost |= rep.node_lost > 0;
    }
    assert!(any_lost, "mtbf 100s over the eager stream should kill at least one attempt");
}

/// The dependency-gated DAG sweep rides the same pool: (policy ×
/// predictor × concurrent-instance count) over the eager workflow at
/// seed 42 is bit-identical at workers = 1 and workers = 8 — workflow
/// makespans, critical paths and straggler counts included.
#[test]
fn dag_grid_bit_identical_across_worker_counts() {
    let wf = eager_workflow();
    let mut methods: Vec<PredictorFactory> = vec![
        Box::new(|| Box::new(DefaultConfigPredictor::new())),
        Box::new(|| Box::new(PpmPredictor::improved())),
    ];
    methods.extend(makers_for_keys(&["ensemble", "dynseg"], FitterChoice::Native));
    let grid = DagGrid::new(
        vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise],
        methods,
        &wf,
        vec![2],
        vec![2, 4],
    )
    .with_base(
        SchedConfig { seed: 42, ..SchedConfig::default() },
        NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 },
    );
    let seq = grid.run(1);
    let par = grid.run(8);
    assert_eq!(seq, par, "DAG grid diverged under parallelism");
    assert_eq!(seq.reports.len(), 2 * 4 * 1 * 2);
    for (cell, rep) in seq.cells.iter().zip(&seq.reports) {
        assert_eq!(rep.workflows_completed, rep.workflows_submitted, "cell {cell:?}");
        assert_eq!(rep.completed, rep.submitted, "cell {cell:?} lost tasks");
        assert_eq!(
            rep.workflow_makespans.len() as u64,
            rep.workflows_completed,
            "cell {cell:?}"
        );
    }
}

/// Cells see an immutable trace: running the same grid twice (any
/// worker counts) gives the same answer — no hidden shared state
/// between runs or cells.
#[test]
fn grid_runs_are_repeatable() {
    let traces = paper_traces(7);
    let grid = EvalGrid::new(fig7_makers(FitterChoice::Native), &traces, vec![0.5]);
    let a = grid.run(4);
    let b = grid.run(3);
    assert_eq!(a, b, "repeat run with different pool size diverged");
}
