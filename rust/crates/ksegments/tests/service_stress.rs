//! Concurrency stress suite for the sharded prediction service: many
//! client threads × mixed predict/complete/failure traffic across many
//! task types, exact aggregated counters, per-type FIFO under
//! sharding, and clean behaviour when the service is dropped while
//! traffic is still flowing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use ksegments::coordinator::{PredictionService, ServiceStats, ShardedPredictionService};
use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::{Allocation, FailureInfo};
use ksegments::trace::{TaskRun, UsageSeries};
use ksegments::units::{MemMiB, Seconds};

const N_CLIENTS: usize = 16;
const TYPES_PER_CLIENT: usize = 2; // 32 task types total, hashed over the shards

fn mk_run(ty: &str, input: f64, peak: f64, seq: u64) -> TaskRun {
    let samples: Vec<f64> = (0..8).map(|j| peak * (j + 1) as f64 / 8.0).collect();
    TaskRun {
        task_type: ty.into(),
        input_mib: input,
        runtime: Seconds(16.0),
        series: UsageSeries::new(2.0, samples),
        seq,
    }
}

/// 16 clients × mixed traffic over 32 task types against 4 shards:
/// aggregated totals must be exact, and each client's
/// completions-then-predict sequence must observe the per-task-type
/// FIFO guarantee (the predict returns a trained, dynamic allocation).
#[test]
fn sixteen_clients_mixed_traffic_exact_totals_and_fifo() {
    const COMPLETIONS_PER_TYPE: u64 = 12;
    const PREDICTS_PER_TYPE: u64 = 5;
    const FAILURES_PER_TYPE: u64 = 3;

    let svc = ShardedPredictionService::spawn(4, |_| {
        Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
    });
    let mut joins = Vec::new();
    for c in 0..N_CLIENTS {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            for t in 0..TYPES_PER_CLIENT {
                let ty = format!("stress/c{c}_t{t}");
                h.prime(&ty, MemMiB(2048.0));
                // online phase: completions first ...
                for i in 0..COMPLETIONS_PER_TYPE {
                    h.complete(mk_run(&ty, 100.0 + 10.0 * i as f64, 200.0 + 10.0 * i as f64, i));
                }
                // ... then predicts; FIFO per task type means every one
                // of these sees the trained model, never the default
                for i in 0..PREDICTS_PER_TYPE {
                    let alloc = h.predict(&ty, 150.0 + i as f64);
                    assert!(
                        alloc.is_dynamic(),
                        "{ty}: predict #{i} answered before the completions were ingested"
                    );
                }
                for i in 0..FAILURES_PER_TYPE {
                    let failed = Allocation::Static(MemMiB(100.0 + i as f64));
                    let info = FailureInfo::oom(1.0, 400.0, 1 + i as u32);
                    let next = h.report_failure(&ty, 150.0, failed, info);
                    assert!(next.max_value() > 0.0);
                }
            }
        }));
    }
    for j in joins {
        j.join().expect("client thread panicked");
    }

    let per_shard = svc.shutdown_per_shard();
    assert_eq!(per_shard.len(), 4);
    let total = ServiceStats::aggregated(&per_shard);
    let n_types = (N_CLIENTS * TYPES_PER_CLIENT) as u64;
    assert_eq!(total.predictions, n_types * PREDICTS_PER_TYPE);
    assert_eq!(total.completions, n_types * COMPLETIONS_PER_TYPE);
    assert_eq!(total.failures, n_types * FAILURES_PER_TYPE);
    // 32 FNV-hashed types over 4 shards: every shard took traffic
    assert!(
        per_shard.iter().all(|s| s.completions > 0),
        "a shard sat idle: {per_shard:?}"
    );
}

/// Dropping the service mid-traffic must never panic a client:
/// fire-and-forget sends fail silently, blocking calls return `None`
/// through the `try_` variants.
#[test]
fn drop_mid_traffic_is_panic_free() {
    let svc = ShardedPredictionService::spawn(3, |_| Box::new(DefaultConfigPredictor::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for c in 0..N_CLIENTS {
        let h = svc.handle();
        let stop = Arc::clone(&stop);
        joins.push(std::thread::spawn(move || {
            let mut sent = 0u64;
            let mut refused = 0u64;
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) || refused == 0 {
                let ty = format!("drop/c{}_t{}", c, i % 4);
                match h.try_predict(&ty, i as f64) {
                    Some(_) => sent += 1,
                    None => refused += 1,
                }
                h.complete(mk_run(&ty, 1.0, 10.0, i)); // silently dropped after shutdown
                i += 1;
                if i > 200_000 {
                    break; // liveness guard; the service must be long gone by now
                }
            }
            (sent, refused)
        }));
    }
    // let traffic build up, then yank the service out from under the clients
    std::thread::sleep(std::time::Duration::from_millis(20));
    drop(svc);
    stop.store(true, Ordering::Relaxed);
    let mut total_refused = 0;
    for j in joins {
        let (_sent, refused) = j.join().expect("client panicked during service drop");
        total_refused += refused;
    }
    assert!(total_refused > 0, "every client finished before the drop landed");
}

/// shards=1 through the sharded code path behaves exactly like the
/// single-model PredictionService under the same concurrent traffic.
#[test]
fn single_shard_matches_prediction_service_totals() {
    let sharded = ShardedPredictionService::spawn(1, |_| Box::new(DefaultConfigPredictor::new()));
    let single = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
    for (h, svc_name) in [(sharded.handle(), "sharded"), (single.handle(), "single")] {
        let mut joins = Vec::new();
        for c in 0..8 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..100 {
                    let ty = format!("eq/c{c}");
                    let _ = h.predict(&ty, i as f64);
                    h.complete(mk_run(&ty, i as f64, 50.0, i));
                }
            }));
        }
        for j in joins {
            j.join().unwrap_or_else(|_| panic!("{svc_name} client panicked"));
        }
    }
    let a = sharded.shutdown();
    let b = single.shutdown();
    assert_eq!(a.predictions, 800);
    assert_eq!(a.predictions, b.predictions);
    assert_eq!(a.completions, b.completions);
    assert_eq!(a.failures, b.failures);
}

/// Many concurrent `stats` readers against a service under live
/// write traffic: every snapshot is internally consistent and
/// monotone per reader, and once the writers drain the very next
/// snapshot is exact — the service-side path behind the wire
/// protocol's `stats` frame.
#[test]
fn concurrent_stats_readers_see_monotone_then_exact_totals() {
    const WRITERS: usize = 4;
    const READERS: usize = 4;
    const RUNS_PER_WRITER: u64 = 200;

    let svc = ShardedPredictionService::spawn(3, |_| Box::new(DefaultConfigPredictor::new()));
    let done = Arc::new(AtomicBool::new(false));

    let mut writers = Vec::new();
    for c in 0..WRITERS {
        let h = svc.handle();
        writers.push(std::thread::spawn(move || {
            let ty = format!("snap/w{c}");
            h.prime(&ty, MemMiB(128.0));
            for i in 0..RUNS_PER_WRITER {
                let _ = h.predict(&ty, i as f64);
                h.complete(mk_run(&ty, i as f64, 50.0, i));
            }
        }));
    }
    let mut readers = Vec::new();
    for _ in 0..READERS {
        let h = svc.handle();
        let done = Arc::clone(&done);
        readers.push(std::thread::spawn(move || {
            let mut polls = 0u64;
            let mut last = 0u64;
            while !done.load(Ordering::Relaxed) {
                let s = h.stats();
                assert!(
                    s.completions >= last,
                    "completions went backwards: {last} -> {}",
                    s.completions
                );
                last = s.completions;
                polls += 1;
            }
            polls
        }));
    }
    for w in writers {
        w.join().expect("writer panicked");
    }
    // writers joined: every complete is enqueued, so per-shard FIFO
    // makes this live snapshot exact — no quiescing sleep needed
    let live = svc.handle().stats();
    assert_eq!(live.predictions, WRITERS as u64 * RUNS_PER_WRITER);
    assert_eq!(live.completions, WRITERS as u64 * RUNS_PER_WRITER);
    done.store(true, Ordering::Relaxed);
    for r in readers {
        let polls = r.join().expect("reader panicked");
        assert!(polls > 0, "a reader never got a snapshot in");
    }
    let fin = svc.shutdown();
    assert_eq!(fin.predictions, live.predictions);
    assert_eq!(fin.completions, live.completions);
}

/// Aggregated stats observed through a live handle equal the sum of
/// per-shard stats at shutdown once traffic has quiesced.
#[test]
fn live_stats_equal_final_stats_after_quiescence() {
    let svc = ShardedPredictionService::spawn(5, |_| Box::new(DefaultConfigPredictor::new()));
    let h = svc.handle();
    for i in 0..64 {
        let ty = format!("stats/t{i}");
        h.prime(&ty, MemMiB(256.0));
        let _ = h.predict(&ty, 1.0);
        h.complete(mk_run(&ty, 1.0, 10.0, 0));
    }
    // predict is blocking, so after the final predict every earlier
    // message on every shard it shares a channel with is processed;
    // completions on other shards may still be in flight — the Stats
    // request queues behind them per shard, so the totals are exact.
    let live = h.stats();
    assert_eq!(live.predictions, 64);
    assert_eq!(live.completions, 64);
    let fin = svc.shutdown();
    assert_eq!(fin.predictions, live.predictions);
    assert_eq!(fin.completions, live.completions);
}
