//! The TCP front-end of the sharded prediction service.
//!
//! One accept thread plus one std thread per connection — the same
//! "blocking callers around channel-owned models" architecture as the
//! coordinator itself, so the per-type FIFO contract carries through
//! unchanged: a connection's frames are parsed and dispatched in
//! arrival order, and each gets exactly one response in that order
//! (pipelining-safe). Malformed frames answer with a typed error and,
//! when the framing itself is intact, the connection keeps serving.
//!
//! **Drain semantics.** A `shutdown` frame (or [`NetServer::stop`])
//! flips a shared flag: the listener stops accepting, every connection
//! finishes answering the frames it has already buffered (bounded by
//! `drain_timeout_ms`), the shards are joined for their final
//! counters, and — when configured — the predictor checkpoint is
//! saved. Responses written before the close are never abandoned.
//!
//! **Warm restart.** With [`NetServerConfig::restore`] set, the
//! service is primed from an [`ingest::Checkpoint`] before the
//! listener accepts its first connection, and with `checkpoint_out`
//! set the server keeps recording (starting from the restored state),
//! so `restore(ck_half) + remaining traffic` saves byte-identical
//! state to an uninterrupted run — checkpoint serialization is
//! deterministic.
//!
//! [`ingest::Checkpoint`]: crate::ingest::Checkpoint

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use ksegments_core::source::{InMemorySource, DEFAULT_CHUNK};
use ksegments_core::telemetry::Registry;
use ksegments_core::util::timer::Stopwatch;

use crate::coordinator::{ServiceHandle, ServiceStats, ShardedPredictionService};
use crate::ingest::Checkpoint;
use crate::net::frame::{
    take_frame, write_alloc_frame, write_error_frame, write_fed_frame, write_ok_frame,
    write_stats_frame, ErrCode, NetError, NetRequest, MAX_FRAME_DEFAULT,
};

/// Tuning knobs for [`NetServer::spawn`].
pub struct NetServerConfig {
    /// Hard cap on any frame's payload size.
    pub max_frame: usize,
    /// Socket read timeout — the cadence at which idle connections
    /// notice the stop flag.
    pub read_timeout_ms: u64,
    /// After stop, how long a connection keeps answering frames it has
    /// already buffered before closing anyway.
    pub drain_timeout_ms: u64,
    /// Warm-start the predictors from this checkpoint before accepting.
    pub restore: Option<Checkpoint>,
    /// Record primes/completions and save the checkpoint here on drain.
    pub checkpoint_out: Option<PathBuf>,
}

impl Default for NetServerConfig {
    fn default() -> NetServerConfig {
        NetServerConfig {
            max_frame: MAX_FRAME_DEFAULT,
            read_timeout_ms: 25,
            drain_timeout_ms: 2000,
            restore: None,
            checkpoint_out: None,
        }
    }
}

/// Network-layer counters, shared across all connection threads.
#[derive(Default)]
pub struct NetCounters {
    pub connections: AtomicU64,
    pub frames: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub predictions: AtomicU64,
    pub completions: AtomicU64,
    pub failures: AtomicU64,
    pub replayed_runs: AtomicU64,
}

/// A plain-value snapshot of [`NetCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetSnapshot {
    pub connections: u64,
    pub frames: u64,
    pub responses: u64,
    pub errors: u64,
    pub predictions: u64,
    pub completions: u64,
    pub failures: u64,
    pub replayed_runs: u64,
}

impl NetCounters {
    pub fn snapshot(&self) -> NetSnapshot {
        NetSnapshot {
            connections: self.connections.load(Ordering::SeqCst),
            frames: self.frames.load(Ordering::SeqCst),
            responses: self.responses.load(Ordering::SeqCst),
            errors: self.errors.load(Ordering::SeqCst),
            predictions: self.predictions.load(Ordering::SeqCst),
            completions: self.completions.load(Ordering::SeqCst),
            failures: self.failures.load(Ordering::SeqCst),
            replayed_runs: self.replayed_runs.load(Ordering::SeqCst),
        }
    }

    fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::SeqCst);
    }
}

/// Export the network counters into a metrics registry (the
/// service-shard counters export separately via
/// [`export_service_metrics`]).
///
/// [`export_service_metrics`]: crate::coordinator::export_service_metrics
pub fn export_net_metrics(net: &NetSnapshot, reg: &mut Registry) {
    reg.counter_add("net_connections_total", net.connections);
    reg.counter_add("net_frames_total", net.frames);
    reg.counter_add("net_responses_total", net.responses);
    reg.counter_add("net_errors_total", net.errors);
    reg.counter_add("net_predictions_total", net.predictions);
    reg.counter_add("net_completions_total", net.completions);
    reg.counter_add("net_failures_total", net.failures);
    reg.counter_add("net_replayed_runs_total", net.replayed_runs);
}

/// What a drained server hands back: the shards' final counters, the
/// network-layer counters, and where the checkpoint was saved (if
/// configured).
#[derive(Debug)]
pub struct ServerReport {
    /// Final per-shard service counters, in shard order.
    pub per_shard: Vec<ServiceStats>,
    pub net: NetSnapshot,
    pub checkpoint_out: Option<PathBuf>,
}

impl ServerReport {
    /// Aggregated service counters across shards.
    pub fn total(&self) -> ServiceStats {
        ServiceStats::aggregated(&self.per_shard)
    }
}

/// A running TCP server; join it with [`NetServer::wait`] (blocks
/// until a `shutdown` frame drains it) or [`NetServer::stop`].
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    handle: ServiceHandle,
    accept: JoinHandle<Result<ServerReport>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), warm
    /// the service from `cfg.restore` if set, and start accepting.
    /// Takes ownership of the service: drain joins its shards.
    pub fn spawn(
        addr: &str,
        svc: ShardedPredictionService,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("resolving bound address")?;
        listener.set_nonblocking(true).context("setting listener non-blocking")?;
        let handle = svc.handle();
        if let Some(ck) = &cfg.restore {
            handle.restore_checkpoint(ck);
        }
        let NetServerConfig {
            max_frame,
            read_timeout_ms,
            drain_timeout_ms,
            restore,
            checkpoint_out,
        } = cfg;
        let ckpt = checkpoint_out.as_ref().map(|_| {
            Arc::new(Mutex::new(
                restore.unwrap_or_else(|| Checkpoint::new(Checkpoint::DEFAULT_WINDOW)),
            ))
        });
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(NetCounters::default());
        let conn_cfg = ConnConfig { max_frame, read_timeout_ms, drain_timeout_ms };
        let accept = {
            let stop = stop.clone();
            let counters = counters.clone();
            std::thread::Builder::new()
                .name("ksegments-net-accept".to_string())
                .spawn(move || {
                    accept_loop(listener, svc, stop, counters, ckpt, conn_cfg, checkpoint_out)
                })
                .context("spawning accept thread")?
        };
        Ok(NetServer { addr: local, stop, counters, handle, accept })
    }

    /// The actually bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// An in-process handle to the fronted service (tests use this to
    /// observe live stats without a connection).
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Live network-layer counters.
    pub fn net_snapshot(&self) -> NetSnapshot {
        self.counters.snapshot()
    }

    /// Block until a `shutdown` frame (or [`NetServer::stop`] from
    /// another thread holding the struct) drains the server.
    pub fn wait(self) -> Result<ServerReport> {
        self.accept.join().map_err(|_| anyhow!("accept thread panicked"))?
    }

    /// Request drain from the host process and join.
    pub fn stop(self) -> Result<ServerReport> {
        self.stop.store(true, Ordering::SeqCst);
        self.wait()
    }
}

/// Per-connection knobs, copied out of [`NetServerConfig`].
#[derive(Clone, Copy)]
struct ConnConfig {
    max_frame: usize,
    read_timeout_ms: u64,
    drain_timeout_ms: u64,
}

type SharedCheckpoint = Option<Arc<Mutex<Checkpoint>>>;

fn accept_loop(
    listener: TcpListener,
    svc: ShardedPredictionService,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    ckpt: SharedCheckpoint,
    cfg: ConnConfig,
    checkpoint_out: Option<PathBuf>,
) -> Result<ServerReport> {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                NetCounters::bump(&counters.connections);
                let h = svc.handle();
                let stop = stop.clone();
                let counters = counters.clone();
                let ckpt = ckpt.clone();
                let conn = std::thread::Builder::new()
                    .name("ksegments-net-conn".to_string())
                    .spawn(move || {
                        // a connection-level I/O error (peer reset,
                        // write to a closed socket) ends that
                        // connection only, never the server
                        let _ = serve_connection(stream, h, stop, counters, ckpt, cfg);
                    })
                    .context("spawning connection thread")?;
                conns.push(conn);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accepting connection"),
        }
    }
    // drain: no new connections, existing ones answer what they have
    drop(listener);
    for conn in conns {
        let _ = conn.join();
    }
    let per_shard = svc.shutdown_per_shard();
    let checkpoint_out = save_checkpoint_on_drain(checkpoint_out, ckpt)?;
    Ok(ServerReport { per_shard, net: counters.snapshot(), checkpoint_out })
}

/// Save the drain-time checkpoint when both a path and a recorder are
/// configured. A poisoned recorder (a connection thread panicked
/// mid-record) is a hard drain error, never a panic — and never a
/// torn checkpoint file.
fn save_checkpoint_on_drain(
    checkpoint_out: Option<PathBuf>,
    ckpt: SharedCheckpoint,
) -> Result<Option<PathBuf>> {
    match (checkpoint_out, ckpt) {
        (Some(path), Some(ck)) => {
            let ck = ck
                .lock()
                .map_err(|_| anyhow!("checkpoint recorder poisoned; refusing to save"))?;
            ck.save(&path).with_context(|| format!("saving checkpoint {}", path.display()))?;
            Ok(Some(path))
        }
        _ => Ok(None),
    }
}

fn serve_connection(
    mut stream: TcpStream,
    h: ServiceHandle,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    ckpt: SharedCheckpoint,
    cfg: ConnConfig,
) -> io::Result<()> {
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(Duration::from_millis(cfg.read_timeout_ms.max(1))))?;
    let mut pending: Vec<u8> = Vec::new();
    let mut resp: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 16 * 1024];
    let mut drain_clock: Option<Stopwatch> = None;
    loop {
        // answer every complete frame already buffered, in order
        loop {
            match take_frame(&mut pending, cfg.max_frame) {
                Ok(Some(payload)) => {
                    handle_frame(&payload, &h, &stop, &counters, &ckpt, &mut resp)?;
                    stream.write_all(&resp)?;
                }
                Ok(None) => break,
                Err(err) => {
                    // framing is lost: typed error, then close
                    NetCounters::bump(&counters.errors);
                    NetCounters::bump(&counters.responses);
                    write_error_frame(&mut resp, &err)?;
                    let _ = stream.write_all(&resp);
                    return Ok(());
                }
            }
        }
        if stop.load(Ordering::SeqCst) {
            if pending.is_empty() {
                return Ok(());
            }
            let clock = drain_clock.get_or_insert_with(Stopwatch::start);
            if clock.elapsed_s() * 1000.0 > cfg.drain_timeout_ms as f64 {
                return Ok(());
            }
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                if !pending.is_empty() {
                    // EOF inside a frame: report it, best-effort
                    NetCounters::bump(&counters.errors);
                    NetCounters::bump(&counters.responses);
                    write_error_frame(
                        &mut resp,
                        &NetError::new(
                            ErrCode::TruncatedFrame,
                            "connection closed inside a frame",
                        ),
                    )?;
                    let _ = stream.write_all(&resp);
                }
                return Ok(());
            }
            // in bounds: read() returns at most tmp.len() bytes
            Ok(n) => pending.extend_from_slice(&tmp[..n]), // lint:allow(panic-policy)
            Err(e) if is_wait(&e) => {}
            Err(e) => return Err(e),
        }
    }
}

/// Read errors that just mean "no bytes yet" under a read timeout.
fn is_wait(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
    )
}

/// Dispatch one parsed frame and serialize its response into `resp`
/// (a fully framed buffer, reused across frames).
fn handle_frame(
    payload: &[u8],
    h: &ServiceHandle,
    stop: &AtomicBool,
    counters: &NetCounters,
    ckpt: &SharedCheckpoint,
    resp: &mut Vec<u8>,
) -> io::Result<()> {
    NetCounters::bump(&counters.frames);
    NetCounters::bump(&counters.responses);
    let unavailable = |resp: &mut Vec<u8>, counters: &NetCounters, id: u64| {
        NetCounters::bump(&counters.errors);
        write_error_frame(
            resp,
            &NetError::with_id(ErrCode::Unavailable, "prediction service is down", id),
        )
    };
    // panic-policy: a poisoned checkpoint recorder (a connection
    // thread panicked mid-record) answers with a typed error instead
    // of panicking this thread too; the request is NOT applied to the
    // service either, so recorded state and live state cannot diverge
    // from each other
    let poisoned = |resp: &mut Vec<u8>, counters: &NetCounters, id: u64| {
        NetCounters::bump(&counters.errors);
        write_error_frame(
            resp,
            &NetError::with_id(ErrCode::Unavailable, "checkpoint recorder poisoned", id),
        )
    };
    let (id, req) = match crate::net::frame::parse_request(payload) {
        Ok(parsed) => parsed,
        Err(err) => {
            NetCounters::bump(&counters.errors);
            return write_error_frame(resp, &err);
        }
    };
    match req {
        NetRequest::Prime { task_type, default } => {
            if let Some(ck) = ckpt {
                match ck.lock() {
                    Ok(mut ck) => ck.record_default(&task_type, default),
                    Err(_) => return poisoned(resp, counters, id),
                }
            }
            h.prime(&task_type, default);
            write_ok_frame(resp, id)
        }
        NetRequest::Predict { task_type, input_mib } => {
            match h.try_predict(&task_type, input_mib) {
                Some(alloc) => {
                    NetCounters::bump(&counters.predictions);
                    write_alloc_frame(resp, id, &alloc)
                }
                None => unavailable(resp, counters, id),
            }
        }
        NetRequest::ReportFailure { task_type, input_mib, failed, info } => {
            match h.try_report_failure(&task_type, input_mib, failed, info) {
                Some(alloc) => {
                    NetCounters::bump(&counters.failures);
                    write_alloc_frame(resp, id, &alloc)
                }
                None => unavailable(resp, counters, id),
            }
        }
        NetRequest::Complete { run } => {
            if let Some(ck) = ckpt {
                match ck.lock() {
                    Ok(mut ck) => ck.record(&run),
                    Err(_) => return poisoned(resp, counters, id),
                }
            }
            NetCounters::bump(&counters.completions);
            h.complete(*run);
            write_ok_frame(resp, id)
        }
        NetRequest::Replay { runs } => {
            if let Some(ck) = ckpt {
                match ck.lock() {
                    Ok(mut ck) => {
                        for run in &runs {
                            ck.record(run);
                        }
                    }
                    Err(_) => return poisoned(resp, counters, id),
                }
            }
            let mut src = InMemorySource::from_runs(Vec::new(), runs);
            match h.replay_source(&mut src, DEFAULT_CHUNK) {
                Ok(fed) => {
                    counters.predictions.fetch_add(fed, Ordering::SeqCst);
                    counters.completions.fetch_add(fed, Ordering::SeqCst);
                    counters.replayed_runs.fetch_add(fed, Ordering::SeqCst);
                    write_fed_frame(resp, id, fed)
                }
                Err(e) => {
                    NetCounters::bump(&counters.errors);
                    write_error_frame(
                        resp,
                        &NetError::with_id(ErrCode::Unavailable, e.to_string(), id),
                    )
                }
            }
        }
        NetRequest::Stats => match h.try_per_shard_stats() {
            Some(per_shard) => {
                write_stats_frame(resp, id, &ServiceStats::aggregated(&per_shard), &per_shard)
            }
            None => unavailable(resp, counters, id),
        },
        NetRequest::Shutdown => {
            stop.store(true, Ordering::SeqCst);
            write_ok_frame(resp, id)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use ksegments_core::predictors::default_config::DefaultConfigPredictor;
    use ksegments_core::trace::{run_record, TaskRun, UsageSeries};
    use ksegments_core::units::Seconds;
    use ksegments_core::util::json::Json;

    use crate::net::frame::{parse_response, NetResponse, LEN_PREFIX};

    /// A checkpoint recorder whose mutex has been poisoned by a
    /// panicking holder — the failure mode the typed `unavailable`
    /// responses in `handle_frame` and the drain-save error path guard
    /// against (regression tests for the former `expect()` sites).
    fn poisoned_ckpt() -> Arc<Mutex<Checkpoint>> {
        let ck = Arc::new(Mutex::new(Checkpoint::new(Checkpoint::DEFAULT_WINDOW)));
        let c2 = ck.clone();
        let _ = std::thread::spawn(move || {
            let _guard = c2.lock().unwrap();
            panic!("poisoning the recorder on purpose");
        })
        .join();
        assert!(ck.lock().is_err(), "recorder must start poisoned");
        ck
    }

    fn toy_run(seq: u64) -> TaskRun {
        TaskRun {
            task_type: "wf/task".into(),
            input_mib: 10.0,
            runtime: Seconds(4.0),
            series: UsageSeries::new(2.0, vec![50.0, 100.0]),
            seq,
        }
    }

    /// Dispatch one request through `handle_frame` against a poisoned
    /// recorder; returns the parsed response plus the net and service
    /// counters after the call.
    fn dispatch_poisoned(doc: Json) -> (NetResponse, NetSnapshot, ServiceStats) {
        let svc = ShardedPredictionService::spawn(1, |_| Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        let stop = AtomicBool::new(false);
        let counters = NetCounters::default();
        let ckpt: SharedCheckpoint = Some(poisoned_ckpt());
        let mut resp = Vec::new();
        handle_frame(doc.to_string().as_bytes(), &h, &stop, &counters, &ckpt, &mut resp)
            .expect("writing into a Vec cannot fail");
        let parsed = parse_response(&resp[LEN_PREFIX..]).expect("well-formed response frame");
        let net = counters.snapshot();
        let stats = ServiceStats::aggregated(&svc.shutdown_per_shard());
        (parsed, net, stats)
    }

    fn assert_poisoned_error(resp: &NetResponse, id: u64) {
        assert!(!resp.ok);
        assert_eq!(resp.id, Some(id));
        let (code, msg) = resp.error.as_ref().expect("typed error body");
        assert_eq!(code, "unavailable");
        assert_eq!(msg, "checkpoint recorder poisoned");
    }

    #[test]
    fn prime_on_poisoned_recorder_is_typed_error_not_panic() {
        let doc = Json::obj(vec![
            ("method", "prime".into()),
            ("id", 7u64.into()),
            ("task_type", "wf/task".into()),
            ("default_mib", 2048.0.into()),
        ]);
        let (resp, net, stats) = dispatch_poisoned(doc);
        assert_poisoned_error(&resp, 7);
        assert_eq!(net.errors, 1);
        // the prime was NOT applied: recorded state and live state
        // stay in lockstep even when the recorder is lost
        assert_eq!(stats.completions, 0);
    }

    #[test]
    fn complete_on_poisoned_recorder_is_typed_error_not_panic() {
        let doc = Json::obj(vec![
            ("method", "complete".into()),
            ("id", 8u64.into()),
            ("run", run_record(&toy_run(0))),
        ]);
        let (resp, net, stats) = dispatch_poisoned(doc);
        assert_poisoned_error(&resp, 8);
        assert_eq!(net.errors, 1);
        assert_eq!(net.completions, 0, "completion counter must not advance");
        assert_eq!(stats.completions, 0, "service must not observe the run");
    }

    #[test]
    fn replay_on_poisoned_recorder_is_typed_error_not_panic() {
        let doc = Json::obj(vec![
            ("method", "replay".into()),
            ("id", 9u64.into()),
            ("runs", Json::Arr(vec![run_record(&toy_run(0)), run_record(&toy_run(1))])),
        ]);
        let (resp, net, stats) = dispatch_poisoned(doc);
        assert_poisoned_error(&resp, 9);
        assert_eq!(net.errors, 1);
        assert_eq!(net.replayed_runs, 0, "no run may be fed past the failed record");
        assert_eq!(stats.predictions, 0);
        assert_eq!(stats.completions, 0);
    }

    #[test]
    fn drain_save_on_poisoned_recorder_is_error_not_panic() {
        let dir = std::env::temp_dir().join("ksegments_poisoned_drain_test");
        let path = dir.join("ck.json");
        let err = save_checkpoint_on_drain(Some(path.clone()), Some(poisoned_ckpt()))
            .expect_err("poisoned recorder must fail the drain");
        assert!(err.to_string().contains("poisoned"), "unexpected error: {err:#}");
        assert!(!path.exists(), "no torn checkpoint file may be written");
    }

    #[test]
    fn drain_save_without_checkpoint_is_noop() {
        assert!(matches!(save_checkpoint_on_drain(None, None), Ok(None)));
        let ck = Arc::new(Mutex::new(Checkpoint::new(4)));
        // recorder configured but no output path: nothing to save
        assert!(matches!(save_checkpoint_on_drain(None, Some(ck)), Ok(None)));
    }

    #[test]
    fn net_metrics_export_names() {
        let snap = NetSnapshot {
            connections: 2,
            frames: 10,
            responses: 10,
            errors: 1,
            predictions: 4,
            completions: 4,
            failures: 0,
            replayed_runs: 3,
        };
        let mut reg = Registry::new();
        export_net_metrics(&snap, &mut reg);
        assert_eq!(reg.counter("net_connections_total"), 2);
        assert_eq!(reg.counter("net_frames_total"), 10);
        assert_eq!(reg.counter("net_errors_total"), 1);
        assert_eq!(reg.counter("net_replayed_runs_total"), 3);
    }

    #[test]
    fn config_default_is_sane() {
        let cfg = NetServerConfig::default();
        assert_eq!(cfg.max_frame, MAX_FRAME_DEFAULT);
        assert!(cfg.restore.is_none());
        assert!(cfg.checkpoint_out.is_none());
        assert!(cfg.drain_timeout_ms >= cfg.read_timeout_ms);
    }
}
