//! The paper's §III-B change-point segmentation and per-segment peak
//! extraction (`Y* → Y**`) — the f64 mirror of the `segpeaks` Pallas
//! kernel.

/// Change points evenly distributed over a series of length `t`:
/// `i = floor(t/k)`; segment `s` is `[s·i, (s+1)·i)` for `s < k−1`, and
/// the last segment absorbs the remainder `[(k−1)·i, t)`.
///
/// Panics when `k == 0` or `t < k` (some segment would be empty).
pub fn segment_bounds(t: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k >= 1, "k must be >= 1");
    assert!(t >= k, "series length {t} shorter than k={k}");
    let i = t / k;
    let mut out = Vec::with_capacity(k);
    for s in 0..k - 1 {
        out.push((s * i, (s + 1) * i));
    }
    out.push(((k - 1) * i, t));
    out
}

/// Time boundaries of the k segments over a predicted runtime `r_e`,
/// mirrored from the index segmentation of a `t`-sample series: the
/// paper's change points sit at `(s+1)·⌊t/k⌋` samples (§III-B/§III-C —
/// the LAST segment absorbs the remainder), so in time the boundary of
/// segment `s < k−1` is `r_e · (s+1)·⌊t/k⌋ / t` and the last is `r_e`.
///
/// Using equal splits of `r_e` instead would misalign the predicted
/// values (trained on floor-segmented peaks) with the interval they
/// cover whenever `k ∤ t` — a systematic underprediction at segment
/// tails caught by the adaptive-k counterfactual tests.
pub fn segment_time_bounds(r_e: f64, t: usize, k: usize) -> Vec<f64> {
    assert!(r_e > 0.0, "non-positive runtime");
    segment_bounds(t, k)
        .into_iter()
        .map(|(_, hi)| r_e * hi as f64 / t as f64)
        .collect()
}

/// Per-segment peaks `Y** = (max(s_1), ..., max(s_k))` of one series.
pub fn seg_peaks(samples: &[f64], k: usize) -> Vec<f64> {
    seg_peaks_with_bounds(samples, &segment_bounds(samples.len(), k))
}

/// Per-segment peaks over caller-supplied index bounds (the dynamic
/// segmentation path: bounds come from change-point detection on the
/// window's mean curve, not from the equal-width split).
pub fn seg_peaks_with_bounds(samples: &[f64], bounds: &[(usize, usize)]) -> Vec<f64> {
    bounds
        .iter()
        .map(|&(lo, hi)| samples[lo..hi].iter().copied().fold(f64::MIN, f64::max))
        .collect()
}

/// Map index bounds over a `t`-sample grid onto time boundaries of a
/// predicted runtime `r_e` (same formula as [`segment_time_bounds`],
/// generalized to arbitrary change points).
pub fn index_bounds_to_time(r_e: f64, t: usize, bounds: &[(usize, usize)]) -> Vec<f64> {
    assert!(r_e > 0.0, "non-positive runtime");
    bounds.iter().map(|&(_, hi)| r_e * hi as f64 / t as f64).collect()
}

/// Wastage cost of covering `curve[lo..hi)` with one flat piece at the
/// segment max: `Σ (max − y_i)` — exactly the over-allocation integral
/// (in sample units) a step-function segment pays on this curve.
fn segment_cost(curve: &[f64], lo: usize, hi: usize) -> f64 {
    let max = curve[lo..hi].iter().copied().fold(f64::MIN, f64::max);
    curve[lo..hi].iter().map(|y| max - y).sum()
}

/// Best interior split of `curve[lo..hi)`: the position `lo < p < hi`
/// minimizing `cost(lo,p) + cost(p,hi)`, with the earliest such `p` on
/// ties (deterministic). `None` when the segment is too short to split.
///
/// O(hi − lo): one backward pass builds suffix max/sum (the right
/// piece), one forward pass sweeps the left piece's running max/sum.
fn best_split(curve: &[f64], lo: usize, hi: usize) -> Option<(usize, f64)> {
    let len = hi - lo;
    if len < 2 {
        return None;
    }
    // suffix[i] = (max, sum) of curve[lo+i..hi)
    let mut suffix = vec![(f64::MIN, 0.0f64); len + 1];
    for i in (0..len).rev() {
        let y = curve[lo + i];
        let (m, s) = suffix[i + 1];
        suffix[i] = (m.max(y), s + y);
    }
    let mut best: Option<(usize, f64)> = None;
    let mut left_max = f64::MIN;
    let mut left_sum = 0.0f64;
    for p in lo + 1..hi {
        let y = curve[p - 1];
        left_max = left_max.max(y);
        left_sum += y;
        let n_left = (p - lo) as f64;
        let (r_max, r_sum) = suffix[p - lo];
        let n_right = (hi - p) as f64;
        let cost = (n_left * left_max - left_sum) + (n_right * r_max - r_sum);
        let better = match best {
            Some((_, c)) => cost < c,
            None => true,
        };
        if better {
            best = Some((p, cost));
        }
    }
    best
}

/// KS+-style change-point segmentation: split the curve into at most
/// `k` segments by **greedy error-minimizing binary splits** instead of
/// `k` equal-width bins. Each round splits whichever existing segment
/// yields the largest strictly-positive reduction of the total
/// flat-piece wastage cost; ties break toward the earliest segment and
/// earliest position, so the result is fully deterministic. A curve
/// that no split can improve (e.g. constant usage) stops early with
/// fewer than `k` segments — the budget is a ceiling, not a quota.
///
/// Returns contiguous half-open index ranges covering `[0, t)`.
/// Panics when `k == 0` or the curve is empty.
pub fn greedy_segment_bounds(curve: &[f64], k: usize) -> Vec<(usize, usize)> {
    assert!(k >= 1, "k must be >= 1");
    let t = curve.len();
    assert!(t >= 1, "empty curve");
    let mut segs: Vec<(usize, usize)> = vec![(0, t)];
    while segs.len() < k.min(t) {
        let mut winner: Option<(usize, usize, f64)> = None; // (seg idx, pos, reduction)
        for (i, &(lo, hi)) in segs.iter().enumerate() {
            let Some((p, split_cost)) = best_split(curve, lo, hi) else {
                continue;
            };
            let reduction = segment_cost(curve, lo, hi) - split_cost;
            let better = match winner {
                Some((_, _, r)) => reduction > r,
                None => true,
            };
            if reduction > 0.0 && better {
                winner = Some((i, p, reduction));
            }
        }
        let Some((i, p, _)) = winner else {
            break; // nothing left to gain: fewer than k segments
        };
        let (lo, hi) = segs[i];
        segs[i] = (lo, p);
        segs.insert(i + 1, (p, hi));
    }
    segs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split() {
        assert_eq!(segment_bounds(8, 4), vec![(0, 2), (2, 4), (4, 6), (6, 8)]);
    }

    #[test]
    fn remainder_to_last() {
        assert_eq!(segment_bounds(10, 4), vec![(0, 2), (2, 4), (4, 6), (6, 10)]);
    }

    #[test]
    fn k1_whole_series() {
        assert_eq!(segment_bounds(17, 1), vec![(0, 17)]);
    }

    #[test]
    fn covers_exactly_no_overlap() {
        for t in [4usize, 7, 16, 100, 256] {
            for k in 1..=t.min(16) {
                let b = segment_bounds(t, k);
                assert_eq!(b.len(), k);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[k - 1].1, t);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                assert!(b.iter().all(|(lo, hi)| hi > lo));
            }
        }
    }

    #[test]
    #[should_panic]
    fn k_zero_panics() {
        segment_bounds(10, 0);
    }

    #[test]
    #[should_panic]
    fn t_less_than_k_panics() {
        segment_bounds(3, 4);
    }

    #[test]
    fn peaks_known_values() {
        let y = [1.0, 5.0, 2.0, 3.0, 9.0, 0.0];
        assert_eq!(seg_peaks(&y, 3), vec![5.0, 3.0, 9.0]);
    }

    #[test]
    fn peaks_k1_is_global_max() {
        let y = [3.0, 7.0, 1.0];
        assert_eq!(seg_peaks(&y, 1), vec![7.0]);
    }

    #[test]
    fn peaks_match_python_reference_semantics() {
        // same as ref.segpeaks_ref: uneven split, remainder in last
        let y = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        // t=7, k=3 -> i=2: [0,2) [2,4) [4,7)
        assert_eq!(seg_peaks(&y, 3), vec![2.0, 4.0, 7.0]);
    }

    #[test]
    fn peaks_with_custom_bounds() {
        let y = [1.0, 5.0, 2.0, 3.0, 9.0, 0.0];
        assert_eq!(
            seg_peaks_with_bounds(&y, &[(0, 1), (1, 5), (5, 6)]),
            vec![1.0, 9.0, 0.0]
        );
    }

    #[test]
    fn greedy_finds_the_change_point_of_a_step_profile() {
        // flat 10 for 12 samples, then flat 100 for 4: one split at the
        // jump removes ALL wastage — greedy must find index 12 exactly.
        let mut y = vec![10.0; 12];
        y.extend(vec![100.0; 4]);
        let b = greedy_segment_bounds(&y, 2);
        assert_eq!(b, vec![(0, 12), (12, 16)]);
        // k budget above what helps: constant pieces can't be improved,
        // so the result stays at 2 segments even with budget 4
        assert_eq!(greedy_segment_bounds(&y, 4), vec![(0, 12), (12, 16)]);
    }

    #[test]
    fn greedy_on_linear_ramp_matches_equal_width() {
        // a straight line's optimal binary splits are midpoints, so the
        // greedy bounds coincide with the equal-width segmentation when
        // k divides t — the equal-k-budget differential anchor
        let y: Vec<f64> = (0..256).map(|i| i as f64).collect();
        assert_eq!(greedy_segment_bounds(&y, 4), segment_bounds(256, 4));
    }

    #[test]
    fn greedy_flat_curve_stays_single_segment() {
        let y = vec![7.0; 32];
        assert_eq!(greedy_segment_bounds(&y, 8), vec![(0, 32)]);
    }

    #[test]
    fn greedy_covers_exactly_and_respects_budget() {
        let y: Vec<f64> = (0..100)
            .map(|i| ((i * 2654435761usize) % 977) as f64)
            .collect();
        for k in 1..=16 {
            let b = greedy_segment_bounds(&y, k);
            assert!(!b.is_empty() && b.len() <= k);
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, 100);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            assert!(b.iter().all(|(lo, hi)| hi > lo));
        }
    }

    #[test]
    fn greedy_single_sample_and_k1() {
        assert_eq!(greedy_segment_bounds(&[5.0], 3), vec![(0, 1)]);
        assert_eq!(greedy_segment_bounds(&[1.0, 9.0, 1.0], 1), vec![(0, 3)]);
    }

    #[test]
    fn index_bounds_map_to_time() {
        let b = vec![(0usize, 3usize), (3, 4)];
        let t = index_bounds_to_time(40.0, 4, &b);
        assert_eq!(t, vec![30.0, 40.0]);
    }
}
