//! Parameter study: the Fig. 8 k-sweep through the public API, over
//! any task of either workflow — how the segment count trades
//! granularity against prediction-error risk (paper §IV-E).
//!
//! Run: `cargo run --release --example k_sweep [task ...]`

use ksegments::bench_harness::{run_fig8, FitterChoice};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tasks: Vec<String> = if args.is_empty() {
        vec![
            "eager/qualimap".to_string(),        // zigzag (Fig. 8a)
            "eager/adapter_removal".to_string(), // smooth decrease (Fig. 8b)
            "eager/markduplicates".to_string(),  // late spike: big-k payoff
        ]
    } else {
        args
    };

    let ks: Vec<usize> = (1..=15).collect();
    for task in &tasks {
        let r = run_fig8(42, FitterChoice::Native, task, &ks);
        println!("{}", r.render());
        // the paper's point: there is structure here worth optimizing —
        // report the gain of the per-task optimum over the k=4 default
        let w4 = r.sweep.iter().find(|(k, _)| *k == 4).unwrap().1;
        let best = r.sweep.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
        println!(
            "per-task k tuning: k={} saves {:.1}% over the k=4 default\n",
            best.0,
            100.0 * (1.0 - best.1 / w4)
        );
    }
}
