//! Integration lockdown for the cluster scheduler: the headline
//! packing claim (segment-wise reservations beat static-peak on a
//! ramp-profile workload at fixed capacity), the accounting
//! conservation identities under randomized configs, permutation
//! invariance of `SchedReport` merging, and end-to-end determinism
//! with a real (learning) predictor.

use ksegments::cluster::NodeSpec;
use ksegments::ml::step_fn::StepFunction;
use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::{Allocation, FailureInfo, MemoryPredictor};
use ksegments::rng::Rng;
use ksegments::sched::{schedule_trace, ReservationPolicy, SchedConfig, SchedReport};
use ksegments::trace::{TaskRun, Trace, UsageSeries};
use ksegments::units::{MemMiB, Seconds};
use ksegments::workload::{eager_workflow, generate_workflow_trace};

/// Ramp trace: every run climbs linearly to `peak` over `n_samples`
/// 2-second samples.
fn ramp_trace(n_runs: usize, peak: f64, n_samples: usize) -> Trace {
    let mut t = Trace::new();
    t.set_default("w/ramp", MemMiB(peak * 1.2));
    for i in 0..n_runs {
        let samples: Vec<f64> =
            (0..n_samples).map(|j| peak * (j + 1) as f64 / n_samples as f64).collect();
        t.push(TaskRun {
            task_type: "w/ramp".into(),
            input_mib: 100.0,
            runtime: Seconds(n_samples as f64 * 2.0),
            series: UsageSeries::new(2.0, samples),
            seq: i as u64,
        });
    }
    t.sort();
    t
}

/// Oracle predictor: a k-step function whose segment values are the
/// exact per-segment peaks of the reference series — isolates the
/// reservation-policy effect from prediction error.
struct OracleRamp {
    series: UsageSeries,
    k: usize,
}
impl OracleRamp {
    fn for_trace(trace: &Trace, ty: &str, k: usize) -> OracleRamp {
        OracleRamp { series: trace.runs_of(ty)[0].series.clone(), k }
    }
}
impl MemoryPredictor for OracleRamp {
    fn name(&self) -> String {
        "oracle-ramp".into()
    }
    fn prime(&mut self, _: &str, _: MemMiB) {}
    fn predict(&mut self, _: &str, _: f64) -> Allocation {
        let rt = self.series.duration().0;
        let dt = self.series.interval().0;
        let samples = self.series.samples();
        let values: Vec<f64> = (1..=self.k)
            .map(|s| {
                let lo = rt * (s - 1) as f64 / self.k as f64;
                let hi = rt * s as f64 / self.k as f64;
                samples
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| {
                        let t0 = *j as f64 * dt;
                        t0 < hi && t0 + dt > lo
                    })
                    .map(|(_, &u)| u)
                    .fold(0.0f64, f64::max)
            })
            .collect();
        Allocation::Dynamic(StepFunction::monotone_clamped(
            Seconds(rt),
            values,
            MemMiB(1.0),
            MemMiB(1e9),
        ))
    }
    fn on_failure(&mut self, _: &str, _: f64, _: &Allocation, _: &FailureInfo) -> Allocation {
        Allocation::Static(MemMiB(self.series.peak()))
    }
    fn observe(&mut self, _: &TaskRun) {}
}

fn identities(r: &SchedReport) {
    assert_eq!(r.completed, r.submitted, "every task must leave the system");
    assert_eq!(
        r.admitted,
        r.completed + r.oom_kills + r.grow_denials + r.preempted + r.node_lost,
        "every admitted attempt ends exactly one way"
    );
    assert_eq!(
        r.placement_attempts,
        r.admitted + r.rejected,
        "every placement attempt admits or rejects"
    );
    assert_eq!(r.queue_waits.len() as u64, r.admitted);
}

/// The acceptance-criterion test: on a ramp-profile workload at fixed
/// cluster capacity, segment-wise reservations admit strictly more
/// concurrent tasks and finish the stream strictly sooner than
/// static-peak reservations.
#[test]
fn segment_wise_beats_static_peak_on_ramp_workload() {
    let trace = ramp_trace(8, 1000.0, 10); // peak 1 GB-ish, 20 s runtime
    let cfg = |policy| SchedConfig {
        policy,
        nodes: vec![NodeSpec { mem: MemMiB(2000.0), cores: 8 }], // 2 static tasks max
        mean_interarrival: Seconds(5.0),
        deterministic_arrivals: true,
        seed: 1,
        training_frac: 0.0,
        max_attempts: 10,
        event_log_cap: 0,
        ..SchedConfig::default()
    };
    let mk = || OracleRamp::for_trace(&trace, "w/ramp", 4);
    let stat = schedule_trace(&trace, &mut mk(), &cfg(ReservationPolicy::StaticPeak));
    let segw = schedule_trace(&trace, &mut mk(), &cfg(ReservationPolicy::SegmentWise));

    identities(&stat);
    identities(&segw);
    assert_eq!(stat.completed, 8);
    assert_eq!(segw.completed, 8);
    assert_eq!(stat.oom_kills + segw.oom_kills, 0, "oracle predictions never OOM");

    // static-peak can hold exactly 2 × 1000 MiB at once
    assert_eq!(stat.peak_running, 2);
    // step-function packing overlaps early small segments with late
    // big ones — strictly more co-located tasks, strictly lower
    // makespan, shorter queues, less reserved-but-unused memory
    assert!(segw.peak_running > stat.peak_running, "{} !> {}", segw.peak_running, stat.peak_running);
    assert!(segw.makespan.0 < stat.makespan.0, "{} !< {}", segw.makespan.0, stat.makespan.0);
    assert!(segw.mean_queue_wait_s() < stat.mean_queue_wait_s());
    assert!(segw.total_wastage.0 < stat.total_wastage.0);
}

/// Conservation identities under randomized traces, cluster shapes,
/// policies and (sometimes undersized) defaults.
#[test]
fn conservation_identities_under_random_configs() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed + 4000);
        let mut trace = Trace::new();
        let n_types = 1 + rng.below(3);
        for ty in 0..n_types {
            let name = format!("w/t{ty}");
            let peak = rng.uniform(100.0, 2000.0);
            // sometimes undersized -> OOM-kill/requeue paths exercised
            let default = if rng.f64() < 0.5 { peak * 1.5 } else { peak * 0.1 };
            trace.set_default(&name, MemMiB(default));
            for i in 0..(3 + rng.below(10)) {
                let n = 2 + rng.below(12) as usize;
                let samples: Vec<f64> =
                    (0..n).map(|j| peak * (j + 1) as f64 / n as f64).collect();
                trace.push(TaskRun {
                    task_type: name.clone(),
                    input_mib: rng.uniform(10.0, 500.0),
                    runtime: Seconds(n as f64 * 2.0),
                    series: UsageSeries::new(2.0, samples),
                    seq: ty * 1000 + i,
                });
            }
        }
        trace.sort();
        let policy = if rng.f64() < 0.5 {
            ReservationPolicy::StaticPeak
        } else {
            ReservationPolicy::SegmentWise
        };
        let cfg = SchedConfig {
            policy,
            nodes: vec![
                NodeSpec { mem: MemMiB(rng.uniform(2000.0, 6000.0)), cores: 4 };
                1 + rng.below(3) as usize
            ],
            mean_interarrival: Seconds(rng.uniform(0.0, 6.0)),
            deterministic_arrivals: false,
            seed,
            training_frac: 0.0,
            max_attempts: 8,
            event_log_cap: 100,
            ..SchedConfig::default()
        };
        let mut p = DefaultConfigPredictor::new();
        let r = schedule_trace(&trace, &mut p, &cfg);
        identities(&r);
        assert!(r.makespan.0 >= 0.0, "seed {seed}");
        assert!(r.peak_util_frac <= 1.0 + 1e-9, "seed {seed}: over-reserved");
    }
}

/// Conservation identities under seeded failure injection: random
/// traces and cluster shapes with node loss, preemption, and the
/// autoscaler all randomly enabled. The extended identity
/// (`admitted == completed + oom_kills + grow_denials + preempted +
/// node_lost`) must hold exactly, every task must still finish, and
/// the run must replay bit-identically.
#[test]
fn conservation_identities_under_seeded_failure_injection() {
    use ksegments::sched::AutoscaleConfig;
    let mut any_lost = false;
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed + 7000);
        let mut trace = Trace::new();
        let peak = rng.uniform(200.0, 1500.0);
        // sometimes undersized -> OOM paths interleave with blameless kills
        let default = peak * if rng.f64() < 0.5 { 1.5 } else { 0.2 };
        trace.set_default("w/f", MemMiB(default));
        for i in 0..(5 + rng.below(15)) {
            let n = 3 + rng.below(10) as usize;
            let samples: Vec<f64> = (0..n).map(|j| peak * (j + 1) as f64 / n as f64).collect();
            trace.push(TaskRun {
                task_type: "w/f".into(),
                input_mib: rng.uniform(10.0, 500.0),
                runtime: Seconds(n as f64 * 2.0),
                series: UsageSeries::new(2.0, samples),
                seq: i,
            });
        }
        trace.sort();
        let cfg = SchedConfig {
            policy: if rng.f64() < 0.5 {
                ReservationPolicy::StaticPeak
            } else {
                ReservationPolicy::SegmentWise
            },
            nodes: vec![
                NodeSpec { mem: MemMiB(rng.uniform(2000.0, 6000.0)), cores: 4 };
                1 + rng.below(3) as usize
            ],
            mean_interarrival: Seconds(rng.uniform(0.0, 6.0)),
            seed,
            training_frac: 0.0,
            max_attempts: 8,
            fail_mtbf: Seconds(rng.uniform(5.0, 60.0)),
            fail_downtime: Seconds(rng.uniform(1.0, 30.0)),
            preempt: rng.f64() < 0.5,
            autoscale: if rng.f64() < 0.5 { Some(AutoscaleConfig::default()) } else { None },
            ..SchedConfig::default()
        };
        let mut p = DefaultConfigPredictor::new();
        let r = schedule_trace(&trace, &mut p, &cfg);
        identities(&r);
        assert!(r.peak_util_frac <= 1.0 + 1e-9, "seed {seed}: over-reserved");
        any_lost |= r.node_lost > 0;
        // bit-identical replay under adversity (fresh predictor)
        let mut p2 = DefaultConfigPredictor::new();
        let r2 = schedule_trace(&trace, &mut p2, &cfg);
        assert_eq!(r2, r, "seed {seed}: failure injection broke determinism");
    }
    assert!(any_lost, "25 seeds at mtbf 5-60s should requeue at least one task");
}

/// Merging per-trace partial reports is permutation-invariant: exact
/// for counters and extremes, float-reorder-tolerant for sums, and a
/// multiset match for the queue-wait samples.
#[test]
fn sched_report_merge_is_permutation_invariant() {
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed + 9000);
        // several small per-trace reports from real scheduler runs
        let mut parts: Vec<SchedReport> = (0..6)
            .map(|i| {
                let trace = ramp_trace(3 + (i % 3), 500.0 + 100.0 * i as f64, 6);
                let mut p = OracleRamp::for_trace(&trace, "w/ramp", 3);
                let cfg = SchedConfig {
                    policy: ReservationPolicy::SegmentWise,
                    nodes: vec![NodeSpec { mem: MemMiB(4000.0), cores: 4 }; 2],
                    mean_interarrival: Seconds(2.0),
                    seed: seed + i as u64,
                    training_frac: 0.0,
                    ..SchedConfig::default()
                };
                schedule_trace(&trace, &mut p, &cfg)
            })
            .collect();
        let reference = SchedReport::merged(parts.clone()).unwrap();
        rng.shuffle(&mut parts);
        let shuffled = SchedReport::merged(parts).unwrap();

        assert_eq!(shuffled.submitted, reference.submitted, "seed {seed}");
        assert_eq!(shuffled.completed, reference.completed, "seed {seed}");
        assert_eq!(shuffled.admitted, reference.admitted, "seed {seed}");
        assert_eq!(shuffled.rejected, reference.rejected, "seed {seed}");
        assert_eq!(shuffled.oom_kills, reference.oom_kills, "seed {seed}");
        assert_eq!(shuffled.grow_denials, reference.grow_denials, "seed {seed}");
        assert_eq!(shuffled.peak_running, reference.peak_running, "seed {seed}");
        assert_eq!(shuffled.makespan, reference.makespan, "seed {seed}: max is order-free");
        assert_eq!(shuffled.peak_util_frac, reference.peak_util_frac, "seed {seed}");
        assert!(
            close(shuffled.total_wastage.0, reference.total_wastage.0),
            "seed {seed}"
        );
        assert!(
            close(shuffled.reserved_integral_gbs, reference.reserved_integral_gbs),
            "seed {seed}"
        );
        assert!(close(shuffled.mean_queue_wait_s(), reference.mean_queue_wait_s()), "seed {seed}");
        let mut a = shuffled.queue_waits.clone();
        let mut b = reference.queue_waits.clone();
        a.sort_by(f64::total_cmp);
        b.sort_by(f64::total_cmp);
        assert_eq!(a, b, "seed {seed}: queue-wait samples are not the same multiset");
    }
}

/// End-to-end with the paper's learning predictor on the eager-like
/// workflow: deterministic replay, every task completes, and the
/// scheduler exercises the online loop (observations flow back).
#[test]
fn ksegments_schedules_eager_workflow_deterministically() {
    let trace = generate_workflow_trace(&eager_workflow(), 42);
    let run = || {
        let mut p = KSegmentsPredictor::native(4, RetryStrategy::Selective);
        let cfg = SchedConfig {
            policy: ReservationPolicy::SegmentWise,
            nodes: vec![NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 }; 2],
            mean_interarrival: Seconds(5.0),
            seed: 42,
            training_frac: 0.5,
            ..SchedConfig::default()
        };
        schedule_trace(&trace, &mut p, &cfg)
    };
    let a = run();
    identities(&a);
    assert!(a.submitted > 100, "eager stream should be substantial");
    assert_eq!(a.completed, a.submitted);
    assert!(a.makespan.0 > 0.0);
    assert!(a.peak_running >= 1);
    // bit-identical replay (fresh predictor, same seeds)
    let b = run();
    assert_eq!(a, b, "scheduler must be deterministic end to end");
}
