//! LR — Witt et al.'s feedback-loop linear-regression predictor [16].
//!
//! Learns `peak ~ input size` online and offsets the prediction to
//! avoid underprovisioning. The three offset strategies from the
//! original paper are implemented:
//!
//! * **MeanPlusStd** (`LR mean±`): add the standard deviation of the
//!   historical prediction errors — the variant the k-Segments paper
//!   uses as its LR baseline ("as an offset, they add the standard
//!   deviation");
//! * **MeanNeg** (`LR mean−`): add the mean magnitude of only the
//!   negative errors (overpredictions ignored);
//! * **MaxUnder** (`LR max`): add the largest observed underprediction.
//!
//! Failed tasks are assigned double the memory and executed again.

use crate::ml::linreg::LinReg;
use crate::trace::TaskRun;
use crate::units::MemMiB;

use super::history::HistoryMap;
use super::{Allocation, Defaults, FailureInfo, MemoryPredictor, MIN_ALLOC_MIB};

/// Offset strategy for the LR prediction (Witt et al. §offsetting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffsetStrategy {
    MeanPlusStd,
    MeanNeg,
    MaxUnder,
}

impl OffsetStrategy {
    pub fn label(&self) -> &'static str {
        match self {
            OffsetStrategy::MeanPlusStd => "mean±",
            OffsetStrategy::MeanNeg => "mean−",
            OffsetStrategy::MaxUnder => "max",
        }
    }
}

/// Witt et al.'s online LR predictor.
#[derive(Debug, Clone)]
pub struct LrWittPredictor {
    strategy: OffsetStrategy,
    node_max: MemMiB,
    defaults: Defaults,
    histories: HistoryMap,
}

impl LrWittPredictor {
    pub fn new(strategy: OffsetStrategy, node_max: MemMiB) -> Self {
        LrWittPredictor {
            strategy,
            node_max,
            defaults: Defaults::default(),
            histories: HistoryMap::new(1024, 1),
        }
    }

    /// The configuration the k-Segments paper benchmarks against.
    pub fn paper_baseline() -> Self {
        Self::new(OffsetStrategy::MeanPlusStd, MemMiB::from_gib(128.0))
    }
}

impl MemoryPredictor for LrWittPredictor {
    fn name(&self) -> String {
        format!("LR ({})", self.strategy.label())
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, input_mib: f64) -> Allocation {
        let Some(h) = self.histories.get(task_type) else {
            return Allocation::Static(self.defaults.get(task_type));
        };
        if h.len() < 2 {
            // a single observation cannot support a regression + error
            // model; stay on the default (the original method's warmup)
            return Allocation::Static(self.defaults.get(task_type));
        }
        let lr = LinReg::fit(h.x(), h.peaks());
        let st = lr.residuals(h.x(), h.peaks());
        let offset = match self.strategy {
            OffsetStrategy::MeanPlusStd => st.std(),
            OffsetStrategy::MeanNeg => st.mean_neg_magnitude(),
            OffsetStrategy::MaxUnder => st.max_under,
        };
        let pred = (lr.predict(input_mib) + offset)
            .max(MIN_ALLOC_MIB)
            .min(self.node_max.0);
        Allocation::Static(MemMiB(pred))
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        _info: &FailureInfo,
    ) -> Allocation {
        Allocation::Static(MemMiB((failed.max_value() * 2.0).min(self.node_max.0)))
    }

    fn observe(&mut self, run: &TaskRun) {
        self.histories.push(run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn run(input: f64, peak: f64) -> TaskRun {
        TaskRun {
            task_type: "t".into(),
            input_mib: input,
            runtime: Seconds(4.0),
            series: UsageSeries::new(2.0, vec![peak * 0.6, peak]),
            seq: 0,
        }
    }

    #[test]
    fn warmup_returns_default() {
        let mut p = LrWittPredictor::paper_baseline();
        p.prime("t", MemMiB(4096.0));
        assert_eq!(p.predict("t", 50.0), Allocation::Static(MemMiB(4096.0)));
        p.observe(&run(10.0, 100.0));
        assert_eq!(p.predict("t", 50.0), Allocation::Static(MemMiB(4096.0)));
    }

    #[test]
    fn learns_linear_relationship() {
        let mut p = LrWittPredictor::paper_baseline();
        for i in 1..=10 {
            let x = i as f64 * 100.0;
            p.observe(&run(x, 50.0 + 0.5 * x));
        }
        // noiseless -> std offset ~ 0; prediction ≈ 50 + 0.5 * 2000
        let Allocation::Static(m) = p.predict("t", 2000.0) else {
            panic!()
        };
        assert!((m.0 - 1050.0).abs() < 1.0, "{m:?}");
    }

    #[test]
    fn mean_plus_std_offsets_by_std() {
        let mut p = LrWittPredictor::new(OffsetStrategy::MeanPlusStd, MemMiB(1e9));
        // alternating residuals: peaks = 100 ± 10 at constant x -> the
        // regression falls back to the mean 100 with error std = 10
        for i in 0..10 {
            p.observe(&run(500.0, if i % 2 == 0 { 90.0 } else { 110.0 }));
        }
        let Allocation::Static(m) = p.predict("t", 500.0) else {
            panic!()
        };
        assert!((m.0 - 110.0).abs() < 2.0, "{m:?}"); // ≈ mean 100 + std 10
    }

    #[test]
    fn max_under_covers_worst_case() {
        let mut p = LrWittPredictor::new(OffsetStrategy::MaxUnder, MemMiB(1e9));
        for i in 0..8 {
            p.observe(&run(500.0, if i % 2 == 0 { 90.0 } else { 130.0 }));
        }
        let Allocation::Static(m) = p.predict("t", 500.0) else {
            panic!()
        };
        // mean = 110, max underprediction = 20 -> ≥ 130: covers every
        // historical peak
        assert!(m.0 >= 129.9, "{m:?}");
    }

    #[test]
    fn floor_and_cap_apply() {
        let mut p = LrWittPredictor::new(OffsetStrategy::MeanPlusStd, MemMiB(500.0));
        for i in 1..=4 {
            p.observe(&run(i as f64 * 100.0, 1.0)); // tiny peaks -> floor
        }
        let Allocation::Static(m) = p.predict("t", 100.0) else {
            panic!()
        };
        assert_eq!(m.0, MIN_ALLOC_MIB);
        // huge extrapolation -> cap
        for i in 1..=4 {
            p.observe(&run(i as f64 * 100.0, i as f64 * 300.0));
        }
        let Allocation::Static(m) = p.predict("t", 1e7) else {
            panic!()
        };
        assert_eq!(m.0, 500.0);
    }

    #[test]
    fn failure_doubles_capped() {
        let mut p = LrWittPredictor::paper_baseline();
        let info = FailureInfo::oom(0.0, 0.0, 1);
        let next = p.on_failure("t", 1.0, &Allocation::Static(MemMiB(300.0)), &info);
        assert_eq!(next, Allocation::Static(MemMiB(600.0)));
    }

    #[test]
    fn names_include_strategy() {
        assert_eq!(LrWittPredictor::paper_baseline().name(), "LR (mean±)");
        assert_eq!(
            LrWittPredictor::new(OffsetStrategy::MaxUnder, MemMiB(1.0)).name(),
            "LR (max)"
        );
    }
}
