#!/usr/bin/env python3
"""Gate a fresh `ksegments-lint --format json` report against the
committed invariants file.

Usage: lint_check.py LINT_invariants.json FRESH_report.json

Policy (mirrors tools/bench_check.py and DESIGN.md §15):
  * schema must match exactly ("ksegments-lint-v1");
  * violations must be empty -- the linter already exits non-zero on
    any, this re-checks the artifact so the gate holds even if the
    report was produced out-of-band;
  * every suppression's rule must be on the committed whitelist
    (today: panic-policy only -- the determinism passes carry zero
    waivers, pinned again by the crate's own meta-test);
  * per-file suppression COUNTS must match the committed map exactly.
    Line numbers churn with unrelated edits, so they are context, not
    gated. Adding or removing a `lint:allow` means editing
    rust/LINT_invariants.json in the same PR -- that diff is the
    review surface;
  * files_scanned must not drop below min_files_scanned (a walker
    regression that skips half the tree would otherwise pass
    vacuously);
  * a baseline marked "provisional": true records the fresh report and
    passes (same placeholder convention as BENCH_*.json).
"""

import argparse
import json
import sys
from collections import Counter


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"lint_check: cannot read {path}: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed LINT_invariants.json")
    ap.add_argument("fresh", help="fresh ksegments-lint --format json report")
    args = ap.parse_args()

    base = load(args.baseline)
    fresh = load(args.fresh)

    if base.get("provisional"):
        print("lint_check: baseline is provisional -- recording only, no gate.")
        print(json.dumps(fresh, indent=2, sort_keys=True))
        print(f'lint_check: commit this as {args.baseline} (with '
              '"provisional": false) to arm the gate.')
        return

    failures = []
    want_schema = base.get("schema")
    if fresh.get("schema") != want_schema:
        failures.append(f"schema mismatch: committed {want_schema!r}, "
                        f"fresh {fresh.get('schema')!r}")

    for v in fresh.get("violations", []):
        failures.append(f"violation: {v.get('path')}:{v.get('line')} "
                        f"[{v.get('rule')}] {v.get('message')}")

    allowed_rules = set(base.get("suppression_rules", []))
    got_counts = Counter()
    for s in fresh.get("suppressions", []):
        rule, path = s.get("rule"), s.get("path")
        if rule not in allowed_rules:
            failures.append(
                f"suppression of {rule!r} at {path}:{s.get('line')} -- only "
                f"{sorted(allowed_rules)} may carry lint:allow waivers")
        got_counts[path] += 1

    want_counts = {k: int(v) for k, v in base.get("suppressions", {}).items()}
    for path in sorted(set(want_counts) | set(got_counts)):
        want, got = want_counts.get(path, 0), got_counts.get(path, 0)
        if want != got:
            failures.append(
                f"suppression count for {path}: committed {want}, fresh {got} "
                "(update rust/LINT_invariants.json in the same PR that adds or "
                "removes a lint:allow)")

    floor = int(base.get("min_files_scanned", 0))
    scanned = int(fresh.get("files_scanned", 0))
    if scanned < floor:
        failures.append(f"files_scanned {scanned} below floor {floor} -- the "
                        "workspace walker is skipping files")

    if failures:
        for f in failures:
            print(f"lint_check: FAIL: {f}", file=sys.stderr)
        sys.exit(1)
    print(f"lint_check: OK ({scanned} files, 0 violations, "
          f"{sum(got_counts.values())} suppressions matching the committed map).")


if __name__ == "__main__":
    main()
