//! Parallel scheduling sweeps on the same worker pool as the
//! evaluation grid: [`SchedGrid`] over (policy × predictor × cluster
//! size × arrival rate) for independent arrivals, [`DagGrid`] over
//! (policy × predictor × cluster size × concurrent-workflow count) for
//! dependency-gated workflow instances, and [`FailureGrid`] over
//! (predictor × failure rate × autoscale lag) for the failure-domain
//! adversity sweeps.
//!
//! All mirror the sim evaluation grid (`EvalGrid`): cells are
//! enumerated in a canonical major order and executed via
//! [`parallel_map`]; every cell builds a fresh predictor and a fresh
//! cluster (and, for [`DagGrid`], regenerates its instances from the
//! seed), so results are bit-identical for any worker count.

use crate::cluster::NodeSpec;
use crate::sched::{
    schedule_trace, schedule_workflows, AutoscaleConfig, ReservationPolicy, SchedConfig,
    SchedReport, WorkflowSource,
};
use ksegments_core::trace::Trace;
use ksegments_core::units::Seconds;
use ksegments_core::workload::WorkflowSpec;
use ksegments_core::parallel::{parallel_map, PredictorFactory};

/// Index quadruple identifying one cell of a [`SchedGrid`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedCell {
    pub policy_idx: usize,
    pub method_idx: usize,
    pub nodes_idx: usize,
    pub arrival_idx: usize,
}

/// The sweep axes: reservation policies × predictor factories × node
/// counts × mean inter-arrival gaps, over a shared set of traces.
pub struct SchedGrid<'a> {
    policies: Vec<ReservationPolicy>,
    methods: Vec<PredictorFactory>,
    traces: &'a [Trace],
    node_counts: Vec<usize>,
    interarrivals: Vec<f64>,
    /// Template for per-cell configs (policy/nodes/interarrival are
    /// overwritten per cell; node specs replicate `node_spec`).
    base: SchedConfig,
    node_spec: NodeSpec,
}

/// Results of a [`SchedGrid`] run, in [`SchedGrid::cells`] order.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedGridResults {
    pub cells: Vec<SchedCell>,
    pub reports: Vec<SchedReport>,
}

impl SchedGridResults {
    /// Report of one cell by axis indices.
    pub fn report(
        &self,
        policy_idx: usize,
        method_idx: usize,
        nodes_idx: usize,
        arrival_idx: usize,
    ) -> Option<&SchedReport> {
        self.cells
            .iter()
            .position(|c| {
                c.policy_idx == policy_idx
                    && c.method_idx == method_idx
                    && c.nodes_idx == nodes_idx
                    && c.arrival_idx == arrival_idx
            })
            .map(|i| &self.reports[i])
    }
}

impl<'a> SchedGrid<'a> {
    pub fn new(
        policies: Vec<ReservationPolicy>,
        methods: Vec<PredictorFactory>,
        traces: &'a [Trace],
        node_counts: Vec<usize>,
        interarrivals: Vec<f64>,
    ) -> Self {
        assert!(!policies.is_empty(), "grid needs at least one policy");
        assert!(!methods.is_empty(), "grid needs at least one predictor factory");
        assert!(!traces.is_empty(), "grid needs at least one trace");
        assert!(!node_counts.is_empty(), "grid needs at least one cluster size");
        assert!(!interarrivals.is_empty(), "grid needs at least one arrival rate");
        SchedGrid {
            policies,
            methods,
            traces,
            node_counts,
            interarrivals,
            base: SchedConfig::default(),
            node_spec: NodeSpec::paper_testbed(),
        }
    }

    /// Override the per-cell config template (seed, training fraction,
    /// arrival determinism, ...) and the replicated node spec.
    pub fn with_base(mut self, base: SchedConfig, node_spec: NodeSpec) -> Self {
        self.base = base;
        self.node_spec = node_spec;
        self
    }

    pub fn n_cells(&self) -> usize {
        self.policies.len() * self.methods.len() * self.node_counts.len() * self.interarrivals.len()
    }

    /// Cell enumeration in canonical order: policy-major, then method,
    /// then cluster size, then arrival rate.
    pub fn cells(&self) -> Vec<SchedCell> {
        let mut out = Vec::with_capacity(self.n_cells());
        for policy_idx in 0..self.policies.len() {
            for method_idx in 0..self.methods.len() {
                for nodes_idx in 0..self.node_counts.len() {
                    for arrival_idx in 0..self.interarrivals.len() {
                        out.push(SchedCell { policy_idx, method_idx, nodes_idx, arrival_idx });
                    }
                }
            }
        }
        out
    }

    fn cell_config(&self, c: SchedCell) -> SchedConfig {
        SchedConfig {
            policy: self.policies[c.policy_idx],
            nodes: vec![self.node_spec; self.node_counts[c.nodes_idx]],
            mean_interarrival: Seconds(self.interarrivals[c.arrival_idx]),
            ..self.base.clone()
        }
    }

    /// Execute every cell on `workers` threads; per-trace reports are
    /// merged in trace order within each cell.
    pub fn run(&self, workers: usize) -> SchedGridResults {
        let cells = self.cells();
        let reports = parallel_map(cells.len(), workers, |i| {
            let c = cells[i];
            let cfg = self.cell_config(c);
            SchedReport::merged(self.traces.iter().map(|trace| {
                let mut predictor = (self.methods[c.method_idx])();
                schedule_trace(trace, predictor.as_mut(), &cfg)
            }))
            .expect("at least one trace per cell")
        });
        SchedGridResults { cells, reports }
    }
}

/// Index quadruple identifying one cell of a [`DagGrid`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DagCell {
    pub policy_idx: usize,
    pub method_idx: usize,
    pub nodes_idx: usize,
    pub instances_idx: usize,
}

/// Results of a [`DagGrid`] run, in [`DagGrid::cells`] order.
#[derive(Debug, Clone, PartialEq)]
pub struct DagGridResults {
    pub cells: Vec<DagCell>,
    pub reports: Vec<SchedReport>,
}

impl DagGridResults {
    /// Report of one cell by axis indices.
    pub fn report(
        &self,
        policy_idx: usize,
        method_idx: usize,
        nodes_idx: usize,
        instances_idx: usize,
    ) -> Option<&SchedReport> {
        self.cells
            .iter()
            .position(|c| {
                c.policy_idx == policy_idx
                    && c.method_idx == method_idx
                    && c.nodes_idx == nodes_idx
                    && c.instances_idx == instances_idx
            })
            .map(|i| &self.reports[i])
    }
}

/// The dependency-gated sweep: reservation policies × predictor
/// factories × cluster sizes × **concurrent workflow instance
/// counts**, all scheduling DAG executions of one [`WorkflowSpec`]
/// through [`schedule_workflows`].
pub struct DagGrid<'a> {
    policies: Vec<ReservationPolicy>,
    methods: Vec<PredictorFactory>,
    wf: &'a WorkflowSpec,
    node_counts: Vec<usize>,
    instance_counts: Vec<usize>,
    base: SchedConfig,
    node_spec: NodeSpec,
}

impl<'a> DagGrid<'a> {
    pub fn new(
        policies: Vec<ReservationPolicy>,
        methods: Vec<PredictorFactory>,
        wf: &'a WorkflowSpec,
        node_counts: Vec<usize>,
        instance_counts: Vec<usize>,
    ) -> Self {
        assert!(!policies.is_empty(), "grid needs at least one policy");
        assert!(!methods.is_empty(), "grid needs at least one predictor factory");
        assert!(!node_counts.is_empty(), "grid needs at least one cluster size");
        assert!(!instance_counts.is_empty(), "grid needs at least one instance count");
        DagGrid {
            policies,
            methods,
            wf,
            node_counts,
            instance_counts,
            base: SchedConfig::default(),
            node_spec: NodeSpec::paper_testbed(),
        }
    }

    /// Override the per-cell config template (seed, arrival shape, ...)
    /// and the replicated node spec.
    pub fn with_base(mut self, base: SchedConfig, node_spec: NodeSpec) -> Self {
        self.base = base;
        self.node_spec = node_spec;
        self
    }

    pub fn n_cells(&self) -> usize {
        self.policies.len()
            * self.methods.len()
            * self.node_counts.len()
            * self.instance_counts.len()
    }

    /// Canonical policy-major cell order (then method, cluster size,
    /// instance count).
    pub fn cells(&self) -> Vec<DagCell> {
        let mut out = Vec::with_capacity(self.n_cells());
        for policy_idx in 0..self.policies.len() {
            for method_idx in 0..self.methods.len() {
                for nodes_idx in 0..self.node_counts.len() {
                    for instances_idx in 0..self.instance_counts.len() {
                        out.push(DagCell { policy_idx, method_idx, nodes_idx, instances_idx });
                    }
                }
            }
        }
        out
    }

    /// Execute every cell on `workers` threads. Each cell regenerates
    /// its [`WorkflowSource`] from `base.seed` — the instances of two
    /// cells with equal instance counts are identical draws, so the
    /// policy/method axes compare like against like.
    pub fn run(&self, workers: usize) -> DagGridResults {
        let cells = self.cells();
        let reports = parallel_map(cells.len(), workers, |i| {
            let c = cells[i];
            let cfg = SchedConfig {
                policy: self.policies[c.policy_idx],
                nodes: vec![self.node_spec; self.node_counts[c.nodes_idx]],
                ..self.base.clone()
            };
            let src =
                WorkflowSource::from_spec(self.wf, cfg.seed, self.instance_counts[c.instances_idx]);
            let mut predictor = (self.methods[c.method_idx])();
            schedule_workflows(src, predictor.as_mut(), &cfg)
        });
        DagGridResults { cells, reports }
    }
}

/// Index triple identifying one cell of a [`FailureGrid`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailureCell {
    pub method_idx: usize,
    /// Index into the failure-rate axis (`fail_rates`).
    pub rate_idx: usize,
    /// Index into the autoscale-lag axis (`lags`).
    pub lag_idx: usize,
}

/// The failure-domain sweep: predictor factories × node-failure rates
/// × autoscale lags, at a fixed reservation policy. A rate of `0`
/// disables injection (the control column); a lag of `None` disables
/// the autoscaler (the fixed-roster control row).
pub struct FailureGrid<'a> {
    methods: Vec<PredictorFactory>,
    traces: &'a [Trace],
    /// Failures per second; `0.0` = injection off.
    fail_rates: Vec<f64>,
    /// Autoscaler provisioning lag in seconds; `None` = autoscaler off.
    lags: Vec<Option<f64>>,
    base: SchedConfig,
    node_spec: NodeSpec,
    n_nodes: usize,
}

/// Results of a [`FailureGrid`] run, in [`FailureGrid::cells`] order.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureGridResults {
    pub cells: Vec<FailureCell>,
    pub reports: Vec<SchedReport>,
}

impl FailureGridResults {
    /// Report of one cell by axis indices.
    pub fn report(
        &self,
        method_idx: usize,
        rate_idx: usize,
        lag_idx: usize,
    ) -> Option<&SchedReport> {
        self.cells
            .iter()
            .position(|c| {
                c.method_idx == method_idx && c.rate_idx == rate_idx && c.lag_idx == lag_idx
            })
            .map(|i| &self.reports[i])
    }
}

impl<'a> FailureGrid<'a> {
    pub fn new(
        methods: Vec<PredictorFactory>,
        traces: &'a [Trace],
        fail_rates: Vec<f64>,
        lags: Vec<Option<f64>>,
    ) -> Self {
        assert!(!methods.is_empty(), "grid needs at least one predictor factory");
        assert!(!traces.is_empty(), "grid needs at least one trace");
        assert!(!fail_rates.is_empty(), "grid needs at least one failure rate");
        assert!(!lags.is_empty(), "grid needs at least one autoscale lag");
        FailureGrid {
            methods,
            traces,
            fail_rates,
            lags,
            base: SchedConfig::default(),
            node_spec: NodeSpec::paper_testbed(),
            n_nodes: 2,
        }
    }

    /// Override the per-cell config template, node spec, and base
    /// roster size.
    pub fn with_base(mut self, base: SchedConfig, node_spec: NodeSpec, n_nodes: usize) -> Self {
        self.base = base;
        self.node_spec = node_spec;
        self.n_nodes = n_nodes.max(1);
        self
    }

    pub fn n_cells(&self) -> usize {
        self.methods.len() * self.fail_rates.len() * self.lags.len()
    }

    /// Canonical method-major cell order (then rate, then lag).
    pub fn cells(&self) -> Vec<FailureCell> {
        let mut out = Vec::with_capacity(self.n_cells());
        for method_idx in 0..self.methods.len() {
            for rate_idx in 0..self.fail_rates.len() {
                for lag_idx in 0..self.lags.len() {
                    out.push(FailureCell { method_idx, rate_idx, lag_idx });
                }
            }
        }
        out
    }

    fn cell_config(&self, c: FailureCell) -> SchedConfig {
        let rate = self.fail_rates[c.rate_idx];
        SchedConfig {
            nodes: vec![self.node_spec; self.n_nodes],
            fail_mtbf: Seconds(if rate > 0.0 { 1.0 / rate } else { 0.0 }),
            autoscale: self.lags[c.lag_idx]
                .map(|lag| AutoscaleConfig { lag: Seconds(lag), ..AutoscaleConfig::default() }),
            ..self.base.clone()
        }
    }

    /// Execute every cell on `workers` threads; per-trace reports are
    /// merged in trace order within each cell.
    pub fn run(&self, workers: usize) -> FailureGridResults {
        let cells = self.cells();
        let reports = parallel_map(cells.len(), workers, |i| {
            let c = cells[i];
            let cfg = self.cell_config(c);
            SchedReport::merged(self.traces.iter().map(|trace| {
                let mut predictor = (self.methods[c.method_idx])();
                schedule_trace(trace, predictor.as_mut(), &cfg)
            }))
            .expect("at least one trace per cell")
        });
        FailureGridResults { cells, reports }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::predictors::default_config::DefaultConfigPredictor;
    use ksegments_core::predictors::ppm::PpmPredictor;
    use ksegments_core::trace::{TaskRun, UsageSeries};
    use ksegments_core::units::MemMiB;

    fn toy_trace(ty: &str, n: usize) -> Trace {
        let mut t = Trace::new();
        t.set_default(ty, MemMiB(2000.0));
        for i in 0..n {
            let input = 100.0 + 10.0 * i as f64;
            let peak = 10.0 + input;
            let samples: Vec<f64> = (0..10).map(|j| peak * (j + 1) as f64 / 10.0).collect();
            t.push(TaskRun {
                task_type: ty.to_string(),
                input_mib: input,
                runtime: Seconds(20.0),
                series: UsageSeries::new(2.0, samples),
                seq: i as u64,
            });
        }
        t.sort();
        t
    }

    fn toy_grid(traces: &[Trace]) -> SchedGrid<'_> {
        let methods: Vec<PredictorFactory> = vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new())),
            Box::new(|| Box::new(PpmPredictor::improved())),
        ];
        SchedGrid::new(
            vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise],
            methods,
            traces,
            vec![1, 2],
            vec![2.0, 8.0],
        )
    }

    #[test]
    fn cell_enumeration_is_policy_major() {
        let traces = vec![toy_trace("a/x", 20)];
        let grid = toy_grid(&traces);
        let cells = grid.cells();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2);
        assert_eq!(
            cells[0],
            SchedCell { policy_idx: 0, method_idx: 0, nodes_idx: 0, arrival_idx: 0 }
        );
        assert_eq!(
            cells[1],
            SchedCell { policy_idx: 0, method_idx: 0, nodes_idx: 0, arrival_idx: 1 }
        );
        assert_eq!(
            cells[15],
            SchedCell { policy_idx: 1, method_idx: 1, nodes_idx: 1, arrival_idx: 1 }
        );
    }

    #[test]
    fn grid_results_independent_of_worker_count() {
        let traces = vec![toy_trace("a/x", 25), toy_trace("b/y", 25)];
        let grid = toy_grid(&traces);
        let seq = grid.run(1);
        for workers in [2, 4] {
            assert_eq!(grid.run(workers), seq, "workers={workers} diverged");
        }
    }

    fn tiny_workflow() -> WorkflowSpec {
        use ksegments_core::units::Seconds as S;
        use ksegments_core::workload::{ProfileShape, TaskTypeSpec};
        let t = |name: &str| TaskTypeSpec {
            name: format!("w/{name}"),
            profile: ProfileShape::RampUp { alpha: 1.0 },
            rt_base: S(10.0),
            rt_per_mib: 0.01,
            peak_base: MemMiB(200.0),
            peak_per_mib: 0.3,
            noise_sigma: 0.1,
            spike_prob: 0.0,
            wiggle_sigma: 0.02,
            input_mu: 5.0,
            input_sigma: 0.4,
            n_executions: 4,
            default_mem: MemMiB(2048.0),
        };
        WorkflowSpec {
            name: "w".into(),
            tasks: vec![t("a"), t("b"), t("c")],
            edges: vec![(0, 1), (0, 2)],
        }
    }

    #[test]
    fn dag_grid_enumerates_and_runs_deterministically() {
        let wf = tiny_workflow();
        let methods: Vec<PredictorFactory> = vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new())),
            Box::new(|| Box::new(PpmPredictor::improved())),
        ];
        let grid = DagGrid::new(
            vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise],
            methods,
            &wf,
            vec![1],
            vec![1, 3],
        )
        .with_base(
            SchedConfig { seed: 7, ..SchedConfig::default() },
            NodeSpec { mem: MemMiB(4096.0), cores: 8 },
        );
        assert_eq!(grid.n_cells(), 2 * 2 * 1 * 2);
        let cells = grid.cells();
        assert_eq!(
            cells[0],
            DagCell { policy_idx: 0, method_idx: 0, nodes_idx: 0, instances_idx: 0 }
        );
        assert_eq!(
            cells[7],
            DagCell { policy_idx: 1, method_idx: 1, nodes_idx: 0, instances_idx: 1 }
        );
        let seq = grid.run(1);
        for workers in [2, 4] {
            assert_eq!(grid.run(workers), seq, "workers={workers} diverged");
        }
        // every cell completes all its workflow instances and tasks
        for (c, rep) in seq.cells.iter().zip(&seq.reports) {
            let n_inst = [1u64, 3][c.instances_idx];
            assert_eq!(rep.workflows_submitted, n_inst, "cell {c:?}");
            assert_eq!(rep.workflows_completed, n_inst, "cell {c:?}");
            assert_eq!(rep.submitted, n_inst * 3, "cell {c:?}");
            assert_eq!(rep.completed, rep.submitted, "cell {c:?}");
        }
        // axis lookup
        let r = seq.report(1, 0, 0, 1).unwrap();
        assert_eq!(r.policy, "segment-wise");
        assert_eq!(r.workflows_completed, 3);
        assert!(seq.report(9, 0, 0, 0).is_none());
    }

    #[test]
    fn every_cell_schedules_every_task() {
        let traces = vec![toy_trace("a/x", 25), toy_trace("b/y", 25)];
        let grid = toy_grid(&traces);
        let res = grid.run(2);
        // training_frac 0.5 → 12 + 12 scored runs per cell (floor(25/2))
        for rep in &res.reports {
            assert_eq!(rep.submitted, 26);
            assert_eq!(rep.completed, 26);
        }
        // cell lookup by axes
        let r = res.report(1, 0, 1, 1).unwrap();
        assert_eq!(r.policy, "segment-wise");
        assert_eq!(r.n_nodes, 2);
        assert_eq!(r.mean_interarrival_s, 8.0);
        assert!(res.report(5, 0, 0, 0).is_none());
    }

    #[test]
    fn failure_grid_cell_order_and_config_wiring() {
        let traces = vec![toy_trace("a/x", 20)];
        let methods: Vec<PredictorFactory> = vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new())),
            Box::new(|| Box::new(PpmPredictor::improved())),
        ];
        let grid = FailureGrid::new(methods, &traces, vec![0.0, 0.1], vec![None, Some(7.0)]);
        assert_eq!(grid.n_cells(), 2 * 2 * 2);
        let cells = grid.cells();
        assert_eq!(cells[0], FailureCell { method_idx: 0, rate_idx: 0, lag_idx: 0 });
        assert_eq!(cells[1], FailureCell { method_idx: 0, rate_idx: 0, lag_idx: 1 });
        assert_eq!(cells[7], FailureCell { method_idx: 1, rate_idx: 1, lag_idx: 1 });
        // axis values reach the per-cell config: rate 0 / lag None are
        // the controls, rate 0.1 → mtbf 10 s, lag Some(7) → autoscaler
        let clean = grid.cell_config(cells[0]);
        assert_eq!(clean.fail_mtbf, Seconds(0.0));
        assert_eq!(clean.autoscale, None);
        let harsh = grid.cell_config(cells[7]);
        assert!((harsh.fail_mtbf.0 - 10.0).abs() < 1e-12);
        let auto = harsh.autoscale.expect("autoscale wired through");
        assert_eq!(auto.lag, Seconds(7.0));
        assert_eq!(auto.queue_per_node, AutoscaleConfig::default().queue_per_node);
        assert_eq!(auto.max_nodes, AutoscaleConfig::default().max_nodes);
    }

    #[test]
    fn failure_grid_conserves_and_is_worker_independent() {
        let traces = vec![toy_trace("a/x", 20), toy_trace("b/y", 20)];
        let mut any_failures = false;
        for seed in [11u64, 12, 13] {
            let methods: Vec<PredictorFactory> =
                vec![Box::new(|| Box::new(PpmPredictor::improved()))];
            let grid = FailureGrid::new(methods, &traces, vec![0.0, 0.05], vec![None, Some(10.0)])
                .with_base(
                    SchedConfig { seed, fail_downtime: Seconds(5.0), ..SchedConfig::default() },
                    NodeSpec { mem: MemMiB(4096.0), cores: 8 },
                    2,
                );
            let seq = grid.run(1);
            for workers in [4, 8] {
                assert_eq!(grid.run(workers), seq, "seed={seed} workers={workers} diverged");
            }
            for (c, r) in seq.cells.iter().zip(&seq.reports) {
                // every admission ends in exactly one outcome, even
                // under injected node loss
                assert_eq!(r.completed, r.submitted, "cell {c:?}");
                assert_eq!(
                    r.admitted,
                    r.completed + r.oom_kills + r.grow_denials + r.preempted + r.node_lost,
                    "cell {c:?}"
                );
                if c.rate_idx == 0 {
                    assert_eq!(r.node_failures, 0, "control column saw failures: {c:?}");
                    assert_eq!(r.node_lost, 0, "control column lost tasks: {c:?}");
                } else {
                    any_failures |= r.node_failures > 0;
                }
                if c.lag_idx == 0 {
                    assert_eq!(r.nodes_added, 0, "autoscaler off but nodes added: {c:?}");
                }
            }
            // axis lookup
            assert!(seq.report(0, 1, 1).is_some());
            assert!(seq.report(1, 0, 0).is_none());
        }
        assert!(any_failures, "no seed produced a node failure at mtbf 20s");
    }
}
