//! `map-iter-order`: modules whose output feeds reports, merges,
//! grids or checkpoints must not use `HashMap`/`HashSet` at all —
//! their iteration order is randomized per process, so any loop over
//! one is a nondeterminism bug waiting for a reorder. The sanctioned
//! substitutes are `BTreeMap`/`BTreeSet` or index-keyed `Vec`s.
//!
//! The pass bans the *types*, not just `.iter()` calls: a token-level
//! linter cannot see through method calls (`values()`, `extend`,
//! `from_iter`, serialization helpers), and every observed
//! determinism bug in the literature starts with the map existing.

use super::{FileCtx, Rule};
use crate::diag::Diagnostic;
use crate::lexer::contains_word;

/// (crate, rel-path prefix) pairs of the order-sensitive modules.
/// A trailing `/` makes the entry a directory prefix.
const SCOPED: &[(&str, &str)] = &[
    ("ksegments-core", "src/wastage.rs"),
    ("ksegments-core", "src/telemetry/"),
    ("ksegments-core", "src/parallel.rs"),
    ("ksegments-sim", "src/"),
    ("ksegments-sched", "src/sched/"),
    ("ksegments-serve", "src/ingest/"),
    ("ksegments-serve", "src/coordinator/"),
];

pub(crate) fn in_scope(krate: &str, rel_path: &str) -> bool {
    SCOPED.iter().any(|(k, prefix)| {
        *k == krate
            && if prefix.ends_with('/') {
                rel_path.starts_with(prefix)
            } else {
                rel_path == *prefix
            }
    })
}

pub struct MapIterOrder;

impl Rule for MapIterOrder {
    fn id(&self) -> &'static str {
        "map-iter-order"
    }

    fn check(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        if !in_scope(ctx.krate, ctx.rel_path) {
            return;
        }
        for (idx, line) in ctx.file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for ty in ["HashMap", "HashSet"] {
                if contains_word(&line.code, ty) {
                    out.push(Diagnostic {
                        rule: self.id(),
                        path: ctx.display_path.to_string(),
                        line: idx + 1,
                        message: format!(
                            "{ty} in an order-sensitive module (iteration order is \
                             nondeterministic); use BTreeMap/BTreeSet or index-keyed Vecs"
                        ),
                    });
                }
            }
        }
    }
}
