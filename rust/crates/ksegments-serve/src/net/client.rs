//! Blocking client for the TCP prediction protocol.
//!
//! [`NetClient`] mirrors the in-process [`ServiceHandle`] surface
//! (prime / predict / report_failure / complete / replay / stats /
//! shutdown) over one connection. The raw [`NetClient::send_request`]
//! / [`NetClient::recv_response`] pair exposes the pipelining the
//! protocol guarantees — write N frames, then read N in-order
//! responses — which the conformance tests and the load generator both
//! lean on.
//!
//! [`ServiceHandle`]: crate::coordinator::ServiceHandle

use std::io::{BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use ksegments_core::predictors::{Allocation, FailureInfo};
use ksegments_core::trace::{run_record, TaskRun};
use ksegments_core::units::MemMiB;
use ksegments_core::util::json::Json;

use crate::coordinator::ServiceStats;
use crate::net::frame::{
    alloc_to_json, failure_info_to_json, parse_response, read_frame, NetResponse, LEN_PREFIX,
    MAX_FRAME_DEFAULT,
};

/// One connection to a [`NetServer`], with monotonically increasing
/// request ids.
///
/// [`NetServer`]: crate::net::NetServer
pub struct NetClient {
    w: TcpStream,
    r: BufReader<TcpStream>,
    next_id: u64,
    max_frame: usize,
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let w = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = w.set_nodelay(true);
        let r = BufReader::new(w.try_clone().context("cloning stream for reads")?);
        Ok(NetClient { w, r, next_id: 0, max_frame: MAX_FRAME_DEFAULT })
    }

    /// Send one request frame without waiting for its response; the
    /// pipelining half of the protocol. Returns the id to match the
    /// eventual response against.
    pub fn send_request(&mut self, method: &str, mut fields: Vec<(&str, Json)>) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        fields.push(("method", method.into()));
        fields.push(("id", id.into()));
        let payload = Json::obj(fields).to_string();
        let mut buf = Vec::with_capacity(LEN_PREFIX + payload.len());
        buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        buf.extend_from_slice(payload.as_bytes());
        self.w.write_all(&buf).context("writing request frame")?;
        Ok(id)
    }

    /// Read the next response frame (in request order).
    pub fn recv_response(&mut self) -> Result<NetResponse> {
        let payload = read_frame(&mut self.r, self.max_frame)
            .context("reading response frame")?
            .context("server closed the connection")?;
        parse_response(&payload).map_err(|e| anyhow::anyhow!("malformed response: {e}"))
    }

    /// Send one request and read its response (success or typed
    /// error), verifying the echoed id.
    pub fn call(&mut self, method: &str, fields: Vec<(&str, Json)>) -> Result<NetResponse> {
        let id = self.send_request(method, fields)?;
        let resp = self.recv_response()?;
        if resp.id != Some(id) {
            bail!("response id {:?} does not match request id {id}", resp.id);
        }
        Ok(resp)
    }

    fn expect_ok(&mut self, method: &str, fields: Vec<(&str, Json)>) -> Result<NetResponse> {
        let resp = self.call(method, fields)?;
        if !resp.ok {
            let (code, msg) = resp.error.unwrap_or_default();
            bail!("{method} failed: {code}: {msg}");
        }
        Ok(resp)
    }

    // -- typed surface -----------------------------------------------------

    pub fn prime(&mut self, task_type: &str, default: MemMiB) -> Result<()> {
        self.expect_ok(
            "prime",
            vec![("task_type", task_type.into()), ("default_mib", default.0.into())],
        )?;
        Ok(())
    }

    pub fn predict(&mut self, task_type: &str, input_mib: f64) -> Result<Allocation> {
        self.expect_ok(
            "predict",
            vec![("task_type", task_type.into()), ("input_mib", input_mib.into())],
        )?
        .alloc
        .context("predict response without an allocation")
    }

    pub fn report_failure(
        &mut self,
        task_type: &str,
        input_mib: f64,
        failed: &Allocation,
        info: &FailureInfo,
    ) -> Result<Allocation> {
        self.expect_ok(
            "report_failure",
            vec![
                ("task_type", task_type.into()),
                ("input_mib", input_mib.into()),
                ("failed", alloc_to_json(failed)),
                ("info", failure_info_to_json(info)),
            ],
        )?
        .alloc
        .context("report_failure response without an allocation")
    }

    pub fn complete(&mut self, run: &TaskRun) -> Result<()> {
        self.expect_ok("complete", vec![("run", run_record(run))])?;
        Ok(())
    }

    /// Batched replay of `runs` through the server's chunked replay
    /// path; returns how many runs the server fed.
    pub fn replay(&mut self, runs: &[TaskRun]) -> Result<u64> {
        let arr = Json::Arr(runs.iter().map(run_record).collect());
        self.expect_ok("replay", vec![("runs", arr)])?
            .fed
            .context("replay response without a fed count")
    }

    /// Live `(aggregated, per_shard)` service counters.
    pub fn stats(&mut self) -> Result<(ServiceStats, Vec<ServiceStats>)> {
        let resp = self.expect_ok("stats", Vec::new())?;
        let total = resp.stats.context("stats response without totals")?;
        Ok((total, resp.per_shard))
    }

    /// Ask the server to drain; the ack arrives before the server
    /// closes.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.expect_ok("shutdown", Vec::new())?;
        Ok(())
    }
}
