//! The prediction service — the long-running coordinator a SWMS talks
//! to (the deployment shape of Fig. 2/6).
//!
//! A dedicated model thread owns the predictor (and through it the
//! PJRT runtime, which wants single-threaded use); SWMS-side clients
//! hold a cheap clonable [`ServiceHandle`] and talk to it over
//! channels:
//!
//! * [`ServiceHandle::predict`] — blocking request/response, the
//!   submission-time path;
//! * [`ServiceHandle::report_failure`] — blocking, returns the retry
//!   allocation per the predictor's failure strategy;
//! * [`ServiceHandle::complete`] — fire-and-forget completion
//!   ingestion; the model thread folds finished runs into the model in
//!   arrival order (the online loop), so prediction latency never
//!   blocks on retraining more than one fit.
//!
//! The offline crate cache has no tokio; the service uses std threads
//! and mpsc channels, which for this request pattern (single model
//! owner, many blocking callers) is the same architecture tokio's
//! actor pattern would express.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::predictors::{Allocation, FailureInfo, MemoryPredictor};
use crate::trace::TaskRun;
use crate::units::MemMiB;

/// Requests understood by the model thread.
enum Request {
    Prime { task_type: String, default: MemMiB },
    Predict { task_type: String, input_mib: f64, reply: Sender<Allocation> },
    Failure {
        task_type: String,
        input_mib: f64,
        failed: Allocation,
        info: FailureInfo,
        reply: Sender<Allocation>,
    },
    Complete { run: Box<TaskRun> },
    Stats { reply: Sender<ServiceStats> },
    Shutdown,
}

/// Observability counters maintained by the model thread.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub predictions: u64,
    pub completions: u64,
    pub failures: u64,
}

/// Clonable client handle.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Request>,
}

impl ServiceHandle {
    pub fn prime(&self, task_type: &str, default: MemMiB) {
        let _ = self.tx.send(Request::Prime {
            task_type: task_type.to_string(),
            default,
        });
    }

    /// Submission-time allocation request (blocking).
    pub fn predict(&self, task_type: &str, input_mib: f64) -> Allocation {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Predict { task_type: task_type.to_string(), input_mib, reply })
            .expect("prediction service is down");
        rx.recv().expect("prediction service dropped the reply")
    }

    /// Failure-strategy request (blocking).
    pub fn report_failure(
        &self,
        task_type: &str,
        input_mib: f64,
        failed: Allocation,
        info: FailureInfo,
    ) -> Allocation {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Failure {
                task_type: task_type.to_string(),
                input_mib,
                failed,
                info,
                reply,
            })
            .expect("prediction service is down");
        rx.recv().expect("prediction service dropped the reply")
    }

    /// Completion ingestion (non-blocking).
    pub fn complete(&self, run: TaskRun) {
        let _ = self.tx.send(Request::Complete { run: Box::new(run) });
    }

    pub fn stats(&self) -> ServiceStats {
        let (reply, rx) = channel();
        self.tx.send(Request::Stats { reply }).expect("service down");
        rx.recv().expect("service dropped stats reply")
    }
}

/// The running service; join it via [`PredictionService::shutdown`].
pub struct PredictionService {
    handle: ServiceHandle,
    thread: Option<JoinHandle<ServiceStats>>,
}

impl PredictionService {
    /// Spawn the model thread around any predictor.
    pub fn spawn(predictor: Box<dyn MemoryPredictor>) -> PredictionService {
        let (tx, rx) = channel();
        let thread = std::thread::Builder::new()
            .name("ksegments-model".to_string())
            .spawn(move || model_loop(predictor, rx))
            .expect("spawning model thread");
        PredictionService { handle: ServiceHandle { tx }, thread: Some(thread) }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Stop the model thread and return its final counters.
    pub fn shutdown(mut self) -> ServiceStats {
        let _ = self.handle.tx.send(Request::Shutdown);
        self.thread
            .take()
            .expect("already shut down")
            .join()
            .expect("model thread panicked")
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        if let Some(t) = self.thread.take() {
            let _ = self.handle.tx.send(Request::Shutdown);
            let _ = t.join();
        }
    }
}

fn model_loop(mut predictor: Box<dyn MemoryPredictor>, rx: Receiver<Request>) -> ServiceStats {
    let mut stats = ServiceStats::default();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Prime { task_type, default } => predictor.prime(&task_type, default),
            Request::Predict { task_type, input_mib, reply } => {
                stats.predictions += 1;
                let _ = reply.send(predictor.predict(&task_type, input_mib));
            }
            Request::Failure { task_type, input_mib, failed, info, reply } => {
                stats.failures += 1;
                let _ = reply.send(predictor.on_failure(&task_type, input_mib, &failed, &info));
            }
            Request::Complete { run } => {
                stats.completions += 1;
                predictor.observe(&run);
            }
            Request::Stats { reply } => {
                let _ = reply.send(stats);
            }
            Request::Shutdown => break,
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictors::default_config::DefaultConfigPredictor;
    use crate::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn run(input: f64, peak: f64) -> TaskRun {
        let samples: Vec<f64> = (0..8).map(|j| peak * (j + 1) as f64 / 8.0).collect();
        TaskRun {
            task_type: "w/t".into(),
            input_mib: input,
            runtime: Seconds(16.0),
            series: UsageSeries::new(2.0, samples),
            seq: 0,
        }
    }

    #[test]
    fn predict_roundtrip() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        h.prime("w/t", MemMiB(2048.0));
        assert_eq!(h.predict("w/t", 10.0), Allocation::Static(MemMiB(2048.0)));
        let stats = svc.shutdown();
        assert_eq!(stats.predictions, 1);
    }

    #[test]
    fn completions_train_the_model() {
        let svc = PredictionService::spawn(Box::new(KSegmentsPredictor::native(
            4,
            RetryStrategy::Selective,
        )));
        let h = svc.handle();
        h.prime("w/t", MemMiB(2048.0));
        for i in 0..12 {
            h.complete(run(100.0 + 10.0 * i as f64, 200.0 + 10.0 * i as f64));
        }
        // channel is FIFO: by the time predict is answered, all
        // completions have been ingested
        let alloc = h.predict("w/t", 150.0);
        assert!(alloc.is_dynamic());
        let stats = svc.shutdown();
        assert_eq!(stats.completions, 12);
    }

    #[test]
    fn failure_path_returns_escalated_allocation() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        let failed = Allocation::Static(MemMiB(100.0));
        let info = FailureInfo { time_s: 1.0, used_mib: 150.0, attempt: 1 };
        let next = h.report_failure("w/t", 10.0, failed, info);
        assert_eq!(next, Allocation::Static(MemMiB(200.0)));
        assert_eq!(svc.shutdown().failures, 1);
    }

    #[test]
    fn many_clients_share_the_service() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let mut joins = Vec::new();
        for i in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let _ = h.predict(&format!("w/t{i}"), 1.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(svc.shutdown().predictions, 400);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let svc = PredictionService::spawn(Box::new(DefaultConfigPredictor::new()));
        let h = svc.handle();
        drop(svc);
        // handle calls after shutdown must not panic the caller thread
        // (send fails silently for fire-and-forget)
        h.complete(run(1.0, 1.0));
    }
}
