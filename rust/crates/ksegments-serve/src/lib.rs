//! Serving layer of the ksegments workspace: the path from a real
//! workflow engine into (and back out of) the prediction core.
//!
//! `ksegments-core` defines the data model and the streaming
//! [`TraceSource`](ksegments_core::source::TraceSource) seam; this
//! crate owns everything that touches files, threads and long-lived
//! state:
//!
//! * [`ingest`] — Nextflow `trace.txt` + monitoring-CSV parsers, the
//!   streaming JSONL reader, shape-sniffing [`ingest::open_source`],
//!   the online replay engine ([`ingest::replay_source`]) and
//!   predictor [`ingest::Checkpoint`]s for warm starts.
//! * [`coordinator`] — the sharded in-process prediction service: a
//!   router hashing task types onto worker shards, each owning a
//!   private predictor, with request/response plumbing, telemetry
//!   spans and merged metrics.
//! * [`net`] — the TCP front of the coordinator: a length-prefixed
//!   JSONL wire protocol ([`net::NetServer`]/[`net::NetClient`]) with
//!   per-connection pipelining, typed protocol errors, graceful drain,
//!   checkpoint-backed warm restart, and a QPS-paced multi-connection
//!   load generator ([`net::run_loadgen`]).
//!
//! The `ksegments` facade re-exports these modules under their
//! historical single-crate paths (`ksegments::ingest`,
//! `ksegments::coordinator`, `ksegments::net`).

pub mod coordinator;
pub mod ingest;
pub mod net;
