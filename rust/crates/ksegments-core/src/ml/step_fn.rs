//! The monotone step allocation function (paper Eq. 1).
//!
//! `f(x) = v_s` for `r_{s-1} < x <= r_s`, extended with `f(x) = v_k` for
//! `x > r_k`: if a task runs longer than its predicted runtime the last
//! segment's allocation is held (the conservative reading of Eq. 1 —
//! without it any runtime underprediction would instantly fail the
//! task at memory 0).

use crate::units::{MemMiB, Seconds};

/// A right-continuous step function over time: `k` boundaries
/// `r_1 < r_2 < … < r_k` and `k` values `v_1 … v_k` (MiB).
///
/// # Example
///
/// ```
/// use ksegments::ml::step_fn::StepFunction;
///
/// // 0–10 s → 100 MiB, 10–20 s → 300 MiB (held beyond 20 s).
/// let f = StepFunction::new(vec![10.0, 20.0], vec![100.0, 300.0]);
/// assert_eq!(f.value_at(5.0), 100.0);
/// assert_eq!(f.value_at(15.0), 300.0);
/// assert_eq!(f.value_at(99.0), 300.0);
/// assert_eq!(f.max_value(), 300.0);
/// assert_eq!(f.predicted_runtime().0, 20.0);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct StepFunction {
    /// Segment end times, strictly increasing; `bounds[k-1]` is the
    /// predicted runtime `r_e`.
    bounds: Vec<f64>,
    /// Allocation per segment (MiB).
    values: Vec<f64>,
}

impl StepFunction {
    /// Build from raw boundary/value vectors, validating the invariants
    /// `simulate_attempt`'s two-pointer piece walk relies on: non-empty,
    /// equal lengths, and boundaries positive, finite and **strictly**
    /// increasing. Duplicate boundaries would create zero-width pieces
    /// (silently tolerated only by accident) and unsorted boundaries
    /// would mis-attribute failure times, so both are rejected here at
    /// construction instead of surfacing downstream.
    ///
    /// Does NOT clamp values — see [`Self::monotone_clamped`] for the
    /// paper's construction.
    pub fn try_new(bounds: Vec<f64>, values: Vec<f64>) -> Result<Self, String> {
        if bounds.is_empty() {
            return Err("empty step function".into());
        }
        if bounds.len() != values.len() {
            return Err(format!(
                "bounds/values length mismatch: {} vs {}",
                bounds.len(),
                values.len()
            ));
        }
        if bounds.iter().any(|b| !b.is_finite()) {
            return Err(format!("non-finite boundary: {bounds:?}"));
        }
        if !(bounds.windows(2).all(|w| w[1] > w[0]) && bounds[0] > 0.0) {
            return Err(format!(
                "boundaries must be positive and strictly increasing: {bounds:?}"
            ));
        }
        debug_assert!(bounds.windows(2).all(|w| w[1] > w[0]));
        Ok(StepFunction { bounds, values })
    }

    /// [`Self::try_new`], panicking on invalid input (the predictors'
    /// internal constructions are valid by design; a panic here is a
    /// bug in the caller, not bad data).
    pub fn new(bounds: Vec<f64>, values: Vec<f64>) -> Self {
        Self::try_new(bounds, values).unwrap_or_else(|e| panic!("{e}"))
    }

    /// The paper's §III-C construction: split predicted runtime `r_e`
    /// into k near-equal boundaries, clamp predictions to be
    /// monotonically non-decreasing (`v_s < v_{s-1}` takes the previous
    /// value), apply the floor (`v_1 < 0` → default 100 MB; every
    /// segment respects the floor) and a capacity ceiling.
    pub fn monotone_clamped(
        runtime: Seconds,
        values: Vec<f64>,
        floor: MemMiB,
        ceil: MemMiB,
    ) -> Self {
        assert!(!values.is_empty());
        let k = values.len();
        let r_e = runtime.0.max(1e-6);
        // R = (r_s, 2 r_s, ..., r_e), r_s = r_e / k (the predictors use
        // `monotone_clamped_with_bounds` to mirror the floor-based
        // training segmentation exactly; this equal split is the
        // generic construction).
        let r_s = r_e / k as f64;
        let bounds: Vec<f64> = (1..=k)
            .map(|s| if s == k { r_e } else { s as f64 * r_s })
            .collect();
        Self::monotone_clamped_with_bounds(bounds, values, floor, ceil)
    }

    /// Same clamping with caller-supplied boundaries (see
    /// [`crate::ml::segmentation::segment_time_bounds`]).
    pub fn monotone_clamped_with_bounds(
        bounds: Vec<f64>,
        mut values: Vec<f64>,
        floor: MemMiB,
        ceil: MemMiB,
    ) -> Self {
        let mut prev = f64::MIN;
        for v in values.iter_mut() {
            *v = v.max(floor.0).min(ceil.0); // floor/cap first
            *v = v.max(prev); // then monotone clamp
            prev = *v;
        }
        StepFunction::new(bounds, values)
    }

    pub fn k(&self) -> usize {
        self.values.len()
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Predicted runtime `r_e = r_k`.
    pub fn predicted_runtime(&self) -> Seconds {
        Seconds(*self.bounds.last().unwrap())
    }

    /// Allocation at time `t` (MiB). Holds `v_k` past `r_k` and `v_1`
    /// before 0.
    pub fn value_at(&self, t: f64) -> f64 {
        // segments are (r_{s-1}, r_s]; t=0 belongs to the first
        match self.bounds.iter().position(|&b| t <= b) {
            Some(idx) => self.values[idx],
            None => *self.values.last().unwrap(),
        }
    }

    /// Segment index active at time `t` (clamped to the last).
    pub fn segment_at(&self, t: f64) -> usize {
        self.bounds
            .iter()
            .position(|&b| t <= b)
            .unwrap_or(self.values.len() - 1)
    }

    /// Peak allocation (= v_k after monotone clamping).
    pub fn max_value(&self) -> f64 {
        self.values.iter().copied().fold(f64::MIN, f64::max)
    }

    /// Multiply segment values in `[from, to)` by `factor` (used by the
    /// retry strategies), re-applying ceiling and monotone clamping.
    pub fn scale_segments(
        &self,
        from: usize,
        to: usize,
        factor: f64,
        ceil: MemMiB,
    ) -> StepFunction {
        assert!(from < self.values.len() && to <= self.values.len() && from < to);
        let mut values = self.values.clone();
        for v in values[from..to].iter_mut() {
            *v = (*v * factor).min(ceil.0);
        }
        let mut prev = f64::MIN;
        for v in values.iter_mut() {
            *v = v.max(prev);
            prev = *v;
        }
        StepFunction::new(self.bounds.clone(), values)
    }

    /// True if `values` never decreases.
    pub fn is_monotone(&self) -> bool {
        self.values.windows(2).all(|w| w[1] >= w[0])
    }

    /// Time-integral of the allocation over `[0, horizon]` (MiB·s) —
    /// used in wastage accounting and Fig. 1-style visualisations.
    pub fn integral(&self, horizon: f64) -> f64 {
        let mut total = 0.0;
        let mut prev_t = 0.0;
        for (i, &b) in self.bounds.iter().enumerate() {
            if prev_t >= horizon {
                return total;
            }
            let end = b.min(horizon);
            total += self.values[i] * (end - prev_t).max(0.0);
            prev_t = b;
        }
        if horizon > prev_t {
            total += self.values.last().unwrap() * (horizon - prev_t);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f() -> StepFunction {
        StepFunction::new(vec![10.0, 20.0, 30.0, 40.0], vec![1.0, 2.0, 2.0, 5.0])
    }

    #[test]
    fn value_lookup() {
        let f = f();
        assert_eq!(f.value_at(0.0), 1.0);
        assert_eq!(f.value_at(10.0), 1.0); // right-closed segment
        assert_eq!(f.value_at(10.1), 2.0);
        assert_eq!(f.value_at(40.0), 5.0);
        assert_eq!(f.value_at(100.0), 5.0); // held past r_k
    }

    #[test]
    fn segment_lookup() {
        let f = f();
        assert_eq!(f.segment_at(0.0), 0);
        assert_eq!(f.segment_at(15.0), 1);
        assert_eq!(f.segment_at(999.0), 3);
    }

    #[test]
    fn monotone_clamp_construction() {
        // v2 dips below v1 -> takes previous; v1 below floor -> floor
        let sf = StepFunction::monotone_clamped(
            Seconds(40.0),
            vec![-5.0, 3000.0, 2000.0, 4000.0],
            MemMiB(100.0),
            MemMiB(128.0 * 1024.0),
        );
        assert_eq!(sf.values(), &[100.0, 3000.0, 3000.0, 4000.0]);
        assert_eq!(sf.bounds(), &[10.0, 20.0, 30.0, 40.0]);
        assert!(sf.is_monotone());
    }

    #[test]
    fn ceiling_applies_before_monotone() {
        let sf = StepFunction::monotone_clamped(
            Seconds(10.0),
            vec![500_000.0, 1.0],
            MemMiB(100.0),
            MemMiB(1000.0),
        );
        assert_eq!(sf.values(), &[1000.0, 1000.0]);
    }

    #[test]
    fn k1_function() {
        let sf =
            StepFunction::monotone_clamped(Seconds(30.0), vec![512.0], MemMiB(100.0), MemMiB(1e9));
        assert_eq!(sf.k(), 1);
        assert_eq!(sf.value_at(29.0), 512.0);
        assert_eq!(sf.predicted_runtime(), Seconds(30.0));
    }

    #[test]
    fn scale_selective_and_partial() {
        let f = f();
        let ceil = MemMiB(1e9);
        // selective: only segment 1
        let sel = f.scale_segments(1, 2, 2.0, ceil);
        assert_eq!(sel.values(), &[1.0, 4.0, 4.0, 5.0]); // re-clamped
        // partial: segment 1..end
        let par = f.scale_segments(1, 4, 2.0, ceil);
        assert_eq!(par.values(), &[1.0, 4.0, 4.0, 10.0]);
        assert!(sel.is_monotone() && par.is_monotone());
    }

    #[test]
    fn scale_respects_ceiling() {
        let f = f();
        let s = f.scale_segments(3, 4, 1e6, MemMiB(7.0));
        assert_eq!(s.values()[3], 7.0);
    }

    #[test]
    fn integral_piecewise() {
        let f = f();
        // 1*10 + 2*10 + 2*10 + 5*10 = 100
        assert!((f.integral(40.0) - 100.0).abs() < 1e-9);
        // stop mid-segment: 1*10 + 2*5 = 20
        assert!((f.integral(15.0) - 20.0).abs() < 1e-9);
        // beyond r_k holds v_k: 100 + 5*10
        assert!((f.integral(50.0) - 150.0).abs() < 1e-9);
        assert_eq!(f.integral(0.0), 0.0);
    }

    #[test]
    #[should_panic]
    fn non_increasing_bounds_panic() {
        StepFunction::new(vec![10.0, 10.0], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        StepFunction::new(vec![10.0], vec![1.0, 2.0]);
    }

    #[test]
    fn try_new_rejects_duplicate_bounds() {
        // Regression: duplicate boundaries produce zero-width pieces
        // that the attempt walk only tolerated by accident.
        let err = StepFunction::try_new(vec![5.0, 5.0, 10.0], vec![1.0, 2.0, 3.0]);
        assert!(err.is_err(), "{err:?}");
        assert!(err.unwrap_err().contains("strictly increasing"));
    }

    #[test]
    fn try_new_rejects_unsorted_and_nonpositive_and_nonfinite() {
        assert!(StepFunction::try_new(vec![20.0, 10.0], vec![1.0, 2.0]).is_err());
        assert!(StepFunction::try_new(vec![0.0, 10.0], vec![1.0, 2.0]).is_err());
        assert!(StepFunction::try_new(vec![-3.0], vec![1.0]).is_err());
        assert!(StepFunction::try_new(vec![f64::NAN], vec![1.0]).is_err());
        assert!(StepFunction::try_new(vec![], vec![]).is_err());
    }

    #[test]
    fn try_new_accepts_single_segment() {
        // k = 1 is the degenerate-but-valid case (a static allocation
        // expressed as a one-piece step function).
        let f = StepFunction::try_new(vec![30.0], vec![512.0]).unwrap();
        assert_eq!(f.k(), 1);
        assert_eq!(f.value_at(0.0), 512.0);
        assert_eq!(f.value_at(1e9), 512.0);
        assert_eq!(f.segment_at(29.0), 0);
        assert!((f.integral(30.0) - 512.0 * 30.0).abs() < 1e-9);
    }
}
