//! Minimal criterion-style timing harness (the offline crate cache has
//! no criterion). Used by the `cargo bench` targets and the §Perf pass.
//!
//! Also home of [`Stopwatch`] — the workspace's **only** sanctioned
//! wall-clock. Every simulated result (SchedReport, MethodReport,
//! trace events from the engine or replay) is a function of the seed
//! alone; wall time may only appear in `BENCH_*.json` snapshots and in
//! service-thread trace spans, and both must read it through a
//! `Stopwatch` so the boundary stays greppable (DESIGN.md §12).

use std::time::{Duration, Instant};

/// The single sanctioned wall-clock. Construct with
/// [`Stopwatch::start`] and read elapsed time in the unit you need —
/// never call `Instant::now()` directly outside this type.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ns(&self) -> f64 {
        self.start.elapsed().as_nanos() as f64
    }

    /// Whole microseconds since start — the unit of Chrome trace `ts`.
    pub fn elapsed_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }
}

/// One benchmark's measurements.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub samples_ns: Vec<f64>,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ns)
    }

    pub fn p50_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn p95_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 95.0)
    }

    pub fn std_ns(&self) -> f64 {
        crate::util::stats::std(&self.samples_ns)
    }

    /// criterion-like one-liner.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{}  p50 {}  p95 {}] ±{} ({} samples)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p50_ns()),
            fmt_ns(self.p95_ns()),
            fmt_ns(self.std_ns()),
            self.samples_ns.len(),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Run `f` repeatedly: a warmup, then `samples` timed samples of
/// `iters_per_sample` iterations each. The closure's return value is
/// black-boxed to keep the optimizer honest.
// Sanctioned stdout site: this IS the bench harness's reporter, the
// one exception the workspace no-print policy carves out.
#[allow(clippy::print_stdout)]
pub fn bench<T>(
    name: &str,
    samples: usize,
    iters_per_sample: usize,
    mut f: impl FnMut() -> T,
) -> Measurement {
    // warmup
    for _ in 0..iters_per_sample.min(3) {
        black_box(f());
    }
    let mut samples_ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let sw = Stopwatch::start();
        for _ in 0..iters_per_sample {
            black_box(f());
        }
        samples_ns.push(sw.elapsed_ns() / iters_per_sample as f64);
    }
    let m = Measurement { name: name.to_string(), iters: samples * iters_per_sample, samples_ns };
    println!("{}", m.report());
    m
}

/// Time a single long-running call (for whole-figure benches).
// Sanctioned stdout site: bench-harness reporting, as above.
#[allow(clippy::print_stdout)]
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
    let sw = Stopwatch::start();
    let out = black_box(f());
    let dt = sw.elapsed();
    println!("{:<44} wall: {}", name, fmt_ns(dt.as_nanos() as f64));
    (out, dt)
}

/// `std::hint::black_box` wrapper (stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let m = bench("noop", 5, 10, || 1 + 1);
        assert_eq!(m.samples_ns.len(), 5);
        assert!(m.mean_ns() >= 0.0);
        assert!(m.p95_ns() >= m.p50_ns() * 0.5);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once("t", || 42);
        assert_eq!(v, 42);
        assert!(dt.as_nanos() > 0);
    }

    #[test]
    fn stopwatch_units_agree() {
        let sw = Stopwatch::start();
        let _ = black_box((0..1000).sum::<u64>());
        let ns = sw.elapsed_ns();
        let s = sw.elapsed_s();
        let us = sw.elapsed_us();
        assert!(ns >= 0.0);
        // later reads see monotonically non-decreasing time
        assert!(s * 1e9 >= ns * 0.5);
        assert!(us as f64 >= ns / 1e3 - 1.0, "µs and ns reads must agree");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
