//! Quickstart: generate a workload trace, train the k-Segments
//! predictor online, and compare its wastage against the workflow
//! defaults — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::{default_config::DefaultConfigPredictor, MemoryPredictor};
use ksegments::sim::{simulate_trace, SimConfig};
use ksegments::workload::{eager_workflow, generate_workflow_trace};

fn main() {
    // 1. A synthetic trace of the eager-like workflow (18 task types,
    //    deterministic from the seed).
    let trace = generate_workflow_trace(&eager_workflow(), 42);
    println!(
        "trace: {} runs over {} task types",
        trace.n_runs(),
        trace.n_types()
    );

    // 2. The paper's evaluation protocol: first half of each task's
    //    executions warm the model, the rest are scored online.
    let cfg = SimConfig::with_training_frac(0.5);

    // 3. Two predictors: the sanity baseline and the paper's method.
    let mut default = DefaultConfigPredictor::new();
    let mut kseg = KSegmentsPredictor::native(4, RetryStrategy::Selective);

    let rep_default = simulate_trace(&trace, &mut default, &cfg);
    let rep_kseg = simulate_trace(&trace, &mut kseg, &cfg);

    println!("\n{:<24} {:>14} {:>12}", "method", "wastage (GB·s)", "retries/run");
    for rep in [&rep_default, &rep_kseg] {
        println!(
            "{:<24} {:>14.1} {:>12.3}",
            rep.method,
            rep.avg_wastage_gbs(),
            rep.avg_retries()
        );
    }
    let reduction = 100.0 * (1.0 - rep_kseg.avg_wastage_gbs() / rep_default.avg_wastage_gbs());
    println!("\nk-Segments cuts wastage by {reduction:.1}% vs the workflow defaults");

    // 4. Peek at one prediction: a monotone step function over time.
    let probe = &trace.runs_of("eager/adapter_removal")[100];
    if let ksegments::predictors::Allocation::Dynamic(f) =
        kseg.predict("eager/adapter_removal", probe.input_mib)
    {
        println!(
            "\nadapter_removal @ input {:.0} MiB -> predicted runtime {:.0} s, segments {:?} MiB",
            probe.input_mib,
            f.predicted_runtime().0,
            f.values().iter().map(|v| v.round()).collect::<Vec<_>>()
        );
    }
}
