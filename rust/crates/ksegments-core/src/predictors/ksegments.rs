//! The paper's contribution: the **k-Segments** time-series memory
//! predictor (§III), with Selective and Partial retry strategies
//! (§III-D).
//!
//! Pipeline per prediction (§III-C):
//! 1. predict the task's runtime from its total input size, minus the
//!    largest historical overprediction (conservative underprediction);
//! 2. predict k per-segment peak values from the input size, each plus
//!    its largest historical underprediction;
//! 3. merge into a monotonically non-decreasing step function over the
//!    predicted runtime, with the 100 MB floor and the node-capacity
//!    ceiling.
//!
//! The model fit itself runs on one of two interchangeable backends
//! ([`KsegFitter`]): the native f64 mirror, or the AOT-compiled
//! JAX + Pallas module via PJRT ([`crate::runtime::XlaFitter`]) — the
//! production path, where the fit executes as a single fused XLA
//! computation.

use std::collections::BTreeMap;

use crate::ml::fitter::{FitResult, KsegFitter, NativeFitter};
use crate::ml::step_fn::StepFunction;
use crate::trace::TaskRun;
use crate::units::MemMiB;
#[cfg(test)]
use crate::units::Seconds;

use super::history::HistoryMap;
use super::{Allocation, Defaults, FailureInfo, MemoryPredictor, MIN_ALLOC};

/// §III-D failure-handling strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetryStrategy {
    /// Adjust only the segment that caused the failure.
    Selective,
    /// Adjust the failed segment and every later segment.
    Partial,
}

impl RetryStrategy {
    pub fn label(&self) -> &'static str {
        match self {
            RetryStrategy::Selective => "Selective",
            RetryStrategy::Partial => "Partial",
        }
    }
}

/// Tunables (paper defaults from §IV-A).
#[derive(Debug, Clone)]
pub struct KSegmentsConfig {
    /// Number of segments k (paper default 4).
    pub k: usize,
    /// Retry factor l multiplying failed segment allocations (default 2).
    pub retry_factor: f64,
    /// Minimum allocation when the model predicts ≤ 0 (default 100 MB
    /// ≈ 95.37 MiB, [`MIN_ALLOC`]).
    pub min_alloc: MemMiB,
    /// Node capacity ceiling for any allocation.
    pub node_max: MemMiB,
    /// Sliding training window (most recent executions kept).
    pub n_hist: usize,
    /// Peak-preserving resample length for history series.
    pub t_resample: usize,
    /// Executions required before the model replaces the default.
    pub min_train: usize,
    /// Apply the historical-error offsets (§III-B). Disabling them is
    /// the ablation of the paper's "avoid underpredictions" mechanism
    /// (`bench_harness::ablation`); production keeps them on.
    pub use_offsets: bool,
}

impl Default for KSegmentsConfig {
    fn default() -> Self {
        KSegmentsConfig {
            k: 4,
            retry_factor: 2.0,
            min_alloc: MIN_ALLOC,
            node_max: MemMiB::from_gib(128.0),
            n_hist: 64,
            t_resample: 256,
            min_train: 2,
            use_offsets: true,
        }
    }
}

/// The k-Segments predictor.
pub struct KSegmentsPredictor {
    cfg: KSegmentsConfig,
    strategy: RetryStrategy,
    fitter: Box<dyn KsegFitter>,
    defaults: Defaults,
    histories: HistoryMap,
    /// Fit cache per task, keyed by the history version that produced it.
    fits: BTreeMap<String, (u64, FitResult)>,
}

impl KSegmentsPredictor {
    pub fn with_fitter(
        fitter: Box<dyn KsegFitter>,
        cfg: KSegmentsConfig,
        strategy: RetryStrategy,
    ) -> Self {
        assert!(cfg.k >= 1 && cfg.k <= cfg.t_resample);
        assert!(cfg.retry_factor > 1.0, "retry factor must make progress");
        let histories = HistoryMap::new(cfg.n_hist, cfg.t_resample);
        KSegmentsPredictor {
            cfg,
            strategy,
            fitter,
            defaults: Defaults::default(),
            histories,
            fits: BTreeMap::new(),
        }
    }

    /// Native-backend predictor with paper defaults and the given k.
    pub fn native(k: usize, strategy: RetryStrategy) -> Self {
        let cfg = KSegmentsConfig { k, ..KSegmentsConfig::default() };
        Self::with_fitter(Box::new(NativeFitter), cfg, strategy)
    }

    pub fn config(&self) -> &KSegmentsConfig {
        &self.cfg
    }

    pub fn strategy(&self) -> RetryStrategy {
        self.strategy
    }

    /// Current fit for a task (fitting lazily if the history advanced).
    fn fit_for(&mut self, task_type: &str) -> Option<FitResult> {
        let h = self.histories.get(task_type)?;
        if h.len() < self.cfg.min_train {
            return None;
        }
        let version = h.total_seen();
        if let Some((v, fit)) = self.fits.get(task_type) {
            if *v == version {
                return Some(fit.clone());
            }
        }
        let input = h.fit_input();
        let mut fit = self.fitter.fit(&input, self.cfg.k);
        if !self.cfg.use_offsets {
            fit.rt_offset = 0.0;
            fit.seg_off.iter_mut().for_each(|o| *o = 0.0);
        }
        self.fits
            .insert(task_type.to_string(), (version, fit.clone()));
        Some(fit)
    }
}

impl MemoryPredictor for KSegmentsPredictor {
    fn name(&self) -> String {
        format!("k-Segments {}", self.strategy.label())
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, input_mib: f64) -> Allocation {
        let default = self.defaults.get(task_type);
        let Some(fit) = self.fit_for(task_type) else {
            return Allocation::Static(default);
        };
        // Runtime prediction with the negative offset; never below one
        // monitoring interval.
        let rt = fit.predict_runtime(input_mib).max(1.0);
        let values = fit.predict_segments(input_mib);
        // Boundaries mirror the floor-based training segmentation over
        // the resample grid (see segment_time_bounds).
        let bounds =
            crate::ml::segmentation::segment_time_bounds(rt, self.cfg.t_resample, self.cfg.k);
        let f = StepFunction::monotone_clamped_with_bounds(
            bounds,
            values,
            self.cfg.min_alloc,
            self.cfg.node_max,
        );
        Allocation::Dynamic(f)
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        info: &FailureInfo,
    ) -> Allocation {
        let l = self.cfg.retry_factor;
        match failed {
            // Untrained default failed: double it, like the baselines.
            Allocation::Static(m) => {
                Allocation::Static(MemMiB((m.0 * l).min(self.cfg.node_max.0)))
            }
            Allocation::Dynamic(f) => {
                let seg = f.segment_at(info.time_s);
                let k = f.k();
                let (from, to) = match self.strategy {
                    RetryStrategy::Selective => (seg, seg + 1),
                    RetryStrategy::Partial => (seg, k),
                };
                let mut next = f.scale_segments(from, to, l, self.cfg.node_max);
                // Guarantee progress even if the scaled value still sits
                // below the observed usage (e.g. a deep underprediction):
                // lift the failed segment to cover what was actually seen.
                if next.value_at(info.time_s) <= info.used_mib {
                    let need = (info.used_mib * 1.05).min(self.cfg.node_max.0);
                    let mut values = next.values().to_vec();
                    let hi = to.min(values.len());
                    for v in values[from..hi].iter_mut() {
                        *v = v.max(need);
                    }
                    next = StepFunction::monotone_clamped_with_bounds(
                        next.bounds().to_vec(),
                        values,
                        self.cfg.min_alloc,
                        self.cfg.node_max,
                    );
                }
                Allocation::Dynamic(next)
            }
        }
    }

    fn observe(&mut self, run: &TaskRun) {
        self.histories.push(run);
        // fit cache is invalidated implicitly by the version check
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;

    /// Ramp workload: runtime 100 + 0.1·x, usage ramps linearly to peak
    /// 200 + x.
    fn ramp_run(input: f64) -> TaskRun {
        let runtime = 100.0 + 0.1 * input;
        let peak = 200.0 + input;
        let n = (runtime / 2.0).ceil() as usize;
        let series: Vec<f64> = (0..n)
            .map(|i| peak * ((i + 1) as f64 / n as f64))
            .collect();
        TaskRun {
            task_type: "t".into(),
            input_mib: input,
            runtime: Seconds(n as f64 * 2.0),
            series: UsageSeries::new(2.0, series),
            seq: 0,
        }
    }

    fn trained(strategy: RetryStrategy) -> KSegmentsPredictor {
        let mut p = KSegmentsPredictor::native(4, strategy);
        p.prime("t", MemMiB(8192.0));
        for i in 0..16 {
            p.observe(&ramp_run(100.0 + 50.0 * i as f64));
        }
        p
    }

    #[test]
    fn untrained_returns_default() {
        let mut p = KSegmentsPredictor::native(4, RetryStrategy::Selective);
        p.prime("t", MemMiB(4096.0));
        assert_eq!(p.predict("t", 100.0), Allocation::Static(MemMiB(4096.0)));
        // one observation is below min_train=2
        p.observe(&ramp_run(100.0));
        assert!(!p.predict("t", 100.0).is_dynamic());
    }

    #[test]
    fn trained_returns_monotone_step_function() {
        let mut p = trained(RetryStrategy::Selective);
        let Allocation::Dynamic(f) = p.predict("t", 400.0) else {
            panic!("expected dynamic allocation")
        };
        assert_eq!(f.k(), 4);
        assert!(f.is_monotone());
        // ramp profile: the step function must actually step up
        assert!(f.values()[3] > f.values()[0]);
        // final segment covers the true peak (200 + 400 = 600)
        assert!(f.values()[3] >= 600.0 * 0.9, "{:?}", f.values());
    }

    #[test]
    fn runtime_prediction_is_conservative() {
        let mut p = trained(RetryStrategy::Selective);
        let Allocation::Dynamic(f) = p.predict("t", 400.0) else {
            panic!()
        };
        // true runtime 100 + 40 = 140; prediction must not exceed it by
        // much (offset subtracts the max overprediction)
        assert!(f.predicted_runtime().0 <= 145.0, "{}", f.predicted_runtime());
    }

    #[test]
    fn dynamic_beats_static_peak_on_ramp() {
        // the whole point of the paper: integral of the step function is
        // well below peak * runtime
        let mut p = trained(RetryStrategy::Selective);
        let Allocation::Dynamic(f) = p.predict("t", 400.0) else {
            panic!()
        };
        let rt = f.predicted_runtime().0;
        let dynamic_area = f.integral(rt);
        let static_area = f.max_value() * rt;
        assert!(
            dynamic_area < 0.8 * static_area,
            "dynamic {dynamic_area} vs static {static_area}"
        );
    }

    #[test]
    fn selective_retry_scales_only_failed_segment() {
        let mut p = trained(RetryStrategy::Selective);
        let alloc = p.predict("t", 400.0);
        let Allocation::Dynamic(f) = &alloc else { panic!() };
        let t_fail = f.bounds()[1] * 0.9; // inside segment 1
        let before = f.values().to_vec();
        let info = FailureInfo::oom(t_fail, before[1] + 1.0, 1);
        let Allocation::Dynamic(g) = p.on_failure("t", 400.0, &alloc, &info) else {
            panic!()
        };
        assert!(g.values()[1] >= before[1] * 2.0 * 0.999);
        assert_eq!(g.values()[0], before[0]);
        // later segments only move if monotone clamping requires it
        assert!(g.values()[3] >= before[3] * 0.999);
        assert!(g.is_monotone());
    }

    #[test]
    fn partial_retry_scales_failed_and_later_segments() {
        let mut p = trained(RetryStrategy::Partial);
        let alloc = p.predict("t", 400.0);
        let Allocation::Dynamic(f) = &alloc else { panic!() };
        let before = f.values().to_vec();
        let t_fail = f.bounds()[1] * 0.9;
        let info = FailureInfo::oom(t_fail, before[1] + 1.0, 1);
        let Allocation::Dynamic(g) = p.on_failure("t", 400.0, &alloc, &info) else {
            panic!()
        };
        assert_eq!(g.values()[0], before[0]);
        for s in 1..4 {
            assert!(
                g.values()[s] >= before[s] * 2.0 * 0.999,
                "segment {s}: {} vs {}",
                g.values()[s],
                before[s]
            );
        }
    }

    #[test]
    fn failure_makes_progress_beyond_observed_usage() {
        let mut p = trained(RetryStrategy::Selective);
        let alloc = p.predict("t", 400.0);
        let Allocation::Dynamic(f) = &alloc else { panic!() };
        // usage wildly above 2x the segment value
        let info = FailureInfo::oom(f.bounds()[0] * 0.5, f.values()[0] * 10.0, 1);
        let next = p.on_failure("t", 400.0, &alloc, &info);
        assert!(next.value_at(info.time_s) > info.used_mib);
    }

    #[test]
    fn static_default_failure_doubles() {
        let mut p = KSegmentsPredictor::native(4, RetryStrategy::Partial);
        p.prime("t", MemMiB(1000.0));
        let alloc = p.predict("t", 50.0);
        let info = FailureInfo::oom(3.0, 1500.0, 1);
        let next = p.on_failure("t", 50.0, &alloc, &info);
        assert_eq!(next, Allocation::Static(MemMiB(2000.0)));
    }

    #[test]
    fn fit_cache_reuses_until_new_observation() {
        let mut p = trained(RetryStrategy::Selective);
        let a = p.predict("t", 300.0);
        let b = p.predict("t", 300.0);
        assert_eq!(a, b);
        p.observe(&ramp_run(900.0));
        // cache invalidated; new fit still valid (may or may not differ)
        let _ = p.predict("t", 300.0);
        assert_eq!(p.fits.len(), 1);
    }

    #[test]
    fn respects_node_ceiling_and_floor() {
        let cfg = KSegmentsConfig {
            node_max: MemMiB(500.0),
            ..KSegmentsConfig::default()
        };
        let mut p =
            KSegmentsPredictor::with_fitter(Box::new(NativeFitter), cfg, RetryStrategy::Partial);
        p.prime("t", MemMiB(100.0));
        for i in 0..8 {
            p.observe(&ramp_run(1000.0 + i as f64 * 200.0)); // peaks ≫ 500
        }
        let Allocation::Dynamic(f) = p.predict("t", 2000.0) else {
            panic!()
        };
        assert!(f.max_value() <= 500.0);
        assert!(f.values()[0] >= MIN_ALLOC.0);
    }

    #[test]
    fn k1_degenerates_to_single_peak_prediction() {
        let mut p = KSegmentsPredictor::native(1, RetryStrategy::Selective);
        p.prime("t", MemMiB(8192.0));
        for i in 0..8 {
            p.observe(&ramp_run(100.0 + 100.0 * i as f64));
        }
        let Allocation::Dynamic(f) = p.predict("t", 500.0) else {
            panic!()
        };
        assert_eq!(f.k(), 1);
        // k=1 must cover the global peak (700)
        assert!(f.values()[0] >= 700.0 * 0.9);
    }

    #[test]
    fn name_reflects_strategy() {
        assert_eq!(
            KSegmentsPredictor::native(4, RetryStrategy::Selective).name(),
            "k-Segments Selective"
        );
        assert_eq!(
            KSegmentsPredictor::native(4, RetryStrategy::Partial).name(),
            "k-Segments Partial"
        );
    }
}
