//! Simple (masked) linear regression — the learning primitive of every
//! method in the paper's evaluation.
//!
//! Uses the **centered** closed form `b = cov(x,y)/var(x)` with the same
//! degeneracy fallback as the Pallas kernel (`linfit.py`): fewer than 2
//! points, or relatively-constant x, fall back to slope 0 / intercept =
//! mean. Constants (`sw >= 1.5`, `var > 1e-7·sw·(x̄²+1)`) are identical
//! so the native and XLA paths are differential-testable.

/// A fitted line `y ≈ a + b·x`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LinReg {
    /// Intercept.
    pub a: f64,
    /// Slope.
    pub b: f64,
}

impl LinReg {
    /// Fit from paired samples. Panics if lengths differ.
    pub fn fit(x: &[f64], y: &[f64]) -> LinReg {
        assert_eq!(x.len(), y.len(), "linreg: length mismatch");
        Self::fit_masked(x, y, None)
    }

    /// Fit using only rows where `mask[i]` (None = all rows).
    ///
    /// Mirrors `linfit_kernel`: centered sums, identical thresholds.
    pub fn fit_masked(x: &[f64], y: &[f64], mask: Option<&[bool]>) -> LinReg {
        let included = |i: usize| mask.map_or(true, |m| m[i]);
        let mut sw: f64 = 0.0;
        let mut sx = 0.0;
        let mut sy = 0.0;
        for i in 0..x.len() {
            if included(i) {
                sw += 1.0;
                sx += x[i];
                sy += y[i];
            }
        }
        let sw_safe = sw.max(1.0);
        let xbar = sx / sw_safe;
        let ybar = sy / sw_safe;

        let mut varx = 0.0;
        let mut cov = 0.0;
        for i in 0..x.len() {
            if included(i) {
                let xc = x[i] - xbar;
                varx += xc * xc;
                cov += xc * y[i]; // ybar term cancels under the mask sum
            }
        }
        let thresh = 1e-7 * sw_safe * (xbar * xbar + 1.0);
        let safe = sw >= 1.5 && varx > thresh;
        let b = if safe { cov / varx } else { 0.0 };
        let a = ybar - b * xbar;
        LinReg { a, b }
    }

    /// Predicted value at `x`.
    pub fn predict(&self, x: f64) -> f64 {
        self.a + self.b * x
    }

    /// Residual statistics of this fit over a training set.
    pub fn residuals(&self, x: &[f64], y: &[f64]) -> ResidualStats {
        let mut st = ResidualStats::default();
        for (&xi, &yi) in x.iter().zip(y) {
            st.update(yi - self.predict(xi));
        }
        st
    }
}

/// Streaming residual statistics used by the offset strategies:
/// Witt et al. add the stddev (LR mean±) or the largest observed
/// underprediction (LR max); k-Segments uses the extreme errors.
///
/// Error convention: `e = actual − predicted`; `e > 0` is an
/// UNDERprediction (actual exceeded the prediction).
#[derive(Debug, Clone, Copy, Default)]
pub struct ResidualStats {
    n: usize,
    mean: f64,
    m2: f64,
    /// Largest underprediction (max positive error), 0 if none.
    pub max_under: f64,
    /// Largest overprediction magnitude (−min negative error), 0 if none.
    pub max_over: f64,
    /// Mean of only the negative errors (overpredictions), for LR mean−.
    neg_sum: f64,
    neg_n: usize,
}

impl ResidualStats {
    pub fn update(&mut self, e: f64) {
        self.n += 1;
        let d = e - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (e - self.mean);
        if e > self.max_under {
            self.max_under = e;
        }
        if -e > self.max_over {
            self.max_over = -e;
        }
        if e < 0.0 {
            self.neg_sum += e;
            self.neg_n += 1;
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population standard deviation of the errors.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    /// Mean magnitude of overpredictions only (Witt's LR mean−).
    pub fn mean_neg_magnitude(&self) -> f64 {
        if self.neg_n == 0 {
            0.0
        } else {
            -self.neg_sum / self.neg_n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovery() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let f = LinReg::fit(&x, &y);
        assert!((f.a - 3.0).abs() < 1e-9);
        assert!((f.b - 2.0).abs() < 1e-9);
        assert!((f.predict(10.0) - 23.0).abs() < 1e-9);
    }

    #[test]
    fn single_point_falls_back_to_mean() {
        let f = LinReg::fit(&[5.0], &[42.0]);
        assert_eq!(f, LinReg { a: 42.0, b: 0.0 });
    }

    #[test]
    fn empty_fit_is_zero() {
        let f = LinReg::fit(&[], &[]);
        assert_eq!(f, LinReg { a: 0.0, b: 0.0 });
    }

    #[test]
    fn constant_x_falls_back_to_mean() {
        let f = LinReg::fit(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]);
        assert!((f.a - 2.0).abs() < 1e-12);
        assert_eq!(f.b, 0.0);
    }

    #[test]
    fn masked_rows_are_ignored() {
        let x = [1.0, 2.0, 3.0, 1e9];
        let y = [2.0, 4.0, 6.0, -5e9];
        let mask = [true, true, true, false];
        let f = LinReg::fit_masked(&x, &y, Some(&mask));
        assert!((f.b - 2.0).abs() < 1e-9, "{f:?}");
        assert!(f.a.abs() < 1e-9, "{f:?}");
    }

    #[test]
    fn large_close_x_is_stable() {
        // the f32 cancellation case that motivated centering; in f64 with
        // centering the slope is exact
        let x = [8322.689, 8706.586];
        let y = [4367.238, 4601.943];
        let f = LinReg::fit(&x, &y);
        let slope = (y[1] - y[0]) / (x[1] - x[0]);
        assert!((f.b - slope).abs() < 1e-9);
    }

    #[test]
    fn residual_stats_moments() {
        let mut st = ResidualStats::default();
        for e in [1.0, -1.0, 3.0, -3.0] {
            st.update(e);
        }
        assert_eq!(st.n(), 4);
        assert!(st.mean().abs() < 1e-12);
        assert!((st.std() - (5.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(st.max_under, 3.0);
        assert_eq!(st.max_over, 3.0);
        assert!((st.mean_neg_magnitude() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn residuals_of_perfect_fit_are_zero() {
        let x = [1.0, 2.0, 3.0];
        let y = [10.0, 20.0, 30.0];
        let f = LinReg::fit(&x, &y);
        let st = f.residuals(&x, &y);
        assert!(st.max_under < 1e-9);
        assert!(st.max_over < 1e-9);
        assert!(st.std() < 1e-9);
    }

    #[test]
    fn underprediction_tracking() {
        // y actual above the line for one point
        let f = LinReg { a: 0.0, b: 1.0 };
        let st = f.residuals(&[1.0, 2.0], &[1.5, 1.5]);
        assert!((st.max_under - 0.5).abs() < 1e-12); // 1.5 vs predicted 1.0
        assert!((st.max_over - 0.5).abs() < 1e-12); // 1.5 vs predicted 2.0
    }
}
