//! Small descriptive-statistics helpers shared by metrics and the
//! bench harness.
//!
//! One percentile implementation serves every caller — the ensemble's
//! percentile sub-model, the bench timer, and the scheduler's
//! `SchedReport` queue-wait quantiles — so "p95"
//! means the same thing everywhere. Quantiles are **linear
//! interpolation** over the sorted order statistics (numpy's default,
//! R type 7): the q-th percentile of n samples sits at fractional rank
//! `(q/100)·(n−1)` and interpolates between its two neighbors. The
//! previous nearest-rank rounding picked an arbitrary neighbor for
//! even-length medians and small-window quantiles.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation (0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolation percentile of an unsorted slice; `q` is
/// clamped to [0, 100], empty input yields 0. Sorts a copy — callers
/// querying many quantiles of the same data should build a
/// [`SortedSamples`] once instead.
///
/// # Example
///
/// ```
/// use ksegments::util::stats::percentile;
///
/// // the interpolated even-length median
/// assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.5);
/// ```
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    percentile_sorted(&s, q)
}

/// [`percentile`] over an **already ascending-sorted** slice — no copy,
/// no sort. The shared kernel behind [`percentile`] and
/// [`SortedSamples::percentile`].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    // lo == hi at integer ranks (incl. q = 0 and q = 100)
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// A sample set sorted **once** for repeated quantile queries — the
/// fix for percentile hot paths that re-sorted the full vector on
/// every call (see `benches/hotpath.rs` `stats/percentile`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SortedSamples {
    sorted: Vec<f64>,
}

impl SortedSamples {
    /// Sort a copy of `xs` (NaNs order via `total_cmp`).
    pub fn new(xs: &[f64]) -> SortedSamples {
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        SortedSamples { sorted }
    }

    /// q-th percentile in O(1) (after the one-time sort).
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_sorted(&self.sorted, q)
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// The ascending samples.
    pub fn as_slice(&self) -> &[f64] {
        &self.sorted
    }
}

/// Pearson correlation (0 when degenerate).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.len() < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(x), mean(y));
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        cov += (a - mx) * (b - my);
        vx += (a - mx) * (a - mx);
        vy += (b - my) * (b - my);
    }
    if vx <= 0.0 || vy <= 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(std(&[5.0]), 0.0);
        assert!((std(&[1.0, -1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_on_odd_length() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    /// The headline regression: an even-length median interpolates
    /// instead of rounding to an arbitrary neighbor.
    #[test]
    fn even_length_median_interpolates() {
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.5);
        assert_eq!(percentile(&[4.0, 1.0, 3.0, 2.0], 50.0), 2.5, "order must not matter");
        assert_eq!(percentile(&[10.0, 20.0], 50.0), 15.0);
    }

    #[test]
    fn q_between_ranks_interpolates_linearly() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        // rank = 0.95·4 = 3.8 → 40 + 0.8·10
        assert!((percentile(&xs, 95.0) - 48.0).abs() < 1e-12);
        // rank = 0.25·4 = 1.0 → exactly the order statistic
        assert_eq!(percentile(&xs, 25.0), 20.0);
        // rank = 0.10·4 = 0.4 → 10 + 0.4·10
        assert!((percentile(&xs, 10.0) - 14.0).abs() < 1e-12);
        // two samples: q=25 sits a quarter of the way up
        assert_eq!(percentile(&[10.0, 20.0], 25.0), 12.5);
    }

    #[test]
    fn extreme_and_degenerate_quantiles() {
        assert_eq!(percentile(&[7.0], 0.0), 7.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
        assert_eq!(percentile(&[7.0], 100.0), 7.0);
        let xs = [2.0, 8.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 2.0);
        assert_eq!(percentile(&xs, 100.0), 8.0);
        // out-of-range q clamps instead of panicking
        assert_eq!(percentile(&xs, -10.0), 2.0);
        assert_eq!(percentile(&xs, 250.0), 8.0);
    }

    #[test]
    fn sorted_samples_match_percentile() {
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0, 2.0];
        let s = SortedSamples::new(&xs);
        assert_eq!(s.len(), 6);
        assert!(!s.is_empty());
        for q in [0.0, 10.0, 25.0, 50.0, 77.7, 95.0, 100.0] {
            assert_eq!(s.percentile(q), percentile(&xs, q), "q={q}");
        }
        assert_eq!(s.as_slice(), &[1.0, 2.0, 3.0, 5.0, 7.0, 9.0]);
        let empty = SortedSamples::new(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.percentile(50.0), 0.0);
    }

    #[test]
    fn pearson_perfect_and_degenerate() {
        let x = [1.0, 2.0, 3.0];
        let y = [10.0, 20.0, 30.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [30.0, 20.0, 10.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }
}
