//! PJRT runtime bridge: load the AOT-lowered JAX + Pallas fit modules
//! and execute them from the rust online-learning path.
//!
//! Build-time python (`make artifacts`) emits one HLO-text module per
//! segment count k (`artifacts/ksegments_fit_k{K}.hlo.txt`) plus a
//! `manifest.json` with the padded shapes. This module loads the text,
//! compiles it once on the PJRT CPU client, and marshals task history
//! in and [`FitResult`]s out. Python never runs at request time.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).
//!
//! The PJRT pieces need the `xla` crate (xla-rs bindings over a
//! vendored `xla_extension`), which not every build environment
//! carries — they are gated behind the `xla` cargo feature. Without
//! it, [`ArtifactRegistry::load`]/[`XlaFitter::load_default`] return
//! an error explaining the gate and every caller falls back to the
//! bit-mirrored [`NativeFitter`], so the default build has no native
//! dependencies beyond anyhow.

use std::collections::BTreeMap;
use std::path::Path;
#[cfg(feature = "xla")]
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use crate::ml::fitter::{FitInput, FitResult, KsegFitter, NativeFitter};
#[cfg(feature = "xla")]
use crate::ml::linreg::LinReg;
use crate::util::json::Json;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub n_hist: usize,
    pub t_max: usize,
    /// k -> artifact file name.
    pub fits: BTreeMap<usize, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let n_hist = v.get("n_hist").as_u64().context("manifest n_hist")? as usize;
        let t_max = v.get("t_max").as_u64().context("manifest t_max")? as usize;
        let mut fits = BTreeMap::new();
        for (k, name) in v.get("fits").as_obj().context("manifest fits")? {
            let k: usize = k.parse().map_err(|_| anyhow!("bad k {k:?}"))?;
            fits.insert(k, name.as_str().context("fit name")?.to_string());
        }
        if fits.is_empty() {
            bail!("manifest has no fit modules");
        }
        Ok(Manifest { n_hist, t_max, fits })
    }
}

/// PJRT CPU client + lazily compiled per-k executables.
#[cfg(feature = "xla")]
pub struct ArtifactRegistry {
    dir: PathBuf,
    manifest: Manifest,
    client: xla::PjRtClient,
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

// SAFETY: the registry is only ever used behind exclusive references
// (&mut self on every entry point), so cross-thread use is serialized.
// The PJRT CPU client itself is thread-compatible under that regime.
#[cfg(feature = "xla")]
unsafe impl Send for ArtifactRegistry {}

#[cfg(feature = "xla")]
impl ArtifactRegistry {
    /// Load the manifest and start the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            manifest,
            client,
            exes: BTreeMap::new(),
        })
    }

    /// Default artifact location (repo-root `artifacts/`).
    pub fn load_default() -> Result<ArtifactRegistry> {
        Self::load(Path::new("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn available_ks(&self) -> Vec<usize> {
        self.manifest.fits.keys().copied().collect()
    }

    /// Compile (once) and return the executable for segment count `k`.
    pub fn executable(&mut self, k: usize) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(&k) {
            let name = self
                .manifest
                .fits
                .get(&k)
                .ok_or_else(|| anyhow!("no artifact for k={k}"))?;
            let path = self.dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling k={k}: {e:?}"))?;
            self.exes.insert(k, exe);
        }
        Ok(&self.exes[&k])
    }

    /// Execute the k-fit on padded history arrays.
    ///
    /// Rows beyond `n` are zero-padded with `valid = 0`; if the history
    /// exceeds `n_hist`, the most recent rows are kept (matching the
    /// sliding window of `predictors::history`).
    pub fn fit(&mut self, input: &FitInput, k: usize) -> Result<FitResult> {
        input.validate().map_err(|e| anyhow!("fit input: {e}"))?;
        let n_hist = self.manifest.n_hist;
        let t_max = self.manifest.t_max;
        if input.series.first().map(Vec::len) != Some(t_max) {
            bail!(
                "series rows must be resampled to t_max={t_max} (got {:?})",
                input.series.first().map(Vec::len)
            );
        }
        let n = input.n();
        let start = n.saturating_sub(n_hist);
        let rows = n - start;

        let mut x = vec![0f32; n_hist];
        let mut rt = vec![0f32; n_hist];
        let mut valid = vec![0f32; n_hist];
        let mut y = vec![0f32; n_hist * t_max];
        for (i, src) in (start..n).enumerate() {
            x[i] = input.x[src] as f32;
            rt[i] = input.runtime[src] as f32;
            valid[i] = 1.0;
            for (j, &v) in input.series[src].iter().enumerate() {
                y[i * t_max + j] = v as f32;
            }
        }

        let x_lit = xla::Literal::vec1(&x);
        let y_lit = xla::Literal::vec1(&y).reshape(&[n_hist as i64, t_max as i64])?;
        let rt_lit = xla::Literal::vec1(&rt);
        let v_lit = xla::Literal::vec1(&valid);

        let exe = self.executable(k)?;
        let result = exe
            .execute::<xla::Literal>(&[x_lit, y_lit, rt_lit, v_lit])
            .map_err(|e| anyhow!("executing fit k={k}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching fit result: {e:?}"))?;

        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untupling fit result: {e:?}"))?;
        if parts.len() != 4 {
            bail!("fit module returned {} outputs, expected 4", parts.len());
        }
        let rt_coef = parts[0].to_vec::<f32>()?;
        let rt_offset = parts[1].to_vec::<f32>()?;
        let seg_coef = parts[2].to_vec::<f32>()?;
        let seg_off = parts[3].to_vec::<f32>()?;
        if rt_coef.len() != 2 || rt_offset.len() != 1 || seg_coef.len() != 2 * k || seg_off.len() != k
        {
            bail!(
                "fit output shapes off: rt={} off={} seg={} segoff={} (k={k}, rows={rows})",
                rt_coef.len(),
                rt_offset.len(),
                seg_coef.len(),
                seg_off.len()
            );
        }

        Ok(FitResult {
            rt: LinReg { a: rt_coef[0] as f64, b: rt_coef[1] as f64 },
            rt_offset: rt_offset[0] as f64,
            seg: (0..k)
                .map(|s| LinReg { a: seg_coef[2 * s] as f64, b: seg_coef[2 * s + 1] as f64 })
                .collect(),
            seg_off: seg_off.iter().map(|&v| v as f64).collect(),
        })
    }
}

/// [`KsegFitter`] backend that executes the AOT JAX + Pallas module.
///
/// Falls back to the native fitter when the requested shape has no
/// artifact (k outside the compiled range, or series length mismatch)
/// — the fallback is bit-mirrored math, so behaviour is identical up
/// to f32-vs-f64 rounding (bounded by the differential tests in
/// rust/tests/integration_runtime.rs).
#[cfg(feature = "xla")]
pub struct XlaFitter {
    registry: ArtifactRegistry,
    native: NativeFitter,
    /// Count of fits served by XLA vs the native fallback (observability).
    pub xla_fits: u64,
    pub native_fits: u64,
}

#[cfg(feature = "xla")]
impl XlaFitter {
    pub fn new(registry: ArtifactRegistry) -> XlaFitter {
        XlaFitter { registry, native: NativeFitter, xla_fits: 0, native_fits: 0 }
    }

    pub fn load_default() -> Result<XlaFitter> {
        Ok(XlaFitter::new(ArtifactRegistry::load_default()?))
    }

    pub fn manifest(&self) -> &Manifest {
        self.registry.manifest()
    }
}

#[cfg(feature = "xla")]
impl KsegFitter for XlaFitter {
    fn backend(&self) -> &'static str {
        "xla-pjrt"
    }

    // Sanctioned stderr site (the other is the roster's fallback
    // warning): a silent XLA→native fallback would misattribute
    // benchmark results, and core has no logging facility by design.
    #[allow(clippy::print_stderr)]
    fn fit(&mut self, input: &FitInput, k: usize) -> FitResult {
        let usable = self.registry.manifest.fits.contains_key(&k)
            && input.series.first().map(Vec::len) == Some(self.registry.manifest.t_max);
        if usable {
            match self.registry.fit(input, k) {
                Ok(fit) => {
                    self.xla_fits += 1;
                    return fit;
                }
                Err(e) => {
                    // Execution errors are unexpected; fall back loudly.
                    eprintln!("XlaFitter: falling back to native fit: {e:#}");
                }
            }
        }
        self.native_fits += 1;
        self.native.fit(input, k)
    }
}

// ---------------------------------------------------------------------
// Feature-gated stubs: same public API, but loading always fails with
// a message naming the gate, so every caller takes its native-fallback
// branch and the default build needs no xla crate.
// ---------------------------------------------------------------------

#[cfg(not(feature = "xla"))]
const NO_XLA: &str = "built without the `xla` cargo feature — the PJRT runtime is \
                      unavailable; rebuild with `--features xla` (requires the xla-rs \
                      bindings and a vendored xla_extension, see DESIGN.md §2)";

/// Stub registry (crate built without the `xla` feature): loading
/// always fails after surfacing any artifact errors first.
#[cfg(not(feature = "xla"))]
pub struct ArtifactRegistry {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl ArtifactRegistry {
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let _ = Manifest::load(dir)?;
        bail!("{NO_XLA}");
    }

    pub fn load_default() -> Result<ArtifactRegistry> {
        Self::load(Path::new("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn available_ks(&self) -> Vec<usize> {
        self.manifest.fits.keys().copied().collect()
    }

    pub fn fit(&mut self, _input: &FitInput, k: usize) -> Result<FitResult> {
        bail!("cannot run the k={k} fit: {NO_XLA}");
    }
}

/// Stub fitter (crate built without the `xla` feature): never
/// constructible via [`XlaFitter::load_default`]; fits, were one ever
/// built, would all take the native path.
#[cfg(not(feature = "xla"))]
pub struct XlaFitter {
    registry: ArtifactRegistry,
    native: NativeFitter,
    pub xla_fits: u64,
    pub native_fits: u64,
}

#[cfg(not(feature = "xla"))]
impl XlaFitter {
    pub fn new(registry: ArtifactRegistry) -> XlaFitter {
        XlaFitter { registry, native: NativeFitter, xla_fits: 0, native_fits: 0 }
    }

    pub fn load_default() -> Result<XlaFitter> {
        Ok(XlaFitter::new(ArtifactRegistry::load_default()?))
    }

    pub fn manifest(&self) -> &Manifest {
        self.registry.manifest()
    }
}

#[cfg(not(feature = "xla"))]
impl KsegFitter for XlaFitter {
    fn backend(&self) -> &'static str {
        "native-fallback (no xla feature)"
    }

    fn fit(&mut self, input: &FitInput, k: usize) -> FitResult {
        self.native_fits += 1;
        self.native.fit(input, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_minimal() {
        let dir = std::env::temp_dir().join("ksegments_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"n_hist": 8, "t_max": 16, "fits": {"2": "f2.hlo.txt", "4": "f4.hlo.txt"}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_hist, 8);
        assert_eq!(m.t_max, 16);
        assert_eq!(m.fits.get(&4).unwrap(), "f4.hlo.txt");
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load(Path::new("/nonexistent/nope")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn manifest_rejects_empty_fits() {
        let dir = std::env::temp_dir().join("ksegments_manifest_empty");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"n_hist": 8, "t_max": 16, "fits": {}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    // Full execution tests against real artifacts live in
    // rust/tests/integration_runtime.rs (they need `make artifacts`).
}
