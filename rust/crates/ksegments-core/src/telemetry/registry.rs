//! Metrics registry — counters, gauges and fixed-bucket histograms
//! with Prometheus text exposition and a JSON snapshot.
//!
//! The registry is deliberately lock-free: every thread/shard owns a
//! private `Registry` (or plain counter struct) and partials are
//! folded with [`Registry::merge`] after the joins — the same
//! merge-in-deterministic-order discipline as
//! [`crate::wastage::MethodReport`]. Nothing here synchronizes, so
//! recording a metric costs a `BTreeMap` lookup at worst and can never
//! perturb scheduling or prediction.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io;

use crate::util::json::JsonWriter;

/// Fixed-bucket histogram. `bounds` are finite upper bounds (ascending,
/// `le` semantics); one extra overflow bucket catches everything above
/// the last bound. Mergeable when the bounds match exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// `bounds.len() + 1` counts; the last is the `+Inf` bucket.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Fold another histogram with **identical bounds** into this one.
    /// Counts add, so merging is permutation-invariant.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "merging histograms with different buckets");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket (non-cumulative) counts, overflow bucket last.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn count(&self) -> u64 {
        self.count
    }
}

/// A named collection of counters, gauges and histograms.
///
/// Metric names may carry Prometheus-style labels inline:
/// `sched_oom_kills{policy="static-peak"}`. Exposition splits the name
/// at the first `{` to place `# TYPE` lines and to splice `le` into
/// histogram bucket labels.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter_add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record one observation; the histogram is created with `bounds`
    /// on first use (later calls must pass the same bounds).
    pub fn observe(&mut self, name: &str, bounds: &[f64], v: f64) {
        self.hists
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Fold another registry into this one: counters and histogram
    /// buckets add; gauges take the other side's value (last write
    /// wins, like a scrape).
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            self.counter_add(k, *v);
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            match self.hists.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.hists.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_base = String::new();
        for (name, v) in &self.counters {
            let (base, _) = split_labels(name);
            if base != last_base {
                let _ = writeln!(out, "# TYPE {base} counter");
                last_base = base.to_string();
            }
            let _ = writeln!(out, "{name} {v}");
        }
        last_base.clear();
        for (name, v) in &self.gauges {
            let (base, _) = split_labels(name);
            if base != last_base {
                let _ = writeln!(out, "# TYPE {base} gauge");
                last_base = base.to_string();
            }
            let _ = writeln!(out, "{name} {v}");
        }
        last_base.clear();
        for (name, h) in &self.hists {
            let (base, labels) = split_labels(name);
            if base != last_base {
                let _ = writeln!(out, "# TYPE {base} histogram");
                last_base = base.to_string();
            }
            let mut cum = 0u64;
            for (i, b) in h.bounds().iter().enumerate() {
                cum += h.counts()[i];
                let _ = writeln!(out, "{} {cum}", bucket_name(base, labels, &fmt_bound(*b)));
            }
            cum += *h.counts().last().expect("histogram has an overflow bucket");
            let _ = writeln!(out, "{} {cum}", bucket_name(base, labels, "+Inf"));
            let _ = writeln!(out, "{}_sum{} {}", base, brace(labels), h.sum());
            let _ = writeln!(out, "{}_count{} {}", base, brace(labels), h.count());
        }
        out
    }

    /// Compact JSON snapshot (counters/gauges/histograms).
    pub fn to_json(&self) -> String {
        let buf = self.write_json(Vec::new()).expect("in-memory JSON write cannot fail");
        String::from_utf8(buf).expect("JSON is UTF-8")
    }

    fn write_json<W: io::Write>(&self, w: W) -> io::Result<W> {
        let mut j = JsonWriter::new(w);
        j.begin_obj()?;
        j.key("counters")?;
        j.begin_obj()?;
        for (k, v) in &self.counters {
            j.field_u64(k, *v)?;
        }
        j.end_obj()?;
        j.key("gauges")?;
        j.begin_obj()?;
        for (k, v) in &self.gauges {
            j.field_f64(k, *v)?;
        }
        j.end_obj()?;
        j.key("histograms")?;
        j.begin_obj()?;
        for (k, h) in &self.hists {
            j.key(k)?;
            j.begin_obj()?;
            j.key("bounds")?;
            j.begin_arr()?;
            for b in h.bounds() {
                j.f64_val(*b)?;
            }
            j.end_arr()?;
            j.key("counts")?;
            j.begin_arr()?;
            for c in h.counts() {
                j.u64_val(*c)?;
            }
            j.end_arr()?;
            j.field_f64("sum", h.sum())?;
            j.field_u64("count", h.count())?;
            j.end_obj()?;
        }
        j.end_obj()?;
        j.end_obj()?;
        j.finish()
    }
}

/// Split `name{labels}` into (`name`, `Some("labels")`).
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match name.find('{') {
        Some(i) => (&name[..i], name[i + 1..].strip_suffix('}')),
        None => (name, None),
    }
}

fn brace(labels: Option<&str>) -> String {
    match labels {
        Some(l) => format!("{{{l}}}"),
        None => String::new(),
    }
}

fn bucket_name(base: &str, labels: Option<&str>, le: &str) -> String {
    match labels {
        Some(l) => format!("{base}_bucket{{{l},le=\"{le}\"}}"),
        None => format!("{base}_bucket{{le=\"{le}\"}}"),
    }
}

fn fmt_bound(b: f64) -> String {
    if b.fract() == 0.0 && b.abs() < 1e15 {
        format!("{}", b as i64)
    } else {
        format!("{b}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn counters_and_gauges_record() {
        let mut r = Registry::new();
        assert!(r.is_empty());
        r.counter_add("events", 3);
        r.counter_add("events", 4);
        r.gauge_set("util", 0.25);
        r.gauge_set("util", 0.5);
        assert_eq!(r.counter("events"), 7);
        assert_eq!(r.counter("missing"), 0);
        assert_eq!(r.gauge("util"), Some(0.5));
        assert!(!r.is_empty());
    }

    #[test]
    fn histogram_buckets_have_le_semantics() {
        let mut h = Histogram::new(&[1.0, 5.0]);
        for v in [0.5, 1.0, 2.0, 100.0] {
            h.observe(v);
        }
        // 1.0 lands in the le=1 bucket (inclusive upper bound)
        assert_eq!(h.counts(), &[2, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 103.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_merge_is_permutation_invariant() {
        // integer-valued observations: f64 addition is exact, so even
        // `sum` is order-independent
        let obs = [1.0, 7.0, 3.0, 2.0, 9.0, 4.0];
        let bounds = [2.0, 5.0];
        let mut parts: Vec<Histogram> = obs
            .iter()
            .map(|&v| {
                let mut h = Histogram::new(&bounds);
                h.observe(v);
                h
            })
            .collect();
        let mut fwd = Histogram::new(&bounds);
        for p in &parts {
            fwd.merge(p);
        }
        parts.reverse();
        let mut rev = Histogram::new(&bounds);
        for p in &parts {
            rev.merge(p);
        }
        assert_eq!(fwd, rev);
        assert_eq!(fwd.counts(), &[2, 2, 2]);
    }

    #[test]
    #[should_panic(expected = "different buckets")]
    fn histogram_merge_rejects_mismatched_bounds() {
        let mut a = Histogram::new(&[1.0]);
        a.merge(&Histogram::new(&[2.0]));
    }

    #[test]
    fn registry_merge_folds_all_kinds() {
        let mut a = Registry::new();
        a.counter_add("c", 1);
        a.observe("h", &[10.0], 3.0);
        let mut b = Registry::new();
        b.counter_add("c", 2);
        b.gauge_set("g", 9.0);
        b.observe("h", &[10.0], 30.0);
        b.observe("h2", &[1.0], 0.5);
        a.merge(&b);
        assert_eq!(a.counter("c"), 3);
        assert_eq!(a.gauge("g"), Some(9.0));
        assert_eq!(a.histogram("h").unwrap().counts(), &[1, 1]);
        assert_eq!(a.histogram("h2").unwrap().count(), 1);
    }

    #[test]
    fn prometheus_exposition_renders_all_sections() {
        let mut r = Registry::new();
        r.counter_add("sched_oom_kills{policy=\"static-peak\"}", 2);
        r.counter_add("sched_oom_kills{policy=\"segment-wise\"}", 1);
        r.gauge_set("sched_util", 0.5);
        r.observe("wait_s", &[1.0, 5.0], 0.5);
        r.observe("wait_s", &[1.0, 5.0], 99.0);
        let text = r.to_prometheus();
        // one TYPE line per base name, not per labeled series
        assert_eq!(text.matches("# TYPE sched_oom_kills counter").count(), 1);
        assert!(text.contains("sched_oom_kills{policy=\"static-peak\"} 2"), "{text}");
        assert!(text.contains("# TYPE sched_util gauge"), "{text}");
        assert!(text.contains("# TYPE wait_s histogram"), "{text}");
        assert!(text.contains("wait_s_bucket{le=\"1\"} 1"), "{text}");
        assert!(text.contains("wait_s_bucket{le=\"5\"} 1"), "{text}");
        assert!(text.contains("wait_s_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("wait_s_sum 99.5"), "{text}");
        assert!(text.contains("wait_s_count 2"), "{text}");
    }

    #[test]
    fn labeled_histogram_splices_le_into_labels() {
        let mut r = Registry::new();
        r.observe("wait_s{policy=\"both\"}", &[1.0], 0.5);
        let text = r.to_prometheus();
        assert!(text.contains("wait_s_bucket{policy=\"both\",le=\"1\"} 1"), "{text}");
        assert!(text.contains("wait_s_sum{policy=\"both\"} 0.5"), "{text}");
    }

    #[test]
    fn json_snapshot_parses_back() {
        let mut r = Registry::new();
        r.counter_add("c", 7);
        r.gauge_set("g", 1.5);
        r.observe("h", &[2.0], 1.0);
        let v = Json::parse(&r.to_json()).expect("valid JSON");
        assert_eq!(v.get("counters").get("c").as_u64(), Some(7));
        assert_eq!(v.get("gauges").get("g").as_f64(), Some(1.5));
        let h = v.get("histograms").get("h");
        assert_eq!(h.get("count").as_u64(), Some(1));
        assert_eq!(h.get("bounds").as_arr().unwrap().len(), 1);
        assert_eq!(h.get("counts").as_arr().unwrap().len(), 2);
    }
}
