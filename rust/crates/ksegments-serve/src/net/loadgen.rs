//! Load generator for the TCP prediction service.
//!
//! Replays any [`TraceSource`] against a running server over N
//! connections at a target aggregate QPS, timing every predict
//! round-trip. Latency here is **wall time** by design: it measures
//! the served protocol stack (socket, framing, shard queue, model),
//! not simulated workflow time — the sanctioned exception of DESIGN.md
//! §12, same as the coordinator's wakeup spans.
//!
//! Runs are routed to connections with the same FNV hash the service
//! uses for shards ([`shard_of`] over `connections`), so each task
//! type's traffic stays on one connection in arrival order — which
//! preserves the per-type predict-after-complete contract and makes a
//! TCP replay's predictions and final counters bit-identical to the
//! in-process [`ServiceHandle::replay_source`] at any connection
//! count.
//!
//! [`ServiceHandle::replay_source`]: crate::coordinator::ServiceHandle::replay_source

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use ksegments_core::source::{TraceSource, DEFAULT_CHUNK};
use ksegments_core::trace::TaskRun;
use ksegments_core::units::MemMiB;
use ksegments_core::util::stats::percentile;
use ksegments_core::util::timer::Stopwatch;

use crate::coordinator::{shard_of, ServiceStats};
use crate::net::client::NetClient;

/// Knobs for [`run_loadgen`].
pub struct LoadgenConfig {
    /// Client connections (and dispatch partitions).
    pub connections: usize,
    /// Target aggregate dispatch rate; `0.0` = unthrottled.
    pub qps: f64,
    /// Keep replaying (rewinding the source) until this much wall time
    /// has passed; `None` = a single pass over the source.
    pub duration_s: Option<f64>,
    /// Send a `shutdown` frame once done (after collecting stats).
    pub send_shutdown: bool,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig { connections: 2, qps: 0.0, duration_s: None, send_shutdown: false }
    }
}

/// What a loadgen run measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub connections: usize,
    /// Runs fully served (predict answered + completion acked).
    pub runs_fed: u64,
    /// Request failures of any kind, as seen by the clients.
    pub errors: u64,
    pub wall_s: f64,
    /// Predict round-trip latency percentiles, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Served predicts per second of wall time — the saturation
    /// throughput when `qps` is 0.
    pub predict_rps: f64,
    /// Aggregated live service counters after the replay.
    pub stats: ServiceStats,
    pub per_shard: Vec<ServiceStats>,
}

enum Job {
    Prime(String, MemMiB),
    Run(Box<TaskRun>),
}

/// Replay `src` against the server at `addr` per `cfg`.
pub fn run_loadgen(
    addr: &str,
    src: &mut dyn TraceSource,
    cfg: &LoadgenConfig,
) -> Result<LoadgenReport> {
    let n = cfg.connections.max(1);
    let mut txs: Vec<Sender<Job>> = Vec::with_capacity(n);
    let mut workers = Vec::with_capacity(n);
    for i in 0..n {
        let (tx, rx) = channel();
        let addr = addr.to_string();
        let worker = std::thread::Builder::new()
            .name(format!("ksegments-loadgen-{i}"))
            .spawn(move || worker_loop(&addr, rx))
            .context("spawning loadgen worker")?;
        txs.push(tx);
        workers.push(worker);
    }

    // primes first, routed like the runs, so each connection primes
    // its own types before replaying them (channel FIFO does the rest)
    src.rewind()?;
    for (ty, mem) in src.defaults() {
        let s = shard_of(&ty, n);
        // in bounds: shard_of reduces modulo n == txs.len()
        txs[s] // lint:allow(panic-policy)
            .send(Job::Prime(ty, mem))
            .map_err(|_| anyhow!("worker {s} exited early"))?;
    }

    let sw = Stopwatch::start();
    let mut dispatched = 0u64;
    let mut empty_passes = 0u32;
    'dispatch: loop {
        let chunk = src.next_chunk(DEFAULT_CHUNK)?;
        if chunk.is_empty() {
            match cfg.duration_s {
                Some(d) if sw.elapsed_s() < d => {
                    empty_passes += 1;
                    if empty_passes > 1 {
                        bail!("source {} yields no runs", src.origin());
                    }
                    src.rewind()?;
                    continue;
                }
                _ => break,
            }
        }
        empty_passes = 0;
        for run in chunk {
            if let Some(d) = cfg.duration_s {
                if sw.elapsed_s() >= d {
                    break 'dispatch;
                }
            }
            if cfg.qps > 0.0 {
                let ahead = dispatched as f64 / cfg.qps - sw.elapsed_s();
                if ahead > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(ahead));
                }
            }
            let s = shard_of(&run.task_type, n);
            // in bounds: shard_of reduces modulo n == txs.len()
            txs[s] // lint:allow(panic-policy)
                .send(Job::Run(Box::new(run)))
                .map_err(|_| anyhow!("worker {s} exited early"))?;
            dispatched += 1;
        }
    }

    drop(txs);
    let mut runs_fed = 0u64;
    let mut errors = 0u64;
    let mut latencies_ms: Vec<f64> = Vec::new();
    for worker in workers {
        let (fed, errs, lat) =
            worker.join().map_err(|_| anyhow!("loadgen worker panicked"))??;
        runs_fed += fed;
        errors += errs;
        latencies_ms.extend(lat);
    }
    let wall_s = sw.elapsed_s();

    // a fresh control connection for the final counters + drain
    let mut control = NetClient::connect(addr)?;
    let (stats, per_shard) = control.stats()?;
    if cfg.send_shutdown {
        control.shutdown_server()?;
    }

    Ok(LoadgenReport {
        connections: n,
        runs_fed,
        errors,
        wall_s,
        p50_ms: percentile(&latencies_ms, 50.0),
        p99_ms: percentile(&latencies_ms, 99.0),
        p999_ms: percentile(&latencies_ms, 99.9),
        predict_rps: if wall_s > 0.0 { runs_fed as f64 / wall_s } else { 0.0 },
        stats,
        per_shard,
    })
}

/// One connection's replay loop: predict (timed) then complete, per
/// run, in dispatch order.
fn worker_loop(addr: &str, rx: Receiver<Job>) -> Result<(u64, u64, Vec<f64>)> {
    let mut client = NetClient::connect(addr)?;
    let mut fed = 0u64;
    let mut errors = 0u64;
    let mut latencies_ms = Vec::new();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Prime(ty, mem) => {
                if client.prime(&ty, mem).is_err() {
                    errors += 1;
                }
            }
            Job::Run(run) => {
                let sw = Stopwatch::start();
                match client.predict(&run.task_type, run.input_mib) {
                    Ok(_) => {
                        latencies_ms.push(sw.elapsed_s() * 1e3);
                        if client.complete(&run).is_ok() {
                            fed += 1;
                        } else {
                            errors += 1;
                        }
                    }
                    Err(_) => errors += 1,
                }
            }
        }
    }
    Ok((fed, errors, latencies_ms))
}
