"""Pallas kernel: masked per-segment peak extraction (paper §III-B, Y**).

Given a batch of resampled memory-usage time series ``Y: [N, T]`` and a
static segment count ``k``, computes ``P: [N, k]`` where ``P[n, s]`` is the
maximum of segment ``s`` of row ``n``.  Change points follow the paper:
``i = floor(T/k)``; the last segment absorbs the remainder.

Kernel structure (written for the TPU memory hierarchy even though we
execute under ``interpret=True`` on CPU — see DESIGN.md
§Hardware-Adaptation):

* The grid tiles the batch dimension into ``block_n``-row slabs; each
  program instance holds one ``[block_n, T]`` slab in VMEM.  For the AOT
  shapes (N=64, T=256, f32) a slab is 64 KiB — far below VMEM budget, so
  one program sees whole rows and no cross-program reduction is needed.
* Segment maxima are computed with an iota-derived column mask and a
  lane-dimension ``max`` reduction — contiguous, vectorizable, and free of
  data-dependent control flow (the k-loop is unrolled at trace time since
  k is static).
* Masked-out lanes contribute ``-inf`` so padding can never win the max.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segpeaks", "segpeaks_kernel"]


def segpeaks_kernel(y_ref, out_ref, *, k: int, t: int):
    """Pallas kernel body: one [block_n, T] slab -> [block_n, k] peaks.

    Work is O(block_n · T) independent of k (perf pass, EXPERIMENTS.md
    §Perf): the first k·i columns (i = ⌊T/k⌋) reshape to
    [block_n, k, i] and reduce along the lane tail in one pass; the
    remainder columns [k·i, T) — which the paper's change-point formula
    assigns to the LAST segment — reduce separately and fold into
    column k−1. The previous version unrolled k full-width masked
    reductions (O(block_n · T · k)), which at k=16 cost 16× the VPU
    work for identical output.
    """
    y = y_ref[...]  # [block_n, T] in VMEM
    n = y.shape[0]
    i = t // k
    body = y[:, : k * i].reshape(n, k, i)
    peaks = jnp.max(body, axis=2)  # [block_n, k]
    if k * i < t:
        tail = jnp.max(y[:, k * i :], axis=1)  # [block_n]
        last = jnp.maximum(peaks[:, k - 1], tail)
        peaks = jnp.concatenate([peaks[:, : k - 1], last[:, None]], axis=1)
    out_ref[...] = peaks


def segpeaks(y: jnp.ndarray, k: int, *, block_n: int | None = None) -> jnp.ndarray:
    """Per-segment peaks of batched series via the Pallas kernel.

    y: [N, T]; returns [N, k].  ``block_n`` tiles the batch dimension
    (must divide N); defaults to min(N, 64).
    """
    n, t = y.shape
    if t < k:
        raise ValueError(f"series length {t} shorter than k={k}")
    if block_n is None:
        block_n = min(n, 64)
    if n % block_n != 0:
        raise ValueError(f"block_n={block_n} must divide N={n}")

    kernel = functools.partial(segpeaks_kernel, k=k, t=t)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), y.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(y)
