//! Small self-contained utilities (offline-build substitutes for
//! common ecosystem crates — see the dependency note in Cargo.toml).

pub mod json;
pub mod stats;
pub mod timer;
