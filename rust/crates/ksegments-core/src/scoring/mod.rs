//! The online evaluation protocol (paper §IV-B) — feeds a trace
//! through a predictor, accounting wastage and retries.
//!
//! This is the single-threaded scoring kernel. The worker-pool fan-out
//! over (method × trace × training-fraction) grids lives one layer up
//! in `ksegments-sim` (`parallel`), and the `ksegments` facade stitches
//! both back together under the historical `ksegments::sim` path.

mod attempt;

pub use attempt::{simulate_attempt, AttemptOutcome};

use crate::predictors::{Allocation, MemoryPredictor};
use crate::trace::{TaskRun, Trace};
use crate::units::{GbSeconds, MemMiB};
use crate::wastage::{MethodReport, TaskReport};
use crate::workload::EVAL_MIN_RUNS;

/// Evaluation-protocol parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Fraction of each task's executions used as warm-up training
    /// (their wastage is not scored). Paper sweeps {0.25, 0.5, 0.75}.
    pub training_frac: f64,
    /// Safety valve on the retry loop. The paper's policies all
    /// escalate geometrically (×2) or jump to node max, so this is
    /// never reached in practice; it guards against a buggy predictor.
    pub max_attempts: u32,
    /// Minimum executions for a task type to be scored (the paper's
    /// "33 evaluated tasks" filter).
    pub min_runs: usize,
    /// Node capacity: allocations above this are clamped (the resource
    /// manager would refuse to place them).
    pub node_max: MemMiB,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            training_frac: 0.5,
            max_attempts: 40,
            min_runs: EVAL_MIN_RUNS,
            node_max: MemMiB::from_gib(128.0),
        }
    }
}

impl SimConfig {
    pub fn with_training_frac(frac: f64) -> Self {
        assert!((0.0..1.0).contains(&frac), "training fraction in [0,1)");
        SimConfig { training_frac: frac, ..SimConfig::default() }
    }
}

/// Result of scoring one run: wastage across all its attempts plus the
/// number of retries it needed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunScore {
    pub wastage: GbSeconds,
    pub retries: u32,
}

/// Drive one run through the predict → attempt → retry loop.
///
/// Exposed for the coordinator and tests; `simulate_trace` is the
/// batch entry point.
pub fn score_run(
    predictor: &mut dyn MemoryPredictor,
    run: &TaskRun,
    cfg: &SimConfig,
) -> RunScore {
    let mut alloc = clamp_alloc(predictor.predict(&run.task_type, run.input_mib), cfg);
    let mut wastage_mibs = 0.0;
    let mut attempt = 1u32;
    loop {
        match simulate_attempt(&run.series, &alloc, attempt) {
            AttemptOutcome::Success { wastage_mibs: w } => {
                wastage_mibs += w;
                predictor.observe(run);
                return RunScore {
                    wastage: GbSeconds(MemMiB(wastage_mibs).as_gb()),
                    retries: attempt - 1,
                };
            }
            AttemptOutcome::Failure { info, wastage_mibs: w } => {
                wastage_mibs += w;
                if attempt >= cfg.max_attempts {
                    // Escalate to node max and force completion: a real
                    // resource manager cannot retry forever. This also
                    // terminates if the predictor stops making progress.
                    alloc = Allocation::Static(cfg.node_max);
                    let out = simulate_attempt(&run.series, &alloc, attempt + 1);
                    wastage_mibs += out.wastage_mibs();
                    predictor.observe(run);
                    return RunScore {
                        wastage: GbSeconds(MemMiB(wastage_mibs).as_gb()),
                        retries: attempt,
                    };
                }
                alloc = clamp_alloc(
                    predictor.on_failure(&run.task_type, run.input_mib, &alloc, &info),
                    cfg,
                );
                attempt += 1;
            }
        }
    }
}

fn clamp_alloc(alloc: Allocation, cfg: &SimConfig) -> Allocation {
    match alloc {
        Allocation::Static(m) => Allocation::Static(m.min(cfg.node_max)),
        // Dynamic allocations are built with the node ceiling already
        // applied (StepFunction::monotone_clamped); trust but verify.
        Allocation::Dynamic(f) => {
            debug_assert!(f.max_value() <= cfg.node_max.0 + 1e-6);
            Allocation::Dynamic(f)
        }
    }
}

/// Run the full online protocol for one predictor over one trace.
///
/// Per task type: the first `training_frac · n` executions are fed to
/// `observe` unscored (warm-up); the remainder are scored **online** —
/// each scored run's successful execution is folded back into the
/// model before the next run (paper: "finished task executions can be
/// incorporated into the learning process").
pub fn simulate_trace(
    trace: &Trace,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SimConfig,
) -> MethodReport {
    // Prime developer defaults.
    for ty in trace.task_types() {
        if let Some(mem) = trace.default_alloc(ty) {
            predictor.prime(ty, mem);
        }
    }

    let mut tasks = Vec::new();
    for ty in trace.task_types().map(String::from).collect::<Vec<_>>() {
        let runs = trace.runs_of(&ty);
        if runs.len() < cfg.min_runs {
            continue; // below the evaluated-task threshold
        }
        let n_train = ((runs.len() as f64) * cfg.training_frac).floor() as usize;
        for run in &runs[..n_train] {
            predictor.observe(run);
        }
        let mut report = TaskReport::new(&ty);
        for run in &runs[n_train..] {
            let score = score_run(predictor, run, cfg);
            report.record(score.wastage, score.retries);
        }
        tasks.push(report);
    }
    MethodReport::new(&predictor.name(), cfg.training_frac, tasks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictors::default_config::DefaultConfigPredictor;
    use crate::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
    use crate::predictors::ppm::PpmPredictor;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    /// Trace with one task type: ramp profile, peak = 10 + input.
    fn toy_trace(n: usize) -> Trace {
        let mut t = Trace::new();
        t.set_default("w/t", MemMiB(2000.0));
        for i in 0..n {
            let input = 100.0 + 10.0 * i as f64;
            let peak = 10.0 + input;
            let samples: Vec<f64> = (0..10).map(|j| peak * (j + 1) as f64 / 10.0).collect();
            t.push(TaskRun {
                task_type: "w/t".into(),
                input_mib: input,
                runtime: Seconds(20.0),
                series: UsageSeries::new(2.0, samples),
                seq: i as u64,
            });
        }
        t.sort();
        t
    }

    #[test]
    fn default_predictor_never_retries() {
        let trace = toy_trace(40);
        let mut p = DefaultConfigPredictor::new();
        let rep = simulate_trace(&trace, &mut p, &SimConfig::with_training_frac(0.25));
        assert_eq!(rep.tasks.len(), 1);
        assert_eq!(rep.total_retries(), 0);
        assert!(rep.total_wastage_gbs() > 0.0);
    }

    #[test]
    fn ksegments_beats_default_on_ramp() {
        let trace = toy_trace(60);
        let cfg = SimConfig::with_training_frac(0.5);
        let mut d = DefaultConfigPredictor::new();
        let mut k = KSegmentsPredictor::native(4, RetryStrategy::Selective);
        let rd = simulate_trace(&trace, &mut d, &cfg);
        let rk = simulate_trace(&trace, &mut k, &cfg);
        assert!(
            rk.total_wastage_gbs() < rd.total_wastage_gbs() / 2.0,
            "ksegments {} vs default {}",
            rk.total_wastage_gbs(),
            rd.total_wastage_gbs()
        );
    }

    #[test]
    fn ksegments_beats_static_peak_predictor_on_ramp() {
        // the core claim: time-varying allocation < static peak allocation
        let trace = toy_trace(60);
        let cfg = SimConfig::with_training_frac(0.5);
        let mut ppm = PpmPredictor::improved();
        let mut k = KSegmentsPredictor::native(4, RetryStrategy::Selective);
        let rp = simulate_trace(&trace, &mut ppm, &cfg);
        let rk = simulate_trace(&trace, &mut k, &cfg);
        assert!(
            rk.total_wastage_gbs() < rp.total_wastage_gbs(),
            "ksegments {} vs ppm-improved {}",
            rk.total_wastage_gbs(),
            rp.total_wastage_gbs()
        );
    }

    #[test]
    fn training_fraction_controls_scored_runs() {
        let trace = toy_trace(40);
        let mut p = DefaultConfigPredictor::new();
        let rep = simulate_trace(&trace, &mut p, &SimConfig::with_training_frac(0.75));
        assert_eq!(rep.tasks[0].n_scored, 10);
    }

    #[test]
    fn below_min_runs_is_not_scored() {
        let trace = toy_trace(EVAL_MIN_RUNS - 1);
        let mut p = DefaultConfigPredictor::new();
        let rep = simulate_trace(&trace, &mut p, &SimConfig::default());
        assert!(rep.tasks.is_empty());
    }

    #[test]
    fn retry_loop_terminates_under_adversarial_predictor() {
        /// Predictor that always allocates 1 MiB and never escalates.
        struct Stubborn;
        impl MemoryPredictor for Stubborn {
            fn name(&self) -> String {
                "stubborn".into()
            }
            fn prime(&mut self, _: &str, _: MemMiB) {}
            fn predict(&mut self, _: &str, _: f64) -> Allocation {
                Allocation::Static(MemMiB(1.0))
            }
            fn on_failure(
                &mut self,
                _: &str,
                _: f64,
                _: &Allocation,
                _: &crate::predictors::FailureInfo,
            ) -> Allocation {
                Allocation::Static(MemMiB(1.0))
            }
            fn observe(&mut self, _: &TaskRun) {}
        }
        let trace = toy_trace(25);
        let run = &trace.runs_of("w/t")[0];
        let cfg = SimConfig { max_attempts: 5, ..SimConfig::default() };
        let score = score_run(&mut Stubborn, run, &cfg);
        assert_eq!(score.retries, 5);
        assert!(score.wastage.0 > 0.0);
    }

    #[test]
    fn online_learning_happens_during_scoring() {
        // PPM starts untrained (no warm-up) but must learn during the
        // scored phase: later runs see non-default predictions.
        let trace = toy_trace(30);
        let mut ppm = PpmPredictor::improved();
        let rep = simulate_trace(&trace, &mut ppm, &SimConfig::with_training_frac(0.0));
        assert_eq!(rep.tasks[0].n_scored, 30);
        // after the sim, the predictor has history -> non-default predict
        let alloc = ppm.predict("w/t", 200.0);
        assert_ne!(alloc, Allocation::Static(MemMiB(2000.0)));
    }
}
