//! The rule registry. Each pass is a [`Rule`] over one scrubbed file;
//! the `layering` pass additionally checks crate manifests (see
//! [`layering::check_manifest`]).
//!
//! To add a pass: implement [`Rule`] in a new submodule, add its id to
//! [`RULE_IDS`], register it in [`all_rules`], and give it known-good
//! and known-bad fixtures in `tests/engine.rs` (the engine test fails
//! any rule id without a firing fixture).

pub mod layering;
pub mod map_iter_order;
pub mod panic_policy;
pub mod rng_discipline;
pub mod wallclock;

use crate::diag::Diagnostic;
use crate::lexer::ScrubbedFile;

/// Everything a rule may look at for one file.
pub struct FileCtx<'a> {
    /// Package name, e.g. `ksegments-serve`.
    pub krate: &'a str,
    /// Path inside the crate directory, e.g. `src/net/frame.rs`,
    /// always with forward slashes.
    pub rel_path: &'a str,
    /// Repo-relative display path for diagnostics.
    pub display_path: &'a str,
    pub file: &'a ScrubbedFile,
}

pub trait Rule {
    fn id(&self) -> &'static str;
    /// Emit raw findings; the engine applies `lint:allow` filtering.
    fn check(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>);
}

/// Every rule id, in registry order (stable for reports).
pub const RULE_IDS: &[&str] =
    &["layering", "map-iter-order", "panic-policy", "rng-discipline", "wallclock"];

pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(layering::Layering),
        Box::new(map_iter_order::MapIterOrder),
        Box::new(panic_policy::PanicPolicy),
        Box::new(rng_discipline::RngDiscipline),
        Box::new(wallclock::Wallclock),
    ]
}

/// The crate DAG of DESIGN.md §13, shared by the layering pass and
/// its manifest check: internal crates each crate may depend on.
/// `ksegments-lint` itself is pinned to nothing — the linter must
/// build before everything else.
pub const CRATE_DAG: &[(&str, &[&str])] = &[
    ("ksegments-core", &[]),
    ("ksegments-sim", &["ksegments-core"]),
    ("ksegments-sched", &["ksegments-core"]),
    ("ksegments-serve", &["ksegments-core"]),
    ("ksegments", &["ksegments-core", "ksegments-sim", "ksegments-sched", "ksegments-serve"]),
    ("ksegments-cli", &["ksegments"]),
    ("ksegments-lint", &[]),
];

/// Allowed internal deps for `krate` (None for unknown crates — the
/// engine reports those separately rather than guessing).
pub fn allowed_deps(krate: &str) -> Option<&'static [&'static str]> {
    CRATE_DAG.iter().find(|(k, _)| *k == krate).map(|(_, deps)| *deps)
}
