//! PPM — Tovar et al.'s job-sizing strategy [15], plus the paper's
//! "PPM Improved" variant.
//!
//! Tovar et al. keep the empirical distribution of observed **peak**
//! values per task and pick the first allocation that minimizes the
//! expected cost under the *slow-peaks* worst case (a task that fails
//! does so at the end of its execution, wasting its whole first
//! allocation). With uniform probability over the n observed peaks and
//! fallback allocation `M`, the expected cost of first-allocating `a`
//! is
//!
//! ```text
//! cost(a) = Σ_{p ≤ a} a  +  Σ_{p > a} (a + M)
//! ```
//!
//! minimized over the candidate set {observed peaks}. The original
//! method's failure policy assigns the **node's maximum memory** on
//! retry (`M` = node max); the k-Segments paper's Improved variant
//! instead **doubles** the failed allocation — which is exactly the
//! difference that makes PPM Improved the strongest baseline on
//! 128 GB nodes (paper §IV-E).

use crate::trace::TaskRun;
use crate::units::MemMiB;

use super::history::HistoryMap;
use super::{Allocation, Defaults, FailureInfo, MemoryPredictor};

/// What to allocate after an under-allocation failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailurePolicy {
    /// Original PPM: jump straight to the node's maximum memory.
    NodeMax,
    /// PPM Improved: double the failed allocation (capped at node max).
    Double,
}

/// Tovar et al.'s probability-of-peak-memory predictor.
#[derive(Debug, Clone)]
pub struct PpmPredictor {
    policy: FailurePolicy,
    node_max: MemMiB,
    defaults: Defaults,
    histories: HistoryMap,
}

impl PpmPredictor {
    pub fn new(policy: FailurePolicy, node_max: MemMiB) -> Self {
        PpmPredictor {
            policy,
            node_max,
            defaults: Defaults::default(),
            // PPM only needs peaks; series length 1 keeps the history cheap.
            histories: HistoryMap::new(1024, 1),
        }
    }

    /// Original PPM on the paper's 128 GB testbed.
    pub fn original() -> Self {
        Self::new(FailurePolicy::NodeMax, MemMiB::from_gib(128.0))
    }

    /// The paper's improved variant (double on failure).
    pub fn improved() -> Self {
        Self::new(FailurePolicy::Double, MemMiB::from_gib(128.0))
    }

    /// Expected-cost-minimizing first allocation over observed peaks.
    ///
    /// The failure term is policy-consistent: the original strategy
    /// retries at node max (`M`), so a failure costs `a + M`; the
    /// Improved strategy retries at `2a`, so a failure costs `a + 2a`.
    /// (Evaluating candidates under the policy that will actually run
    /// is what makes the Improved variant pick sensible quantiles
    /// instead of the window max.)
    fn choose(&self, peaks: &[f64]) -> f64 {
        debug_assert!(!peaks.is_empty());
        // O(n log n): over sorted peaks, the candidate at (the last
        // duplicate of) index i has count_le = i+1 and count_gt = n-i-1,
        // so cost(a) = (i+1)·a + (n-i-1)·fail_cost(a) in O(1) each.
        // (The paper's PPM baseline evaluates up to 1512 peaks per
        // prediction; the naive candidate × peak double loop was the
        // top entry of the fig7 profile — see EXPERIMENTS.md §Perf.)
        let mut sorted = peaks.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mut best = (f64::INFINITY, sorted[n - 1]);
        let mut i = 0;
        while i < n {
            // skip to the last duplicate of this candidate value
            let a = sorted[i];
            let mut j = i;
            while j + 1 < n && sorted[j + 1] == a {
                j += 1;
            }
            let fail_cost = match self.policy {
                FailurePolicy::NodeMax => a + self.node_max.0,
                FailurePolicy::Double => a + (2.0 * a).min(self.node_max.0),
            };
            let count_le = (j + 1) as f64;
            let count_gt = (n - j - 1) as f64;
            let cost = count_le * a + count_gt * fail_cost;
            if cost < best.0 {
                best = (cost, a);
            }
            i = j + 1;
        }
        best.1
    }
}

impl MemoryPredictor for PpmPredictor {
    fn name(&self) -> String {
        match self.policy {
            FailurePolicy::NodeMax => "PPM".to_string(),
            FailurePolicy::Double => "PPM Improved".to_string(),
        }
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, _input_mib: f64) -> Allocation {
        match self.histories.get(task_type) {
            Some(h) if !h.is_empty() => {
                Allocation::Static(MemMiB(self.choose(h.peaks()).min(self.node_max.0)))
            }
            _ => Allocation::Static(self.defaults.get(task_type)),
        }
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        _info: &FailureInfo,
    ) -> Allocation {
        let next = match self.policy {
            FailurePolicy::NodeMax => self.node_max.0,
            FailurePolicy::Double => (failed.max_value() * 2.0).min(self.node_max.0),
        };
        Allocation::Static(MemMiB(next))
    }

    fn observe(&mut self, run: &TaskRun) {
        self.histories.push(run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn run(peak: f64) -> TaskRun {
        TaskRun {
            task_type: "t".into(),
            input_mib: 100.0,
            runtime: Seconds(4.0),
            series: UsageSeries::new(2.0, vec![peak / 2.0, peak]),
            seq: 0,
        }
    }

    #[test]
    fn untrained_uses_default() {
        let mut p = PpmPredictor::original();
        p.prime("t", MemMiB(4096.0));
        assert_eq!(p.predict("t", 1.0), Allocation::Static(MemMiB(4096.0)));
    }

    #[test]
    fn homogeneous_peaks_choose_the_peak() {
        let mut p = PpmPredictor::improved();
        for _ in 0..5 {
            p.observe(&run(1000.0));
        }
        assert_eq!(p.predict("t", 1.0), Allocation::Static(MemMiB(1000.0)));
    }

    #[test]
    fn skewed_distribution_prefers_low_candidate_when_failures_are_cheap() {
        // one huge outlier among many small peaks: with node_max small
        // (cheap failure), picking the low value wins
        let mut p = PpmPredictor::new(FailurePolicy::Double, MemMiB(1500.0));
        for _ in 0..9 {
            p.observe(&run(100.0));
        }
        p.observe(&run(1400.0));
        // cost(100) = 9*100 + (100+1500) = 2500 ; cost(1400) = 10*1400 = 14000
        assert_eq!(p.predict("t", 1.0), Allocation::Static(MemMiB(100.0)));
    }

    #[test]
    fn expensive_failures_push_allocation_up() {
        // under the ORIGINAL node-max policy a failure costs ~the whole
        // node, so the cost model picks the window max
        let mut p = PpmPredictor::new(FailurePolicy::NodeMax, MemMiB(131072.0));
        for _ in 0..9 {
            p.observe(&run(100.0));
        }
        p.observe(&run(1400.0));
        // cost(100) = 900 + (100 + 131072) ≫ cost(1400) = 14000
        assert_eq!(p.predict("t", 1.0), Allocation::Static(MemMiB(1400.0)));
    }

    #[test]
    fn improved_cost_model_tolerates_rare_tail() {
        // the Improved policy's failure cost is only 3a, so one outlier
        // among many small peaks does not drag the allocation up
        let mut p = PpmPredictor::improved();
        for _ in 0..9 {
            p.observe(&run(100.0));
        }
        p.observe(&run(1400.0));
        // cost(100) = 900 + 300 = 1200 < cost(1400) = 14000
        assert_eq!(p.predict("t", 1.0), Allocation::Static(MemMiB(100.0)));
    }

    #[test]
    fn node_max_failure_policy() {
        let mut p = PpmPredictor::original();
        let info = FailureInfo::oom(1.0, 2000.0, 1);
        let next = p.on_failure("t", 1.0, &Allocation::Static(MemMiB(1000.0)), &info);
        assert_eq!(next, Allocation::Static(MemMiB::from_gib(128.0)));
    }

    #[test]
    fn double_failure_policy_caps_at_node_max() {
        let mut p = PpmPredictor::improved();
        let info = FailureInfo::oom(1.0, 2000.0, 1);
        let next = p.on_failure("t", 1.0, &Allocation::Static(MemMiB(1000.0)), &info);
        assert_eq!(next, Allocation::Static(MemMiB(2000.0)));
        let huge = p.on_failure("t", 1.0, &Allocation::Static(MemMiB::from_gib(100.0)), &info);
        assert_eq!(huge, Allocation::Static(MemMiB::from_gib(128.0)));
    }

    #[test]
    fn names_distinguish_variants() {
        assert_eq!(PpmPredictor::original().name(), "PPM");
        assert_eq!(PpmPredictor::improved().name(), "PPM Improved");
    }
}
