//! Time-indexed reservation ledger — the committed future memory load
//! of one node, as a sparse step function.
//!
//! The discrete-event scheduler ([`crate::sched`]) admits a
//! segment-wise task only if the node can carry its whole *planned*
//! reservation profile (first-segment value, grows at each boundary,
//! release at the predicted runtime) on top of everything already
//! committed — otherwise step-function packing thrashes: co-admitted
//! tasks all grow into the same headroom and kill each other at the
//! first boundary. Admission against the committed profile makes grows
//! conflict-free whenever runtime predictions hold; runtime
//! *under*prediction (a task holding memory past its planned release)
//! is caught later by the actual-reservation check and the scheduler's
//! grow-denial path.
//!
//! The ledger is a multiset of `(time, delta_mib)` events kept sorted
//! by time; the committed load at `t` is the sum of all deltas at or
//! before `t`. Adding and removing a profile use the exact same event
//! values, so removal cancels bit-exactly (same-time entries coalesce;
//! entries below 1e-6 MiB are pruned).

/// Sparse committed-load step function over time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimeProfile {
    /// `(time, delta_mib)` sorted by time, one entry per distinct time.
    deltas: Vec<(f64, f64)>,
}

/// Entries smaller than this (MiB) are float residue, not memory.
const PRUNE_EPS: f64 = 1e-6;

impl TimeProfile {
    pub fn new() -> TimeProfile {
        TimeProfile::default()
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    pub fn n_events(&self) -> usize {
        self.deltas.len()
    }

    /// Add one `(time, delta)` event, coalescing equal times.
    pub fn add(&mut self, time: f64, delta: f64) {
        debug_assert!(time.is_finite() && delta.is_finite());
        match self.deltas.binary_search_by(|(t, _)| t.total_cmp(&time)) {
            Ok(i) => {
                self.deltas[i].1 += delta;
                if self.deltas[i].1.abs() < PRUNE_EPS {
                    self.deltas.remove(i);
                }
            }
            Err(i) => {
                if delta.abs() >= PRUNE_EPS {
                    self.deltas.insert(i, (time, delta));
                }
            }
        }
    }

    /// Commit a planned reservation profile (events in any order).
    pub fn add_profile(&mut self, events: &[(f64, f64)]) {
        for &(t, d) in events {
            self.add(t, d);
        }
    }

    /// Withdraw a previously committed profile (exact cancellation —
    /// pass the same event list that was added).
    pub fn subtract_profile(&mut self, events: &[(f64, f64)]) {
        for &(t, d) in events {
            self.add(t, -d);
        }
    }

    /// Peak committed load over all time.
    pub fn peak(&self) -> f64 {
        self.peak_with(&[])
    }

    /// Peak of (committed + candidate) over all time; `cand` must be
    /// sorted by time (planned profiles are generated sorted).
    pub fn peak_with(&self, cand: &[(f64, f64)]) -> f64 {
        debug_assert!(cand.windows(2).all(|w| w[0].0 <= w[1].0), "candidate not sorted");
        let a = &self.deltas;
        let (mut i, mut j) = (0usize, 0usize);
        let (mut acc, mut peak) = (0.0f64, 0.0f64);
        while i < a.len() || j < cand.len() {
            let t = match (a.get(i), cand.get(j)) {
                (Some(&(ta, _)), Some(&(tc, _))) => ta.min(tc),
                (Some(&(ta, _)), None) => ta,
                (None, Some(&(tc, _))) => tc,
                (None, None) => unreachable!(),
            };
            while i < a.len() && a[i].0 <= t {
                acc += a[i].1;
                i += 1;
            }
            while j < cand.len() && cand[j].0 <= t {
                acc += cand[j].1;
                j += 1;
            }
            if acc > peak {
                peak = acc;
            }
        }
        peak
    }

    /// Whether (committed + candidate) stays within `capacity_mib` at
    /// every instant (1e-6 MiB tolerance for exact fits).
    pub fn fits(&self, cand: &[(f64, f64)], capacity_mib: f64) -> bool {
        self.peak_with(cand) <= capacity_mib + PRUNE_EPS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(start: f64) -> Vec<(f64, f64)> {
        // 250 → 500 → 750 → 1000 over 20 s, released at start+20
        vec![
            (start, 250.0),
            (start + 5.0, 250.0),
            (start + 10.0, 250.0),
            (start + 15.0, 250.0),
            (start + 20.0, -1000.0),
        ]
    }

    #[test]
    fn empty_profile_peak_is_zero() {
        let p = TimeProfile::new();
        assert_eq!(p.peak(), 0.0);
        assert!(p.fits(&[], 0.0));
    }

    #[test]
    fn single_profile_peaks_at_its_max() {
        let mut p = TimeProfile::new();
        p.add_profile(&ramp(0.0));
        assert_eq!(p.peak(), 1000.0);
        assert!(p.fits(&[], 1000.0));
    }

    #[test]
    fn overlapping_identical_ramps_stack() {
        let mut p = TimeProfile::new();
        p.add_profile(&ramp(0.0));
        // simultaneous twin: peaks coincide, 2000 total
        assert_eq!(p.peak_with(&ramp(0.0)), 2000.0);
        // staggered by 15 s: 1000 + 250 in [15,20), 750+500 later... max 1250
        assert_eq!(p.peak_with(&ramp(15.0)), 1250.0);
        assert!(p.fits(&ramp(15.0), 1500.0));
        assert!(!p.fits(&ramp(0.0), 1500.0));
    }

    #[test]
    fn subtract_cancels_exactly() {
        let mut p = TimeProfile::new();
        p.add_profile(&ramp(3.0));
        p.add_profile(&ramp(11.0));
        p.subtract_profile(&ramp(3.0));
        p.subtract_profile(&ramp(11.0));
        assert!(p.is_empty(), "{p:?}");
        assert_eq!(p.peak(), 0.0);
    }

    #[test]
    fn coalesces_equal_times() {
        let mut p = TimeProfile::new();
        p.add(1.0, 100.0);
        p.add(1.0, 50.0);
        assert_eq!(p.n_events(), 1);
        assert_eq!(p.peak(), 150.0);
        p.add(1.0, -150.0);
        assert!(p.is_empty());
    }

    #[test]
    fn peak_sees_interior_maximum() {
        let mut p = TimeProfile::new();
        // spike in the middle: +100 @1, +900 @2, -900 @3, -100 @4
        p.add_profile(&[(1.0, 100.0), (2.0, 900.0), (3.0, -900.0), (4.0, -100.0)]);
        assert_eq!(p.peak(), 1000.0);
        // candidate spike overlapping the valley only
        assert_eq!(p.peak_with(&[(3.0, 500.0), (4.0, -500.0)]), 1000.0);
        // candidate overlapping the spike
        assert_eq!(p.peak_with(&[(1.5, 500.0), (5.0, -500.0)]), 1500.0);
    }

    #[test]
    fn exact_fit_tolerated() {
        let mut p = TimeProfile::new();
        p.add_profile(&ramp(0.0));
        p.add_profile(&ramp(5.0));
        // 1000 + 750 + 250 = 2000 exactly with a third at +15
        assert!(p.fits(&ramp(15.0), 2000.0));
        assert!(!p.fits(&ramp(15.0), 1999.0));
    }
}
