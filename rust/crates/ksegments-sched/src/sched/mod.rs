//! Cluster-scale discrete-event scheduling simulator — the layer that
//! turns segment-wise memory predictions into **throughput**.
//!
//! The paper motivates time-varying allocation with cluster-level
//! wastage *and decreased throughput*; `sim` only scores per-run
//! wastage in isolation. This module measures the other half: a
//! deterministic discrete-event scheduler consumes a whole trace as a
//! timed arrival stream, places tasks onto a (possibly heterogeneous)
//! multi-node [`Cluster`] under a pluggable [`ReservationPolicy`], and
//! reports makespan, queue-wait distribution, admission/kill counters,
//! peak utilization, and wastage as a [`SchedReport`].
//!
//! ## Policies
//!
//! * [`ReservationPolicy::StaticPeak`] — reserve the predicted **peak**
//!   for the whole runtime (today's implicit model; what every static
//!   baseline and a Slurm-style `--mem` flag do);
//! * [`ReservationPolicy::SegmentWise`] — reserve the predictor's
//!   [`Allocation::Dynamic`] step function: admission only needs the
//!   first segment's value and the reservation **grows in place** at
//!   each segment boundary, so staggered tasks overlap in the time
//!   dimension and more of them pack onto a node at once.
//!
//! ## Admission: time-indexed reservations
//!
//! Each node carries a committed-load ledger
//! ([`crate::cluster::TimeProfile`]). An attempt is admitted onto a
//! node only if its whole *planned* profile — first-segment value,
//! grows at each boundary, release at the predicted runtime — fits
//! under the node's capacity on top of everything already committed,
//! **and** the node's live memory can supply the first segment. This
//! makes grows conflict-free whenever runtime predictions hold; a task
//! running *longer* than predicted holds memory past its planned
//! release, and a grow colliding with that reality is denied: the
//! attempt is killed (its reservation integral is wasted), counted in
//! `grow_denials`, and requeued with a full-peak reservation so it
//! cannot starve mid-run twice.
//!
//! ## Event model
//!
//! Five event kinds flow through a deterministic heap
//! ([`queue::EventQueue`], ordered by time → kind rank → insertion):
//! `Finish` (completion or OOM-kill instant, precomputed against the
//! ground-truth usage curve via [`simulate_attempt`]), then `NodeJoin`
//! and `NodeFail` (failure-domain lifecycle), then `SegmentBoundary`
//! (grow), then `Arrival` (predict + place or enqueue) — releases are
//! visible to everything else at the same instant. An OOM-killed
//! attempt re-enters the queue with the predictor's escalated
//! [`MemoryPredictor::on_failure`] allocation — the `score_run` retry
//! loop, under real contention. Placement is FIFO with backfill: every
//! release re-scans the wait queue in order and admits whatever fits
//! (a later small task may jump an earlier one that does not fit yet).
//!
//! ## Failure domains
//!
//! Three mechanisms model the cluster losing (and regaining) capacity
//! underneath the workload; all are off by default so existing runs
//! are untouched:
//!
//! * **Node loss** (`fail_mtbf > 0`): node failures arrive as a
//!   Poisson process on a dedicated RNG stream. A failure takes one up
//!   node down, killing every resident attempt; victims requeue
//!   **blamelessly** — same allocation, same attempt number, and
//!   critically *no* [`MemoryPredictor::on_failure`] call, because the
//!   kill carries [`FailureCause::NodeLost`], not an OOM. Escalating a
//!   node loss as if it were a misprediction would permanently inflate
//!   the task's allocation (the bug class this module's tests pin
//!   down). The node rejoins after `fail_downtime`. A node-lost
//!   workflow task has not finally completed, so its subtree stays
//!   gated.
//! * **Priority preemption** (`preempt`): each submission draws a
//!   priority (high with probability `hipri_frac`). A high-priority
//!   task that cannot place may evict enough lower-priority running
//!   attempts (youngest first, single node, dry-run against a cloned
//!   ledger so eviction only happens when placement then succeeds).
//!   Victims are killed blamelessly with [`FailureCause::Preempted`]
//!   and requeued *after* the preemptor places.
//! * **Autoscaling** (`autoscale`): queue pressure above
//!   `queue_per_node` waiting tasks per effective node provisions a
//!   new node (it joins `lag` seconds later); an empty queue retires
//!   one idle autoscaled node. Base-roster nodes never retire, which
//!   preserves the termination guarantee (`node_max` is snapshotted
//!   from the base roster and every allocation is clamped to it).
//!
//! ## Invariants
//!
//! * same seed + same trace ⇒ bit-identical [`SchedReport`] (the heap
//!   tie-breaks on insertion order; failure, priority, and arrival
//!   draws come from independently forked RNG streams; there is no
//!   other nondeterminism);
//! * `completed == submitted` (retry escalation forces termination;
//!   blameless kills never consume retry budget but arrivals, failure
//!   injections, and preemptors are all finite);
//! * `admitted == completed + oom_kills + grow_denials + preempted +
//!   node_lost`;
//! * `placement_attempts == admitted + rejected`;
//! * the predictor's `on_failure` fires **only** for
//!   [`FailureCause::Oom`];
//! * the cluster is empty when the simulation ends.
//!
//! ## Streaming arrivals
//!
//! The event loop pulls its arrival stream lazily — exactly one
//! not-yet-arrived run is held at a time, and a completed run's data
//! is dropped with its last reference — so memory is bounded by the
//! *in-flight* task set, not the trace. [`schedule_trace`] feeds it
//! the materialized warm-up split (the paper's protocol);
//! [`schedule_stream`] feeds it a [`TraceSource`] chunk by chunk, the
//! path from `ksegments ingest` output (or a live engine) straight
//! into the scheduler, with warm starts via
//! the serve layer’s `Checkpoint::restore_into` instead of an offline
//! training split.
//!
//! ## Workflow DAG mode
//!
//! [`schedule_workflows`] replaces the independent arrival stream with
//! **dependency-gated** releases: the feed yields whole
//! [`WorkflowInstance`]s (N concurrent executions of a workflow DAG,
//! gapped by `mean_interarrival` like single tasks are), and a task is
//! submitted to the resource manager only when every parent in its
//! instance has reached its *final* completion — an OOM-killed or
//! grow-denied parent retries first, so memory underprediction delays
//! everything downstream of it. "Final" is the same termination rule
//! as the rest of the engine: normally a successful attempt, or — in
//! the one unreachable-by-construction corner where a task's true peak
//! exceeds the largest node and the retry budget runs out — the
//! forced-through final attempt (children still release then; holding
//! the gate shut would deadlock the event loop, and a real manager
//! would cancel rather than hang). The engine logs
//! [`EngineEvent::Released`] per gate opening and
//! [`EngineEvent::WorkflowDone`] per finished instance, and the report
//! gains per-instance workflow metrics (achieved makespan vs.
//! critical-path length, time to first completion, straggler counts).
//! Everything else — placement, ledgers, retries, determinism — is the
//! same event loop.

pub mod grid;
pub mod queue;
mod report;
pub mod workflow;

pub use grid::{
    DagCell, DagGrid, DagGridResults, FailureCell, FailureGrid, FailureGridResults, SchedCell,
    SchedGrid, SchedGridResults,
};
pub use queue::{EventQueue, SchedEvent};
pub use report::{SchedReport, STRAGGLER_FACTOR};
pub use workflow::{DagTask, WorkflowInstance, WorkflowSource};

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::rc::Rc;

use anyhow::Result;

use crate::cluster::{Cluster, NodeSpec, Reservation, TimeProfile};
use crate::engine::{EngineEvent, EventLog};
use crate::telemetry_ext::trace_engine_event;
use ksegments_core::ml::step_fn::StepFunction;
use ksegments_core::predictors::{Allocation, FailureCause, MemoryPredictor};
use ksegments_core::rng::Rng;
use ksegments_core::scoring::{simulate_attempt, AttemptOutcome};
use ksegments_core::source::TraceSource;
use ksegments_core::telemetry::RunTelemetry;
use ksegments_core::trace::{TaskRun, Trace};
use ksegments_core::units::{GbSeconds, MemMiB, Seconds};

/// How the resource manager reserves memory for an admitted attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReservationPolicy {
    /// Reserve the allocation's peak value for the whole runtime.
    StaticPeak,
    /// Reserve the step function: admit at the first segment's value,
    /// grow at each boundary, release everything at the end.
    SegmentWise,
}

impl ReservationPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ReservationPolicy::StaticPeak => "static-peak",
            ReservationPolicy::SegmentWise => "segment-wise",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<ReservationPolicy> {
        match s {
            "static" | "static-peak" | "peak" => Some(ReservationPolicy::StaticPeak),
            "segment" | "segment-wise" | "segmentwise" | "dynamic" => {
                Some(ReservationPolicy::SegmentWise)
            }
            _ => None,
        }
    }
}

/// Autoscaler policy: queue-pressure-driven node add/remove with a
/// provisioning lag (cloud VMs do not boot instantly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    /// Delay between deciding to add a node and it joining the roster.
    pub lag: Seconds,
    /// Scale up when more than this many tasks wait per effective
    /// (up + provisioning) node.
    pub queue_per_node: usize,
    /// Lifetime cap on the roster size (base + autoscaled − retired).
    pub max_nodes: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig { lag: Seconds(30.0), queue_per_node: 4, max_nodes: 8 }
    }
}

/// Scheduler parameters.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub policy: ReservationPolicy,
    /// Node roster; heterogeneous specs are allowed.
    pub nodes: Vec<NodeSpec>,
    /// Mean inter-arrival gap; `<= 0` submits the whole stream at
    /// t = 0 (batch mode).
    pub mean_interarrival: Seconds,
    /// Fixed gaps instead of exponential ones (tests and reproducible
    /// what-if sweeps; production load is bursty, keep the default).
    pub deterministic_arrivals: bool,
    /// Seed of the arrival stream (independent of the trace seed).
    pub seed: u64,
    /// Fraction of each task type's runs observed offline before the
    /// remainder is scheduled (the paper's warm-up protocol).
    pub training_frac: f64,
    /// Retry budget per task; once exhausted the attempt runs at the
    /// node maximum and completes regardless of outcome (mirrors
    /// [`ksegments_core::scoring::score_run`]).
    pub max_attempts: u32,
    /// Event-log ring cap (0 = unbounded).
    pub event_log_cap: usize,
    /// Mean time between injected node failures; `<= 0` disables
    /// failure injection. The CLI exposes this as `--fail-rate R`
    /// (failures per second, mtbf = 1/R).
    pub fail_mtbf: Seconds,
    /// How long a failed node stays down before rejoining.
    pub fail_downtime: Seconds,
    /// Hard cap on injected failures (termination backstop for soak
    /// configs with extreme rates).
    pub max_node_failures: u64,
    /// Enable priority preemption.
    pub preempt: bool,
    /// Probability a submission is high-priority (only drawn when
    /// `preempt` is set, so disabled runs consume no RNG).
    pub hipri_frac: f64,
    /// Queue-pressure autoscaler; `None` keeps the roster fixed.
    pub autoscale: Option<AutoscaleConfig>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            policy: ReservationPolicy::SegmentWise,
            nodes: vec![NodeSpec::paper_testbed(); 4],
            mean_interarrival: Seconds(5.0),
            deterministic_arrivals: false,
            seed: 42,
            training_frac: 0.5,
            max_attempts: 40,
            event_log_cap: 10_000,
            fail_mtbf: Seconds(0.0),
            fail_downtime: Seconds(60.0),
            max_node_failures: 10_000,
            preempt: false,
            hipri_frac: 0.1,
            autoscale: None,
        }
    }
}

/// Which workflow-instance task a pending/running attempt belongs to
/// (`None` for independent arrivals): index into `Sim::dag` plus the
/// task's index within its instance.
#[derive(Debug, Clone, Copy)]
struct WfRef {
    inst: usize,
    task: usize,
}

/// A placement request waiting for (or attempting) admission.
#[derive(Debug, Clone)]
struct Pending {
    /// The run's data, shared with the event loop (`Rc`: the engine is
    /// single-threaded, and dropping the last reference after the
    /// final completion is what keeps streaming memory bounded).
    run: Rc<TaskRun>,
    attempt: u32,
    /// The predictor's (clamped) allocation for this attempt.
    alloc: Allocation,
    /// Reserve the full peak regardless of allocation shape: set for
    /// the StaticPeak policy and after a denied grow.
    reserve_static: bool,
    /// Retry budget exhausted — complete whatever the outcome.
    final_attempt: bool,
    enqueued_at: f64,
    /// DAG mode: the workflow task this attempt executes.
    wf: Option<WfRef>,
    /// Preemption priority (0 = normal; higher may evict lower).
    priority: u8,
}

/// An admitted attempt occupying cluster memory.
#[derive(Debug, Clone)]
struct Running {
    run: Rc<TaskRun>,
    attempt: u32,
    /// Predictor allocation (fed back to `on_failure`).
    pred_alloc: Allocation,
    /// Reservation-shaped allocation actually held on the node.
    res_alloc: Allocation,
    reservation: Reservation,
    /// Planned `(time, delta)` profile committed to the node's ledger;
    /// subtracted verbatim on release.
    profile: Vec<(f64, f64)>,
    start: f64,
    /// Precomputed ground-truth outcome of this attempt.
    outcome: AttemptOutcome,
    final_attempt: bool,
    /// DAG mode: the workflow task this attempt executes.
    wf: Option<WfRef>,
    /// Preemption priority (0 = normal; higher may evict lower).
    priority: u8,
    /// The pending request reserved the full peak (StaticPeak policy
    /// or post-grow-denial); a blameless requeue must restore this so
    /// the re-placed attempt keeps its reservation shape.
    reserve_static: bool,
}

/// Release-gating state of one arrived workflow instance.
#[derive(Debug)]
struct InstanceState {
    name: String,
    /// Instance ordinal (the `instance` field of emitted events).
    index: u64,
    /// Per task: parents not yet finally completed. A task is released
    /// when this reaches 0.
    remaining: Vec<usize>,
    /// Per task: the tasks its completion unblocks.
    children: Vec<Vec<usize>>,
    /// Per task: the run, taken at release time.
    runs: Vec<Option<Rc<TaskRun>>>,
    /// Tasks not yet finally completed.
    outstanding: usize,
    arrived_at: f64,
    critical_path_s: f64,
    first_completion_at: Option<f64>,
}

/// Clamp an allocation to the largest node's capacity so every request
/// is placeable on an empty cluster (the termination guarantee).
fn clamp_to_node_max(alloc: Allocation, node_max: MemMiB) -> Allocation {
    match alloc {
        Allocation::Static(m) => Allocation::Static(m.min(node_max)),
        Allocation::Dynamic(f) => {
            if f.max_value() <= node_max.0 + 1e-9 {
                Allocation::Dynamic(f)
            } else {
                Allocation::Dynamic(StepFunction::monotone_clamped_with_bounds(
                    f.bounds().to_vec(),
                    f.values().to_vec(),
                    MemMiB::ZERO,
                    node_max,
                ))
            }
        }
    }
}

/// The memory a reservation-shaped allocation needs at admission time.
fn initial_request(alloc: &Allocation) -> MemMiB {
    match alloc {
        Allocation::Static(m) => *m,
        Allocation::Dynamic(f) => MemMiB(f.values()[0]),
    }
}

/// Planned ledger profile of an attempt admitted at `now`: grows at
/// each boundary, release at the predicted runtime. Static allocations
/// have no runtime prediction — they stay committed until the attempt
/// actually releases (conservative, equivalent to live-memory
/// admission).
fn planned_profile(alloc: &Allocation, now: f64) -> Vec<(f64, f64)> {
    match alloc {
        Allocation::Static(m) => vec![(now, m.0)],
        Allocation::Dynamic(f) => {
            let values = f.values();
            let mut ev = Vec::with_capacity(values.len() + 1);
            ev.push((now, values[0]));
            for s in 1..values.len() {
                let d = values[s] - values[s - 1];
                if d > 0.0 {
                    ev.push((now + f.bounds()[s - 1], d));
                }
            }
            ev.push((now + f.predicted_runtime().0, -values[values.len() - 1]));
            ev
        }
    }
}

struct Sim<'a> {
    cfg: &'a SchedConfig,
    predictor: &'a mut dyn MemoryPredictor,
    /// Observation-only attachments (trace sink + provenance log);
    /// [`RunTelemetry::off`] on the plain entry points.
    tel: &'a mut RunTelemetry,
    cluster: Cluster,
    /// Per-node committed-load ledgers (time-indexed reservations).
    ledgers: Vec<TimeProfile>,
    events: EventQueue,
    waiting: VecDeque<Pending>,
    running: BTreeMap<u64, Running>,
    next_exec: u64,
    node_max: MemMiB,
    report: SchedReport,
    log: EventLog,
    /// Arrived workflow instances (DAG mode; empty otherwise).
    dag: Vec<InstanceState>,
    /// Failure-injection stream (forked from the seed; untouched when
    /// injection is off, so legacy runs consume the same draws).
    fail_rng: Rng,
    /// Priority stream (only drawn when `cfg.preempt`).
    pri_rng: Rng,
    /// Nodes `0..n_base_nodes` are the configured roster; only nodes
    /// at indices past this (autoscaled) may retire.
    n_base_nodes: usize,
    /// Autoscaled nodes added but not yet joined.
    provisioning: BTreeSet<usize>,
    /// Failure events injected so far (capped by `max_node_failures`).
    failures_scheduled: u64,
    /// The arrival feed still has items (failure injection stops
    /// re-arming once all work is done, so the event loop terminates).
    arrivals_open: bool,
}

impl Sim<'_> {
    /// Record an engine event, mirroring it to the trace sink when one
    /// is attached (the default [`ksegments_core::telemetry::NullSink`] gates
    /// this to a branch, so the hot path never builds a trace event).
    fn emit(&mut self, now: f64, ev: EngineEvent) {
        if self.tel.trace.enabled() {
            trace_engine_event(self.tel.trace.as_mut(), &ev, now);
        }
        self.log.push(ev);
    }

    fn reservation_alloc(&self, p: &Pending) -> Allocation {
        if p.reserve_static {
            Allocation::Static(MemMiB(p.alloc.max_value()))
        } else {
            p.alloc.clone()
        }
    }

    /// Try to admit `p` now; on success the attempt starts running and
    /// its Finish (and grow) events are scheduled.
    fn try_place(&mut self, p: &Pending, now: f64) -> bool {
        let run = Rc::clone(&p.run);
        let res_alloc = self.reservation_alloc(p);
        let profile = planned_profile(&res_alloc, now);
        let initial = initial_request(&res_alloc);
        self.report.placement_attempts += 1;

        let mut placed: Option<Reservation> = None;
        for i in 0..self.cluster.n_nodes() {
            if !self.cluster.nodes()[i].is_up() {
                continue; // down/retired nodes are invisible, not probes
            }
            let cap = self.cluster.nodes()[i].spec.mem.0;
            if !self.ledgers[i].fits(&profile, cap) {
                self.cluster.node_mut(i).rejected += 1;
                continue;
            }
            if let Some(r) = self.cluster.reserve_on(i, initial) {
                placed = Some(r);
                break;
            }
        }
        let Some(reservation) = placed else {
            self.cluster.failed_placements += 1;
            self.report.rejected += 1;
            return false;
        };
        self.ledgers[reservation.node_idx].add_profile(&profile);
        self.report.admitted += 1;
        self.report.queue_waits.push(now - p.enqueued_at);

        let outcome = simulate_attempt(&run.series, &res_alloc, p.attempt);
        let end_elapsed = match &outcome {
            AttemptOutcome::Success { .. } => run.series.duration().0,
            AttemptOutcome::Failure { info, .. } => info.time_s,
        };
        let exec = self.next_exec;
        self.next_exec += 1;
        if let Allocation::Dynamic(f) = &res_alloc {
            let (bounds, values) = (f.bounds(), f.values());
            for s in 1..values.len() {
                // the step to segment s happens at the end of segment
                // s-1; only schedule grows the attempt actually reaches
                if bounds[s - 1] < end_elapsed && values[s] > values[s - 1] + 1e-9 {
                    self.events
                        .push(now + bounds[s - 1], SchedEvent::SegmentBoundary { exec, segment: s });
                }
            }
        }
        self.events.push(now + end_elapsed, SchedEvent::Finish { exec });
        self.emit(
            now,
            EngineEvent::Placed {
                task_type: run.task_type.clone(),
                seq: run.seq,
                node: reservation.node_idx,
                time_s: now,
                reserved: reservation.mem,
            },
        );
        self.running.insert(
            exec,
            Running {
                run,
                attempt: p.attempt,
                pred_alloc: p.alloc.clone(),
                res_alloc,
                reservation,
                profile,
                start: now,
                outcome,
                final_attempt: p.final_attempt,
                wf: p.wf,
                priority: p.priority,
                reserve_static: p.reserve_static,
            },
        );
        true
    }

    fn place_or_queue(&mut self, p: Pending, now: f64) {
        if !self.try_place(&p, now) && !self.try_preempt_place(&p, now) {
            self.emit(
                now,
                EngineEvent::Queued {
                    task_type: p.run.task_type.clone(),
                    seq: p.run.seq,
                    requested: initial_request(&self.reservation_alloc(&p)),
                },
            );
            self.waiting.push_back(p);
        }
    }

    /// FIFO with backfill: try every waiting attempt in order. One pass
    /// suffices — placements only shrink capacity during the pass.
    /// (Preemption victims evicted mid-pass append to `self.waiting`
    /// and are picked up by the same `pop_front` loop.)
    fn drain(&mut self, now: f64) {
        let mut still = VecDeque::with_capacity(self.waiting.len());
        while let Some(p) = self.waiting.pop_front() {
            if !self.try_place(&p, now) && !self.try_preempt_place(&p, now) {
                still.push_back(p);
            }
        }
        self.waiting = still;
    }

    /// Kill a running attempt through no fault of its own (node loss
    /// or preemption): release everything it holds, waste its
    /// reservation integral (a killed attempt produced nothing), and
    /// hand back a Pending with the SAME allocation and attempt
    /// number. The predictor is never told — `on_failure` escalation
    /// is reserved for genuine OOMs ([`FailureCause::Oom`]); treating
    /// a blameless kill as a misprediction would permanently inflate
    /// the task's allocation.
    ///
    /// The caller decides when to requeue the returned Pending (node
    /// loss requeues immediately; preemption requeues only after the
    /// preemptor has placed, so victims cannot re-grab the freed
    /// memory first).
    fn kill_blameless(&mut self, exec: u64, cause: FailureCause, now: f64) -> Pending {
        let r = self.running.remove(&exec).expect("blameless kill of a non-running exec");
        let elapsed = now - r.start;
        let held_mibs = match &r.res_alloc {
            Allocation::Static(m) => m.0 * elapsed,
            Allocation::Dynamic(f) => f.integral(elapsed),
        };
        self.report.total_wastage += GbSeconds(MemMiB(held_mibs).as_gb());
        self.cluster.release(r.reservation);
        self.ledgers[r.reservation.node_idx].subtract_profile(&r.profile);
        match cause {
            FailureCause::NodeLost => {
                self.report.node_lost += 1;
                self.emit(
                    now,
                    EngineEvent::NodeLost {
                        task_type: r.run.task_type.clone(),
                        seq: r.run.seq,
                        attempt: r.attempt,
                        node: r.reservation.node_idx,
                        time_s: now,
                    },
                );
            }
            FailureCause::Preempted => {
                self.report.preempted += 1;
                self.emit(
                    now,
                    EngineEvent::Preempted {
                        task_type: r.run.task_type.clone(),
                        seq: r.run.seq,
                        attempt: r.attempt,
                        node: r.reservation.node_idx,
                        time_s: now,
                    },
                );
            }
            FailureCause::Oom => unreachable!("OOM kills resolve through on_finish"),
        }
        Pending {
            run: r.run,
            attempt: r.attempt,
            alloc: r.pred_alloc,
            reserve_static: r.reserve_static,
            final_attempt: r.final_attempt,
            enqueued_at: now,
            wf: r.wf,
            priority: r.priority,
        }
    }

    /// Arm the next injected node failure. Re-armed only while work
    /// remains (open arrivals, running, or queued tasks) so the event
    /// loop cannot chase an infinite failure chain past the workload.
    fn schedule_next_failure(&mut self, now: f64) {
        if self.cfg.fail_mtbf.0 <= 0.0
            || self.failures_scheduled >= self.cfg.max_node_failures
            || !(self.arrivals_open || !self.running.is_empty() || !self.waiting.is_empty())
        {
            return;
        }
        self.failures_scheduled += 1;
        let gap = -(1.0 - self.fail_rng.f64()).ln() * self.cfg.fail_mtbf.0;
        self.events.push(now + gap, SchedEvent::NodeFail);
    }

    /// An injected node loss fires: draw the victim among the nodes
    /// that are up *now* (the roster may have changed since the event
    /// was scheduled), take it down, blamelessly kill its residents,
    /// and schedule both the rejoin and the next failure.
    fn on_node_fail(&mut self, now: f64) {
        let up: Vec<usize> =
            (0..self.cluster.n_nodes()).filter(|&i| self.cluster.nodes()[i].is_up()).collect();
        if !up.is_empty() {
            let node = up[self.fail_rng.below(up.len() as u64) as usize];
            self.cluster.set_down(node);
            self.report.node_failures += 1;
            let victims: Vec<u64> = self
                .running
                .iter()
                .filter(|(_, r)| r.reservation.node_idx == node)
                .map(|(&e, _)| e)
                .collect();
            self.emit(
                now,
                EngineEvent::NodeFailed { node, killed: victims.len() as u32, time_s: now },
            );
            let requeue: Vec<Pending> = victims
                .into_iter()
                .map(|exec| self.kill_blameless(exec, FailureCause::NodeLost, now))
                .collect();
            for p in requeue {
                self.place_or_queue(p, now);
            }
            self.events
                .push(now + self.cfg.fail_downtime.0.max(0.0), SchedEvent::NodeJoin { node });
            self.drain(now);
        }
        self.schedule_next_failure(now);
    }

    /// A node comes (back) up: a post-failure rejoin or an autoscaled
    /// node finishing provisioning. Retired nodes stay retired
    /// ([`Cluster::set_up`] is a no-op for them).
    fn on_node_join(&mut self, node: usize, now: f64) {
        let was_provisioning = self.provisioning.remove(&node);
        let was_down = !self.cluster.nodes()[node].is_up();
        self.cluster.set_up(node);
        if was_down && self.cluster.nodes()[node].is_up() {
            if was_provisioning {
                self.report.nodes_added += 1;
            }
            self.emit(now, EngineEvent::NodeJoined { node, time_s: now });
            self.drain(now);
        }
    }

    /// Queue-pressure autoscaler, evaluated after every event: scale
    /// up when the queue exceeds `queue_per_node` per effective node
    /// (counting in-flight provisioning so one burst does not
    /// over-provision), scale down by retiring one idle autoscaled
    /// node when the queue is empty. Base-roster nodes never retire.
    fn autoscale_tick(&mut self, now: f64) {
        let Some(a) = self.cfg.autoscale else { return };
        let effective = self.cluster.n_up() + self.provisioning.len();
        let live = self.cluster.n_nodes() - self.report.nodes_retired as usize;
        if !self.waiting.is_empty()
            && self.waiting.len() > a.queue_per_node * effective.max(1)
            && live < a.max_nodes
        {
            let node = self.cluster.add_node(self.cfg.nodes[0]);
            self.ledgers.push(TimeProfile::new());
            self.provisioning.insert(node);
            self.events.push(now + a.lag.0.max(0.0), SchedEvent::NodeJoin { node });
        }
        if self.waiting.is_empty() {
            let idle = (self.n_base_nodes..self.cluster.n_nodes()).find(|&i| {
                self.cluster.nodes()[i].is_up()
                    && self.cluster.nodes()[i].reserved().0 <= 1e-9
                    && !self.running.values().any(|r| r.reservation.node_idx == i)
            });
            if let Some(i) = idle {
                self.cluster.retire(i);
                self.report.nodes_retired += 1;
                self.emit(now, EngineEvent::NodeRetired { node: i, time_s: now });
            }
        }
    }

    /// Last-resort placement for a high-priority request: find one up
    /// node where evicting lower-priority running attempts (youngest
    /// first — least work lost) frees enough ledger *and* live memory,
    /// dry-run against a cloned ledger, and only then evict for real.
    /// Victims requeue blamelessly after the preemptor has placed.
    fn try_preempt_place(&mut self, p: &Pending, now: f64) -> bool {
        if !self.cfg.preempt || p.priority == 0 {
            return false;
        }
        let res_alloc = self.reservation_alloc(p);
        let profile = planned_profile(&res_alloc, now);
        let initial = initial_request(&res_alloc).0;
        let mut plan: Option<Vec<u64>> = None;
        for i in 0..self.cluster.n_nodes() {
            if !self.cluster.nodes()[i].is_up() {
                continue;
            }
            let cap = self.cluster.nodes()[i].spec.mem.0;
            // youngest first: highest exec id = most recently placed
            let mut victims: Vec<u64> = self
                .running
                .iter()
                .filter(|(_, r)| r.reservation.node_idx == i && r.priority < p.priority)
                .map(|(&e, _)| e)
                .collect();
            victims.sort_unstable_by(|a, b| b.cmp(a));
            let mut ledger = self.ledgers[i].clone();
            let mut freed = 0.0f64;
            let mut take = 0usize;
            loop {
                let live_ok = self.cluster.nodes()[i].free().0 + freed + 1e-9 >= initial;
                if live_ok && ledger.fits(&profile, cap) {
                    plan = Some(victims[..take].to_vec());
                    break;
                }
                if take >= victims.len() {
                    break;
                }
                let v = &self.running[&victims[take]];
                ledger.subtract_profile(&v.profile);
                freed += v.reservation.mem.0;
                take += 1;
            }
            if plan.is_some() {
                break;
            }
        }
        let Some(evict) = plan else { return false };
        let requeue: Vec<Pending> = evict
            .into_iter()
            .map(|exec| self.kill_blameless(exec, FailureCause::Preempted, now))
            .collect();
        let placed = self.try_place(p, now);
        debug_assert!(placed, "preemption dry-run promised a fit");
        for v in requeue {
            self.place_or_queue(v, now);
        }
        placed
    }

    /// Submit one run to the resource manager: predict, log, place or
    /// queue. `wf` ties the attempt back to its workflow task in DAG
    /// mode; independent arrivals pass `None`.
    fn submit(&mut self, run: Rc<TaskRun>, wf: Option<WfRef>, now: f64) {
        self.report.submitted += 1;
        // Snapshot the fit behind the upcoming prediction first. Both
        // calls are observation-only (fit caches are deterministically
        // idempotent), so predict() below returns exactly what it
        // would have without the provenance log attached.
        let detail = if self.tel.provenance.is_some() {
            self.predictor.decision(&run.task_type)
        } else {
            None
        };
        let alloc = clamp_to_node_max(
            self.predictor.predict(&run.task_type, run.input_mib),
            self.node_max,
        );
        if let Some(log) = &mut self.tel.provenance {
            let segments = match &alloc {
                Allocation::Static(_) => 1,
                Allocation::Dynamic(f) => f.k(),
            };
            log.record_predict(
                now,
                &run.task_type,
                run.seq,
                run.input_mib,
                alloc.max_value(),
                segments,
                detail.as_ref(),
            );
        }
        self.emit(
            now,
            EngineEvent::Submitted {
                task_type: run.task_type.clone(),
                seq: run.seq,
                requested: MemMiB(alloc.max_value()),
            },
        );
        let priority =
            if self.cfg.preempt && self.pri_rng.f64() < self.cfg.hipri_frac { 1 } else { 0 };
        let p = Pending {
            run,
            attempt: 1,
            alloc,
            reserve_static: self.cfg.policy == ReservationPolicy::StaticPeak,
            final_attempt: false,
            enqueued_at: now,
            wf,
            priority,
        };
        self.place_or_queue(p, now);
    }

    /// A workflow instance arrives: register its gating state and
    /// release every root (a task with no parents) immediately.
    fn on_instance(&mut self, inst: WorkflowInstance, now: f64) {
        self.report.workflows_submitted += 1;
        // computes the longest runtime chain and validates acyclicity
        let critical_path_s = inst.critical_path_s();
        let WorkflowInstance { name, index, tasks } = inst;
        let n = tasks.len();
        let mut remaining = vec![0usize; n];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut runs: Vec<Option<Rc<TaskRun>>> = Vec::with_capacity(n);
        for (t, task) in tasks.into_iter().enumerate() {
            for &p in &task.parents {
                children[p].push(t);
                remaining[t] += 1;
            }
            runs.push(Some(Rc::new(task.run)));
        }
        let idx = self.dag.len();
        self.dag.push(InstanceState {
            name,
            index,
            remaining,
            children,
            runs,
            outstanding: n,
            arrived_at: now,
            critical_path_s,
            first_completion_at: None,
        });
        for t in 0..n {
            if self.dag[idx].remaining[t] == 0 {
                self.release_task(idx, t, now);
            }
        }
        if n == 0 {
            self.finish_instance(idx, now);
        }
    }

    /// Open a task's gate: log the release and submit it. Called for
    /// roots at instance arrival and for children at their last
    /// parent's final completion.
    fn release_task(&mut self, inst: usize, task: usize, now: f64) {
        let run = self.dag[inst].runs[task].take().expect("task released twice");
        self.emit(
            now,
            EngineEvent::Released {
                task_type: run.task_type.clone(),
                seq: run.seq,
                instance: self.dag[inst].index,
                time_s: now,
            },
        );
        self.submit(run, Some(WfRef { inst, task }), now);
    }

    /// A workflow task reached its final successful completion:
    /// unblock its children and close out the instance when it was the
    /// last one.
    fn on_workflow_task_done(&mut self, wf: WfRef, now: f64) {
        let st = &mut self.dag[wf.inst];
        st.outstanding -= 1;
        if st.first_completion_at.is_none() {
            st.first_completion_at = Some(now);
        }
        let kids = st.children[wf.task].clone();
        let mut ready = Vec::new();
        for c in kids {
            st.remaining[c] -= 1;
            if st.remaining[c] == 0 {
                ready.push(c);
            }
        }
        let instance_done = st.outstanding == 0;
        for c in ready {
            self.release_task(wf.inst, c, now);
        }
        if instance_done {
            self.finish_instance(wf.inst, now);
        }
    }

    /// The last task of an instance completed: emit the event and fold
    /// the instance's workflow metrics into the report.
    fn finish_instance(&mut self, inst: usize, now: f64) {
        let st = &self.dag[inst];
        let makespan_s = now - st.arrived_at;
        let first_s = st.first_completion_at.unwrap_or(now) - st.arrived_at;
        let done = EngineEvent::WorkflowDone {
            workflow: st.name.clone(),
            instance: st.index,
            tasks: st.children.len() as u32,
            time_s: now,
            makespan_s,
        };
        self.emit(now, done);
        let st = &self.dag[inst];
        self.report.workflows_completed += 1;
        self.report.workflow_makespans.push(makespan_s);
        self.report.workflow_critical_paths.push(st.critical_path_s);
        self.report.workflow_first_completions.push(first_s);
        if st.critical_path_s > 0.0 && makespan_s > STRAGGLER_FACTOR * st.critical_path_s {
            self.report.workflow_stragglers += 1;
        }
    }

    fn on_boundary(&mut self, exec: u64, segment: usize, now: f64) {
        // The attempt may already be gone (killed at this timestamp by
        // an earlier-ranked event) — stale boundary events are no-ops.
        let Some(r) = self.running.get(&exec) else { return };
        let Allocation::Dynamic(f) = &r.res_alloc else { return };
        let delta = MemMiB(f.values()[segment] - f.values()[segment - 1]);
        let mut reservation = r.reservation;
        if self.cluster.grow(&mut reservation, delta) {
            self.running.get_mut(&exec).unwrap().reservation = reservation;
            return;
        }
        // Contention (some co-located task overran its predicted
        // runtime): kill the attempt — its reservation integral so far
        // is wasted, a killed attempt produced nothing — and requeue it
        // with a full-peak reservation so it cannot starve mid-run
        // twice. This is not a misprediction, so the predictor's
        // failure path is not invoked and the attempt number is kept.
        let r = self.running.remove(&exec).unwrap();
        self.report.grow_denials += 1;
        let elapsed = now - r.start;
        let held_mibs = match &r.res_alloc {
            Allocation::Static(m) => m.0 * elapsed,
            Allocation::Dynamic(f) => f.integral(elapsed),
        };
        self.report.total_wastage += GbSeconds(MemMiB(held_mibs).as_gb());
        self.cluster.release(r.reservation);
        self.ledgers[r.reservation.node_idx].subtract_profile(&r.profile);
        self.emit(
            now,
            EngineEvent::GrowDenied {
                task_type: r.run.task_type.clone(),
                seq: r.run.seq,
                segment,
                time_s: now,
            },
        );
        let p = Pending {
            run: r.run,
            attempt: r.attempt,
            alloc: r.pred_alloc,
            reserve_static: true,
            final_attempt: r.final_attempt,
            enqueued_at: now,
            wf: r.wf,
            priority: r.priority,
        };
        self.place_or_queue(p, now);
        self.drain(now);
    }

    fn on_finish(&mut self, exec: u64, now: f64) {
        let Some(r) = self.running.remove(&exec) else { return };
        self.cluster.release(r.reservation);
        self.ledgers[r.reservation.node_idx].subtract_profile(&r.profile);
        self.report.total_wastage += GbSeconds(MemMiB(r.outcome.wastage_mibs()).as_gb());
        // A finally-completed workflow task, resolved after the drain:
        // waiters see the freed memory before any newly gated child.
        let mut completed_wf: Option<WfRef> = None;
        match &r.outcome {
            AttemptOutcome::Failure { info, .. } if !r.final_attempt => {
                // the only `on_failure` path: simulate_attempt produces
                // OOMs exclusively; blameless kills never reach here
                debug_assert_eq!(info.cause, FailureCause::Oom);
                self.report.oom_kills += 1;
                self.emit(
                    now,
                    EngineEvent::OomKilled {
                        task_type: r.run.task_type.clone(),
                        seq: r.run.seq,
                        attempt: r.attempt,
                        time_s: now,
                    },
                );
                let next_attempt = r.attempt + 1;
                let (alloc, final_attempt) = if next_attempt > self.cfg.max_attempts {
                    // budget exhausted: node max, complete regardless
                    (Allocation::Static(self.node_max), true)
                } else {
                    (
                        clamp_to_node_max(
                            self.predictor.on_failure(
                                &r.run.task_type,
                                r.run.input_mib,
                                &r.pred_alloc,
                                info,
                            ),
                            self.node_max,
                        ),
                        false,
                    )
                };
                if let Some(log) = &mut self.tel.provenance {
                    log.record_failure(
                        now,
                        &r.run.task_type,
                        r.run.seq,
                        r.attempt,
                        FailureCause::Oom.name(),
                        info.used_mib,
                        alloc.max_value(),
                    );
                }
                let p = Pending {
                    run: r.run,
                    attempt: next_attempt,
                    alloc,
                    reserve_static: self.cfg.policy == ReservationPolicy::StaticPeak,
                    final_attempt,
                    enqueued_at: now,
                    wf: r.wf,
                    priority: r.priority,
                };
                self.place_or_queue(p, now);
            }
            _ => {
                // success, or a final attempt the manager forces through
                self.report.completed += 1;
                self.emit(
                    now,
                    EngineEvent::Completed {
                        task_type: r.run.task_type.clone(),
                        seq: r.run.seq,
                        attempts: r.attempt,
                    },
                );
                // the run's last reference drops here in streaming mode
                self.predictor.observe(&r.run);
                completed_wf = r.wf;
            }
        }
        self.drain(now);
        // Dependency gate: children release only on the parent's FINAL
        // completion (the requeue branch above keeps the gate shut),
        // after this instant's backfill pass — so an OOM-killed
        // parent's retries delay its whole subtree. A forced-through
        // final attempt (retry budget exhausted at node max — only
        // reachable when the true peak exceeds the largest node) also
        // opens the gate: that is the engine-wide termination rule,
        // and refusing would leave the children unreleased forever.
        if let Some(wf) = completed_wf {
            self.on_workflow_task_done(wf, now);
        }
    }
}

/// One unit of the arrival stream: a lone task run, or a whole
/// workflow instance whose roots release on arrival.
enum FeedItem {
    Run(TaskRun),
    Instance(WorkflowInstance),
}

/// Where [`run_engine`] pulls its arrival stream from.
enum RunFeed<'a> {
    /// Materialized run list (the classic [`schedule_trace`] path).
    Vec(VecDeque<TaskRun>),
    /// Incremental pull from a streaming source.
    Source { src: &'a mut dyn TraceSource, chunk: usize, buf: VecDeque<TaskRun> },
    /// Whole workflow instances (the [`schedule_workflows`] DAG path).
    Instances(VecDeque<WorkflowInstance>),
}

impl RunFeed<'_> {
    fn next_item(&mut self) -> Result<Option<FeedItem>> {
        match self {
            RunFeed::Vec(q) => Ok(q.pop_front().map(FeedItem::Run)),
            RunFeed::Source { src, chunk, buf } => {
                if buf.is_empty() {
                    buf.extend(src.next_chunk(*chunk)?);
                }
                Ok(buf.pop_front().map(FeedItem::Run))
            }
            RunFeed::Instances(q) => Ok(q.pop_front().map(FeedItem::Instance)),
        }
    }
}

/// Next inter-arrival gap (seconds); `rng` is consumed one draw per
/// arrival, in arrival order, so the stream is a pure function of the
/// seed regardless of how the runs are fed.
fn arrival_gap(rng: &mut Rng, cfg: &SchedConfig) -> f64 {
    if cfg.mean_interarrival.0 <= 0.0 {
        0.0 // batch mode: everything arrives at t = 0
    } else if cfg.deterministic_arrivals {
        cfg.mean_interarrival.0
    } else {
        -(1.0 - rng.f64()).ln() * cfg.mean_interarrival.0
    }
}

/// Schedule one trace; see the module docs for the protocol.
pub fn schedule_trace(
    trace: &Trace,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
) -> SchedReport {
    schedule_trace_logged(trace, predictor, cfg).0
}

/// [`schedule_trace`] variant that also returns the engine-style event
/// log (capped at `cfg.event_log_cap`).
pub fn schedule_trace_logged(
    trace: &Trace,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
) -> (SchedReport, EventLog) {
    schedule_trace_telemetry(trace, predictor, cfg, &mut RunTelemetry::off())
}

/// [`schedule_trace`] variant with telemetry attachments (trace sink
/// and/or provenance log). Telemetry is observation-only: the returned
/// report and event log are bit-identical to the untraced run
/// (`tests/telemetry.rs` pins this). The caller finishes `tel`.
pub fn schedule_trace_telemetry(
    trace: &Trace,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
    tel: &mut RunTelemetry,
) -> (SchedReport, EventLog) {
    assert!(
        (0.0..1.0).contains(&cfg.training_frac),
        "training fraction in [0,1)"
    );
    // Prime developer defaults, then warm the model offline on the
    // first `training_frac` of each type (the sim protocol).
    for ty in trace.task_types() {
        if let Some(mem) = trace.default_alloc(ty) {
            predictor.prime(ty, mem);
        }
    }
    let mut scored: Vec<TaskRun> = Vec::new();
    for ty in trace.task_types().map(String::from).collect::<Vec<_>>() {
        let runs = trace.runs_of(&ty);
        let n_train = ((runs.len() as f64) * cfg.training_frac).floor() as usize;
        for run in &runs[..n_train] {
            predictor.observe(run);
        }
        scored.extend(runs[n_train..].iter().cloned());
    }
    scored.sort_by_key(|r| r.seq);
    run_engine(RunFeed::Vec(scored.into()), predictor, cfg, tel)
        .expect("in-memory run feed cannot fail")
}

/// Schedule a **streaming** arrival stream: runs arrive in the order
/// the source yields them, pulled chunk by chunk as the simulated
/// clock advances — the whole trace is never materialized.
///
/// There is no offline warm-up split (a stream has no "first
/// `training_frac`"); to start from trained state, restore a replay
/// `Checkpoint` (serve layer) into the predictor first. Source
/// defaults are primed before the first arrival.
pub fn schedule_stream(
    src: &mut dyn TraceSource,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
    chunk: usize,
) -> Result<(SchedReport, EventLog)> {
    schedule_stream_telemetry(src, predictor, cfg, chunk, &mut RunTelemetry::off())
}

/// [`schedule_stream`] variant with telemetry attachments; see
/// [`schedule_trace_telemetry`] for the observation-only contract.
pub fn schedule_stream_telemetry(
    src: &mut dyn TraceSource,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
    chunk: usize,
    tel: &mut RunTelemetry,
) -> Result<(SchedReport, EventLog)> {
    for (ty, mem) in src.defaults() {
        predictor.prime(&ty, mem);
    }
    run_engine(
        RunFeed::Source { src, chunk: chunk.max(1), buf: VecDeque::new() },
        predictor,
        cfg,
        tel,
    )
}

/// Schedule N concurrent, **dependency-gated** executions of a
/// workflow DAG (see the module docs' "Workflow DAG mode"). Instances
/// arrive gapped by `cfg.mean_interarrival` (batch mode submits all of
/// them at t = 0); within an instance a task is released only when
/// every parent has finally completed. Developer defaults from the
/// source are primed; there is no offline warm-up split — the
/// predictor learns online across instances, exactly as a workflow
/// engine would drive it.
pub fn schedule_workflows(
    src: WorkflowSource,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
) -> SchedReport {
    schedule_workflows_logged(src, predictor, cfg).0
}

/// [`schedule_workflows`] variant that also returns the engine-style
/// event log (`Released` / `Placed` / `OomKilled` / `Completed` /
/// `WorkflowDone`, capped at `cfg.event_log_cap`).
pub fn schedule_workflows_logged(
    src: WorkflowSource,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
) -> (SchedReport, EventLog) {
    schedule_workflows_telemetry(src, predictor, cfg, &mut RunTelemetry::off())
}

/// [`schedule_workflows`] variant with telemetry attachments; see
/// [`schedule_trace_telemetry`] for the observation-only contract.
pub fn schedule_workflows_telemetry(
    src: WorkflowSource,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
    tel: &mut RunTelemetry,
) -> (SchedReport, EventLog) {
    for (ty, mem) in src.defaults() {
        predictor.prime(ty, *mem);
    }
    run_engine(RunFeed::Instances(src.instances.into()), predictor, cfg, tel)
        .expect("in-memory instance feed cannot fail")
}

/// The discrete-event loop shared by [`schedule_trace`] and
/// [`schedule_stream`]. Arrivals are generated lazily — exactly one
/// not-yet-arrived run is pulled ahead, its arrival event scheduled at
/// the previous arrival time plus [`arrival_gap`] — which is
/// observably identical to pre-pushing the whole stream (arrival times
/// are non-decreasing and same-instant ordering is by event rank), but
/// bounds memory by the in-flight task set.
fn run_engine(
    mut feed: RunFeed<'_>,
    predictor: &mut dyn MemoryPredictor,
    cfg: &SchedConfig,
    tel: &mut RunTelemetry,
) -> Result<(SchedReport, EventLog)> {
    let cluster = Cluster::heterogeneous(cfg.nodes.clone());
    // Snapshotted from the base roster: base nodes never retire and
    // failed nodes rejoin, so clamping to this still guarantees every
    // request is eventually placeable (termination).
    let node_max = cluster.node_max_mem();
    let n_nodes = cluster.n_nodes();

    let report = SchedReport::new(
        cfg.policy.name(),
        &predictor.name(),
        n_nodes,
        cfg.mean_interarrival.0,
    );
    let mut sim = Sim {
        cfg,
        predictor,
        tel,
        cluster,
        ledgers: vec![TimeProfile::new(); n_nodes],
        events: EventQueue::new(),
        waiting: VecDeque::new(),
        running: BTreeMap::new(),
        next_exec: 0,
        node_max,
        report,
        log: EventLog::with_cap(cfg.event_log_cap),
        dag: Vec::new(),
        fail_rng: Rng::new(cfg.seed).fork("node-failures"),
        pri_rng: Rng::new(cfg.seed).fork("priorities"),
        n_base_nodes: n_nodes,
        provisioning: BTreeSet::new(),
        failures_scheduled: 0,
        arrivals_open: false,
    };

    // Arrival stream: exponential (or fixed) gaps, deterministic from
    // the seed; one item (run or whole instance) pulled ahead of the
    // clock.
    let mut rng = Rng::new(cfg.seed);
    let mut arrival_ordinal = 0usize;
    let mut next_arrival_t = 0.0f64;
    let mut upcoming: Option<FeedItem> = feed.next_item()?;
    if upcoming.is_some() {
        next_arrival_t += arrival_gap(&mut rng, cfg);
        sim.events.push(next_arrival_t, SchedEvent::Arrival { task: 0 });
        sim.arrivals_open = true;
        sim.schedule_next_failure(0.0);
    }

    let mut last_t = 0.0f64;
    let mut reserved_gb = 0.0f64;
    let mut cap_gb = sim.cluster.up_capacity().as_gb();
    let mut reserved_integral = 0.0f64;
    let mut capacity_integral = 0.0f64;
    // Utilization integrals snapshotted at the makespan: lifecycle
    // events trailing the last task-driven event (a rejoin scheduled
    // past the final completion) must not stretch the measured window.
    let mut reserved_at_makespan = 0.0f64;
    let mut capacity_at_makespan = 0.0f64;
    let mut makespan = 0.0f64;
    while let Some((now, ev)) = sim.events.pop() {
        sim.report.events_processed += 1;
        reserved_integral += reserved_gb * (now - last_t);
        capacity_integral += cap_gb * (now - last_t);
        last_t = now;
        let task_event =
            !matches!(ev, SchedEvent::NodeFail | SchedEvent::NodeJoin { .. });
        if task_event {
            makespan = makespan.max(now);
            reserved_at_makespan = reserved_integral;
            capacity_at_makespan = capacity_integral;
        }
        match ev {
            SchedEvent::Finish { exec } => sim.on_finish(exec, now),
            SchedEvent::SegmentBoundary { exec, segment } => sim.on_boundary(exec, segment, now),
            SchedEvent::NodeFail => sim.on_node_fail(now),
            SchedEvent::NodeJoin { node } => sim.on_node_join(node, now),
            SchedEvent::Arrival { .. } => {
                match upcoming.take().expect("arrival event without a pulled item") {
                    FeedItem::Run(run) => sim.submit(Rc::new(run), None, now),
                    FeedItem::Instance(inst) => sim.on_instance(inst, now),
                }
                if let Some(next) = feed.next_item()? {
                    arrival_ordinal += 1;
                    next_arrival_t += arrival_gap(&mut rng, cfg);
                    sim.events
                        .push(next_arrival_t, SchedEvent::Arrival { task: arrival_ordinal });
                    upcoming = Some(next);
                } else {
                    sim.arrivals_open = false;
                }
            }
        }
        sim.autoscale_tick(now);
        reserved_gb = sim.cluster.total_reserved().as_gb();
        let up_capacity = sim.cluster.up_capacity();
        cap_gb = up_capacity.as_gb();
        let running_now = sim.running.len() as u64;
        if running_now > sim.report.peak_running {
            sim.report.peak_running = running_now;
        }
        if up_capacity.0 > 0.0 {
            let frac = sim.cluster.total_reserved().0 / up_capacity.0;
            if frac > sim.report.peak_util_frac {
                sim.report.peak_util_frac = frac;
            }
        }
    }
    assert!(sim.waiting.is_empty(), "scheduler ended with queued tasks");
    assert!(sim.running.is_empty(), "scheduler ended with running tasks");
    let ungated: usize = sim.dag.iter().map(|s| s.outstanding).sum();
    assert_eq!(ungated, 0, "scheduler ended with {ungated} never-released workflow tasks");
    debug_assert!(sim.cluster.total_reserved().0 < 1e-6, "cluster not empty at end");

    let mut report = sim.report;
    report.makespan = Seconds(makespan);
    report.reserved_integral_gbs = reserved_at_makespan;
    report.capacity_integral_gbs = capacity_at_makespan;
    Ok((report, sim.log))
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::predictors::default_config::DefaultConfigPredictor;
    use ksegments_core::predictors::FailureInfo;
    use ksegments_core::trace::UsageSeries;

    /// Ramp trace: every run climbs linearly to `peak` over `n_samples`
    /// 2-second samples.
    fn ramp_trace(n_runs: usize, peak: f64, n_samples: usize) -> Trace {
        let mut t = Trace::new();
        t.set_default("w/ramp", MemMiB(peak * 1.2));
        for i in 0..n_runs {
            let samples: Vec<f64> =
                (0..n_samples).map(|j| peak * (j + 1) as f64 / n_samples as f64).collect();
            t.push(TaskRun {
                task_type: "w/ramp".into(),
                input_mib: 100.0,
                runtime: Seconds(n_samples as f64 * 2.0),
                series: UsageSeries::new(2.0, samples),
                seq: i as u64,
            });
        }
        t.sort();
        t
    }

    /// Oracle predictor: a k-step function whose segment values are the
    /// exact per-segment peaks of the reference series (no noise, no
    /// learning — isolates the *policy* effect from prediction error).
    struct OracleRamp {
        series: UsageSeries,
        k: usize,
    }
    impl OracleRamp {
        fn for_trace(trace: &Trace, ty: &str, k: usize) -> OracleRamp {
            OracleRamp { series: trace.runs_of(ty)[0].series.clone(), k }
        }
    }
    impl MemoryPredictor for OracleRamp {
        fn name(&self) -> String {
            "oracle-ramp".into()
        }
        fn prime(&mut self, _: &str, _: MemMiB) {}
        fn predict(&mut self, _: &str, _: f64) -> Allocation {
            let rt = self.series.duration().0;
            let dt = self.series.interval().0;
            let samples = self.series.samples();
            let values: Vec<f64> = (1..=self.k)
                .map(|s| {
                    let lo = rt * (s - 1) as f64 / self.k as f64;
                    let hi = rt * s as f64 / self.k as f64;
                    samples
                        .iter()
                        .enumerate()
                        .filter(|(j, _)| {
                            let t0 = *j as f64 * dt;
                            t0 < hi && t0 + dt > lo
                        })
                        .map(|(_, &u)| u)
                        .fold(0.0f64, f64::max)
                })
                .collect();
            Allocation::Dynamic(StepFunction::monotone_clamped(
                Seconds(rt),
                values,
                MemMiB(1.0),
                MemMiB(1e9),
            ))
        }
        fn on_failure(&mut self, _: &str, _: f64, _: &Allocation, _: &FailureInfo) -> Allocation {
            Allocation::Static(MemMiB(self.series.peak()))
        }
        fn observe(&mut self, _: &TaskRun) {}
    }

    fn staggered_cfg(policy: ReservationPolicy) -> SchedConfig {
        SchedConfig {
            policy,
            // room for exactly 2 static-peak tasks (peak 1000)
            nodes: vec![NodeSpec { mem: MemMiB(2000.0), cores: 8 }],
            mean_interarrival: Seconds(5.0),
            deterministic_arrivals: true,
            seed: 1,
            training_frac: 0.0,
            max_attempts: 10,
            event_log_cap: 0,
            ..SchedConfig::default()
        }
    }

    // The headline packing claim (segment-wise strictly beats
    // static-peak on a staggered ramp workload) is asserted once, in
    // `tests/sched_integration.rs` — not duplicated here.

    #[test]
    fn accounting_identities_hold() {
        let trace = ramp_trace(12, 800.0, 6);
        let mut p = OracleRamp::for_trace(&trace, "w/ramp", 3);
        let mut cfg = staggered_cfg(ReservationPolicy::SegmentWise);
        cfg.mean_interarrival = Seconds(0.0); // batch mode
        let r = schedule_trace(&trace, &mut p, &cfg);
        assert_eq!(r.completed, r.submitted);
        assert_eq!(
            r.admitted,
            r.completed + r.oom_kills + r.grow_denials + r.preempted + r.node_lost
        );
        assert_eq!(r.placement_attempts, r.admitted + r.rejected);
        assert_eq!(r.queue_waits.len() as u64, r.admitted);
    }

    #[test]
    fn oom_kill_requeues_and_completes() {
        // defaults primed far below the true peak: every first attempt
        // is OOM-killed; the escalation loop must still finish all runs
        let mut trace = ramp_trace(6, 1000.0, 6);
        trace.set_default("w/ramp", MemMiB(10.0));
        let mut p = DefaultConfigPredictor::new();
        let cfg = SchedConfig {
            training_frac: 0.0,
            nodes: vec![NodeSpec { mem: MemMiB(4000.0), cores: 8 }],
            mean_interarrival: Seconds(1.0),
            ..SchedConfig::default()
        };
        let r = schedule_trace(&trace, &mut p, &cfg);
        assert_eq!(r.completed, 6);
        assert!(r.oom_kills > 0, "under-allocated defaults must OOM");
        assert_eq!(r.admitted, r.completed + r.oom_kills + r.grow_denials);
    }

    /// Runtime underprediction is the one hole in ledger admission: a
    /// task holding memory past its planned release collides with a
    /// later task's grow — the grow is denied, the attempt killed and
    /// requeued with a full-peak reservation.
    #[test]
    fn runtime_underprediction_triggers_grow_denial() {
        struct FixedStep;
        impl MemoryPredictor for FixedStep {
            fn name(&self) -> String {
                "fixed-step".into()
            }
            fn prime(&mut self, _: &str, _: MemMiB) {}
            fn predict(&mut self, _: &str, _: f64) -> Allocation {
                // predicts a 10 s runtime; the real tasks run 20 s
                Allocation::Dynamic(StepFunction::new(vec![5.0, 10.0], vec![400.0, 600.0]))
            }
            fn on_failure(
                &mut self,
                _: &str,
                _: f64,
                _: &Allocation,
                _: &FailureInfo,
            ) -> Allocation {
                Allocation::Static(MemMiB(800.0))
            }
            fn observe(&mut self, _: &TaskRun) {}
        }
        let mut trace = Trace::new();
        trace.set_default("w/t", MemMiB(600.0));
        for i in 0..2 {
            trace.push(TaskRun {
                task_type: "w/t".into(),
                input_mib: 10.0,
                runtime: Seconds(20.0),
                series: UsageSeries::new(2.0, vec![300.0; 10]),
                seq: i,
            });
        }
        trace.sort();
        let cfg = SchedConfig {
            policy: ReservationPolicy::SegmentWise,
            nodes: vec![NodeSpec { mem: MemMiB(1000.0), cores: 4 }],
            mean_interarrival: Seconds(12.0),
            deterministic_arrivals: true,
            seed: 7,
            training_frac: 0.0,
            max_attempts: 10,
            event_log_cap: 0,
            ..SchedConfig::default()
        };
        let r = schedule_trace(&trace, &mut FixedStep, &cfg);
        assert_eq!(r.completed, 2);
        assert_eq!(r.grow_denials, 1, "the second task's grow must collide");
        assert_eq!(r.oom_kills, 0);
        assert_eq!(r.admitted, r.completed + r.grow_denials);
        assert_eq!(r.placement_attempts, r.admitted + r.rejected);
    }

    /// A streamed source with no warm-up split must reproduce the
    /// materialized `schedule_trace` at `training_frac = 0` bit for
    /// bit: the lazy arrival generator consumes the same rng sequence
    /// and sees the same run order.
    #[test]
    fn stream_matches_materialized_schedule() {
        let trace = ramp_trace(10, 900.0, 8);
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(2500.0), cores: 4 }; 2],
            mean_interarrival: Seconds(3.0),
            training_frac: 0.0,
            ..SchedConfig::default()
        };
        let mut p1 = ksegments_core::predictors::ppm::PpmPredictor::improved();
        let a = schedule_trace(&trace, &mut p1, &cfg);
        let mut src = ksegments_core::source::InMemorySource::from_trace(&trace);
        let mut p2 = ksegments_core::predictors::ppm::PpmPredictor::improved();
        let (b, _) = schedule_stream(&mut src, &mut p2, &cfg, 4).unwrap();
        assert_eq!(a, b);
        // batch mode streams identically too
        let mut cfg = cfg;
        cfg.mean_interarrival = Seconds(0.0);
        let mut p3 = ksegments_core::predictors::ppm::PpmPredictor::improved();
        let c = schedule_trace(&trace, &mut p3, &cfg);
        src.rewind().unwrap();
        let mut p4 = ksegments_core::predictors::ppm::PpmPredictor::improved();
        let (d, _) = schedule_stream(&mut src, &mut p4, &cfg, 3).unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let trace = ramp_trace(10, 900.0, 8);
        let mk = || OracleRamp::for_trace(&trace, "w/ramp", 4);
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(2500.0), cores: 4 }; 2],
            mean_interarrival: Seconds(3.0),
            training_frac: 0.0,
            ..SchedConfig::default()
        };
        let a = schedule_trace(&trace, &mut mk(), &cfg);
        let b = schedule_trace(&trace, &mut mk(), &cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn event_log_records_scheduler_lifecycle() {
        let trace = ramp_trace(4, 1000.0, 6);
        let mut p = OracleRamp::for_trace(&trace, "w/ramp", 4);
        let (r, log) = schedule_trace_logged(
            &trace,
            &mut p,
            &staggered_cfg(ReservationPolicy::SegmentWise),
        );
        assert_eq!(r.completed, 4);
        let placed = log.iter().filter(|e| matches!(e, EngineEvent::Placed { .. })).count();
        assert_eq!(placed as u64, r.admitted);
        let comps = log.iter().filter(|e| matches!(e, EngineEvent::Completed { .. })).count();
        assert_eq!(comps as u64, r.completed);
    }

    #[test]
    fn batch_mode_queues_when_capacity_is_tight() {
        let trace = ramp_trace(8, 1000.0, 10);
        let mut p = OracleRamp::for_trace(&trace, "w/ramp", 1); // k=1 == static
        let mut cfg = staggered_cfg(ReservationPolicy::StaticPeak);
        cfg.mean_interarrival = Seconds(0.0);
        let r = schedule_trace(&trace, &mut p, &cfg);
        // 8 tasks, 2 fit at once: most admissions waited
        assert!(r.rejected > 0);
        assert!(r.queue_wait_percentile_s(95.0) > 0.0);
        assert!(r.peak_util_frac > 0.99, "tight batch should saturate the node");
        assert_eq!(r.peak_running, 2);
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(ReservationPolicy::parse("static"), Some(ReservationPolicy::StaticPeak));
        assert_eq!(ReservationPolicy::parse("segment"), Some(ReservationPolicy::SegmentWise));
        assert_eq!(
            ReservationPolicy::parse("segment-wise"),
            Some(ReservationPolicy::SegmentWise)
        );
        assert!(ReservationPolicy::parse("bogus").is_none());
        assert_eq!(ReservationPolicy::StaticPeak.name(), "static-peak");
    }

    #[test]
    fn empty_trace_is_a_noop() {
        let trace = Trace::new();
        let mut p = DefaultConfigPredictor::new();
        let r = schedule_trace(&trace, &mut p, &SchedConfig::default());
        assert_eq!(r.submitted, 0);
        assert_eq!(r.completed, 0);
        assert_eq!(r.makespan, Seconds::ZERO);
    }

    /// A hand-built chain instance: parent → child. Runtime 20 s each.
    fn chain_instance(index: u64, peak: f64) -> WorkflowInstance {
        let run = |ty: &str, seq: u64| TaskRun {
            task_type: ty.into(),
            input_mib: 100.0,
            runtime: Seconds(20.0),
            series: UsageSeries::new(2.0, (1..=10).map(|j| peak * j as f64 / 10.0).collect()),
            seq,
        };
        WorkflowInstance {
            name: "w".into(),
            index,
            tasks: vec![
                workflow::DagTask { run: run("w/parent", index * 2), parents: vec![] },
                workflow::DagTask { run: run("w/child", index * 2 + 1), parents: vec![0] },
            ],
        }
    }

    #[test]
    fn dependency_gate_serializes_a_chain() {
        // plenty of capacity: without the gate both tasks would
        // overlap and the makespan would be ~20 s
        let src = WorkflowSource::from_instances(
            vec![chain_instance(0, 500.0)],
            vec![("w/parent".into(), MemMiB(800.0)), ("w/child".into(), MemMiB(800.0))],
        );
        let mut p = DefaultConfigPredictor::new();
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(8000.0), cores: 8 }],
            mean_interarrival: Seconds(0.0),
            ..SchedConfig::default()
        };
        let (r, log) = schedule_workflows_logged(src, &mut p, &cfg);
        assert_eq!(r.workflows_submitted, 1);
        assert_eq!(r.workflows_completed, 1);
        assert_eq!(r.submitted, 2);
        assert_eq!(r.completed, 2);
        assert_eq!(r.oom_kills, 0);
        // chain: 20 s parent + 20 s child, no overlap
        assert!((r.makespan.0 - 40.0).abs() < 1e-9, "makespan {}", r.makespan.0);
        assert_eq!(r.peak_running, 1, "child must not overlap its parent");
        assert_eq!(r.workflow_makespans, vec![40.0]);
        assert_eq!(r.workflow_critical_paths, vec![40.0]);
        assert_eq!(r.workflow_first_completions, vec![20.0]);
        assert_eq!(r.workflow_stragglers, 0);
        assert!((r.critical_path_stretch() - 1.0).abs() < 1e-9);
        // log order: child released strictly after parent completed
        let pos = |pred: &dyn Fn(&EngineEvent) -> bool| {
            log.iter().position(|e| pred(e)).expect("event present")
        };
        let completed = |ty: &'static str| {
            move |e: &EngineEvent| {
                matches!(e, EngineEvent::Completed { task_type, .. } if task_type == ty)
            }
        };
        let released = |ty: &'static str| {
            move |e: &EngineEvent| {
                matches!(e, EngineEvent::Released { task_type, .. } if task_type == ty)
            }
        };
        let parent_done = pos(&completed("w/parent"));
        let child_released = pos(&released("w/child"));
        let wf_done = pos(&|e: &EngineEvent| matches!(e, EngineEvent::WorkflowDone { .. }));
        assert!(child_released > parent_done);
        assert!(wf_done > child_released);
    }

    #[test]
    fn workflow_accounting_and_determinism() {
        let mk_src = || {
            WorkflowSource::from_instances(
                (0..4).map(|i| chain_instance(i, 900.0)).collect(),
                vec![("w/parent".into(), MemMiB(1200.0)), ("w/child".into(), MemMiB(1200.0))],
            )
        };
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(2000.0), cores: 4 }],
            mean_interarrival: Seconds(5.0),
            seed: 11,
            ..SchedConfig::default()
        };
        let run = || {
            let mut p = DefaultConfigPredictor::new();
            schedule_workflows(mk_src(), &mut p, &cfg)
        };
        let a = run();
        assert_eq!(a.workflows_completed, 4);
        assert_eq!(a.completed, a.submitted);
        assert_eq!(a.admitted, a.completed + a.oom_kills + a.grow_denials);
        assert_eq!(a.placement_attempts, a.admitted + a.rejected);
        assert_eq!(a.workflow_makespans.len(), 4);
        // achieved makespan can never beat the critical path
        for (m, cp) in a.workflow_makespans.iter().zip(&a.workflow_critical_paths) {
            assert!(*m >= *cp - 1e-9, "makespan {m} below critical path {cp}");
        }
        let b = run();
        assert_eq!(a, b, "workflow scheduling must be deterministic");
    }

    #[test]
    fn undersized_default_ooms_and_still_completes_the_workflow() {
        // parent+child defaults far below the 1000 MiB true peak
        let src = WorkflowSource::from_instances(
            vec![chain_instance(0, 1000.0)],
            vec![("w/parent".into(), MemMiB(50.0)), ("w/child".into(), MemMiB(50.0))],
        );
        let mut p = DefaultConfigPredictor::new();
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(4000.0), cores: 4 }],
            mean_interarrival: Seconds(0.0),
            ..SchedConfig::default()
        };
        let r = schedule_workflows(src, &mut p, &cfg);
        assert_eq!(r.workflows_completed, 1);
        assert_eq!(r.completed, 2);
        assert!(r.oom_kills > 0, "undersized defaults must OOM");
        // the parent's retries push the instance past its critical path
        assert!(r.workflow_makespans[0] > r.workflow_critical_paths[0] + 1.0);
    }

    /// Records every escalation so tests can prove whether the
    /// scheduler blamed the predictor for a kill.
    struct Spy {
        predict_mib: f64,
        escalations: u32,
    }
    impl MemoryPredictor for Spy {
        fn name(&self) -> String {
            "spy".into()
        }
        fn prime(&mut self, _: &str, _: MemMiB) {}
        fn predict(&mut self, _: &str, _: f64) -> Allocation {
            Allocation::Static(MemMiB(self.predict_mib))
        }
        fn on_failure(&mut self, _: &str, _: f64, _: &Allocation, _: &FailureInfo) -> Allocation {
            self.escalations += 1;
            Allocation::Static(MemMiB(2000.0))
        }
        fn observe(&mut self, _: &TaskRun) {}
    }

    fn extended_identity(r: &SchedReport) {
        assert_eq!(r.completed, r.submitted);
        assert_eq!(
            r.admitted,
            r.completed + r.oom_kills + r.grow_denials + r.preempted + r.node_lost
        );
        assert_eq!(r.placement_attempts, r.admitted + r.rejected);
        assert_eq!(r.queue_waits.len() as u64, r.admitted);
    }

    /// THE blameless-requeue regression: a node-lost attempt must come
    /// back with the SAME allocation and attempt number, and the
    /// predictor's escalation path must never fire. (The bug this
    /// pins: treating a node loss like an OOM permanently triples the
    /// task's allocation under retry-based baselines.)
    #[test]
    fn node_loss_requeues_blamelessly_without_escalation() {
        let trace = ramp_trace(1, 400.0, 50); // one 100 s task
        let mut p = Spy { predict_mib: 500.0, escalations: 0 };
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(1000.0), cores: 4 }],
            mean_interarrival: Seconds(0.0),
            training_frac: 0.0,
            fail_mtbf: Seconds(5.0),
            fail_downtime: Seconds(1.0),
            max_node_failures: 30,
            ..SchedConfig::default()
        };
        let (r, log) = schedule_trace_logged(&trace, &mut p, &cfg);
        assert_eq!(r.completed, 1);
        assert!(r.node_lost >= 1, "a 100 s task at mtbf 5 s must be hit at least once");
        assert_eq!(r.oom_kills, 0);
        assert_eq!(p.escalations, 0, "blameless kills must never reach on_failure");
        // every re-placement kept the original 500 MiB request…
        for e in log.iter() {
            if let EngineEvent::Placed { reserved, .. } = e {
                assert_eq!(*reserved, MemMiB(500.0), "blameless requeue changed the allocation");
            }
        }
        // …and the task still completed on (logical) attempt 1
        assert!(
            log.iter().any(|e| matches!(e, EngineEvent::Completed { attempts: 1, .. })),
            "node loss must not consume retry budget"
        );
        assert_eq!(r.node_failures as usize, log.iter()
            .filter(|e| matches!(e, EngineEvent::NodeFailed { .. }))
            .count());
        extended_identity(&r);
    }

    /// Control for the regression above: a genuine OOM on the same
    /// workload MUST escalate through `on_failure` exactly once.
    #[test]
    fn oom_kill_escalates_through_on_failure() {
        let trace = ramp_trace(1, 400.0, 50);
        let mut p = Spy { predict_mib: 300.0, escalations: 0 };
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(1000.0), cores: 4 }],
            mean_interarrival: Seconds(0.0),
            training_frac: 0.0,
            ..SchedConfig::default()
        };
        let (r, log) = schedule_trace_logged(&trace, &mut p, &cfg);
        assert_eq!(r.completed, 1);
        assert_eq!(r.oom_kills, 1);
        assert_eq!(r.node_lost, 0);
        assert_eq!(p.escalations, 1, "an OOM must reach on_failure exactly once");
        assert!(log.iter().any(|e| matches!(e, EngineEvent::Completed { attempts: 2, .. })));
        extended_identity(&r);
    }

    /// Node loss keeps the dependency gate shut: a killed parent has
    /// not finally completed, so its child stays unreleased until the
    /// parent's re-run finishes. Seed-swept because whether a loss
    /// lands inside a 20 s run is a property of the failure stream.
    #[test]
    fn node_lost_parent_keeps_subtree_gated() {
        let mut any_loss = false;
        for seed in 0..5 {
            let src = WorkflowSource::from_instances(
                vec![chain_instance(0, 500.0)],
                vec![("w/parent".into(), MemMiB(800.0)), ("w/child".into(), MemMiB(800.0))],
            );
            let mut p = DefaultConfigPredictor::new();
            let cfg = SchedConfig {
                nodes: vec![NodeSpec { mem: MemMiB(4000.0), cores: 4 }],
                mean_interarrival: Seconds(0.0),
                seed,
                fail_mtbf: Seconds(5.0),
                fail_downtime: Seconds(1.0),
                max_node_failures: 10,
                ..SchedConfig::default()
            };
            let (r, log) = schedule_workflows_logged(src, &mut p, &cfg);
            assert_eq!(r.workflows_completed, 1);
            assert_eq!(r.completed, 2);
            assert_eq!(r.oom_kills, 0);
            extended_identity(&r);
            any_loss |= r.node_lost > 0;
            let parent_done = log
                .iter()
                .position(|e| {
                    matches!(e, EngineEvent::Completed { task_type, .. } if task_type == "w/parent")
                })
                .expect("parent completes");
            let child_released = log
                .iter()
                .position(|e| {
                    matches!(e, EngineEvent::Released { task_type, .. } if task_type == "w/child")
                })
                .expect("child releases");
            assert!(
                child_released > parent_done,
                "seed {seed}: child released before its parent finally completed"
            );
        }
        assert!(any_loss, "no seed produced a node loss — failure injection is broken");
    }

    /// Preemption: high-priority arrivals evict running low-priority
    /// work (counted separately, requeued blamelessly), and the
    /// extended conservation identity absorbs it.
    #[test]
    fn preemption_evicts_low_priority_and_conserves() {
        let mut any_preempt = false;
        for seed in 0..5 {
            let trace = ramp_trace(20, 900.0, 30); // 60 s tasks, whole-node
            let mut p = Spy { predict_mib: 950.0, escalations: 0 };
            let cfg = SchedConfig {
                nodes: vec![NodeSpec { mem: MemMiB(1000.0), cores: 4 }],
                mean_interarrival: Seconds(5.0),
                seed,
                training_frac: 0.0,
                preempt: true,
                hipri_frac: 0.5,
                ..SchedConfig::default()
            };
            let (r, log) = schedule_trace_logged(&trace, &mut p, &cfg);
            assert_eq!(r.completed, 20);
            assert_eq!(p.escalations, 0, "preemption must not escalate allocations");
            extended_identity(&r);
            assert_eq!(
                r.preempted as usize,
                log.iter().filter(|e| matches!(e, EngineEvent::Preempted { .. })).count()
            );
            any_preempt |= r.preempted > 0;
        }
        assert!(any_preempt, "no seed preempted — eviction path is dead");
    }

    /// Autoscaling: queue pressure provisions nodes (after the lag),
    /// the added capacity shortens the makespan, and idle autoscaled
    /// nodes retire once the queue empties.
    #[test]
    fn autoscaler_adds_capacity_under_pressure_and_retires_idle() {
        let trace = ramp_trace(12, 900.0, 10); // 20 s whole-node tasks
        let mut p = Spy { predict_mib: 950.0, escalations: 0 };
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(1000.0), cores: 4 }],
            mean_interarrival: Seconds(0.0), // batch: 11 queue instantly
            training_frac: 0.0,
            autoscale: Some(AutoscaleConfig {
                lag: Seconds(10.0),
                queue_per_node: 2,
                max_nodes: 4,
            }),
            ..SchedConfig::default()
        };
        let r = schedule_trace(&trace, &mut p, &cfg);
        assert_eq!(r.completed, 12);
        assert!(r.nodes_added >= 1, "queue pressure must provision nodes");
        assert!(r.nodes_added <= 3, "max_nodes caps the roster at 4");
        assert!(r.nodes_retired >= 1, "idle autoscaled nodes must retire");
        // serial on the base node alone: 12 × 20 s = 240 s
        assert!(r.makespan.0 < 200.0, "autoscaled capacity must shorten the makespan");
        extended_identity(&r);
    }

    /// With every failure-domain knob off, the report's new counters
    /// stay zero — existing behavior is untouched.
    #[test]
    fn failure_domain_counters_zero_when_disabled() {
        let trace = ramp_trace(6, 800.0, 6);
        let mut p = OracleRamp::for_trace(&trace, "w/ramp", 3);
        let r = schedule_trace(&trace, &mut p, &staggered_cfg(ReservationPolicy::SegmentWise));
        assert_eq!(r.preempted, 0);
        assert_eq!(r.node_lost, 0);
        assert_eq!(r.node_failures, 0);
        assert_eq!(r.nodes_added, 0);
        assert_eq!(r.nodes_retired, 0);
        assert!(r.events_processed > 0);
    }
}
