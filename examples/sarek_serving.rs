//! Serving scenario: the prediction service (coordinator) fronting the
//! sarek-like workflow, with four SWMS worker threads submitting
//! concurrently — the deployment shape of the paper's Fig. 2, with
//! request latency measured at the client.
//!
//! Run: `cargo run --release --example sarek_serving`

use std::time::Instant;

use ksegments::coordinator::PredictionService;
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::sim::{simulate_attempt, AttemptOutcome};
use ksegments::util::stats;
use ksegments::workload::{generate_workflow_trace, sarek_workflow};

fn main() {
    let trace = generate_workflow_trace(&sarek_workflow(), 7);
    println!(
        "sarek trace: {} runs over {} task types",
        trace.n_runs(),
        trace.n_types()
    );

    let svc = PredictionService::spawn(Box::new(KSegmentsPredictor::native(
        4,
        RetryStrategy::Selective,
    )));
    for ty in trace.task_types() {
        if let Some(mem) = trace.default_alloc(ty) {
            svc.handle().prime(ty, mem);
        }
    }

    // Four workers replay disjoint slices of the submission stream:
    // predict -> execute (against ground truth) -> report failures ->
    // feed the completion back. Client-side latency is recorded per
    // request.
    let runs: Vec<_> = trace.all_runs_ordered().into_iter().cloned().collect();
    let n_workers = 4;
    let chunk = runs.len().div_ceil(n_workers);
    let start = Instant::now();
    let mut joins = Vec::new();
    for part in runs.chunks(chunk) {
        let h = svc.handle();
        let part = part.to_vec();
        joins.push(std::thread::spawn(move || {
            let mut latencies_us = Vec::with_capacity(part.len());
            let mut retries = 0u64;
            for run in part {
                let t0 = Instant::now();
                let mut alloc = h.predict(&run.task_type, run.input_mib);
                latencies_us.push(t0.elapsed().as_nanos() as f64 / 1000.0);
                let mut attempt = 1;
                loop {
                    match simulate_attempt(&run.series, &alloc, attempt) {
                        AttemptOutcome::Success { .. } => break,
                        AttemptOutcome::Failure { info, .. } => {
                            retries += 1;
                            attempt += 1;
                            alloc =
                                h.report_failure(&run.task_type, run.input_mib, alloc, info);
                            if attempt > 40 {
                                break;
                            }
                        }
                    }
                }
                h.complete(run);
            }
            (latencies_us, retries)
        }));
    }

    let mut all_lat = Vec::new();
    let mut total_retries = 0;
    for j in joins {
        let (lat, retries) = j.join().expect("worker panicked");
        all_lat.extend(lat);
        total_retries += retries;
    }
    let wall = start.elapsed();
    let stats_snapshot = svc.shutdown();

    println!(
        "\nserved {} predictions / {} completions / {} failure consults in {:.2} s ({:.0} req/s)",
        stats_snapshot.predictions,
        stats_snapshot.completions,
        stats_snapshot.failures,
        wall.as_secs_f64(),
        stats_snapshot.predictions as f64 / wall.as_secs_f64()
    );
    println!(
        "prediction latency: p50 {:.1} µs  p95 {:.1} µs  p99 {:.1} µs  max {:.1} µs",
        stats::percentile(&all_lat, 50.0),
        stats::percentile(&all_lat, 95.0),
        stats::percentile(&all_lat, 99.0),
        stats::percentile(&all_lat, 100.0),
    );
    println!("task retries across the workflow: {total_retries}");
    assert_eq!(stats_snapshot.completions as usize, runs.len());
    println!("SERVING OK");
}
