//! Parallel scheduling sweep: (policy × predictor × cluster size ×
//! arrival rate) cells on the same worker pool as the evaluation grid.
//!
//! Mirrors [`crate::sim::parallel::EvalGrid`]: cells are enumerated in
//! a canonical policy-major order and executed via [`parallel_map`];
//! every cell builds a fresh predictor and a fresh cluster, schedules
//! each trace independently and merges per-trace [`SchedReport`]s in
//! trace order — results are bit-identical for any worker count.

use crate::cluster::NodeSpec;
use crate::sched::{schedule_trace, ReservationPolicy, SchedConfig, SchedReport};
use crate::sim::{parallel_map, PredictorFactory};
use crate::trace::Trace;
use crate::units::Seconds;

/// Index quadruple identifying one cell of a [`SchedGrid`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedCell {
    pub policy_idx: usize,
    pub method_idx: usize,
    pub nodes_idx: usize,
    pub arrival_idx: usize,
}

/// The sweep axes: reservation policies × predictor factories × node
/// counts × mean inter-arrival gaps, over a shared set of traces.
pub struct SchedGrid<'a> {
    policies: Vec<ReservationPolicy>,
    methods: Vec<PredictorFactory>,
    traces: &'a [Trace],
    node_counts: Vec<usize>,
    interarrivals: Vec<f64>,
    /// Template for per-cell configs (policy/nodes/interarrival are
    /// overwritten per cell; node specs replicate `node_spec`).
    base: SchedConfig,
    node_spec: NodeSpec,
}

/// Results of a [`SchedGrid`] run, in [`SchedGrid::cells`] order.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedGridResults {
    pub cells: Vec<SchedCell>,
    pub reports: Vec<SchedReport>,
}

impl SchedGridResults {
    /// Report of one cell by axis indices.
    pub fn report(
        &self,
        policy_idx: usize,
        method_idx: usize,
        nodes_idx: usize,
        arrival_idx: usize,
    ) -> Option<&SchedReport> {
        self.cells
            .iter()
            .position(|c| {
                c.policy_idx == policy_idx
                    && c.method_idx == method_idx
                    && c.nodes_idx == nodes_idx
                    && c.arrival_idx == arrival_idx
            })
            .map(|i| &self.reports[i])
    }
}

impl<'a> SchedGrid<'a> {
    pub fn new(
        policies: Vec<ReservationPolicy>,
        methods: Vec<PredictorFactory>,
        traces: &'a [Trace],
        node_counts: Vec<usize>,
        interarrivals: Vec<f64>,
    ) -> Self {
        assert!(!policies.is_empty(), "grid needs at least one policy");
        assert!(!methods.is_empty(), "grid needs at least one predictor factory");
        assert!(!traces.is_empty(), "grid needs at least one trace");
        assert!(!node_counts.is_empty(), "grid needs at least one cluster size");
        assert!(!interarrivals.is_empty(), "grid needs at least one arrival rate");
        SchedGrid {
            policies,
            methods,
            traces,
            node_counts,
            interarrivals,
            base: SchedConfig::default(),
            node_spec: NodeSpec::paper_testbed(),
        }
    }

    /// Override the per-cell config template (seed, training fraction,
    /// arrival determinism, ...) and the replicated node spec.
    pub fn with_base(mut self, base: SchedConfig, node_spec: NodeSpec) -> Self {
        self.base = base;
        self.node_spec = node_spec;
        self
    }

    pub fn n_cells(&self) -> usize {
        self.policies.len() * self.methods.len() * self.node_counts.len() * self.interarrivals.len()
    }

    /// Cell enumeration in canonical order: policy-major, then method,
    /// then cluster size, then arrival rate.
    pub fn cells(&self) -> Vec<SchedCell> {
        let mut out = Vec::with_capacity(self.n_cells());
        for policy_idx in 0..self.policies.len() {
            for method_idx in 0..self.methods.len() {
                for nodes_idx in 0..self.node_counts.len() {
                    for arrival_idx in 0..self.interarrivals.len() {
                        out.push(SchedCell { policy_idx, method_idx, nodes_idx, arrival_idx });
                    }
                }
            }
        }
        out
    }

    fn cell_config(&self, c: SchedCell) -> SchedConfig {
        SchedConfig {
            policy: self.policies[c.policy_idx],
            nodes: vec![self.node_spec; self.node_counts[c.nodes_idx]],
            mean_interarrival: Seconds(self.interarrivals[c.arrival_idx]),
            ..self.base.clone()
        }
    }

    /// Execute every cell on `workers` threads; per-trace reports are
    /// merged in trace order within each cell.
    pub fn run(&self, workers: usize) -> SchedGridResults {
        let cells = self.cells();
        let reports = parallel_map(cells.len(), workers, |i| {
            let c = cells[i];
            let cfg = self.cell_config(c);
            SchedReport::merged(self.traces.iter().map(|trace| {
                let mut predictor = (self.methods[c.method_idx])();
                schedule_trace(trace, predictor.as_mut(), &cfg)
            }))
            .expect("at least one trace per cell")
        });
        SchedGridResults { cells, reports }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictors::default_config::DefaultConfigPredictor;
    use crate::predictors::ppm::PpmPredictor;
    use crate::trace::{TaskRun, UsageSeries};
    use crate::units::MemMiB;

    fn toy_trace(ty: &str, n: usize) -> Trace {
        let mut t = Trace::new();
        t.set_default(ty, MemMiB(2000.0));
        for i in 0..n {
            let input = 100.0 + 10.0 * i as f64;
            let peak = 10.0 + input;
            let samples: Vec<f64> = (0..10).map(|j| peak * (j + 1) as f64 / 10.0).collect();
            t.push(TaskRun {
                task_type: ty.to_string(),
                input_mib: input,
                runtime: Seconds(20.0),
                series: UsageSeries::new(2.0, samples),
                seq: i as u64,
            });
        }
        t.sort();
        t
    }

    fn toy_grid(traces: &[Trace]) -> SchedGrid<'_> {
        let methods: Vec<PredictorFactory> = vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new())),
            Box::new(|| Box::new(PpmPredictor::improved())),
        ];
        SchedGrid::new(
            vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise],
            methods,
            traces,
            vec![1, 2],
            vec![2.0, 8.0],
        )
    }

    #[test]
    fn cell_enumeration_is_policy_major() {
        let traces = vec![toy_trace("a/x", 20)];
        let grid = toy_grid(&traces);
        let cells = grid.cells();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2);
        assert_eq!(
            cells[0],
            SchedCell { policy_idx: 0, method_idx: 0, nodes_idx: 0, arrival_idx: 0 }
        );
        assert_eq!(
            cells[1],
            SchedCell { policy_idx: 0, method_idx: 0, nodes_idx: 0, arrival_idx: 1 }
        );
        assert_eq!(
            cells[15],
            SchedCell { policy_idx: 1, method_idx: 1, nodes_idx: 1, arrival_idx: 1 }
        );
    }

    #[test]
    fn grid_results_independent_of_worker_count() {
        let traces = vec![toy_trace("a/x", 25), toy_trace("b/y", 25)];
        let grid = toy_grid(&traces);
        let seq = grid.run(1);
        for workers in [2, 4] {
            assert_eq!(grid.run(workers), seq, "workers={workers} diverged");
        }
    }

    #[test]
    fn every_cell_schedules_every_task() {
        let traces = vec![toy_trace("a/x", 25), toy_trace("b/y", 25)];
        let grid = toy_grid(&traces);
        let res = grid.run(2);
        // training_frac 0.5 → 12 + 12 scored runs per cell (floor(25/2))
        for rep in &res.reports {
            assert_eq!(rep.submitted, 26);
            assert_eq!(rep.completed, 26);
        }
        // cell lookup by axes
        let r = res.report(1, 0, 1, 1).unwrap();
        assert_eq!(r.policy, "segment-wise");
        assert_eq!(r.n_nodes, 2);
        assert_eq!(r.mean_interarrival_s, 8.0);
        assert!(res.report(5, 0, 0, 0).is_none());
    }
}
