//! Cluster and resource-manager model.
//!
//! The paper's experiments ran on nodes with 128 GB of memory; the
//! resource manager (Slurm/Kubernetes in the paper's framing) admits a
//! task onto a node only if its requested memory fits, and the PPM
//! baseline's failure policy is "assign a node's maximum amount of
//! memory" — so node capacity is load-bearing for reproducing Fig. 7
//! (it is exactly what makes original PPM waste so much, §IV-E).
//!
//! Beyond the single-node evaluation setup, the cluster supports
//! **heterogeneous** node specs and **grow-able** reservations: the
//! discrete-event scheduler ([`crate::sched`]) places a task with its
//! first-segment allocation and grows the reservation in place at each
//! segment boundary of the k-Segments step function. Growing can fail
//! under contention — that is the scheduler's `grow_denials` signal.
//!
//! Nodes also have a **lifecycle** ([`NodeState`]): the failure-domain
//! scheduler takes nodes down (loss) and back up (rejoin), and the
//! autoscaler appends new nodes and retires idle ones. Node indexes
//! are stable forever — a vacated node stays in the roster as `Down`
//! or `Retired` so outstanding [`Reservation`] handles and per-node
//! ledgers never dangle; any reserve or grow against a non-`Up` node
//! is a denial, never a panic or a silent success.

mod profile;

pub use profile::TimeProfile;

use ksegments_core::units::MemMiB;

/// Static description of one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeSpec {
    pub mem: MemMiB,
    pub cores: u32,
}

impl NodeSpec {
    /// The paper's testbed: 128 GB DDR4, 16C/32T EPYC 7282.
    pub fn paper_testbed() -> NodeSpec {
        NodeSpec { mem: MemMiB::from_gib(128.0), cores: 32 }
    }
}

/// Lifecycle of a node in the roster. Indexes are stable: a node is
/// never removed from the cluster's vector, only marked `Down`
/// (failed, will rejoin) or `Retired` (autoscaled away, permanent).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Up,
    Down,
    Retired,
}

/// A node with live memory accounting.
#[derive(Debug, Clone)]
pub struct Node {
    pub spec: NodeSpec,
    reserved: f64, // MiB
    state: NodeState,
    /// Monotone counters for observability.
    pub admitted: u64,
    pub rejected: u64,
}

impl Node {
    pub fn new(spec: NodeSpec) -> Node {
        Node { spec, reserved: 0.0, state: NodeState::Up, admitted: 0, rejected: 0 }
    }

    pub fn state(&self) -> NodeState {
        self.state
    }

    pub fn is_up(&self) -> bool {
        self.state == NodeState::Up
    }

    pub fn free(&self) -> MemMiB {
        MemMiB((self.spec.mem.0 - self.reserved).max(0.0))
    }

    pub fn reserved(&self) -> MemMiB {
        MemMiB(self.reserved)
    }

    /// Try to reserve `mem`; returns false (and counts a rejection) if
    /// it does not fit. A non-`Up` node denies without counting a
    /// rejection — it was never really probed as capacity.
    pub fn reserve(&mut self, mem: MemMiB) -> bool {
        if !self.is_up() {
            return false;
        }
        if mem.0 <= 0.0 {
            return true;
        }
        if self.reserved + mem.0 <= self.spec.mem.0 + 1e-9 {
            self.reserved += mem.0;
            self.admitted += 1;
            true
        } else {
            self.rejected += 1;
            false
        }
    }

    /// Grow an existing reservation in place by `delta` MiB. Unlike
    /// [`Self::reserve`], a denied grow does not count as a rejection —
    /// it is a contention event the scheduler accounts separately.
    /// A grow against a vacated (down or retired) node is a denial,
    /// never a panic or a silent success.
    pub fn grow(&mut self, delta: MemMiB) -> bool {
        if !self.is_up() {
            return false;
        }
        if delta.0 <= 0.0 {
            return true;
        }
        if self.reserved + delta.0 <= self.spec.mem.0 + 1e-9 {
            self.reserved += delta.0;
            true
        } else {
            false
        }
    }

    pub fn release(&mut self, mem: MemMiB) {
        self.reserved = (self.reserved - mem.0).max(0.0);
    }
}

/// Reservation handle returned by the resource manager; releasing it
/// returns the memory to its node. `mem` tracks the *current* size,
/// including any grows applied since placement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reservation {
    pub node_idx: usize,
    pub mem: MemMiB,
}

/// A cluster with first-fit placement — the substrate the simulated
/// SWMS submits to. Nodes may be heterogeneous.
#[derive(Debug, Clone)]
pub struct Cluster {
    nodes: Vec<Node>,
    /// Placement attempts that failed on **every** node (the
    /// cluster-wide rejection the scheduler's queue-wait comes from).
    pub failed_placements: u64,
}

impl Cluster {
    /// Homogeneous cluster of `n_nodes` identical nodes.
    pub fn new(n_nodes: usize, spec: NodeSpec) -> Cluster {
        Self::heterogeneous((0..n_nodes).map(|_| spec).collect())
    }

    /// Cluster from an explicit (possibly heterogeneous) node list.
    pub fn heterogeneous(specs: Vec<NodeSpec>) -> Cluster {
        assert!(!specs.is_empty(), "cluster needs at least one node");
        Cluster { nodes: specs.into_iter().map(Node::new).collect(), failed_placements: 0 }
    }

    /// Single paper-testbed node (the evaluation setup).
    pub fn paper_testbed() -> Cluster {
        Cluster::new(1, NodeSpec::paper_testbed())
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Capacity of the largest node — what "assign the node's maximum
    /// memory" resolves to for the PPM failure policy, and the ceiling
    /// any placeable allocation must respect.
    pub fn node_max_mem(&self) -> MemMiB {
        self.nodes
            .iter()
            .map(|n| n.spec.mem)
            .fold(MemMiB::ZERO, MemMiB::max)
    }

    /// First-fit reservation across nodes.
    ///
    /// Every node probed before the successful one counts a rejection
    /// on that node (previously the free-memory pre-check short-
    /// circuited `Node::reserve`, making per-node rejections invisible);
    /// an attempt that fits nowhere additionally increments
    /// [`Self::failed_placements`].
    pub fn reserve(&mut self, mem: MemMiB) -> Option<Reservation> {
        for (i, node) in self.nodes.iter_mut().enumerate() {
            if !node.is_up() {
                continue; // vacated nodes are not capacity, not probes
            }
            if node.reserve(mem) {
                return Some(Reservation { node_idx: i, mem });
            }
        }
        self.failed_placements += 1;
        None
    }

    /// Targeted reservation on one node (the scheduler picks nodes via
    /// its time-indexed ledgers, then reserves here); rejections count
    /// on that node as with first-fit probing.
    pub fn reserve_on(&mut self, node_idx: usize, mem: MemMiB) -> Option<Reservation> {
        if self.nodes[node_idx].reserve(mem) {
            Some(Reservation { node_idx, mem })
        } else {
            None
        }
    }

    /// Mutable node access for scheduler-level accounting (e.g.
    /// counting a ledger rejection on the node that was probed).
    pub fn node_mut(&mut self, node_idx: usize) -> &mut Node {
        &mut self.nodes[node_idx]
    }

    /// Grow `r` in place by `delta`; false (reservation unchanged) if
    /// the node cannot supply the delta.
    pub fn grow(&mut self, r: &mut Reservation, delta: MemMiB) -> bool {
        if self.nodes[r.node_idx].grow(delta) {
            r.mem += delta;
            true
        } else {
            false
        }
    }

    pub fn release(&mut self, r: Reservation) {
        self.nodes[r.node_idx].release(r.mem);
    }

    /// Total free memory across nodes.
    pub fn total_free(&self) -> MemMiB {
        self.nodes.iter().map(|n| n.free()).sum()
    }

    /// Total reserved memory across nodes.
    pub fn total_reserved(&self) -> MemMiB {
        self.nodes.iter().map(|n| n.reserved()).sum()
    }

    /// Total memory capacity across nodes.
    pub fn total_capacity(&self) -> MemMiB {
        self.nodes.iter().map(|n| n.spec.mem).sum()
    }

    /// Sum of per-node rejection counters (probes that did not fit).
    pub fn total_rejections(&self) -> u64 {
        self.nodes.iter().map(|n| n.rejected).sum()
    }

    // ---- node lifecycle (failure domains & autoscaling) ----

    /// Append a new node to the roster, created `Down` (provisioning);
    /// it becomes capacity when [`Self::set_up`] fires after the
    /// autoscaler's lag. Returns the new node's stable index.
    pub fn add_node(&mut self, spec: NodeSpec) -> usize {
        let mut n = Node::new(spec);
        n.state = NodeState::Down;
        self.nodes.push(n);
        self.nodes.len() - 1
    }

    /// Mark a node lost. Its reservations are the caller's problem —
    /// the scheduler kills and requeues residents — but the node
    /// itself denies all placement and grow traffic until it rejoins.
    pub fn set_down(&mut self, node_idx: usize) {
        let n = &mut self.nodes[node_idx];
        if n.state == NodeState::Up {
            n.state = NodeState::Down;
        }
    }

    /// Bring a `Down` node back `Up`. A `Retired` node stays retired —
    /// a rejoin scheduled before retirement must not resurrect it.
    pub fn set_up(&mut self, node_idx: usize) {
        let n = &mut self.nodes[node_idx];
        if n.state == NodeState::Down {
            n.state = NodeState::Up;
        }
    }

    /// Permanently remove a node from service (autoscale-down). The
    /// caller must only retire idle nodes; this is debug-asserted.
    pub fn retire(&mut self, node_idx: usize) {
        let n = &mut self.nodes[node_idx];
        debug_assert!(n.reserved <= 1e-9, "retiring a node with live reservations");
        n.state = NodeState::Retired;
    }

    /// Number of nodes currently serving (state `Up`).
    pub fn n_up(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_up()).count()
    }

    /// Memory capacity of the nodes currently serving — the live
    /// denominator for utilization under failures and autoscaling.
    pub fn up_capacity(&self) -> MemMiB {
        self.nodes
            .iter()
            .filter(|n| n.is_up())
            .map(|n| n.spec.mem)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_is_128_gib() {
        let c = Cluster::paper_testbed();
        assert_eq!(c.node_max_mem(), MemMiB::from_gib(128.0));
        assert_eq!(c.n_nodes(), 1);
    }

    #[test]
    fn reserve_and_release() {
        let mut c = Cluster::new(1, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let r = c.reserve(MemMiB(600.0)).unwrap();
        assert_eq!(c.total_free(), MemMiB(400.0));
        assert!(c.reserve(MemMiB(500.0)).is_none());
        c.release(r);
        assert_eq!(c.total_free(), MemMiB(1000.0));
    }

    #[test]
    fn first_fit_spills_to_second_node() {
        let mut c = Cluster::new(2, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let _a = c.reserve(MemMiB(800.0)).unwrap();
        let b = c.reserve(MemMiB(800.0)).unwrap();
        assert_eq!(b.node_idx, 1);
    }

    #[test]
    fn rejection_counting() {
        let mut n = Node::new(NodeSpec { mem: MemMiB(100.0), cores: 1 });
        assert!(n.reserve(MemMiB(80.0)));
        assert!(!n.reserve(MemMiB(30.0)));
        assert_eq!(n.admitted, 1);
        assert_eq!(n.rejected, 1);
        assert_eq!(n.free(), MemMiB(20.0));
    }

    #[test]
    fn probed_nodes_count_rejections() {
        // Node 0 is full; a request that lands on node 1 must still
        // count a rejection on node 0 (this was the invisible-rejection
        // bug: the free() pre-check skipped Node::reserve entirely).
        let mut c = Cluster::new(2, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let _ = c.reserve(MemMiB(900.0)).unwrap();
        let r = c.reserve(MemMiB(500.0)).unwrap();
        assert_eq!(r.node_idx, 1);
        assert_eq!(c.nodes()[0].rejected, 1);
        assert_eq!(c.nodes()[1].rejected, 0);
        assert_eq!(c.total_rejections(), 1);
        assert_eq!(c.failed_placements, 0);
    }

    #[test]
    fn cluster_wide_failure_counts_every_node_and_the_attempt() {
        let mut c = Cluster::new(3, NodeSpec { mem: MemMiB(100.0), cores: 1 });
        assert!(c.reserve(MemMiB(500.0)).is_none());
        assert_eq!(c.total_rejections(), 3);
        assert_eq!(c.failed_placements, 1);
        assert!(c.reserve(MemMiB(500.0)).is_none());
        assert_eq!(c.total_rejections(), 6);
        assert_eq!(c.failed_placements, 2);
    }

    #[test]
    fn heterogeneous_nodes_and_first_fit() {
        let mut c = Cluster::heterogeneous(vec![
            NodeSpec { mem: MemMiB(100.0), cores: 1 },
            NodeSpec { mem: MemMiB(1000.0), cores: 8 },
        ]);
        assert_eq!(c.node_max_mem(), MemMiB(1000.0));
        assert_eq!(c.total_capacity(), MemMiB(1100.0));
        // does not fit node 0, lands on node 1 and counts the probe
        let r = c.reserve(MemMiB(400.0)).unwrap();
        assert_eq!(r.node_idx, 1);
        assert_eq!(c.nodes()[0].rejected, 1);
        assert_eq!(c.total_reserved(), MemMiB(400.0));
    }

    #[test]
    fn grow_reservation_in_place() {
        let mut c = Cluster::new(1, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let mut r = c.reserve(MemMiB(300.0)).unwrap();
        assert!(c.grow(&mut r, MemMiB(200.0)));
        assert_eq!(r.mem, MemMiB(500.0));
        assert_eq!(c.total_reserved(), MemMiB(500.0));
        // over capacity: denied, reservation unchanged, no rejection
        assert!(!c.grow(&mut r, MemMiB(600.0)));
        assert_eq!(r.mem, MemMiB(500.0));
        assert_eq!(c.total_rejections(), 0);
        // releasing the grown reservation returns everything
        c.release(r);
        assert_eq!(c.total_free(), MemMiB(1000.0));
    }

    #[test]
    fn reserve_on_targets_one_node() {
        let mut c = Cluster::new(2, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let r = c.reserve_on(1, MemMiB(600.0)).unwrap();
        assert_eq!(r.node_idx, 1);
        assert_eq!(c.nodes()[0].reserved(), MemMiB(0.0));
        // node 0 would fit, but a targeted reserve does not spill
        assert!(c.reserve_on(1, MemMiB(600.0)).is_none());
        assert_eq!(c.nodes()[1].rejected, 1);
        c.node_mut(1).rejected += 1; // scheduler-level ledger rejection
        assert_eq!(c.nodes()[1].rejected, 2);
    }

    #[test]
    fn release_never_goes_negative() {
        let mut n = Node::new(NodeSpec { mem: MemMiB(100.0), cores: 1 });
        n.release(MemMiB(50.0));
        assert_eq!(n.free(), MemMiB(100.0));
    }

    #[test]
    fn zero_reservation_is_free() {
        let mut n = Node::new(NodeSpec { mem: MemMiB(100.0), cores: 1 });
        assert!(n.reserve(MemMiB(0.0)));
        assert_eq!(n.reserved(), MemMiB(0.0));
        assert!(n.grow(MemMiB(0.0)));
    }

    #[test]
    fn grow_against_vacated_node_is_denied() {
        // Satellite bugfix: a step-function grow landing after its node
        // was lost (or autoscaled away) must be a denial — not a panic,
        // not a silent success that inflates a dead node's ledger.
        let mut c = Cluster::new(1, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let mut r = c.reserve(MemMiB(300.0)).unwrap();
        c.set_down(0);
        assert!(!c.grow(&mut r, MemMiB(1.0)), "grow on a down node must deny");
        assert_eq!(r.mem, MemMiB(300.0), "denied grow must leave the handle unchanged");
        assert_eq!(c.nodes()[0].reserved(), MemMiB(300.0));
        // releasing the stranded reservation still works (accounting
        // survives the node's death), and zero-delta grows deny too
        assert!(!c.grow(&mut r, MemMiB(0.0)));
        c.release(r);
        assert_eq!(c.nodes()[0].reserved(), MemMiB(0.0));
    }

    #[test]
    fn node_lifecycle_up_down_retired() {
        let mut c = Cluster::new(2, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        assert_eq!(c.n_up(), 2);
        assert_eq!(c.up_capacity(), MemMiB(2000.0));
        c.set_down(0);
        assert_eq!(c.nodes()[0].state(), NodeState::Down);
        assert_eq!(c.n_up(), 1);
        assert_eq!(c.up_capacity(), MemMiB(1000.0));
        // first-fit skips the down node without counting probes
        let r = c.reserve(MemMiB(500.0)).unwrap();
        assert_eq!(r.node_idx, 1);
        assert_eq!(c.nodes()[0].rejected, 0);
        // rejoin restores capacity at the same stable index
        c.set_up(0);
        assert!(c.nodes()[0].is_up());
        assert_eq!(c.up_capacity(), MemMiB(2000.0));
        // a retired node never rejoins, even if a rejoin fires later
        c.release(r);
        c.retire(1);
        assert_eq!(c.nodes()[1].state(), NodeState::Retired);
        c.set_up(1);
        assert_eq!(c.nodes()[1].state(), NodeState::Retired);
        assert_eq!(c.total_capacity(), MemMiB(2000.0), "roster indexes stay stable");
        assert_eq!(c.up_capacity(), MemMiB(1000.0));
    }

    #[test]
    fn autoscaled_node_joins_down_then_serves() {
        let mut c = Cluster::new(1, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let idx = c.add_node(NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        assert_eq!(idx, 1);
        // provisioning: not capacity yet
        assert_eq!(c.n_up(), 1);
        assert!(!c.nodes()[idx].is_up());
        assert!(c.reserve_on(idx, MemMiB(100.0)).is_none());
        assert_eq!(c.nodes()[idx].rejected, 0, "a provisioning node is not a probe");
        c.set_up(idx);
        assert_eq!(c.n_up(), 2);
        assert!(c.reserve_on(idx, MemMiB(100.0)).is_some());
    }
}
