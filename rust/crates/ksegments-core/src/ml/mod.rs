//! Native ML building blocks, mirroring the JAX/Pallas fit graph.
//!
//! The math here is a line-for-line f64 mirror of
//! `python/compile/kernels/{linfit,segpeaks}.py` and
//! `python/compile/model.py`: the centered masked linear regression,
//! the paper's change-point segmentation, and the full k-Segments fit
//! (coefficients + historical-error offsets).
//!
//! It serves three roles (DESIGN.md §2):
//! 1. differential-test oracle for the AOT XLA artifact
//!    (`rust/tests/integration_runtime.rs`),
//! 2. fallback fitter for shapes outside the artifact padding,
//! 3. regression backend for the pure-rust baselines (LR-Witt).

pub mod fitter;
pub mod linreg;
pub mod segmentation;
pub mod step_fn;

pub use fitter::{FitResult, KsegFitter, NativeFitter};
pub use linreg::{LinReg, ResidualStats};
pub use segmentation::{
    greedy_segment_bounds, index_bounds_to_time, seg_peaks, seg_peaks_with_bounds, segment_bounds,
};
pub use step_fn::StepFunction;
