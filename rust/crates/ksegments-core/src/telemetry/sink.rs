//! Trace sinks — structured run tracing in the Chrome `trace_event`
//! format (the JSON Perfetto and `chrome://tracing` load directly).
//!
//! Emitters build a [`TraceEvent`] per interesting occurrence and hand
//! it to a [`TraceSink`]. The default [`NullSink`] reports
//! `enabled() == false`, so hot paths gate on that and never allocate
//! an event when tracing is off. [`ChromeTraceSink`] streams events to
//! any writer through [`crate::util::json::JsonWriter`] without
//! buffering the run's event log in memory.

use std::fs::File;
use std::io::{self, BufWriter, Write};

use crate::util::json::JsonWriter;

/// One trace-event argument value (shows up under `args` in the UI).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Str(String),
}

/// One Chrome `trace_event` record.
///
/// * `ph` — the phase: `'b'`/`'e'` async span begin/end (matched by
///   `(cat, id)`), `'i'` instant.
/// * `ts_us` — timestamp in **microseconds**; scheduler/replay events
///   use simulated time, service events the wall clock (the only
///   place wall time is allowed — DESIGN.md §12).
/// * `pid`/`tid` — track ids; the scheduler maps nodes to `tid`, the
///   service maps shards.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    pub cat: &'static str,
    pub ph: char,
    pub ts_us: u64,
    pub pid: u32,
    pub tid: u32,
    /// Async span id (`'b'`/`'e'` phases); kept ≤ 48 bits so it stays
    /// exactly representable after a JSON f64 round-trip.
    pub id: Option<u64>,
    pub args: Vec<(&'static str, ArgValue)>,
}

impl TraceEvent {
    /// An instant event with no span id.
    pub fn instant(name: &str, cat: &'static str, ts_us: u64, tid: u32) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            cat,
            ph: 'i',
            ts_us,
            pid: 0,
            tid,
            id: None,
            args: Vec::new(),
        }
    }

    /// Serialize as one compact JSON object (no trailing newline).
    pub fn write_json<W: Write>(&self, w: W) -> io::Result<()> {
        let mut j = JsonWriter::new(w);
        j.begin_obj()?;
        j.field_str("name", &self.name)?;
        j.field_str("cat", self.cat)?;
        let mut ph = [0u8; 4];
        j.field_str("ph", self.ph.encode_utf8(&mut ph))?;
        j.field_u64("ts", self.ts_us)?;
        j.field_u64("pid", u64::from(self.pid))?;
        j.field_u64("tid", u64::from(self.tid))?;
        if let Some(id) = self.id {
            j.field_u64("id", id)?;
        }
        if !self.args.is_empty() {
            j.key("args")?;
            j.begin_obj()?;
            for (k, v) in &self.args {
                match v {
                    ArgValue::U64(n) => j.field_u64(k, *n)?,
                    ArgValue::F64(x) => j.field_f64(k, *x)?,
                    ArgValue::Str(s) => j.field_str(k, s)?,
                }
            }
            j.end_obj()?;
        }
        j.end_obj()
    }
}

/// Where trace events go. Implementations must be observation-only:
/// a sink never influences scheduling, prediction, or reports (the
/// bit-identical-with-tracing tests in `tests/telemetry.rs` enforce
/// this end to end).
pub trait TraceSink {
    /// Cheap gate emitters check before building a [`TraceEvent`].
    fn enabled(&self) -> bool {
        true
    }

    fn event(&mut self, ev: &TraceEvent);

    /// Write trailers, flush, and surface any deferred I/O error.
    fn finish(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// The default sink: drops everything. `enabled()` is `false`, so
/// emitters skip event construction entirely — the hot path stays
/// allocation-free when tracing is off.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }

    fn event(&mut self, _ev: &TraceEvent) {}
}

/// Collects events in memory — tests and per-shard collection.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VecSink {
    pub events: Vec<TraceEvent>,
}

impl VecSink {
    pub fn new() -> VecSink {
        VecSink::default()
    }
}

impl TraceSink for VecSink {
    fn event(&mut self, ev: &TraceEvent) {
        self.events.push(ev.clone());
    }
}

/// Streams `{"traceEvents":[...]}` to a writer, one event per line.
/// I/O errors are deferred: the first error disables further writes
/// and is surfaced by [`TraceSink::finish`].
pub struct ChromeTraceSink<W: Write> {
    w: W,
    n: u64,
    err: Option<io::Error>,
}

impl<W: Write> ChromeTraceSink<W> {
    pub fn new(mut w: W) -> ChromeTraceSink<W> {
        let err = w.write_all(b"{\"traceEvents\":[\n").err();
        ChromeTraceSink { w, n: 0, err }
    }

    /// Events successfully written so far.
    pub fn len(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

impl ChromeTraceSink<BufWriter<File>> {
    /// File-backed sink (what `--trace-out FILE` opens).
    pub fn create(path: &str) -> io::Result<ChromeTraceSink<BufWriter<File>>> {
        Ok(ChromeTraceSink::new(BufWriter::new(File::create(path)?)))
    }
}

impl<W: Write> TraceSink for ChromeTraceSink<W> {
    fn event(&mut self, ev: &TraceEvent) {
        if self.err.is_some() {
            return;
        }
        if self.n > 0 {
            if let Err(e) = self.w.write_all(b",\n") {
                self.err = Some(e);
                return;
            }
        }
        if let Err(e) = ev.write_json(&mut self.w) {
            self.err = Some(e);
            return;
        }
        self.n += 1;
    }

    fn finish(&mut self) -> io::Result<()> {
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        self.w.write_all(b"\n]}\n")?;
        self.w.flush()
    }
}

/// Render a finished event list as one Chrome trace JSON document.
pub fn chrome_trace_to_string(events: &[TraceEvent]) -> String {
    let mut sink = ChromeTraceSink::new(Vec::new());
    for ev in events {
        sink.event(ev);
    }
    sink.finish().expect("in-memory trace write cannot fail");
    String::from_utf8(sink.w).expect("trace JSON is UTF-8")
}

/// Write a finished event list to `path` as Chrome trace JSON.
pub fn write_chrome_trace(path: &str, events: &[TraceEvent]) -> io::Result<()> {
    let mut sink = ChromeTraceSink::create(path)?;
    for ev in events {
        sink.event(ev);
    }
    sink.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn ev(name: &str, ph: char, ts: u64) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            cat: "task",
            ph,
            ts_us: ts,
            pid: 0,
            tid: 3,
            id: Some(42),
            args: vec![("seq", ArgValue::U64(7)), ("mem_mib", ArgValue::F64(512.5))],
        }
    }

    #[test]
    fn null_sink_is_disabled() {
        let mut s = NullSink;
        assert!(!s.enabled());
        s.event(&ev("a", 'i', 1));
        assert!(s.finish().is_ok());
    }

    #[test]
    fn vec_sink_collects_in_order() {
        let mut s = VecSink::new();
        assert!(s.enabled());
        s.event(&ev("a", 'b', 1));
        s.event(&ev("b", 'e', 2));
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.events[0].name, "a");
        assert_eq!(s.events[1].ph, 'e');
    }

    #[test]
    fn chrome_trace_parses_back() {
        let events =
            vec![ev("align \"x\"", 'b', 10), ev("align \"x\"", 'e', 250), ev("oom", 'i', 99)];
        let doc = chrome_trace_to_string(&events);
        let v = Json::parse(&doc).expect("valid JSON");
        let arr = v.get("traceEvents").as_arr().expect("traceEvents array");
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("name").as_str(), Some("align \"x\""));
        assert_eq!(arr[0].get("ph").as_str(), Some("b"));
        assert_eq!(arr[0].get("id").as_u64(), Some(42));
        assert_eq!(arr[1].get("ts").as_u64(), Some(250));
        assert_eq!(arr[2].get("args").get("mem_mib").as_f64(), Some(512.5));
        assert_eq!(arr[2].get("tid").as_u64(), Some(3));
    }

    #[test]
    fn empty_trace_is_valid_json() {
        let doc = chrome_trace_to_string(&[]);
        let v = Json::parse(&doc).expect("valid JSON");
        assert_eq!(v.get("traceEvents").as_arr().map(<[Json]>::len), Some(0));
    }

    #[test]
    fn event_without_args_or_id_omits_them() {
        let e = TraceEvent::instant("x", "node", 5, 1);
        let mut buf = Vec::new();
        e.write_json(&mut buf).unwrap();
        let v = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        assert_eq!(v.get("id"), &Json::Null);
        assert_eq!(v.get("args"), &Json::Null);
        assert_eq!(v.get("cat").as_str(), Some("node"));
    }
}
