//! Protocol conformance suite for the TCP prediction service
//! (`ksegments::net`), over real loopback sockets throughout:
//!
//! * every request kind round-trips with exact counters;
//! * malformed frames — truncated length prefix, truncated payload,
//!   oversized frame, invalid UTF-8, bad JSON, unknown method, missing
//!   fields — each get a typed error and never kill the server or a
//!   sibling connection;
//! * pipelined requests come back in request order;
//! * a multi-connection TCP replay of the Nextflow fixture is
//!   bit-identical to the in-process `ServiceHandle::replay_source`;
//! * the live `stats` frame snapshots a running server and is exact
//!   after drain;
//! * drain mid-stream + checkpoint warm restart reproduces the
//!   uninterrupted server's predictions and checkpoint byte-for-byte.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use ksegments::bench_harness::{make_method, FitterChoice};
use ksegments::coordinator::{ServiceStats, ShardedPredictionService};
use ksegments::ingest::{materialize, Checkpoint, NextflowDirSource, TraceSource};
use ksegments::net::{
    parse_response, read_frame, run_loadgen, LoadgenConfig, NetClient, NetServer, NetServerConfig,
    MAX_FRAME_DEFAULT,
};
use ksegments::predictors::{Allocation, FailureInfo, MemoryPredictor};
use ksegments::trace::{TaskRun, UsageSeries};
use ksegments::units::{MemMiB, Seconds};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/nextflow")
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ksegments_test_net_protocol");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn kseg(_shard: usize) -> Box<dyn MemoryPredictor> {
    make_method("ksegments-selective", FitterChoice::Native).expect("roster key")
}

fn spawn_server(shards: usize, cfg: NetServerConfig) -> NetServer {
    let svc = ShardedPredictionService::spawn(shards, kseg);
    NetServer::spawn("127.0.0.1:0", svc, cfg).expect("binding loopback server")
}

fn mk_run(ty: &str, input: f64, peak: f64, seq: u64) -> TaskRun {
    let samples: Vec<f64> = (0..8).map(|j| peak * (j + 1) as f64 / 8.0).collect();
    TaskRun {
        task_type: ty.into(),
        input_mib: input,
        runtime: Seconds(16.0),
        series: UsageSeries::new(2.0, samples),
        seq,
    }
}

/// Counters with the scheduling-dependent wakeups masked out.
fn sans_wakeups(s: &ServiceStats) -> (u64, u64, u64) {
    (s.predictions, s.completions, s.failures)
}

/// Write one length-prefixed frame with an arbitrary payload.
fn raw_send(s: &mut TcpStream, payload: &[u8]) {
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload);
    s.write_all(&buf).unwrap();
}

/// Read the next frame and require a typed error; returns (id, code).
fn raw_recv_err(s: &mut TcpStream) -> (Option<u64>, String) {
    let payload = read_frame(s, MAX_FRAME_DEFAULT)
        .expect("reading error frame")
        .expect("server closed before answering");
    let resp = parse_response(&payload).expect("parsing error frame");
    assert!(!resp.ok, "expected a typed error, got ok: {payload:?}");
    let (code, _msg) = resp.error.expect("error frame without an error body");
    (resp.id, code)
}

#[test]
fn every_request_kind_round_trips_over_loopback() {
    let server = spawn_server(2, NetServerConfig::default());
    let addr = server.local_addr().to_string();
    let mut c = NetClient::connect(&addr).unwrap();

    c.prime("wire/A", MemMiB(2048.0)).unwrap();
    let cold = c.predict("wire/A", 100.0).unwrap();
    assert!(!cold.is_dynamic(), "untrained predict should fall back to the static default");

    for i in 0..12u64 {
        c.complete(&mk_run("wire/A", 100.0 + i as f64, 200.0 + 10.0 * i as f64, i)).unwrap();
    }
    // per-type FIFO: this predict is answered after all 12 completions
    let warm = c.predict("wire/A", 150.0).unwrap();
    assert!(warm.is_dynamic(), "trained predict stayed static: {warm:?}");

    let failed = Allocation::Static(MemMiB(100.0));
    let info = FailureInfo::oom(1.0, 400.0, 1);
    let next = c.report_failure("wire/A", 150.0, &failed, &info).unwrap();
    assert!(next.max_value() > 0.0);

    // batched replay through the server's chunked replay path
    let runs: Vec<TaskRun> =
        (0..5).map(|i| mk_run("wire/B", 10.0 * i as f64, 100.0, i as u64)).collect();
    assert_eq!(c.replay(&runs).unwrap(), 5);

    let (total, per_shard) = c.stats().unwrap();
    assert_eq!(per_shard.len(), 2);
    assert_eq!(total, ServiceStats::aggregated(&per_shard));
    assert_eq!(total.predictions, 2 + 5, "2 direct + 5 replay-internal predicts");
    assert_eq!(total.completions, 12 + 5);
    assert_eq!(total.failures, 1);

    c.shutdown_server().unwrap();
    let report = server.wait().unwrap();
    assert_eq!(sans_wakeups(&report.total()), (7, 17, 1));
    assert_eq!(report.net.replayed_runs, 5);
    assert_eq!(report.net.errors, 0);
}

#[test]
fn malformed_frames_get_typed_errors_without_collateral() {
    let server = spawn_server(2, NetServerConfig::default());
    let addr = server.local_addr().to_string();
    // a well-behaved bystander connection, open across every abuse case
    let mut bystander = NetClient::connect(&addr).unwrap();
    bystander.prime("mal/ok", MemMiB(512.0)).unwrap();

    // recoverable malformations: typed error, connection keeps serving
    let mut s = TcpStream::connect(&addr).unwrap();
    raw_send(&mut s, &[0xff, 0xfe, 0x01]);
    assert_eq!(raw_recv_err(&mut s), (None, "invalid_utf8".into()));
    raw_send(&mut s, b"{\"method\":");
    assert_eq!(raw_recv_err(&mut s), (None, "bad_json".into()));
    raw_send(&mut s, b"{\"method\":\"teleport\",\"id\":7}");
    assert_eq!(raw_recv_err(&mut s), (Some(7), "unknown_method".into()));
    raw_send(&mut s, b"{\"method\":\"predict\",\"id\":8}");
    assert_eq!(raw_recv_err(&mut s), (Some(8), "bad_request".into()));
    raw_send(&mut s, b"{\"id\":9}");
    assert_eq!(raw_recv_err(&mut s), (Some(9), "bad_request".into()));
    // ... and a valid request on the same connection still works
    raw_send(&mut s, b"{\"method\":\"stats\",\"id\":10}");
    let payload = read_frame(&mut s, MAX_FRAME_DEFAULT).unwrap().unwrap();
    let resp = parse_response(&payload).unwrap();
    assert!(resp.ok, "recoverable errors must not poison the connection");
    assert_eq!(resp.id, Some(10));
    drop(s);

    // oversized frame: the length prefix alone condemns it; typed
    // error, then the server hangs up — framing is unrecoverable
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&(MAX_FRAME_DEFAULT as u32 + 1).to_be_bytes()).unwrap();
    assert_eq!(raw_recv_err(&mut s), (None, "frame_too_large".into()));
    assert!(read_frame(&mut s, MAX_FRAME_DEFAULT).unwrap().is_none(), "expected close");

    // truncated length prefix: peer closes after 2 of 4 prefix bytes
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&[0x00, 0x00]).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    assert_eq!(raw_recv_err(&mut s), (None, "truncated_frame".into()));
    assert!(read_frame(&mut s, MAX_FRAME_DEFAULT).unwrap().is_none(), "expected close");

    // truncated payload: 10 bytes declared, 3 delivered
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&10u32.to_be_bytes()).unwrap();
    s.write_all(b"abc").unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    assert_eq!(raw_recv_err(&mut s), (None, "truncated_frame".into()));

    // the bystander never noticed any of it
    let alloc = bystander.predict("mal/ok", 1.0).unwrap();
    assert!(alloc.max_value() > 0.0);
    bystander.shutdown_server().unwrap();
    let report = server.wait().unwrap();
    assert_eq!(report.net.errors, 8, "5 parse errors + oversized + 2 truncations");
    assert_eq!(report.total().predictions, 1, "abuse must not reach the model threads");
}

#[test]
fn pipelined_requests_come_back_in_order() {
    let server = spawn_server(3, NetServerConfig::default());
    let addr = server.local_addr().to_string();
    let mut c = NetClient::connect(&addr).unwrap();
    c.prime("pipe/A", MemMiB(1024.0)).unwrap();

    const N: usize = 64;
    let mut ids = Vec::with_capacity(N);
    for i in 0..N {
        let fields = vec![("task_type", "pipe/A".into()), ("input_mib", (i as f64).into())];
        ids.push(c.send_request("predict", fields).unwrap());
    }
    for (i, id) in ids.into_iter().enumerate() {
        let resp = c.recv_response().unwrap();
        assert_eq!(resp.id, Some(id), "response #{i} out of order");
        assert!(resp.ok);
        assert!(resp.alloc.is_some(), "predict response #{i} without an allocation");
    }

    c.shutdown_server().unwrap();
    let report = server.wait().unwrap();
    assert_eq!(report.total().predictions, N as u64);
}

/// Acceptance criterion: replaying the fixture over TCP ends in the
/// same final counters (per shard, wakeups aside) and the same trained
/// per-type predictions as the in-process replay — at 1 connection and
/// at 8.
#[test]
fn tcp_replay_is_bit_identical_to_in_process_replay() {
    const TYPES: [&str; 3] = ["ALIGN", "FILTER", "QUANT"];

    // in-process baseline
    let svc = ShardedPredictionService::spawn(4, kseg);
    let h = svc.handle();
    let mut src = NextflowDirSource::open(&fixture_dir()).unwrap();
    let fed = h.replay_source(&mut src, 5).unwrap();
    assert_eq!(fed, 14);
    let base_shards = h.per_shard_stats();
    let base_preds: Vec<Allocation> =
        TYPES.iter().map(|ty| h.predict(ty, 150.0)).collect();
    svc.shutdown();

    for conns in [1usize, 8] {
        let server = spawn_server(4, NetServerConfig::default());
        let addr = server.local_addr().to_string();
        let mut src = NextflowDirSource::open(&fixture_dir()).unwrap();
        let cfg = LoadgenConfig { connections: conns, ..LoadgenConfig::default() };
        let report = run_loadgen(&addr, &mut src, &cfg).unwrap();
        assert_eq!(report.runs_fed, 14, "connections={conns}");
        assert_eq!(report.errors, 0, "connections={conns}");
        assert_eq!(report.connections, conns);

        assert_eq!(report.per_shard.len(), base_shards.len());
        for (s, (tcp, base)) in report.per_shard.iter().zip(&base_shards).enumerate() {
            assert_eq!(
                sans_wakeups(tcp),
                sans_wakeups(base),
                "shard {s} diverged at connections={conns}"
            );
        }
        // trained model state is identical too, not just the counters
        let mut probe = NetClient::connect(&addr).unwrap();
        for (ty, base_alloc) in TYPES.iter().zip(&base_preds) {
            let got = probe.predict(ty, 150.0).unwrap();
            assert_eq!(&got, base_alloc, "{ty} diverged at connections={conns}");
        }
        probe.shutdown_server().unwrap();
        server.wait().unwrap();
    }
}

#[test]
fn tcp_stats_snapshot_while_running_and_exact_after_drain() {
    const RUNS: u64 = 300;
    let server = spawn_server(2, NetServerConfig::default());
    let addr = server.local_addr().to_string();

    let feeder = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = NetClient::connect(&addr).unwrap();
            c.prime("live/A", MemMiB(256.0)).unwrap();
            for i in 0..RUNS {
                c.complete(&mk_run("live/A", i as f64, 50.0, i)).unwrap();
            }
        })
    };

    // live snapshots from a second connection while traffic flows
    let mut watcher = NetClient::connect(&addr).unwrap();
    let mut snapshots = Vec::new();
    for _ in 0..50 {
        let (total, _) = watcher.stats().unwrap();
        snapshots.push(total.completions);
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(
        snapshots.windows(2).all(|w| w[0] <= w[1]),
        "live completions went backwards: {snapshots:?}"
    );

    // feeder drained: every completion was acked, so per-shard FIFO
    // makes the next stats snapshot exact
    feeder.join().expect("feeder panicked");
    let (total, per_shard) = watcher.stats().unwrap();
    assert_eq!(total.completions, RUNS);
    assert_eq!(total, ServiceStats::aggregated(&per_shard));

    watcher.shutdown_server().unwrap();
    assert_eq!(server.wait().unwrap().total().completions, RUNS);
}

/// Acceptance criterion: serve half the fixture, drain with a
/// checkpoint, warm-restart from it and serve the rest — predictions
/// and the final checkpoint are byte-identical to one uninterrupted
/// server lifetime.
#[test]
fn drain_plus_checkpoint_warm_restart_is_byte_identical() {
    let mut src = NextflowDirSource::open(&fixture_dir()).unwrap();
    let defaults = src.defaults();
    let trace = materialize(&mut src).unwrap();
    let ordered: Vec<TaskRun> = trace.all_runs_ordered().into_iter().cloned().collect();
    assert_eq!(ordered.len(), 14);
    let types: Vec<String> = defaults.iter().map(|(ty, _)| ty.clone()).collect();

    let ck_full = tmp("ck_full.jsonl");
    let ck_half = tmp("ck_half.jsonl");
    let ck_resumed = tmp("ck_resumed.jsonl");

    // uninterrupted reference: all 14 runs in one server lifetime
    let cfg =
        NetServerConfig { checkpoint_out: Some(ck_full.clone()), ..NetServerConfig::default() };
    let server = spawn_server(4, cfg);
    let addr = server.local_addr().to_string();
    let mut c = NetClient::connect(&addr).unwrap();
    for (ty, mem) in &defaults {
        c.prime(ty, *mem).unwrap();
    }
    for run in &ordered {
        c.complete(run).unwrap();
    }
    let base: Vec<Allocation> = types.iter().map(|ty| c.predict(ty, 150.0).unwrap()).collect();
    c.shutdown_server().unwrap();
    server.wait().unwrap();

    // first half, then a graceful drain mid-stream
    let cfg =
        NetServerConfig { checkpoint_out: Some(ck_half.clone()), ..NetServerConfig::default() };
    let server = spawn_server(4, cfg);
    let addr = server.local_addr().to_string();
    let mut c = NetClient::connect(&addr).unwrap();
    for (ty, mem) in &defaults {
        c.prime(ty, *mem).unwrap();
    }
    for run in &ordered[..7] {
        c.complete(run).unwrap();
    }
    c.shutdown_server().unwrap();
    server.wait().unwrap();

    // warm restart from the mid-stream checkpoint, serve the rest
    let cfg = NetServerConfig {
        restore: Some(Checkpoint::load(&ck_half).unwrap()),
        checkpoint_out: Some(ck_resumed.clone()),
        ..NetServerConfig::default()
    };
    let server = spawn_server(4, cfg);
    let addr = server.local_addr().to_string();
    let mut c = NetClient::connect(&addr).unwrap();
    for run in &ordered[7..] {
        c.complete(run).unwrap();
    }
    let resumed: Vec<Allocation> =
        types.iter().map(|ty| c.predict(ty, 150.0).unwrap()).collect();
    // restored history never recounts: stats cover new traffic only
    let (total, _) = c.stats().unwrap();
    assert_eq!(total.completions, 7);
    c.shutdown_server().unwrap();
    server.wait().unwrap();

    assert_eq!(resumed, base, "post-restart predictions diverged from uninterrupted");
    let full = std::fs::read(&ck_full).unwrap();
    let half = std::fs::read(&ck_half).unwrap();
    let resumed_bytes = std::fs::read(&ck_resumed).unwrap();
    assert_ne!(full, half, "the mid-stream checkpoint should be a strict prefix of history");
    assert_eq!(resumed_bytes, full, "resumed checkpoint differs from uninterrupted");
}
