//! Cross-cutting observability: run tracing, metrics, provenance.
//!
//! Three pillars (DESIGN.md §12):
//!
//! * **Run tracing** ([`sink`]) — [`TraceSink`] + a streaming Chrome
//!   `trace_event`/Perfetto JSON writer. The scheduler, the replay
//!   engine and the sharded prediction service emit begin/end/instant
//!   spans; `schedule --trace-out run.json` opens directly in
//!   <https://ui.perfetto.dev> or `chrome://tracing`.
//! * **Metrics** ([`registry`]) — counters/gauges/fixed-bucket
//!   histograms with Prometheus text exposition and a JSON snapshot
//!   (`--metrics-out FILE`).
//! * **Provenance** ([`provenance`]) — optional per-decision JSONL
//!   audit records (`--provenance-out FILE`).
//!
//! The golden rule: telemetry **observes, never influences**. Enabling
//! any sink leaves every `SchedReport`/`MethodReport` bit-identical to
//! the untraced run (`tests/telemetry.rs` pins this), and scheduler/
//! replay events are stamped with **simulated** time — the wall clock
//! appears only in bench snapshots and service-thread spans.

pub mod provenance;
pub mod registry;
pub mod sink;

pub use provenance::{DecisionDetail, ProvenanceLog};
pub use registry::{Histogram, Registry};
pub use sink::{
    chrome_trace_to_string, write_chrome_trace, ArgValue, ChromeTraceSink, NullSink, TraceEvent,
    TraceSink, VecSink,
};

use std::io;

use crate::engine::events::EngineEvent;

/// The telemetry attachments of one scheduler run: a trace sink
/// (default [`NullSink`]) plus an optional provenance log. Owned by
/// the run so the engine needs no lifetime plumbing.
pub struct RunTelemetry {
    pub trace: Box<dyn TraceSink>,
    pub provenance: Option<ProvenanceLog>,
}

impl RunTelemetry {
    /// Everything off — the allocation-free default.
    pub fn off() -> RunTelemetry {
        RunTelemetry { trace: Box::new(NullSink), provenance: None }
    }

    pub fn with_trace(sink: Box<dyn TraceSink>) -> RunTelemetry {
        RunTelemetry { trace: sink, provenance: None }
    }

    /// Close both attachments, surfacing the first deferred I/O error.
    pub fn finish(&mut self) -> io::Result<()> {
        self.trace.finish()?;
        if let Some(p) = &mut self.provenance {
            p.finish()?;
        }
        Ok(())
    }
}

impl Default for RunTelemetry {
    fn default() -> Self {
        RunTelemetry::off()
    }
}

/// FNV-1a 64-bit hash (same constants as the coordinator's shard
/// router).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Async-span id for one task run: type hash mixed with the run seq,
/// masked to 48 bits so a JSON f64 round-trip is exact.
pub fn span_id(task_type: &str, seq: u64) -> u64 {
    (fnv1a64(task_type.as_bytes()) ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15)) & 0xffff_ffff_ffff
}

/// Simulated seconds → trace microseconds.
pub fn sim_ts_us(now_s: f64) -> u64 {
    (now_s * 1e6).round().max(0.0) as u64
}

/// Map one engine event to its trace representation. Task lifecycles
/// become async spans — `'b'` at placement, `'e'` at completion or
/// kill (matched by `(cat, id)`) — and everything else becomes an
/// instant, so OOM storms, preemption cascades, node churn and DAG
/// gating all show up as timeline tracks per node (`tid`).
pub fn trace_engine_event(sink: &mut dyn TraceSink, ev: &EngineEvent, now_s: f64) {
    let ts = sim_ts_us(now_s);
    match ev {
        EngineEvent::Submitted { task_type, seq, requested } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "arrival",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("requested_mib", ArgValue::F64(requested.0)),
                ],
            });
        }
        EngineEvent::Queued { task_type, seq, requested } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "queue",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("requested_mib", ArgValue::F64(requested.0)),
                ],
            });
        }
        EngineEvent::Failed { task_type, seq, attempt, used, allocated, .. } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "kill",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("attempt", ArgValue::U64(u64::from(*attempt))),
                    ("used_mib", ArgValue::F64(used.0)),
                    ("allocated_mib", ArgValue::F64(allocated.0)),
                ],
            });
        }
        EngineEvent::Placed { task_type, seq, node, reserved, .. } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "task",
                ph: 'b',
                ts_us: ts,
                pid: 0,
                tid: *node as u32,
                id: Some(span_id(task_type, *seq)),
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("node", ArgValue::U64(*node as u64)),
                    ("reserved_mib", ArgValue::F64(reserved.0)),
                ],
            });
        }
        EngineEvent::Completed { task_type, seq, attempts } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "task",
                ph: 'e',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: Some(span_id(task_type, *seq)),
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("attempts", ArgValue::U64(u64::from(*attempts))),
                ],
            });
        }
        EngineEvent::OomKilled { task_type, seq, attempt, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *attempt, "oom-kill", 0);
        }
        EngineEvent::GrowDenied { task_type, seq, segment, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *segment as u32, "grow-denied", 0);
        }
        EngineEvent::NodeLost { task_type, seq, attempt, node, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *attempt, "node-lost-kill", *node as u32);
        }
        EngineEvent::Preempted { task_type, seq, attempt, node, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *attempt, "preempt-kill", *node as u32);
        }
        EngineEvent::Released { task_type, seq, instance, .. } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "dag",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("instance", ArgValue::U64(*instance)),
                ],
            });
        }
        EngineEvent::WorkflowDone { workflow, instance, tasks, makespan_s, .. } => {
            sink.event(&TraceEvent {
                name: workflow.clone(),
                cat: "dag",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("instance", ArgValue::U64(*instance)),
                    ("tasks", ArgValue::U64(u64::from(*tasks))),
                    ("makespan_s", ArgValue::F64(*makespan_s)),
                ],
            });
        }
        EngineEvent::NodeFailed { node, killed, .. } => {
            let mut e = TraceEvent::instant("node-failed", "node", ts, *node as u32);
            e.args = vec![("killed", ArgValue::U64(u64::from(*killed)))];
            sink.event(&e);
        }
        EngineEvent::NodeJoined { node, .. } => {
            sink.event(&TraceEvent::instant("node-joined", "node", ts, *node as u32));
        }
        EngineEvent::NodeRetired { node, .. } => {
            sink.event(&TraceEvent::instant("node-retired", "node", ts, *node as u32));
        }
    }
}

/// A killed attempt: close its `'b'` span and drop a kill marker.
fn end_span_with_kill(
    sink: &mut dyn TraceSink,
    ts: u64,
    task_type: &str,
    seq: u64,
    detail: u32,
    kill_name: &'static str,
    tid: u32,
) {
    sink.event(&TraceEvent {
        name: task_type.to_string(),
        cat: "task",
        ph: 'e',
        ts_us: ts,
        pid: 0,
        tid,
        id: Some(span_id(task_type, seq)),
        args: Vec::new(),
    });
    sink.event(&TraceEvent {
        name: kill_name.to_string(),
        cat: "kill",
        ph: 'i',
        ts_us: ts,
        pid: 0,
        tid,
        id: None,
        args: vec![("seq", ArgValue::U64(seq)), ("detail", ArgValue::U64(u64::from(detail)))],
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::MemMiB;

    #[test]
    fn span_ids_are_stable_and_distinct() {
        assert_eq!(span_id("a", 1), span_id("a", 1));
        assert_ne!(span_id("a", 1), span_id("a", 2));
        assert_ne!(span_id("a", 1), span_id("b", 1));
        assert!(span_id("wf/align", u64::MAX) <= 0xffff_ffff_ffff);
    }

    #[test]
    fn sim_time_maps_to_microseconds() {
        assert_eq!(sim_ts_us(0.0), 0);
        assert_eq!(sim_ts_us(1.5), 1_500_000);
        assert_eq!(sim_ts_us(-1.0), 0, "clamped, never underflows");
    }

    #[test]
    fn placement_and_completion_form_a_span() {
        let mut sink = VecSink::new();
        let placed = EngineEvent::Placed {
            task_type: "t".into(),
            seq: 9,
            node: 2,
            time_s: 4.0,
            reserved: MemMiB(512.0),
        };
        let done = EngineEvent::Completed { task_type: "t".into(), seq: 9, attempts: 1 };
        trace_engine_event(&mut sink, &placed, 4.0);
        trace_engine_event(&mut sink, &done, 9.0);
        assert_eq!(sink.events.len(), 2);
        let (b, e) = (&sink.events[0], &sink.events[1]);
        assert_eq!(b.ph, 'b');
        assert_eq!(e.ph, 'e');
        assert_eq!(b.id, e.id, "begin/end must share the span id");
        assert_eq!(b.cat, e.cat);
        assert_eq!(b.tid, 2, "placement is tracked on its node");
        assert!(e.ts_us > b.ts_us);
    }

    #[test]
    fn kills_end_the_span_and_mark_the_cause() {
        let mut sink = VecSink::new();
        let oom =
            EngineEvent::OomKilled { task_type: "t".into(), seq: 3, attempt: 1, time_s: 8.0 };
        trace_engine_event(&mut sink, &oom, 8.0);
        assert_eq!(sink.events.len(), 2);
        assert_eq!(sink.events[0].ph, 'e');
        assert_eq!(sink.events[0].id, Some(span_id("t", 3)));
        assert_eq!(sink.events[1].ph, 'i');
        assert_eq!(sink.events[1].name, "oom-kill");
        assert_eq!(sink.events[1].cat, "kill");
    }

    #[test]
    fn every_variant_maps_to_at_least_one_event() {
        let variants: Vec<EngineEvent> = vec![
            EngineEvent::Submitted { task_type: "t".into(), seq: 0, requested: MemMiB(1.0) },
            EngineEvent::Queued { task_type: "t".into(), seq: 0, requested: MemMiB(1.0) },
            EngineEvent::Failed {
                task_type: "t".into(),
                seq: 0,
                attempt: 1,
                time_s: 1.0,
                used: MemMiB(2.0),
                allocated: MemMiB(1.0),
            },
            EngineEvent::Completed { task_type: "t".into(), seq: 0, attempts: 1 },
            EngineEvent::Placed {
                task_type: "t".into(),
                seq: 0,
                node: 0,
                time_s: 1.0,
                reserved: MemMiB(1.0),
            },
            EngineEvent::OomKilled { task_type: "t".into(), seq: 0, attempt: 1, time_s: 1.0 },
            EngineEvent::GrowDenied { task_type: "t".into(), seq: 0, segment: 1, time_s: 1.0 },
            EngineEvent::Released { task_type: "t".into(), seq: 0, instance: 0, time_s: 1.0 },
            EngineEvent::WorkflowDone {
                workflow: "w".into(),
                instance: 0,
                tasks: 3,
                time_s: 9.0,
                makespan_s: 9.0,
            },
            EngineEvent::NodeLost {
                task_type: "t".into(),
                seq: 0,
                attempt: 1,
                node: 0,
                time_s: 1.0,
            },
            EngineEvent::Preempted {
                task_type: "t".into(),
                seq: 0,
                attempt: 1,
                node: 0,
                time_s: 1.0,
            },
            EngineEvent::NodeFailed { node: 0, killed: 1, time_s: 1.0 },
            EngineEvent::NodeJoined { node: 0, time_s: 1.0 },
            EngineEvent::NodeRetired { node: 0, time_s: 1.0 },
        ];
        for ev in &variants {
            let mut sink = VecSink::new();
            trace_engine_event(&mut sink, ev, 1.0);
            assert!(!sink.events.is_empty(), "{ev:?} produced no trace event");
        }
    }

    #[test]
    fn run_telemetry_off_is_disabled_and_finishes() {
        let mut tel = RunTelemetry::off();
        assert!(!tel.trace.enabled());
        assert!(tel.provenance.is_none());
        tel.finish().unwrap();
        let def = RunTelemetry::default();
        assert!(!def.trace.enabled());
    }
}
