//! Cluster packing: what segment-wise reservations buy a cluster.
//!
//! Schedules the same eager-like task stream onto a small cluster
//! twice — once reserving each task's predicted peak for its whole
//! runtime (what a Slurm `--mem` flag does), once reserving the
//! k-Segments step function with time-indexed admission — and compares
//! makespan, queue waits, co-location and wastage.
//!
//! Run: `cargo run --release --example cluster_packing`

use ksegments::cluster::NodeSpec;
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::sched::{schedule_trace, ReservationPolicy, SchedConfig};
use ksegments::units::{MemMiB, Seconds};
use ksegments::workload::{eager_workflow, generate_workflow_trace};

fn main() {
    let trace = generate_workflow_trace(&eager_workflow(), 42);
    println!(
        "workload: {} runs over {} task types; cluster: 2 x 32 GiB nodes, \
         one task arriving every ~5 s\n",
        trace.n_runs(),
        trace.n_types()
    );

    let mut reports = Vec::new();
    for policy in [ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise] {
        let cfg = SchedConfig {
            policy,
            nodes: vec![NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 }; 2],
            mean_interarrival: Seconds(5.0),
            seed: 42,
            training_frac: 0.5,
            ..SchedConfig::default()
        };
        // fresh predictor per policy: both runs learn from scratch
        let mut predictor = KSegmentsPredictor::native(4, RetryStrategy::Selective);
        let rep = schedule_trace(&trace, &mut predictor, &cfg);
        println!("{}", rep.summary());
        reports.push(rep);
    }

    let (stat, segw) = (&reports[0], &reports[1]);
    println!(
        "\nsegment-wise packing: makespan {:.1}% of static-peak, \
         mean queue wait {:.1}s -> {:.1}s, peak co-located tasks {} -> {}",
        100.0 * segw.makespan.0 / stat.makespan.0,
        stat.mean_queue_wait_s(),
        segw.mean_queue_wait_s(),
        stat.peak_running,
        segw.peak_running,
    );
}
