//! Differential pinning of the predictor zoo (Sizey-style ensemble,
//! KS+-style dynamic segmentation) against the existing predictors:
//! the new methods must not regress where the old ones are known-good,
//! and must win where their design says they should.

use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::dynseg::DynSegPredictor;
use ksegments::predictors::ensemble::{EnsemblePredictor, SUB_MODELS};
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::sim::{simulate_trace, SimConfig};
use ksegments::trace::{TaskRun, Trace, UsageSeries};
use ksegments::units::{MemMiB, Seconds};

/// Linear-memory synthetic workload: peak linear in the input size,
/// usage ramping linearly over a fixed 512 s runtime. Every series has
/// exactly 256 samples — the fit grid's resample length — so the
/// peak-preserving resample is the identity and the window's mean
/// curve is *exactly* linear. A straight line's greedy error-minimizing
/// change points are the equal-width boundaries, which makes the
/// equal-k-budget comparison between KS+ and k-Segments exact.
fn linear_run(input: f64, seq: u64) -> TaskRun {
    let n = 256usize;
    let peak = 50.0 + input;
    let series: Vec<f64> = (0..n).map(|i| peak * ((i + 1) as f64 / n as f64)).collect();
    TaskRun {
        task_type: "zoo/linear".into(),
        input_mib: input,
        runtime: Seconds(n as f64 * 2.0),
        series: UsageSeries::new(2.0, series),
        seq,
    }
}

/// Inputs cycle with period 24, so every scored run's exact
/// (input, peak) pair already sits in the training window — the
/// max-underprediction offsets then cover each scored run exactly and
/// the simulations below are retry-free and deterministic (no float
/// knife-edge on `used > alloc` from a trend the models must chase).
fn linear_trace(n: usize) -> Trace {
    let mut t = Trace::new();
    t.set_default("zoo/linear", MemMiB(8192.0));
    for i in 0..n {
        t.push(linear_run(100.0 + 25.0 * (i % 24) as f64, i as u64));
    }
    t.sort();
    t
}

fn eval(trace: &Trace, p: &mut dyn ksegments::predictors::MemoryPredictor) -> f64 {
    let cfg = SimConfig { min_runs: 1, ..SimConfig::with_training_frac(0.5) };
    simulate_trace(trace, p, &cfg).avg_wastage_gbs()
}

/// ISSUE satellite: on a linear-memory workload, dynamic segmentation
/// at the same k budget must not waste more than the fixed equal-width
/// split — a straight ramp's optimal change points ARE (close to) the
/// equal-width ones, so KS+ degenerates gracefully instead of paying
/// for its flexibility. (1 % head-room absorbs change points landing a
/// resample bucket off the exact k-grid.)
#[test]
fn dynseg_matches_ksegments_on_linear_workload_at_equal_k() {
    let trace = linear_trace(48);
    let mut kseg = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    let mut dseg = DynSegPredictor::native(4, RetryStrategy::Selective);
    let w_kseg = eval(&trace, &mut kseg);
    let w_dseg = eval(&trace, &mut dseg);
    assert!(w_kseg > 0.0 && w_dseg > 0.0);
    assert!(
        w_dseg <= w_kseg * 1.01,
        "dynseg {w_dseg} must not lose to equal-width {w_kseg} at equal k"
    );
}

/// Both zoo methods must comfortably beat the static default on the
/// learnable workload (the same sanity bar every learned predictor in
/// the roster clears), and the time-varying method must out-pack the
/// static ensemble on a ramp.
#[test]
fn zoo_methods_beat_default_config() {
    let trace = linear_trace(48);
    let w_default = eval(&trace, &mut DefaultConfigPredictor::new());
    let w_ens = eval(&trace, &mut EnsemblePredictor::new());
    let w_dseg = eval(&trace, &mut DynSegPredictor::native(4, RetryStrategy::Selective));
    assert!(w_ens < w_default / 2.0, "ensemble {w_ens} vs default {w_default}");
    assert!(w_dseg < w_default / 2.0, "dynseg {w_dseg} vs default {w_default}");
    // a k=4 step function hugging a linear ramp allocates ~5/8 of the
    // peak-static envelope; the static ensemble cannot go below it
    assert!(w_dseg < w_ens, "dynseg {w_dseg} should beat static ensemble {w_ens} on a ramp");
}

/// ISSUE satellite: the ensemble's selection rule is argmax over the
/// sub-model quality scores, so it can never underperform its own
/// worst sub-model on the quality metric — pinned against every
/// sub-model, after online training on the real simulation path.
#[test]
fn ensemble_never_underperforms_worst_submodel_on_quality() {
    let trace = linear_trace(48);
    let mut ens = EnsemblePredictor::new();
    let _ = eval(&trace, &mut ens); // train online through the simulator
    let fit = ens.fit_for("zoo/linear").expect("trained");
    let worst = fit.scores.iter().copied().fold(f64::INFINITY, f64::min);
    let best = fit.scores.iter().copied().fold(f64::MIN, f64::max);
    assert_eq!(
        fit.chosen_score(),
        best,
        "selection must be the argmax of {:?}",
        fit.scores
    );
    assert!(fit.chosen_score() >= worst);
    for (model, score) in SUB_MODELS.iter().zip(fit.scores) {
        assert!(
            (0.0..=1.0).contains(&score),
            "RAQ of {} out of range: {score}",
            model.label()
        );
        assert!(fit.chosen_score() >= score, "chosen loses to {}", model.label());
    }
}

/// The offset mechanism applied on top of the winning sub-model keeps
/// the zoo retry-free on the cyclic workload: every scored run's exact
/// peak is covered by the window's max-underprediction offset.
#[test]
fn zoo_methods_are_retry_free_when_offsets_cover_the_window() {
    let trace = linear_trace(48);
    let cfg = SimConfig { min_runs: 1, ..SimConfig::with_training_frac(0.5) };
    let mut ens = EnsemblePredictor::new();
    let rep_ens = simulate_trace(&trace, &mut ens, &cfg);
    assert_eq!(rep_ens.tasks.len(), 1);
    assert_eq!(rep_ens.tasks[0].n_scored, 24);
    assert_eq!(rep_ens.total_retries(), 0, "ensemble offsets failed to cover");
    let mut dseg = DynSegPredictor::native(4, RetryStrategy::Selective);
    let rep_dseg = simulate_trace(&trace, &mut dseg, &cfg);
    assert_eq!(rep_dseg.total_retries(), 0, "dynseg offsets failed to cover");
}
