//! DynSeg — KS+-style **data-driven dynamic segmentation** of the
//! memory curve (arXiv 2408.12290), the time-aware competitor to the
//! paper's fixed equal-width k-Segments.
//!
//! KS+ observes that equal-width segments waste allocation whenever a
//! task's usage curve has change points that do not fall on the k-grid
//! (long flat prefix, late spike, plateaus). Instead of `k` equal bins
//! it places segment boundaries at change points of the usage curve.
//!
//! Our reproduction: average the window's peak-resampled usage rows
//! into one mean curve, find at most `k` segments by greedy
//! error-minimizing binary splits
//! ([`crate::ml::segmentation::greedy_segment_bounds`] — each split
//! maximally reduces the flat-piece over-allocation cost), then train
//! exactly the k-Segments per-segment machinery over those bounds:
//! per-segment `peak ~ input` regressions with max-underprediction
//! offsets, a runtime regression with the conservative negative
//! offset, and a monotone clamped [`StepFunction`] — so
//! `simulate_attempt`, the retry strategies, and the `sched`
//! segment-wise reservation policy consume DynSeg allocations
//! completely unchanged.

use std::collections::BTreeMap;

use crate::ml::linreg::LinReg;
use crate::ml::segmentation::{greedy_segment_bounds, index_bounds_to_time, seg_peaks_with_bounds};
use crate::ml::step_fn::StepFunction;
use crate::trace::TaskRun;
use crate::units::MemMiB;

use super::history::HistoryMap;
use super::ksegments::{KSegmentsConfig, RetryStrategy};
use super::{Allocation, Defaults, FailureInfo, MemoryPredictor};

/// A fitted DynSeg model: change-point index bounds shared by all
/// window rows, plus the standard per-segment/runtime regressions.
#[derive(Debug, Clone)]
pub struct DynSegFit {
    rt: LinReg,
    rt_offset: f64,
    /// Change-point segmentation of the resample grid (≤ k segments).
    pub bounds: Vec<(usize, usize)>,
    seg: Vec<LinReg>,
    seg_off: Vec<f64>,
}

impl DynSegFit {
    pub fn k(&self) -> usize {
        self.seg.len()
    }

    pub fn predict_runtime(&self, x: f64) -> f64 {
        self.rt.predict(x) - self.rt_offset
    }

    pub fn predict_segments(&self, x: f64) -> Vec<f64> {
        self.seg
            .iter()
            .zip(&self.seg_off)
            .map(|(lr, off)| lr.predict(x) + off)
            .collect()
    }
}

/// The KS+-style dynamic-segmentation predictor. Reuses
/// [`KSegmentsConfig`] — `k` is the segment *budget* (the greedy
/// splitter may stop below it when the curve has fewer change points).
pub struct DynSegPredictor {
    cfg: KSegmentsConfig,
    strategy: RetryStrategy,
    defaults: Defaults,
    histories: HistoryMap,
    fits: BTreeMap<String, (u64, DynSegFit)>,
}

impl DynSegPredictor {
    pub fn with_config(cfg: KSegmentsConfig, strategy: RetryStrategy) -> Self {
        assert!(cfg.k >= 1 && cfg.k <= cfg.t_resample);
        assert!(cfg.retry_factor > 1.0, "retry factor must make progress");
        let histories = HistoryMap::new(cfg.n_hist, cfg.t_resample);
        DynSegPredictor {
            cfg,
            strategy,
            defaults: Defaults::default(),
            histories,
            fits: BTreeMap::new(),
        }
    }

    /// Paper-default configuration with the given segment budget.
    pub fn native(k: usize, strategy: RetryStrategy) -> Self {
        let cfg = KSegmentsConfig { k, ..KSegmentsConfig::default() };
        Self::with_config(cfg, strategy)
    }

    pub fn config(&self) -> &KSegmentsConfig {
        &self.cfg
    }

    pub fn strategy(&self) -> RetryStrategy {
        self.strategy
    }

    /// Current fit for a task (refit lazily when the history advanced);
    /// `None` below `min_train`. Public for tests/observability.
    pub fn fit_for(&mut self, task_type: &str) -> Option<DynSegFit> {
        let h = self.histories.get(task_type)?;
        if h.len() < self.cfg.min_train {
            return None;
        }
        let version = h.total_seen();
        if let Some((v, fit)) = self.fits.get(task_type) {
            if *v == version {
                return Some(fit.clone());
            }
        }
        let input = h.fit_input();
        let n = input.x.len();
        let t = self.cfg.t_resample;

        // Mean usage curve over the window (column means of the
        // peak-resampled rows) — the curve the change points come from.
        let mut mean_curve = vec![0.0f64; t];
        for row in &input.series {
            for (m, y) in mean_curve.iter_mut().zip(row) {
                *m += y;
            }
        }
        for m in mean_curve.iter_mut() {
            *m /= n as f64;
        }
        let bounds = greedy_segment_bounds(&mean_curve, self.cfg.k);

        // Runtime model + conservative offset (identical to NativeFitter).
        let rt = LinReg::fit(&input.x, &input.runtime);
        let mut rt_offset = 0.0f64;
        for (&xi, &ri) in input.x.iter().zip(&input.runtime) {
            rt_offset = rt_offset.max(rt.predict(xi) - ri);
        }

        // Per-segment peak regressions over the SHARED change-point
        // bounds + max-underprediction offsets.
        let peaks: Vec<Vec<f64>> = input
            .series
            .iter()
            .map(|row| seg_peaks_with_bounds(row, &bounds))
            .collect();
        let mut seg = Vec::with_capacity(bounds.len());
        let mut seg_off = Vec::with_capacity(bounds.len());
        let mut col = vec![0.0; n];
        for s in 0..bounds.len() {
            for (row, p) in peaks.iter().enumerate() {
                col[row] = p[s];
            }
            let lr = LinReg::fit(&input.x, &col);
            let mut off = 0.0f64;
            for (&xi, &yi) in input.x.iter().zip(col.iter()) {
                off = off.max(yi - lr.predict(xi));
            }
            seg.push(lr);
            seg_off.push(off);
        }

        let mut fit = DynSegFit { rt, rt_offset, bounds, seg, seg_off };
        if !self.cfg.use_offsets {
            fit.rt_offset = 0.0;
            fit.seg_off.iter_mut().for_each(|o| *o = 0.0);
        }
        self.fits.insert(task_type.to_string(), (version, fit.clone()));
        Some(fit)
    }
}

impl MemoryPredictor for DynSegPredictor {
    fn name(&self) -> String {
        format!("KS+ DynSeg {}", self.strategy.label())
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, input_mib: f64) -> Allocation {
        let default = self.defaults.get(task_type);
        let Some(fit) = self.fit_for(task_type) else {
            return Allocation::Static(default);
        };
        let rt = fit.predict_runtime(input_mib).max(1.0);
        let time_bounds = index_bounds_to_time(rt, self.cfg.t_resample, &fit.bounds);
        let f = StepFunction::monotone_clamped_with_bounds(
            time_bounds,
            fit.predict_segments(input_mib),
            self.cfg.min_alloc,
            self.cfg.node_max,
        );
        Allocation::Dynamic(f)
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        info: &FailureInfo,
    ) -> Allocation {
        // Same escalation contract as k-Segments: the step function is
        // interchangeable, so the retry strategies apply unchanged.
        let l = self.cfg.retry_factor;
        match failed {
            Allocation::Static(m) => {
                Allocation::Static(MemMiB((m.0 * l).min(self.cfg.node_max.0)))
            }
            Allocation::Dynamic(f) => {
                let seg = f.segment_at(info.time_s);
                let k = f.k();
                let (from, to) = match self.strategy {
                    RetryStrategy::Selective => (seg, seg + 1),
                    RetryStrategy::Partial => (seg, k),
                };
                let mut next = f.scale_segments(from, to, l, self.cfg.node_max);
                if next.value_at(info.time_s) <= info.used_mib {
                    let need = (info.used_mib * 1.05).min(self.cfg.node_max.0);
                    let mut values = next.values().to_vec();
                    let hi = to.min(values.len());
                    for v in values[from..hi].iter_mut() {
                        *v = v.max(need);
                    }
                    next = StepFunction::monotone_clamped_with_bounds(
                        next.bounds().to_vec(),
                        values,
                        self.cfg.min_alloc,
                        self.cfg.node_max,
                    );
                }
                Allocation::Dynamic(next)
            }
        }
    }

    fn observe(&mut self, run: &TaskRun) {
        self.histories.push(run);
    }

    fn decision(&mut self, task_type: &str) -> Option<crate::telemetry::DecisionDetail> {
        // fit_for() is cached per history version, so calling it here
        // is deterministically idempotent — predict() is unaffected.
        let window_len = self.histories.get(task_type).map_or(0, |h| h.len());
        let fit = self.fit_for(task_type)?;
        let t = self.cfg.t_resample as f64;
        Some(crate::telemetry::DecisionDetail {
            model: format!("dynseg-k{}", fit.k()),
            scores: Vec::new(),
            offset_mib: fit.seg_off.iter().copied().fold(0.0, f64::max),
            segment_bounds: fit.bounds.iter().map(|&(_, hi)| hi as f64 / t).collect(),
            window_len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    /// Late-spike profile: flat 100 MiB for 62.5 % of the runtime, then
    /// a spike to `200 + input` — the shape equal-width segmentation is
    /// worst at: the change point (grid index 160 of 256) sits strictly
    /// inside an equal-width k = 4 bin ([128, 192)).
    fn spike_run(input: f64) -> TaskRun {
        let n = 80usize;
        let peak = 200.0 + input;
        let series: Vec<f64> = (0..n).map(|i| if i < 50 { 100.0 } else { peak }).collect();
        TaskRun {
            task_type: "t".into(),
            input_mib: input,
            runtime: Seconds(n as f64 * 2.0),
            series: UsageSeries::new(2.0, series),
            seq: 0,
        }
    }

    fn trained() -> DynSegPredictor {
        let mut p = DynSegPredictor::native(4, RetryStrategy::Selective);
        p.prime("t", MemMiB(8192.0));
        for i in 0..16 {
            p.observe(&spike_run(100.0 + 50.0 * i as f64));
        }
        p
    }

    #[test]
    fn untrained_returns_default() {
        let mut p = DynSegPredictor::native(4, RetryStrategy::Selective);
        p.prime("t", MemMiB(4096.0));
        assert_eq!(p.predict("t", 100.0), Allocation::Static(MemMiB(4096.0)));
        p.observe(&spike_run(100.0));
        assert!(!p.predict("t", 100.0).is_dynamic());
    }

    #[test]
    fn change_point_lands_on_the_spike() {
        let mut p = trained();
        let fit = p.fit_for("t").unwrap();
        // the flat→spike jump is at sample 50/80 = index 160/256 of the
        // resample grid; the first boundary must sit exactly there
        assert!(fit.k() >= 2);
        assert_eq!(fit.bounds[0].0, 0);
        assert_eq!(fit.bounds[0].1, 160, "bounds {:?}", fit.bounds);
        let Allocation::Dynamic(f) = p.predict("t", 400.0) else {
            panic!("expected dynamic allocation")
        };
        assert!(f.is_monotone());
        // early segment hugs the flat 100 MiB level, late covers ~600
        assert!(f.values()[0] <= 150.0, "{:?}", f.values());
        assert!(*f.values().last().unwrap() >= 0.9 * 600.0, "{:?}", f.values());
    }

    #[test]
    fn beats_equal_width_on_late_spike() {
        use crate::predictors::ksegments::KSegmentsPredictor;
        use crate::scoring::{simulate_trace, SimConfig};
        use crate::trace::Trace;

        // Inputs CYCLE (period 20, even) so every scored run's exact
        // (input, peak) pair already sits in the training window: the
        // max-underprediction offsets then cover each scored run
        // exactly and the comparison is retry-free and deterministic
        // (no float knife-edge on `used > alloc`). The ±3 % sawtooth
        // keeps the regressions honest without a trend to chase.
        let mut trace = Trace::new();
        trace.set_default("t", MemMiB(8192.0));
        for i in 0..60u64 {
            let x = 100.0 + 25.0 * (i % 20) as f64;
            let mut r = spike_run(x);
            let noise = if i % 2 == 0 { 1.03 } else { 0.97 };
            let samples: Vec<f64> =
                r.series.samples().iter().map(|s| s * noise).collect();
            r.series = UsageSeries::new(2.0, samples);
            r.seq = i;
            trace.push(r);
        }
        trace.sort();
        let cfg = SimConfig { min_runs: 1, ..SimConfig::with_training_frac(0.5) };
        let mut kseg = KSegmentsPredictor::native(4, RetryStrategy::Selective);
        let mut dseg = DynSegPredictor::native(4, RetryStrategy::Selective);
        let rk = simulate_trace(&trace, &mut kseg, &cfg);
        let rd = simulate_trace(&trace, &mut dseg, &cfg);
        assert_eq!(rk.total_retries(), 0, "equal-width retried");
        assert_eq!(rd.total_retries(), 0, "dynseg retried");
        let (w_kseg, w_dseg) = (rk.avg_wastage_gbs(), rd.avg_wastage_gbs());
        assert!(
            w_dseg < w_kseg,
            "dynseg {w_dseg} should beat equal-width {w_kseg} on a late spike"
        );
    }

    #[test]
    fn flat_profile_degenerates_to_one_segment() {
        let mut p = DynSegPredictor::native(8, RetryStrategy::Selective);
        p.prime("t", MemMiB(8192.0));
        for i in 0..8 {
            let series = vec![300.0; 50];
            p.observe(&TaskRun {
                task_type: "t".into(),
                input_mib: 100.0 + i as f64,
                runtime: Seconds(100.0),
                series: UsageSeries::new(2.0, series),
                seq: i,
            });
        }
        let Allocation::Dynamic(f) = p.predict("t", 104.0) else {
            panic!()
        };
        assert_eq!(f.k(), 1, "constant curve needs no change points");
        assert!((f.value_at(10.0) - 300.0).abs() < 1.0);
    }

    #[test]
    fn selective_retry_scales_failed_segment() {
        let mut p = trained();
        let alloc = p.predict("t", 400.0);
        let Allocation::Dynamic(f) = &alloc else { panic!() };
        let before = f.values().to_vec();
        let t_fail = f.bounds()[0] * 0.5; // inside segment 0
        let info = FailureInfo::oom(t_fail, before[0] + 1.0, 1);
        let Allocation::Dynamic(g) = p.on_failure("t", 400.0, &alloc, &info) else {
            panic!()
        };
        assert!(g.values()[0] >= before[0] * 2.0 * 0.999);
        assert!(g.is_monotone());
    }

    #[test]
    fn failure_makes_progress_beyond_observed_usage() {
        let mut p = trained();
        let alloc = p.predict("t", 400.0);
        let Allocation::Dynamic(f) = &alloc else { panic!() };
        let info = FailureInfo::oom(f.bounds()[0] * 0.5, f.values()[0] * 10.0, 1);
        let next = p.on_failure("t", 400.0, &alloc, &info);
        assert!(next.value_at(info.time_s) > info.used_mib);
    }

    #[test]
    fn static_default_failure_doubles() {
        let mut p = DynSegPredictor::native(4, RetryStrategy::Partial);
        p.prime("t", MemMiB(1000.0));
        let alloc = p.predict("t", 50.0);
        let info = FailureInfo::oom(3.0, 1500.0, 1);
        let next = p.on_failure("t", 50.0, &alloc, &info);
        assert_eq!(next, Allocation::Static(MemMiB(2000.0)));
    }

    #[test]
    fn respects_node_ceiling_and_floor() {
        let cfg = KSegmentsConfig { node_max: MemMiB(500.0), ..KSegmentsConfig::default() };
        let mut p = DynSegPredictor::with_config(cfg, RetryStrategy::Selective);
        p.prime("t", MemMiB(100.0));
        for i in 0..8 {
            p.observe(&spike_run(1000.0 + i as f64 * 200.0)); // peaks ≫ 500
        }
        let Allocation::Dynamic(f) = p.predict("t", 2000.0) else {
            panic!()
        };
        assert!(f.max_value() <= 500.0);
        assert!(f.values()[0] >= crate::predictors::MIN_ALLOC.0);
    }

    #[test]
    fn name_reflects_strategy() {
        assert_eq!(
            DynSegPredictor::native(4, RetryStrategy::Selective).name(),
            "KS+ DynSeg Selective"
        );
        assert_eq!(
            DynSegPredictor::native(4, RetryStrategy::Partial).name(),
            "KS+ DynSeg Partial"
        );
    }
}
