//! Serving layer of the ksegments workspace: the path from a real
//! workflow engine into (and back out of) the prediction core.
//!
//! `ksegments-core` defines the data model and the streaming
//! [`TraceSource`](ksegments_core::source::TraceSource) seam; this
//! crate owns everything that touches files, threads and long-lived
//! state:
//!
//! * [`ingest`] — Nextflow `trace.txt` + monitoring-CSV parsers, the
//!   streaming JSONL reader, shape-sniffing [`ingest::open_source`],
//!   the online replay engine ([`ingest::replay_source`]) and
//!   predictor [`ingest::Checkpoint`]s for warm starts.
//! * [`coordinator`] — the sharded in-process prediction service: a
//!   router hashing task types onto worker shards, each owning a
//!   private predictor, with request/response plumbing, telemetry
//!   spans and merged metrics.
//!
//! The `ksegments` facade re-exports both modules under their
//! historical single-crate paths (`ksegments::ingest`,
//! `ksegments::coordinator`).

pub mod coordinator;
pub mod ingest;
