//! Simulation of a single task attempt against its ground-truth usage
//! curve — the innermost loop of the evaluation (and the L3 hot path
//! profiled in EXPERIMENTS.md §Perf).

use crate::predictors::{Allocation, FailureInfo};
use crate::trace::UsageSeries;

/// Outcome of running one attempt under an allocation.
#[derive(Debug, Clone, PartialEq)]
pub enum AttemptOutcome {
    /// The allocation covered the whole run. `wastage_mibs` is
    /// `∫ (alloc(t) − used(t)) dt` over the full runtime.
    Success { wastage_mibs: f64 },
    /// Under-allocation at `info.time_s`. `wastage_mibs` is the FULL
    /// allocation integral up to the failure instant: a failed attempt
    /// produces no useful output, so every allocated byte-second of it
    /// is wasted (this is the accounting that makes retries expensive,
    /// consistent with the paper's discussion of failure-handling cost
    /// and Tovar's slow-peaks model).
    Failure { info: FailureInfo, wastage_mibs: f64 },
}

impl AttemptOutcome {
    pub fn wastage_mibs(&self) -> f64 {
        match self {
            AttemptOutcome::Success { wastage_mibs } => *wastage_mibs,
            AttemptOutcome::Failure { wastage_mibs, .. } => *wastage_mibs,
        }
    }

    pub fn is_success(&self) -> bool {
        matches!(self, AttemptOutcome::Success { .. })
    }
}

/// Simulate one attempt: walk the usage curve at monitoring resolution
/// and compare against the allocation function.
///
/// Semantics:
/// * usage is sample-and-hold over each interval `[i·f, (i+1)·f)`;
/// * the allocation is piecewise constant (static, or the k-Segments
///   step function, which changes value at its segment boundaries);
/// * within one usage sample the allocation may step; each piece is
///   checked separately, so a failure lands at the exact boundary
///   where `alloc` first drops below `used` (this matters for the
///   k-Segments runtime-underprediction case: the function steps UP,
///   so the dangerous instants are segment starts).
/// * `attempt` is the 1-based attempt index recorded in failures.
pub fn simulate_attempt(series: &UsageSeries, alloc: &Allocation, attempt: u32) -> AttemptOutcome {
    let dt = series.interval().0;
    let mut wastage = 0.0f64;

    match alloc {
        Allocation::Static(m) => {
            let a = m.0;
            for (i, &used) in series.samples().iter().enumerate() {
                if used > a {
                    // failure at the start of this sample interval
                    let t = i as f64 * dt;
                    return AttemptOutcome::Failure {
                        info: FailureInfo::oom(t, used, attempt),
                        wastage_mibs: wastage + 0.0, // failure at piece start
                    };
                }
                wastage += a * dt; // full-allocation accounting (see below)
            }
            // success: wastage is alloc − used
            let used_integral: f64 = series.samples().iter().map(|u| u * dt).sum();
            AttemptOutcome::Success { wastage_mibs: wastage - used_integral }
        }
        Allocation::Dynamic(f) => {
            let bounds = f.bounds();
            let values = f.values();
            let k = values.len();
            let mut seg = 0usize; // current allocation segment (two-pointer)
            let mut used_integral = 0.0f64;
            for (i, &used) in series.samples().iter().enumerate() {
                let t0 = i as f64 * dt;
                let t1 = t0 + dt;
                // advance to the segment covering (t0, t0+ε): Eq. 1's
                // segments are right-closed (r_{s-1}, r_s], so for the
                // duration-based check the piece that matters at a
                // boundary instant is the NEXT segment (a boundary has
                // measure zero; the new allocation applies from it on)
                while seg < k - 1 && bounds[seg] <= t0 {
                    seg += 1;
                }
                // walk allocation pieces inside [t0, t1)
                let mut piece_start = t0;
                let mut s = seg;
                loop {
                    let piece_end = if s < k - 1 { bounds[s].min(t1) } else { t1 };
                    let a = values[s.min(k - 1)];
                    if used > a {
                        return AttemptOutcome::Failure {
                            info: FailureInfo::oom(piece_start, used, attempt),
                            wastage_mibs: wastage,
                        };
                    }
                    wastage += a * (piece_end - piece_start);
                    used_integral += used * (piece_end - piece_start);
                    if piece_end >= t1 - 1e-12 {
                        break;
                    }
                    piece_start = piece_end;
                    s += 1;
                }
            }
            AttemptOutcome::Success { wastage_mibs: wastage - used_integral }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::step_fn::StepFunction;
    use crate::units::MemMiB;

    fn series(samples: Vec<f64>) -> UsageSeries {
        UsageSeries::new(2.0, samples)
    }

    #[test]
    fn static_success_wastage() {
        // alloc 100 for 6 s; usage 10,20,30 -> waste (90+80+70)*2 = 480
        let out = simulate_attempt(
            &series(vec![10.0, 20.0, 30.0]),
            &Allocation::Static(MemMiB(100.0)),
            1,
        );
        match out {
            AttemptOutcome::Success { wastage_mibs } => {
                assert!((wastage_mibs - 480.0).abs() < 1e-9)
            }
            _ => panic!("{out:?}"),
        }
    }

    #[test]
    fn static_failure_at_right_sample() {
        let out = simulate_attempt(
            &series(vec![10.0, 20.0, 300.0, 5.0]),
            &Allocation::Static(MemMiB(100.0)),
            2,
        );
        match out {
            AttemptOutcome::Failure { info, wastage_mibs } => {
                assert_eq!(info.time_s, 4.0);
                assert_eq!(info.used_mib, 300.0);
                assert_eq!(info.attempt, 2);
                // full allocation up to failure: 100 * 4 s
                assert!((wastage_mibs - 400.0).abs() < 1e-9);
            }
            _ => panic!("{out:?}"),
        }
    }

    #[test]
    fn exact_fit_is_success() {
        let out = simulate_attempt(
            &series(vec![100.0, 100.0]),
            &Allocation::Static(MemMiB(100.0)),
            1,
        );
        assert!(out.is_success());
        assert!(out.wastage_mibs().abs() < 1e-9);
    }

    fn step(bounds: Vec<f64>, values: Vec<f64>) -> Allocation {
        Allocation::Dynamic(StepFunction::new(bounds, values))
    }

    #[test]
    fn dynamic_success_tracks_pieces() {
        // alloc: 50 on (0,4], 100 on (4,8]; usage 40,40,80,80
        let out = simulate_attempt(
            &series(vec![40.0, 40.0, 80.0, 80.0]),
            &step(vec![4.0, 8.0], vec![50.0, 100.0]),
            1,
        );
        match out {
            AttemptOutcome::Success { wastage_mibs } => {
                // waste = (10+10+20+20)*2 = 120
                assert!((wastage_mibs - 120.0).abs() < 1e-9, "{wastage_mibs}");
            }
            _ => panic!("{out:?}"),
        }
    }

    #[test]
    fn dynamic_failure_when_segment_too_low() {
        // usage 80 in the first segment that only allows 50
        let out = simulate_attempt(
            &series(vec![80.0, 10.0]),
            &step(vec![4.0, 8.0], vec![50.0, 100.0]),
            1,
        );
        match out {
            AttemptOutcome::Failure { info, wastage_mibs } => {
                assert_eq!(info.time_s, 0.0);
                assert_eq!(wastage_mibs, 0.0);
            }
            _ => panic!("{out:?}"),
        }
    }

    #[test]
    fn dynamic_failure_mid_sample_at_boundary() {
        // usage sample [2,4) = 80; allocation steps DOWN is impossible
        // after monotone clamp, but StepFunction::new allows it for this
        // accounting test: alloc 100 on (0,3], 50 on (3,6] -> failure at
        // exactly t=3 inside the second usage sample
        let out = simulate_attempt(
            &series(vec![60.0, 80.0, 10.0]),
            &step(vec![3.0, 6.0], vec![100.0, 50.0]),
            1,
        );
        match out {
            AttemptOutcome::Failure { info, wastage_mibs } => {
                assert_eq!(info.time_s, 3.0);
                // 100 MiB held for 3 s
                assert!((wastage_mibs - 300.0).abs() < 1e-9);
            }
            _ => panic!("{out:?}"),
        }
    }

    #[test]
    fn runtime_underprediction_holds_last_value() {
        // allocation predicted only 4 s but the task runs 8 s: v_k held
        let out = simulate_attempt(
            &series(vec![10.0, 10.0, 10.0, 10.0]),
            &step(vec![2.0, 4.0], vec![20.0, 20.0]),
            1,
        );
        assert!(out.is_success());
        // waste = 10 MiB * 8 s
        assert!((out.wastage_mibs() - 80.0).abs() < 1e-9);
    }

    #[test]
    fn single_segment_dynamic_curve() {
        // Regression (k = 1): the two-pointer walk must hold the single
        // piece over the whole run, attribute failures at the exact
        // sample start, and account wastage like the static path.
        let ok = simulate_attempt(
            &series(vec![30.0, 40.0, 20.0]),
            &step(vec![6.0], vec![50.0]),
            1,
        );
        match ok {
            AttemptOutcome::Success { wastage_mibs } => {
                // (20 + 10 + 30) * 2 = 120
                assert!((wastage_mibs - 120.0).abs() < 1e-9, "{wastage_mibs}");
            }
            _ => panic!("{ok:?}"),
        }
        let fail = simulate_attempt(
            &series(vec![30.0, 90.0, 20.0]),
            &step(vec![6.0], vec![50.0]),
            3,
        );
        match fail {
            AttemptOutcome::Failure { info, wastage_mibs } => {
                assert_eq!(info.time_s, 2.0);
                assert_eq!(info.used_mib, 90.0);
                assert_eq!(info.attempt, 3);
                assert!((wastage_mibs - 100.0).abs() < 1e-9); // 50 MiB * 2 s
            }
            _ => panic!("{fail:?}"),
        }
    }

    #[test]
    fn duplicate_bounds_cannot_reach_the_walk() {
        // Regression: the zero-width pieces the walk used to tolerate
        // by accident are now rejected at StepFunction construction, so
        // no allocation with duplicate boundaries can reach this loop.
        assert!(
            crate::ml::step_fn::StepFunction::try_new(vec![4.0, 4.0], vec![50.0, 100.0]).is_err()
        );
    }

    #[test]
    fn empty_series_is_trivial_success() {
        let out = simulate_attempt(&series(vec![]), &Allocation::Static(MemMiB(10.0)), 1);
        assert!(out.is_success());
        assert_eq!(out.wastage_mibs(), 0.0);
    }
}
