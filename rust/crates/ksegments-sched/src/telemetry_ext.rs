//! Scheduler-side telemetry: mapping [`EngineEvent`]s onto the trace
//! model defined in `ksegments_core::telemetry`.
//!
//! The core crate owns the sinks, span-id scheme and time mapping;
//! this module owns the one function that knows about the
//! discrete-event engine's vocabulary, so the core layer never links
//! the engine. Re-exported by the `ksegments` facade under the
//! historical `ksegments::telemetry::trace_engine_event` path.

use ksegments_core::telemetry::{sim_ts_us, span_id, ArgValue, TraceEvent, TraceSink};

use crate::engine::events::EngineEvent;

/// Map one engine event to its trace representation. Task lifecycles
/// become async spans — `'b'` at placement, `'e'` at completion or
/// kill (matched by `(cat, id)`) — and everything else becomes an
/// instant, so OOM storms, preemption cascades, node churn and DAG
/// gating all show up as timeline tracks per node (`tid`).
pub fn trace_engine_event(sink: &mut dyn TraceSink, ev: &EngineEvent, now_s: f64) {
    let ts = sim_ts_us(now_s);
    match ev {
        EngineEvent::Submitted { task_type, seq, requested } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "arrival",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("requested_mib", ArgValue::F64(requested.0)),
                ],
            });
        }
        EngineEvent::Queued { task_type, seq, requested } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "queue",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("requested_mib", ArgValue::F64(requested.0)),
                ],
            });
        }
        EngineEvent::Failed { task_type, seq, attempt, used, allocated, .. } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "kill",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("attempt", ArgValue::U64(u64::from(*attempt))),
                    ("used_mib", ArgValue::F64(used.0)),
                    ("allocated_mib", ArgValue::F64(allocated.0)),
                ],
            });
        }
        EngineEvent::Placed { task_type, seq, node, reserved, .. } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "task",
                ph: 'b',
                ts_us: ts,
                pid: 0,
                tid: *node as u32,
                id: Some(span_id(task_type, *seq)),
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("node", ArgValue::U64(*node as u64)),
                    ("reserved_mib", ArgValue::F64(reserved.0)),
                ],
            });
        }
        EngineEvent::Completed { task_type, seq, attempts } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "task",
                ph: 'e',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: Some(span_id(task_type, *seq)),
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("attempts", ArgValue::U64(u64::from(*attempts))),
                ],
            });
        }
        EngineEvent::OomKilled { task_type, seq, attempt, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *attempt, "oom-kill", 0);
        }
        EngineEvent::GrowDenied { task_type, seq, segment, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *segment as u32, "grow-denied", 0);
        }
        EngineEvent::NodeLost { task_type, seq, attempt, node, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *attempt, "node-lost-kill", *node as u32);
        }
        EngineEvent::Preempted { task_type, seq, attempt, node, .. } => {
            end_span_with_kill(sink, ts, task_type, *seq, *attempt, "preempt-kill", *node as u32);
        }
        EngineEvent::Released { task_type, seq, instance, .. } => {
            sink.event(&TraceEvent {
                name: task_type.clone(),
                cat: "dag",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("seq", ArgValue::U64(*seq)),
                    ("instance", ArgValue::U64(*instance)),
                ],
            });
        }
        EngineEvent::WorkflowDone { workflow, instance, tasks, makespan_s, .. } => {
            sink.event(&TraceEvent {
                name: workflow.clone(),
                cat: "dag",
                ph: 'i',
                ts_us: ts,
                pid: 0,
                tid: 0,
                id: None,
                args: vec![
                    ("instance", ArgValue::U64(*instance)),
                    ("tasks", ArgValue::U64(u64::from(*tasks))),
                    ("makespan_s", ArgValue::F64(*makespan_s)),
                ],
            });
        }
        EngineEvent::NodeFailed { node, killed, .. } => {
            let mut e = TraceEvent::instant("node-failed", "node", ts, *node as u32);
            e.args = vec![("killed", ArgValue::U64(u64::from(*killed)))];
            sink.event(&e);
        }
        EngineEvent::NodeJoined { node, .. } => {
            sink.event(&TraceEvent::instant("node-joined", "node", ts, *node as u32));
        }
        EngineEvent::NodeRetired { node, .. } => {
            sink.event(&TraceEvent::instant("node-retired", "node", ts, *node as u32));
        }
    }
}

/// A killed attempt: close its `'b'` span and drop a kill marker.
fn end_span_with_kill(
    sink: &mut dyn TraceSink,
    ts: u64,
    task_type: &str,
    seq: u64,
    detail: u32,
    kill_name: &'static str,
    tid: u32,
) {
    sink.event(&TraceEvent {
        name: task_type.to_string(),
        cat: "task",
        ph: 'e',
        ts_us: ts,
        pid: 0,
        tid,
        id: Some(span_id(task_type, seq)),
        args: Vec::new(),
    });
    sink.event(&TraceEvent {
        name: kill_name.to_string(),
        cat: "kill",
        ph: 'i',
        ts_us: ts,
        pid: 0,
        tid,
        id: None,
        args: vec![("seq", ArgValue::U64(seq)), ("detail", ArgValue::U64(u64::from(detail)))],
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::telemetry::VecSink;
    use ksegments_core::units::MemMiB;

    #[test]
    fn placement_and_completion_form_a_span() {
        let mut sink = VecSink::new();
        let placed = EngineEvent::Placed {
            task_type: "t".into(),
            seq: 9,
            node: 2,
            time_s: 4.0,
            reserved: MemMiB(512.0),
        };
        let done = EngineEvent::Completed { task_type: "t".into(), seq: 9, attempts: 1 };
        trace_engine_event(&mut sink, &placed, 4.0);
        trace_engine_event(&mut sink, &done, 9.0);
        assert_eq!(sink.events.len(), 2);
        let (b, e) = (&sink.events[0], &sink.events[1]);
        assert_eq!(b.ph, 'b');
        assert_eq!(e.ph, 'e');
        assert_eq!(b.id, e.id, "begin/end must share the span id");
        assert_eq!(b.cat, e.cat);
        assert_eq!(b.tid, 2, "placement is tracked on its node");
        assert!(e.ts_us > b.ts_us);
    }

    #[test]
    fn kills_end_the_span_and_mark_the_cause() {
        let mut sink = VecSink::new();
        let oom =
            EngineEvent::OomKilled { task_type: "t".into(), seq: 3, attempt: 1, time_s: 8.0 };
        trace_engine_event(&mut sink, &oom, 8.0);
        assert_eq!(sink.events.len(), 2);
        assert_eq!(sink.events[0].ph, 'e');
        assert_eq!(sink.events[0].id, Some(span_id("t", 3)));
        assert_eq!(sink.events[1].ph, 'i');
        assert_eq!(sink.events[1].name, "oom-kill");
        assert_eq!(sink.events[1].cat, "kill");
    }

    #[test]
    fn every_variant_maps_to_at_least_one_event() {
        let variants: Vec<EngineEvent> = vec![
            EngineEvent::Submitted { task_type: "t".into(), seq: 0, requested: MemMiB(1.0) },
            EngineEvent::Queued { task_type: "t".into(), seq: 0, requested: MemMiB(1.0) },
            EngineEvent::Failed {
                task_type: "t".into(),
                seq: 0,
                attempt: 1,
                time_s: 1.0,
                used: MemMiB(2.0),
                allocated: MemMiB(1.0),
            },
            EngineEvent::Completed { task_type: "t".into(), seq: 0, attempts: 1 },
            EngineEvent::Placed {
                task_type: "t".into(),
                seq: 0,
                node: 0,
                time_s: 1.0,
                reserved: MemMiB(1.0),
            },
            EngineEvent::OomKilled { task_type: "t".into(), seq: 0, attempt: 1, time_s: 1.0 },
            EngineEvent::GrowDenied { task_type: "t".into(), seq: 0, segment: 1, time_s: 1.0 },
            EngineEvent::Released { task_type: "t".into(), seq: 0, instance: 0, time_s: 1.0 },
            EngineEvent::WorkflowDone {
                workflow: "w".into(),
                instance: 0,
                tasks: 3,
                time_s: 9.0,
                makespan_s: 9.0,
            },
            EngineEvent::NodeLost {
                task_type: "t".into(),
                seq: 0,
                attempt: 1,
                node: 0,
                time_s: 1.0,
            },
            EngineEvent::Preempted {
                task_type: "t".into(),
                seq: 0,
                attempt: 1,
                node: 0,
                time_s: 1.0,
            },
            EngineEvent::NodeFailed { node: 0, killed: 1, time_s: 1.0 },
            EngineEvent::NodeJoined { node: 0, time_s: 1.0 },
            EngineEvent::NodeRetired { node: 0, time_s: 1.0 },
        ];
        for ev in &variants {
            let mut sink = VecSink::new();
            trace_engine_event(&mut sink, ev, 1.0);
            assert!(!sink.events.is_empty(), "{ev:?} produced no trace event");
        }
    }
}
