//! The sanity baseline: workflow developers' static default
//! allocations (paper §IV-C, "used when running the workflows out of
//! the box").

use crate::trace::TaskRun;
use crate::units::MemMiB;

use super::{Allocation, Defaults, FailureInfo, MemoryPredictor};

/// Always allocates the configured default; never learns. On the rare
/// failure (defaults are deliberately generous) it doubles, which
/// matches how a user would bump a failing default.
#[derive(Debug, Clone, Default)]
pub struct DefaultConfigPredictor {
    defaults: Defaults,
}

impl DefaultConfigPredictor {
    pub fn new() -> Self {
        Self::default()
    }
}

impl MemoryPredictor for DefaultConfigPredictor {
    fn name(&self) -> String {
        "Default".to_string()
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, _input_mib: f64) -> Allocation {
        Allocation::Static(self.defaults.get(task_type))
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        _info: &FailureInfo,
    ) -> Allocation {
        Allocation::Static(MemMiB(failed.max_value() * 2.0))
    }

    fn observe(&mut self, _run: &TaskRun) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_configured_default() {
        let mut p = DefaultConfigPredictor::new();
        p.prime("wf/a", MemMiB(2048.0));
        assert_eq!(p.predict("wf/a", 123.0), Allocation::Static(MemMiB(2048.0)));
    }

    #[test]
    fn unknown_type_gets_global_fallback() {
        let mut p = DefaultConfigPredictor::new();
        assert_eq!(
            p.predict("nope", 1.0),
            Allocation::Static(MemMiB::from_gib(8.0))
        );
    }

    #[test]
    fn never_learns() {
        let mut p = DefaultConfigPredictor::new();
        p.prime("wf/a", MemMiB(512.0));
        let run = TaskRun {
            task_type: "wf/a".into(),
            input_mib: 10.0,
            runtime: crate::units::Seconds(2.0),
            series: crate::trace::UsageSeries::new(2.0, vec![400.0]),
            seq: 0,
        };
        p.observe(&run);
        assert_eq!(p.predict("wf/a", 10.0), Allocation::Static(MemMiB(512.0)));
    }

    #[test]
    fn failure_doubles() {
        let mut p = DefaultConfigPredictor::new();
        let failed = Allocation::Static(MemMiB(100.0));
        let info = FailureInfo::oom(1.0, 150.0, 1);
        let next = p.on_failure("wf/a", 1.0, &failed, &info);
        assert_eq!(next, Allocation::Static(MemMiB(200.0)));
    }
}
