//! Source scrubbing: turn a `.rs` file into per-line *code* text with
//! comments and string/char-literal contents blanked out (columns and
//! line structure preserved), plus the per-line comment text, the
//! `#[cfg(test)]` span map and the `lint:allow(...)` suppressions.
//!
//! The scrubber is a hand-rolled state machine, not a full lexer: the
//! rules only need to know "this text is code" vs "this text is a
//! comment or literal". It understands nested block comments, raw
//! strings (`r#"…"#`, any hash depth, `b`-prefixed too), escaped
//! string/char literals, and the char-literal/lifetime ambiguity.

/// One scrubbed source line.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Source text with comments and literal contents replaced by
    /// spaces; string delimiters are kept so rules can see something
    /// was there.
    pub code: String,
    /// Concatenated comment text of the line (for `lint:allow`).
    pub comment: String,
    /// Inside a `#[cfg(test)]`-gated item (or a test-only file).
    pub in_test: bool,
    /// Rules suppressed on this line: its own trailing `lint:allow`
    /// plus any from standalone comment lines directly above.
    pub allows: Vec<String>,
}

impl Line {
    pub fn allows_rule(&self, rule: &str) -> bool {
        self.allows.iter().any(|a| a == rule)
    }
}

/// A whole scrubbed file.
#[derive(Debug, Default)]
pub struct ScrubbedFile {
    pub lines: Vec<Line>,
}

impl ScrubbedFile {
    /// Scrubbed code rejoined with newlines (used by the span scan).
    fn joined_code(&self) -> String {
        let mut out = String::new();
        for (i, l) in self.lines.iter().enumerate() {
            if i > 0 {
                out.push('\n');
            }
            out.push_str(&l.code);
        }
        out
    }
}

/// Scrub `src` and compute test spans + suppressions.
pub fn scrub(src: &str) -> ScrubbedFile {
    let mut file = scrub_text(src);
    mark_cfg_test_spans(&mut file);
    attach_allows(&mut file);
    file
}

enum St {
    Code,
    LineComment,
    Block(u32),
    Str,
    RawStr(usize),
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn scrub_text(src: &str) -> ScrubbedFile {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines: Vec<Line> = vec![Line::default()];
    let mut st = St::Code;
    let mut i = 0;
    macro_rules! cur {
        () => {
            lines.last_mut().expect("never empty")
        };
    }
    while i < n {
        let c = chars[i];
        let next = if i + 1 < n { chars[i + 1] } else { '\0' };
        if c == '\n' {
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            lines.push(Line::default());
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && next == '/' {
                    st = St::LineComment;
                    cur!().code.push_str("  ");
                    i += 2;
                } else if c == '/' && next == '*' {
                    st = St::Block(1);
                    cur!().code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur!().code.push('"');
                    i += 1;
                } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident(chars[i - 1])) {
                    // raw string r#*" (optionally b-prefixed), byte
                    // string b", or byte char b' — else a plain ident
                    let mut j = i;
                    if chars[j] == 'b' {
                        j += 1;
                    }
                    let mut hashes = 0;
                    if j < n && chars[j] == 'r' {
                        j += 1;
                        while j < n && chars[j] == '#' {
                            hashes += 1;
                            j += 1;
                        }
                    }
                    if j < n && chars[j] == '"' && (hashes > 0 || j > i) {
                        for _ in i..j {
                            cur!().code.push(' ');
                        }
                        cur!().code.push('"');
                        st = if j > i && (chars[j - 1] == 'r' || chars[j - 1] == '#') {
                            St::RawStr(hashes)
                        } else {
                            St::Str
                        };
                        i = j + 1;
                    } else if c == 'b' && next == '\'' {
                        // byte char literal b'x' / b'\n'
                        cur!().code.push(' ');
                        i += 1; // the '\'' branch below handles the rest
                    } else {
                        cur!().code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    if next == '\\' {
                        // escaped char literal: scan to the closing quote
                        let mut j = i + 2;
                        while j < n && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        cur!().code.push('\'');
                        for _ in (i + 1)..j {
                            cur!().code.push(' ');
                        }
                        if j < n && chars[j] == '\'' {
                            cur!().code.push('\'');
                            j += 1;
                        }
                        i = j;
                    } else if i + 2 < n && chars[i + 2] == '\'' && next != '\'' && next != '\n' {
                        cur!().code.push('\'');
                        cur!().code.push(' ');
                        cur!().code.push('\'');
                        i += 3;
                    } else {
                        // lifetime or loop label
                        cur!().code.push('\'');
                        i += 1;
                    }
                } else {
                    cur!().code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur!().comment.push(c);
                cur!().code.push(' ');
                i += 1;
            }
            St::Block(depth) => {
                if c == '*' && next == '/' {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    cur!().code.push_str("  ");
                    i += 2;
                } else if c == '/' && next == '*' {
                    st = St::Block(depth + 1);
                    cur!().code.push_str("  ");
                    i += 2;
                } else {
                    cur!().comment.push(c);
                    cur!().code.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    if next == '\n' {
                        i += 1; // line continuation: newline handled above
                    } else {
                        cur!().code.push_str("  ");
                        i += 2;
                    }
                } else if c == '"' {
                    cur!().code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    cur!().code.push(' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                let closed = c == '"'
                    && i + hashes < n
                    && chars[i + 1..i + 1 + hashes].iter().all(|&h| h == '#');
                if closed {
                    cur!().code.push('"');
                    for _ in 0..hashes {
                        cur!().code.push(' ');
                    }
                    st = St::Code;
                    i += 1 + hashes;
                } else {
                    cur!().code.push(' ');
                    i += 1;
                }
            }
        }
    }
    ScrubbedFile { lines }
}

/// Mark every line covered by a `#[cfg(test)]`-gated item.
fn mark_cfg_test_spans(file: &mut ScrubbedFile) {
    let joined = file.joined_code();
    let chars: Vec<char> = joined.chars().collect();
    let line_of: Vec<usize> = {
        let mut v = Vec::with_capacity(chars.len());
        let mut l = 0;
        for &c in &chars {
            v.push(l);
            if c == '\n' {
                l += 1;
            }
        }
        v
    };
    let n = chars.len();
    let mut i = 0;
    while i < n {
        if chars[i] != '#' {
            i += 1;
            continue;
        }
        let attr_start = i;
        let mut j = i + 1;
        while j < n && chars[j].is_whitespace() {
            j += 1;
        }
        if j >= n || chars[j] != '[' {
            i += 1;
            continue;
        }
        // read the attribute body up to its matching ']'
        let mut depth = 0usize;
        let body_start = j;
        while j < n {
            match chars[j] {
                '[' => depth += 1,
                ']' => {
                    if depth <= 1 {
                        break;
                    }
                    depth -= 1;
                }
                _ => {}
            }
            j += 1;
        }
        let body: String = chars[body_start..j.min(n)].iter().collect();
        i = j.saturating_add(1);
        if !(contains_word(&body, "cfg") && contains_word(&body, "test")) {
            continue;
        }
        // skip further attributes, then span the gated item: to the
        // matching '}' of its first '{', or to a top-level ';'
        let mut k = i;
        loop {
            while k < n && chars[k].is_whitespace() {
                k += 1;
            }
            if k < n && chars[k] == '#' {
                let mut d = 0usize;
                while k < n {
                    match chars[k] {
                        '[' => d += 1,
                        ']' => {
                            if d <= 1 {
                                break;
                            }
                            d -= 1;
                        }
                        _ => {}
                    }
                    k += 1;
                }
                k += 1;
            } else {
                break;
            }
        }
        let mut end = k;
        let mut brace = 0i64;
        while end < n {
            match chars[end] {
                '{' => brace += 1,
                '}' => {
                    brace -= 1;
                    if brace == 0 {
                        break;
                    }
                }
                ';' if brace == 0 => break,
                _ => {}
            }
            end += 1;
        }
        let first = line_of.get(attr_start).copied().unwrap_or(0);
        let last = line_of.get(end.min(n.saturating_sub(1))).copied().unwrap_or(first);
        for l in first..=last.min(file.lines.len().saturating_sub(1)) {
            file.lines[l].in_test = true;
        }
        i = end.saturating_add(1);
    }
}

/// Word-boundary containment check on scrubbed text.
pub fn contains_word(text: &str, word: &str) -> bool {
    find_word(text, word).is_some()
}

/// Byte offset of the first word-boundary occurrence of `word`.
pub fn find_word(text: &str, word: &str) -> Option<usize> {
    let bytes = text.as_bytes();
    let mut from = 0;
    while let Some(pos) = text[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let before_ok = start == 0 || !is_ident(bytes[start - 1] as char);
        let after_ok = end >= bytes.len() || !is_ident(bytes[end] as char);
        if before_ok && after_ok {
            return Some(start);
        }
        from = start + 1;
    }
    None
}

/// Parse every `lint:allow(rule, rule2)` group out of comment text.
fn parse_allows(comment: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find("lint:allow(") {
        let after = &rest[pos + "lint:allow(".len()..];
        if let Some(close) = after.find(')') {
            for part in after[..close].split(',') {
                let rule = part.trim();
                if !rule.is_empty() {
                    out.push(rule.to_string());
                }
            }
            rest = &after[close + 1..];
        } else {
            break;
        }
    }
    out
}

/// Attach allows: a comment on a code line suppresses that line; a
/// standalone comment line (no code) suppresses the next code line.
/// Consecutive standalone comment lines accumulate.
fn attach_allows(file: &mut ScrubbedFile) {
    let mut pending: Vec<String> = Vec::new();
    for line in &mut file.lines {
        let own = parse_allows(&line.comment);
        if line.code.trim().is_empty() {
            pending.extend(own);
        } else {
            line.allows = own;
            line.allows.append(&mut pending);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let f = scrub("let x = \"Instant::now()\"; // Instant::now()\nInstant::now();\n");
        assert!(!f.lines[0].code.contains("Instant"));
        assert!(f.lines[0].comment.contains("Instant::now()"));
        assert!(f.lines[1].code.contains("Instant::now()"));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "/* a /* b */ still */ code1\nlet s = r#\"quote \" inside\"#; code2\n";
        let f = scrub(src);
        assert!(f.lines[0].code.contains("code1"));
        assert!(!f.lines[0].code.contains("still"));
        assert!(f.lines[1].code.contains("code2"));
        assert!(!f.lines[1].code.contains("inside"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let f = scrub("fn f<'a>(x: &'a str) { let c = 'x'; let d = '\\n'; }\n");
        let code = &f.lines[0].code;
        assert!(code.contains("<'a>"), "lifetime kept: {code}");
        assert!(!code.contains('x') || !code.contains("'x'"), "char blanked: {code}");
    }

    #[test]
    fn multiline_string_stays_scrubbed() {
        let f = scrub("let s = \"line one\nInstant::now()\nend\"; done();\n");
        assert!(!f.lines[1].code.contains("Instant"));
        assert!(f.lines[2].code.contains("done()"));
    }

    #[test]
    fn cfg_test_span_covers_module() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = scrub(src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test && f.lines[2].in_test && f.lines[3].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn allows_trailing_and_standalone() {
        let src = "a(); // lint:allow(rule-a)\n// lint:allow(rule-b)\nb();\nc();\n";
        let f = scrub(src);
        assert!(f.lines[0].allows_rule("rule-a"));
        assert!(f.lines[2].allows_rule("rule-b"));
        assert!(!f.lines[3].allows_rule("rule-b"));
    }
}
