//! Streaming JSON-lines trace reader — [`read_trace_jsonl`] without
//! the materialization: runs are yielded chunk by chunk in file order,
//! so a multi-gigabyte trace replays in constant memory.
//!
//! [`ksegments_core::trace::write_trace_jsonl_ordered`] files (what `ksegments
//! ingest` emits) stream in global submission order; plain
//! [`ksegments_core::trace::write_trace_jsonl`] files stream grouped by task
//! type, which still satisfies the per-type ordering contract of
//! [`super::TraceSource`] (and is sufficient for every per-task-type
//! consumer — only the scheduler's arrival stream cares about the
//! global order).
//!
//! [`read_trace_jsonl`]: ksegments_core::trace::read_trace_jsonl

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use ksegments_core::trace::{parse_jsonl_record, JsonlRecord, TaskRun};
use ksegments_core::units::MemMiB;

use super::TraceSource;

/// A [`TraceSource`] streaming a JSONL trace file line by line.
pub struct JsonlReader {
    path: PathBuf,
    /// All `default` records, collected by a cheap line-scan pass at
    /// open so [`TraceSource::defaults`] is available before the first
    /// chunk (the format allows defaults anywhere in the file).
    defaults: Vec<(String, MemMiB)>,
    reader: Option<BufReader<File>>,
    lineno: usize,
}

impl JsonlReader {
    /// Open a JSONL trace file for streaming. The file is scanned once
    /// for `default` records (and early syntax errors on them); run
    /// records are parsed lazily per [`TraceSource::next_chunk`].
    ///
    /// The scan is a full sequential pass by design: the grouped
    /// [`write_trace_jsonl`] layout interleaves each type's default
    /// with its runs, so stopping at the first run record would
    /// silently lose every later type's default. The pass is cheap —
    /// lines are only JSON-parsed when they can be default records —
    /// and the streaming read that follows is typically served from
    /// the page cache.
    ///
    /// [`write_trace_jsonl`]: ksegments_core::trace::write_trace_jsonl
    pub fn open(path: &Path) -> Result<JsonlReader> {
        let mut defaults_map = std::collections::BTreeMap::new();
        let scan = BufReader::new(
            File::open(path).with_context(|| format!("opening jsonl trace {}", path.display()))?,
        );
        for (lineno, line) in scan.lines().enumerate() {
            let line = line?;
            let trimmed = line.trim();
            if trimmed.is_empty() || !trimmed.contains("\"default\"") {
                continue;
            }
            let rec = parse_jsonl_record(trimmed)
                .with_context(|| format!("jsonl line {}", lineno + 1))?;
            if let JsonlRecord::Default { task_type, mem } = rec {
                defaults_map.insert(task_type, mem);
            }
        }
        let mut reader = JsonlReader {
            path: path.to_path_buf(),
            defaults: defaults_map.into_iter().collect(),
            reader: None,
            lineno: 0,
        };
        reader.rewind()?;
        Ok(reader)
    }
}

impl TraceSource for JsonlReader {
    fn origin(&self) -> String {
        self.path.display().to_string()
    }

    fn defaults(&self) -> Vec<(String, MemMiB)> {
        self.defaults.clone()
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<TaskRun>> {
        let mut out = Vec::new();
        let Some(reader) = self.reader.as_mut() else {
            return Ok(out); // exhausted
        };
        let mut line = String::new();
        while out.len() < max.max(1) {
            line.clear();
            let n = reader
                .read_line(&mut line)
                .with_context(|| format!("reading {}", self.path.display()))?;
            if n == 0 {
                self.reader = None; // EOF
                break;
            }
            self.lineno += 1;
            if line.trim().is_empty() {
                continue;
            }
            let rec = parse_jsonl_record(line.trim())
                .with_context(|| format!("jsonl line {}", self.lineno))?;
            match rec {
                // defaults were surfaced by the open-time scan
                JsonlRecord::Default { .. } => continue,
                JsonlRecord::Run(run) => out.push(run),
            }
        }
        Ok(out)
    }

    fn rewind(&mut self) -> Result<()> {
        self.reader = Some(BufReader::new(File::open(&self.path).with_context(|| {
            format!("reopening jsonl trace {}", self.path.display())
        })?));
        self.lineno = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::trace::{write_trace_jsonl_ordered, Trace, UsageSeries};
    use ksegments_core::units::Seconds;

    fn toy_trace() -> Trace {
        let mut t = Trace::new();
        t.set_default("w/b", MemMiB(2000.0));
        t.set_default("w/a", MemMiB(1000.0));
        for seq in 0..7u64 {
            t.push(TaskRun {
                task_type: if seq % 2 == 0 { "w/a".into() } else { "w/b".into() },
                input_mib: 5.0 * seq as f64,
                runtime: Seconds(6.0),
                series: UsageSeries::new(2.0, vec![1.0, 4.0 + seq as f64, 2.0]),
                seq,
            });
        }
        t.sort();
        t
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ksegments_test_jsonl");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn streams_ordered_file_in_seq_order() {
        let t = toy_trace();
        let path = tmp("ordered.jsonl");
        write_trace_jsonl_ordered(&t, &path).unwrap();
        let mut src = JsonlReader::open(&path).unwrap();
        assert_eq!(
            src.defaults(),
            vec![
                ("w/a".to_string(), MemMiB(1000.0)),
                ("w/b".to_string(), MemMiB(2000.0)),
            ]
        );
        let mut all = Vec::new();
        loop {
            let chunk = src.next_chunk(3).unwrap();
            if chunk.is_empty() {
                break;
            }
            all.extend(chunk);
        }
        let seqs: Vec<u64> = all.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4, 5, 6]);
        // round-trip equality against the in-memory model
        let expected: Vec<TaskRun> = t.all_runs_ordered().into_iter().cloned().collect();
        assert_eq!(all, expected);
    }

    #[test]
    fn rewind_restarts_the_stream() {
        let t = toy_trace();
        let path = tmp("rewind.jsonl");
        write_trace_jsonl_ordered(&t, &path).unwrap();
        let mut src = JsonlReader::open(&path).unwrap();
        let first = src.next_chunk(100).unwrap();
        assert_eq!(first.len(), 7);
        assert!(src.next_chunk(1).unwrap().is_empty());
        src.rewind().unwrap();
        let again = src.next_chunk(100).unwrap();
        assert_eq!(again, first);
    }

    #[test]
    fn malformed_run_line_reports_position() {
        let path = tmp("bad.jsonl");
        std::fs::write(
            &path,
            "{\"kind\":\"run\",\"task_type\":\"a\",\"seq\":0,\"input_mib\":1,\
             \"runtime_s\":4,\"interval_s\":2,\"samples_mib\":[1]}\n\
             {\"kind\":\"run\",\"task_type\":\"a\",\"seq\":1,\"input_mib\":1,\
             \"runtime_s\":-4,\"interval_s\":2,\"samples_mib\":[1]}\n",
        )
        .unwrap();
        let mut src = JsonlReader::open(&path).unwrap();
        let err = src.next_chunk(10).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg:?}");
        assert!(msg.contains("runtime_s"), "{msg:?}");
    }
}
