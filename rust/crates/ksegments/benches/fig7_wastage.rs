//! `cargo bench --bench fig7_wastage` — regenerates the paper's
//! Fig. 7a (average wastage), Fig. 7b (lowest-wastage wins) and
//! Fig. 7c (average retries) across the 8-method predictor zoo × 3
//! training fractions × 33 evaluated tasks, and times both the full
//! grid and the per-method evaluation.
//!
//! The printed tables are the source of the numbers recorded in
//! EXPERIMENTS.md.

use ksegments::bench_harness::{
    evaluate_method, paper_traces, run_fig7, time_once, FitterChoice,
};
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::lr_witt::LrWittPredictor;
use ksegments::predictors::ppm::PpmPredictor;
use ksegments::predictors::MemoryPredictor;

fn main() {
    println!("== fig7 benchmark (seed 42, native fitter) ==\n");

    // Per-method single-fraction timings (the unit of repeated work).
    let traces = paper_traces(42);
    let mk_list: Vec<(&str, Box<dyn Fn() -> Box<dyn MemoryPredictor>>)> = vec![
        ("ppm_improved", Box::new(|| Box::new(PpmPredictor::improved()))),
        ("lr_witt", Box::new(|| Box::new(LrWittPredictor::paper_baseline()))),
        (
            "ksegments_selective",
            Box::new(|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))),
        ),
    ];
    for (name, mk) in &mk_list {
        let (_rep, _dt) = time_once(&format!("evaluate_method/{name}@0.5"), || {
            evaluate_method(mk.as_ref(), &traces, 0.5)
        });
    }
    println!();

    // The full grid through the parallel EvalGrid — timed at one
    // worker and at all cores (identical tables either way), then
    // rendered from the parallel run.
    let workers = ksegments::sim::default_workers();
    let (_seq, _dt) = time_once("fig7 full grid (workers=1)", || {
        run_fig7(42, FitterChoice::Native, 1)
    });
    let (results, _dt) = time_once(&format!("fig7 full grid (workers={workers})"), || {
        run_fig7(42, FitterChoice::Native, workers)
    });
    println!();
    println!("{}", results.render_wastage());
    println!("{}", results.render_wins());
    println!("{}", results.render_retries());
    println!("{}", results.headline(0.75));
    println!("{}", results.headline(0.5));
}
