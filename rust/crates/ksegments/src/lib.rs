//! # ksegments — dynamic memory prediction for scientific workflow tasks
//!
//! Production-grade reproduction of Bader et al., *Predicting Dynamic
//! Memory Requirements for Scientific Workflow Tasks* (2023).
//!
//! This crate is the **compatibility facade** over the layered
//! workspace: `ksegments-core` (data model, predictors, scoring) ←
//! `ksegments-sim` (parallel grids, figures) ← `ksegments-sched`
//! (cluster + discrete-event scheduler), with `ksegments-serve`
//! (ingestion, replay, the prediction service) alongside. Every public
//! path of the pre-workspace single crate is re-exported here
//! unchanged, so downstream code — and this package's own tests,
//! benches and examples — keep compiling against `ksegments::…` while
//! a SWMS that only needs prediction can link `ksegments-core` alone.
//! See DESIGN.md §13 for the crate DAG.
//!
//! The workspace implements the complete system the paper describes:
//!
//! * the **k-Segments** method — runtime prediction + per-segment peak
//!   regressions merged into a monotone step allocation function, with
//!   Selective and Partial retry strategies ([`predictors::ksegments`]);
//! * every **baseline** it is evaluated against — workflow defaults,
//!   Tovar et al.'s PPM (+ the paper's Improved variant), and Witt
//!   et al.'s feedback-loop linear regression — plus the follow-up
//!   literature's **predictor zoo**: a Sizey-style scored model
//!   ensemble and KS+-style dynamic change-point segmentation
//!   ([`predictors`]);
//! * the **substrate**: a Nextflow-like workflow engine
//!   ([`workflow`], [`engine`]), a cluster/resource-manager model
//!   ([`cluster`]), a cgroup-style monitoring pipeline with an
//!   in-memory time-series store ([`monitoring`], [`tsdb`]), and a
//!   synthetic nf-core workload generator calibrated to the paper's
//!   eager/sarek traces ([`workload`]);
//! * the **evaluation harness**: the online simulator and wastage
//!   accounting of §IV ([`sim`], [`metrics`]), the **parallel
//!   evaluation engine** that runs the predictor × trace × fraction
//!   grid on a worker pool with bit-identical results at any worker
//!   count ([`sim::parallel`]), and the figure regeneration code
//!   ([`bench_harness`]);
//! * the **cluster scheduler**: a deterministic discrete-event
//!   simulator that turns segment-wise predictions into throughput —
//!   timed arrival streams, multi-node packing under static-peak vs
//!   segment-wise reservation policies with time-indexed admission,
//!   OOM-kill/requeue retry loops under real contention, and a
//!   (policy × predictor × cluster × arrival) sweep grid ([`sched`]);
//! * the **ingestion & replay layer**: parsers for Nextflow-style
//!   `trace.txt` + monitoring dumps, the streaming
//!   [`ingest::TraceSource`] abstraction feeding the replay engine,
//!   the scheduler and the service without materializing traces, and
//!   JSONL predictor checkpoints for warm-started replays
//!   ([`ingest`]);
//! * the **telemetry layer**: structured run tracing in the Chrome
//!   `trace_event` format (open any scheduler run in Perfetto), a
//!   Prometheus/JSON metrics registry, and per-decision prediction
//!   provenance logs ([`telemetry`]);
//! * the **prediction service**: the long-running coordinator a SWMS
//!   submits to, with task types hash-partitioned across N model
//!   threads ([`coordinator`]), fronted by a length-prefixed JSONL
//!   wire protocol over TCP with pipelining, typed protocol errors,
//!   graceful drain, checkpoint warm restart and a QPS-paced load
//!   generator ([`net`], `ksegments serve-tcp` / `ksegments loadgen`);
//! * the **AOT runtime bridge**: the batched model fit is lowered from
//!   JAX + Pallas to HLO at build time and executed through the PJRT
//!   CPU client on the online-learning path ([`runtime`]), with a
//!   bit-mirrored native implementation in [`ml`] used for
//!   differential testing and as a general-shape fallback.
//!
//! See `DESIGN.md` for the paper→module mapping and `EXPERIMENTS.md`
//! for reproduced-vs-paper results.
//!
//! ## Quickstart
//!
//! ```no_run
//! use ksegments::prelude::*;
//!
//! // Generate an eager-like trace and evaluate k-Segments on it.
//! let trace = ksegments::workload::generate_workflow_trace(
//!     &ksegments::workload::eager_workflow(), 42);
//! let cfg = ksegments::sim::SimConfig::default();
//! let mut method = ksegments::predictors::ksegments::KSegmentsPredictor::native(
//!     4, ksegments::predictors::ksegments::RetryStrategy::Selective);
//! let report = ksegments::sim::simulate_trace(&trace, &mut method, &cfg);
//! println!("wastage = {:.2} GB·s", report.total_wastage_gbs());
//! ```

pub mod bench_harness;

// Prediction-side foundation (ksegments-core), under its historical
// module names. `wastage` is also exposed under its new canonical
// name; `metrics` below is the compatibility alias.
pub use ksegments_core::{
    ml, monitoring, predictors, rng, runtime, trace, tsdb, units, util, wastage, workload,
};

// Scheduling layer (ksegments-sched).
pub use ksegments_sched::{cluster, engine, sched};

// Serving layer (ksegments-serve). `ingest` re-exports the core
// `source` items (TraceSource, InMemorySource, materialize) next to
// the file-backed readers, so the historical flat paths survive.
pub use ksegments_serve::{coordinator, ingest, net};

/// Wastage accounting and report tables (compatibility alias).
///
/// The canonical home is [`wastage`] (`ksegments_core::wastage`) —
/// renamed from `metrics` when the workspace split landed, because the
/// old name collided with the operational metrics registry in
/// [`telemetry::registry`]. This alias keeps `ksegments::metrics::…`
/// paths compiling; new code should prefer [`wastage`].
pub mod metrics {
    pub use ksegments_core::wastage::*;
}

/// The online evaluation protocol and its parallel fan-out.
///
/// Stitches the historical `ksegments::sim` surface back together
/// from two workspace layers: the single-run scoring kernel
/// (`ksegments_core::scoring`) and the worker-pool grid
/// (`ksegments_sim::parallel`).
pub mod sim {
    pub use ksegments_core::scoring::*;
    pub use ksegments_sim::parallel;
    pub use ksegments_sim::parallel::{
        default_workers, eval_cell, eval_sources, parallel_map, EvalCell, EvalGrid, GridResults,
        PredictorFactory,
    };
}

/// Cross-cutting observability: run tracing, metrics, provenance.
///
/// The engine-agnostic primitives live in `ksegments_core::telemetry`;
/// the engine-event mapping ([`trace_engine_event`]) lives in
/// `ksegments_sched::telemetry_ext`. Both are re-exported here under
/// the historical flat path.
///
/// [`trace_engine_event`]: ksegments_sched::telemetry_ext::trace_engine_event
pub mod telemetry {
    pub use ksegments_core::telemetry::*;
    pub use ksegments_sched::telemetry_ext::trace_engine_event;
}

/// Workflow DAG specifications (re-export; lives in [`workload`]).
pub mod workflow {
    pub use crate::workload::{TaskTypeSpec, WorkflowSpec};
}

/// Most-used types, re-exported for downstream convenience.
pub mod prelude {
    pub use crate::ingest::{replay_source, Checkpoint, InMemorySource, TraceSource};
    pub use crate::metrics::{MethodReport, TaskReport};
    pub use crate::ml::step_fn::StepFunction;
    pub use crate::net::{NetClient, NetServer, NetServerConfig};
    pub use crate::predictors::{Allocation, FailureInfo, MemoryPredictor};
    pub use crate::sched::{
        schedule_stream, schedule_trace, schedule_workflows, ReservationPolicy, SchedConfig,
        SchedReport, WorkflowSource,
    };
    pub use crate::sim::{simulate_trace, SimConfig};
    pub use crate::telemetry::{
        ChromeTraceSink, NullSink, Registry, RunTelemetry, TraceEvent, TraceSink, VecSink,
    };
    pub use crate::trace::{TaskRun, Trace, UsageSeries};
    pub use crate::units::{GbSeconds, MemMiB, Seconds};
    pub use crate::workload::{eager_workflow, generate_workflow_trace, sarek_workflow};
}
