//! Ablation studies for the design choices DESIGN.md calls out
//! (§IV-E discussion + §V future work):
//!
//! * the historical-error **offsets** (§III-B) — the paper's
//!   "avoid underpredictions" mechanism, on vs off;
//! * the **retry factor** l (paper default 2);
//! * the sliding **history window** feeding the fit;
//! * Witt et al.'s three **LR offset strategies** (mean±σ / mean− / max);
//! * fixed k = 4 vs the Fig. 8 best fixed k vs **adaptive per-task k**
//!   (our implementation of the paper's §V proposal);
//! * the **predictor zoo** head-to-head (k-Segments vs Sizey ensemble
//!   vs KS+ dynamic segmentation, DESIGN.md §6);
//! * the ensemble's **RAQ interpolation weight** α (failure avoidance
//!   vs allocation efficiency).
//!
//! Exposed through `ksegments ablate` and `cargo bench --bench
//! ablations`; results recorded in EXPERIMENTS.md §Ablations.

use crate::figures::{evaluate_method, make_method, paper_traces, FitterChoice};
use crate::parallel::parallel_map;
use ksegments_core::predictors::adaptive_k::AdaptiveKPredictor;
use ksegments_core::predictors::ensemble::{EnsembleConfig, EnsemblePredictor};
use ksegments_core::predictors::ksegments::{KSegmentsConfig, KSegmentsPredictor, RetryStrategy};
use ksegments_core::predictors::lr_witt::{LrWittPredictor, OffsetStrategy};
use ksegments_core::predictors::MemoryPredictor;
use ksegments_core::trace::Trace;
use ksegments_core::units::MemMiB;

/// One ablation row: configuration label → (avg wastage GB·s, avg retries).
pub type AblationRow = (String, f64, f64);

fn run_one(mk: &dyn Fn() -> Box<dyn MemoryPredictor>, traces: &[Trace], frac: f64) -> (f64, f64) {
    let rep = evaluate_method(mk, traces, frac);
    (rep.avg_wastage_gbs(), rep.avg_retries())
}

fn kseg_with(cfg: KSegmentsConfig, strategy: RetryStrategy) -> Box<dyn MemoryPredictor> {
    Box::new(KSegmentsPredictor::with_fitter(
        Box::new(ksegments_core::ml::fitter::NativeFitter),
        cfg,
        strategy,
    ))
}

/// Offsets on/off (both retry strategies).
pub fn ablate_offsets(traces: &[Trace], frac: f64, workers: usize) -> Vec<AblationRow> {
    let combos: Vec<(RetryStrategy, bool)> = [RetryStrategy::Selective, RetryStrategy::Partial]
        .into_iter()
        .flat_map(|s| [(s, true), (s, false)])
        .collect();
    parallel_map(combos.len(), workers, |i| {
        let (strategy, use_offsets) = combos[i];
        let cfg = KSegmentsConfig { use_offsets, ..KSegmentsConfig::default() };
        let (w, r) = run_one(&|| kseg_with(cfg.clone(), strategy), traces, frac);
        (
            format!(
                "{} / offsets {}",
                strategy.label(),
                if use_offsets { "ON " } else { "OFF" }
            ),
            w,
            r,
        )
    })
}

/// Retry factor l sweep (paper default l = 2).
pub fn ablate_retry_factor(
    traces: &[Trace],
    frac: f64,
    ls: &[f64],
    workers: usize,
) -> Vec<AblationRow> {
    parallel_map(ls.len(), workers, |i| {
        let l = ls[i];
        let cfg = KSegmentsConfig { retry_factor: l, ..KSegmentsConfig::default() };
        let (w, r) = run_one(&|| kseg_with(cfg.clone(), RetryStrategy::Selective), traces, frac);
        (format!("l = {l:.2}"), w, r)
    })
}

/// History window sweep (paper's online setting keeps all history; our
/// artifact pads to 64 — how much does the window matter?).
pub fn ablate_history_window(
    traces: &[Trace],
    frac: f64,
    windows: &[usize],
    workers: usize,
) -> Vec<AblationRow> {
    parallel_map(windows.len(), workers, |i| {
        let n_hist = windows[i];
        let cfg = KSegmentsConfig { n_hist, ..KSegmentsConfig::default() };
        let (w, r) = run_one(&|| kseg_with(cfg.clone(), RetryStrategy::Selective), traces, frac);
        (format!("n_hist = {n_hist}"), w, r)
    })
}

/// Witt et al.'s offset strategies head-to-head.
pub fn ablate_lr_offsets(traces: &[Trace], frac: f64, workers: usize) -> Vec<AblationRow> {
    let strategies = [
        OffsetStrategy::MeanPlusStd,
        OffsetStrategy::MeanNeg,
        OffsetStrategy::MaxUnder,
    ];
    parallel_map(strategies.len(), workers, |i| {
        let s = strategies[i];
        let (w, r) = run_one(
            &|| Box::new(LrWittPredictor::new(s, MemMiB::from_gib(128.0))),
            traces,
            frac,
        );
        (format!("LR offset {}", s.label()), w, r)
    })
}

/// Fixed k vs adaptive per-task k (§V future work).
pub fn ablate_adaptive_k(traces: &[Trace], frac: f64, workers: usize) -> Vec<AblationRow> {
    let fixed_ks = [1usize, 4, 8, 13];
    parallel_map(fixed_ks.len() + 1, workers, |i| {
        if let Some(&k) = fixed_ks.get(i) {
            let cfg = KSegmentsConfig { k, ..KSegmentsConfig::default() };
            let (w, r) =
                run_one(&|| kseg_with(cfg.clone(), RetryStrategy::Selective), traces, frac);
            (format!("fixed k = {k}"), w, r)
        } else {
            let (w, r) = run_one(
                &|| Box::new(AdaptiveKPredictor::native(RetryStrategy::Selective)),
                traces,
                frac,
            );
            ("adaptive per-task k".to_string(), w, r)
        }
    })
}

/// Predictor-zoo head-to-head: the paper's method against the
/// follow-up-literature competitors at one training fraction (the
/// ablation-sized companion of the full Fig. 7 grid).
pub fn ablate_zoo(traces: &[Trace], frac: f64, workers: usize) -> Vec<AblationRow> {
    let keys = ["ksegments-selective", "ksegments-partial", "ensemble", "dynseg", "ppm-improved"];
    parallel_map(keys.len(), workers, |i| {
        let key = keys[i];
        let mk = || make_method(key, FitterChoice::Native).expect("zoo key");
        let name = mk().name();
        let (w, r) = run_one(&mk, traces, frac);
        (name, w, r)
    })
}

/// The ensemble's RAQ interpolation weight α: 0 scores pure allocation
/// efficiency, 1 pure failure avoidance.
pub fn ablate_ensemble_alpha(
    traces: &[Trace],
    frac: f64,
    alphas: &[f64],
    workers: usize,
) -> Vec<AblationRow> {
    parallel_map(alphas.len(), workers, |i| {
        let alpha = alphas[i];
        let cfg = EnsembleConfig { alpha, ..EnsembleConfig::default() };
        let (w, r) = run_one(
            &|| Box::new(EnsemblePredictor::with_config(cfg.clone())),
            traces,
            frac,
        );
        (format!("α = {alpha:.2}"), w, r)
    })
}

/// Render rows as a markdown table.
pub fn render_ablation(title: &str, rows: &[AblationRow]) -> String {
    let mut out = format!("## Ablation — {title}\n\n| configuration | avg wastage (GB·s) | avg retries |\n|---|---|---|\n");
    for (label, w, r) in rows {
        out.push_str(&format!("| {label} | {w:.3} | {r:.3} |\n"));
    }
    out
}

/// All ablations at the paper's mid setting (50 % training), each
/// family fanned out over `workers` threads; the paper traces are
/// generated once and shared by every row (they are read-only, like
/// the grid's cells).
pub fn run_all(seed: u64, workers: usize) -> String {
    let frac = 0.5;
    let traces = paper_traces(seed);
    let mut out = String::new();
    out.push_str(&render_ablation(
        "error offsets (§III-B)",
        &ablate_offsets(&traces, frac, workers),
    ));
    out.push('\n');
    out.push_str(&render_ablation(
        "retry factor l (§III-D)",
        &ablate_retry_factor(&traces, frac, &[1.25, 1.5, 2.0, 3.0], workers),
    ));
    out.push('\n');
    out.push_str(&render_ablation(
        "history window",
        &ablate_history_window(&traces, frac, &[8, 16, 32, 64], workers),
    ));
    out.push('\n');
    out.push_str(&render_ablation(
        "LR offset strategies (Witt et al.)",
        &ablate_lr_offsets(&traces, frac, workers),
    ));
    out.push('\n');
    out.push_str(&render_ablation(
        "fixed vs adaptive k (§V)",
        &ablate_adaptive_k(&traces, frac, workers),
    ));
    out.push('\n');
    out.push_str(&render_ablation(
        "predictor zoo head-to-head (DESIGN.md §6)",
        &ablate_zoo(&traces, frac, workers),
    ));
    out.push('\n');
    out.push_str(&render_ablation(
        "ensemble RAQ weight α",
        &ablate_ensemble_alpha(&traces, frac, &[0.0, 0.25, 0.5, 0.75, 1.0], workers),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full ablations run in the bench target; unit tests exercise the
    // plumbing on the smaller eager-only workload via low seeds.

    #[test]
    fn offsets_matter() {
        let rows = ablate_offsets(&paper_traces(42), 0.5, 2);
        assert_eq!(rows.len(), 4);
        // offsets OFF must cost more retries (that is their purpose)
        let on = rows.iter().find(|r| r.0.contains("Selective / offsets ON")).unwrap();
        let off = rows.iter().find(|r| r.0.contains("Selective / offsets OFF")).unwrap();
        assert!(off.2 > on.2, "offsets off should retry more: {off:?} vs {on:?}");
    }

    #[test]
    fn zoo_rows_cover_competitors() {
        let rows = ablate_zoo(&paper_traces(42), 0.5, 4);
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().any(|r| r.0 == "Sizey Ensemble"));
        assert!(rows.iter().any(|r| r.0 == "KS+ DynSeg Selective"));
        assert!(rows.iter().any(|r| r.0 == "k-Segments Selective"));
        // every zoo member actually scored tasks
        assert!(rows.iter().all(|r| r.1.is_finite() && r.1 > 0.0));
    }

    #[test]
    fn render_contains_rows() {
        let rows = vec![("a".to_string(), 1.0, 0.5)];
        let s = render_ablation("t", &rows);
        assert!(s.contains("| a | 1.000 | 0.500 |"));
    }
}
