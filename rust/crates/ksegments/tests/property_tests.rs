//! Randomized property tests over the core invariants (the offline
//! crate cache has no proptest; cases are driven by the crate's own
//! deterministic RNG — failures print the seed, so every case is
//! replayable).

use ksegments::metrics::{MethodReport, TaskReport};
use ksegments::ml::fitter::{FitInput, KsegFitter, NativeFitter};
use ksegments::ml::segmentation::{seg_peaks, segment_bounds};
use ksegments::ml::step_fn::StepFunction;
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::{Allocation, FailureInfo, MemoryPredictor};
use ksegments::rng::Rng;
use ksegments::sim::{simulate_attempt, AttemptOutcome};
use ksegments::trace::{TaskRun, UsageSeries};
use ksegments::units::{GbSeconds, MemMiB, Seconds};

const CASES: u64 = 300;

fn random_series(rng: &mut Rng) -> UsageSeries {
    let n = 1 + rng.below(400) as usize;
    let peak = rng.uniform(1.0, 30_000.0);
    let samples: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, peak)).collect();
    UsageSeries::new(2.0, samples)
}

fn random_step_fn(rng: &mut Rng) -> StepFunction {
    let k = 1 + rng.below(16) as usize;
    let rt = rng.uniform(4.0, 4000.0);
    let values: Vec<f64> = (0..k).map(|_| rng.uniform(-50.0, 20_000.0)).collect();
    StepFunction::monotone_clamped(Seconds(rt), values, MemMiB(100.0), MemMiB(131_072.0))
}

/// segment_bounds: covers [0, t) exactly, contiguously, non-empty.
#[test]
fn prop_segment_bounds_partition() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let t = 1 + rng.below(5000) as usize;
        let k = 1 + rng.below(t.min(64) as u64) as usize;
        let b = segment_bounds(t, k);
        assert_eq!(b.len(), k, "seed {seed}");
        assert_eq!(b[0].0, 0, "seed {seed}");
        assert_eq!(b[k - 1].1, t, "seed {seed}");
        for w in b.windows(2) {
            assert_eq!(w[0].1, w[1].0, "seed {seed}");
        }
        assert!(b.iter().all(|(lo, hi)| hi > lo), "seed {seed}");
    }
}

/// seg_peaks: max of segment peaks == global peak; every segment peak
/// is attained within its bounds.
#[test]
fn prop_seg_peaks_consistent() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 10_000);
        let series = random_series(&mut rng);
        let t = series.len();
        let k = 1 + rng.below(t.min(16) as u64) as usize;
        let peaks = seg_peaks(series.samples(), k);
        let global = series.peak();
        let max_peak = peaks.iter().copied().fold(f64::MIN, f64::max);
        assert_eq!(max_peak, global, "seed {seed}");
        for ((lo, hi), p) in segment_bounds(t, k).into_iter().zip(&peaks) {
            assert!(series.samples()[lo..hi].contains(p), "seed {seed}");
        }
    }
}

/// Peak-preserving resample never loses the global peak and never
/// invents values above it.
#[test]
fn prop_resample_preserves_peak() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 20_000);
        let series = random_series(&mut rng);
        let t_max = 1 + rng.below(512) as usize;
        let r = series.resample_peaks(t_max);
        assert_eq!(r.len(), t_max, "seed {seed}");
        let rmax = r.iter().copied().fold(f64::MIN, f64::max);
        assert_eq!(rmax, series.peak(), "seed {seed}");
        let smin = series.samples().iter().copied().fold(f64::MAX, f64::min);
        assert!(r.iter().all(|&v| v >= smin && v <= series.peak()), "seed {seed}");
    }
}

/// monotone_clamped: monotone, floored, capped, k preserved; retry
/// scaling keeps all three invariants and never lowers any segment.
#[test]
fn prop_step_fn_invariants() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 30_000);
        let f = random_step_fn(&mut rng);
        assert!(f.is_monotone(), "seed {seed}");
        assert!(f.values().iter().all(|&v| (100.0..=131_072.0).contains(&v)), "seed {seed}");

        let k = f.k();
        let from = rng.below(k as u64) as usize;
        let to = if rng.f64() < 0.5 { from + 1 } else { k }; // selective | partial
        let g = f.scale_segments(from, to, 2.0, MemMiB(131_072.0));
        assert!(g.is_monotone(), "seed {seed}");
        assert_eq!(g.k(), k, "seed {seed}");
        for s in 0..k {
            assert!(g.values()[s] >= f.values()[s] - 1e-9, "seed {seed} segment {s} decreased");
        }
        // scaled segments actually doubled (unless already at the cap)
        for s in from..to {
            let expect = (f.values()[s] * 2.0).min(131_072.0);
            assert!(g.values()[s] >= expect - 1e-6, "seed {seed} segment {s} under-scaled");
        }
    }
}

/// simulate_attempt: success wastage is non-negative and bounded by
/// the allocation integral; failure implies the usage really exceeded
/// the allocation at the failure instant.
#[test]
fn prop_attempt_accounting_sound() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 40_000);
        let series = random_series(&mut rng);
        let alloc = if rng.f64() < 0.5 {
            Allocation::Static(MemMiB(rng.uniform(50.0, 40_000.0)))
        } else {
            Allocation::Dynamic(random_step_fn(&mut rng))
        };
        match simulate_attempt(&series, &alloc, 1) {
            AttemptOutcome::Success { wastage_mibs } => {
                assert!(wastage_mibs >= -1e-6, "seed {seed}: negative wastage");
                // success means alloc covered usage at every sample
                for (t, u) in series.iter_timed() {
                    assert!(
                        alloc.value_at(t + 1e-9) >= u - 1e-9,
                        "seed {seed}: success but usage {u} above alloc at {t}"
                    );
                }
            }
            AttemptOutcome::Failure { info, wastage_mibs } => {
                assert!(wastage_mibs >= -1e-6, "seed {seed}");
                assert!(
                    info.used_mib > alloc.value_at(info.time_s + 1e-9) - 1e-6,
                    "seed {seed}: failure without excess usage"
                );
                assert!(info.time_s >= 0.0 && info.time_s <= series.duration().0, "seed {seed}");
            }
        }
    }
}

/// NativeFitter: offsets always cover the training rows (no historical
/// underprediction survives) and the runtime offset is conservative.
#[test]
fn prop_fit_offsets_cover_history() {
    for seed in 0..100 {
        let mut rng = Rng::new(seed + 50_000);
        let n = 1 + rng.below(40) as usize;
        let t = 8 + rng.below(128) as usize;
        let k = 1 + rng.below(t.min(16) as u64) as usize;
        let mut input = FitInput::default();
        for _ in 0..n {
            let x = rng.uniform(1.0, 10_000.0);
            let peak = rng.uniform(10.0, 20_000.0);
            input.x.push(x);
            input.runtime.push(rng.uniform(2.0, 5_000.0));
            input
                .series
                .push((0..t).map(|_| rng.uniform(0.0, peak)).collect());
        }
        let fit = NativeFitter.fit(&input, k);
        for (row, series) in input.series.iter().enumerate() {
            let x = input.x[row];
            let preds = fit.predict_segments(x);
            for (p, pk) in preds.iter().zip(seg_peaks(series, k)) {
                assert!(
                    *p >= pk - 1e-6 * pk.abs().max(1.0),
                    "seed {seed} row {row}: prediction {p} under historical peak {pk}"
                );
            }
            assert!(
                fit.predict_runtime(x) <= input.runtime[row] + 1e-6 * input.runtime[row],
                "seed {seed} row {row}: runtime overpredicted after offset"
            );
        }
    }
}

/// The predictor's full retry loop always terminates and ends with an
/// allocation that covers the observed failure.
#[test]
fn prop_retry_loop_progresses() {
    for seed in 0..100 {
        let mut rng = Rng::new(seed + 60_000);
        let strategy = if rng.f64() < 0.5 {
            RetryStrategy::Selective
        } else {
            RetryStrategy::Partial
        };
        let mut p = KSegmentsPredictor::native(1 + rng.below(8) as usize, strategy);
        p.prime("t", MemMiB(rng.uniform(100.0, 2000.0)));
        // train on a few random runs
        for i in 0..(2 + rng.below(10)) {
            let series = random_series(&mut rng);
            p.observe(&TaskRun {
                task_type: "t".into(),
                input_mib: rng.uniform(10.0, 5000.0),
                runtime: series.duration(),
                series,
                seq: i,
            });
        }
        let victim = random_series(&mut rng);
        let mut alloc = p.predict("t", rng.uniform(10.0, 5000.0));
        let mut attempts = 0;
        loop {
            attempts += 1;
            assert!(attempts <= 64, "seed {seed}: retry loop did not terminate");
            match simulate_attempt(&victim, &alloc, attempts) {
                AttemptOutcome::Success { .. } => break,
                AttemptOutcome::Failure { info, .. } => {
                    let next = p.on_failure("t", 100.0, &alloc, &info);
                    assert!(
                        next.value_at(info.time_s + 1e-9) > alloc.value_at(info.time_s + 1e-9)
                            || next.value_at(info.time_s + 1e-9) > info.used_mib,
                        "seed {seed}: no progress at failure point"
                    );
                    alloc = next;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Report-merging properties (the parallel grid and the sharded service
// both combine partial reports; order of combination must not matter).
// ---------------------------------------------------------------------

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

/// TaskReport: merging per-run chunks in any permutation reproduces
/// the single sequential pass — counts exactly, float totals to within
/// addition-reordering tolerance, samples as a multiset.
#[test]
fn prop_task_report_merge_permutation_invariant() {
    for seed in 0..200 {
        let mut rng = Rng::new(seed + 70_000);
        let n_runs = 1 + rng.below(60) as usize;
        let runs: Vec<(f64, u32)> = (0..n_runs)
            .map(|_| (rng.uniform(0.0, 500.0), rng.below(6) as u32))
            .collect();

        // single sequential pass
        let mut sequential = TaskReport::new("t");
        for &(w, r) in &runs {
            sequential.record(GbSeconds(w), r);
        }

        // chop into chunks, shuffle the chunk order, merge
        let n_chunks = 1 + rng.below(8) as usize;
        let chunk_len = n_runs.div_ceil(n_chunks);
        let mut chunks: Vec<TaskReport> = runs
            .chunks(chunk_len)
            .map(|chunk| {
                let mut part = TaskReport::new("t");
                for &(w, r) in chunk {
                    part.record(GbSeconds(w), r);
                }
                part
            })
            .collect();
        rng.shuffle(&mut chunks);
        let mut merged = TaskReport::new("t");
        for part in chunks {
            merged.merge(part);
        }

        assert_eq!(merged.n_scored, sequential.n_scored, "seed {seed}");
        assert_eq!(merged.total_retries, sequential.total_retries, "seed {seed}");
        assert!(
            close(merged.total_wastage.0, sequential.total_wastage.0),
            "seed {seed}: {} vs {}",
            merged.total_wastage.0,
            sequential.total_wastage.0
        );
        assert!(close(merged.avg_wastage_gbs(), sequential.avg_wastage_gbs()), "seed {seed}");
        assert!(close(merged.avg_retries(), sequential.avg_retries()), "seed {seed}");
        let mut a = merged.per_run_wastage.clone();
        let mut b = sequential.per_run_wastage.clone();
        a.sort_by(f64::total_cmp);
        b.sort_by(f64::total_cmp);
        assert_eq!(a, b, "seed {seed}: per-run samples are not the same multiset");
    }
}

/// MethodReport: merging per-(task, chunk) partial reports in any
/// permutation matches the sequential single-report totals, per task
/// type and overall.
#[test]
fn prop_method_report_merge_permutation_invariant() {
    for seed in 0..200 {
        let mut rng = Rng::new(seed + 80_000);
        let n_types = 1 + rng.below(6) as usize;
        let types: Vec<String> = (0..n_types).map(|i| format!("w/t{i}")).collect();

        // sequential reference: one report, tasks recorded in type order
        let mut parts: Vec<MethodReport> = Vec::new();
        let mut reference_tasks: Vec<TaskReport> = Vec::new();
        for ty in &types {
            let n_runs = 1 + rng.below(30) as usize;
            let runs: Vec<(f64, u32)> = (0..n_runs)
                .map(|_| (rng.uniform(0.0, 300.0), rng.below(4) as u32))
                .collect();
            let mut whole = TaskReport::new(ty);
            for &(w, r) in &runs {
                whole.record(GbSeconds(w), r);
            }
            reference_tasks.push(whole);
            // split this type's runs into partial single-task reports
            let chunk_len = 1 + rng.below(n_runs as u64) as usize;
            for chunk in runs.chunks(chunk_len) {
                let mut part = TaskReport::new(ty);
                for &(w, r) in chunk {
                    part.record(GbSeconds(w), r);
                }
                parts.push(MethodReport::new("m", 0.5, vec![part]));
            }
        }
        let reference = MethodReport::new("m", 0.5, reference_tasks);

        rng.shuffle(&mut parts);
        let merged = MethodReport::merged(parts).expect("non-empty");

        assert_eq!(merged.tasks.len(), reference.tasks.len(), "seed {seed}");
        assert_eq!(merged.total_retries(), reference.total_retries(), "seed {seed}");
        assert!(
            close(merged.total_wastage_gbs(), reference.total_wastage_gbs()),
            "seed {seed}"
        );
        assert!(close(merged.avg_wastage_gbs(), reference.avg_wastage_gbs()), "seed {seed}");
        assert!(close(merged.avg_retries(), reference.avg_retries()), "seed {seed}");
        for ty in &types {
            let m = merged.task(ty).expect("type present after merge");
            let r = reference.task(ty).unwrap();
            assert_eq!(m.n_scored, r.n_scored, "seed {seed} type {ty}");
            assert_eq!(m.total_retries, r.total_retries, "seed {seed} type {ty}");
            assert!(close(m.total_wastage.0, r.total_wastage.0), "seed {seed} type {ty}");
        }
    }
}

/// FailureInfo attempt numbering is propagated untouched.
#[test]
fn prop_failure_attempt_number() {
    let series = UsageSeries::new(2.0, vec![10.0, 1000.0]);
    for attempt in 1..10 {
        match simulate_attempt(&series, &Allocation::Static(MemMiB(100.0)), attempt) {
            AttemptOutcome::Failure { info, .. } => assert_eq!(info.attempt, attempt),
            _ => panic!("expected failure"),
        }
    }
    let _ = FailureInfo::oom(0.0, 0.0, 1);
}
