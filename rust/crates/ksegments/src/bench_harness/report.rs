//! One-shot report generation: every figure + the ablation suite +
//! runtime validation, rendered into a single markdown document
//! (`ksegments report --out FILE`). Useful for regenerating the data
//! section of EXPERIMENTS.md after any change.

use crate::bench_harness::ablation::run_all as run_ablations;
use crate::bench_harness::figures::{run_fig1, run_fig4, run_fig7_selected, run_fig8, FitterChoice};
use crate::bench_harness::throughput::{run_dag_throughput, run_failure_sweep, run_throughput};
use crate::workload::eager_workflow;

/// Build the complete experiments report (may take ~seconds); the
/// fig7/fig8 grids and the ablation suite fan out over `workers`
/// threads — the rendered tables are identical for any worker count.
/// `methods` selects the Fig. 7 rows (resolved from `--method`;
/// [`crate::bench_harness::figures::METHOD_KEYS`] = `--method all`,
/// the full predictor zoo).
pub fn full_report(
    seed: u64,
    choice: FitterChoice,
    workers: usize,
    methods: &[&'static str],
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "# ksegments experiment report\n\nseed = {seed}, fitter = {choice:?}, methods = {methods:?}\n\n"
    ));

    out.push_str(&run_fig1(seed));
    out.push('\n');
    out.push_str(&run_fig4(seed, choice));
    out.push('\n');

    let fig7 = run_fig7_selected(seed, choice, workers, methods);
    out.push_str(&fig7.render_wastage());
    out.push('\n');
    out.push_str(&fig7.render_wins());
    out.push('\n');
    out.push_str(&fig7.render_retries());
    out.push('\n');
    out.push_str("```\n");
    out.push_str(&fig7.headline(0.75));
    out.push_str(&fig7.headline(0.5));
    out.push_str("```\n\n");

    let ks: Vec<usize> = (1..=15).collect();
    for task in ["eager/qualimap", "eager/adapter_removal"] {
        out.push_str(&run_fig8(seed, choice, task, &ks, workers).render());
        out.push('\n');
    }

    let sweep = run_throughput(seed, &[2.0, 5.0, 10.0], workers);
    out.push_str(&sweep.render_makespan());
    out.push('\n');
    out.push_str(&sweep.render_queue_wait());
    out.push('\n');
    out.push_str(&sweep.render_packing());
    out.push('\n');

    let dag = run_dag_throughput(&eager_workflow(), seed, &[2, 4], workers);
    out.push_str(&dag.render_workflow_makespan());
    out.push('\n');
    out.push_str(&dag.render_stretch());
    out.push('\n');
    out.push_str(&dag.render_stragglers());
    out.push('\n');

    let adversity = run_failure_sweep(seed, workers);
    out.push_str(&adversity.render_makespan());
    out.push('\n');
    out.push_str(&adversity.render_disruption());
    out.push('\n');
    out.push_str(&adversity.render_wastage());
    out.push('\n');

    out.push_str(&run_ablations(seed, workers));
    out
}

#[cfg(test)]
mod tests {
    // full_report is exercised end-to-end by the CLI; keep a cheap
    // structural test here so regressions in any section surface fast.
    use super::*;

    #[test]
    #[ignore = "runs the full grid (~10 s); covered by `ksegments report` in CI-style runs"]
    fn report_contains_every_section() {
        let r = full_report(
            42,
            FitterChoice::Native,
            crate::sim::default_workers(),
            crate::bench_harness::figures::METHOD_KEYS,
        );
        for needle in [
            "Fig 1",
            "Fig 4",
            "Fig 7a",
            "Fig 7b",
            "Fig 7c",
            "Fig 8",
            "Throughput — makespan",
            "DAG throughput — mean workflow makespan",
            "critical-path stretch",
            "Failure domains — makespan",
            "blameless kills",
            "Ablation — error offsets",
            "fixed vs adaptive k",
            "predictor zoo head-to-head",
            "ensemble RAQ weight",
            "Sizey Ensemble",
            "KS+ DynSeg",
        ] {
            assert!(r.contains(needle), "missing section {needle}");
        }
    }
}
