//! Adaptive per-task k — the paper's §V future work, implemented.
//!
//! The paper fixes k = 4 for all tasks and notes (§IV-E, Fig. 8) that
//! the best k is task-specific, with zigzag wastage-vs-k curves that
//! defeat gradient search; §V proposes explore/exploit techniques.
//!
//! This implementation goes one step further than a bandit: in this
//! problem the learner is **full-information** — once a run completes
//! we hold its entire usage series, so the wastage every candidate k
//! *would* have produced is exactly computable (counterfactual replay
//! of the predict → fail → retry loop against the recorded series).
//! Each completion therefore updates an EWMA wastage score for every
//! candidate simultaneously, and predictions use the current argmin.
//! No exploration is wasted on bad arms; zigzag landscapes are handled
//! because every candidate is tracked, not locally searched.
//! (A true bandit remains necessary only where counterfactual replay
//! is impossible — e.g. allocation-dependent task behaviour.)

use std::collections::BTreeMap;

use crate::ml::fitter::{FitResult, KsegFitter, NativeFitter};
use crate::ml::step_fn::StepFunction;
use crate::scoring::{simulate_attempt, AttemptOutcome};
use crate::trace::TaskRun;
use crate::units::MemMiB;

use super::history::HistoryMap;
use super::ksegments::{KSegmentsConfig, RetryStrategy};
use super::{Allocation, Defaults, FailureInfo, MemoryPredictor};

/// Default candidate grid: covers the paper's Fig. 8 sweep range with
/// geometric-ish spacing.
pub const DEFAULT_CANDIDATES: &[usize] = &[1, 2, 3, 4, 6, 8, 10, 13, 16];

/// EWMA smoothing for candidate scores: recent workload behaviour
/// dominates, echoing the online setting.
const EWMA_ALPHA: f64 = 0.2;

#[derive(Debug, Clone)]
struct KState {
    /// EWMA counterfactual wastage (MiB·s) per candidate.
    score: Vec<f64>,
    /// Completions scored so far.
    n: u64,
}

/// k-Segments with online per-task segment-count selection.
pub struct AdaptiveKPredictor {
    cfg: KSegmentsConfig,
    strategy: RetryStrategy,
    candidates: Vec<usize>,
    fitter: Box<dyn KsegFitter>,
    defaults: Defaults,
    histories: HistoryMap,
    states: BTreeMap<String, KState>,
    /// Fit cache keyed by (task, k) and history version.
    fits: BTreeMap<(String, usize), (u64, FitResult)>,
}

impl AdaptiveKPredictor {
    pub fn new(
        fitter: Box<dyn KsegFitter>,
        cfg: KSegmentsConfig,
        strategy: RetryStrategy,
        candidates: Vec<usize>,
    ) -> Self {
        assert!(!candidates.is_empty());
        assert!(candidates.iter().all(|&k| k >= 1 && k <= cfg.t_resample));
        let histories = HistoryMap::new(cfg.n_hist, cfg.t_resample);
        AdaptiveKPredictor {
            cfg,
            strategy,
            candidates,
            fitter,
            defaults: Defaults::default(),
            histories,
            states: BTreeMap::new(),
            fits: BTreeMap::new(),
        }
    }

    /// Native-backend adaptive predictor with the default grid.
    pub fn native(strategy: RetryStrategy) -> Self {
        Self::new(
            Box::new(NativeFitter),
            KSegmentsConfig::default(),
            strategy,
            DEFAULT_CANDIDATES.to_vec(),
        )
    }

    /// Currently selected k for a task (the default 4 until scored).
    pub fn current_k(&self, task_type: &str) -> usize {
        match self.states.get(task_type) {
            Some(st) if st.n > 0 => {
                let (mut best_k, mut best) = (self.cfg.k, f64::INFINITY);
                for (i, &k) in self.candidates.iter().enumerate() {
                    if st.score[i] < best {
                        best = st.score[i];
                        best_k = k;
                    }
                }
                best_k
            }
            _ => self.cfg.k,
        }
    }

    /// Candidate grid and current EWMA scores (observability/debug).
    pub fn debug_scores(&self, task_type: &str) -> Vec<(usize, f64)> {
        match self.states.get(task_type) {
            Some(st) => self.candidates.iter().copied().zip(st.score.iter().copied()).collect(),
            None => Vec::new(),
        }
    }

    fn fit_for(&mut self, task_type: &str, k: usize) -> Option<FitResult> {
        let h = self.histories.get(task_type)?;
        if h.len() < self.cfg.min_train {
            return None;
        }
        let version = h.total_seen();
        let key = (task_type.to_string(), k);
        if let Some((v, fit)) = self.fits.get(&key) {
            if *v == version {
                return Some(fit.clone());
            }
        }
        let input = h.fit_input();
        let fit = self.fitter.fit(&input, k);
        self.fits.insert(key, (version, fit.clone()));
        Some(fit)
    }

    fn step_fn(&self, fit: &FitResult, input_mib: f64) -> StepFunction {
        let rt = fit.predict_runtime(input_mib).max(1.0);
        let bounds =
            crate::ml::segmentation::segment_time_bounds(rt, self.cfg.t_resample, fit.k());
        StepFunction::monotone_clamped_with_bounds(
            bounds,
            fit.predict_segments(input_mib),
            self.cfg.min_alloc,
            self.cfg.node_max,
        )
    }

    /// Counterfactual replay: wastage (MiB·s) this fit/k would have
    /// accrued on the observed run, including the retry loop.
    fn counterfactual_wastage(&self, fit: &FitResult, run: &TaskRun) -> f64 {
        let mut f = self.step_fn(fit, run.input_mib);
        let mut wastage = 0.0;
        for attempt in 1..=12u32 {
            match simulate_attempt(&run.series, &Allocation::Dynamic(f.clone()), attempt) {
                AttemptOutcome::Success { wastage_mibs } => return wastage + wastage_mibs,
                AttemptOutcome::Failure { info, wastage_mibs } => {
                    wastage += wastage_mibs;
                    let seg = f.segment_at(info.time_s);
                    let (from, to) = match self.strategy {
                        RetryStrategy::Selective => (seg, seg + 1),
                        RetryStrategy::Partial => (seg, f.k()),
                    };
                    f = f.scale_segments(from, to, self.cfg.retry_factor, self.cfg.node_max);
                    if f.value_at(info.time_s) <= info.used_mib {
                        // deep underprediction: lift like the real
                        // on_failure path does
                        let need = (info.used_mib * 1.05).min(self.cfg.node_max.0);
                        let values: Vec<f64> =
                            f.values().iter().map(|v| v.max(need)).collect();
                        f = StepFunction::monotone_clamped_with_bounds(
                            f.bounds().to_vec(),
                            values,
                            self.cfg.min_alloc,
                            self.cfg.node_max,
                        );
                    }
                }
            }
        }
        // pathological: charge the node-max envelope
        wastage + self.cfg.node_max.0 * run.runtime.0
    }
}

impl MemoryPredictor for AdaptiveKPredictor {
    fn name(&self) -> String {
        format!("k-Segments Adaptive-k {}", self.strategy.label())
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, input_mib: f64) -> Allocation {
        let default = self.defaults.get(task_type);
        let k = self.current_k(task_type);
        let Some(fit) = self.fit_for(task_type, k) else {
            return Allocation::Static(default);
        };
        Allocation::Dynamic(self.step_fn(&fit, input_mib))
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        info: &FailureInfo,
    ) -> Allocation {
        let l = self.cfg.retry_factor;
        match failed {
            Allocation::Static(m) => {
                Allocation::Static(MemMiB((m.0 * l).min(self.cfg.node_max.0)))
            }
            Allocation::Dynamic(f) => {
                let seg = f.segment_at(info.time_s);
                let (from, to) = match self.strategy {
                    RetryStrategy::Selective => (seg, seg + 1),
                    RetryStrategy::Partial => (seg, f.k()),
                };
                let mut next = f.scale_segments(from, to, l, self.cfg.node_max);
                if next.value_at(info.time_s) <= info.used_mib {
                    let need = (info.used_mib * 1.05).min(self.cfg.node_max.0);
                    let values: Vec<f64> = next.values().iter().map(|v| v.max(need)).collect();
                    next = StepFunction::monotone_clamped_with_bounds(
                        next.bounds().to_vec(),
                        values,
                        self.cfg.min_alloc,
                        self.cfg.node_max,
                    );
                }
                Allocation::Dynamic(next)
            }
        }
    }

    fn observe(&mut self, run: &TaskRun) {
        // Counterfactual scores use the model state BEFORE folding the
        // run in (out-of-sample: the fit has not seen this run).
        let candidates = self.candidates.clone();
        let mut scores = Vec::with_capacity(candidates.len());
        let mut have_fit = false;
        for &k in &candidates {
            if let Some(fit) = self.fit_for(&run.task_type, k) {
                have_fit = true;
                scores.push(self.counterfactual_wastage(&fit, run));
            } else {
                scores.push(f64::INFINITY);
            }
        }
        if have_fit {
            let st = self
                .states
                .entry(run.task_type.clone())
                .or_insert_with(|| KState { score: vec![0.0; candidates.len()], n: 0 });
            for (i, s) in scores.into_iter().enumerate() {
                if s.is_finite() {
                    st.score[i] = if st.n == 0 {
                        s
                    } else {
                        (1.0 - EWMA_ALPHA) * st.score[i] + EWMA_ALPHA * s
                    };
                }
            }
            st.n += 1;
        }
        self.histories.push(run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    /// Smooth ramp: bigger k strictly reduces wastage (Fig. 8b shape).
    fn ramp_run(input: f64, seq: u64) -> TaskRun {
        let runtime = 100.0 + 0.1 * input;
        let peak = 200.0 + input;
        let n = (runtime / 2.0).ceil() as usize;
        let series: Vec<f64> = (0..n).map(|i| peak * ((i + 1) as f64 / n as f64)).collect();
        TaskRun {
            task_type: "ramp".into(),
            input_mib: input,
            runtime: Seconds(n as f64 * 2.0),
            series: UsageSeries::new(2.0, series),
            seq,
        }
    }

    fn trained() -> AdaptiveKPredictor {
        let mut p = AdaptiveKPredictor::native(RetryStrategy::Selective);
        p.prime("ramp", MemMiB(8192.0));
        for i in 0..32 {
            p.observe(&ramp_run(100.0 + 40.0 * i as f64, i));
        }
        p
    }

    #[test]
    fn starts_at_default_k() {
        let p = AdaptiveKPredictor::native(RetryStrategy::Selective);
        assert_eq!(p.current_k("unseen"), 4);
    }

    #[test]
    fn ramp_drives_k_up() {
        let p = trained();
        // on a pure ramp, finer segmentation always wins: the selected
        // k must leave the default 4 behind
        let k = p.current_k("ramp");
        assert!(k > 4, "adaptive k stayed at {k}");
    }

    #[test]
    fn prediction_uses_selected_k() {
        let mut p = trained();
        let k = p.current_k("ramp");
        let Allocation::Dynamic(f) = p.predict("ramp", 500.0) else {
            panic!("expected dynamic allocation");
        };
        assert_eq!(f.k(), k);
        assert!(f.is_monotone());
    }

    #[test]
    fn adaptive_beats_fixed_default_k_on_ramp() {
        use crate::predictors::ksegments::KSegmentsPredictor;
        use crate::scoring::{simulate_trace, SimConfig};
        use crate::trace::Trace;

        let mut trace = Trace::new();
        trace.set_default("ramp", MemMiB(8192.0));
        for i in 0..80 {
            trace.push(ramp_run(100.0 + 25.0 * i as f64, i));
        }
        trace.sort();
        let cfg = SimConfig { min_runs: 1, ..SimConfig::with_training_frac(0.5) };
        let mut fixed = KSegmentsPredictor::native(4, RetryStrategy::Selective);
        let mut adaptive = AdaptiveKPredictor::native(RetryStrategy::Selective);
        let w_fixed = simulate_trace(&trace, &mut fixed, &cfg).avg_wastage_gbs();
        let w_adapt = simulate_trace(&trace, &mut adaptive, &cfg).avg_wastage_gbs();
        assert!(
            w_adapt < w_fixed,
            "adaptive {w_adapt} should beat fixed k=4 {w_fixed} on a ramp"
        );
    }

    #[test]
    fn counterfactual_is_out_of_sample() {
        // the score of the run being observed must not use a fit that
        // already includes it: train 2 runs, observe a wild outlier —
        // scores update using the pre-outlier fit (finite, large)
        let mut p = AdaptiveKPredictor::native(RetryStrategy::Selective);
        p.prime("ramp", MemMiB(8192.0));
        p.observe(&ramp_run(100.0, 0));
        p.observe(&ramp_run(200.0, 1));
        let st_before = p.states.get("ramp").map(|s| s.n).unwrap_or(0);
        p.observe(&ramp_run(10_000.0, 2));
        let st = p.states.get("ramp").unwrap();
        assert_eq!(st.n, st_before + 1);
        assert!(st.score.iter().any(|s| s.is_finite()));
    }

    #[test]
    fn untrained_returns_default_static() {
        let mut p = AdaptiveKPredictor::native(RetryStrategy::Partial);
        p.prime("t", MemMiB(1234.0));
        assert_eq!(p.predict("t", 10.0), Allocation::Static(MemMiB(1234.0)));
    }

    #[test]
    fn name_labels_strategy() {
        assert_eq!(
            AdaptiveKPredictor::native(RetryStrategy::Selective).name(),
            "k-Segments Adaptive-k Selective"
        );
    }

    #[test]
    #[should_panic]
    fn rejects_empty_candidates() {
        AdaptiveKPredictor::new(
            Box::new(NativeFitter),
            KSegmentsConfig::default(),
            RetryStrategy::Selective,
            vec![],
        );
    }
}
