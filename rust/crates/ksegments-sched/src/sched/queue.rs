//! Deterministic discrete-event queue for the cluster scheduler.
//!
//! Events are ordered by `(time, rank, insertion order)`. The rank
//! encodes the semantic ordering at equal timestamps: releases
//! (`Finish`) are processed before node rejoins (`NodeJoin`), which
//! are processed before node losses (`NodeFail`), then grows
//! (`SegmentBoundary`), then new work (`Arrival`) — freed and rejoined
//! memory is visible to everything that happens "at the same instant"
//! (the packing-friendly and reproducible choice), a task finishing
//! exactly when its node dies counts as finished, and a loss lands
//! before the grows it must deny. The insertion-order tie-breaker
//! makes the pop order a pure function of the push sequence, so the
//! whole simulation is deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedEvent {
    /// A running attempt reaches its precomputed end (completion or
    /// OOM-kill instant). `exec` identifies the running execution.
    Finish { exec: u64 },
    /// A running attempt crosses a step-function boundary and must
    /// grow its reservation to the next segment's value.
    SegmentBoundary { exec: u64, segment: usize },
    /// Task `task` (index into the scheduled run list) arrives.
    Arrival { task: usize },
    /// An injected node loss fires; the victim node is drawn (from the
    /// failure RNG stream) when the event is processed, so the draw
    /// always sees the then-current roster.
    NodeFail,
    /// Node `node` comes (back) up: a failed node rejoining after its
    /// downtime, or an autoscaled node finishing provisioning.
    NodeJoin { node: usize },
}

impl SchedEvent {
    /// Same-timestamp processing rank (lower fires first).
    fn rank(&self) -> u8 {
        match self {
            SchedEvent::Finish { .. } => 0,
            SchedEvent::NodeJoin { .. } => 1,
            SchedEvent::NodeFail => 2,
            SchedEvent::SegmentBoundary { .. } => 3,
            SchedEvent::Arrival { .. } => 4,
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    time: f64,
    rank: u8,
    tie: u64,
    event: SchedEvent,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse so the earliest event pops
        // first. total_cmp keeps this a total order for any f64.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.rank.cmp(&self.rank))
            .then_with(|| other.tie.cmp(&self.tie))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The scheduler's event heap.
#[derive(Debug, Clone, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    next_tie: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, time: f64, event: SchedEvent) {
        debug_assert!(time.is_finite(), "non-finite event time");
        let tie = self.next_tie;
        self.next_tie += 1;
        self.heap.push(Entry { time, rank: event.rank(), tie, event });
    }

    /// Earliest event, ties broken by rank then insertion order.
    pub fn pop(&mut self) -> Option<(f64, SchedEvent)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, SchedEvent::Arrival { task: 0 });
        q.push(1.0, SchedEvent::Arrival { task: 1 });
        q.push(3.0, SchedEvent::Arrival { task: 2 });
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t).collect();
        assert_eq!(order, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn equal_time_orders_finish_before_grow_before_arrival() {
        let mut q = EventQueue::new();
        q.push(2.0, SchedEvent::Arrival { task: 0 });
        q.push(2.0, SchedEvent::SegmentBoundary { exec: 7, segment: 1 });
        q.push(2.0, SchedEvent::Finish { exec: 7 });
        assert_eq!(q.pop().unwrap().1, SchedEvent::Finish { exec: 7 });
        assert_eq!(q.pop().unwrap().1, SchedEvent::SegmentBoundary { exec: 7, segment: 1 });
        assert_eq!(q.pop().unwrap().1, SchedEvent::Arrival { task: 0 });
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_time_and_rank_keeps_insertion_order() {
        let mut q = EventQueue::new();
        for task in 0..5 {
            q.push(1.0, SchedEvent::Arrival { task });
        }
        for expect in 0..5 {
            match q.pop().unwrap().1 {
                SchedEvent::Arrival { task } => assert_eq!(task, expect),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn equal_time_orders_node_events_between_finish_and_grow() {
        let mut q = EventQueue::new();
        q.push(2.0, SchedEvent::Arrival { task: 0 });
        q.push(2.0, SchedEvent::SegmentBoundary { exec: 7, segment: 1 });
        q.push(2.0, SchedEvent::NodeFail);
        q.push(2.0, SchedEvent::NodeJoin { node: 3 });
        q.push(2.0, SchedEvent::Finish { exec: 7 });
        assert_eq!(q.pop().unwrap().1, SchedEvent::Finish { exec: 7 });
        assert_eq!(q.pop().unwrap().1, SchedEvent::NodeJoin { node: 3 });
        assert_eq!(q.pop().unwrap().1, SchedEvent::NodeFail);
        assert_eq!(q.pop().unwrap().1, SchedEvent::SegmentBoundary { exec: 7, segment: 1 });
        assert_eq!(q.pop().unwrap().1, SchedEvent::Arrival { task: 0 });
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.0, SchedEvent::Finish { exec: 0 });
        assert_eq!(q.len(), 1);
        let _ = q.pop();
        assert!(q.is_empty());
    }
}
