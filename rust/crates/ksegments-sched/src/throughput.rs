//! Throughput-vs-policy tables: the scheduling counterpart of the
//! Fig. 7 harness — what does segment-wise packing buy a cluster
//! operator at different load levels?
//!
//! Two row families, shared by the CLI (`ksegments schedule --sweep` /
//! `schedule --dag ... --sweep`) and `ksegments report`:
//!
//! * [`run_throughput`] — independent arrivals: (policy × predictor ×
//!   arrival rate) via [`SchedGrid`]; makespan, mean queue wait, peak
//!   concurrency;
//! * [`run_dag_throughput`] — dependency-gated workflow instances:
//!   (policy × predictor × concurrent-instance count) via [`DagGrid`];
//!   per-instance workflow makespan, critical-path stretch and
//!   straggler counts, where an OOM-ing predictor now pays along the
//!   critical path instead of just in per-task retries;
//! * [`run_failure_sweep`] — cluster adversity: (predictor × node
//!   failure rate × autoscale lag) via [`FailureGrid`]; how much
//!   makespan and wastage each predictor pays when nodes die under it
//!   and how much an autoscaler claws back. Also the workload behind
//!   the `BENCH_sched.json` scheduler-throughput snapshot
//!   (`bench_sched_json` in the facade's bench harness).

use ksegments_core::predictors::MemoryPredictor;
use ksegments_core::units::MemMiB;
use ksegments_core::workload::{eager_workflow, generate_workflow_trace};
use ksegments_core::parallel::PredictorFactory;
use ksegments_core::predictors::roster::{makers_for_keys, FitterChoice};

use crate::cluster::NodeSpec;
use crate::sched::{
    DagGrid, DagGridResults, FailureGrid, FailureGridResults, ReservationPolicy, SchedConfig,
    SchedGrid, SchedGridResults,
};

/// One sweep's rendered axes plus the raw per-cell reports.
pub struct ThroughputResults {
    pub interarrivals: Vec<f64>,
    pub policies: Vec<ReservationPolicy>,
    pub methods: Vec<String>,
    pub results: SchedGridResults,
}

/// `--method` keys of the sweep roster: the two time-varying methods
/// (whose Dynamic allocations the segment-wise policy exploits —
/// k-Segments and KS+ DynSeg), the strongest static competitors
/// (PPM Improved, Sizey Ensemble), and the HTCondor `3 * MemoryUsage`
/// production heuristic (whose enormous static headroom is the
/// packing-density anti-pattern the sweeps quantify). Every method
/// runs under both policies — static allocations are unaffected by
/// the policy choice, which makes the static rows the control.
pub const THROUGHPUT_KEYS: &[&str] =
    &["ksegments-selective", "dynseg", "ppm-improved", "ensemble", "condor"];

/// The sweep roster as thread-safe factories, in [`THROUGHPUT_KEYS`]
/// order.
pub fn throughput_makers() -> Vec<PredictorFactory> {
    makers_for_keys(THROUGHPUT_KEYS, FitterChoice::Native)
}

/// Run the throughput sweep on the eager-like workflow: 2 policies ×
/// 4 predictors × the given mean inter-arrival gaps, on a small
/// cluster sized so that packing pressure is real (2 × 32 GiB).
pub fn run_throughput(seed: u64, interarrivals: &[f64], workers: usize) -> ThroughputResults {
    let traces = vec![generate_workflow_trace(&eager_workflow(), seed)];
    let policies = vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise];
    let base = SchedConfig { seed, training_frac: 0.5, ..SchedConfig::default() };
    let node = NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 };
    let grid = SchedGrid::new(
        policies.clone(),
        throughput_makers(),
        &traces,
        vec![2],
        interarrivals.to_vec(),
    )
    .with_base(base, node);
    let results = grid.run(workers);
    // row labels in THROUGHPUT_KEYS order (display names, not keys)
    let methods = throughput_makers().iter().map(|mk| mk().name()).collect();
    ThroughputResults { interarrivals: interarrivals.to_vec(), policies, methods, results }
}

/// Markdown table shared by all sweep families: one labelled row per
/// swept combination, one column per swept point.
fn render_sweep_table(
    title: &str,
    unit: &str,
    row_header: &str,
    col_labels: &[String],
    row_labels: &[String],
    cell: impl Fn(usize, usize) -> f64,
) -> String {
    let mut out = format!("## {title}\n\n| {row_header} |");
    for label in col_labels {
        out.push_str(&format!(" {label} |"));
    }
    out.push_str("\n|---|");
    for _ in col_labels {
        out.push_str("---|");
    }
    out.push('\n');
    for (r, row) in row_labels.iter().enumerate() {
        out.push_str(&format!("| {row} |"));
        for c in 0..col_labels.len() {
            out.push_str(&format!(" {:.3} |", cell(r, c)));
        }
        out.push('\n');
    }
    out.push_str(&format!("\n(unit: {unit})\n"));
    out
}

/// Row labels for the (policy × method) families.
fn policy_method_rows(policies: &[ReservationPolicy], methods: &[String]) -> Vec<String> {
    let mut out = Vec::with_capacity(policies.len() * methods.len());
    for policy in policies {
        for method in methods {
            out.push(format!("{} · {}", policy.name(), method));
        }
    }
    out
}

impl ThroughputResults {
    fn cell(&self, p: usize, m: usize, a: usize) -> &crate::sched::SchedReport {
        self.results.report(p, m, 0, a).expect("cell present")
    }

    fn render_metric(
        &self,
        title: &str,
        unit: &str,
        get: impl Fn(&crate::sched::SchedReport) -> f64,
    ) -> String {
        let cols: Vec<String> =
            self.interarrivals.iter().map(|ia| format!("ia={ia:.0}s")).collect();
        let rows = policy_method_rows(&self.policies, &self.methods);
        let n_methods = self.methods.len();
        render_sweep_table(title, unit, "policy · method", &cols, &rows, |r, a| {
            get(self.cell(r / n_methods, r % n_methods, a))
        })
    }

    /// The headline table: makespan per policy × arrival rate.
    pub fn render_makespan(&self) -> String {
        self.render_metric(
            "Throughput — makespan by policy × arrival rate",
            "seconds until the last task completes",
            |r| r.makespan.0,
        )
    }

    pub fn render_queue_wait(&self) -> String {
        self.render_metric(
            "Throughput — mean queue wait by policy × arrival rate",
            "seconds from enqueue to placement, mean over admissions",
            |r| r.mean_queue_wait_s(),
        )
    }

    pub fn render_packing(&self) -> String {
        self.render_metric(
            "Throughput — peak concurrent tasks by policy × arrival rate",
            "max tasks co-located on the cluster",
            |r| r.peak_running as f64,
        )
    }

    /// One-line summary per cell, for the CLI.
    pub fn render_summaries(&self) -> String {
        let mut out = String::new();
        for r in &self.results.reports {
            out.push_str(&r.summary());
            out.push('\n');
        }
        out
    }
}

/// One DAG sweep's rendered axes plus the raw per-cell reports.
pub struct DagThroughputResults {
    pub workflow: String,
    pub instance_counts: Vec<usize>,
    pub policies: Vec<ReservationPolicy>,
    pub methods: Vec<String>,
    pub results: DagGridResults,
}

/// Run the dependency-gated sweep on a paper workflow: 2 policies ×
/// the [`THROUGHPUT_KEYS`] roster × the given concurrent-instance
/// counts, on the same packing-pressure cluster as [`run_throughput`]
/// (2 × 32 GiB). Instances arrive gapped by the default
/// inter-arrival; tasks inside an instance release only as their
/// parents complete.
pub fn run_dag_throughput(
    wf: &ksegments_core::workload::WorkflowSpec,
    seed: u64,
    instance_counts: &[usize],
    workers: usize,
) -> DagThroughputResults {
    let policies = vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise];
    let base = SchedConfig { seed, ..SchedConfig::default() };
    let node = NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 };
    let grid = DagGrid::new(
        policies.clone(),
        throughput_makers(),
        wf,
        vec![2],
        instance_counts.to_vec(),
    )
    .with_base(base, node);
    let results = grid.run(workers);
    let methods = throughput_makers().iter().map(|mk| mk().name()).collect();
    DagThroughputResults {
        workflow: wf.name.clone(),
        instance_counts: instance_counts.to_vec(),
        policies,
        methods,
        results,
    }
}

impl DagThroughputResults {
    fn cell(&self, p: usize, m: usize, i: usize) -> &crate::sched::SchedReport {
        self.results.report(p, m, 0, i).expect("cell present")
    }

    fn render_metric(
        &self,
        title: &str,
        unit: &str,
        get: impl Fn(&crate::sched::SchedReport) -> f64,
    ) -> String {
        let title = format!("{title} ({})", self.workflow);
        let unit = format!("{unit}; N = concurrent workflow instances");
        let cols: Vec<String> = self.instance_counts.iter().map(|n| format!("N={n}")).collect();
        let rows = policy_method_rows(&self.policies, &self.methods);
        let n_methods = self.methods.len();
        render_sweep_table(&title, &unit, "policy · method", &cols, &rows, |r, i| {
            get(self.cell(r / n_methods, r % n_methods, i))
        })
    }

    /// The headline table: mean per-instance workflow makespan.
    pub fn render_workflow_makespan(&self) -> String {
        self.render_metric(
            "DAG throughput — mean workflow makespan by policy × instance count",
            "seconds from instance arrival to its last completion, mean over instances",
            |r| r.mean_workflow_makespan_s(),
        )
    }

    /// Mean makespan / critical-path ratio (1.0 = DAG-speed).
    pub fn render_stretch(&self) -> String {
        self.render_metric(
            "DAG throughput — critical-path stretch by policy × instance count",
            "mean per-instance makespan / critical-path length",
            |r| r.critical_path_stretch(),
        )
    }

    /// Straggler instances (makespan > 2× critical path).
    pub fn render_stragglers(&self) -> String {
        self.render_metric(
            "DAG throughput — straggler instances by policy × instance count",
            "instances whose makespan exceeded 2x their critical path",
            |r| r.workflow_stragglers as f64,
        )
    }

    /// One-line summary per cell, for the CLI.
    pub fn render_summaries(&self) -> String {
        let mut out = String::new();
        for r in &self.results.reports {
            out.push_str(&r.summary());
            out.push('\n');
        }
        out
    }
}

/// Default failure-rate axis (failures per second; 0 = none). The
/// non-zero points are MTBF 500 s and MTBF 100 s — mild and harsh
/// relative to the eager trace's ~20–200 s task runtimes.
pub const FAILURE_SWEEP_RATES: &[f64] = &[0.0, 0.002, 0.01];

/// Default autoscale-lag axis: fixed roster vs a 30 s provisioning lag.
pub const FAILURE_SWEEP_LAGS: &[Option<f64>] = &[None, Some(30.0)];

/// One failure sweep's rendered axes plus the raw per-cell reports.
pub struct FailureSweepResults {
    pub fail_rates: Vec<f64>,
    pub lags: Vec<Option<f64>>,
    pub methods: Vec<String>,
    pub results: FailureGridResults,
}

/// Run the failure-domain sweep on the eager-like workflow trace: the
/// [`THROUGHPUT_KEYS`] roster × [`FAILURE_SWEEP_RATES`] ×
/// [`FAILURE_SWEEP_LAGS`], on the same packing-pressure cluster as
/// [`run_throughput`] (2 × 32 GiB base roster).
pub fn run_failure_sweep(seed: u64, workers: usize) -> FailureSweepResults {
    run_failure_sweep_axes(seed, FAILURE_SWEEP_RATES, FAILURE_SWEEP_LAGS, workers)
}

/// [`run_failure_sweep`] with explicit axes (tests and the CLI's
/// `--fail-rate` override).
pub fn run_failure_sweep_axes(
    seed: u64,
    fail_rates: &[f64],
    lags: &[Option<f64>],
    workers: usize,
) -> FailureSweepResults {
    let traces = vec![generate_workflow_trace(&eager_workflow(), seed)];
    let base = SchedConfig { seed, training_frac: 0.5, ..SchedConfig::default() };
    let node = NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 };
    let grid =
        FailureGrid::new(throughput_makers(), &traces, fail_rates.to_vec(), lags.to_vec())
            .with_base(base, node, 2);
    let results = grid.run(workers);
    let methods = throughput_makers().iter().map(|mk| mk().name()).collect();
    FailureSweepResults {
        fail_rates: fail_rates.to_vec(),
        lags: lags.to_vec(),
        methods,
        results,
    }
}

impl FailureSweepResults {
    fn cell(&self, m: usize, r: usize, l: usize) -> &crate::sched::SchedReport {
        self.results.report(m, r, l).expect("cell present")
    }

    fn roster_label(lag: Option<f64>) -> String {
        match lag {
            None => "fixed roster".to_string(),
            Some(l) => format!("autoscale lag={l:.0}s"),
        }
    }

    fn render_metric(
        &self,
        title: &str,
        unit: &str,
        get: impl Fn(&crate::sched::SchedReport) -> f64,
    ) -> String {
        let cols: Vec<String> = self
            .fail_rates
            .iter()
            .map(|&r| {
                if r > 0.0 {
                    format!("mtbf={:.0}s", 1.0 / r)
                } else {
                    "no failures".to_string()
                }
            })
            .collect();
        let mut rows = Vec::with_capacity(self.methods.len() * self.lags.len());
        for method in &self.methods {
            for &lag in &self.lags {
                rows.push(format!("{} · {}", method, Self::roster_label(lag)));
            }
        }
        let n_lags = self.lags.len();
        render_sweep_table(title, unit, "method · roster", &cols, &rows, |row, col| {
            get(self.cell(row / n_lags, col, row % n_lags))
        })
    }

    /// The headline table: makespan under increasing failure pressure.
    pub fn render_makespan(&self) -> String {
        self.render_metric(
            "Failure domains — makespan by failure rate × roster policy",
            "seconds until the last task completes",
            |r| r.makespan.0,
        )
    }

    /// Blameless kills absorbed (node-lost + preempted requeues).
    pub fn render_disruption(&self) -> String {
        self.render_metric(
            "Failure domains — blameless kills by failure rate × roster policy",
            "task attempts killed by node loss or preemption (requeued, not escalated)",
            |r| (r.node_lost + r.preempted) as f64,
        )
    }

    /// Wastage including the partial work thrown away by kills.
    pub fn render_wastage(&self) -> String {
        self.render_metric(
            "Failure domains — wastage by failure rate × roster policy",
            "GB·s reserved-but-unused plus work lost to kills",
            |r| r.total_wastage.0,
        )
    }

    /// One-line summary per cell, for the CLI.
    pub fn render_summaries(&self) -> String {
        let mut out = String::new();
        for r in &self.results.reports {
            out.push_str(&r.summary());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dag_sweep_renders_all_tables() {
        let t = run_dag_throughput(&eager_workflow(), 42, &[2], 2);
        assert_eq!(t.methods.len(), THROUGHPUT_KEYS.len());
        let mk = t.render_workflow_makespan();
        assert!(mk.contains("static-peak · k-Segments Selective"));
        assert!(mk.contains("segment-wise · Sizey Ensemble"));
        assert!(mk.contains("N=2"));
        assert!(mk.contains("(eager)"));
        assert!(t.render_stretch().contains("critical-path stretch"));
        assert!(t.render_stragglers().contains("straggler"));
        assert!(t.render_summaries().contains("workflows: 2/2 done"));
        for r in &t.results.reports {
            assert_eq!(r.workflows_completed, 2);
            assert_eq!(r.completed, r.submitted);
            // stretch is a ratio ≥ 1 whenever instances completed
            assert!(r.critical_path_stretch() >= 1.0 - 1e-9);
        }
    }

    #[test]
    fn sweep_renders_all_tables() {
        // one arrival rate keeps this test cheap; report/CLI sweep more
        let t = run_throughput(42, &[2.0], 2);
        assert_eq!(t.methods.len(), THROUGHPUT_KEYS.len());
        let mk = t.render_makespan();
        assert!(mk.contains("static-peak · k-Segments Selective"));
        assert!(mk.contains("segment-wise · PPM Improved"));
        assert!(mk.contains("segment-wise · KS+ DynSeg Selective"));
        assert!(mk.contains("static-peak · Sizey Ensemble"));
        assert!(mk.contains("static-peak · HTCondor 3x"));
        assert!(mk.contains("ia=2s"));
        assert!(t.render_queue_wait().contains("queue wait"));
        assert!(t.render_packing().contains("peak concurrent"));
        assert!(!t.render_summaries().is_empty());
        // every task completes in every cell
        for r in &t.results.reports {
            assert_eq!(r.completed, r.submitted);
        }
    }

    #[test]
    fn failure_sweep_renders_and_conserves() {
        // small axes keep this cheap; report/CLI sweep the full grid
        let t = run_failure_sweep_axes(42, &[0.0, 0.01], &[Some(30.0)], 2);
        assert_eq!(t.methods.len(), THROUGHPUT_KEYS.len());
        let mk = t.render_makespan();
        assert!(mk.contains("no failures"));
        assert!(mk.contains("mtbf=100s"));
        assert!(mk.contains("k-Segments Selective · autoscale lag=30s"));
        assert!(mk.contains("HTCondor 3x · autoscale lag=30s"));
        assert!(t.render_disruption().contains("blameless kills"));
        assert!(t.render_wastage().contains("wastage"));
        assert!(!t.render_summaries().is_empty());
        for (c, r) in t.results.cells.iter().zip(&t.results.reports) {
            assert_eq!(r.completed, r.submitted, "cell {c:?}");
            assert_eq!(
                r.admitted,
                r.completed + r.oom_kills + r.grow_denials + r.preempted + r.node_lost,
                "cell {c:?}"
            );
            if c.rate_idx == 0 {
                assert_eq!(r.node_failures, 0, "control cell saw failures: {c:?}");
            }
        }
    }

}
