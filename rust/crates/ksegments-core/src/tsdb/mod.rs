//! In-memory time-series database — the InfluxDB substitute
//! (DESIGN.md §3).
//!
//! The paper's monitoring extension stores periodic cgroup metrics in
//! InfluxDB keyed by task execution; the memory predictor later
//! retrieves a completed run's series. This store provides exactly
//! that contract: append-only points per (task type, run id, metric),
//! range queries, and series export, all deterministic.

use std::collections::BTreeMap;

/// A single monitored data point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// Seconds since the run started.
    pub t: f64,
    pub value: f64,
}

/// Identifies one metric stream of one task execution.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesKey {
    pub task_type: String,
    pub run_id: u64,
    /// Metric name, e.g. `"mem_mib"`, `"cpu_frac"`, `"blkio_mib"`.
    pub metric: String,
}

impl SeriesKey {
    pub fn mem(task_type: &str, run_id: u64) -> SeriesKey {
        SeriesKey {
            task_type: task_type.to_string(),
            run_id,
            metric: "mem_mib".to_string(),
        }
    }
}

/// Append-only in-memory TSDB.
#[derive(Debug, Default, Clone)]
pub struct TsDb {
    series: BTreeMap<SeriesKey, Vec<Point>>,
}

impl TsDb {
    pub fn new() -> TsDb {
        TsDb::default()
    }

    /// Append a point; points must arrive in time order per series
    /// (the monitoring sampler guarantees this).
    pub fn append(&mut self, key: &SeriesKey, p: Point) {
        let s = self.series.entry(key.clone()).or_default();
        if let Some(last) = s.last() {
            assert!(
                p.t >= last.t,
                "out-of-order append to {key:?}: {} after {}",
                p.t,
                last.t
            );
        }
        s.push(p);
    }

    pub fn n_series(&self) -> usize {
        self.series.len()
    }

    pub fn n_points(&self) -> usize {
        self.series.values().map(Vec::len).sum()
    }

    /// Full series for a key (empty if unknown).
    pub fn get(&self, key: &SeriesKey) -> &[Point] {
        self.series.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Index window of points with `t ∈ [from, to)`. Appends are
    /// time-ordered (enforced in [`Self::append`]), so both ends are
    /// found by binary search instead of a full scan — the segment-peak
    /// query runs per completed execution on the online-learning path.
    fn range_bounds(pts: &[Point], from: f64, to: f64) -> (usize, usize) {
        let lo = pts.partition_point(|p| p.t < from);
        let hi = pts.partition_point(|p| p.t < to);
        (lo, hi.max(lo))
    }

    /// Range query: points with `t ∈ [from, to)`.
    pub fn range(&self, key: &SeriesKey, from: f64, to: f64) -> Vec<Point> {
        let pts = self.get(key);
        let (lo, hi) = Self::range_bounds(pts, from, to);
        pts[lo..hi].to_vec()
    }

    /// Max value over a range (None if empty) — the segment-peak query.
    pub fn range_max(&self, key: &SeriesKey, from: f64, to: f64) -> Option<f64> {
        let pts = self.get(key);
        let (lo, hi) = Self::range_bounds(pts, from, to);
        pts[lo..hi]
            .iter()
            .map(|p| p.value)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// All run ids recorded for a task type + metric, in order.
    pub fn run_ids(&self, task_type: &str, metric: &str) -> Vec<u64> {
        self.series
            .keys()
            .filter(|k| k.task_type == task_type && k.metric == metric)
            .map(|k| k.run_id)
            .collect()
    }

    /// Drop all series of a run (retention management).
    pub fn drop_run(&mut self, task_type: &str, run_id: u64) {
        self.series
            .retain(|k, _| !(k.task_type == task_type && k.run_id == run_id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(run: u64) -> SeriesKey {
        SeriesKey::mem("wf/task", run)
    }

    #[test]
    fn append_and_get() {
        let mut db = TsDb::new();
        db.append(&key(0), Point { t: 0.0, value: 10.0 });
        db.append(&key(0), Point { t: 2.0, value: 20.0 });
        assert_eq!(db.get(&key(0)).len(), 2);
        assert_eq!(db.n_series(), 1);
        assert_eq!(db.n_points(), 2);
        assert!(db.get(&key(1)).is_empty());
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    fn out_of_order_append_panics() {
        let mut db = TsDb::new();
        db.append(&key(0), Point { t: 5.0, value: 1.0 });
        db.append(&key(0), Point { t: 1.0, value: 2.0 });
    }

    #[test]
    fn range_queries() {
        let mut db = TsDb::new();
        for i in 0..10 {
            db.append(&key(0), Point { t: i as f64, value: i as f64 * 10.0 });
        }
        let r = db.range(&key(0), 2.0, 5.0);
        assert_eq!(r.len(), 3);
        assert_eq!(r[0].value, 20.0);
        assert_eq!(db.range_max(&key(0), 2.0, 5.0), Some(40.0));
        assert_eq!(db.range_max(&key(0), 100.0, 200.0), None);
    }

    #[test]
    fn range_handles_duplicates_and_degenerate_windows() {
        let mut db = TsDb::new();
        // duplicate timestamps are legal (append only requires >=)
        for v in [1.0, 2.0] {
            db.append(&key(0), Point { t: 5.0, value: v });
        }
        db.append(&key(0), Point { t: 7.0, value: 3.0 });
        assert_eq!(db.range(&key(0), 5.0, 7.0).len(), 2);
        assert_eq!(db.range_max(&key(0), 5.0, 7.0), Some(2.0));
        // inverted and empty windows
        assert!(db.range(&key(0), 7.0, 5.0).is_empty());
        assert_eq!(db.range_max(&key(0), 7.0, 5.0), None);
        assert!(db.range(&key(0), 6.0, 6.0).is_empty());
        // half-open: `to` excluded, `from` included
        assert_eq!(db.range(&key(0), 7.0, 8.0).len(), 1);
        assert!(db.range(&key(0), 7.1, 8.0).is_empty());
    }

    #[test]
    fn range_agrees_with_linear_scan() {
        let mut db = TsDb::new();
        let mut rng = crate::rng::Rng::new(99);
        let mut t = 0.0;
        for _ in 0..500 {
            t += rng.uniform(0.0, 2.0);
            db.append(&key(0), Point { t, value: rng.uniform(0.0, 100.0) });
        }
        let pts: Vec<Point> = db.get(&key(0)).to_vec();
        for _ in 0..200 {
            let a = rng.uniform(-10.0, t + 10.0);
            let b = rng.uniform(-10.0, t + 10.0);
            let linear: Vec<Point> =
                pts.iter().filter(|p| p.t >= a && p.t < b).copied().collect();
            assert_eq!(db.range(&key(0), a, b), linear, "window [{a}, {b})");
            let lmax = linear.iter().map(|p| p.value).fold(None, |acc: Option<f64>, v| {
                Some(acc.map_or(v, |m| m.max(v)))
            });
            assert_eq!(db.range_max(&key(0), a, b), lmax, "window [{a}, {b})");
        }
    }

    #[test]
    fn run_ids_per_type() {
        let mut db = TsDb::new();
        db.append(&key(3), Point { t: 0.0, value: 1.0 });
        db.append(&key(1), Point { t: 0.0, value: 1.0 });
        db.append(&SeriesKey::mem("other", 9), Point { t: 0.0, value: 1.0 });
        assert_eq!(db.run_ids("wf/task", "mem_mib"), vec![1, 3]);
    }

    #[test]
    fn drop_run_retention() {
        let mut db = TsDb::new();
        db.append(&key(1), Point { t: 0.0, value: 1.0 });
        db.append(&key(2), Point { t: 0.0, value: 1.0 });
        db.drop_run("wf/task", 1);
        assert_eq!(db.run_ids("wf/task", "mem_mib"), vec![2]);
    }

    #[test]
    fn distinct_metrics_are_distinct_series() {
        let mut db = TsDb::new();
        let mem = SeriesKey::mem("t", 0);
        let cpu = SeriesKey { metric: "cpu_frac".into(), ..mem.clone() };
        db.append(&mem, Point { t: 0.0, value: 1.0 });
        db.append(&cpu, Point { t: 0.0, value: 0.5 });
        assert_eq!(db.n_series(), 2);
    }
}
