//! End-to-end driver (DESIGN.md §5, EXPERIMENTS.md §E2E): exercises
//! every layer of the system on the eager-like workflow —
//!
//!   workload generator → workflow engine (cluster reservations +
//!   cgroup-style monitoring into the TSDB) → k-Segments predictor
//!   backed by the **AOT JAX + Pallas fit module via PJRT** → online
//!   retraining from TSDB-reconstructed series → wastage accounting —
//!
//! and prints the headline comparison against every baseline. Python
//! never runs here; the XLA fit executes from `artifacts/*.hlo.txt`
//! (falls back to the bit-mirrored native fitter with a warning if
//! `make artifacts` has not been run).
//!
//! Run: `cargo run --release --example eager_e2e`

use ksegments::cluster::Cluster;
use ksegments::engine::WorkflowEngine;
use ksegments::ml::fitter::KsegFitter;
use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::ksegments::{KSegmentsConfig, KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::lr_witt::LrWittPredictor;
use ksegments::predictors::ppm::PpmPredictor;
use ksegments::predictors::MemoryPredictor;
use ksegments::runtime::XlaFitter;
use ksegments::workload::{eager_workflow, generate_workflow_trace};

fn engine_row(name: &str, predictor: Box<dyn MemoryPredictor>) -> (String, f64, u64, u64) {
    let trace = generate_workflow_trace(&eager_workflow(), 42);
    let mut engine = WorkflowEngine::new(predictor, Cluster::paper_testbed());
    let report = engine.run_trace(&trace);
    (
        name.to_string(),
        report.wastage.0,
        report.retries,
        report.monitor_points,
    )
}

fn main() {
    println!("=== eager end-to-end: full engine, all methods, seed 42 ===\n");

    // The paper's method on the production path: XLA-backed fit.
    let xla_fitter: Box<dyn KsegFitter> = match XlaFitter::load_default() {
        Ok(f) => {
            println!(
                "PJRT runtime up: artifacts n_hist={} t_max={} ({} fit modules)\n",
                f.manifest().n_hist,
                f.manifest().t_max,
                f.manifest().fits.len()
            );
            Box::new(f)
        }
        Err(e) => {
            eprintln!("warning: {e:#}\nfalling back to the native fitter\n");
            Box::new(ksegments::ml::fitter::NativeFitter)
        }
    };
    let kseg_xla = Box::new(KSegmentsPredictor::with_fitter(
        xla_fitter,
        KSegmentsConfig::default(),
        RetryStrategy::Selective,
    ));

    let rows = vec![
        engine_row("Default", Box::new(DefaultConfigPredictor::new())),
        engine_row("PPM", Box::new(PpmPredictor::original())),
        engine_row("PPM Improved", Box::new(PpmPredictor::improved())),
        engine_row("LR (mean±)", Box::new(LrWittPredictor::paper_baseline())),
        engine_row("k-Segments Selective [XLA]", kseg_xla),
        engine_row(
            "k-Segments Partial",
            Box::new(KSegmentsPredictor::native(4, RetryStrategy::Partial)),
        ),
    ];

    println!(
        "{:<28} {:>16} {:>9} {:>14}",
        "method", "wastage (GB·s)", "retries", "monitor pts"
    );
    for (name, wastage, retries, points) in &rows {
        println!("{name:<28} {wastage:>16.1} {retries:>9} {points:>14}");
    }

    let default_w = rows[0].1;
    let best_baseline = rows[1..4]
        .iter()
        .map(|r| r.1)
        .fold(f64::INFINITY, f64::min);
    let kseg_w = rows[4].1;
    println!(
        "\nk-Segments (XLA path): {:.1}% below defaults, {:.1}% below the best baseline",
        100.0 * (1.0 - kseg_w / default_w),
        100.0 * (1.0 - kseg_w / best_baseline)
    );
    assert!(kseg_w < best_baseline, "k-Segments must beat every baseline end-to-end");
    println!("E2E OK");
}
