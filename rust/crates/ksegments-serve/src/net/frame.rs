//! Wire grammar of the TCP prediction protocol.
//!
//! A **frame** is a 4-byte big-endian `u32` length prefix followed by
//! exactly that many bytes of UTF-8 JSON — one request or response
//! object per frame, no framing inside the payload. The length prefix
//! never counts itself, and a declared length above the server's frame
//! cap is a protocol error *before* any allocation of that size
//! happens ([`take_frame`] checks the prefix alone).
//!
//! Every request carries a client-chosen `id` echoed verbatim in its
//! response, and every frame gets **exactly one** response, in request
//! order per connection — which is what makes pipelining safe: a
//! client may write any number of frames before reading.
//!
//! Malformed input never panics and never kills the connection unless
//! resynchronization is impossible: [`ErrCode::recoverable`] documents
//! which errors leave the stream usable. Responses are serialized
//! through the streaming [`JsonWriter`] into a caller-owned buffer, so
//! the server's hot path performs no per-response tree allocation.

use std::io::{self, Read, Write};

use ksegments_core::predictors::{Allocation, FailureCause, FailureInfo};
use ksegments_core::trace::{run_from_json, run_record, TaskRun};
use ksegments_core::units::MemMiB;
use ksegments_core::util::json::{Json, JsonWriter};

use crate::coordinator::ServiceStats;

/// Bytes of the length prefix.
pub const LEN_PREFIX: usize = 4;

/// Default hard cap on a frame's payload size (4 MiB) — a `replay`
/// frame of a few thousand runs fits comfortably; a corrupt or hostile
/// prefix is rejected before any buffer grows to match it.
pub const MAX_FRAME_DEFAULT: usize = 4 << 20;

/// Typed protocol error codes, exactly as they appear on the wire in
/// `error.code`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// The length prefix declared a payload above the server's cap.
    /// Not recoverable: the stream cannot be resynchronized.
    FrameTooLarge,
    /// The peer closed the connection mid-frame (a dangling length
    /// prefix or a short payload). Reported once, then the connection
    /// closes.
    TruncatedFrame,
    /// The payload is not valid UTF-8.
    InvalidUtf8,
    /// The payload is not valid JSON.
    BadJson,
    /// Valid JSON, but `method` names no known request.
    UnknownMethod,
    /// Known method with missing or malformed fields.
    BadRequest,
    /// The prediction service shut down underneath the request.
    Unavailable,
}

impl ErrCode {
    /// The wire spelling of the code.
    pub fn name(self) -> &'static str {
        match self {
            ErrCode::FrameTooLarge => "frame_too_large",
            ErrCode::TruncatedFrame => "truncated_frame",
            ErrCode::InvalidUtf8 => "invalid_utf8",
            ErrCode::BadJson => "bad_json",
            ErrCode::UnknownMethod => "unknown_method",
            ErrCode::BadRequest => "bad_request",
            ErrCode::Unavailable => "unavailable",
        }
    }

    /// True when the connection remains usable after the error
    /// response: framing was intact, only the payload was bad.
    pub fn recoverable(self) -> bool {
        !matches!(self, ErrCode::FrameTooLarge | ErrCode::TruncatedFrame)
    }
}

/// A typed protocol error, rendered as
/// `{"id":N|null,"ok":false,"error":{"code":...,"message":...}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct NetError {
    pub code: ErrCode,
    pub message: String,
    /// The request id, when parsing got far enough to extract one.
    pub id: Option<u64>,
}

impl NetError {
    pub fn new(code: ErrCode, message: impl Into<String>) -> NetError {
        NetError { code, message: message.into(), id: None }
    }

    pub fn with_id(code: ErrCode, message: impl Into<String>, id: u64) -> NetError {
        NetError { code, message: message.into(), id: Some(id) }
    }
}

/// A parsed request frame (the `id` is returned alongside by
/// [`parse_request`]).
#[derive(Debug, Clone, PartialEq)]
pub enum NetRequest {
    /// Register a developer default for a task type.
    Prime { task_type: String, default: MemMiB },
    /// Submission-time allocation request.
    Predict { task_type: String, input_mib: f64 },
    /// Failure-strategy request: returns the retry allocation.
    ReportFailure {
        task_type: String,
        input_mib: f64,
        failed: Allocation,
        info: FailureInfo,
    },
    /// Completion ingestion (one observed run).
    Complete { run: Box<TaskRun> },
    /// Batched replay: predict + complete every run, in order.
    Replay { runs: Vec<TaskRun> },
    /// Live counters snapshot.
    Stats,
    /// Graceful drain: ack, then stop accepting and join.
    Shutdown,
}

// -- frame I/O -------------------------------------------------------------

/// Write one frame (length prefix + payload) as a single buffer write.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    let mut buf = Vec::with_capacity(LEN_PREFIX + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf)
}

/// Blocking frame read: `Ok(None)` on clean EOF at a frame boundary,
/// an `UnexpectedEof` error on EOF mid-frame, `InvalidData` when the
/// prefix exceeds `max_frame`.
pub fn read_frame<R: Read>(r: &mut R, max_frame: usize) -> io::Result<Option<Vec<u8>>> {
    let mut prefix = [0u8; LEN_PREFIX];
    let mut got = 0;
    while got < LEN_PREFIX {
        // in bounds: the loop guard keeps got < LEN_PREFIX
        match r.read(&mut prefix[got..])? { // lint:allow(panic-policy)
            0 if got == 0 => return Ok(None),
            0 => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed inside a frame length prefix",
                ))
            }
            n => got += n,
        }
    }
    let len = u32::from_be_bytes(prefix) as usize;
    if len > max_frame {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {max_frame}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Incremental frame extraction for the server's accumulation buffer:
/// split one complete frame off the front of `pending`, `Ok(None)`
/// when more bytes are needed, a [`ErrCode::FrameTooLarge`] error as
/// soon as the prefix alone proves the frame oversized.
pub fn take_frame(pending: &mut Vec<u8>, max_frame: usize) -> Result<Option<Vec<u8>>, NetError> {
    if pending.len() < LEN_PREFIX {
        return Ok(None);
    }
    // in bounds: the early return above guarantees LEN_PREFIX bytes
    let prefix = [pending[0], pending[1], pending[2], pending[3]]; // lint:allow(panic-policy)
    let len = u32::from_be_bytes(prefix) as usize;
    if len > max_frame {
        return Err(NetError::new(
            ErrCode::FrameTooLarge,
            format!("declared frame length {len} exceeds the {max_frame}-byte cap"),
        ));
    }
    if pending.len() < LEN_PREFIX + len {
        return Ok(None);
    }
    // in bounds: the length check above guarantees LEN_PREFIX + len bytes
    let payload = pending[LEN_PREFIX..LEN_PREFIX + len].to_vec(); // lint:allow(panic-policy)
    pending.drain(..LEN_PREFIX + len);
    Ok(Some(payload))
}

// -- request parsing -------------------------------------------------------

fn field_str(doc: &Json, key: &str, id: u64) -> Result<String, NetError> {
    doc.get(key).as_str().map(str::to_string).ok_or_else(|| {
        NetError::with_id(ErrCode::BadRequest, format!("missing string field {key:?}"), id)
    })
}

fn field_f64(doc: &Json, key: &str, id: u64) -> Result<f64, NetError> {
    let v = doc.get(key).as_f64().ok_or_else(|| {
        NetError::with_id(ErrCode::BadRequest, format!("missing numeric field {key:?}"), id)
    })?;
    if !v.is_finite() || v < 0.0 {
        return Err(NetError::with_id(
            ErrCode::BadRequest,
            format!("field {key:?} must be finite and non-negative, got {v}"),
            id,
        ));
    }
    Ok(v)
}

/// Parse + validate one request payload into `(id, request)`. Every
/// malformed-input path lands here as a typed [`NetError`] — the
/// server never panics on wire input.
pub fn parse_request(payload: &[u8]) -> Result<(u64, NetRequest), NetError> {
    let text = std::str::from_utf8(payload)
        .map_err(|e| NetError::new(ErrCode::InvalidUtf8, format!("payload is not UTF-8: {e}")))?;
    let doc = Json::parse(text).map_err(|e| NetError::new(ErrCode::BadJson, e.to_string()))?;
    let id = doc.get("id").as_u64();
    let Some(method) = doc.get("method").as_str() else {
        return Err(NetError {
            code: ErrCode::BadRequest,
            message: "missing string field \"method\"".to_string(),
            id,
        });
    };
    let Some(id) = id else {
        return Err(NetError::new(
            ErrCode::BadRequest,
            "missing numeric field \"id\"".to_string(),
        ));
    };
    let req = match method {
        "prime" => NetRequest::Prime {
            task_type: field_str(&doc, "task_type", id)?,
            default: MemMiB(field_f64(&doc, "default_mib", id)?),
        },
        "predict" => NetRequest::Predict {
            task_type: field_str(&doc, "task_type", id)?,
            input_mib: field_f64(&doc, "input_mib", id)?,
        },
        "report_failure" => NetRequest::ReportFailure {
            task_type: field_str(&doc, "task_type", id)?,
            input_mib: field_f64(&doc, "input_mib", id)?,
            failed: parse_alloc(doc.get("failed"))
                .map_err(|e| NetError::with_id(ErrCode::BadRequest, format!("failed: {e}"), id))?,
            info: parse_failure_info(doc.get("info"))
                .map_err(|e| NetError::with_id(ErrCode::BadRequest, format!("info: {e}"), id))?,
        },
        "complete" => NetRequest::Complete {
            run: Box::new(run_from_json(doc.get("run")).map_err(|e| {
                NetError::with_id(ErrCode::BadRequest, format!("run: {e:#}"), id)
            })?),
        },
        "replay" => {
            let arr = doc.get("runs").as_arr().ok_or_else(|| {
                NetError::with_id(ErrCode::BadRequest, "missing array field \"runs\"", id)
            })?;
            let runs = arr
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    run_from_json(r).map_err(|e| {
                        NetError::with_id(ErrCode::BadRequest, format!("runs[{i}]: {e:#}"), id)
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            NetRequest::Replay { runs }
        }
        "stats" => NetRequest::Stats,
        "shutdown" => NetRequest::Shutdown,
        other => {
            return Err(NetError::with_id(
                ErrCode::UnknownMethod,
                format!("unknown method {other:?}"),
                id,
            ))
        }
    };
    Ok((id, req))
}

// -- allocation / failure-info wire forms ----------------------------------

/// `{"kind":"static","mib":X}` or
/// `{"kind":"dynamic","bounds":[...],"values":[...]}` (the
/// [`StepFunction`] arrays, reconstructed through its validating
/// constructor).
///
/// [`StepFunction`]: ksegments_core::ml::step_fn::StepFunction
pub fn parse_alloc(doc: &Json) -> Result<Allocation, String> {
    match doc.get("kind").as_str() {
        Some("static") => {
            let mib = doc.get("mib").as_f64().ok_or("static allocation needs \"mib\"")?;
            if !mib.is_finite() || mib < 0.0 {
                return Err(format!("allocation mib must be finite and non-negative, got {mib}"));
            }
            Ok(Allocation::Static(MemMiB(mib)))
        }
        Some("dynamic") => {
            let nums = |key: &str| -> Result<Vec<f64>, String> {
                doc.get(key)
                    .as_arr()
                    .ok_or_else(|| format!("dynamic allocation needs array {key:?}"))?
                    .iter()
                    .map(|v| v.as_f64().ok_or_else(|| format!("non-numeric entry in {key:?}")))
                    .collect()
            };
            let step = ksegments_core::ml::step_fn::StepFunction::try_new(
                nums("bounds")?,
                nums("values")?,
            )?;
            Ok(Allocation::Dynamic(step))
        }
        other => Err(format!("unknown allocation kind {other:?}")),
    }
}

/// The client-side [`Json`] form of an allocation (requests are built
/// as trees; only server responses stream through [`JsonWriter`]).
pub fn alloc_to_json(alloc: &Allocation) -> Json {
    match alloc {
        Allocation::Static(m) => {
            Json::obj(vec![("kind", "static".into()), ("mib", m.0.into())])
        }
        Allocation::Dynamic(f) => Json::obj(vec![
            ("kind", "dynamic".into()),
            ("bounds", Json::arr_f64(f.bounds())),
            ("values", Json::arr_f64(f.values())),
        ]),
    }
}

/// `{"time_s":T,"used_mib":U,"attempt":A,"cause":"oom"|...}`.
pub fn parse_failure_info(doc: &Json) -> Result<FailureInfo, String> {
    let num = |key: &str| -> Result<f64, String> {
        let v = doc.get(key).as_f64().ok_or_else(|| format!("missing numeric field {key:?}"))?;
        if !v.is_finite() || v < 0.0 {
            return Err(format!("field {key:?} must be finite and non-negative, got {v}"));
        }
        Ok(v)
    };
    let cause = match doc.get("cause").as_str() {
        Some("oom") | None => FailureCause::Oom,
        Some("node-lost") => FailureCause::NodeLost,
        Some("preempted") => FailureCause::Preempted,
        Some(other) => return Err(format!("unknown failure cause {other:?}")),
    };
    Ok(FailureInfo {
        time_s: num("time_s")?,
        used_mib: num("used_mib")?,
        attempt: doc
            .get("attempt")
            .as_u64()
            .ok_or("missing numeric field \"attempt\"")?
            .min(u32::MAX as u64) as u32,
        cause,
    })
}

/// The client-side [`Json`] form of a [`FailureInfo`].
pub fn failure_info_to_json(info: &FailureInfo) -> Json {
    Json::obj(vec![
        ("time_s", info.time_s.into()),
        ("used_mib", info.used_mib.into()),
        ("attempt", u64::from(info.attempt).into()),
        ("cause", info.cause.name().into()),
    ])
}

// -- response serialization (server side, streaming) -----------------------

fn frame_start(buf: &mut Vec<u8>) {
    buf.clear();
    buf.extend_from_slice(&[0u8; LEN_PREFIX]);
}

fn frame_finish(buf: &mut [u8]) {
    let len = (buf.len() - LEN_PREFIX) as u32;
    // in bounds: every buf passed here was opened by frame_start,
    // which reserves the LEN_PREFIX placeholder bytes
    buf[..LEN_PREFIX].copy_from_slice(&len.to_be_bytes()); // lint:allow(panic-policy)
}

fn write_alloc<W: io::Write>(w: &mut JsonWriter<W>, alloc: &Allocation) -> io::Result<()> {
    w.begin_obj()?;
    match alloc {
        Allocation::Static(m) => {
            w.field_str("kind", "static")?;
            w.field_f64("mib", m.0)?;
        }
        Allocation::Dynamic(f) => {
            w.field_str("kind", "dynamic")?;
            w.key("bounds")?;
            w.begin_arr()?;
            for &b in f.bounds() {
                w.f64_val(b)?;
            }
            w.end_arr()?;
            w.key("values")?;
            w.begin_arr()?;
            for &v in f.values() {
                w.f64_val(v)?;
            }
            w.end_arr()?;
        }
    }
    w.end_obj()
}

fn write_stats_obj<W: io::Write>(w: &mut JsonWriter<W>, s: &ServiceStats) -> io::Result<()> {
    w.begin_obj()?;
    w.field_u64("predictions", s.predictions)?;
    w.field_u64("completions", s.completions)?;
    w.field_u64("failures", s.failures)?;
    w.field_u64("wakeups", s.wakeups)?;
    w.end_obj()
}

/// `{"id":N,"ok":true}` — the ack for `prime`/`complete`/`shutdown`.
/// Like every `write_*_frame`, serializes a complete frame (length
/// prefix included) into the reused `buf`.
pub fn write_ok_frame(buf: &mut Vec<u8>, id: u64) -> io::Result<()> {
    frame_start(buf);
    let mut w = JsonWriter::new(&mut *buf);
    w.begin_obj()?;
    w.field_u64("id", id)?;
    w.field_bool("ok", true)?;
    w.end_obj()?;
    w.finish()?;
    frame_finish(buf);
    Ok(())
}

/// `{"id":N,"ok":true,"alloc":{...}}` — `predict`/`report_failure`.
pub fn write_alloc_frame(buf: &mut Vec<u8>, id: u64, alloc: &Allocation) -> io::Result<()> {
    frame_start(buf);
    let mut w = JsonWriter::new(&mut *buf);
    w.begin_obj()?;
    w.field_u64("id", id)?;
    w.field_bool("ok", true)?;
    w.key("alloc")?;
    write_alloc(&mut w, alloc)?;
    w.end_obj()?;
    w.finish()?;
    frame_finish(buf);
    Ok(())
}

/// `{"id":N,"ok":true,"fed":K}` — the `replay` batch response.
pub fn write_fed_frame(buf: &mut Vec<u8>, id: u64, fed: u64) -> io::Result<()> {
    frame_start(buf);
    let mut w = JsonWriter::new(&mut *buf);
    w.begin_obj()?;
    w.field_u64("id", id)?;
    w.field_bool("ok", true)?;
    w.field_u64("fed", fed)?;
    w.end_obj()?;
    w.finish()?;
    frame_finish(buf);
    Ok(())
}

/// `{"id":N,"ok":true,"stats":{...},"per_shard":[{...},...]}`.
pub fn write_stats_frame(
    buf: &mut Vec<u8>,
    id: u64,
    total: &ServiceStats,
    per_shard: &[ServiceStats],
) -> io::Result<()> {
    frame_start(buf);
    let mut w = JsonWriter::new(&mut *buf);
    w.begin_obj()?;
    w.field_u64("id", id)?;
    w.field_bool("ok", true)?;
    w.key("stats")?;
    write_stats_obj(&mut w, total)?;
    w.key("per_shard")?;
    w.begin_arr()?;
    for s in per_shard {
        write_stats_obj(&mut w, s)?;
    }
    w.end_arr()?;
    w.end_obj()?;
    w.finish()?;
    frame_finish(buf);
    Ok(())
}

/// `{"id":N|null,"ok":false,"error":{"code":...,"message":...}}`.
pub fn write_error_frame(buf: &mut Vec<u8>, err: &NetError) -> io::Result<()> {
    frame_start(buf);
    let mut w = JsonWriter::new(&mut *buf);
    w.begin_obj()?;
    w.key("id")?;
    match err.id {
        Some(id) => w.u64_val(id)?,
        None => w.null_val()?,
    }
    w.field_bool("ok", false)?;
    w.key("error")?;
    w.begin_obj()?;
    w.field_str("code", err.code.name())?;
    w.field_str("message", &err.message)?;
    w.end_obj()?;
    w.end_obj()?;
    w.finish()?;
    frame_finish(buf);
    Ok(())
}

// -- response parsing (client side) ----------------------------------------

/// A parsed response frame; exactly the fields the responding method
/// emits are populated.
#[derive(Debug, Clone, PartialEq)]
pub struct NetResponse {
    /// The echoed request id (`None` only on pre-id protocol errors).
    pub id: Option<u64>,
    pub ok: bool,
    pub alloc: Option<Allocation>,
    pub fed: Option<u64>,
    pub stats: Option<ServiceStats>,
    pub per_shard: Vec<ServiceStats>,
    /// `(code, message)` of an error response.
    pub error: Option<(String, String)>,
}

fn parse_stats_obj(doc: &Json) -> Result<ServiceStats, String> {
    let num = |key: &str| doc.get(key).as_u64().ok_or_else(|| format!("stats field {key:?}"));
    Ok(ServiceStats {
        predictions: num("predictions")?,
        completions: num("completions")?,
        failures: num("failures")?,
        wakeups: num("wakeups")?,
    })
}

/// Parse one response payload (the client-side mirror of the
/// `write_*_frame` family, without their length prefixes).
pub fn parse_response(payload: &[u8]) -> Result<NetResponse, String> {
    let text = std::str::from_utf8(payload).map_err(|e| e.to_string())?;
    let doc = Json::parse(text).map_err(|e| e.to_string())?;
    let ok = doc.get("ok").as_bool().ok_or("missing \"ok\"")?;
    let alloc = match doc.get("alloc") {
        Json::Null => None,
        a => Some(parse_alloc(a)?),
    };
    let stats = match doc.get("stats") {
        Json::Null => None,
        s => Some(parse_stats_obj(s)?),
    };
    let per_shard = match doc.get("per_shard").as_arr() {
        Some(arr) => arr.iter().map(parse_stats_obj).collect::<Result<Vec<_>, _>>()?,
        None => Vec::new(),
    };
    let error = match doc.get("error") {
        Json::Null => None,
        e => Some((
            e.get("code").as_str().ok_or("error without code")?.to_string(),
            e.get("message").as_str().unwrap_or("").to_string(),
        )),
    };
    Ok(NetResponse {
        id: doc.get("id").as_u64(),
        ok,
        alloc,
        fed: doc.get("fed").as_u64(),
        stats,
        per_shard,
        error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::ml::step_fn::StepFunction;
    use ksegments_core::trace::UsageSeries;
    use ksegments_core::units::Seconds;

    fn payload(buf: &[u8]) -> &[u8] {
        let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        assert_eq!(buf.len(), LEN_PREFIX + len, "prefix matches payload");
        &buf[LEN_PREFIX..]
    }

    fn req(doc: Json) -> Result<(u64, NetRequest), NetError> {
        parse_request(doc.to_string().as_bytes())
    }

    #[test]
    fn take_frame_assembles_incrementally() {
        let mut pending = Vec::new();
        let mut framed = Vec::new();
        write_frame(&mut framed, b"{\"x\":1}").unwrap();
        // drip-feed byte by byte: no frame until the last byte lands
        for (i, b) in framed.iter().enumerate() {
            pending.push(*b);
            let got = take_frame(&mut pending, 1024).unwrap();
            if i + 1 < framed.len() {
                assert!(got.is_none(), "no frame after {} bytes", i + 1);
            } else {
                assert_eq!(got.as_deref(), Some(b"{\"x\":1}".as_ref()));
            }
        }
        assert!(pending.is_empty());
    }

    #[test]
    fn take_frame_rejects_oversized_prefix_before_payload() {
        let mut pending = 5000u32.to_be_bytes().to_vec();
        let err = take_frame(&mut pending, 4096).unwrap_err();
        assert_eq!(err.code, ErrCode::FrameTooLarge);
        assert!(!err.code.recoverable());
    }

    #[test]
    fn read_frame_roundtrip_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abc").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r, 64).unwrap().as_deref(), Some(b"abc".as_ref()));
        assert_eq!(read_frame(&mut r, 64).unwrap().as_deref(), Some(b"".as_ref()));
        assert!(read_frame(&mut r, 64).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn parse_every_request_kind() {
        let (id, r) = req(Json::obj(vec![
            ("method", "prime".into()),
            ("id", 7u64.into()),
            ("task_type", "w/t".into()),
            ("default_mib", 2048.0.into()),
        ]))
        .unwrap();
        assert_eq!(id, 7);
        assert_eq!(r, NetRequest::Prime { task_type: "w/t".into(), default: MemMiB(2048.0) });

        let (_, r) = req(Json::obj(vec![
            ("method", "predict".into()),
            ("id", 8u64.into()),
            ("task_type", "w/t".into()),
            ("input_mib", 10.0.into()),
        ]))
        .unwrap();
        assert_eq!(r, NetRequest::Predict { task_type: "w/t".into(), input_mib: 10.0 });

        let run = TaskRun {
            task_type: "w/t".into(),
            input_mib: 10.0,
            runtime: Seconds(4.0),
            series: UsageSeries::new(2.0, vec![50.0, 100.0]),
            seq: 3,
        };
        let (_, r) = req(Json::obj(vec![
            ("method", "complete".into()),
            ("id", 9u64.into()),
            ("run", run_record(&run)),
        ]))
        .unwrap();
        assert_eq!(r, NetRequest::Complete { run: Box::new(run.clone()) });

        let (_, r) = req(Json::obj(vec![
            ("method", "replay".into()),
            ("id", 10u64.into()),
            ("runs", Json::Arr(vec![run_record(&run), run_record(&run)])),
        ]))
        .unwrap();
        assert_eq!(r, NetRequest::Replay { runs: vec![run.clone(), run] });

        let (_, r) = req(Json::obj(vec![
            ("method", "report_failure".into()),
            ("id", 11u64.into()),
            ("task_type", "w/t".into()),
            ("input_mib", 10.0.into()),
            ("failed", alloc_to_json(&Allocation::Static(MemMiB(100.0)))),
            ("info", failure_info_to_json(&FailureInfo::oom(1.0, 150.0, 1))),
        ]))
        .unwrap();
        assert_eq!(
            r,
            NetRequest::ReportFailure {
                task_type: "w/t".into(),
                input_mib: 10.0,
                failed: Allocation::Static(MemMiB(100.0)),
                info: FailureInfo::oom(1.0, 150.0, 1),
            }
        );

        for (m, want) in [("stats", NetRequest::Stats), ("shutdown", NetRequest::Shutdown)] {
            let (_, r) =
                req(Json::obj(vec![("method", m.into()), ("id", 1u64.into())])).unwrap();
            assert_eq!(r, want);
        }
    }

    #[test]
    fn malformed_requests_get_typed_codes() {
        let e = parse_request(&[0xff, 0xfe, 0x80]).unwrap_err();
        assert_eq!(e.code, ErrCode::InvalidUtf8);
        assert!(e.code.recoverable());

        let e = parse_request(b"{not json").unwrap_err();
        assert_eq!(e.code, ErrCode::BadJson);

        let e = req(Json::obj(vec![("method", "frobnicate".into()), ("id", 1u64.into())]))
            .unwrap_err();
        assert_eq!(e.code, ErrCode::UnknownMethod);
        assert_eq!(e.id, Some(1), "unknown method still echoes the id");

        // missing id
        let e = req(Json::obj(vec![("method", "stats".into())])).unwrap_err();
        assert_eq!(e.code, ErrCode::BadRequest);
        assert_eq!(e.id, None);

        // known method, missing field
        let e = req(Json::obj(vec![("method", "predict".into()), ("id", 2u64.into())]))
            .unwrap_err();
        assert_eq!(e.code, ErrCode::BadRequest);
        assert_eq!(e.id, Some(2));

        // non-finite numeric field
        let e = req(Json::obj(vec![
            ("method", "predict".into()),
            ("id", 3u64.into()),
            ("task_type", "w/t".into()),
            ("input_mib", (-1.0).into()),
        ]))
        .unwrap_err();
        assert_eq!(e.code, ErrCode::BadRequest);
    }

    #[test]
    fn alloc_roundtrips_both_kinds() {
        let stat = Allocation::Static(MemMiB(512.0));
        assert_eq!(parse_alloc(&alloc_to_json(&stat)).unwrap(), stat);
        let dyn_ = Allocation::Dynamic(StepFunction::new(
            vec![10.0, 20.0, 30.0],
            vec![100.0, 200.0, 150.0],
        ));
        assert_eq!(parse_alloc(&dyn_to_json_roundtrip(&dyn_)).unwrap(), dyn_);
        // the validating constructor rejects a malformed step function
        let bad = Json::obj(vec![
            ("kind", "dynamic".into()),
            ("bounds", Json::arr_f64(&[20.0, 10.0])),
            ("values", Json::arr_f64(&[1.0, 2.0])),
        ]);
        assert!(parse_alloc(&bad).is_err());
    }

    fn dyn_to_json_roundtrip(a: &Allocation) -> Json {
        // exercise the streaming writer against the tree parser: the
        // wire bytes a server emits must parse back to the same value
        let mut buf = Vec::new();
        write_alloc_frame(&mut buf, 1, a).unwrap();
        let resp = parse_response(payload(&buf)).unwrap();
        alloc_to_json(&resp.alloc.unwrap())
    }

    #[test]
    fn response_frames_parse_back() {
        let mut buf = Vec::new();
        write_ok_frame(&mut buf, 42).unwrap();
        let r = parse_response(payload(&buf)).unwrap();
        assert_eq!((r.id, r.ok), (Some(42), true));

        buf.clear();
        write_fed_frame(&mut buf, 5, 14).unwrap();
        let r = parse_response(payload(&buf)).unwrap();
        assert_eq!(r.fed, Some(14));

        let per_shard = vec![
            ServiceStats { predictions: 3, completions: 2, failures: 1, wakeups: 4 },
            ServiceStats { predictions: 5, completions: 0, failures: 0, wakeups: 2 },
        ];
        let total = ServiceStats::aggregated(&per_shard);
        buf.clear();
        write_stats_frame(&mut buf, 6, &total, &per_shard).unwrap();
        let r = parse_response(payload(&buf)).unwrap();
        assert_eq!(r.stats, Some(total));
        assert_eq!(r.per_shard, per_shard);

        buf.clear();
        write_error_frame(&mut buf, &NetError::new(ErrCode::BadJson, "nope")).unwrap();
        let r = parse_response(payload(&buf)).unwrap();
        assert!(!r.ok);
        assert_eq!(r.id, None);
        assert_eq!(r.error, Some(("bad_json".to_string(), "nope".to_string())));
    }
}
