//! Dependency-gated workflow instances for the cluster scheduler.
//!
//! The paper's cluster argument is about a *workflow engine*: a task
//! is not an independent arrival, it becomes runnable only when its
//! parents in the DAG have produced their outputs. [`WorkflowSource`]
//! materializes that structure for the discrete-event engine: N
//! concurrent executions ("instances") of a workflow, each carrying
//! one run per task plus the parent edges the engine gates releases
//! on. An OOM-killed parent retries before it counts as completed, so
//! memory underprediction delays everything downstream of it — the
//! critical-path propagation an independent-arrivals model hides.
//!
//! Two constructors:
//!
//! * [`WorkflowSource::from_spec`] — synthesize instances of a
//!   [`WorkflowSpec`] (the paper's eager/sarek catalogs), one
//!   execution of every task type per instance, deterministically from
//!   a seed via the same [`ksegments_core::workload::synth_execution`]
//!   distributions the trace generator uses;
//! * [`WorkflowSource::from_trace`] — infer a DAG from an ingested
//!   trace (e.g. a Nextflow `trace.txt` via
//!   `read_nextflow_dir` in the serve layer): task types are ranked into
//!   process levels by their first submission (`seq`), instance `i`
//!   takes each type's `i`-th run, and every task depends on the
//!   previous level present in its instance — a conservative
//!   chain-of-levels reading of the pipeline's process order.

use ksegments_core::rng::Rng;
use ksegments_core::trace::{TaskRun, Trace};
use ksegments_core::units::MemMiB;
use ksegments_core::workload::{synth_execution, WorkflowSpec};

/// One task of a workflow instance: its (ground-truth) run plus the
/// indices of the tasks in the **same instance** that must complete
/// before it is released.
#[derive(Debug, Clone)]
pub struct DagTask {
    pub run: TaskRun,
    pub parents: Vec<usize>,
}

/// One execution of a whole workflow: a DAG of [`DagTask`]s.
#[derive(Debug, Clone)]
pub struct WorkflowInstance {
    /// Workflow name (shared by all instances of a source).
    pub name: String,
    /// Instance ordinal (0-based submission order).
    pub index: u64,
    pub tasks: Vec<DagTask>,
}

impl WorkflowInstance {
    /// Topological order over the parent edges (Kahn). Panics on a
    /// cycle — instances are built from validated specs or from
    /// by-construction-acyclic level chains.
    fn topo_order(&self) -> Vec<usize> {
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (t, task) in self.tasks.iter().enumerate() {
            for &p in &task.parents {
                assert!(p < n, "parent index out of range");
                children[p].push(t);
                indeg[t] += 1;
            }
        }
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        while let Some(u) = ready.pop() {
            order.push(u);
            for &v in &children[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    ready.push(v);
                }
            }
        }
        assert_eq!(order.len(), n, "workflow instance '{}' has a cycle", self.name);
        order
    }

    /// Critical-path length (seconds): the longest chain of task
    /// runtimes through the DAG — the instance's makespan lower bound
    /// on an infinite, retry-free cluster. The achieved makespan is
    /// compared against this in [`super::SchedReport`].
    pub fn critical_path_s(&self) -> f64 {
        let mut finish = vec![0.0f64; self.tasks.len()];
        for t in self.topo_order() {
            let ready_at = self.tasks[t]
                .parents
                .iter()
                .map(|&p| finish[p])
                .fold(0.0f64, f64::max);
            finish[t] = ready_at + self.tasks[t].run.runtime.0;
        }
        finish.iter().copied().fold(0.0, f64::max)
    }
}

/// N concurrent instances of a workflow, plus the developer defaults
/// the predictor is primed with — the DAG-mode arrival stream of
/// [`super::schedule_workflows`].
#[derive(Debug, Clone)]
pub struct WorkflowSource {
    pub instances: Vec<WorkflowInstance>,
    defaults: Vec<(String, MemMiB)>,
}

impl WorkflowSource {
    /// Synthesize `n_instances` executions of `wf`, deterministically
    /// from `seed`. Task `t` of instance `i` gets the globally unique
    /// `seq = i · n_tasks + t`; the rng stream is forked per
    /// `(task type, instance)` so instances are independent draws from
    /// the same per-type distributions as [`ksegments_core::workload::generate_workflow_trace`].
    pub fn from_spec(wf: &WorkflowSpec, seed: u64, n_instances: usize) -> WorkflowSource {
        wf.validate().expect("invalid workflow spec");
        let parents = wf.parents();
        // distinct fork label from the trace generator: DAG instances
        // are a different experiment axis, not a trace prefix
        let root = Rng::new(seed).fork(&wf.name).fork("dag-instances");
        let n_tasks = wf.tasks.len();
        let instances = (0..n_instances)
            .map(|i| {
                let tasks = wf
                    .tasks
                    .iter()
                    .enumerate()
                    .map(|(t, spec)| {
                        let mut rng = root.fork(&format!("{}#{}", spec.name, i));
                        let seq = (i * n_tasks + t) as u64;
                        let run = synth_execution(spec, &mut rng, seq);
                        DagTask { run, parents: parents[t].clone() }
                    })
                    .collect();
                WorkflowInstance { name: wf.name.clone(), index: i as u64, tasks }
            })
            .collect();
        let defaults = wf
            .tasks
            .iter()
            .map(|t| (t.name.clone(), t.default_mem))
            .collect();
        WorkflowSource { instances, defaults }
    }

    /// Infer a chain-of-levels DAG from an ingested trace: task types
    /// are ranked by the `seq` of their first run (Nextflow submits a
    /// process's first task only once its inputs exist, so first
    /// submission order is a topological order of the process graph);
    /// instance `i` takes the `i`-th run of every type that has one,
    /// and each task's parent is the task from the nearest earlier
    /// level present in the same instance. `n_instances` is capped at
    /// the deepest type's run count.
    pub fn from_trace(name: &str, trace: &Trace, n_instances: usize) -> WorkflowSource {
        // types in first-submission order
        let mut levels: Vec<(u64, &str)> = trace
            .task_types()
            .filter_map(|ty| trace.runs_of(ty).iter().map(|r| r.seq).min().map(|s| (s, ty)))
            .collect();
        levels.sort_unstable();
        let max_runs = levels
            .iter()
            .map(|(_, ty)| trace.runs_of(ty).len())
            .max()
            .unwrap_or(0);
        let n_instances = n_instances.min(max_runs);
        let n_tasks = levels.len();
        let mut instances = Vec::with_capacity(n_instances);
        for i in 0..n_instances {
            let mut tasks: Vec<DagTask> = Vec::new();
            for (l, &(_, ty)) in levels.iter().enumerate() {
                let runs = trace.runs_of(ty);
                let Some(run) = runs.get(i) else { continue };
                let mut run = run.clone();
                // re-key seq so it is globally unique across instances
                run.seq = (i * n_tasks + l) as u64;
                // chain: depend on the previous level present in this
                // instance (roots when this is the first one)
                let parents = if tasks.is_empty() { vec![] } else { vec![tasks.len() - 1] };
                tasks.push(DagTask { run, parents });
            }
            instances.push(WorkflowInstance { name: name.to_string(), index: i as u64, tasks });
        }
        let defaults = trace
            .task_types()
            .filter_map(|ty| trace.default_alloc(ty).map(|m| (ty.to_string(), m)))
            .collect();
        WorkflowSource { instances, defaults }
    }

    /// Assemble a source from hand-built instances — custom DAGs,
    /// oracle tests, or engine integrations that already know their
    /// dependency structure.
    pub fn from_instances(
        instances: Vec<WorkflowInstance>,
        defaults: Vec<(String, MemMiB)>,
    ) -> WorkflowSource {
        WorkflowSource { instances, defaults }
    }

    /// Developer defaults the scheduler primes the predictor with.
    pub fn defaults(&self) -> &[(String, MemMiB)] {
        &self.defaults
    }

    pub fn n_instances(&self) -> usize {
        self.instances.len()
    }

    /// Total tasks across all instances.
    pub fn n_tasks(&self) -> usize {
        self.instances.iter().map(|i| i.tasks.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::trace::UsageSeries;
    use ksegments_core::units::Seconds;
    use ksegments_core::workload::eager_workflow;

    #[test]
    fn from_spec_is_deterministic_and_complete() {
        let wf = eager_workflow();
        let a = WorkflowSource::from_spec(&wf, 42, 3);
        let b = WorkflowSource::from_spec(&wf, 42, 3);
        assert_eq!(a.n_instances(), 3);
        assert_eq!(a.n_tasks(), 3 * wf.tasks.len());
        assert_eq!(a.defaults().len(), wf.tasks.len());
        for (ia, ib) in a.instances.iter().zip(&b.instances) {
            assert_eq!(ia.tasks.len(), ib.tasks.len());
            for (ta, tb) in ia.tasks.iter().zip(&ib.tasks) {
                assert_eq!(ta.run, tb.run);
                assert_eq!(ta.parents, tb.parents);
            }
        }
        // instances draw different executions
        assert_ne!(
            a.instances[0].tasks[0].run.input_mib,
            a.instances[1].tasks[0].run.input_mib
        );
        // seqs are globally unique and dense
        let mut seqs: Vec<u64> = a
            .instances
            .iter()
            .flat_map(|i| i.tasks.iter().map(|t| t.run.seq))
            .collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..a.n_tasks() as u64).collect::<Vec<_>>());
    }

    #[test]
    fn critical_path_of_diamond() {
        fn task(rt: f64, parents: Vec<usize>) -> DagTask {
            DagTask {
                run: TaskRun {
                    task_type: "w/t".into(),
                    input_mib: 1.0,
                    runtime: Seconds(rt),
                    series: UsageSeries::new(rt, vec![10.0]),
                    seq: 0,
                },
                parents,
            }
        }
        let inst = WorkflowInstance {
            name: "w".into(),
            index: 0,
            tasks: vec![
                task(10.0, vec![]),
                task(5.0, vec![0]),
                task(20.0, vec![0]),
                task(1.0, vec![1, 2]),
            ],
        };
        // longest chain: 10 + 20 + 1
        assert!((inst.critical_path_s() - 31.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cyclic_instance_panics() {
        let run = TaskRun {
            task_type: "w/t".into(),
            input_mib: 1.0,
            runtime: Seconds(1.0),
            series: UsageSeries::new(1.0, vec![1.0]),
            seq: 0,
        };
        let inst = WorkflowInstance {
            name: "w".into(),
            index: 0,
            tasks: vec![
                DagTask { run: run.clone(), parents: vec![1] },
                DagTask { run, parents: vec![0] },
            ],
        };
        inst.critical_path_s();
    }

    #[test]
    fn from_trace_builds_level_chain() {
        let mut trace = Trace::new();
        trace.set_default("A", MemMiB(1000.0));
        // A first (seqs 0,2), then B (seqs 1,3), C has a single run
        for (ty, seq) in [("A", 0u64), ("B", 1), ("A", 2), ("B", 3), ("C", 4)] {
            trace.push(TaskRun {
                task_type: ty.into(),
                input_mib: 1.0,
                runtime: Seconds(4.0),
                series: UsageSeries::new(2.0, vec![50.0, 100.0]),
                seq,
            });
        }
        trace.sort();
        let src = WorkflowSource::from_trace("nf", &trace, 5);
        // capped at the deepest type's run count (A and B have 2)
        assert_eq!(src.n_instances(), 2);
        let i0 = &src.instances[0];
        assert_eq!(i0.tasks.len(), 3, "instance 0 has A, B and C");
        assert_eq!(i0.tasks[0].run.task_type, "A");
        assert_eq!(i0.tasks[0].parents, Vec::<usize>::new());
        assert_eq!(i0.tasks[1].run.task_type, "B");
        assert_eq!(i0.tasks[1].parents, vec![0]);
        assert_eq!(i0.tasks[2].run.task_type, "C");
        assert_eq!(i0.tasks[2].parents, vec![1]);
        // instance 1 misses C; B still chains to A
        let i1 = &src.instances[1];
        assert_eq!(i1.tasks.len(), 2);
        assert_eq!(i1.tasks[1].parents, vec![0]);
        // seqs unique across the source
        let mut seqs: Vec<u64> = src
            .instances
            .iter()
            .flat_map(|i| i.tasks.iter().map(|t| t.run.seq))
            .collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), src.n_tasks());
        assert_eq!(src.defaults(), &[("A".to_string(), MemMiB(1000.0))]);
    }
}
