"""Pallas kernel: masked batched simple linear regression (closed form).

Fits ``targets[:, m] ~ a_m + b_m * x`` by least squares over valid rows,
for all M target columns at once.  This is the learning hot-spot of the
k-Segments online loop: after every task completion the coordinator
refits k segment models + 1 runtime model from the N most recent
executions, so the fit is (k+1) simultaneous regressions over a shared
design vector — exactly what this kernel computes.

Kernel structure: a single program holds ``x [N]``, ``targets [N, M]``
and ``valid [N]`` in VMEM (for the AOT shapes N=64, M<=17: ~5 KiB) and
reduces the five masked sufficient statistics (sw, sx, sxx, sy, sxy)
along the batch (sublane) dimension, then solves the 2x2 normal
equations per column.  Degenerate designs (fewer than 2 distinct valid
x) fall back to slope 0 / intercept = masked mean via a select, keeping
the kernel free of data-dependent control flow.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["linfit", "linfit_kernel"]


def linfit_kernel(x_ref, t_ref, v_ref, out_ref):
    """Pallas kernel body: [N] x, [N, M] targets, [N] valid -> [M, 2]."""
    x = x_ref[...]
    targets = t_ref[...]
    w = v_ref[...]

    # Centered formulation (matches ref.linfit_ref and rust/src/ml):
    # b = cov_w(x, y) / var_w(x) — stable in f32 where the uncentered
    # normal equations cancel catastrophically.
    sw = jnp.sum(w)
    sw_safe = jnp.maximum(sw, 1.0)
    xbar = jnp.sum(w * x) / sw_safe
    ybar = jnp.sum(w[:, None] * targets, axis=0) / sw_safe  # [M]
    xc = x - xbar
    varx = jnp.sum(w * xc * xc)
    cov = jnp.sum((w * xc)[:, None] * targets, axis=0)  # [M]

    thresh = 1e-7 * sw_safe * (xbar * xbar + 1.0)
    safe = (sw >= 1.5) & (varx > thresh)
    b = jnp.where(safe, cov / jnp.where(safe, varx, 1.0), 0.0)
    a = ybar - b * xbar

    out_ref[:, 0] = a
    out_ref[:, 1] = b


def linfit(x: jnp.ndarray, targets: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Masked batched linear fit via the Pallas kernel.

    x: [N], targets: [N, M], valid: [N] in {0,1}.
    Returns [M, 2] rows of (intercept, slope).
    """
    n, m = targets.shape
    if x.shape != (n,) or valid.shape != (n,):
        raise ValueError(
            f"shape mismatch: x{x.shape}, targets{targets.shape}, valid{valid.shape}"
        )
    return pl.pallas_call(
        linfit_kernel,
        out_shape=jax.ShapeDtypeStruct((m, 2), targets.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, targets, valid.astype(targets.dtype))
