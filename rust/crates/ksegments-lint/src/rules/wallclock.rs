//! `wallclock`: `Instant::now()` / `SystemTime::now()` are legal only
//! in the timer module (`ksegments-core/src/util/timer.rs`), whose
//! `Stopwatch` is the single sanctioned wall-clock site. Everything
//! else must take time as data (event-clock seconds, recorded traces)
//! or go through `Stopwatch` — reading the wall clock anywhere else
//! breaks bit-identical replay. Test code is exempt.

use super::{FileCtx, Rule};
use crate::diag::Diagnostic;

const PATTERNS: &[&str] = &["Instant::now", "SystemTime::now"];

fn sanctioned(ctx: &FileCtx<'_>) -> bool {
    ctx.krate == "ksegments-core" && ctx.rel_path == "src/util/timer.rs"
}

pub struct Wallclock;

impl Rule for Wallclock {
    fn id(&self) -> &'static str {
        "wallclock"
    }

    fn check(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        if sanctioned(ctx) {
            return;
        }
        for (idx, line) in ctx.file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for pat in PATTERNS {
                if line.code.contains(pat) {
                    out.push(Diagnostic {
                        rule: self.id(),
                        path: ctx.display_path.to_string(),
                        line: idx + 1,
                        message: format!(
                            "{pat}() outside the sanctioned timer module \
                             (util/timer.rs); route timing through Stopwatch"
                        ),
                    });
                }
            }
        }
    }
}
