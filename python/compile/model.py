"""L2: the k-Segments fit graph (paper §III-B), built on the L1 kernels.

``make_fit_fn(k)`` returns a jax function computing, in one fused module:

  inputs  x       f32[N]     total input size per historical execution (MiB)
          y       f32[N, T]  peak-preserving resampled usage series (MiB)
          runtime f32[N]     actual runtime per execution (seconds)
          valid   f32[N]     1.0 for real rows, 0.0 for padding

  outputs rt_coef    f32[2]    runtime regression (intercept, slope)
          rt_offset  f32[]     largest historical runtime OVERprediction
                               (subtracted at predict time -> underpredict)
          seg_coef   f32[k,2]  per-segment peak regressions
          seg_off    f32[k]    largest historical segment UNDERprediction
                               (added at predict time -> overpredict)

This module is lowered once per k by ``aot.py`` to HLO text and executed
from the rust coordinator's online-learning path (rust/src/runtime).
Python never runs at request time.

Prediction itself (evaluating the step function, monotonicity clamping,
the 100 MB floor) is trivial scalar math and lives in rust
(rust/src/predictors/ksegments.rs) — shipping it through XLA would cost
more in dispatch than it computes.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from .kernels.linfit import linfit
from .kernels.segpeaks import segpeaks

# Shared padding constants — mirrored into artifacts/manifest.json by
# aot.py and read by rust/src/runtime at load time.  Keep in sync with
# DESIGN.md §4.
N_HIST = 64  # most recent executions used per fit
T_MAX = 256  # peak-preserving resample length
K_RANGE = tuple(range(1, 17))  # artifact emitted per k in 1..=16

__all__ = ["N_HIST", "T_MAX", "K_RANGE", "ksegments_fit", "make_fit_fn"]


def ksegments_fit(x, y, runtime, valid, *, k: int):
    """Full fit: segment peaks (L1) -> k+1 regressions (L1) -> offsets (L2)."""
    w = valid.astype(y.dtype)

    peaks = segpeaks(y, k)  # [N, k] via Pallas
    # One fused solve for the k segment models and the runtime model:
    # column 0..k-1 = segment peaks, column k = runtime.
    targets = jnp.concatenate([peaks, runtime[:, None]], axis=1)  # [N, k+1]
    coef = linfit(x, targets, w)  # [k+1, 2] via Pallas

    seg_coef = coef[:k]  # [k, 2]
    rt_coef = coef[k]  # [2]

    # Residual offsets (paper: "largest historical prediction error").
    rt_pred = rt_coef[0] + rt_coef[1] * x
    rt_over = jnp.where(w > 0, rt_pred - runtime, -jnp.inf)
    rt_offset = jnp.maximum(jnp.max(rt_over), 0.0)

    seg_pred = seg_coef[:, 0][None, :] + seg_coef[:, 1][None, :] * x[:, None]
    under = jnp.where(w[:, None] > 0, peaks - seg_pred, -jnp.inf)
    seg_off = jnp.maximum(jnp.max(under, axis=0), 0.0)  # [k]

    return rt_coef, rt_offset, seg_coef, seg_off


def make_fit_fn(k: int):
    """Bind the static segment count; the result is jit/lower-able."""
    return functools.partial(ksegments_fit, k=k)
