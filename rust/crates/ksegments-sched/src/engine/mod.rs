//! The SWMS execution engine — the Fig. 2 / Fig. 6 loop of the paper,
//! wired end to end.
//!
//! `sim` answers "how good is a predictor" with the paper's offline
//! evaluation protocol; this module is the *system*: a Nextflow-like
//! engine that, per task execution,
//!
//! 1. asks the predictor for an allocation (Fig. 2 "predicted resource
//!    allocation function"),
//! 2. reserves memory on the [`Cluster`] through the resource manager,
//! 3. "executes" the task against its ground-truth usage curve,
//!    sampling cgroup-style metrics into the [`TsDb`] at the
//!    monitoring interval,
//! 4. on under-allocation, applies the predictor's failure strategy
//!    and retries,
//! 5. on completion, reconstructs the run's series **from the TSDB**
//!    (not from the generator) and feeds it back into the model —
//!    closing the paper's online loop.

mod events;

pub use events::{EngineEvent, EventLog};

use crate::cluster::Cluster;
use ksegments_core::monitoring::Sampler;
use ksegments_core::predictors::{Allocation, MemoryPredictor};
use ksegments_core::scoring::{simulate_attempt, AttemptOutcome};
use ksegments_core::trace::{TaskRun, Trace};
use ksegments_core::tsdb::{SeriesKey, TsDb};
use ksegments_core::units::{GbSeconds, MemMiB};

/// Counters the engine reports after a workflow execution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineReport {
    pub completed: u64,
    pub attempts: u64,
    pub retries: u64,
    /// Reservation requests the resource manager had to queue (no
    /// capacity at submission).
    pub queued: u64,
    pub wastage: GbSeconds,
    pub monitor_points: u64,
}

impl EngineReport {
    pub fn retry_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.retries as f64 / self.completed as f64
        }
    }
}

/// The workflow engine: predictor + cluster + monitoring pipeline.
pub struct WorkflowEngine<P: MemoryPredictor> {
    pub predictor: P,
    pub cluster: Cluster,
    pub sampler: Sampler,
    pub tsdb: TsDb,
    pub events: EventLog,
    max_attempts: u32,
}

impl<P: MemoryPredictor> WorkflowEngine<P> {
    pub fn new(predictor: P, cluster: Cluster) -> Self {
        WorkflowEngine {
            predictor,
            cluster,
            sampler: Sampler::default(),
            tsdb: TsDb::new(),
            events: EventLog::new(),
            max_attempts: 40,
        }
    }

    /// Execute every run of a trace in submission order, returning the
    /// aggregate report. `runs` play the role of the real workload; the
    /// predictor only ever sees what the monitoring pipeline recorded.
    pub fn run_trace(&mut self, trace: &Trace) -> EngineReport {
        for ty in trace.task_types() {
            if let Some(mem) = trace.default_alloc(ty) {
                self.predictor.prime(ty, mem);
            }
        }
        let mut report = EngineReport::default();
        for run in trace.all_runs_ordered() {
            self.execute_run(run, &mut report);
        }
        report
    }

    fn execute_run(&mut self, run: &TaskRun, report: &mut EngineReport) {
        let mut alloc = self.predictor.predict(&run.task_type, run.input_mib);
        let node_max = self.cluster.node_max_mem();
        self.events.push(EngineEvent::Submitted {
            task_type: run.task_type.clone(),
            seq: run.seq,
            requested: MemMiB(alloc.max_value()),
        });
        let mut attempt = 1u32;
        loop {
            // Resource-manager admission: reserve the allocation's peak.
            let want = MemMiB(alloc.max_value().min(node_max.0));
            let reservation = match self.cluster.reserve(want) {
                Some(r) => r,
                None => {
                    // No capacity: in a real cluster the task queues; in
                    // this sequential engine the previous release always
                    // frees capacity, so this only fires on oversized
                    // requests. Count it and clamp to what fits.
                    report.queued += 1;
                    self.events.push(EngineEvent::Queued {
                        task_type: run.task_type.clone(),
                        seq: run.seq,
                        requested: want,
                    });
                    let fallback = self.cluster.total_free().min(node_max);
                    self.cluster
                        .reserve(fallback)
                        .expect("fallback reservation must fit")
                }
            };

            report.attempts += 1;
            let outcome = simulate_attempt(&run.series, &alloc, attempt);

            // Monitoring: sample what the container actually used, up
            // to the failure instant if the attempt died.
            let horizon = match &outcome {
                AttemptOutcome::Success { .. } => run.runtime.0,
                AttemptOutcome::Failure { info, .. } => info.time_s,
            };
            let key = SeriesKey::mem(&run.task_type, run.seq);
            if horizon > 0.0 && outcome.is_success() {
                report.monitor_points += self
                    .sampler
                    .sample_run(&mut self.tsdb, &key, horizon, |t| run.series.value_at(t))
                    as u64;
            }

            report.wastage += GbSeconds(MemMiB(outcome.wastage_mibs()).as_gb());
            self.cluster.release(reservation);

            match outcome {
                AttemptOutcome::Success { .. } => {
                    report.completed += 1;
                    self.events.push(EngineEvent::Completed {
                        task_type: run.task_type.clone(),
                        seq: run.seq,
                        attempts: attempt,
                    });
                    // Close the loop from the TSDB, not the generator.
                    let observed = self.sampler.series_from_db(&self.tsdb, &key);
                    let observed_run = TaskRun {
                        task_type: run.task_type.clone(),
                        input_mib: run.input_mib,
                        runtime: observed.duration(),
                        series: observed,
                        seq: run.seq,
                    };
                    self.predictor.observe(&observed_run);
                    return;
                }
                AttemptOutcome::Failure { info, .. } => {
                    report.retries += 1;
                    self.events.push(EngineEvent::Failed {
                        task_type: run.task_type.clone(),
                        seq: run.seq,
                        attempt,
                        time_s: info.time_s,
                        used: MemMiB(info.used_mib),
                        allocated: MemMiB(alloc.value_at(info.time_s)),
                    });
                    if attempt >= self.max_attempts {
                        alloc = Allocation::Static(node_max);
                    } else {
                        alloc = self
                            .predictor
                            .on_failure(&run.task_type, run.input_mib, &alloc, &info);
                    }
                    attempt += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::predictors::default_config::DefaultConfigPredictor;
    use ksegments_core::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
    use ksegments_core::trace::UsageSeries;
    use ksegments_core::units::Seconds;

    fn toy_trace(n: usize) -> Trace {
        let mut t = Trace::new();
        t.set_default("w/t", MemMiB(1000.0));
        for i in 0..n {
            let input = 50.0 + 10.0 * i as f64;
            let peak = 100.0 + input;
            let samples: Vec<f64> = (0..8).map(|j| peak * (j + 1) as f64 / 8.0).collect();
            t.push(TaskRun {
                task_type: "w/t".into(),
                input_mib: input,
                runtime: Seconds(16.0),
                series: UsageSeries::new(2.0, samples),
                seq: i as u64,
            });
        }
        t.sort();
        t
    }

    #[test]
    fn engine_completes_all_runs() {
        let mut e = WorkflowEngine::new(DefaultConfigPredictor::new(), Cluster::paper_testbed());
        let rep = e.run_trace(&toy_trace(20));
        assert_eq!(rep.completed, 20);
        assert_eq!(rep.retries, 0);
        assert_eq!(rep.attempts, 20);
        assert!(rep.wastage.0 > 0.0);
        assert!(rep.monitor_points >= 20 * 8);
    }

    #[test]
    fn monitoring_feeds_the_model() {
        let mut e = WorkflowEngine::new(
            KSegmentsPredictor::native(4, RetryStrategy::Selective),
            Cluster::paper_testbed(),
        );
        let rep = e.run_trace(&toy_trace(30));
        assert_eq!(rep.completed, 30);
        // after enough observations the predictor must be dynamic
        let alloc = e.predictor.predict("w/t", 200.0);
        assert!(alloc.is_dynamic(), "predictor never left default mode");
        // tsdb holds one mem series per completed run
        assert_eq!(e.tsdb.run_ids("w/t", "mem_mib").len(), 30);
    }

    #[test]
    fn retries_counted_and_recovered() {
        // default primed far below real peaks -> first runs fail & retry
        let mut trace = toy_trace(10);
        trace.set_default("w/t", MemMiB(10.0));
        let mut e = WorkflowEngine::new(DefaultConfigPredictor::new(), Cluster::paper_testbed());
        let rep = e.run_trace(&trace);
        assert_eq!(rep.completed, 10);
        assert!(rep.retries > 0);
        assert!(rep.attempts > 10);
        assert!(rep.retry_rate() > 0.0);
    }

    #[test]
    fn event_log_records_lifecycle() {
        let mut trace = toy_trace(5);
        trace.set_default("w/t", MemMiB(10.0)); // force failures
        let mut e = WorkflowEngine::new(DefaultConfigPredictor::new(), Cluster::paper_testbed());
        let rep = e.run_trace(&trace);
        // one Submitted and one Completed per run
        let subs = e.events.iter().filter(|ev| matches!(ev, EngineEvent::Submitted { .. })).count();
        let comps = e.events.iter().filter(|ev| matches!(ev, EngineEvent::Completed { .. })).count();
        assert_eq!(subs as u64, rep.completed);
        assert_eq!(comps as u64, rep.completed);
        // failures in the log match the retry counter
        let fails = e.events.iter().filter(|ev| matches!(ev, EngineEvent::Failed { .. })).count();
        assert_eq!(fails as u64, rep.retries);
        assert!(!e.events.retried_runs().is_empty());
        assert!(!e.events.failures_of("w/t").is_empty());
    }

    #[test]
    fn cluster_is_clean_after_run() {
        let mut e = WorkflowEngine::new(DefaultConfigPredictor::new(), Cluster::paper_testbed());
        let _ = e.run_trace(&toy_trace(5));
        assert_eq!(e.cluster.total_free(), e.cluster.node_max_mem());
    }
}
