//! Prediction-side foundation of the ksegments workspace — the only
//! layer a scientific workflow management system has to link.
//!
//! This crate reproduces the modeling core of Bader et al.,
//! *Predicting Dynamic Memory Requirements for Scientific Workflow
//! Tasks* (2023): the [`trace`] data model for task runs and their
//! time-resolved memory-usage series, the [`ml`] segmented-regression
//! machinery, the [`predictors`] roster (k-Segments and the baselines
//! it is evaluated against), the single-run [`scoring`] kernel that
//! accounts wastage and retries, and the [`wastage`] report types the
//! paper's Fig. 7 plots.
//!
//! Everything here is dependency-light and engine-agnostic: no
//! discrete-event engine, no file-format sniffing, no sockets. Those
//! live in the higher workspace layers — `ksegments-sim` (parallel
//! evaluation grids, figure regeneration), `ksegments-sched` (cluster
//! + scheduler), `ksegments-serve` (ingestion, replay, the prediction
//! service) — and the `ksegments` facade crate re-exports all of them
//! under the historical single-crate paths. The one piece of shared
//! fan-out infrastructure, the deterministic [`parallel`] worker pool,
//! lives here precisely because sim, sched and serve are peers: the
//! crate DAG (enforced by `ksegments-lint`) lets them depend on core
//! only.
//!
//! Module map:
//!
//! * [`units`], [`rng`], [`util`] — shared vocabulary: MiB/GB·s/s
//!   newtypes, the deterministic splittable rng, stats/json helpers
//!   and the bench stopwatch.
//! * [`trace`], [`source`], [`tsdb`], [`monitoring`] — task runs,
//!   usage series, the streaming [`source::TraceSource`] seam,
//!   Gorilla-style series compression and the monitoring pipeline
//!   that downsamples raw samples into [`trace::UsageSeries`].
//! * [`ml`], [`runtime`] — piecewise-constant step functions, the
//!   k-segments dynamic-programming fitter (native, plus the
//!   XLA-backed drop-in behind the `xla` feature), and fitter
//!   selection.
//! * [`predictors`] — the paper's method roster behind one
//!   [`predictors::MemoryPredictor`] trait, with the CLI-key registry
//!   in [`predictors::roster`].
//! * [`parallel`] — the deterministic order-preserving worker pool
//!   every grid and sweep fans out on.
//! * [`scoring`] — the online evaluation protocol (predict → attempt
//!   → retry) for a single predictor over a single trace.
//! * [`wastage`] — per-task and per-method wastage/retry reports
//!   (formerly the top-level `metrics` module; see the module docs for
//!   the rename rationale).
//! * [`telemetry`] — engine-agnostic observability primitives: trace
//!   sinks, the metrics registry, provenance logs.
//! * [`workload`] — synthetic workflow specs and trace generators.

pub mod monitoring;
pub mod ml;
pub mod parallel;
pub mod predictors;
pub mod rng;
pub mod runtime;
pub mod scoring;
pub mod source;
pub mod telemetry;
pub mod trace;
pub mod tsdb;
pub mod units;
pub mod util;
pub mod wastage;
pub mod workload;
