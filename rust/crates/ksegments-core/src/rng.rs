//! Deterministic pseudo-random number generation for the workload
//! generator and the simulator.
//!
//! Self-contained (no `rand` dependency): SplitMix64 for seeding and
//! xoshiro256** for the stream, plus the distributions the synthetic
//! nf-core workloads need (uniform, normal, log-normal). Determinism is
//! load-bearing — every figure in EXPERIMENTS.md is regenerated from a
//! fixed seed, and the rust integration tests assert exact replay.

/// SplitMix64 — used to expand a single u64 seed into xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream for a named substream (task type,
    /// execution index, ...) without correlating with the parent.
    pub fn fork(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        // mix parent state in so distinct parents give distinct children
        Rng::new(h ^ self.s[0].rotate_left(17) ^ self.s[2])
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift with rejection for unbiased results.
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
            if n.is_power_of_two() {
                return x & (n - 1);
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean / std deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterised by the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_is_deterministic_and_independent() {
        let root = Rng::new(7);
        let mut c1 = root.fork("task_a");
        let mut c2 = root.fork("task_a");
        let mut c3 = root.fork("task_b");
        assert_eq!(c1.next_u64(), c2.next_u64());
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.uniform(2.0, 4.0);
            assert!((2.0..4.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_is_positive() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.lognormal(5.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
