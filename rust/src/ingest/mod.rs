//! Trace ingestion and streaming replay — the path from a real
//! workflow engine's monitoring output into every evaluation surface.
//!
//! The paper evaluates on nf-core traces captured by a Nextflow
//! monitoring extension; everything else in this crate consumes the
//! [`Trace`] data model. This module closes the gap between the two
//! and removes the requirement that a trace be fully materialized in
//! memory before anything can run:
//!
//! * **parsers** ([`nextflow`]): Nextflow-style `trace.txt` TSV (task
//!   names, `realtime`, `peak_rss`, requested `memory`, input-size
//!   columns, with `KB`/`MB`/`GB` unit suffixes via
//!   [`MemMiB::parse`]) plus per-task monitoring sample CSVs,
//!   normalized into [`TaskRun`]/[`crate::trace::UsageSeries`];
//! * **the [`TraceSource`] trait**: a chunked, rewindable iterator of
//!   [`TaskRun`]s in arrival order, with [`InMemorySource`],
//!   [`JsonlReader`] (streaming JSON-lines) and [`NextflowDirSource`]
//!   implementations — consumed by the streaming replay engine
//!   ([`replay_source`]), the scheduler's arrival stream
//!   ([`crate::sched::schedule_stream`]) and the prediction service
//!   ([`crate::coordinator::ServiceHandle::replay_source`]);
//! * **predictor checkpointing** ([`Checkpoint`]): the fitted
//!   per-task-type state — primed defaults plus the sliding window of
//!   observed runs every predictor derives its fit and offsets from —
//!   serialized as JSONL, so a replay (or a restarted service) can
//!   warm-start instead of re-learning from scratch.
//!
//! CLI entry points: `ksegments ingest <dir>` (normalize a Nextflow
//! trace directory to replay-ordered JSONL) and `ksegments replay
//! --source <path> --method <key> [--checkpoint <path>]`.

pub mod checkpoint;
pub mod jsonl;
pub mod nextflow;
pub mod replay;

pub use checkpoint::Checkpoint;
pub use jsonl::JsonlReader;
pub use nextflow::{read_nextflow_dir, NextflowDirSource};
pub use replay::{replay_source, ReplayConfig, ReplayOutcome};

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::trace::{read_trace_csv, TaskRun, Trace};
use crate::units::MemMiB;

/// Default [`TraceSource::next_chunk`] request size used by the CLI
/// and the replay surfaces.
pub const DEFAULT_CHUNK: usize = 256;

/// A streaming source of task runs in arrival order.
///
/// The contract every consumer relies on: runs of one task type are
/// yielded oldest-first (the online-learning order), and the
/// concatenation of all chunks is the full stream. Sources that read a
/// `ksegments ingest` output file (or any
/// [`crate::trace::write_trace_jsonl_ordered`] file) additionally
/// yield the *global* submission order, which is what the scheduler's
/// arrival stream consumes.
pub trait TraceSource: Send {
    /// Human-readable origin (a path, `"in-memory"`, ...).
    fn origin(&self) -> String;

    /// Developer-default allocations known for this source, sorted by
    /// task type (may be empty; Nextflow traces carry the requested
    /// `memory` per process).
    fn defaults(&self) -> Vec<(String, MemMiB)>;

    /// Pull the next chunk of at most `max` runs. An empty vector
    /// means the stream is exhausted.
    fn next_chunk(&mut self, max: usize) -> Result<Vec<TaskRun>>;

    /// Restart the stream from the beginning (re-opens files).
    fn rewind(&mut self) -> Result<()>;
}

/// A [`TraceSource`] over an already-materialized run list — the
/// adapter that lets every streaming consumer also accept an in-memory
/// [`Trace`] (and the reference implementation the streaming readers
/// are tested against).
#[derive(Debug, Clone)]
pub struct InMemorySource {
    defaults: Vec<(String, MemMiB)>,
    runs: Vec<TaskRun>,
    pos: usize,
}

impl InMemorySource {
    /// Stream a trace's runs in global submission (`seq`) order.
    pub fn from_trace(trace: &Trace) -> InMemorySource {
        let defaults = trace
            .task_types()
            .filter_map(|ty| trace.default_alloc(ty).map(|m| (ty.to_string(), m)))
            .collect();
        let runs = trace.all_runs_ordered().into_iter().cloned().collect();
        InMemorySource { defaults, runs, pos: 0 }
    }

    /// Stream an explicit run list in the order given.
    pub fn from_runs(defaults: Vec<(String, MemMiB)>, runs: Vec<TaskRun>) -> InMemorySource {
        InMemorySource { defaults, runs, pos: 0 }
    }

    pub fn len(&self) -> usize {
        self.runs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }
}

impl TraceSource for InMemorySource {
    fn origin(&self) -> String {
        format!("in-memory ({} runs)", self.runs.len())
    }

    fn defaults(&self) -> Vec<(String, MemMiB)> {
        self.defaults.clone()
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<TaskRun>> {
        let end = (self.pos + max.max(1)).min(self.runs.len());
        let chunk = self.runs[self.pos..end].to_vec();
        self.pos = end;
        Ok(chunk)
    }

    fn rewind(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }
}

/// Open a path as a [`TraceSource`] by sniffing its shape: a directory
/// is a Nextflow trace dir (`trace.txt` [+ `samples/`]), a `.jsonl`
/// file streams through [`JsonlReader`], a `.csv` file is read whole
/// (the CSV layout interleaves runs, so it cannot stream) and served
/// from memory.
pub fn open_source(path: &Path) -> Result<Box<dyn TraceSource>> {
    if path.is_dir() {
        return Ok(Box::new(NextflowDirSource::open(path)?));
    }
    match path.extension().and_then(|e| e.to_str()) {
        Some("jsonl") => Ok(Box::new(JsonlReader::open(path)?)),
        Some("csv") => {
            let trace = read_trace_csv(path)
                .with_context(|| format!("reading csv trace {}", path.display()))?;
            Ok(Box::new(InMemorySource::from_trace(&trace)))
        }
        _ => bail!(
            "cannot open {} as a trace source (expected a Nextflow trace \
             directory, a .jsonl file or a .csv file)",
            path.display()
        ),
    }
}

/// Drain a source into a fully materialized [`Trace`] (defaults
/// applied, runs sorted per type) — the bridge back to the batch
/// surfaces ([`crate::sim::EvalGrid`], figure regeneration).
pub fn materialize(src: &mut dyn TraceSource) -> Result<Trace> {
    let mut trace = Trace::new();
    for (ty, mem) in src.defaults() {
        trace.set_default(&ty, mem);
    }
    loop {
        let chunk = src.next_chunk(DEFAULT_CHUNK)?;
        if chunk.is_empty() {
            break;
        }
        for run in chunk {
            trace.push(run);
        }
    }
    trace.sort();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn toy_trace() -> Trace {
        let mut t = Trace::new();
        t.set_default("w/a", MemMiB(1000.0));
        for seq in 0..5u64 {
            t.push(TaskRun {
                task_type: if seq % 2 == 0 { "w/a".into() } else { "w/b".into() },
                input_mib: 10.0 * seq as f64,
                runtime: Seconds(4.0),
                series: UsageSeries::new(2.0, vec![1.0, 2.0 + seq as f64]),
                seq,
            });
        }
        t.sort();
        t
    }

    #[test]
    fn in_memory_source_streams_in_seq_order() {
        let t = toy_trace();
        let mut src = InMemorySource::from_trace(&t);
        assert_eq!(src.defaults(), vec![("w/a".to_string(), MemMiB(1000.0))]);
        let mut seqs = Vec::new();
        loop {
            let chunk = src.next_chunk(2).unwrap();
            if chunk.is_empty() {
                break;
            }
            assert!(chunk.len() <= 2);
            seqs.extend(chunk.iter().map(|r| r.seq));
        }
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
        // exhausted stays exhausted until rewind
        assert!(src.next_chunk(8).unwrap().is_empty());
        src.rewind().unwrap();
        assert_eq!(src.next_chunk(8).unwrap().len(), 5);
    }

    #[test]
    fn materialize_round_trips_the_trace() {
        let t = toy_trace();
        let mut src = InMemorySource::from_trace(&t);
        let back = materialize(&mut src).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn open_source_rejects_unknown_shapes() {
        let dir = std::env::temp_dir().join("ksegments_test_ingest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.parquet");
        std::fs::write(&path, b"nope").unwrap();
        assert!(open_source(&path).is_err());
        assert!(open_source(&dir.join("missing.jsonl")).is_err());
    }
}
