"""AOT pipeline tests: HLO text generation + manifest consistency.

These validate the build-time contract the rust runtime depends on; the
rust side has a mirror-image integration test that loads the emitted
artifacts and cross-checks numerics against its native fit.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_fit, to_hlo_text
from compile.model import K_RANGE, N_HIST, T_MAX, make_fit_fn

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


class TestLowering:
    def test_hlo_text_parses_back(self):
        """Round-trip: the text we emit must be valid HLO text."""
        text = lower_fit(k=2, n=8, t=16)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_hlo_has_tuple_root_with_four_elements(self):
        text = lower_fit(k=3, n=8, t=16)
        # return_tuple=True -> root is a 4-tuple (rt_coef, rt_off, seg, off)
        assert "(f32[2]" in text and "f32[3,2]" in text and "f32[3]" in text

    def test_small_and_aot_shapes_produce_distinct_modules(self):
        a = lower_fit(k=2, n=8, t=16)
        b = lower_fit(k=2, n=16, t=32)
        assert a != b

    def test_numerics_survive_lowering(self):
        """Execute the lowered module through jax and compare to eager."""
        rng = np.random.default_rng(0)
        n, t, k = 8, 16, 4
        x = jnp.asarray(rng.uniform(1, 100, n), dtype=jnp.float32)
        y = jnp.asarray(rng.uniform(0, 500, (n, t)), dtype=jnp.float32)
        rt = jnp.asarray(rng.uniform(10, 50, n), dtype=jnp.float32)
        v = jnp.ones(n, dtype=jnp.float32)
        eager = make_fit_fn(k)(x, y, rt, v)
        compiled = jax.jit(make_fit_fn(k))(x, y, rt, v)
        for g, w in zip(compiled, eager):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-4)


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts` first")
class TestEmittedArtifacts:
    def test_manifest_covers_k_range(self):
        manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
        assert manifest["n_hist"] == N_HIST
        assert manifest["t_max"] == T_MAX
        assert sorted(int(k) for k in manifest["fits"]) == sorted(K_RANGE)

    def test_artifact_files_exist_and_are_hlo_text(self):
        manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
        for k, name in manifest["fits"].items():
            text = (ARTIFACTS / name).read_text()
            assert text.startswith("HloModule"), f"k={k} artifact is not HLO text"
            assert f"f32[{k},2]" in text, f"k={k} artifact has wrong seg_coef shape"

    def test_sentinel_matches_default_k(self):
        sentinel = (ARTIFACTS / "model.hlo.txt").read_text()
        k4 = (ARTIFACTS / "ksegments_fit_k4.hlo.txt").read_text()
        assert sentinel == k4
