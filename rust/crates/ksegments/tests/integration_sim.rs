//! Integration: the full evaluation pipeline over both paper
//! workflows — asserts the *shape* results the reproduction must hold
//! (DESIGN.md §5 "shape expectations") plus cross-module behaviours:
//! trace I/O round-trips through the simulator, the engine agrees with
//! the protocol, determinism end to end.

use ksegments::bench_harness::{evaluate_method, paper_traces};
use ksegments::cluster::Cluster;
use ksegments::engine::WorkflowEngine;
use ksegments::metrics::count_wins;
use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::lr_witt::LrWittPredictor;
use ksegments::predictors::ppm::PpmPredictor;
use ksegments::predictors::MemoryPredictor;
use ksegments::sim::{simulate_trace, SimConfig};
use ksegments::trace::{read_trace_jsonl, write_trace_jsonl};
use ksegments::workload::{
    eager_workflow, generate_workflow_trace, sarek_workflow, EVAL_MIN_RUNS,
};

#[test]
fn thirty_three_tasks_are_evaluated() {
    let traces = paper_traces(42);
    let n: usize = traces
        .iter()
        .map(|t| t.evaluated_types(EVAL_MIN_RUNS).len())
        .sum();
    assert_eq!(n, 33, "the paper evaluates 33 tasks");
}

/// The central ordering claim of Fig. 7a at 50 % training.
#[test]
fn method_ordering_matches_paper() {
    let traces = paper_traces(42);
    let frac = 0.5;
    let w = |mk: &dyn Fn() -> Box<dyn MemoryPredictor>| {
        evaluate_method(mk, &traces, frac).avg_wastage_gbs()
    };
    let default = w(&|| Box::new(DefaultConfigPredictor::new()));
    let ppm = w(&|| Box::new(PpmPredictor::original()));
    let ppm_improved = w(&|| Box::new(PpmPredictor::improved()));
    let lr = w(&|| Box::new(LrWittPredictor::paper_baseline()));
    let sel = w(&|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective)));
    let par = w(&|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Partial)));

    // default is the worst by a wide margin (paper: 2.5-3x the best)
    assert!(default > 2.0 * ppm_improved, "default {default} vs ppm improved {ppm_improved}");
    assert!(default > 5.0 * sel, "default {default} vs k-seg {sel}");
    // original PPM's node-max failure policy is catastrophic vs Improved
    assert!(ppm > 1.5 * ppm_improved, "ppm {ppm} vs improved {ppm_improved}");
    // k-Segments (both strategies) beats every baseline
    for (name, base) in [("ppm", ppm), ("ppm_improved", ppm_improved), ("lr", lr)] {
        assert!(sel < base, "selective {sel} !< {name} {base}");
        assert!(par < base, "partial {par} !< {name} {base}");
    }
    // and by a meaningful factor vs the best baseline (paper: 29.48%)
    let best_base = ppm_improved.min(lr).min(ppm);
    assert!(
        sel < 0.9 * best_base,
        "selective {sel} should be >=10% below best baseline {best_base}"
    );
}

/// Fig. 7a trend: k-Segments improves with more training data.
#[test]
fn ksegments_improves_with_training_data() {
    let traces = paper_traces(42);
    let mk = || -> Box<dyn MemoryPredictor> {
        Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
    };
    let w25 = evaluate_method(&mk, &traces, 0.25).avg_wastage_gbs();
    let w75 = evaluate_method(&mk, &traces, 0.75).avg_wastage_gbs();
    assert!(w75 < w25, "wastage should fall with training data: 25%={w25} 75%={w75}");
}

/// Fig. 7b: k-Segments collects the most lowest-wastage wins.
#[test]
fn ksegments_wins_most_tasks() {
    let traces = paper_traces(42);
    let reports = vec![
        evaluate_method(&|| Box::new(PpmPredictor::improved()) as _, &traces, 0.5),
        evaluate_method(&|| Box::new(LrWittPredictor::paper_baseline()) as _, &traces, 0.5),
        evaluate_method(
            &|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective)) as _,
            &traces,
            0.5,
        ),
    ];
    let wins = count_wins(&reports);
    let kseg_wins = wins.iter().find(|w| w.0.starts_with("k-Segments")).unwrap().1;
    let max_other = wins
        .iter()
        .filter(|w| !w.0.starts_with("k-Segments"))
        .map(|w| w.1)
        .max()
        .unwrap();
    assert!(kseg_wins > max_other, "k-Segments wins {kseg_wins} vs best other {max_other}");
}

/// Fig. 7c trends: defaults never retry; k-Segments retries shrink
/// with training data and end below LR's.
#[test]
fn retry_trends_match_paper() {
    let traces = paper_traces(42);
    let retries = |mk: &dyn Fn() -> Box<dyn MemoryPredictor>, frac: f64| {
        evaluate_method(mk, &traces, frac).avg_retries()
    };
    let default_r = retries(&|| Box::new(DefaultConfigPredictor::new()), 0.5);
    assert_eq!(default_r, 0.0, "defaults are sized to never fail");

    let mk_kseg = || -> Box<dyn MemoryPredictor> {
        Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
    };
    let k25 = retries(&mk_kseg, 0.25);
    let k75 = retries(&mk_kseg, 0.75);
    assert!(k75 < k25, "k-seg retries should fall with data: {k25} -> {k75}");

    let lr75 = retries(&|| Box::new(LrWittPredictor::paper_baseline()), 0.75);
    assert!(k75 < lr75, "at 75% k-seg ({k75}) must retry less than LR ({lr75})");
}

/// Selective vs Partial (paper: Selective lowest, Partial close).
#[test]
fn selective_edges_out_partial_overall() {
    let traces = paper_traces(42);
    let sel = evaluate_method(
        &|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective)) as _,
        &traces,
        0.75,
    )
    .avg_wastage_gbs();
    let par = evaluate_method(
        &|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Partial)) as _,
        &traces,
        0.75,
    )
    .avg_wastage_gbs();
    // close together, selective no worse than a couple % ahead
    assert!((sel - par).abs() / par < 0.05, "sel {sel} vs par {par} diverged");
    assert!(sel <= par * 1.01, "selective should be at least on par");
}

/// Trace I/O round-trips through the full simulator identically.
#[test]
fn persisted_trace_reproduces_simulation() {
    let trace = generate_workflow_trace(&eager_workflow(), 7);
    let dir = std::env::temp_dir().join("ksegments_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("eager.jsonl");
    write_trace_jsonl(&trace, &path).unwrap();
    let reloaded = read_trace_jsonl(&path).unwrap();

    let cfg = SimConfig::with_training_frac(0.5);
    let mut a = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    let mut b = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    let rep_a = simulate_trace(&trace, &mut a, &cfg);
    let rep_b = simulate_trace(&reloaded, &mut b, &cfg);
    assert_eq!(rep_a.avg_wastage_gbs(), rep_b.avg_wastage_gbs());
    assert_eq!(rep_a.total_retries(), rep_b.total_retries());
}

/// The protocol is bit-deterministic for a given seed.
#[test]
fn simulation_is_deterministic() {
    for _ in 0..2 {
        let traces = paper_traces(13);
        let rep = evaluate_method(
            &|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective)) as _,
            &traces,
            0.5,
        );
        // spot-check a stable scalar
        let w = rep.avg_wastage_gbs();
        let again = evaluate_method(
            &|| Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective)) as _,
            &paper_traces(13),
            0.5,
        )
        .avg_wastage_gbs();
        assert_eq!(w, again);
    }
}

/// The engine (cluster + monitoring loop) and the protocol agree on
/// which method is better.
#[test]
fn engine_agrees_with_protocol_on_ordering() {
    let trace = generate_workflow_trace(&sarek_workflow(), 3)
        .filtered(|ty| ty == "sarek/haplotypecaller" || ty == "sarek/mosdepth");
    let mut e_default =
        WorkflowEngine::new(DefaultConfigPredictor::new(), Cluster::paper_testbed());
    let mut e_kseg = WorkflowEngine::new(
        KSegmentsPredictor::native(4, RetryStrategy::Selective),
        Cluster::paper_testbed(),
    );
    let r_default = e_default.run_trace(&trace);
    let r_kseg = e_kseg.run_trace(&trace);
    assert_eq!(r_default.completed, r_kseg.completed);
    assert!(
        r_kseg.wastage.0 < r_default.wastage.0,
        "k-seg {} vs default {}",
        r_kseg.wastage.0,
        r_default.wastage.0
    );
}
