//! Per-task execution history — the sliding training window shared by
//! the learned predictors.

use std::collections::BTreeMap;

use crate::ml::fitter::FitInput;
use crate::trace::TaskRun;

/// Ring buffer of the most recent executions of one task type, already
//  transformed into fit-ready arrays.
///
/// Eviction is amortized O(1): the backing vectors keep up to `cap`
/// dead rows at their front (tracked by `start`) and are compacted
/// with a single `drain` once the slack fills, instead of an O(cap)
/// memmove per completion (`Vec::remove(0)` — the former hot-path
/// cost on every `observe`; see `hotpath` bench `history/push-evict`).
/// The live window is always the contiguous tail `[start..]`, so the
/// `x()`/`runtime()`/`peaks()` slice views stay free.
#[derive(Debug, Clone)]
pub struct TaskHistory {
    cap: usize,
    /// Resample length for series rows (all rows share it).
    t_len: usize,
    /// Index of the first LIVE row in the backing vectors; rows before
    /// it have been evicted but not yet compacted away. Invariant:
    /// `start < cap` and `len() <= cap` (so the vectors never exceed
    /// `2·cap − 1` rows).
    start: usize,
    x: Vec<f64>,
    runtime: Vec<f64>,
    peaks: Vec<f64>,
    series: Vec<Vec<f64>>,
    /// Total completions ever observed (not capped).
    total_seen: u64,
}

impl TaskHistory {
    pub fn new(cap: usize, t_len: usize) -> TaskHistory {
        assert!(cap > 0 && t_len > 0);
        TaskHistory {
            cap,
            t_len,
            start: 0,
            x: Vec::new(),
            runtime: Vec::new(),
            peaks: Vec::new(),
            series: Vec::new(),
            total_seen: 0,
        }
    }

    pub fn push(&mut self, run: &TaskRun) {
        if self.x.len() - self.start == self.cap {
            // Evict the oldest row by advancing the head; compact the
            // dead prefix only once per `cap` evictions.
            self.start += 1;
            if self.start == self.cap {
                self.x.drain(..self.start);
                self.runtime.drain(..self.start);
                self.peaks.drain(..self.start);
                self.series.drain(..self.start);
                self.start = 0;
            }
        }
        self.x.push(run.input_mib);
        self.runtime.push(run.runtime.0);
        self.peaks.push(run.series.peak());
        self.series.push(run.series.resample_peaks(self.t_len));
        self.total_seen += 1;
    }

    pub fn len(&self) -> usize {
        self.x.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn total_seen(&self) -> u64 {
        self.total_seen
    }

    pub fn x(&self) -> &[f64] {
        &self.x[self.start..]
    }

    pub fn runtime(&self) -> &[f64] {
        &self.runtime[self.start..]
    }

    /// Whole-run peak per execution (what static baselines learn from).
    pub fn peaks(&self) -> &[f64] {
        &self.peaks[self.start..]
    }

    /// Resampled usage rows of the live window (fit training rows).
    pub fn series(&self) -> &[Vec<f64>] {
        &self.series[self.start..]
    }

    /// Fit-ready view for the k-Segments fitters.
    pub fn fit_input(&self) -> FitInput {
        FitInput {
            x: self.x().to_vec(),
            runtime: self.runtime().to_vec(),
            series: self.series().to_vec(),
        }
    }
}

/// Histories for all task types.
#[derive(Debug, Clone)]
pub struct HistoryMap {
    cap: usize,
    t_len: usize,
    map: BTreeMap<String, TaskHistory>,
}

impl HistoryMap {
    pub fn new(cap: usize, t_len: usize) -> HistoryMap {
        HistoryMap { cap, t_len, map: BTreeMap::new() }
    }

    pub fn push(&mut self, run: &TaskRun) {
        self.map
            .entry(run.task_type.clone())
            .or_insert_with(|| TaskHistory::new(self.cap, self.t_len))
            .push(run);
    }

    pub fn get(&self, task_type: &str) -> Option<&TaskHistory> {
        self.map.get(task_type)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn run(input: f64, peak: f64) -> TaskRun {
        TaskRun {
            task_type: "t".into(),
            input_mib: input,
            runtime: Seconds(8.0),
            series: UsageSeries::new(2.0, vec![peak / 2.0, peak, peak / 4.0, peak / 8.0]),
            seq: 0,
        }
    }

    #[test]
    fn push_and_views() {
        let mut h = TaskHistory::new(4, 8);
        h.push(&run(10.0, 100.0));
        h.push(&run(20.0, 200.0));
        assert_eq!(h.len(), 2);
        assert_eq!(h.x(), &[10.0, 20.0]);
        assert_eq!(h.peaks(), &[100.0, 200.0]);
        assert_eq!(h.runtime(), &[8.0, 8.0]);
        let fi = h.fit_input();
        fi.validate().unwrap();
        assert_eq!(fi.series[0].len(), 8);
    }

    #[test]
    fn ring_buffer_evicts_oldest() {
        let mut h = TaskHistory::new(3, 4);
        for i in 0..5 {
            h.push(&run(i as f64, 1.0));
        }
        assert_eq!(h.len(), 3);
        assert_eq!(h.x(), &[2.0, 3.0, 4.0]);
        assert_eq!(h.total_seen(), 5);
    }

    #[test]
    fn ring_views_stay_correct_across_many_compactions() {
        // Push far past cap so the lazy head crosses several compaction
        // boundaries; the window must always be the last `cap` rows and
        // the backing storage must stay bounded by 2·cap − 1.
        let cap = 7;
        let mut h = TaskHistory::new(cap, 4);
        for i in 0..10 * cap {
            h.push(&run(i as f64, (i + 1) as f64));
            let lo = (i + 1).saturating_sub(cap);
            let expect: Vec<f64> = (lo..=i).map(|j| j as f64).collect();
            assert_eq!(h.x(), &expect[..], "window wrong after push {i}");
            assert_eq!(h.peaks().len(), h.len());
            assert_eq!(h.runtime().len(), h.len());
            assert_eq!(h.series().len(), h.len());
            assert!(h.x.len() < 2 * cap, "backing storage grew unbounded");
        }
        assert_eq!(h.total_seen(), 10 * cap as u64);
        let fi = h.fit_input();
        fi.validate().unwrap();
        assert_eq!(fi.x, h.x());
    }

    #[test]
    fn resample_preserves_peak_in_rows() {
        let mut h = TaskHistory::new(2, 4);
        h.push(&run(1.0, 777.0));
        let fi = h.fit_input();
        let row_max = fi.series[0].iter().copied().fold(f64::MIN, f64::max);
        assert_eq!(row_max, 777.0);
    }

    #[test]
    fn history_map_routes_by_type() {
        let mut m = HistoryMap::new(8, 4);
        let mut r1 = run(1.0, 10.0);
        r1.task_type = "a".into();
        let mut r2 = run(2.0, 20.0);
        r2.task_type = "b".into();
        m.push(&r1);
        m.push(&r2);
        m.push(&r1);
        assert_eq!(m.get("a").unwrap().len(), 2);
        assert_eq!(m.get("b").unwrap().len(), 1);
        assert!(m.get("c").is_none());
    }
}
