//! Scheduler-level metrics: what a cluster operator sees — makespan,
//! queue waits, admission/kill counters, utilization, wastage.
//!
//! [`SchedReport`] merges like [`ksegments_core::wastage::MethodReport`]: the
//! parallel grid runs one cell per (policy × predictor × cluster ×
//! arrival × trace) and folds per-trace partials together in trace
//! order. Counters and integrals add, makespan and peak utilization
//! take the max, queue-wait samples concatenate. All derived
//! statistics (mean/percentile waits, utilization, throughput) are
//! therefore permutation-invariant up to float-addition reordering —
//! locked down by the property tests in `tests/sched_integration.rs`.

use ksegments_core::telemetry::Registry;
use ksegments_core::units::{GbSeconds, Seconds};
use ksegments_core::util::stats;
use ksegments_core::util::stats::SortedSamples;

/// Queue-wait histogram buckets (seconds) used by
/// [`SchedReport::export_metrics`] — fixed so that partial registries
/// from different runs always merge.
pub const QUEUE_WAIT_BUCKETS_S: &[f64] = &[0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0, 120.0];

/// An instance counts as a **straggler** when its achieved makespan
/// exceeds this multiple of its critical-path length — it spent more
/// time queued, retried or contended than actually computing.
pub const STRAGGLER_FACTOR: f64 = 2.0;

/// Aggregate result of scheduling one trace (or several merged traces)
/// on a simulated cluster.
///
/// Accounting identities (asserted by tests):
///
/// * every scheduled task eventually leaves the system:
///   `completed == submitted`;
/// * every admitted attempt ends exactly one way:
///   `admitted == completed + oom_kills + grow_denials + preempted + node_lost`;
/// * every placement attempt either admits or rejects:
///   `placement_attempts == admitted + rejected`.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedReport {
    /// Reservation policy name ("static-peak" / "segment-wise").
    pub policy: String,
    /// Predictor display name.
    pub method: String,
    /// Cluster size the cell ran with.
    pub n_nodes: usize,
    /// Mean inter-arrival time of the arrival stream (seconds).
    pub mean_interarrival_s: f64,
    /// Tasks submitted to the scheduler (the scored arrival stream).
    pub submitted: u64,
    /// Tasks that finished (every task does, via retry escalation).
    pub completed: u64,
    /// Successful placements (attempt starts).
    pub admitted: u64,
    /// Cluster-wide placement attempts that fit on no node.
    pub rejected: u64,
    /// Total placement attempts (`admitted + rejected`).
    pub placement_attempts: u64,
    /// Attempts killed by the OOM killer and requeued (ground-truth
    /// usage exceeded the reservation before the attempt ended).
    pub oom_kills: u64,
    /// Attempts killed because a segment-boundary grow was denied
    /// under contention and requeued with a full-peak reservation.
    pub grow_denials: u64,
    /// Attempts evicted by a higher-priority placement and requeued
    /// **blamelessly** (same allocation, same attempt number).
    pub preempted: u64,
    /// Attempts killed because their node was lost; requeued
    /// blamelessly like preemptions.
    pub node_lost: u64,
    /// Injected node-loss events (each takes one node down).
    pub node_failures: u64,
    /// Nodes the autoscaler brought into service (joins after lag).
    pub nodes_added: u64,
    /// Idle autoscaled nodes the autoscaler retired.
    pub nodes_retired: u64,
    /// Discrete events the engine processed — the denominator of the
    /// scheduler events/s perf snapshot (`BENCH_sched.json`).
    pub events_processed: u64,
    /// Maximum number of concurrently running attempts — the direct
    /// "how many tasks co-locate" packing signal.
    pub peak_running: u64,
    /// Time from first arrival epoch (t = 0) to the last completion.
    pub makespan: Seconds,
    /// Reserved-minus-used wastage over all attempts (failed attempts
    /// waste their full reservation integral, as in [`ksegments_core::scoring`]).
    pub total_wastage: GbSeconds,
    /// Per-admission queue wait (seconds from enqueue to placement).
    pub queue_waits: Vec<f64>,
    /// Integral of reserved memory over time (GB·s).
    pub reserved_integral_gbs: f64,
    /// Integral of **up** cluster capacity over the run (GB·s) — the
    /// utilization denominator. With a fixed, always-up roster this is
    /// capacity × makespan; under failures and autoscaling the
    /// denominator tracks the live roster.
    pub capacity_integral_gbs: f64,
    /// Peak of (reserved / capacity) over the run.
    pub peak_util_frac: f64,
    /// Workflow instances that arrived (0 = independent-arrivals mode;
    /// every field below is empty/zero then).
    pub workflows_submitted: u64,
    /// Workflow instances whose last task finally completed.
    pub workflows_completed: u64,
    /// Per completed instance, in completion order: seconds from the
    /// instance's arrival to its last task's final completion.
    pub workflow_makespans: Vec<f64>,
    /// Per completed instance (same order): critical-path length — the
    /// longest runtime chain through its DAG, the retry-free
    /// infinite-cluster lower bound on the achieved makespan.
    pub workflow_critical_paths: Vec<f64>,
    /// Per completed instance (same order): seconds from arrival to
    /// the instance's **first** task completion.
    pub workflow_first_completions: Vec<f64>,
    /// Instances whose makespan exceeded [`STRAGGLER_FACTOR`] × their
    /// critical path.
    pub workflow_stragglers: u64,
}

impl SchedReport {
    pub fn new(
        policy: &str,
        method: &str,
        n_nodes: usize,
        mean_interarrival_s: f64,
    ) -> SchedReport {
        SchedReport {
            policy: policy.to_string(),
            method: method.to_string(),
            n_nodes,
            mean_interarrival_s,
            submitted: 0,
            completed: 0,
            admitted: 0,
            rejected: 0,
            placement_attempts: 0,
            oom_kills: 0,
            grow_denials: 0,
            preempted: 0,
            node_lost: 0,
            node_failures: 0,
            nodes_added: 0,
            nodes_retired: 0,
            events_processed: 0,
            peak_running: 0,
            makespan: Seconds::ZERO,
            total_wastage: GbSeconds::ZERO,
            queue_waits: Vec::new(),
            reserved_integral_gbs: 0.0,
            capacity_integral_gbs: 0.0,
            peak_util_frac: 0.0,
            workflows_submitted: 0,
            workflows_completed: 0,
            workflow_makespans: Vec::new(),
            workflow_critical_paths: Vec::new(),
            workflow_first_completions: Vec::new(),
            workflow_stragglers: 0,
        }
    }

    /// Mean queue wait per admission (seconds; 0 if nothing admitted).
    pub fn mean_queue_wait_s(&self) -> f64 {
        stats::mean(&self.queue_waits)
    }

    /// p-th percentile queue wait (seconds). Sorts per call — querying
    /// several quantiles of one report should go through
    /// [`Self::queue_wait_percentiles`] instead.
    pub fn queue_wait_percentile_s(&self, p: f64) -> f64 {
        stats::percentile(&self.queue_waits, p)
    }

    /// The queue-wait samples sorted **once** for repeated quantile
    /// queries — what the summary line and the per-row throughput
    /// tables use instead of re-sorting the full vector per call.
    pub fn queue_wait_percentiles(&self) -> SortedSamples {
        SortedSamples::new(&self.queue_waits)
    }

    /// Mean achieved workflow makespan (seconds; 0 without instances).
    pub fn mean_workflow_makespan_s(&self) -> f64 {
        stats::mean(&self.workflow_makespans)
    }

    /// Mean critical-path length across completed instances.
    pub fn mean_critical_path_s(&self) -> f64 {
        stats::mean(&self.workflow_critical_paths)
    }

    /// Mean of per-instance `makespan / critical path` — 1.0 means
    /// every instance ran as fast as its DAG allows; the excess is
    /// queueing, contention and retry propagation. 0 without instances.
    pub fn critical_path_stretch(&self) -> f64 {
        if self.workflow_makespans.is_empty() {
            return 0.0;
        }
        let ratios: Vec<f64> = self
            .workflow_makespans
            .iter()
            .zip(&self.workflow_critical_paths)
            .filter(|(_, &cp)| cp > 0.0)
            .map(|(&m, &cp)| m / cp)
            .collect();
        stats::mean(&ratios)
    }

    /// Mean time from instance arrival to its first task completion.
    pub fn mean_time_to_first_completion_s(&self) -> f64 {
        stats::mean(&self.workflow_first_completions)
    }

    /// Time-averaged cluster memory utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.capacity_integral_gbs <= 0.0 {
            0.0
        } else {
            self.reserved_integral_gbs / self.capacity_integral_gbs
        }
    }

    /// Completed tasks per hour of makespan — the throughput headline.
    pub fn throughput_per_hour(&self) -> f64 {
        if self.makespan.0 <= 0.0 {
            0.0
        } else {
            self.completed as f64 * 3600.0 / self.makespan.0
        }
    }

    /// Fold another report of the **same configuration** into this one
    /// (per-trace partials of one grid cell).
    pub fn merge(&mut self, other: SchedReport) {
        assert_eq!(self.policy, other.policy, "merging different policies");
        assert_eq!(self.method, other.method, "merging different methods");
        assert_eq!(self.n_nodes, other.n_nodes, "merging different cluster sizes");
        assert!(
            (self.mean_interarrival_s - other.mean_interarrival_s).abs() < 1e-12,
            "merging different arrival rates"
        );
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.placement_attempts += other.placement_attempts;
        self.oom_kills += other.oom_kills;
        self.grow_denials += other.grow_denials;
        self.preempted += other.preempted;
        self.node_lost += other.node_lost;
        self.node_failures += other.node_failures;
        self.nodes_added += other.nodes_added;
        self.nodes_retired += other.nodes_retired;
        self.events_processed += other.events_processed;
        self.peak_running = self.peak_running.max(other.peak_running);
        self.makespan = self.makespan.max(other.makespan);
        self.total_wastage += other.total_wastage;
        self.queue_waits.extend(other.queue_waits);
        self.reserved_integral_gbs += other.reserved_integral_gbs;
        self.capacity_integral_gbs += other.capacity_integral_gbs;
        self.peak_util_frac = self.peak_util_frac.max(other.peak_util_frac);
        self.workflows_submitted += other.workflows_submitted;
        self.workflows_completed += other.workflows_completed;
        self.workflow_makespans.extend(other.workflow_makespans);
        self.workflow_critical_paths.extend(other.workflow_critical_paths);
        self.workflow_first_completions.extend(other.workflow_first_completions);
        self.workflow_stragglers += other.workflow_stragglers;
    }

    /// Merge an ordered sequence of per-trace reports; `None` for an
    /// empty sequence.
    pub fn merged(reports: impl IntoIterator<Item = SchedReport>) -> Option<SchedReport> {
        let mut it = reports.into_iter();
        let mut acc = it.next()?;
        for rep in it {
            acc.merge(rep);
        }
        Some(acc)
    }

    /// Export the report into a metrics [`Registry`] under
    /// `{policy,method}` labels — counters for the accounting
    /// identities, gauges for the derived ratios and a fixed-bucket
    /// queue-wait histogram ([`QUEUE_WAIT_BUCKETS_S`]). Purely
    /// observational: reads `&self`, writes only into `reg`.
    pub fn export_metrics(&self, reg: &mut Registry) {
        let l = format!("{{policy=\"{}\",method=\"{}\"}}", self.policy, self.method);
        for (name, v) in [
            ("sched_submitted", self.submitted),
            ("sched_completed", self.completed),
            ("sched_admitted", self.admitted),
            ("sched_rejected", self.rejected),
            ("sched_placement_attempts", self.placement_attempts),
            ("sched_oom_kills", self.oom_kills),
            ("sched_grow_denials", self.grow_denials),
            ("sched_preempted", self.preempted),
            ("sched_node_lost", self.node_lost),
            ("sched_node_failures", self.node_failures),
            ("sched_nodes_added", self.nodes_added),
            ("sched_nodes_retired", self.nodes_retired),
            ("sched_events_processed", self.events_processed),
            ("sched_workflows_submitted", self.workflows_submitted),
            ("sched_workflows_completed", self.workflows_completed),
            ("sched_workflow_stragglers", self.workflow_stragglers),
        ] {
            reg.counter_add(&format!("{name}{l}"), v);
        }
        for (name, v) in [
            ("sched_makespan_s", self.makespan.0),
            ("sched_utilization_frac", self.utilization()),
            ("sched_peak_util_frac", self.peak_util_frac),
            ("sched_peak_running", self.peak_running as f64),
            ("sched_throughput_per_hour", self.throughput_per_hour()),
            ("sched_total_wastage_gbs", self.total_wastage.0),
        ] {
            reg.gauge_set(&format!("{name}{l}"), v);
        }
        for &w in &self.queue_waits {
            reg.observe(&format!("sched_queue_wait_s{l}"), QUEUE_WAIT_BUCKETS_S, w);
        }
    }

    /// One-line operator summary (plus a workflow line in DAG mode).
    pub fn summary(&self) -> String {
        let waits = self.queue_wait_percentiles();
        let mut s = format!(
            "{} · {} · {} nodes · ia={:.1}s: {}/{} done, makespan {}, \
             util {:.1}% (peak {:.1}%), peak-concurrent {}, wait mean {:.1}s p95 {:.1}s, \
             {} oom, {} grow-denied, {} preempted, {} node-lost, {} rejected, wastage {}",
            self.policy,
            self.method,
            self.n_nodes,
            self.mean_interarrival_s,
            self.completed,
            self.submitted,
            self.makespan,
            100.0 * self.utilization(),
            100.0 * self.peak_util_frac,
            self.peak_running,
            self.mean_queue_wait_s(),
            waits.percentile(95.0),
            self.oom_kills,
            self.grow_denials,
            self.preempted,
            self.node_lost,
            self.rejected,
            self.total_wastage,
        );
        if self.node_failures > 0 || self.nodes_added > 0 || self.nodes_retired > 0 {
            s.push_str(&format!(
                "\n  cluster: {} node failure(s), {} node(s) autoscaled in, {} retired",
                self.node_failures, self.nodes_added, self.nodes_retired,
            ));
        }
        if self.workflows_submitted > 0 {
            let spans = SortedSamples::new(&self.workflow_makespans);
            s.push_str(&format!(
                "\n  workflows: {}/{} done, wf-makespan mean {:.1}s p95 {:.1}s \
                 (critical path mean {:.1}s, stretch x{:.2}), first-completion mean {:.1}s, \
                 {} straggler(s)",
                self.workflows_completed,
                self.workflows_submitted,
                self.mean_workflow_makespan_s(),
                spans.percentile(95.0),
                self.mean_critical_path_s(),
                self.critical_path_stretch(),
                self.mean_time_to_first_completion_s(),
                self.workflow_stragglers,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rep(waits: &[f64], completed: u64, makespan: f64) -> SchedReport {
        let mut r = SchedReport::new("segment-wise", "m", 4, 5.0);
        r.submitted = completed;
        r.completed = completed;
        r.admitted = completed;
        r.placement_attempts = completed;
        r.makespan = Seconds(makespan);
        r.queue_waits = waits.to_vec();
        r.reserved_integral_gbs = 10.0;
        r.capacity_integral_gbs = 40.0;
        r.peak_util_frac = 0.5;
        r
    }

    #[test]
    fn derived_statistics() {
        let r = rep(&[0.0, 2.0, 4.0], 30, 3600.0);
        assert_eq!(r.mean_queue_wait_s(), 2.0);
        assert_eq!(r.utilization(), 0.25);
        assert_eq!(r.throughput_per_hour(), 30.0);
        assert_eq!(r.queue_wait_percentile_s(100.0), 4.0);
    }

    #[test]
    fn empty_report_is_zero() {
        // Satellite bugfix: every ratio metric on a degenerate report
        // must be exactly 0.0 — never NaN/inf from a 0/0.
        let r = SchedReport::new("static-peak", "m", 1, 1.0);
        assert_eq!(r.mean_queue_wait_s(), 0.0);
        assert_eq!(r.utilization(), 0.0);
        assert_eq!(r.throughput_per_hour(), 0.0);
        assert_eq!(r.critical_path_stretch(), 0.0);
        assert_eq!(r.mean_workflow_makespan_s(), 0.0);
        assert_eq!(r.queue_wait_percentile_s(95.0), 0.0);
        assert!(r.summary().contains("0/0 done"), "empty summary must render");
    }

    #[test]
    fn zero_makespan_merge_stays_finite() {
        // Satellite bugfix: merging zero-duration partials (a trace
        // whose every cell was empty) keeps makespan 0 and every
        // derived ratio 0.0 — the 0-completed/0-makespan division is
        // guarded, not propagated.
        let a = SchedReport::new("segment-wise", "m", 2, 1.0);
        let b = SchedReport::new("segment-wise", "m", 2, 1.0);
        let m = SchedReport::merged(vec![a, b]).unwrap();
        assert_eq!(m.makespan, Seconds::ZERO);
        assert_eq!(m.throughput_per_hour(), 0.0);
        assert_eq!(m.utilization(), 0.0);
        assert_eq!(m.critical_path_stretch(), 0.0);
        assert!(m.throughput_per_hour().is_finite());
        assert!(m.summary().contains("makespan"), "zero-makespan summary must render");

        // a zero-makespan partial merged into a real one is harmless
        let mut real = rep(&[1.0], 5, 50.0);
        real.merge(SchedReport::new("segment-wise", "m", 4, 5.0));
        assert_eq!(real.makespan, Seconds(50.0));
        assert_eq!(real.throughput_per_hour(), 360.0);
    }

    #[test]
    fn zero_critical_path_is_skipped_not_divided() {
        // An instance with cp == 0 must not poison the stretch mean.
        let r = wf_rep(&[100.0, 200.0], &[0.0, 100.0], 0);
        assert!((r.critical_path_stretch() - 2.0).abs() < 1e-12);
        let all_zero = wf_rep(&[100.0], &[0.0], 0);
        assert_eq!(all_zero.critical_path_stretch(), 0.0);
        assert!(all_zero.critical_path_stretch().is_finite());
    }

    #[test]
    fn failure_domain_counters_merge_and_render() {
        let mut a = rep(&[1.0], 10, 100.0);
        a.preempted = 2;
        a.node_lost = 1;
        a.node_failures = 1;
        a.events_processed = 50;
        let mut b = rep(&[2.0], 5, 80.0);
        b.preempted = 1;
        b.node_lost = 3;
        b.node_failures = 2;
        b.nodes_added = 1;
        b.nodes_retired = 1;
        b.events_processed = 30;
        a.merge(b);
        assert_eq!(a.preempted, 3);
        assert_eq!(a.node_lost, 4);
        assert_eq!(a.node_failures, 3);
        assert_eq!(a.nodes_added, 1);
        assert_eq!(a.nodes_retired, 1);
        assert_eq!(a.events_processed, 80);
        let s = a.summary();
        assert!(s.contains("3 preempted"), "{s}");
        assert!(s.contains("4 node-lost"), "{s}");
        assert!(s.contains("3 node failure(s)"), "{s}");

        // without failure-domain activity the cluster line is absent
        let plain = rep(&[1.0], 5, 50.0).summary();
        assert!(!plain.contains("cluster:"), "{plain}");
    }

    #[test]
    fn merge_adds_counters_and_maxes_extremes() {
        let mut a = rep(&[1.0], 10, 100.0);
        let mut b = rep(&[3.0], 20, 250.0);
        b.peak_util_frac = 0.9;
        b.oom_kills = 2;
        a.merge(b);
        assert_eq!(a.completed, 30);
        assert_eq!(a.oom_kills, 2);
        assert_eq!(a.makespan, Seconds(250.0));
        assert_eq!(a.peak_util_frac, 0.9);
        assert_eq!(a.queue_waits, vec![1.0, 3.0]);
        assert_eq!(a.reserved_integral_gbs, 20.0);
    }

    #[test]
    #[should_panic(expected = "merging different policies")]
    fn merge_rejects_mismatched_policy() {
        let mut a = rep(&[], 1, 1.0);
        let mut b = rep(&[], 1, 1.0);
        b.policy = "static-peak".into();
        a.merge(b);
    }

    #[test]
    fn merged_over_sequence() {
        assert!(SchedReport::merged(std::iter::empty()).is_none());
        let m = SchedReport::merged(vec![rep(&[1.0], 1, 10.0), rep(&[2.0], 2, 5.0)]).unwrap();
        assert_eq!(m.completed, 3);
        assert_eq!(m.makespan, Seconds(10.0));
    }

    #[test]
    fn summary_renders() {
        let s = rep(&[1.0], 5, 50.0).summary();
        assert!(s.contains("segment-wise"));
        assert!(s.contains("5/5 done"));
        assert!(!s.contains("workflows:"), "no workflow line without instances");
    }

    #[test]
    fn queue_wait_percentiles_sort_once_and_agree() {
        let r = rep(&[4.0, 0.0, 2.0, 6.0], 4, 10.0);
        let sorted = r.queue_wait_percentiles();
        for q in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(sorted.percentile(q), r.queue_wait_percentile_s(q), "q={q}");
        }
        // the interpolated even-length median
        assert_eq!(sorted.percentile(50.0), 3.0);
    }

    fn wf_rep(makespans: &[f64], cps: &[f64], stragglers: u64) -> SchedReport {
        let mut r = rep(&[], makespans.len() as u64, 100.0);
        r.workflows_submitted = makespans.len() as u64;
        r.workflows_completed = makespans.len() as u64;
        r.workflow_makespans = makespans.to_vec();
        r.workflow_critical_paths = cps.to_vec();
        r.workflow_first_completions = makespans.iter().map(|m| m / 2.0).collect();
        r.workflow_stragglers = stragglers;
        r
    }

    #[test]
    fn workflow_metrics_derive_and_merge() {
        let r = wf_rep(&[100.0, 300.0], &[100.0, 100.0], 1);
        assert_eq!(r.mean_workflow_makespan_s(), 200.0);
        assert_eq!(r.mean_critical_path_s(), 100.0);
        assert!((r.critical_path_stretch() - 2.0).abs() < 1e-12);
        assert_eq!(r.mean_time_to_first_completion_s(), 100.0);
        let s = r.summary();
        assert!(s.contains("workflows: 2/2 done"), "{s}");
        assert!(s.contains("1 straggler"), "{s}");

        let mut a = wf_rep(&[100.0], &[50.0], 1);
        a.merge(wf_rep(&[40.0], &[40.0], 0));
        assert_eq!(a.workflows_submitted, 2);
        assert_eq!(a.workflows_completed, 2);
        assert_eq!(a.workflow_makespans, vec![100.0, 40.0]);
        assert_eq!(a.workflow_critical_paths, vec![50.0, 40.0]);
        assert_eq!(a.workflow_stragglers, 1);
    }

    #[test]
    fn export_metrics_labels_policy_and_method() {
        let mut r = rep(&[0.4, 3.0, 200.0], 30, 3600.0);
        r.oom_kills = 2;
        let mut reg = Registry::new();
        r.export_metrics(&mut reg);
        let l = "{policy=\"segment-wise\",method=\"m\"}";
        assert_eq!(reg.counter(&format!("sched_completed{l}")), 30);
        assert_eq!(reg.counter(&format!("sched_oom_kills{l}")), 2);
        assert_eq!(reg.gauge(&format!("sched_makespan_s{l}")), Some(3600.0));
        assert_eq!(reg.gauge(&format!("sched_utilization_frac{l}")), Some(0.25));
        let h = reg.histogram(&format!("sched_queue_wait_s{l}")).expect("wait histogram");
        assert_eq!(h.count(), 3);
        assert_eq!(h.bounds(), QUEUE_WAIT_BUCKETS_S);
        // 0.4 → le=0.5 bucket, 3.0 → le=5, 200.0 → overflow
        assert_eq!(h.counts()[0], 1);
        assert_eq!(*h.counts().last().unwrap(), 1);
        // exposition renders the spliced-label histogram
        let prom = reg.to_prometheus();
        assert!(
            prom.contains("sched_queue_wait_s_bucket{policy=\"segment-wise\",method=\"m\",le=\"0.5\"} 1"),
            "{prom}"
        );
    }

    #[test]
    fn empty_workflow_metrics_are_zero() {
        let r = SchedReport::new("static-peak", "m", 1, 1.0);
        assert_eq!(r.mean_workflow_makespan_s(), 0.0);
        assert_eq!(r.critical_path_stretch(), 0.0);
        assert_eq!(r.mean_time_to_first_completion_s(), 0.0);
    }
}
