//! Scheduling layer of the ksegments workspace: what memory
//! prediction buys a cluster, not just a single task.
//!
//! `ksegments-core` scores predictors in isolation; this crate puts
//! them inside a shared cluster and measures the system-level
//! consequences — packing density, queue waits, makespan, and how
//! allocation mistakes ripple through dependency DAGs and node
//! failures:
//!
//! * [`cluster`] — node specs and the reservation ledger.
//! * [`engine`] — the discrete-event engine: placement, memory-usage
//!   tracking against reservations, OOM kills, segment-boundary grow
//!   requests, node loss/join/retire and preemption, emitting
//!   [`engine::events::EngineEvent`]s.
//! * [`sched`] — scheduling policies ([`sched::ReservationPolicy`]:
//!   static-peak vs segment-wise), the trace/stream/DAG entry points
//!   and the (policy × predictor × load) sweep grids.
//! * [`throughput`] — rendered sweep tables for the CLI and reports.
//! * [`telemetry_ext`] — maps engine events onto the core telemetry
//!   sinks (the engine-aware half of run tracing).
//!
//! The `ksegments` facade re-exports these modules under their
//! historical single-crate paths (`ksegments::sched`,
//! `ksegments::engine`, `ksegments::cluster`,
//! `ksegments::telemetry::trace_engine_event`,
//! `ksegments::bench_harness::throughput`).

pub mod cluster;
pub mod engine;
pub mod sched;
pub mod telemetry_ext;
pub mod throughput;
