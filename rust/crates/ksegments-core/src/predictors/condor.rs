//! HTCondor-style retry baseline: `request_memory =
//! ifThenElse(isUndefined(MemoryUsage), default, 3 * MemoryUsage)`.
//!
//! This is the classic production heuristic the paper's related work
//! measures dynamic methods against: until a task type has run once,
//! ask for the configured default; afterwards ask for **three times
//! the most recently observed peak** (`MemoryUsage` in the ClassAd).
//! Failed attempts are handled like a periodic-release policy — the
//! job goes back to the queue with the request bumped to three times
//! the usage at the kill instant, so a genuinely underpredicted task
//! converges in one retry at the cost of enormous headroom.
//!
//! The 3× factor makes this an interesting scheduling baseline: it
//! almost never OOMs, but its wastage and packing density are terrible
//! — exactly the trade-off the failure-domain sweeps quantify.

use crate::trace::TaskRun;
use crate::units::MemMiB;

use super::history::HistoryMap;
use super::{Allocation, Defaults, FailureInfo, MemoryPredictor, MIN_ALLOC_MIB};

/// Multiplier applied to the last observed peak (HTCondor's canonical
/// `3 * MemoryUsage` idiom).
pub const CONDOR_FACTOR: f64 = 3.0;

/// HTCondor `3 * MemoryUsage` baseline (see module docs).
#[derive(Debug)]
pub struct CondorTriple {
    defaults: Defaults,
    histories: HistoryMap,
}

impl Default for CondorTriple {
    fn default() -> Self {
        CondorTriple::new()
    }
}

impl CondorTriple {
    pub fn new() -> CondorTriple {
        CondorTriple {
            defaults: Defaults::default(),
            // only the latest peak is ever read, but a short window
            // keeps the memory profile flat on long streams
            histories: HistoryMap::new(1024, 1),
        }
    }
}

impl MemoryPredictor for CondorTriple {
    fn name(&self) -> String {
        "HTCondor 3x".to_string()
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, _input_mib: f64) -> Allocation {
        let mib = match self.histories.get(task_type).and_then(|h| h.peaks().last()) {
            // MemoryUsage is defined: 3 × the most recent peak
            Some(&peak) => (CONDOR_FACTOR * peak).max(MIN_ALLOC_MIB),
            // isUndefined(MemoryUsage): the submit-file default
            None => self.defaults.get(task_type).0,
        };
        Allocation::Static(MemMiB(mib))
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        info: &FailureInfo,
    ) -> Allocation {
        // periodic release: requeue at 3 × the usage that killed the
        // attempt (never below what just failed — usage at the kill
        // instant can undershoot the true peak on noisy curves)
        let bumped = (CONDOR_FACTOR * info.used_mib).max(failed.max_value()).max(MIN_ALLOC_MIB);
        Allocation::Static(MemMiB(bumped))
    }

    fn observe(&mut self, run: &TaskRun) {
        self.histories.push(run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn run(ty: &str, peak: f64, seq: u64) -> TaskRun {
        TaskRun {
            task_type: ty.into(),
            input_mib: 10.0,
            runtime: Seconds(4.0),
            series: UsageSeries::new(2.0, vec![peak / 2.0, peak]),
            seq,
        }
    }

    #[test]
    fn undefined_memory_usage_falls_back_to_default() {
        let mut p = CondorTriple::new();
        p.prime("wf/a", MemMiB(2048.0));
        assert_eq!(p.predict("wf/a", 1.0), Allocation::Static(MemMiB(2048.0)));
    }

    #[test]
    fn defined_memory_usage_triples_the_latest_peak() {
        let mut p = CondorTriple::new();
        p.prime("wf/a", MemMiB(2048.0));
        p.observe(&run("wf/a", 400.0, 0));
        assert_eq!(p.predict("wf/a", 1.0), Allocation::Static(MemMiB(1200.0)));
        // the LATEST observation wins, not the max
        p.observe(&run("wf/a", 100.0, 1));
        assert_eq!(p.predict("wf/a", 1.0), Allocation::Static(MemMiB(300.0)));
    }

    #[test]
    fn failure_retries_at_triple_usage() {
        let mut p = CondorTriple::new();
        let failed = Allocation::Static(MemMiB(500.0));
        let next = p.on_failure("wf/a", 1.0, &failed, &FailureInfo::oom(2.0, 600.0, 1));
        assert_eq!(next, Allocation::Static(MemMiB(1800.0)));
    }

    #[test]
    fn failure_never_shrinks_below_the_failed_request() {
        let mut p = CondorTriple::new();
        // usage at the kill instant (120) × 3 < the 500 that failed
        let failed = Allocation::Static(MemMiB(500.0));
        let next = p.on_failure("wf/a", 1.0, &failed, &FailureInfo::oom(2.0, 120.0, 1));
        assert_eq!(next, Allocation::Static(MemMiB(500.0)));
    }

    #[test]
    fn floor_applies_to_tiny_peaks() {
        let mut p = CondorTriple::new();
        p.observe(&run("wf/a", 10.0, 0));
        match p.predict("wf/a", 1.0) {
            Allocation::Static(m) => assert_eq!(m.0, MIN_ALLOC_MIB),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn types_are_independent() {
        let mut p = CondorTriple::new();
        p.prime("wf/a", MemMiB(1000.0));
        p.prime("wf/b", MemMiB(2000.0));
        p.observe(&run("wf/a", 600.0, 0));
        assert_eq!(p.predict("wf/a", 1.0), Allocation::Static(MemMiB(1800.0)));
        assert_eq!(p.predict("wf/b", 1.0), Allocation::Static(MemMiB(2000.0)));
    }
}
