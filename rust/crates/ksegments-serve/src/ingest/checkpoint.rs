//! Predictor checkpointing: persist the fitted per-task-type state so
//! a replay (or a restarted prediction service) warm-starts instead of
//! re-learning from scratch.
//!
//! Every predictor in the zoo derives its fitted state — regressions,
//! peak distributions, historical-error offsets — deterministically
//! from (a) the primed developer defaults and (b) its sliding window
//! of observed runs. A [`Checkpoint`] therefore records exactly that:
//! per task type, the default plus the most recent
//! [`Checkpoint::window_cap`] observed runs (and the lifetime
//! observation count, which drives warm-up accounting). Restoring is
//! [`Checkpoint::restore_into`]: replay `prime` + `observe` into a
//! fresh [`MemoryPredictor`] — which reproduces the predictor's
//! internal state *exactly* whenever its own history window is no
//! larger than the checkpoint's (the largest window in the crate is
//! 1024, the default cap).
//!
//! The JSONL layout is deterministic (types sorted, runs oldest
//! first), so two equal checkpoints serialize to identical bytes —
//! what the warm-vs-cold replay test in `tests/ingest_replay.rs`
//! pins down.

use std::collections::{BTreeMap, VecDeque};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use ksegments_core::predictors::MemoryPredictor;
use ksegments_core::trace::{parse_jsonl_record, run_record, JsonlRecord, TaskRun};
use ksegments_core::units::MemMiB;
use ksegments_core::util::json::Json;

/// Format marker + version of the checkpoint header line.
const FORMAT: &str = "ksegments-checkpoint";
const VERSION: u64 = 1;

/// Per-task-type persisted state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TypeState {
    /// Primed developer default (MiB), if any.
    pub default_mib: Option<f64>,
    /// Lifetime observation count (not capped by the window).
    pub total_seen: u64,
    /// The most recent observed runs, oldest first.
    pub runs: VecDeque<TaskRun>,
}

/// Serialized predictor state: defaults + sliding run windows per task
/// type. See the module docs for the exactness guarantee.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    window_cap: usize,
    types: BTreeMap<String, TypeState>,
}

impl Checkpoint {
    /// Default per-type window — matches the largest predictor history
    /// window in the crate (PPM/LR keep 1024 runs), so restoring is
    /// exact for the whole zoo.
    pub const DEFAULT_WINDOW: usize = 1024;

    pub fn new(window_cap: usize) -> Checkpoint {
        Checkpoint { window_cap: window_cap.max(1), types: BTreeMap::new() }
    }

    pub fn window_cap(&self) -> usize {
        self.window_cap
    }

    pub fn n_types(&self) -> usize {
        self.types.len()
    }

    /// Lifetime observation count summed over types.
    pub fn total_seen(&self) -> u64 {
        self.types.values().map(|s| s.total_seen).sum()
    }

    /// Per-type state, sorted by task type.
    pub fn types(&self) -> &BTreeMap<String, TypeState> {
        &self.types
    }

    /// Record (or overwrite) a task type's developer default.
    pub fn record_default(&mut self, task_type: &str, mem: MemMiB) {
        self.types.entry(task_type.to_string()).or_default().default_mib = Some(mem.0);
    }

    /// Record an observed run, evicting the oldest once the type's
    /// window is full. `>=` (not `==`): a state seeded by
    /// [`Checkpoint::insert_state`] from a wider-windowed checkpoint
    /// must shrink back under this cap, not grow without bound.
    pub fn record(&mut self, run: &TaskRun) {
        let st = self.types.entry(run.task_type.clone()).or_default();
        while st.runs.len() >= self.window_cap {
            st.runs.pop_front();
        }
        st.runs.push_back(run.clone());
        st.total_seen += 1;
    }

    /// Seed a type's state wholesale (shard restore path); replaces
    /// any existing state for the type. A state wider than this
    /// checkpoint's window is trimmed to the most recent
    /// `window_cap` runs so [`Checkpoint::save`] output stays loadable.
    pub fn insert_state(&mut self, task_type: String, mut state: TypeState) {
        while state.runs.len() > self.window_cap {
            state.runs.pop_front();
        }
        self.types.insert(task_type, state);
    }

    /// Fold another checkpoint covering a **disjoint** task-type set
    /// into this one (per-shard partials).
    pub fn merge_disjoint(&mut self, other: Checkpoint) {
        for (ty, st) in other.types {
            let prev = self.types.insert(ty.clone(), st);
            assert!(prev.is_none(), "checkpoint shards overlap on task type {ty:?}");
        }
    }

    /// Warm-start a fresh predictor: prime every recorded default,
    /// then replay every windowed run through `observe`, types in
    /// sorted order, runs oldest first.
    pub fn restore_into(&self, predictor: &mut dyn MemoryPredictor) {
        for (ty, st) in &self.types {
            if let Some(d) = st.default_mib {
                predictor.prime(ty, MemMiB(d));
            }
            for run in &st.runs {
                predictor.observe(run);
            }
        }
    }

    /// Write the checkpoint as JSONL (header, then per type a `type`
    /// record followed by its `run` records, oldest first).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        let header = Json::obj(vec![
            ("format", FORMAT.into()),
            ("version", VERSION.into()),
            ("window_cap", (self.window_cap as u64).into()),
        ]);
        writeln!(w, "{header}")?;
        for (ty, st) in &self.types {
            let mut fields: Vec<(&str, Json)> = vec![
                ("kind", "type".into()),
                ("task_type", ty.as_str().into()),
                ("total_seen", st.total_seen.into()),
            ];
            if let Some(d) = st.default_mib {
                fields.push(("default_mib", d.into()));
            }
            writeln!(w, "{}", Json::obj(fields))?;
            for run in &st.runs {
                writeln!(w, "{}", run_record(run))?;
            }
        }
        Ok(())
    }

    /// Read a checkpoint written by [`Checkpoint::save`]; every
    /// malformed line errors with its position.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let r = BufReader::new(
            File::open(path).with_context(|| format!("opening checkpoint {}", path.display()))?,
        );
        let mut lines = r.lines().enumerate();
        let (_, header) = lines.next().context("empty checkpoint file")?;
        let header = Json::parse(&header?).map_err(|e| anyhow::anyhow!("header: {e}"))?;
        ensure!(
            header.get("format").as_str() == Some(FORMAT),
            "not a ksegments checkpoint (missing format marker)"
        );
        ensure!(
            header.get("version").as_u64() == Some(VERSION),
            "unsupported checkpoint version {:?}",
            header.get("version")
        );
        let window_cap = header
            .get("window_cap")
            .as_u64()
            .context("header window_cap")? as usize;
        let mut ck = Checkpoint::new(window_cap);
        let mut current: Option<String> = None;
        for (i, line) in lines {
            let lineno = i + 1;
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(&line)
                .map_err(|e| anyhow::anyhow!("checkpoint line {lineno}: {e}"))?;
            match parsed.get("kind").as_str() {
                Some("type") => {
                    let ty = parsed
                        .get("task_type")
                        .as_str()
                        .with_context(|| format!("checkpoint line {lineno}: task_type"))?
                        .to_string();
                    let st = TypeState {
                        default_mib: parsed.get("default_mib").as_f64(),
                        total_seen: parsed
                            .get("total_seen")
                            .as_u64()
                            .with_context(|| format!("checkpoint line {lineno}: total_seen"))?,
                        runs: VecDeque::new(),
                    };
                    ck.types.insert(ty.clone(), st);
                    current = Some(ty);
                }
                Some("run") => {
                    let rec = parse_jsonl_record(&line)
                        .with_context(|| format!("checkpoint line {lineno}"))?;
                    let JsonlRecord::Run(run) = rec else {
                        bail!("checkpoint line {lineno}: expected a run record");
                    };
                    let ty = current
                        .as_ref()
                        .with_context(|| format!("checkpoint line {lineno}: run before type"))?;
                    ensure!(
                        run.task_type == *ty,
                        "checkpoint line {lineno}: run of type {:?} under section {ty:?}",
                        run.task_type
                    );
                    let st = ck.types.get_mut(ty).expect("section exists");
                    ensure!(
                        st.runs.len() < window_cap,
                        "checkpoint line {lineno}: more runs than window_cap {window_cap}"
                    );
                    st.runs.push_back(run);
                }
                other => bail!("checkpoint line {lineno}: unknown kind {other:?}"),
            }
        }
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::predictors::ppm::PpmPredictor;
    use ksegments_core::predictors::Allocation;
    use ksegments_core::trace::UsageSeries;
    use ksegments_core::units::Seconds;

    fn run(ty: &str, seq: u64, peak: f64) -> TaskRun {
        TaskRun {
            task_type: ty.into(),
            input_mib: 10.0 * seq as f64,
            runtime: Seconds(4.0),
            series: UsageSeries::new(2.0, vec![peak / 2.0, peak]),
            seq,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ksegments_test_checkpoint");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn window_evicts_oldest_but_counts_all() {
        let mut ck = Checkpoint::new(3);
        for seq in 0..5 {
            ck.record(&run("a", seq, 100.0 + seq as f64));
        }
        let st = &ck.types()["a"];
        assert_eq!(st.total_seen, 5);
        assert_eq!(st.runs.len(), 3);
        assert_eq!(st.runs[0].seq, 2);
        assert_eq!(ck.total_seen(), 5);
    }

    #[test]
    fn save_load_roundtrip_is_exact_and_deterministic() {
        let mut ck = Checkpoint::new(8);
        ck.record_default("b", MemMiB(2048.0));
        for seq in 0..4 {
            ck.record(&run("a", seq, 123.456 + seq as f64 / 3.0));
            ck.record(&run("b", seq + 10, 77.7 * seq as f64));
        }
        let path = tmp("roundtrip.jsonl");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        // byte-determinism: saving the loaded checkpoint reproduces the
        // file exactly
        let path2 = tmp("roundtrip2.jsonl");
        back.save(&path2).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&path2).unwrap());
    }

    #[test]
    fn restore_reproduces_predictor_state() {
        // train a PPM directly vs via checkpoint restore: predictions
        // must coincide (PPM's window is 1024 >= ours)
        let mut direct = PpmPredictor::improved();
        let mut ck = Checkpoint::new(Checkpoint::DEFAULT_WINDOW);
        direct.prime("a", MemMiB(4096.0));
        ck.record_default("a", MemMiB(4096.0));
        for seq in 0..12 {
            let r = run("a", seq, 100.0 + 25.0 * (seq % 4) as f64);
            direct.observe(&r);
            ck.record(&r);
        }
        let mut restored = PpmPredictor::improved();
        ck.restore_into(&mut restored);
        for input in [0.0, 50.0, 500.0] {
            assert_eq!(direct.predict("a", input), restored.predict("a", input));
        }
        // untrained type falls back to the restored default
        assert_eq!(restored.predict("a", 1.0), direct.predict("a", 1.0));
        let mut blank = PpmPredictor::improved();
        Checkpoint::new(4).restore_into(&mut blank);
        assert_eq!(blank.predict("zzz", 1.0), Allocation::Static(MemMiB::from_gib(8.0)));
    }

    /// Regression: restoring a wide-window checkpoint into a narrower
    /// one must keep the window bounded (the eviction test used to be
    /// `==`, which a pre-seeded oversized state slipped past) and the
    /// result must stay loadable after save.
    #[test]
    fn narrow_window_bounds_restored_state() {
        let mut wide = Checkpoint::new(8);
        for seq in 0..8 {
            wide.record(&run("a", seq, 10.0 + seq as f64));
        }
        let mut narrow = Checkpoint::new(3);
        narrow.insert_state("a".into(), wide.types()["a"].clone());
        assert_eq!(narrow.types()["a"].runs.len(), 3, "insert_state must trim");
        assert_eq!(narrow.types()["a"].runs[0].seq, 5, "most recent runs kept");
        for seq in 8..20 {
            narrow.record(&run("a", seq, 10.0 + seq as f64));
            assert!(narrow.types()["a"].runs.len() <= 3, "window grew past cap");
        }
        assert_eq!(narrow.types()["a"].total_seen, 8 + 12);
        let path = tmp("narrow.jsonl");
        narrow.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), narrow);
    }

    #[test]
    fn merge_disjoint_unions_types() {
        let mut a = Checkpoint::new(4);
        a.record(&run("a", 0, 1.0));
        let mut b = Checkpoint::new(4);
        b.record(&run("b", 1, 2.0));
        a.merge_disjoint(b);
        assert_eq!(a.n_types(), 2);
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn merge_rejects_overlap() {
        let mut a = Checkpoint::new(4);
        a.record(&run("a", 0, 1.0));
        let mut b = Checkpoint::new(4);
        b.record(&run("a", 1, 2.0));
        a.merge_disjoint(b);
    }

    #[test]
    fn load_rejects_malformed_files() {
        let not_ours = tmp("not_ours.jsonl");
        std::fs::write(&not_ours, "{\"kind\":\"run\"}\n").unwrap();
        assert!(Checkpoint::load(&not_ours).is_err());

        let bad_run = tmp("bad_run.jsonl");
        std::fs::write(
            &bad_run,
            format!(
                "{{\"format\":\"{FORMAT}\",\"version\":1,\"window_cap\":4}}\n\
                 {{\"kind\":\"type\",\"task_type\":\"a\",\"total_seen\":1}}\n\
                 {{\"kind\":\"run\",\"task_type\":\"MISMATCH\",\"seq\":0,\"input_mib\":1,\
                 \"runtime_s\":4,\"interval_s\":2,\"samples_mib\":[1]}}\n"
            ),
        )
        .unwrap();
        let err = Checkpoint::load(&bad_run).unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");

        let orphan = tmp("orphan.jsonl");
        std::fs::write(
            &orphan,
            format!(
                "{{\"format\":\"{FORMAT}\",\"version\":1,\"window_cap\":4}}\n\
                 {{\"kind\":\"run\",\"task_type\":\"a\",\"seq\":0,\"input_mib\":1,\
                 \"runtime_s\":4,\"interval_s\":2,\"samples_mib\":[1]}}\n"
            ),
        )
        .unwrap();
        let err = Checkpoint::load(&orphan).unwrap_err();
        assert!(format!("{err:#}").contains("run before type"), "{err:#}");
    }
}
