//! Network-facing prediction service: a length-prefixed JSONL
//! protocol over TCP in front of the sharded coordinator — the wire
//! that lets a workflow engine consume predictions as a service
//! (ROADMAP item 1; Fig. 2's deployment shape, reachable from outside
//! the process).
//!
//! Layers, bottom-up:
//!
//! * [`frame`] — the wire grammar: 4-byte big-endian length prefix +
//!   one JSON object; request parsing with typed [`ErrCode`]s;
//!   streaming response serialization through
//!   [`JsonWriter`](ksegments_core::util::json::JsonWriter);
//! * [`server`] — [`NetServer`]: accept loop, per-connection
//!   pipelining with in-order responses, graceful drain, checkpoint
//!   warm restart, [`NetCounters`] telemetry export;
//! * [`client`] — [`NetClient`]: the blocking typed client, mirroring
//!   the in-process `ServiceHandle` surface;
//! * [`loadgen`] — [`run_loadgen`]: N-connection QPS-paced replay of
//!   any `TraceSource` with p50/p99/p999 latency reporting.
//!
//! See DESIGN.md §14 for the frame grammar, error code table, and
//! drain/restart semantics.

pub mod client;
pub mod frame;
pub mod loadgen;
pub mod server;

pub use client::NetClient;
pub use frame::{
    parse_request, parse_response, read_frame, take_frame, write_frame, ErrCode, NetError,
    NetRequest, NetResponse, MAX_FRAME_DEFAULT,
};
pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
pub use server::{
    export_net_metrics, NetCounters, NetServer, NetServerConfig, NetSnapshot, ServerReport,
};
