//! `cargo bench --bench hotpath` — micro-benchmarks of the L3 hot
//! paths identified in EXPERIMENTS.md §Perf:
//!
//! * `simulate_attempt` (static & dynamic) — the simulator inner loop;
//! * `NativeFitter::fit` — the per-completion online refit;
//! * `XlaFitter::fit` — the same fit through the AOT PJRT module
//!   (skipped with a notice if `make artifacts` has not run);
//! * `KSegmentsPredictor::predict` — the submission-time path served
//!   by the coordinator;
//! * `TaskHistory::push` on a full window — the per-completion
//!   eviction (amortized O(1) ring vs the former O(cap) memmove);
//! * step-function construction and evaluation;
//! * `EvalGrid` throughput — the parallel evaluation engine at 1
//!   worker vs all cores;
//! * `ShardedPredictionService` throughput — concurrent predict
//!   traffic at 1 shard vs 4 shards;
//! * `sched::schedule_trace` — the discrete-event scheduler loop under
//!   both reservation policies;
//! * `TsDb::range_max` — the segment-peak query (binary-searched
//!   bounds vs the former linear scan);
//! * `stats::percentile` — per-call re-sort vs the sort-once
//!   `SortedSamples` the report tables now query through.

use ksegments::bench_harness::{bench, black_box, time_once};
use ksegments::coordinator::ShardedPredictionService;
use ksegments::ml::fitter::{FitInput, KsegFitter, NativeFitter};
use ksegments::ml::step_fn::StepFunction;
use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::{Allocation, MemoryPredictor};
use ksegments::rng::Rng;
use ksegments::runtime::XlaFitter;
use ksegments::sim::{default_workers, simulate_attempt, EvalGrid, PredictorFactory};
use ksegments::trace::{TaskRun, UsageSeries};
use ksegments::units::{MemMiB, Seconds};
use ksegments::workload::{eager_workflow, generate_workflow_trace};

fn synth_series(n: usize, rng: &mut Rng) -> UsageSeries {
    let peak = rng.uniform(500.0, 2000.0);
    let samples: Vec<f64> = (0..n)
        .map(|i| peak * ((i + 1) as f64 / n as f64).sqrt())
        .collect();
    UsageSeries::new(2.0, samples)
}

fn synth_fit_input(n: usize, t: usize, rng: &mut Rng) -> FitInput {
    let mut input = FitInput::default();
    for _ in 0..n {
        let x = rng.uniform(100.0, 4000.0);
        let peak = 50.0 + 0.8 * x * rng.uniform(0.9, 1.1);
        input.x.push(x);
        input.runtime.push(30.0 + 0.05 * x);
        input
            .series
            .push((0..t).map(|j| peak * (j + 1) as f64 / t as f64).collect());
    }
    input
}

fn main() {
    println!("== hotpath micro-benchmarks ==\n");
    let mut rng = Rng::new(42);

    // -- simulator inner loop ------------------------------------------
    let series_1800 = synth_series(1800, &mut rng); // a 1-hour task at 2 s
    let static_alloc = Allocation::Static(MemMiB(2500.0));
    bench("simulate_attempt/static/1800-samples", 40, 200, || {
        simulate_attempt(black_box(&series_1800), black_box(&static_alloc), 1)
    });

    let step = StepFunction::monotone_clamped(
        Seconds(3600.0),
        vec![600.0, 1200.0, 1900.0, 2500.0],
        MemMiB(100.0),
        MemMiB(131072.0),
    );
    let dyn_alloc = Allocation::Dynamic(step);
    bench("simulate_attempt/dynamic-k4/1800-samples", 40, 200, || {
        simulate_attempt(black_box(&series_1800), black_box(&dyn_alloc), 1)
    });

    // -- online refit ----------------------------------------------------
    let fit_input = synth_fit_input(64, 256, &mut rng);
    let mut native = NativeFitter;
    bench("fit/native/n64-t256-k4", 30, 50, || {
        native.fit(black_box(&fit_input), 4)
    });
    bench("fit/native/n64-t256-k16", 30, 50, || {
        native.fit(black_box(&fit_input), 16)
    });

    match XlaFitter::load_default() {
        Ok(mut xla) => {
            // warm the executable cache (compile once)
            let _ = xla.fit(&fit_input, 4);
            bench("fit/xla-pjrt/n64-t256-k4", 20, 20, || {
                xla.fit(black_box(&fit_input), 4)
            });
        }
        Err(e) => println!("fit/xla-pjrt: SKIPPED ({e:#})"),
    }

    // -- submission-time predict -----------------------------------------
    let mut predictor = KSegmentsPredictor::native(4, RetryStrategy::Selective);
    predictor.prime("t", MemMiB(8192.0));
    for i in 0..64 {
        let series = synth_series(128, &mut rng);
        predictor.observe(&TaskRun {
            task_type: "t".into(),
            input_mib: 100.0 + i as f64 * 10.0,
            runtime: series.duration(),
            series,
            seq: i,
        });
    }
    // cold predict = refit + build; warm predict = cached fit
    bench("predict/ksegments/warm-cache", 30, 500, || {
        predictor.predict(black_box("t"), black_box(1234.5))
    });

    // -- history ring eviction -------------------------------------------
    // One push per completion on a FULL window is the online-learning
    // hot path. Eviction is amortized O(1) (lazy head + periodic
    // drain), so per-push cost must stay flat as the window capacity
    // grows — before the ring it was four `Vec::remove(0)` memmoves,
    // i.e. O(cap) per completion (the 64x-capacity row exposed it).
    for cap in [64usize, 1024, 4096] {
        let mut h = ksegments::predictors::history::TaskHistory::new(cap, 64);
        let series = synth_series(128, &mut rng);
        let warm = TaskRun {
            task_type: "t".into(),
            input_mib: 1000.0,
            runtime: series.duration(),
            series,
            seq: 0,
        };
        for _ in 0..cap {
            h.push(&warm); // fill: every bench push now evicts
        }
        bench(&format!("history/push-evict cap={cap}"), 20, 2_000, || {
            h.push(black_box(&warm))
        });
    }

    // -- step-function primitives ----------------------------------------
    let f = StepFunction::monotone_clamped(
        Seconds(1000.0),
        vec![100.0, 200.0, 300.0, 400.0],
        MemMiB(100.0),
        MemMiB(131072.0),
    );
    bench("step_fn/value_at", 20, 100_000, || {
        black_box(f.value_at(black_box(567.8)))
    });
    bench("step_fn/monotone_clamped-k16", 20, 10_000, || {
        StepFunction::monotone_clamped(
            Seconds(1000.0),
            black_box(vec![
                1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0,
                16.0,
            ]),
            MemMiB(100.0),
            MemMiB(131072.0),
        )
    });

    // -- parallel grid throughput ----------------------------------------
    // A reduced fig7-style grid (3 methods x 2 fractions x 1 trace);
    // tables are bit-identical at any worker count, so the only thing
    // that changes with workers is wall-clock.
    let traces = vec![generate_workflow_trace(&eager_workflow(), 42)];
    let grid_makers = || -> Vec<PredictorFactory> {
        vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new()) as Box<dyn MemoryPredictor>),
            Box::new(|| {
                Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
                    as Box<dyn MemoryPredictor>
            }),
            Box::new(|| {
                Box::new(KSegmentsPredictor::native(4, RetryStrategy::Partial))
                    as Box<dyn MemoryPredictor>
            }),
        ]
    };
    let grid = EvalGrid::new(grid_makers(), &traces, vec![0.25, 0.75]);
    let (seq, _dt) = time_once("eval_grid/3x2x1 workers=1", || grid.run(1));
    let workers = default_workers();
    let (par, _dt) = time_once(&format!("eval_grid/3x2x1 workers={workers}"), || {
        grid.run(workers)
    });
    assert_eq!(seq, par, "grid results must not depend on worker count");

    // -- sharded prediction service throughput ---------------------------
    for shards in [1usize, 4] {
        let svc = ShardedPredictionService::spawn(shards, |_| {
            Box::new(DefaultConfigPredictor::new())
        });
        let h = svc.handle();
        for i in 0..32 {
            h.prime(&format!("w/t{i}"), MemMiB(1024.0));
        }
        let (_, _dt) = time_once(
            &format!("sharded_service/predict 4 clients x 2000 ({shards} shard(s))"),
            || {
                let mut joins = Vec::new();
                for c in 0..4 {
                    let h = h.clone();
                    joins.push(std::thread::spawn(move || {
                        for i in 0..2000u32 {
                            let ty = format!("w/t{}", (c * 8 + i % 8) % 32);
                            black_box(h.predict(&ty, i as f64));
                        }
                    }));
                }
                for j in joins {
                    j.join().unwrap();
                }
            },
        );
        let stats = svc.shutdown();
        assert_eq!(stats.predictions, 8000);
    }

    // -- discrete-event scheduler loop -----------------------------------
    use ksegments::cluster::NodeSpec;
    use ksegments::sched::{schedule_trace, ReservationPolicy, SchedConfig};
    let sched_trace = generate_workflow_trace(&eager_workflow(), 42);
    for policy in [ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise] {
        let cfg = SchedConfig {
            policy,
            nodes: vec![NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 }; 2],
            seed: 42,
            ..SchedConfig::default()
        };
        bench(&format!("sched/schedule_trace eager ({})", policy.name()), 5, 3, || {
            let mut p = DefaultConfigPredictor::new();
            schedule_trace(black_box(&sched_trace), &mut p, &cfg)
        });
    }

    // -- tsdb range queries ----------------------------------------------
    use ksegments::tsdb::{Point, SeriesKey, TsDb};
    let mut db = TsDb::new();
    let tkey = SeriesKey::mem("bench/task", 0);
    for i in 0..100_000u64 {
        db.append(&tkey, Point { t: i as f64 * 2.0, value: (i % 977) as f64 });
    }
    bench("tsdb/range_max 100k-points narrow-window", 20, 2_000, || {
        db.range_max(black_box(&tkey), black_box(60_000.0), black_box(60_240.0))
    });
    bench("tsdb/range 100k-points narrow-window", 20, 2_000, || {
        db.range(black_box(&tkey), black_box(60_000.0), black_box(60_240.0))
    });

    // -- percentile hot path ---------------------------------------------
    // A SchedReport's queue-wait vector at cluster scale; the summary
    // and every per-row table cell used to re-sort it per call. The
    // sorted-once path must be orders of magnitude cheaper per query.
    use ksegments::util::stats::{percentile, SortedSamples};
    let waits: Vec<f64> = (0..100_000u64)
        .map(|i| (i.wrapping_mul(2654435761) % 100_000) as f64 / 100.0)
        .collect();
    bench("stats/percentile re-sort-per-call 100k", 10, 20, || {
        percentile(black_box(&waits), black_box(95.0))
    });
    let sorted = SortedSamples::new(&waits);
    bench("stats/percentile sorted-once 100k", 20, 100_000, || {
        sorted.percentile(black_box(95.0))
    });
}
