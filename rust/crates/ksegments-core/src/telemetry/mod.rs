//! Cross-cutting observability: run tracing, metrics, provenance.
//!
//! Three pillars (DESIGN.md §12):
//!
//! * **Run tracing** ([`sink`]) — [`TraceSink`] + a streaming Chrome
//!   `trace_event`/Perfetto JSON writer. The scheduler, the replay
//!   engine and the sharded prediction service emit begin/end/instant
//!   spans; `schedule --trace-out run.json` opens directly in
//!   <https://ui.perfetto.dev> or `chrome://tracing`.
//! * **Metrics** ([`registry`]) — counters/gauges/fixed-bucket
//!   histograms with Prometheus text exposition and a JSON snapshot
//!   (`--metrics-out FILE`).
//! * **Provenance** ([`provenance`]) — optional per-decision JSONL
//!   audit records (`--provenance-out FILE`).
//!
//! The golden rule: telemetry **observes, never influences**. Enabling
//! any sink leaves every `SchedReport`/`MethodReport` bit-identical to
//! the untraced run (`tests/telemetry.rs` pins this), and scheduler/
//! replay events are stamped with **simulated** time — the wall clock
//! appears only in bench snapshots and service-thread spans.
//!
//! This module is engine-agnostic: the mapping from discrete-event
//! engine events onto these sinks (`trace_engine_event`) lives in the
//! sched layer (`ksegments_sched::telemetry_ext`) and is re-exported
//! by the `ksegments` facade under the historical
//! `ksegments::telemetry` path.

pub mod provenance;
pub mod registry;
pub mod sink;

pub use provenance::{DecisionDetail, ProvenanceLog};
pub use registry::{Histogram, Registry};
pub use sink::{
    chrome_trace_to_string, write_chrome_trace, ArgValue, ChromeTraceSink, NullSink, TraceEvent,
    TraceSink, VecSink,
};

use std::io;

/// The telemetry attachments of one scheduler run: a trace sink
/// (default [`NullSink`]) plus an optional provenance log. Owned by
/// the run so the engine needs no lifetime plumbing.
pub struct RunTelemetry {
    pub trace: Box<dyn TraceSink>,
    pub provenance: Option<ProvenanceLog>,
}

impl RunTelemetry {
    /// Everything off — the allocation-free default.
    pub fn off() -> RunTelemetry {
        RunTelemetry { trace: Box::new(NullSink), provenance: None }
    }

    pub fn with_trace(sink: Box<dyn TraceSink>) -> RunTelemetry {
        RunTelemetry { trace: sink, provenance: None }
    }

    /// Close both attachments, surfacing the first deferred I/O error.
    pub fn finish(&mut self) -> io::Result<()> {
        self.trace.finish()?;
        if let Some(p) = &mut self.provenance {
            p.finish()?;
        }
        Ok(())
    }
}

impl Default for RunTelemetry {
    fn default() -> Self {
        RunTelemetry::off()
    }
}

/// FNV-1a 64-bit hash (same constants as the coordinator's shard
/// router).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Async-span id for one task run: type hash mixed with the run seq,
/// masked to 48 bits so a JSON f64 round-trip is exact.
pub fn span_id(task_type: &str, seq: u64) -> u64 {
    (fnv1a64(task_type.as_bytes()) ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15)) & 0xffff_ffff_ffff
}

/// Simulated seconds → trace microseconds.
pub fn sim_ts_us(now_s: f64) -> u64 {
    (now_s * 1e6).round().max(0.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_ids_are_stable_and_distinct() {
        assert_eq!(span_id("a", 1), span_id("a", 1));
        assert_ne!(span_id("a", 1), span_id("a", 2));
        assert_ne!(span_id("a", 1), span_id("b", 1));
        assert!(span_id("wf/align", u64::MAX) <= 0xffff_ffff_ffff);
    }

    #[test]
    fn sim_time_maps_to_microseconds() {
        assert_eq!(sim_ts_us(0.0), 0);
        assert_eq!(sim_ts_us(1.5), 1_500_000);
        assert_eq!(sim_ts_us(-1.0), 0, "clamped, never underflows");
    }

    #[test]
    fn run_telemetry_off_is_disabled_and_finishes() {
        let mut tel = RunTelemetry::off();
        assert!(!tel.trace.enabled());
        assert!(tel.provenance.is_none());
        tel.finish().unwrap();
        let def = RunTelemetry::default();
        assert!(!def.trace.enabled());
    }
}
