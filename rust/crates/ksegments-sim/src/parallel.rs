//! Parallel evaluation engine — the paper's §IV grid (predictor ×
//! trace × training fraction) is embarrassingly parallel, so the
//! figure harness executes it on a fixed-size std-thread worker pool
//! instead of one long sequential loop.
//!
//! Determinism is load-bearing (every number in EXPERIMENTS.md is
//! regenerated from a fixed seed): each grid cell builds a **fresh**
//! predictor and reads a shared immutable trace, so its result depends
//! only on the cell's inputs, never on scheduling; results are
//! re-ordered by cell index before any merge. `workers = 1` and
//! `workers = N` therefore produce bit-identical [`MethodReport`]s —
//! `tests/parallel_determinism.rs` locks this down.

use ksegments_core::predictors::MemoryPredictor;
use ksegments_core::scoring::{simulate_trace, SimConfig};
use ksegments_core::trace::Trace;
use ksegments_core::wastage::MethodReport;

// The pool itself moved to the core layer (the sched sweeps need it
// too, and the crate DAG forbids a sideways sched → sim edge); these
// re-exports keep the historical `ksegments_sim::parallel::…` and
// facade `ksegments::sim::…` paths compiling unchanged.
pub use ksegments_core::parallel::{default_workers, parallel_map, PredictorFactory};

/// Evaluate an [`EvalGrid`] over streaming [`TraceSource`]s.
///
/// The grid's protocol needs random access — every (method, fraction)
/// cell re-reads every trace — so a one-pass stream cannot feed it
/// directly (that is the serve layer's `replay_source`'s job). What
/// streaming buys the grid is *ingestion*: each source is drained
/// exactly once into a shared immutable [`Trace`] here, and the
/// parallel cells then read those; an ingested Nextflow directory and
/// a generated workload are interchangeable grid axes.
///
/// [`TraceSource`]: ksegments_core::source::TraceSource
pub fn eval_sources(
    sources: &mut [Box<dyn ksegments_core::source::TraceSource>],
    methods: Vec<PredictorFactory>,
    fractions: Vec<f64>,
    workers: usize,
) -> anyhow::Result<GridResults> {
    let traces = sources
        .iter_mut()
        .map(|s| ksegments_core::source::materialize(s.as_mut()))
        .collect::<anyhow::Result<Vec<Trace>>>()?;
    Ok(EvalGrid::new(methods, &traces, fractions).run(workers))
}

/// Evaluate one grid cell: a fresh predictor from `make`, run online
/// over `trace` at training fraction `frac`.
///
/// This is the single unit of work shared by the parallel grid, the
/// ablation suite, and `evaluate_method` — there is exactly one code
/// path that turns (factory, trace, fraction) into a [`MethodReport`].
pub fn eval_cell(
    make: &dyn Fn() -> Box<dyn MemoryPredictor>,
    trace: &Trace,
    frac: f64,
) -> MethodReport {
    let cfg = SimConfig::with_training_frac(frac);
    let mut predictor = make();
    simulate_trace(trace, predictor.as_mut(), &cfg)
}

/// Index triple identifying one cell of an [`EvalGrid`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalCell {
    pub frac_idx: usize,
    pub method_idx: usize,
    pub trace_idx: usize,
}

/// The full evaluation grid of the paper's §IV: every predictor
/// factory × every training fraction × every workflow trace.
///
/// Per-trace cells of the same (method, fraction) are merged in trace
/// order after the parallel run, reproducing the sequential
/// `evaluate_method` result bit for bit.
pub struct EvalGrid<'a> {
    methods: Vec<PredictorFactory>,
    traces: &'a [Trace],
    fractions: Vec<f64>,
}

/// Results of an [`EvalGrid`] run, indexed `[fraction][method]`.
#[derive(Debug, Clone, PartialEq)]
pub struct GridResults {
    pub fractions: Vec<f64>,
    pub by_fraction: Vec<Vec<MethodReport>>,
}

impl<'a> EvalGrid<'a> {
    pub fn new(methods: Vec<PredictorFactory>, traces: &'a [Trace], fractions: Vec<f64>) -> Self {
        assert!(!methods.is_empty(), "grid needs at least one predictor factory");
        assert!(!traces.is_empty(), "grid needs at least one trace");
        assert!(!fractions.is_empty(), "grid needs at least one training fraction");
        EvalGrid { methods, traces, fractions }
    }

    pub fn n_cells(&self) -> usize {
        self.methods.len() * self.traces.len() * self.fractions.len()
    }

    /// Cell enumeration in canonical order: fraction-major, then
    /// method, then trace. This order is the contract the result
    /// indexing relies on.
    pub fn cells(&self) -> Vec<EvalCell> {
        let mut out = Vec::with_capacity(self.n_cells());
        for frac_idx in 0..self.fractions.len() {
            for method_idx in 0..self.methods.len() {
                for trace_idx in 0..self.traces.len() {
                    out.push(EvalCell { frac_idx, method_idx, trace_idx });
                }
            }
        }
        out
    }

    /// Execute every cell on `workers` threads and merge per-trace
    /// reports (in trace order) into one report per (fraction, method).
    pub fn run(&self, workers: usize) -> GridResults {
        let cells = self.cells();
        let reports = parallel_map(cells.len(), workers, |i| {
            let c = cells[i];
            eval_cell(
                self.methods[c.method_idx].as_ref(),
                &self.traces[c.trace_idx],
                self.fractions[c.frac_idx],
            )
        });
        // cells() is fraction-major → method → trace, so consecutive
        // chunks of n_traces reports belong to one (fraction, method)
        let n_traces = self.traces.len();
        let mut it = reports.into_iter();
        let mut by_fraction = Vec::with_capacity(self.fractions.len());
        for _ in 0..self.fractions.len() {
            let mut row = Vec::with_capacity(self.methods.len());
            for _ in 0..self.methods.len() {
                let per_trace: Vec<MethodReport> = it.by_ref().take(n_traces).collect();
                row.push(MethodReport::merged(per_trace).expect("at least one trace per cell"));
            }
            by_fraction.push(row);
        }
        GridResults { fractions: self.fractions.clone(), by_fraction }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ksegments_core::predictors::default_config::DefaultConfigPredictor;
    use ksegments_core::predictors::ppm::PpmPredictor;
    use ksegments_core::trace::{TaskRun, UsageSeries};
    use ksegments_core::units::{MemMiB, Seconds};

    fn toy_trace(ty: &str, n: usize) -> Trace {
        let mut t = Trace::new();
        t.set_default(ty, MemMiB(2000.0));
        for i in 0..n {
            let input = 100.0 + 10.0 * i as f64;
            let peak = 10.0 + input;
            let samples: Vec<f64> = (0..10).map(|j| peak * (j + 1) as f64 / 10.0).collect();
            t.push(TaskRun {
                task_type: ty.to_string(),
                input_mib: input,
                runtime: Seconds(20.0),
                series: UsageSeries::new(2.0, samples),
                seq: i as u64,
            });
        }
        t.sort();
        t
    }

    fn toy_grid(traces: &[Trace]) -> EvalGrid<'_> {
        let methods: Vec<PredictorFactory> = vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new())),
            Box::new(|| Box::new(PpmPredictor::improved())),
        ];
        EvalGrid::new(methods, traces, vec![0.25, 0.5])
    }

    #[test]
    fn cell_enumeration_is_fraction_major() {
        let traces = vec![toy_trace("a/x", 25), toy_trace("b/y", 25)];
        let grid = toy_grid(&traces);
        let cells = grid.cells();
        assert_eq!(cells.len(), 2 * 2 * 2);
        assert_eq!(cells[0], EvalCell { frac_idx: 0, method_idx: 0, trace_idx: 0 });
        assert_eq!(cells[1], EvalCell { frac_idx: 0, method_idx: 0, trace_idx: 1 });
        assert_eq!(cells[2], EvalCell { frac_idx: 0, method_idx: 1, trace_idx: 0 });
        assert_eq!(cells[7], EvalCell { frac_idx: 1, method_idx: 1, trace_idx: 1 });
    }

    #[test]
    fn grid_results_independent_of_worker_count() {
        let traces = vec![toy_trace("a/x", 30), toy_trace("b/y", 30)];
        let grid = toy_grid(&traces);
        let seq = grid.run(1);
        for workers in [2, 4, 8] {
            assert_eq!(grid.run(workers), seq, "workers={workers} diverged");
        }
    }

    #[test]
    fn eval_sources_matches_direct_grid() {
        let traces = vec![toy_trace("a/x", 30), toy_trace("b/y", 30)];
        let direct = toy_grid(&traces).run(2);
        let mut sources: Vec<Box<dyn ksegments_core::source::TraceSource>> = traces
            .iter()
            .map(|t| {
                Box::new(ksegments_core::source::InMemorySource::from_trace(t))
                    as Box<dyn ksegments_core::source::TraceSource>
            })
            .collect();
        let methods: Vec<PredictorFactory> = vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new())),
            Box::new(|| Box::new(PpmPredictor::improved())),
        ];
        let streamed = eval_sources(&mut sources, methods, vec![0.25, 0.5], 4).unwrap();
        assert_eq!(streamed, direct);
    }

    #[test]
    fn grid_merges_traces_per_cell() {
        let traces = vec![toy_trace("a/x", 30), toy_trace("b/y", 30)];
        let grid = toy_grid(&traces);
        let res = grid.run(2);
        assert_eq!(res.by_fraction.len(), 2);
        assert_eq!(res.by_fraction[0].len(), 2);
        // each merged report covers both task types, in trace order
        let rep = &res.by_fraction[0][0];
        let types: Vec<&str> = rep.tasks.iter().map(|t| t.task_type.as_str()).collect();
        assert_eq!(types, vec!["a/x", "b/y"]);
    }
}
