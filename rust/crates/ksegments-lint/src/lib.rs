//! In-repo invariant linter for the ksegments workspace.
//!
//! `cargo run -p ksegments-lint` tokenizes every `.rs` file in the
//! workspace and runs a small rule engine over the scrubbed source
//! (comments and string/char literals blanked, `#[cfg(test)]` spans
//! tracked). The passes encode the invariants the repo's documentation
//! promises but `rustc` cannot check:
//!
//! | rule id         | invariant                                           |
//! |-----------------|-----------------------------------------------------|
//! | `wallclock`     | `Instant::now`/`SystemTime::now` only in the timer  |
//! | `rng-discipline`| no literal RNG seeds outside tests                  |
//! | `map-iter-order`| no HashMap/HashSet in order-sensitive modules       |
//! | `panic-policy`  | no unwrap/expect/panic!/indexing in `serve/src/net` |
//! | `layering`      | the crate DAG of DESIGN.md §13 holds                |
//!
//! A finding on a line carrying `// lint:allow(rule)` — trailing, or
//! on a standalone comment line directly above — is recorded as a
//! suppression instead of a violation. Suppressions are deliberate,
//! reviewed escape hatches; the meta-test in `tests/engine.rs` pins
//! which rules are allowed to have any at all.
//!
//! See DESIGN.md §15 for the policy rationale and how to add a pass.

pub mod diag;
pub mod engine;
pub mod lexer;
pub mod rules;

pub use diag::{render_human, render_json, Diagnostic, Suppression};
pub use engine::{check_source, run_workspace, Report};
