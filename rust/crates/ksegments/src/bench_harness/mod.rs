//! Benchmark + figure-regeneration harness.
//!
//! * [`timer`] — minimal criterion-style measurement (offline cache has
//!   no criterion) and [`timer::Stopwatch`], the workspace's only
//!   sanctioned wall-clock (lives in `ksegments_core::util::timer`);
//! * [`bench`] — `ksegments bench`: one `BENCH_<area>.json` perf
//!   snapshot per area (sched / replay / grid / service), the
//!   committed perf trajectory CI diffs against;
//! * [`figures`] — one entry point per paper figure (Fig. 1, 4, 7a–c,
//!   8), shared by the CLI and the `cargo bench` targets (lives in
//!   `ksegments_sim`);
//! * [`throughput`] — the scheduling sweeps: makespan / queue-wait /
//!   packing tables per (policy × predictor × arrival rate), the
//!   dependency-gated workflow tables per (policy × predictor ×
//!   concurrent-instance count), and the failure-domain adversity
//!   tables per (predictor × failure rate × autoscale lag) with the
//!   `BENCH_sched.json` scheduler-throughput snapshot (lives in
//!   `ksegments_sched`, plus [`throughput::bench_sched_json`] here).
//!
//! [`bench`] and [`report`] are the two aggregation surfaces that need
//! sim + sched + serve at once, which is why they live in the facade
//! crate rather than any single layer.

pub mod bench;
pub mod report;

pub use ksegments_core::util::timer;
pub use ksegments_sim::{ablation, figures};

/// Scheduling sweep tables (re-export of `ksegments_sched::throughput`
/// plus the facade-level [`throughput::bench_sched_json`] alias, which
/// needs the cross-layer bench areas).
pub mod throughput {
    pub use ksegments_sched::throughput::*;

    /// Run the failure sweep as a scheduler micro-benchmark and render
    /// the `BENCH_sched.json` snapshot — a thin alias of the `sched`
    /// area of [`crate::bench_harness::bench::run_bench_area`], kept
    /// for the `bench-sched` CLI spelling. CI runs this per push so
    /// scheduler-throughput regressions show up as a diffable number.
    pub fn bench_sched_json(seed: u64, workers: usize) -> String {
        crate::bench_harness::bench::run_bench_area("sched", seed, workers)
            .expect("sched is a known bench area")
            .to_json()
    }
}

pub use bench::{run_bench_area, sched_snapshot, BenchSnapshot, BENCH_AREAS, BENCH_SCHEMA_VERSION};

// `bench` the timer *function* (value namespace) coexists with
// `bench` the snapshot *module* (type namespace), as it always has.
pub use ksegments_core::util::timer::{bench, black_box, time_once, Measurement, Stopwatch};
pub use ksegments_sim::figures::{
    evaluate_method, fig7_makers, make_method, makers_for_keys, method_names, method_roster,
    paper_traces, resolve_methods, run_fig1, run_fig4, run_fig7, run_fig7_selected, run_fig8,
    Fig7Results, Fig8Results, FitterChoice, EXTRA_METHOD_KEYS, METHOD_KEYS,
};
pub use throughput::{
    bench_sched_json, run_dag_throughput, run_failure_sweep, run_failure_sweep_axes,
    run_throughput, throughput_makers, DagThroughputResults, FailureSweepResults,
    ThroughputResults, FAILURE_SWEEP_LAGS, FAILURE_SWEEP_RATES,
};
