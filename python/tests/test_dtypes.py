"""Dtype and shape robustness for the Pallas kernels.

The AOT artifacts ship f32, but the kernels must stay correct across
the float dtypes Pallas supports on TPU (bf16 inputs are the realistic
monitoring-precision case) and across block/grid decompositions —
especially the remainder-tail path of the restructured segpeaks kernel
(k ∤ T), which is where the perf-pass rewrite could have broken the
paper's change-point semantics.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.linfit import linfit
from compile.kernels.ref import linfit_ref, segpeaks_ref
from compile.kernels.segpeaks import segpeaks


class TestSegpeaksDtypes:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
    def test_matches_reference_in_dtype(self, dtype):
        rng = np.random.default_rng(0)
        y = jnp.asarray(rng.uniform(0, 1000, size=(8, 48)), dtype=dtype)
        for k in (1, 3, 5, 7):
            got = segpeaks(y, k)
            want = segpeaks_ref(y, k)
            assert got.dtype == dtype
            np.testing.assert_array_equal(
                np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32)
            )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_max_is_exact_in_low_precision(self, dtype):
        # max is order-free: even bf16 must be bit-exact vs reference
        y = jnp.asarray([[1.0, 2.0, 4.0, 8.0, 16.0, 32.0]], dtype=dtype)
        got = np.asarray(segpeaks(y, 3), dtype=np.float32)
        np.testing.assert_array_equal(got, [[2.0, 8.0, 32.0]])


class TestSegpeaksTailPath:
    """k ∤ T exercises the reshape + remainder-fold (perf rewrite)."""

    @settings(max_examples=60, deadline=None)
    @given(
        st.integers(min_value=2, max_value=96),
        st.integers(min_value=1, max_value=16),
        st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_remainder_folds_into_last_segment(self, t, k, seed):
        if k > t:
            k = t
        rng = np.random.default_rng(seed)
        y = jnp.asarray(rng.uniform(-100, 100, size=(4, t)), dtype=jnp.float32)
        got = segpeaks(y, k)
        want = segpeaks_ref(y, k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_tail_peak_wins_when_larger(self):
        # t=10, k=4 -> i=2, last segment covers [6, 10); put the peak in
        # the remainder columns [8, 10)
        y = np.ones((1, 10), dtype=np.float32)
        y[0, 9] = 99.0
        got = np.asarray(segpeaks(jnp.asarray(y), 4))
        assert got[0, 3] == 99.0

    def test_tail_does_not_leak_into_earlier_segments(self):
        y = np.zeros((1, 10), dtype=np.float32)
        y[0, 9] = 99.0  # remainder column
        got = np.asarray(segpeaks(jnp.asarray(y), 4))
        np.testing.assert_array_equal(got[0, :3], [0.0, 0.0, 0.0])


class TestLinfitDtypes:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_recovers_line_in_dtype(self, dtype):
        x = jnp.asarray([1.0, 2.0, 3.0, 4.0], dtype=dtype)
        t = (2.0 + 1.5 * x)[:, None].astype(dtype)
        coef = np.asarray(
            linfit(x, t, jnp.ones(4, dtype=dtype)), dtype=np.float32
        )
        np.testing.assert_allclose(coef, [[2.0, 1.5]], rtol=2e-2, atol=5e-2)

    def test_f32_matches_ref_on_wide_m(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.uniform(1, 100, 32), dtype=jnp.float32)
        t = jnp.asarray(rng.uniform(0, 1000, (32, 17)), dtype=jnp.float32)
        v = jnp.ones(32, dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(linfit(x, t, v)),
            np.asarray(linfit_ref(x, t, v)),
            rtol=1e-5,
            atol=1e-4,
        )
