//! Cluster and resource-manager model.
//!
//! The paper's experiments ran on nodes with 128 GB of memory; the
//! resource manager (Slurm/Kubernetes in the paper's framing) admits a
//! task onto a node only if its requested memory fits, and the PPM
//! baseline's failure policy is "assign a node's maximum amount of
//! memory" — so node capacity is load-bearing for reproducing Fig. 7
//! (it is exactly what makes original PPM waste so much, §IV-E).

use crate::units::MemMiB;

/// Static description of one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeSpec {
    pub mem: MemMiB,
    pub cores: u32,
}

impl NodeSpec {
    /// The paper's testbed: 128 GB DDR4, 16C/32T EPYC 7282.
    pub fn paper_testbed() -> NodeSpec {
        NodeSpec { mem: MemMiB::from_gib(128.0), cores: 32 }
    }
}

/// A node with live memory accounting.
#[derive(Debug, Clone)]
pub struct Node {
    pub spec: NodeSpec,
    reserved: f64, // MiB
    /// Monotone counters for observability.
    pub admitted: u64,
    pub rejected: u64,
}

impl Node {
    pub fn new(spec: NodeSpec) -> Node {
        Node { spec, reserved: 0.0, admitted: 0, rejected: 0 }
    }

    pub fn free(&self) -> MemMiB {
        MemMiB((self.spec.mem.0 - self.reserved).max(0.0))
    }

    pub fn reserved(&self) -> MemMiB {
        MemMiB(self.reserved)
    }

    /// Try to reserve `mem`; returns false (and counts a rejection) if
    /// it does not fit.
    pub fn reserve(&mut self, mem: MemMiB) -> bool {
        if mem.0 <= 0.0 {
            return true;
        }
        if self.reserved + mem.0 <= self.spec.mem.0 + 1e-9 {
            self.reserved += mem.0;
            self.admitted += 1;
            true
        } else {
            self.rejected += 1;
            false
        }
    }

    pub fn release(&mut self, mem: MemMiB) {
        self.reserved = (self.reserved - mem.0).max(0.0);
    }
}

/// Reservation handle returned by the resource manager; releasing it
/// returns the memory to its node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reservation {
    pub node_idx: usize,
    pub mem: MemMiB,
}

/// A homogeneous cluster with first-fit placement — the substrate the
/// simulated SWMS submits to.
#[derive(Debug, Clone)]
pub struct Cluster {
    nodes: Vec<Node>,
}

impl Cluster {
    pub fn new(n_nodes: usize, spec: NodeSpec) -> Cluster {
        assert!(n_nodes > 0);
        Cluster { nodes: (0..n_nodes).map(|_| Node::new(spec)).collect() }
    }

    /// Single paper-testbed node (the evaluation setup).
    pub fn paper_testbed() -> Cluster {
        Cluster::new(1, NodeSpec::paper_testbed())
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Capacity of the largest node — what "assign the node's maximum
    /// memory" resolves to for the PPM failure policy.
    pub fn node_max_mem(&self) -> MemMiB {
        self.nodes
            .iter()
            .map(|n| n.spec.mem)
            .fold(MemMiB::ZERO, MemMiB::max)
    }

    /// First-fit reservation across nodes.
    pub fn reserve(&mut self, mem: MemMiB) -> Option<Reservation> {
        for (i, node) in self.nodes.iter_mut().enumerate() {
            if node.free().0 >= mem.0 && node.reserve(mem) {
                return Some(Reservation { node_idx: i, mem });
            }
        }
        None
    }

    pub fn release(&mut self, r: Reservation) {
        self.nodes[r.node_idx].release(r.mem);
    }

    /// Total free memory across nodes.
    pub fn total_free(&self) -> MemMiB {
        self.nodes.iter().map(|n| n.free()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_is_128_gib() {
        let c = Cluster::paper_testbed();
        assert_eq!(c.node_max_mem(), MemMiB::from_gib(128.0));
        assert_eq!(c.n_nodes(), 1);
    }

    #[test]
    fn reserve_and_release() {
        let mut c = Cluster::new(1, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let r = c.reserve(MemMiB(600.0)).unwrap();
        assert_eq!(c.total_free(), MemMiB(400.0));
        assert!(c.reserve(MemMiB(500.0)).is_none());
        c.release(r);
        assert_eq!(c.total_free(), MemMiB(1000.0));
    }

    #[test]
    fn first_fit_spills_to_second_node() {
        let mut c = Cluster::new(2, NodeSpec { mem: MemMiB(1000.0), cores: 4 });
        let _a = c.reserve(MemMiB(800.0)).unwrap();
        let b = c.reserve(MemMiB(800.0)).unwrap();
        assert_eq!(b.node_idx, 1);
    }

    #[test]
    fn rejection_counting() {
        let mut n = Node::new(NodeSpec { mem: MemMiB(100.0), cores: 1 });
        assert!(n.reserve(MemMiB(80.0)));
        assert!(!n.reserve(MemMiB(30.0)));
        assert_eq!(n.admitted, 1);
        assert_eq!(n.rejected, 1);
        assert_eq!(n.free(), MemMiB(20.0));
    }

    #[test]
    fn release_never_goes_negative() {
        let mut n = Node::new(NodeSpec { mem: MemMiB(100.0), cores: 1 });
        n.release(MemMiB(50.0));
        assert_eq!(n.free(), MemMiB(100.0));
    }

    #[test]
    fn zero_reservation_is_free() {
        let mut n = Node::new(NodeSpec { mem: MemMiB(100.0), cores: 1 });
        assert!(n.reserve(MemMiB(0.0)));
        assert_eq!(n.reserved(), MemMiB(0.0));
    }
}
