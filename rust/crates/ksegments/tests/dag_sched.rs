//! Integration lockdown for dependency-gated workflow scheduling:
//! the event-log gating invariant (no child is released/placed before
//! its last parent's final completion — under OOM retries too), the
//! DAG sweep's worker-count bit identity, and the oracle claim that an
//! OOM-killed parent strictly delays its instance's makespan.

use std::collections::HashMap;

use ksegments::cluster::NodeSpec;
use ksegments::engine::EngineEvent;
use ksegments::predictors::default_config::DefaultConfigPredictor;
use ksegments::predictors::{Allocation, FailureInfo, MemoryPredictor};
use ksegments::rng::Rng;
use ksegments::sched::{
    schedule_workflows, schedule_workflows_logged, DagGrid, DagTask, ReservationPolicy,
    SchedConfig, WorkflowInstance, WorkflowSource,
};
use ksegments::sim::PredictorFactory;
use ksegments::trace::{TaskRun, UsageSeries};
use ksegments::units::{MemMiB, Seconds};
use ksegments::workload::{eager_workflow, sarek_workflow, ProfileShape, TaskTypeSpec, WorkflowSpec};

fn flat_run(ty: &str, seq: u64, peak: f64, runtime_s: f64) -> TaskRun {
    let n = (runtime_s / 2.0).max(1.0) as usize;
    TaskRun {
        task_type: ty.into(),
        input_mib: 50.0,
        runtime: Seconds(n as f64 * 2.0),
        series: UsageSeries::new(2.0, vec![peak; n]),
        seq,
    }
}

/// Linear climb to `peak`: an under-half allocation burns real
/// simulated time before its OOM instant.
fn ramp_run(ty: &str, seq: u64, peak: f64, runtime_s: f64) -> TaskRun {
    let n = (runtime_s / 2.0).max(1.0) as usize;
    let samples: Vec<f64> = (1..=n).map(|j| peak * j as f64 / n as f64).collect();
    TaskRun {
        task_type: ty.into(),
        input_mib: 50.0,
        runtime: Seconds(n as f64 * 2.0),
        series: UsageSeries::new(2.0, samples),
        seq,
    }
}

/// Random DAG instances: every task's parents are a random subset of
/// the tasks before it (topological by construction).
fn random_instances(rng: &mut Rng, n_instances: usize, n_tasks: usize) -> Vec<WorkflowInstance> {
    (0..n_instances)
        .map(|i| {
            let tasks = (0..n_tasks)
                .map(|t| {
                    let parents: Vec<usize> = (0..t).filter(|_| rng.f64() < 0.4).collect();
                    let peak = rng.uniform(100.0, 900.0);
                    let rt = 2.0 * (1.0 + rng.below(5) as f64);
                    let seq = (i * n_tasks + t) as u64;
                    DagTask { run: flat_run(&format!("w/t{t}"), seq, peak, rt), parents }
                })
                .collect();
            WorkflowInstance { name: "w".into(), index: i as u64, tasks }
        })
        .collect()
}

/// THE acceptance-criterion property: for every edge (u → v) of every
/// instance, v's `Released` and first `Placed` events come strictly
/// after u's final `Completed` event in the log — including when
/// parents OOM-retry first (undersized defaults).
#[test]
fn no_child_starts_before_its_last_parent_completes() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed + 100);
        let instances = random_instances(&mut rng, 3, 6);
        // keep the parent edges for the assertion below
        let edges: Vec<(u64, u64)> = instances
            .iter()
            .flat_map(|inst| {
                inst.tasks.iter().enumerate().flat_map(move |(t, task)| {
                    task.parents
                        .iter()
                        .map(move |&p| (inst.tasks[p].run.seq, inst.tasks[t].run.seq))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        // undersized defaults on even seeds: the gate must hold across
        // OOM-kill → requeue retries of the parents too
        let default = if seed % 2 == 0 { MemMiB(60.0) } else { MemMiB(1200.0) };
        let defaults: Vec<(String, MemMiB)> =
            (0..6).map(|t| (format!("w/t{t}"), default)).collect();
        let cfg = SchedConfig {
            nodes: vec![NodeSpec { mem: MemMiB(3000.0), cores: 8 }; 2],
            mean_interarrival: Seconds(4.0),
            seed,
            event_log_cap: 0, // unbounded: the property reads the log
            ..SchedConfig::default()
        };
        let mut p = DefaultConfigPredictor::new();
        let (r, log) = schedule_workflows_logged(
            WorkflowSource::from_instances(instances, defaults),
            &mut p,
            &cfg,
        );
        assert_eq!(r.workflows_completed, 3, "seed {seed}");
        assert_eq!(r.completed, 18, "seed {seed}");
        if seed % 2 == 0 {
            assert!(r.oom_kills > 0, "seed {seed}: undersized defaults must OOM");
        }

        let mut completed_at: HashMap<u64, usize> = HashMap::new();
        let mut released_at: HashMap<u64, usize> = HashMap::new();
        let mut first_placed_at: HashMap<u64, usize> = HashMap::new();
        for (pos, ev) in log.iter().enumerate() {
            match ev {
                EngineEvent::Completed { seq, .. } => {
                    completed_at.insert(*seq, pos);
                }
                EngineEvent::Released { seq, .. } => {
                    assert!(
                        released_at.insert(*seq, pos).is_none(),
                        "seed {seed}: task {seq} released twice"
                    );
                }
                EngineEvent::Placed { seq, .. } => {
                    first_placed_at.entry(*seq).or_insert(pos);
                }
                _ => {}
            }
        }
        for &(u, v) in &edges {
            let u_done = completed_at[&u];
            let v_rel = released_at[&v];
            let v_placed = first_placed_at[&v];
            assert!(
                v_rel > u_done,
                "seed {seed}: task {v} released (log pos {v_rel}) before parent {u} \
                 completed (log pos {u_done})"
            );
            assert!(
                v_placed > u_done,
                "seed {seed}: task {v} placed (log pos {v_placed}) before parent {u} \
                 completed (log pos {u_done})"
            );
        }
        // every task released exactly once, every release placed later
        assert_eq!(released_at.len(), 18, "seed {seed}");
        for (seq, rel) in &released_at {
            assert!(first_placed_at[seq] > *rel, "seed {seed}: task {seq} placed before release");
        }
    }
}

fn small_wf(n_exec: usize) -> WorkflowSpec {
    let t = |name: &str, rt: f64, peak: f64| TaskTypeSpec {
        name: format!("wf/{name}"),
        profile: ProfileShape::RampUp { alpha: 1.0 },
        rt_base: Seconds(rt),
        rt_per_mib: 0.02,
        peak_base: MemMiB(peak),
        peak_per_mib: 0.4,
        noise_sigma: 0.1,
        spike_prob: 0.05,
        wiggle_sigma: 0.02,
        input_mu: 5.5,
        input_sigma: 0.5,
        n_executions: n_exec,
        default_mem: MemMiB(4096.0),
    };
    WorkflowSpec {
        name: "wf".into(),
        tasks: vec![
            t("qc", 15.0, 150.0),
            t("align", 60.0, 900.0),
            t("dedup", 30.0, 500.0),
            t("call", 45.0, 700.0),
        ],
        edges: vec![(0, 1), (1, 2), (1, 3), (2, 3)],
    }
}

/// Acceptance criterion: the DAG sweep is bit-identical at any worker
/// count (the per-cell instances are regenerated from the seed, so
/// cells share nothing).
#[test]
fn dag_grid_bit_identical_across_worker_counts() {
    let wf = small_wf(4);
    let mk_methods = || -> Vec<PredictorFactory> {
        vec![
            Box::new(|| Box::new(DefaultConfigPredictor::new())),
            Box::new(|| Box::new(ksegments::predictors::ppm::PpmPredictor::improved())),
        ]
    };
    let grid = DagGrid::new(
        vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise],
        mk_methods(),
        &wf,
        vec![1, 2],
        vec![2, 4],
    )
    .with_base(
        SchedConfig { seed: 42, ..SchedConfig::default() },
        NodeSpec { mem: MemMiB(6000.0), cores: 8 },
    );
    let seq = grid.run(1);
    for workers in [2, 8] {
        assert_eq!(grid.run(workers), seq, "workers={workers} diverged");
    }
    assert_eq!(seq.reports.len(), 2 * 2 * 2 * 2);
    for (cell, rep) in seq.cells.iter().zip(&seq.reports) {
        assert_eq!(rep.completed, rep.submitted, "cell {cell:?} lost tasks");
        assert_eq!(rep.workflows_completed, rep.workflows_submitted, "cell {cell:?}");
        assert_eq!(
            rep.admitted,
            rep.completed + rep.oom_kills + rep.grow_denials,
            "cell {cell:?} accounting broken"
        );
    }
}

/// A predictor that undersizes one named task type on its first
/// attempt and is exact everywhere else — the controlled failure
/// injection for the delay oracle.
struct Undersize {
    victim: &'static str,
    peaks: HashMap<String, f64>,
    fail_first: bool,
}
impl MemoryPredictor for Undersize {
    fn name(&self) -> String {
        "undersize-oracle".into()
    }
    fn prime(&mut self, _: &str, _: MemMiB) {}
    fn predict(&mut self, task_type: &str, _: f64) -> Allocation {
        let peak = self.peaks[task_type];
        if self.fail_first && task_type == self.victim {
            // below the true peak: the first attempt OOMs mid-run
            Allocation::Static(MemMiB(peak * 0.5))
        } else {
            Allocation::Static(MemMiB(peak * 1.01))
        }
    }
    fn on_failure(
        &mut self,
        task_type: &str,
        _: f64,
        _: &Allocation,
        _: &FailureInfo,
    ) -> Allocation {
        Allocation::Static(MemMiB(self.peaks[task_type] * 1.01))
    }
    fn observe(&mut self, _: &TaskRun) {}
}

/// The oracle delay claim: an OOM-killed parent strictly delays the
/// workflow makespan vs. the failure-free run of the *same* instance —
/// underprediction now propagates along the critical path.
#[test]
fn oom_killed_parent_strictly_delays_workflow_makespan() {
    // parent (20 s ramp) → child (20 s); capacity is never the
    // bottleneck, and the ramp makes the undersized first attempt die
    // mid-run rather than at t = 0
    let mk_src = || {
        let parent = ramp_run("w/parent", 0, 800.0, 20.0);
        let child = flat_run("w/child", 1, 800.0, 20.0);
        WorkflowSource::from_instances(
            vec![WorkflowInstance {
                name: "w".into(),
                index: 0,
                tasks: vec![
                    DagTask { run: parent, parents: vec![] },
                    DagTask { run: child, parents: vec![0] },
                ],
            }],
            vec![("w/parent".into(), MemMiB(1000.0)), ("w/child".into(), MemMiB(1000.0))],
        )
    };
    let peaks: HashMap<String, f64> =
        [("w/parent".to_string(), 800.0), ("w/child".to_string(), 800.0)].into();
    let cfg = SchedConfig {
        nodes: vec![NodeSpec { mem: MemMiB(8000.0), cores: 8 }],
        mean_interarrival: Seconds(0.0),
        ..SchedConfig::default()
    };
    let mut ok = Undersize { victim: "w/parent", peaks: peaks.clone(), fail_first: false };
    let clean = schedule_workflows(mk_src(), &mut ok, &cfg);
    let mut bad = Undersize { victim: "w/parent", peaks, fail_first: true };
    let failed = schedule_workflows(mk_src(), &mut bad, &cfg);

    assert_eq!(clean.oom_kills, 0);
    assert!(failed.oom_kills >= 1, "the victim's first attempt must OOM");
    assert_eq!(clean.workflows_completed, 1);
    assert_eq!(failed.workflows_completed, 1);
    // identical DAG, identical critical path ...
    assert_eq!(clean.workflow_critical_paths, failed.workflow_critical_paths);
    // ... but the parent's retry pushes the whole instance later
    assert!(
        failed.workflow_makespans[0] > clean.workflow_makespans[0] + 1e-9,
        "OOM retry of a parent must delay the workflow: {} !> {}",
        failed.workflow_makespans[0],
        clean.workflow_makespans[0]
    );
    assert!(failed.critical_path_stretch() > clean.critical_path_stretch());
}

/// Both paper workflows schedule end to end in DAG mode under every
/// policy, with all workflow metrics internally consistent.
#[test]
fn paper_workflows_schedule_as_dags() {
    for wf in [eager_workflow(), sarek_workflow()] {
        for policy in [ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise] {
            let cfg = SchedConfig {
                policy,
                nodes: vec![NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 }; 2],
                mean_interarrival: Seconds(5.0),
                seed: 42,
                ..SchedConfig::default()
            };
            let src = WorkflowSource::from_spec(&wf, 42, 2);
            let n_tasks = src.n_tasks() as u64;
            let mut p = DefaultConfigPredictor::new();
            let r = schedule_workflows(src, &mut p, &cfg);
            assert_eq!(r.workflows_submitted, 2, "{} {:?}", wf.name, policy);
            assert_eq!(r.workflows_completed, 2, "{} {:?}", wf.name, policy);
            assert_eq!(r.submitted, n_tasks, "{} {:?}", wf.name, policy);
            assert_eq!(r.completed, r.submitted, "{} {:?}", wf.name, policy);
            assert_eq!(r.workflow_makespans.len(), 2);
            for (m, cp) in r.workflow_makespans.iter().zip(&r.workflow_critical_paths) {
                assert!(cp > &0.0);
                assert!(*m >= *cp - 1e-9, "{}: makespan {m} < critical path {cp}", wf.name);
            }
            for (f, m) in r.workflow_first_completions.iter().zip(&r.workflow_makespans) {
                assert!(*f <= *m + 1e-9);
            }
        }
    }
}
