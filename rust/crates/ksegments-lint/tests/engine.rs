//! Lint engine acceptance tests: every pass fires on a known-bad
//! fixture at the exact line, stays quiet on the known-good twin,
//! suppressions behave as documented, the JSON report parses against
//! its schema, and — the meta-test — the real workspace lints clean
//! with suppressions confined to the rules allowed to carry them.

use std::path::Path;

use ksegments_core::util::json::Json;
use ksegments_lint::{check_source, render_json, run_workspace, rules, Diagnostic};

/// Violations for a src/ (non-test) fixture file.
fn lint(krate: &str, rel_path: &str, src: &str) -> Vec<Diagnostic> {
    check_source(krate, rel_path, src, false).0
}

fn hits(diags: &[Diagnostic], rule: &str) -> Vec<usize> {
    diags.iter().filter(|d| d.rule == rule).map(|d| d.line).collect()
}

// -- wallclock --------------------------------------------------------------

#[test]
fn wallclock_flags_instant_now_outside_timer() {
    let bad = "use std::time::Instant;\nfn f() {\n    let t = Instant::now();\n}\n";
    assert_eq!(hits(&lint("ksegments-sched", "src/sched/mod.rs", bad), "wallclock"), vec![3]);
    // SystemTime too
    let bad2 = "fn f() { let _ = std::time::SystemTime::now(); }\n";
    assert_eq!(hits(&lint("ksegments-core", "src/trace.rs", bad2), "wallclock"), vec![1]);
}

#[test]
fn wallclock_good_in_timer_module_tests_and_strings() {
    let ok = "fn f() {\n    let t = Instant::now();\n}\n";
    assert!(hits(&lint("ksegments-core", "src/util/timer.rs", ok), "wallclock").is_empty());
    let gated = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = Instant::now(); }\n}\n";
    assert!(hits(&lint("ksegments-core", "src/rng.rs", gated), "wallclock").is_empty());
    let in_str = "fn f() { let s = \"Instant::now()\"; } // Instant::now()\n";
    assert!(hits(&lint("ksegments-core", "src/rng.rs", in_str), "wallclock").is_empty());
}

// -- rng-discipline ---------------------------------------------------------

#[test]
fn rng_discipline_flags_literal_seeds() {
    let bad = "fn f() {\n    let mut rng = Rng::new(42);\n}\n";
    assert_eq!(hits(&lint("ksegments-sim", "src/figures.rs", bad), "rng-discipline"), vec![2]);
}

#[test]
fn rng_discipline_good_seed_variable_fork_and_tests() {
    let ok = "fn f(seed: u64) {\n    let rng = Rng::new(seed).fork(\"grid\");\n}\n";
    assert!(hits(&lint("ksegments-sim", "src/figures.rs", ok), "rng-discipline").is_empty());
    let gated = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = Rng::new(42); }\n}\n";
    assert!(hits(&lint("ksegments-core", "src/rng.rs", gated), "rng-discipline").is_empty());
}

// -- map-iter-order ---------------------------------------------------------

#[test]
fn map_iter_order_flags_hashmap_in_scoped_module() {
    let bad = "use std::collections::HashMap;\nfn f(m: &HashMap<String, u64>) {}\n";
    assert_eq!(
        hits(&lint("ksegments-core", "src/wastage.rs", bad), "map-iter-order"),
        vec![1, 2]
    );
}

#[test]
fn map_iter_order_good_btreemap_and_out_of_scope() {
    let ok = "use std::collections::BTreeMap;\nfn f(m: &BTreeMap<String, u64>) {}\n";
    assert!(hits(&lint("ksegments-core", "src/wastage.rs", ok), "map-iter-order").is_empty());
    // same HashMap source is fine outside the order-sensitive modules
    let hash = "use std::collections::HashMap;\n";
    assert!(hits(&lint("ksegments-core", "src/trace.rs", hash), "map-iter-order").is_empty());
}

// -- panic-policy -----------------------------------------------------------

#[test]
fn panic_policy_flags_unwrap_expect_panic_and_indexing() {
    let bad = "fn f(v: &[u8]) {\n    let a = v.first().unwrap();\n    let b = \
               std::str::from_utf8(v).expect(\"utf8\");\n    panic!(\"boom\");\n    \
               let c = v[0];\n}\n";
    let diags = lint("ksegments-serve", "src/net/frame.rs", bad);
    assert_eq!(hits(&diags, "panic-policy"), vec![2, 3, 4, 5]);
}

#[test]
fn panic_policy_good_outside_net_and_in_tests() {
    let src = "fn f(v: &[u8]) { let _ = v[0]; }\n";
    assert!(hits(&lint("ksegments-serve", "src/ingest/mod.rs", src), "panic-policy").is_empty());
    assert!(hits(&lint("ksegments-core", "src/net/x.rs", src), "panic-policy").is_empty());
    let gated = "#[cfg(test)]\nmod tests {\n    fn t(v: &[u8]) { let _ = v[0]; }\n}\n";
    assert!(hits(
        &lint("ksegments-serve", "src/net/frame.rs", gated),
        "panic-policy"
    )
    .is_empty());
}

// -- layering ---------------------------------------------------------------

#[test]
fn layering_flags_sideways_use_edge() {
    let bad = "use ksegments_sim::parallel::PredictorFactory;\n";
    assert_eq!(hits(&lint("ksegments-sched", "src/throughput.rs", bad), "layering"), vec![1]);
    // core reaching up into the facade
    let up = "fn f() { ksegments::sim::run(); }\n";
    assert_eq!(hits(&lint("ksegments-core", "src/ml/mod.rs", up), "layering"), vec![1]);
}

#[test]
fn layering_good_downward_edges_and_self() {
    let ok = "use ksegments_core::parallel::PredictorFactory;\n";
    assert!(hits(&lint("ksegments-sched", "src/throughput.rs", ok), "layering").is_empty());
    let facade = "pub use ksegments_sim::figures;\npub use ksegments_serve::net;\n";
    assert!(hits(&lint("ksegments", "src/lib.rs", facade), "layering").is_empty());
    let cli = "use ksegments::prelude::*;\n";
    assert!(hits(&lint("ksegments-cli", "src/main.rs", cli), "layering").is_empty());
    // core's own `predictors::ksegments` module is not the facade
    let own_mod = "use crate::predictors::ksegments::RetryStrategy;\n";
    assert!(hits(&lint("ksegments-core", "src/predictors/roster.rs", own_mod), "layering")
        .is_empty());
}

// -- suppressions -----------------------------------------------------------

#[test]
fn suppression_trailing_and_standalone() {
    let trailing = "fn f(v: &[u8]) { let _ = v[0]; } // lint:allow(panic-policy)\n";
    let (diags, sups) = check_source("ksegments-serve", "src/net/frame.rs", trailing, false);
    assert!(hits(&diags, "panic-policy").is_empty());
    assert_eq!(sups.len(), 1);
    assert_eq!((sups[0].rule, sups[0].line), ("panic-policy", 1));

    let standalone = "// in bounds: lint:allow(panic-policy)\nfn f(v: &[u8]) { let _ = v[0]; }\n";
    let (diags, sups) = check_source("ksegments-serve", "src/net/frame.rs", standalone, false);
    assert!(diags.is_empty());
    assert_eq!(sups.len(), 1);
    assert_eq!(sups[0].line, 2);
}

#[test]
fn suppression_is_per_rule_and_per_line() {
    // allowing a different rule does not waive the finding
    let wrong = "fn f(v: &[u8]) { let _ = v[0]; } // lint:allow(wallclock)\n";
    let (diags, sups) = check_source("ksegments-serve", "src/net/frame.rs", wrong, false);
    assert_eq!(hits(&diags, "panic-policy"), vec![1]);
    assert!(sups.is_empty());
    // an allow two lines above does not reach the finding
    let far = "// lint:allow(panic-policy)\n\nfn f(v: &[u8]) { let _ = v[0]; }\n";
    let (diags, _) = check_source("ksegments-serve", "src/net/frame.rs", far, false);
    assert_eq!(hits(&diags, "panic-policy"), vec![3]);
}

// -- JSON report ------------------------------------------------------------

#[test]
fn json_report_matches_schema() {
    let src = "fn f() { let _ = Instant::now(); }\nfn g(v: &[u8]) { let _ = v[0]; }\n";
    let (diags, sups) = check_source("ksegments-serve", "src/net/server.rs", src, false);
    let report = ksegments_lint::Report { diags, suppressed: sups, files_scanned: 1 };
    let doc = Json::parse(&render_json(&report)).expect("report must be valid JSON");
    assert_eq!(doc.get("schema").as_str(), Some("ksegments-lint-v1"));
    assert_eq!(doc.get("files_scanned").as_f64(), Some(1.0));
    let rule_list = doc.get("rules").as_arr().expect("rules array");
    assert_eq!(rule_list.len(), rules::RULE_IDS.len());
    let violations = doc.get("violations").as_arr().expect("violations array");
    assert!(!violations.is_empty());
    for v in violations {
        assert!(v.get("rule").as_str().is_some());
        assert!(v.get("path").as_str().is_some());
        assert!(v.get("line").as_f64().is_some());
        assert!(v.get("message").as_str().is_some());
    }
    assert!(doc.get("suppressions").as_arr().is_some());
}

#[test]
fn every_rule_id_has_a_firing_fixture() {
    // the fixtures above cover each id; this guards the registry from
    // growing a pass without one
    let fixtures = [
        ("ksegments-sched", "src/x.rs", "fn f() { let _ = Instant::now(); }\n", "wallclock"),
        ("ksegments-sim", "src/x.rs", "fn f() { let _ = Rng::new(7); }\n", "rng-discipline"),
        ("ksegments-sim", "src/x.rs", "use std::collections::HashMap;\n", "map-iter-order"),
        ("ksegments-serve", "src/net/x.rs", "fn f(v: &[u8]) -> u8 { v[0] }\n", "panic-policy"),
        ("ksegments-core", "src/x.rs", "use ksegments_sim::figures;\n", "layering"),
    ];
    for id in rules::RULE_IDS {
        let covered = fixtures
            .iter()
            .any(|(k, p, src, rule)| rule == id && !hits(&lint(k, p, src), id).is_empty());
        assert!(covered, "rule {id:?} has no firing known-bad fixture");
    }
}

// -- meta: the real workspace ----------------------------------------------

fn workspace_root() -> &'static Path {
    // crates/ksegments-lint -> crates -> the rust/ workspace root
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .expect("lint crate lives two levels under the workspace root")
}

#[test]
fn real_workspace_lints_clean() {
    let report = run_workspace(workspace_root()).expect("scan workspace");
    assert!(report.files_scanned > 50, "suspiciously few files: {}", report.files_scanned);
    assert!(
        report.diags.is_empty(),
        "workspace has lint violations:\n{}",
        ksegments_lint::render_human(&report)
    );
}

#[test]
fn determinism_rules_carry_zero_suppressions() {
    // wallclock reconfirms PR 7: Stopwatch is the only Instant::now()
    // site — with zero waivers. Same bar for the other determinism
    // passes; only panic-policy may carry reviewed in-bounds proofs.
    let report = run_workspace(workspace_root()).expect("scan workspace");
    let waived: Vec<_> = report
        .suppressed
        .iter()
        .filter(|s| s.rule != "panic-policy")
        .map(|s| format!("{}:{} [{}]", s.path, s.line, s.rule))
        .collect();
    assert!(waived.is_empty(), "determinism-critical suppressions found: {waived:?}");
}

#[test]
fn workspace_report_is_deterministic() {
    let a = run_workspace(workspace_root()).expect("scan");
    let b = run_workspace(workspace_root()).expect("scan");
    assert_eq!(render_json(&a), render_json(&b));
}
