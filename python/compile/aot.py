"""AOT pipeline: lower the k-Segments fit graph to HLO text artifacts.

Emits, for every k in ``model.K_RANGE``:

    artifacts/ksegments_fit_k{K}.hlo.txt

plus ``artifacts/manifest.json`` describing shapes, argument order and
output order, which rust/src/runtime reads at load time.

Interchange format is HLO **text**, NOT ``lowered.compile()`` /
``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/README.md.

Lowered with ``return_tuple=True``; the rust side unwraps the 4-tuple.

Run via ``make artifacts`` (no-op when inputs are unchanged).  This is
the ONLY place python runs; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import K_RANGE, N_HIST, T_MAX, make_fit_fn


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fit(k: int, n: int = N_HIST, t: int = T_MAX) -> str:
    """Lower ksegments_fit for a static k to HLO text."""
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    mat = jax.ShapeDtypeStruct((n, t), jnp.float32)
    lowered = jax.jit(make_fit_fn(k)).lower(vec, mat, vec, vec)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out",
        default="../artifacts/model.hlo.txt",
        help="sentinel path; artifacts land in its directory",
    )
    parser.add_argument("--n", type=int, default=N_HIST)
    parser.add_argument("--t", type=int, default=T_MAX)
    args = parser.parse_args()

    out_dir = pathlib.Path(args.out).parent
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {
        "n_hist": args.n,
        "t_max": args.t,
        "dtype": "f32",
        "inputs": ["x[N]", "y[N,T]", "runtime[N]", "valid[N]"],
        "outputs": ["rt_coef[2]", "rt_offset[]", "seg_coef[K,2]", "seg_off[K]"],
        "fits": {},
    }
    for k in K_RANGE:
        text = lower_fit(k, args.n, args.t)
        name = f"ksegments_fit_k{k}.hlo.txt"
        (out_dir / name).write_text(text)
        manifest["fits"][str(k)] = name
        print(f"wrote {name} ({len(text)} chars)")

    # Sentinel file keeps the Makefile dependency simple: it is the k=4
    # (paper default) module under the canonical name.
    sentinel = pathlib.Path(args.out)
    sentinel.write_text((out_dir / "ksegments_fit_k4.hlo.txt").read_text())
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote manifest.json + sentinel {sentinel.name}; dir={out_dir}")


if __name__ == "__main__":
    main()
