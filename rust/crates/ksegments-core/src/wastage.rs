//! Wastage accounting and report tables — the quantities plotted in
//! the paper's Fig. 7 (wastage, lowest-wastage wins, retries).
//!
//! Formerly the top-level `metrics` module; renamed to `wastage` when
//! the workspace split landed, because "metrics" collided with the
//! operational counters in [`crate::telemetry::registry`]. This module
//! is *evaluation results* (how much memory a method wasted); the
//! registry is *run observability* (counters/gauges/histograms about
//! the process itself). The `ksegments` facade still exposes the old
//! `ksegments::metrics` path as an alias.

use crate::telemetry::Registry;
use crate::units::GbSeconds;
use crate::util::stats;

/// Per-task-type metrics for one method at one training fraction.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskReport {
    pub task_type: String,
    pub n_scored: usize,
    pub total_wastage: GbSeconds,
    pub total_retries: u64,
    /// Per-run wastage samples (GB·s), kept for win counting and
    /// dispersion statistics.
    pub per_run_wastage: Vec<f64>,
}

impl TaskReport {
    pub fn new(task_type: &str) -> TaskReport {
        TaskReport {
            task_type: task_type.to_string(),
            n_scored: 0,
            total_wastage: GbSeconds::ZERO,
            total_retries: 0,
            per_run_wastage: Vec::new(),
        }
    }

    pub fn record(&mut self, wastage: GbSeconds, retries: u32) {
        self.n_scored += 1;
        self.total_wastage += wastage;
        self.total_retries += retries as u64;
        self.per_run_wastage.push(wastage.0);
    }

    /// Average wastage per scored run (GB·s) — Fig. 7a's unit.
    pub fn avg_wastage_gbs(&self) -> f64 {
        if self.n_scored == 0 {
            0.0
        } else {
            self.total_wastage.0 / self.n_scored as f64
        }
    }

    /// Average retries per scored run — Fig. 7c's unit.
    pub fn avg_retries(&self) -> f64 {
        if self.n_scored == 0 {
            0.0
        } else {
            self.total_retries as f64 / self.n_scored as f64
        }
    }

    /// Fold another report for the **same task type** into this one
    /// (e.g. per-shard or per-cell partial reports). Totals add; the
    /// per-run samples are concatenated in the order given.
    pub fn merge(&mut self, other: TaskReport) {
        assert_eq!(self.task_type, other.task_type, "merging different task types");
        self.n_scored += other.n_scored;
        self.total_wastage += other.total_wastage;
        self.total_retries += other.total_retries;
        self.per_run_wastage.extend(other.per_run_wastage);
    }
}

/// All evaluated tasks for one method at one training fraction.
#[derive(Debug, Clone, PartialEq)]
pub struct MethodReport {
    pub method: String,
    pub training_frac: f64,
    pub tasks: Vec<TaskReport>,
}

impl MethodReport {
    pub fn new(method: &str, training_frac: f64, tasks: Vec<TaskReport>) -> MethodReport {
        MethodReport { method: method.to_string(), training_frac, tasks }
    }

    pub fn total_wastage_gbs(&self) -> f64 {
        self.tasks.iter().map(|t| t.total_wastage.0).sum()
    }

    /// Mean over tasks of per-task average wastage — the Fig. 7a bar.
    pub fn avg_wastage_gbs(&self) -> f64 {
        stats::mean(&self.tasks.iter().map(|t| t.avg_wastage_gbs()).collect::<Vec<_>>())
    }

    pub fn total_retries(&self) -> u64 {
        self.tasks.iter().map(|t| t.total_retries).sum()
    }

    /// Mean over tasks of per-task average retries — the Fig. 7c bar.
    pub fn avg_retries(&self) -> f64 {
        stats::mean(&self.tasks.iter().map(|t| t.avg_retries()).collect::<Vec<_>>())
    }

    pub fn task(&self, ty: &str) -> Option<&TaskReport> {
        self.tasks.iter().find(|t| t.task_type == ty)
    }

    /// Fold another report (same method, same fraction) into this one.
    ///
    /// Task types present in both are combined via [`TaskReport::merge`]
    /// (per-shard partials of one type); new types are appended in the
    /// order they arrive, so disjoint task sets (e.g. the second
    /// workflow's types) reproduce the old concatenation exactly.
    pub fn merge(&mut self, other: MethodReport) {
        assert_eq!(self.method, other.method, "merging different methods");
        assert!(
            (self.training_frac - other.training_frac).abs() < 1e-12,
            "merging different training fractions"
        );
        for task in other.tasks {
            match self.tasks.iter_mut().find(|t| t.task_type == task.task_type) {
                Some(mine) => mine.merge(task),
                None => self.tasks.push(task),
            }
        }
    }

    /// Export replay results into a metrics [`Registry`] under
    /// `{method,task}` labels: scored/retry counters plus an
    /// average-wastage gauge per task type, and method-level rollups.
    /// Purely observational — reads `&self`, writes only into `reg`.
    pub fn export_metrics(&self, reg: &mut Registry) {
        for t in &self.tasks {
            let l = format!("{{method=\"{}\",task=\"{}\"}}", self.method, t.task_type);
            reg.counter_add(&format!("replay_scored{l}"), t.n_scored as u64);
            reg.counter_add(&format!("replay_retries{l}"), t.total_retries);
            reg.gauge_set(&format!("replay_avg_wastage_gbs{l}"), t.avg_wastage_gbs());
        }
        let l = format!("{{method=\"{}\"}}", self.method);
        reg.counter_add(
            &format!("replay_scored_total{l}"),
            self.tasks.iter().map(|t| t.n_scored as u64).sum(),
        );
        reg.counter_add(&format!("replay_retries_total{l}"), self.total_retries());
        reg.gauge_set(&format!("replay_avg_wastage_gbs_mean{l}"), self.avg_wastage_gbs());
    }

    /// Merge an ordered sequence of per-cell reports into one; `None`
    /// for an empty sequence. The grid uses this to combine per-trace
    /// cells in deterministic trace order.
    pub fn merged(reports: impl IntoIterator<Item = MethodReport>) -> Option<MethodReport> {
        let mut it = reports.into_iter();
        let mut acc = it.next()?;
        for rep in it {
            acc.merge(rep);
        }
        Some(acc)
    }
}

/// Fig. 7b: per method, the number of tasks on which it achieves the
/// lowest average wastage. Ties award a point to every tied method
/// (paper: "If two methods both have the least wastage, they both get
/// one point").
pub fn count_wins(reports: &[MethodReport]) -> Vec<(String, usize)> {
    let mut wins: Vec<(String, usize)> = reports.iter().map(|r| (r.method.clone(), 0)).collect();
    if reports.is_empty() {
        return wins;
    }
    // all reports must cover the same task set
    let tasks: Vec<&str> = reports[0].tasks.iter().map(|t| t.task_type.as_str()).collect();
    for ty in tasks {
        let scores: Vec<f64> = reports
            .iter()
            .map(|r| r.task(ty).map(|t| t.avg_wastage_gbs()).unwrap_or(f64::INFINITY))
            .collect();
        let best = scores.iter().copied().fold(f64::INFINITY, f64::min);
        for (i, &s) in scores.iter().enumerate() {
            // relative tie tolerance: identical within 1e-9
            if (s - best).abs() <= 1e-9 * best.max(1e-12) {
                wins[i].1 += 1;
            }
        }
    }
    wins
}

/// Render a Fig. 7-style table: one row per method, one column per
/// training fraction, via an accessor.
pub fn render_table(
    title: &str,
    fractions: &[f64],
    rows: &[(String, Vec<f64>)],
    unit: &str,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {title}\n\n"));
    out.push_str("| method |");
    for f in fractions {
        out.push_str(&format!(" {:.0}% train |", f * 100.0));
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in fractions {
        out.push_str("---|");
    }
    out.push('\n');
    for (method, vals) in rows {
        out.push_str(&format!("| {method} |"));
        for v in vals {
            out.push_str(&format!(" {v:.3} |"));
        }
        out.push('\n');
    }
    out.push_str(&format!("\n(unit: {unit})\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(ty: &str, wastages: &[f64], retries: &[u32]) -> TaskReport {
        let mut t = TaskReport::new(ty);
        for (w, r) in wastages.iter().zip(retries) {
            t.record(GbSeconds(*w), *r);
        }
        t
    }

    #[test]
    fn task_report_averages() {
        let t = task("a", &[1.0, 3.0], &[0, 2]);
        assert_eq!(t.n_scored, 2);
        assert_eq!(t.avg_wastage_gbs(), 2.0);
        assert_eq!(t.avg_retries(), 1.0);
    }

    #[test]
    fn empty_task_report_is_zero() {
        let t = TaskReport::new("a");
        assert_eq!(t.avg_wastage_gbs(), 0.0);
        assert_eq!(t.avg_retries(), 0.0);
    }

    #[test]
    fn method_report_aggregates() {
        let r = MethodReport::new(
            "m",
            0.5,
            vec![task("a", &[2.0], &[1]), task("b", &[4.0], &[3])],
        );
        assert_eq!(r.total_wastage_gbs(), 6.0);
        assert_eq!(r.avg_wastage_gbs(), 3.0);
        assert_eq!(r.total_retries(), 4);
        assert_eq!(r.avg_retries(), 2.0);
        assert!(r.task("a").is_some());
        assert!(r.task("zzz").is_none());
    }

    #[test]
    fn task_report_merge_adds_totals() {
        let mut a = task("a", &[1.0, 2.0], &[0, 1]);
        let b = task("a", &[3.0], &[2]);
        a.merge(b);
        assert_eq!(a.n_scored, 3);
        assert_eq!(a.total_wastage.0, 6.0);
        assert_eq!(a.total_retries, 3);
        assert_eq!(a.per_run_wastage, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "merging different task types")]
    fn task_report_merge_rejects_mismatched_types() {
        let mut a = task("a", &[1.0], &[0]);
        a.merge(task("b", &[1.0], &[0]));
    }

    #[test]
    fn method_report_merge_disjoint_appends() {
        let mut a = MethodReport::new("m", 0.5, vec![task("a", &[1.0], &[0])]);
        a.merge(MethodReport::new("m", 0.5, vec![task("b", &[2.0], &[1])]));
        let types: Vec<&str> = a.tasks.iter().map(|t| t.task_type.as_str()).collect();
        assert_eq!(types, vec!["a", "b"]);
        assert_eq!(a.total_wastage_gbs(), 3.0);
    }

    #[test]
    fn method_report_merge_combines_shared_types() {
        let mut a = MethodReport::new("m", 0.5, vec![task("a", &[1.0], &[0])]);
        a.merge(MethodReport::new("m", 0.5, vec![task("a", &[2.0], &[3])]));
        assert_eq!(a.tasks.len(), 1);
        assert_eq!(a.tasks[0].n_scored, 2);
        assert_eq!(a.tasks[0].total_retries, 3);
        assert_eq!(a.total_wastage_gbs(), 3.0);
    }

    #[test]
    fn merged_over_sequence() {
        assert!(MethodReport::merged(std::iter::empty()).is_none());
        let reps = vec![
            MethodReport::new("m", 0.5, vec![task("a", &[1.0], &[0])]),
            MethodReport::new("m", 0.5, vec![task("b", &[2.0], &[0])]),
            MethodReport::new("m", 0.5, vec![task("a", &[4.0], &[1])]),
        ];
        let m = MethodReport::merged(reps).unwrap();
        assert_eq!(m.tasks.len(), 2);
        assert_eq!(m.total_wastage_gbs(), 7.0);
        assert_eq!(m.total_retries(), 1);
    }

    #[test]
    fn export_metrics_labels_method_and_task() {
        let r = MethodReport::new(
            "k-Segments",
            0.5,
            vec![task("a", &[2.0, 4.0], &[1, 0]), task("b", &[6.0], &[2])],
        );
        let mut reg = Registry::new();
        r.export_metrics(&mut reg);
        assert_eq!(reg.counter("replay_scored{method=\"k-Segments\",task=\"a\"}"), 2);
        assert_eq!(reg.counter("replay_retries{method=\"k-Segments\",task=\"b\"}"), 2);
        assert_eq!(
            reg.gauge("replay_avg_wastage_gbs{method=\"k-Segments\",task=\"a\"}"),
            Some(3.0)
        );
        assert_eq!(reg.counter("replay_scored_total{method=\"k-Segments\"}"), 3);
        assert_eq!(reg.gauge("replay_avg_wastage_gbs_mean{method=\"k-Segments\"}"), Some(4.5));
    }

    #[test]
    fn win_counting_with_ties() {
        let m1 = MethodReport::new("m1", 0.5, vec![task("a", &[1.0], &[0]), task("b", &[5.0], &[0])]);
        let m2 = MethodReport::new("m2", 0.5, vec![task("a", &[1.0], &[0]), task("b", &[2.0], &[0])]);
        let wins = count_wins(&[m1, m2]);
        assert_eq!(wins, vec![("m1".to_string(), 1), ("m2".to_string(), 2)]);
    }

    #[test]
    fn win_counting_empty() {
        assert!(count_wins(&[]).is_empty());
    }

    #[test]
    fn table_rendering() {
        let rows = vec![
            ("Default".to_string(), vec![3.0, 2.9]),
            ("k-Segments Selective".to_string(), vec![1.0, 0.8]),
        ];
        let t = render_table("Fig 7a", &[0.25, 0.5], &rows, "GB·s");
        assert!(t.contains("| Default | 3.000 | 2.900 |"));
        assert!(t.contains("25% train"));
        assert!(t.contains("GB·s"));
    }
}
