//! Ensemble — a Sizey-style scored model ensemble (arXiv 2407.16353),
//! the strongest *static* competitor in the follow-up literature.
//!
//! Sizey maintains several cheap peak-memory sub-models per task type,
//! scores each on the sliding training window with a **resource
//! allocation quality** (RAQ) metric that interpolates between failure
//! avoidance and wastage minimization, and predicts with whichever
//! sub-model currently scores best. Our sub-model roster:
//!
//! * **Linear** — `peak ~ input size` regression (the Witt-style model);
//! * **Percentile** — the q-th percentile of the window's peaks
//!   (input-independent, robust to outliers);
//! * **PeakMax** — the window maximum (the conservative envelope).
//!
//! Per window row `i` with prediction `p_i` and observed peak `y_i`:
//!
//! ```text
//! raq_i = α·[p_i ≥ y_i]  +  (1−α)·min(p_i,y_i)/max(p_i,y_i)
//! ```
//!
//! `α` weights failure avoidance (the indicator) against allocation
//! efficiency (1 at a perfect fit, → 0 as over- or under-sizing
//! grows); a sub-model's score is the mean RAQ over the window. The
//! k-Segments paper's §III-B offset mechanism is applied **on top** of
//! the winning sub-model: its largest historical underprediction over
//! the window is added to the prediction, so the selected model is
//! conservative the same way every other learned predictor here is.
//!
//! Failure handling doubles the failed allocation (capped at node
//! max), like PPM Improved and LR.

use std::collections::BTreeMap;

use crate::ml::linreg::LinReg;
use crate::trace::TaskRun;
use crate::units::MemMiB;
use crate::util::stats;

use super::history::HistoryMap;
use super::{Allocation, Defaults, FailureInfo, MemoryPredictor, MIN_ALLOC};

/// The ensemble's sub-model roster, in deterministic tie-break order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubModel {
    Linear,
    Percentile,
    PeakMax,
}

/// All sub-models, in scoring/tie-break order.
pub const SUB_MODELS: [SubModel; 3] = [SubModel::Linear, SubModel::Percentile, SubModel::PeakMax];

impl SubModel {
    pub fn label(&self) -> &'static str {
        match self {
            SubModel::Linear => "linear",
            SubModel::Percentile => "percentile",
            SubModel::PeakMax => "peak-max",
        }
    }
}

/// Tunables.
#[derive(Debug, Clone)]
pub struct EnsembleConfig {
    /// RAQ interpolation weight: 1.0 scores pure failure avoidance,
    /// 0.0 pure allocation efficiency (default 0.5).
    pub alpha: f64,
    /// Percentile used by the [`SubModel::Percentile`] model.
    pub percentile: f64,
    /// Sliding training window (most recent executions kept).
    pub n_hist: usize,
    /// Executions required before the ensemble replaces the default.
    pub min_train: usize,
    /// Retry factor multiplying a failed allocation (default 2).
    pub retry_factor: f64,
    /// Allocation floor (paper §IV-A: 100 MB).
    pub min_alloc: MemMiB,
    /// Node capacity ceiling.
    pub node_max: MemMiB,
    /// Apply the §III-B max-underprediction offset on top of the
    /// winning sub-model (off = the scoring ablation).
    pub use_offsets: bool,
}

impl Default for EnsembleConfig {
    fn default() -> Self {
        EnsembleConfig {
            alpha: 0.5,
            percentile: 95.0,
            n_hist: 64,
            min_train: 2,
            retry_factor: 2.0,
            min_alloc: MIN_ALLOC,
            node_max: MemMiB::from_gib(128.0),
            use_offsets: true,
        }
    }
}

/// One fitted ensemble state for a task type (cached per history
/// version, like the k-Segments fit cache).
#[derive(Debug, Clone)]
pub struct EnsembleFit {
    lr: LinReg,
    percentile_value: f64,
    peak_max: f64,
    /// Mean window RAQ per sub-model, in [`SUB_MODELS`] order.
    pub scores: [f64; 3],
    /// The argmax sub-model (earliest wins ties).
    pub chosen: SubModel,
    /// Max historical underprediction of the chosen sub-model.
    pub offset: f64,
}

impl EnsembleFit {
    fn raw_predict(&self, model: SubModel, x: f64) -> f64 {
        match model {
            SubModel::Linear => self.lr.predict(x),
            SubModel::Percentile => self.percentile_value,
            SubModel::PeakMax => self.peak_max,
        }
    }

    /// Score of the selected sub-model (== the max of `scores`).
    pub fn chosen_score(&self) -> f64 {
        let idx = SUB_MODELS.iter().position(|m| *m == self.chosen).unwrap();
        self.scores[idx]
    }
}

/// The Sizey-style ensemble predictor.
#[derive(Debug, Clone)]
pub struct EnsemblePredictor {
    cfg: EnsembleConfig,
    defaults: Defaults,
    histories: HistoryMap,
    fits: BTreeMap<String, (u64, EnsembleFit)>,
}

/// Mean RAQ of predictions `p` against observed peaks `y`.
fn mean_raq(alpha: f64, p: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), y.len());
    let raq = |p: f64, y: f64| {
        let p = p.max(1e-9);
        let y = y.max(1e-9);
        // within float noise of covering counts as covering: an exact
        // in-window fit must score a full success term, not a coin flip
        let success = if p >= y * (1.0 - 1e-9) { 1.0 } else { 0.0 };
        let efficiency = p.min(y) / p.max(y);
        alpha * success + (1.0 - alpha) * efficiency
    };
    stats::mean(&p.iter().zip(y).map(|(&p, &y)| raq(p, y)).collect::<Vec<_>>())
}

impl EnsemblePredictor {
    pub fn with_config(cfg: EnsembleConfig) -> Self {
        assert!((0.0..=1.0).contains(&cfg.alpha), "alpha in [0,1]");
        assert!(cfg.retry_factor > 1.0, "retry factor must make progress");
        let histories = HistoryMap::new(cfg.n_hist, 1); // peaks only
        EnsemblePredictor { cfg, defaults: Defaults::default(), histories, fits: BTreeMap::new() }
    }

    pub fn new() -> Self {
        Self::with_config(EnsembleConfig::default())
    }

    pub fn config(&self) -> &EnsembleConfig {
        &self.cfg
    }

    /// Current fit for a task (refit lazily when the history advanced);
    /// `None` below `min_train`. Public for observability and the
    /// quality-metric differential tests.
    pub fn fit_for(&mut self, task_type: &str) -> Option<EnsembleFit> {
        let h = self.histories.get(task_type)?;
        if h.len() < self.cfg.min_train {
            return None;
        }
        let version = h.total_seen();
        if let Some((v, fit)) = self.fits.get(task_type) {
            if *v == version {
                return Some(fit.clone());
            }
        }
        let (x, y) = (h.x().to_vec(), h.peaks().to_vec());
        let lr = LinReg::fit(&x, &y);
        let mut fit = EnsembleFit {
            lr,
            percentile_value: stats::percentile(&y, self.cfg.percentile),
            peak_max: y.iter().copied().fold(f64::MIN, f64::max),
            scores: [0.0; 3],
            chosen: SubModel::Linear,
            offset: 0.0,
        };
        for (i, model) in SUB_MODELS.iter().enumerate() {
            let preds: Vec<f64> = x.iter().map(|&xi| fit.raw_predict(*model, xi)).collect();
            fit.scores[i] = mean_raq(self.cfg.alpha, &preds, &y);
        }
        // argmax with earliest-wins tie-break (strict > keeps it stable)
        let mut best = 0usize;
        for i in 1..SUB_MODELS.len() {
            if fit.scores[i] > fit.scores[best] {
                best = i;
            }
        }
        fit.chosen = SUB_MODELS[best];
        if self.cfg.use_offsets {
            fit.offset = x
                .iter()
                .zip(&y)
                .map(|(&xi, &yi)| yi - fit.raw_predict(fit.chosen, xi))
                .fold(0.0f64, f64::max);
        }
        self.fits.insert(task_type.to_string(), (version, fit.clone()));
        Some(fit)
    }
}

impl Default for EnsemblePredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryPredictor for EnsemblePredictor {
    fn name(&self) -> String {
        "Sizey Ensemble".to_string()
    }

    fn prime(&mut self, task_type: &str, default: MemMiB) {
        self.defaults.set(task_type, default);
    }

    fn predict(&mut self, task_type: &str, input_mib: f64) -> Allocation {
        let default = self.defaults.get(task_type);
        let Some(fit) = self.fit_for(task_type) else {
            return Allocation::Static(default);
        };
        let pred = (fit.raw_predict(fit.chosen, input_mib) + fit.offset)
            .max(self.cfg.min_alloc.0)
            .min(self.cfg.node_max.0);
        Allocation::Static(MemMiB(pred))
    }

    fn on_failure(
        &mut self,
        _task_type: &str,
        _input_mib: f64,
        failed: &Allocation,
        _info: &FailureInfo,
    ) -> Allocation {
        Allocation::Static(MemMiB(
            (failed.max_value() * self.cfg.retry_factor).min(self.cfg.node_max.0),
        ))
    }

    fn observe(&mut self, run: &TaskRun) {
        self.histories.push(run);
    }

    fn decision(&mut self, task_type: &str) -> Option<crate::telemetry::DecisionDetail> {
        // fit_for() is cached per history version, so calling it here
        // is deterministically idempotent — predict() is unaffected.
        let window_len = self.histories.get(task_type).map_or(0, |h| h.len());
        let fit = self.fit_for(task_type)?;
        let scores = SUB_MODELS
            .iter()
            .zip(fit.scores)
            .map(|(m, s)| (m.label().to_string(), s))
            .collect();
        Some(crate::telemetry::DecisionDetail {
            model: fit.chosen.label().to_string(),
            scores,
            offset_mib: fit.offset,
            segment_bounds: Vec::new(),
            window_len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::UsageSeries;
    use crate::units::Seconds;

    fn run(input: f64, peak: f64) -> TaskRun {
        TaskRun {
            task_type: "t".into(),
            input_mib: input,
            runtime: Seconds(4.0),
            series: UsageSeries::new(2.0, vec![peak * 0.5, peak]),
            seq: 0,
        }
    }

    #[test]
    fn warmup_returns_default() {
        let mut p = EnsemblePredictor::new();
        p.prime("t", MemMiB(4096.0));
        assert_eq!(p.predict("t", 10.0), Allocation::Static(MemMiB(4096.0)));
        p.observe(&run(10.0, 100.0));
        assert_eq!(p.predict("t", 10.0), Allocation::Static(MemMiB(4096.0)));
    }

    #[test]
    fn linear_workload_selects_linear_submodel() {
        // strongly input-correlated peaks: the regression's in-window
        // RAQ beats both flat models
        let mut p = EnsemblePredictor::new();
        for i in 1..=16 {
            let x = 100.0 * i as f64;
            p.observe(&run(x, 50.0 + 0.5 * x));
        }
        let fit = p.fit_for("t").unwrap();
        assert_eq!(fit.chosen, SubModel::Linear);
        // noiseless -> offset ~ 0, prediction ≈ 50 + 0.5 x
        let Allocation::Static(m) = p.predict("t", 4000.0) else {
            panic!()
        };
        assert!((m.0 - 2050.0).abs() < 1.0, "{m:?}");
    }

    #[test]
    fn uncorrelated_peaks_prefer_flat_submodel() {
        // peaks independent of input with an occasional tall run: the
        // percentile/max models score better than a sloped line fitted
        // to noise
        let mut p = EnsemblePredictor::new();
        let peaks = [100.0, 104.0, 98.0, 101.0, 160.0, 99.0, 103.0, 97.0];
        for (i, &pk) in peaks.iter().enumerate() {
            p.observe(&run(1000.0 + ((i * 7919) % 13) as f64, pk));
        }
        let fit = p.fit_for("t").unwrap();
        assert_ne!(fit.chosen, SubModel::Linear, "scores {:?}", fit.scores);
    }

    #[test]
    fn chosen_is_argmax_of_scores() {
        let mut p = EnsemblePredictor::new();
        for i in 1..=12 {
            p.observe(&run(50.0 * i as f64, 20.0 + 3.0 * i as f64));
        }
        let fit = p.fit_for("t").unwrap();
        let max = fit.scores.iter().copied().fold(f64::MIN, f64::max);
        assert_eq!(fit.chosen_score(), max);
        for s in fit.scores {
            assert!(fit.chosen_score() >= s);
            assert!((0.0..=1.0).contains(&s), "RAQ out of range: {s}");
        }
    }

    #[test]
    fn offset_covers_window_underpredictions() {
        // one outlier the chosen model underpredicts: the offset must
        // lift the prediction to cover every window peak at its own x
        let mut p = EnsemblePredictor::new();
        for i in 1..=10 {
            p.observe(&run(100.0 * i as f64, 100.0));
        }
        p.observe(&run(550.0, 400.0));
        let fit = p.fit_for("t").unwrap();
        assert!(fit.offset > 0.0);
        let Allocation::Static(m) = p.predict("t", 550.0) else {
            panic!()
        };
        assert!(m.0 >= 400.0 - 1e-6, "{m:?}");
    }

    #[test]
    fn offsets_off_disables_lift() {
        let cfg = EnsembleConfig { use_offsets: false, ..EnsembleConfig::default() };
        let mut p = EnsemblePredictor::with_config(cfg);
        for i in 1..=10 {
            p.observe(&run(100.0 * i as f64, 100.0));
        }
        p.observe(&run(550.0, 400.0));
        assert_eq!(p.fit_for("t").unwrap().offset, 0.0);
    }

    #[test]
    fn alpha_extremes_shift_selection_pressure() {
        // α = 1 scores only failure avoidance: the max model (never
        // underpredicts in-window) must win
        let cfg = EnsembleConfig { alpha: 1.0, ..EnsembleConfig::default() };
        let mut p = EnsemblePredictor::with_config(cfg);
        let peaks = [100.0, 140.0, 90.0, 120.0, 80.0, 130.0];
        for (i, &pk) in peaks.iter().enumerate() {
            p.observe(&run(100.0 + i as f64, pk));
        }
        let fit = p.fit_for("t").unwrap();
        assert_eq!(fit.chosen_score(), 1.0, "scores {:?}", fit.scores);
    }

    #[test]
    fn floor_and_cap_apply() {
        let cfg = EnsembleConfig { node_max: MemMiB(500.0), ..EnsembleConfig::default() };
        let mut p = EnsemblePredictor::with_config(cfg);
        for i in 1..=4 {
            p.observe(&run(i as f64 * 100.0, 1.0)); // tiny peaks -> floor
        }
        let Allocation::Static(m) = p.predict("t", 100.0) else {
            panic!()
        };
        assert_eq!(m.0, MIN_ALLOC.0);
        for i in 1..=4 {
            p.observe(&run(i as f64 * 100.0, i as f64 * 400.0)); // slope -> cap
        }
        let Allocation::Static(m) = p.predict("t", 1e7) else {
            panic!()
        };
        assert_eq!(m.0, 500.0);
    }

    #[test]
    fn failure_doubles_capped() {
        let mut p = EnsemblePredictor::new();
        let info = FailureInfo::oom(1.0, 900.0, 1);
        let next = p.on_failure("t", 1.0, &Allocation::Static(MemMiB(600.0)), &info);
        assert_eq!(next, Allocation::Static(MemMiB(1200.0)));
        let huge = p.on_failure("t", 1.0, &Allocation::Static(MemMiB::from_gib(100.0)), &info);
        assert_eq!(huge, Allocation::Static(MemMiB::from_gib(128.0)));
    }

    #[test]
    fn fit_cache_invalidates_on_observation() {
        let mut p = EnsemblePredictor::new();
        for i in 1..=4 {
            p.observe(&run(100.0 * i as f64, 10.0 * i as f64));
        }
        let a = p.fit_for("t").unwrap().peak_max;
        p.observe(&run(900.0, 999.0));
        let b = p.fit_for("t").unwrap().peak_max;
        assert_eq!(a, 40.0);
        assert_eq!(b, 999.0);
    }

    #[test]
    fn name_is_stable() {
        assert_eq!(EnsemblePredictor::new().name(), "Sizey Ensemble");
    }
}
