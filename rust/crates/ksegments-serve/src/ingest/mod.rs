//! Trace ingestion and streaming replay — the path from a real
//! workflow engine's monitoring output into every evaluation surface.
//!
//! The paper evaluates on nf-core traces captured by a Nextflow
//! monitoring extension; everything else in the workspace consumes the
//! [`Trace`] data model. This module closes the gap between the two
//! and removes the requirement that a trace be fully materialized in
//! memory before anything can run:
//!
//! * **parsers** ([`nextflow`]): Nextflow-style `trace.txt` TSV (task
//!   names, `realtime`, `peak_rss`, requested `memory`, input-size
//!   columns, with `KB`/`MB`/`GB` unit suffixes via
//!   [`MemMiB::parse`]) plus per-task monitoring sample CSVs,
//!   normalized into [`TaskRun`]/`UsageSeries`;
//! * **the [`TraceSource`] trait** (defined in
//!   `ksegments_core::source`, re-exported here): a chunked,
//!   rewindable iterator of [`TaskRun`]s in arrival order, with
//!   [`InMemorySource`], [`JsonlReader`] (streaming JSON-lines) and
//!   [`NextflowDirSource`] implementations — consumed by the streaming
//!   replay engine ([`replay_source`]), the scheduler's arrival stream
//!   (`schedule_stream`) and the prediction service
//!   ([`crate::coordinator::ServiceHandle::replay_source`]);
//! * **predictor checkpointing** ([`Checkpoint`]): the fitted
//!   per-task-type state — primed defaults plus the sliding window of
//!   observed runs every predictor derives its fit and offsets from —
//!   serialized as JSONL, so a replay (or a restarted service) can
//!   warm-start instead of re-learning from scratch.
//!
//! CLI entry points: `ksegments ingest <dir>` (normalize a Nextflow
//! trace directory to replay-ordered JSONL) and `ksegments replay
//! --source <path> --method <key> [--checkpoint <path>]`.

pub mod checkpoint;
pub mod jsonl;
pub mod nextflow;
pub mod replay;

pub use checkpoint::Checkpoint;
pub use jsonl::JsonlReader;
pub use nextflow::{read_nextflow_dir, NextflowDirSource};
pub use replay::{replay_source, ReplayConfig, ReplayOutcome};

// The trait and in-memory adapter live in the core layer so the
// scheduler and evaluation grid can consume sources without linking
// the serve stack; re-exported here to keep the historical
// `ksegments::ingest::*` paths intact.
pub use ksegments_core::source::{materialize, InMemorySource, TraceSource, DEFAULT_CHUNK};

use std::path::Path;

use anyhow::{bail, Context, Result};

use ksegments_core::trace::{read_trace_csv, TaskRun, Trace};
use ksegments_core::units::MemMiB;

/// Open a path as a [`TraceSource`] by sniffing its shape: a directory
/// is a Nextflow trace dir (`trace.txt` [+ `samples/`]), a `.jsonl`
/// file streams through [`JsonlReader`], a `.csv` file is read whole
/// (the CSV layout interleaves runs, so it cannot stream) and served
/// from memory.
pub fn open_source(path: &Path) -> Result<Box<dyn TraceSource>> {
    if path.is_dir() {
        return Ok(Box::new(NextflowDirSource::open(path)?));
    }
    match path.extension().and_then(|e| e.to_str()) {
        Some("jsonl") => Ok(Box::new(JsonlReader::open(path)?)),
        Some("csv") => {
            let trace = read_trace_csv(path)
                .with_context(|| format!("reading csv trace {}", path.display()))?;
            Ok(Box::new(InMemorySource::from_trace(&trace)))
        }
        _ => bail!(
            "cannot open {} as a trace source (expected a Nextflow trace \
             directory, a .jsonl file or a .csv file)",
            path.display()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_source_rejects_unknown_shapes() {
        let dir = std::env::temp_dir().join("ksegments_test_ingest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.parquet");
        std::fs::write(&path, b"nope").unwrap();
        assert!(open_source(&path).is_err());
        assert!(open_source(&dir.join("missing.jsonl")).is_err());
    }
}
