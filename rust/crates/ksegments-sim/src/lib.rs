//! Batch evaluation layer of the ksegments workspace: the worker-pool
//! grid and the paper-figure harness.
//!
//! `ksegments-core` scores one predictor over one trace; this crate
//! fans that kernel out and turns the results into the paper's
//! artifacts:
//!
//! * [`parallel`] — the deterministic fixed-pool [`parallel::parallel_map`],
//!   the (method × trace × training-fraction) [`parallel::EvalGrid`]
//!   and the streaming-source bridge [`parallel::eval_sources`].
//!   `workers = 1` and `workers = N` are bit-identical by
//!   construction.
//! * [`figures`] — the method roster (`--method` keys → predictor
//!   factories) and the Fig. 1/4/7/8 regeneration entry points.
//! * [`ablation`] — component knock-out sweeps over the k-Segments
//!   configuration space.
//!
//! Downstream, `ksegments-sched` reuses the roster and pool for its
//! scheduler sweeps, and the `ksegments` facade re-exports these
//! modules under the historical `ksegments::sim` and
//! `ksegments::bench_harness` paths.

pub mod ablation;
pub mod figures;
pub mod parallel;
