//! Prediction provenance — an optional per-decision JSONL audit log.
//!
//! Every predict decision and every OOM escalation can be written as
//! one JSON line, so the wastage of any eval cell can be traced back
//! to the decision that caused it: which ensemble sub-model won (and
//! the full RAQ score vector it beat), where the dynseg change points
//! sat, how much §III-B offset was applied, and how a failure
//! escalated the allocation.
//!
//! Like the trace sinks, the log is observation-only and defers I/O
//! errors: recording never fails mid-run; [`ProvenanceLog::finish`]
//! surfaces the first error at the end.

use std::fs::File;
use std::io::{self, BufWriter, Write};

use crate::util::json::JsonWriter;

/// What a predictor can report about its most recent fit for a task
/// type — the introspection record behind one predict decision.
/// Produced by [`crate::predictors::MemoryPredictor::decision`];
/// static-only models leave the fields they lack empty.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionDetail {
    /// Chosen (sub-)model label, e.g. `"linear"` or `"dynseg-k3"`.
    pub model: String,
    /// Candidate scores, e.g. the ensemble's per-sub-model RAQ values.
    pub scores: Vec<(String, f64)>,
    /// §III-B max-underprediction offset applied on top (MiB).
    pub offset_mib: f64,
    /// Segment upper bounds as fractions of the predicted runtime
    /// (dynseg change points); empty for single-segment models.
    pub segment_bounds: Vec<f64>,
    /// Training-window length the fit was computed from.
    pub window_len: usize,
}

/// JSONL audit writer. One line per record; see DESIGN.md §12 for the
/// schema.
pub struct ProvenanceLog {
    w: Box<dyn Write>,
    records: u64,
    err: Option<io::Error>,
}

impl ProvenanceLog {
    pub fn to_writer(w: Box<dyn Write>) -> ProvenanceLog {
        ProvenanceLog { w, records: 0, err: None }
    }

    /// File-backed log (what `--provenance-out FILE` opens).
    pub fn create(path: &str) -> io::Result<ProvenanceLog> {
        Ok(ProvenanceLog::to_writer(Box::new(BufWriter::new(File::create(path)?))))
    }

    /// Records successfully written so far.
    pub fn len(&self) -> u64 {
        self.records
    }

    pub fn is_empty(&self) -> bool {
        self.records == 0
    }

    /// One predict decision: what was asked, what was allocated, and —
    /// when the predictor exposes it — why.
    #[allow(clippy::too_many_arguments)]
    pub fn record_predict(
        &mut self,
        time_s: f64,
        task_type: &str,
        seq: u64,
        input_mib: f64,
        alloc_peak_mib: f64,
        segments: usize,
        detail: Option<&DecisionDetail>,
    ) {
        if self.err.is_some() {
            return;
        }
        let r = write_predict(
            &mut self.w,
            time_s,
            task_type,
            seq,
            input_mib,
            alloc_peak_mib,
            segments,
            detail,
        );
        match r {
            Ok(()) => self.records += 1,
            Err(e) => self.err = Some(e),
        }
    }

    /// One failure-driven escalation (the scheduler only reports OOM
    /// causes here — blameless kills never change the allocation).
    #[allow(clippy::too_many_arguments)]
    pub fn record_failure(
        &mut self,
        time_s: f64,
        task_type: &str,
        seq: u64,
        attempt: u32,
        cause: &str,
        used_mib: f64,
        new_peak_mib: f64,
    ) {
        if self.err.is_some() {
            return;
        }
        let r = write_failure(
            &mut self.w,
            time_s,
            task_type,
            seq,
            attempt,
            cause,
            used_mib,
            new_peak_mib,
        );
        match r {
            Ok(()) => self.records += 1,
            Err(e) => self.err = Some(e),
        }
    }

    /// Flush and surface the first deferred I/O error, if any.
    pub fn finish(&mut self) -> io::Result<()> {
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        self.w.flush()
    }
}

#[allow(clippy::too_many_arguments)]
fn write_predict(
    w: &mut dyn Write,
    time_s: f64,
    task_type: &str,
    seq: u64,
    input_mib: f64,
    alloc_peak_mib: f64,
    segments: usize,
    detail: Option<&DecisionDetail>,
) -> io::Result<()> {
    let mut j = JsonWriter::new(&mut *w);
    j.begin_obj()?;
    j.field_str("kind", "predict")?;
    j.field_f64("time_s", time_s)?;
    j.field_str("task", task_type)?;
    j.field_u64("seq", seq)?;
    j.field_f64("input_mib", input_mib)?;
    j.field_f64("alloc_mib", alloc_peak_mib)?;
    j.field_u64("segments", segments as u64)?;
    if let Some(d) = detail {
        j.field_str("model", &d.model)?;
        if !d.scores.is_empty() {
            j.key("scores")?;
            j.begin_obj()?;
            for (m, s) in &d.scores {
                j.field_f64(m, *s)?;
            }
            j.end_obj()?;
        }
        j.field_f64("offset_mib", d.offset_mib)?;
        if !d.segment_bounds.is_empty() {
            j.key("segment_bounds")?;
            j.begin_arr()?;
            for b in &d.segment_bounds {
                j.f64_val(*b)?;
            }
            j.end_arr()?;
        }
        j.field_u64("window", d.window_len as u64)?;
    }
    j.end_obj()?;
    drop(j);
    w.write_all(b"\n")
}

#[allow(clippy::too_many_arguments)]
fn write_failure(
    w: &mut dyn Write,
    time_s: f64,
    task_type: &str,
    seq: u64,
    attempt: u32,
    cause: &str,
    used_mib: f64,
    new_peak_mib: f64,
) -> io::Result<()> {
    let mut j = JsonWriter::new(&mut *w);
    j.begin_obj()?;
    j.field_str("kind", "failure")?;
    j.field_f64("time_s", time_s)?;
    j.field_str("task", task_type)?;
    j.field_u64("seq", seq)?;
    j.field_u64("attempt", u64::from(attempt))?;
    j.field_str("cause", cause)?;
    j.field_f64("used_mib", used_mib)?;
    j.field_f64("new_alloc_mib", new_peak_mib)?;
    j.end_obj()?;
    drop(j);
    w.write_all(b"\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Test writer sharing its buffer with the asserting side.
    #[derive(Clone, Default)]
    struct SharedBuf(Rc<RefCell<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.0.borrow_mut().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn records_are_one_json_object_per_line() {
        let buf = SharedBuf::default();
        let mut log = ProvenanceLog::to_writer(Box::new(buf.clone()));
        let detail = DecisionDetail {
            model: "percentile".into(),
            scores: vec![("linear".into(), 0.4), ("percentile".into(), 0.9)],
            offset_mib: 12.5,
            segment_bounds: vec![0.25, 1.0],
            window_len: 8,
        };
        log.record_predict(3.5, "wf/align", 7, 100.0, 2048.0, 4, Some(&detail));
        log.record_predict(4.0, "wf/sort", 8, 50.0, 512.0, 1, None);
        log.record_failure(9.0, "wf/align", 7, 1, "oom", 2100.0, 4096.0);
        log.finish().unwrap();
        assert_eq!(log.len(), 3);

        let raw = buf.0.borrow().clone();
        let text = String::from_utf8(raw).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);

        let p = Json::parse(lines[0]).expect("line 1 valid");
        assert_eq!(p.get("kind").as_str(), Some("predict"));
        assert_eq!(p.get("task").as_str(), Some("wf/align"));
        assert_eq!(p.get("model").as_str(), Some("percentile"));
        assert_eq!(p.get("scores").get("percentile").as_f64(), Some(0.9));
        assert_eq!(p.get("segment_bounds").as_arr().unwrap().len(), 2);
        assert_eq!(p.get("window").as_u64(), Some(8));

        let q = Json::parse(lines[1]).expect("line 2 valid");
        assert_eq!(q.get("model"), &Json::Null, "no detail -> no model field");
        assert_eq!(q.get("alloc_mib").as_f64(), Some(512.0));

        let f = Json::parse(lines[2]).expect("line 3 valid");
        assert_eq!(f.get("kind").as_str(), Some("failure"));
        assert_eq!(f.get("cause").as_str(), Some("oom"));
        assert_eq!(f.get("new_alloc_mib").as_f64(), Some(4096.0));
    }

    #[test]
    fn empty_log_finishes_clean() {
        let mut log = ProvenanceLog::to_writer(Box::new(Vec::new()));
        assert!(log.is_empty());
        log.finish().unwrap();
    }
}
