//! Throughput-vs-policy tables: the scheduling counterpart of the
//! Fig. 7 harness — what does segment-wise packing buy a cluster
//! operator at different load levels?
//!
//! Sweeps (policy × predictor × arrival rate) at a fixed cluster via
//! [`SchedGrid`] and renders makespan, mean queue wait, peak
//! concurrency and utilization as markdown tables. Shared by the CLI
//! (`ksegments schedule --sweep`) and `ksegments report`.

use crate::bench_harness::figures::{makers_for_keys, FitterChoice};
use crate::cluster::NodeSpec;
use crate::predictors::MemoryPredictor;
use crate::sched::{ReservationPolicy, SchedConfig, SchedGrid, SchedGridResults};
use crate::sim::PredictorFactory;
use crate::units::MemMiB;
use crate::workload::{eager_workflow, generate_workflow_trace};

/// One sweep's rendered axes plus the raw per-cell reports.
pub struct ThroughputResults {
    pub interarrivals: Vec<f64>,
    pub policies: Vec<ReservationPolicy>,
    pub methods: Vec<String>,
    pub results: SchedGridResults,
}

/// `--method` keys of the sweep roster: the two time-varying methods
/// (whose Dynamic allocations the segment-wise policy exploits —
/// k-Segments and KS+ DynSeg) and the strongest static competitors
/// (PPM Improved, Sizey Ensemble). Every method runs under both
/// policies — static allocations are unaffected by the policy choice,
/// which makes the static rows the control.
pub const THROUGHPUT_KEYS: &[&str] =
    &["ksegments-selective", "dynseg", "ppm-improved", "ensemble"];

/// The sweep roster as thread-safe factories, in [`THROUGHPUT_KEYS`]
/// order.
pub fn throughput_makers() -> Vec<PredictorFactory> {
    makers_for_keys(THROUGHPUT_KEYS, FitterChoice::Native)
}

/// Run the throughput sweep on the eager-like workflow: 2 policies ×
/// 4 predictors × the given mean inter-arrival gaps, on a small
/// cluster sized so that packing pressure is real (2 × 32 GiB).
pub fn run_throughput(seed: u64, interarrivals: &[f64], workers: usize) -> ThroughputResults {
    let traces = vec![generate_workflow_trace(&eager_workflow(), seed)];
    let policies = vec![ReservationPolicy::StaticPeak, ReservationPolicy::SegmentWise];
    let base = SchedConfig { seed, training_frac: 0.5, ..SchedConfig::default() };
    let node = NodeSpec { mem: MemMiB::from_gib(32.0), cores: 32 };
    let grid = SchedGrid::new(
        policies.clone(),
        throughput_makers(),
        &traces,
        vec![2],
        interarrivals.to_vec(),
    )
    .with_base(base, node);
    let results = grid.run(workers);
    // row labels in THROUGHPUT_KEYS order (display names, not keys)
    let methods = throughput_makers().iter().map(|mk| mk().name()).collect();
    ThroughputResults { interarrivals: interarrivals.to_vec(), policies, methods, results }
}

impl ThroughputResults {
    fn cell(&self, p: usize, m: usize, a: usize) -> &crate::sched::SchedReport {
        self.results.report(p, m, 0, a).expect("cell present")
    }

    fn render_metric(
        &self,
        title: &str,
        unit: &str,
        get: impl Fn(&crate::sched::SchedReport) -> f64,
    ) -> String {
        let mut out = format!("## {title}\n\n| policy · method |");
        for ia in &self.interarrivals {
            out.push_str(&format!(" ia={ia:.0}s |"));
        }
        out.push_str("\n|---|");
        for _ in &self.interarrivals {
            out.push_str("---|");
        }
        out.push('\n');
        for (p, policy) in self.policies.iter().enumerate() {
            for (m, method) in self.methods.iter().enumerate() {
                out.push_str(&format!("| {} · {} |", policy.name(), method));
                for a in 0..self.interarrivals.len() {
                    out.push_str(&format!(" {:.3} |", get(self.cell(p, m, a))));
                }
                out.push('\n');
            }
        }
        out.push_str(&format!("\n(unit: {unit})\n"));
        out
    }

    /// The headline table: makespan per policy × arrival rate.
    pub fn render_makespan(&self) -> String {
        self.render_metric(
            "Throughput — makespan by policy × arrival rate",
            "seconds until the last task completes",
            |r| r.makespan.0,
        )
    }

    pub fn render_queue_wait(&self) -> String {
        self.render_metric(
            "Throughput — mean queue wait by policy × arrival rate",
            "seconds from enqueue to placement, mean over admissions",
            |r| r.mean_queue_wait_s(),
        )
    }

    pub fn render_packing(&self) -> String {
        self.render_metric(
            "Throughput — peak concurrent tasks by policy × arrival rate",
            "max tasks co-located on the cluster",
            |r| r.peak_running as f64,
        )
    }

    /// One-line summary per cell, for the CLI.
    pub fn render_summaries(&self) -> String {
        let mut out = String::new();
        for r in &self.results.reports {
            out.push_str(&r.summary());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_renders_all_tables() {
        // one arrival rate keeps this test cheap; report/CLI sweep more
        let t = run_throughput(42, &[2.0], 2);
        assert_eq!(t.methods.len(), THROUGHPUT_KEYS.len());
        let mk = t.render_makespan();
        assert!(mk.contains("static-peak · k-Segments Selective"));
        assert!(mk.contains("segment-wise · PPM Improved"));
        assert!(mk.contains("segment-wise · KS+ DynSeg Selective"));
        assert!(mk.contains("static-peak · Sizey Ensemble"));
        assert!(mk.contains("ia=2s"));
        assert!(t.render_queue_wait().contains("queue wait"));
        assert!(t.render_packing().contains("peak concurrent"));
        assert!(!t.render_summaries().is_empty());
        // every task completes in every cell
        for r in &t.results.reports {
            assert_eq!(r.completed, r.submitted);
        }
    }
}
