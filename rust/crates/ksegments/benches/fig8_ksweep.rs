//! `cargo bench --bench fig8_ksweep` — regenerates the paper's Fig. 8:
//! memory wastage as a function of the segment count k for the
//! Qualimap-like task (8a, zigzag with local optima) and the
//! AdapterRemoval-like task (8b, monotone-ish decrease), at 50 %
//! training data, and times the sweep.

use ksegments::bench_harness::{run_fig8, time_once, FitterChoice};

fn main() {
    println!("== fig8 benchmark (seed 42, 50% training, k = 1..15) ==\n");
    let ks: Vec<usize> = (1..=15).collect();
    let workers = ksegments::sim::default_workers();
    for task in ["eager/qualimap", "eager/adapter_removal"] {
        let (r, _dt) = time_once(&format!("fig8 sweep {task} (workers={workers})"), || {
            run_fig8(42, FitterChoice::Native, task, &ks, workers)
        });
        println!("\n{}", r.render());
    }
}
