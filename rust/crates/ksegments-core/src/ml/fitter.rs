//! The k-Segments fit: trait + native implementation.
//!
//! `KsegFitter` abstracts over the two backends that can produce a
//! [`FitResult`] from task history:
//!
//! * [`NativeFitter`] (here): the f64 mirror of the JAX fit graph —
//!   used by tests as the oracle and wherever artifact padding does
//!   not fit;
//! * [`crate::runtime::XlaFitter`]: executes the AOT-lowered
//!   JAX + Pallas module (`artifacts/ksegments_fit_k{K}.hlo.txt`)
//!   through the PJRT CPU client — the production online-learning path.

use crate::ml::linreg::LinReg;
use crate::ml::segmentation::seg_peaks;

/// Training view of a task's history: parallel arrays, one row per
/// historical execution (already resampled to a common length).
#[derive(Debug, Clone, Default)]
pub struct FitInput {
    /// Total input size per execution (MiB).
    pub x: Vec<f64>,
    /// Actual runtime per execution (s).
    pub runtime: Vec<f64>,
    /// Peak-preserving resampled usage series, all rows the same length.
    pub series: Vec<Vec<f64>>,
}

impl FitInput {
    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.x.len() != self.runtime.len() || self.x.len() != self.series.len() {
            return Err(format!(
                "row mismatch: x={} runtime={} series={}",
                self.x.len(),
                self.runtime.len(),
                self.series.len()
            ));
        }
        if let Some(first) = self.series.first() {
            if self.series.iter().any(|s| s.len() != first.len()) {
                return Err("ragged series rows".into());
            }
            if first.is_empty() {
                return Err("empty series rows".into());
            }
        }
        Ok(())
    }
}

/// Fitted k-Segments model (paper §III-B outputs).
#[derive(Debug, Clone, PartialEq)]
pub struct FitResult {
    /// Runtime regression: input size (MiB) → runtime (s).
    pub rt: LinReg,
    /// Largest historical runtime OVERprediction — subtracted at predict
    /// time so the runtime is under-predicted (paper: "negative offset").
    pub rt_offset: f64,
    /// Per-segment peak regressions: input size (MiB) → segment peak (MiB).
    pub seg: Vec<LinReg>,
    /// Largest historical segment UNDERprediction — added to each
    /// segment's intercept at predict time.
    pub seg_off: Vec<f64>,
}

impl FitResult {
    pub fn k(&self) -> usize {
        self.seg.len()
    }

    /// Offset runtime prediction (may be clamped by the caller).
    pub fn predict_runtime(&self, x: f64) -> f64 {
        self.rt.predict(x) - self.rt_offset
    }

    /// Offset per-segment memory predictions (raw, before monotone
    /// clamping / flooring — that happens in the predictor).
    pub fn predict_segments(&self, x: f64) -> Vec<f64> {
        self.seg
            .iter()
            .zip(&self.seg_off)
            .map(|(lr, off)| lr.predict(x) + off)
            .collect()
    }
}

/// A backend that fits the k-Segments model from task history.
pub trait KsegFitter: Send {
    /// Human-readable backend name (for logs / reports).
    fn backend(&self) -> &'static str;

    /// Fit with `k` segments. `input` must validate; `k >= 1` and the
    /// series length must be ≥ k.
    fn fit(&mut self, input: &FitInput, k: usize) -> FitResult;
}

/// Pure-rust fitter: line-for-line mirror of `python/compile/model.py`.
#[derive(Debug, Clone, Default)]
pub struct NativeFitter;

impl KsegFitter for NativeFitter {
    fn backend(&self) -> &'static str {
        "native"
    }

    fn fit(&mut self, input: &FitInput, k: usize) -> FitResult {
        input.validate().expect("invalid fit input");
        assert!(k >= 1, "k must be >= 1");
        let n = input.n();
        assert!(n > 0, "cannot fit on empty history");

        // Y** per row: [n, k] segment peaks.
        let peaks: Vec<Vec<f64>> = input.series.iter().map(|s| seg_peaks(s, k)).collect();

        // Runtime model + conservative offset.
        let rt = LinReg::fit(&input.x, &input.runtime);
        let mut rt_offset = 0.0f64;
        for (&xi, &ri) in input.x.iter().zip(&input.runtime) {
            rt_offset = rt_offset.max(rt.predict(xi) - ri);
        }

        // k segment models + per-segment max-underprediction offsets.
        let mut seg = Vec::with_capacity(k);
        let mut seg_off = Vec::with_capacity(k);
        let mut col = vec![0.0; n];
        for s in 0..k {
            for (row, p) in peaks.iter().enumerate() {
                col[row] = p[s];
            }
            let lr = LinReg::fit(&input.x, &col);
            let mut off = 0.0f64;
            for (&xi, &yi) in input.x.iter().zip(col.iter()) {
                off = off.max(yi - lr.predict(xi));
            }
            seg.push(lr);
            seg_off.push(off);
        }

        FitResult { rt, rt_offset, seg, seg_off }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic linear workload: runtime = 30 + 0.02 x, series ramps to
    /// peak 50 + 0.5 x.
    fn synth(n: usize, t: usize) -> FitInput {
        let mut input = FitInput::default();
        for i in 0..n {
            let x = 100.0 + 40.0 * i as f64;
            let peak = 50.0 + 0.5 * x;
            let series: Vec<f64> = (0..t)
                .map(|j| peak * ((j + 1) as f64 / t as f64).sqrt())
                .collect();
            input.x.push(x);
            input.runtime.push(30.0 + 0.02 * x);
            input.series.push(series);
        }
        input
    }

    #[test]
    fn recovers_linear_structure() {
        let input = synth(16, 64);
        let fit = NativeFitter.fit(&input, 4);
        assert_eq!(fit.k(), 4);
        // runtime model exact on noiseless data
        assert!((fit.rt.a - 30.0).abs() < 1e-6, "{:?}", fit.rt);
        assert!((fit.rt.b - 0.02).abs() < 1e-9);
        assert!(fit.rt_offset < 1e-6);
        // last segment's peak is the global peak: 50 + 0.5 x
        let last = fit.seg.last().unwrap();
        assert!((last.a - 50.0).abs() < 1e-6);
        assert!((last.b - 0.5).abs() < 1e-9);
        // noiseless -> offsets ~ 0
        assert!(fit.seg_off.iter().all(|&o| o < 1e-6));
        // segment peaks increase over time for a ramp profile
        let preds = fit.predict_segments(500.0);
        assert!(preds.windows(2).all(|w| w[1] >= w[0] - 1e-9), "{preds:?}");
    }

    #[test]
    fn offsets_cover_training_rows() {
        // add an outlier row that the regression underpredicts
        let mut input = synth(8, 16);
        input.x.push(500.0);
        input.runtime.push(10.0);
        input.series.push(vec![10_000.0; 16]);
        let fit = NativeFitter.fit(&input, 4);
        for (row, &xi) in input.x.iter().enumerate() {
            let preds = fit.predict_segments(xi);
            let peaks = seg_peaks(&input.series[row], 4);
            for (p, pk) in preds.iter().zip(peaks) {
                assert!(
                    *p >= pk - 1e-6,
                    "row {row}: predicted {p} < historical peak {pk}"
                );
            }
        }
    }

    #[test]
    fn runtime_offset_is_conservative() {
        let mut input = synth(8, 16);
        // one run much faster than the line -> forces rt_offset > 0
        input.x.push(900.0);
        input.runtime.push(1.0);
        input.series.push(vec![1.0; 16]);
        let fit = NativeFitter.fit(&input, 2);
        assert!(fit.rt_offset > 0.0);
        for (&xi, &ri) in input.x.iter().zip(&input.runtime) {
            assert!(fit.predict_runtime(xi) <= ri + 1e-9);
        }
    }

    #[test]
    fn single_history_row_mean_fallback() {
        let input = FitInput {
            x: vec![100.0],
            runtime: vec![60.0],
            series: vec![vec![10.0, 50.0, 30.0, 20.0]],
        };
        let fit = NativeFitter.fit(&input, 2);
        assert_eq!(fit.rt.b, 0.0);
        assert_eq!(fit.rt.a, 60.0);
        assert_eq!(fit.seg[0].a, 50.0); // max of first half
        assert_eq!(fit.seg[1].a, 30.0);
    }

    #[test]
    fn validate_catches_ragged_input() {
        let input = FitInput {
            x: vec![1.0, 2.0],
            runtime: vec![1.0, 2.0],
            series: vec![vec![1.0, 2.0], vec![1.0]],
        };
        assert!(input.validate().is_err());
    }

    #[test]
    fn validate_catches_row_mismatch() {
        let input = FitInput {
            x: vec![1.0],
            runtime: vec![1.0, 2.0],
            series: vec![vec![1.0]],
        };
        assert!(input.validate().is_err());
    }
}
