//! `layering`: the PR-8 crate DAG must hold — `ksegments-core`
//! depends on nothing internal, `sim`/`sched`/`serve` are peers that
//! depend only on core, the `ksegments` facade sits on all four, the
//! CLI on the facade, and the linter on nothing. Enforced twice:
//! `use`/path references in non-test `.rs` code (this [`Rule`]), and
//! `[dependencies]` entries in each crate manifest
//! ([`check_manifest`], driven by the engine). `[dev-dependencies]`
//! are exempt — the core→facade doc-test cycle is sanctioned.

use super::{allowed_deps, FileCtx, Rule};
use crate::diag::Diagnostic;
use crate::lexer::find_word;

/// Internal crates as they appear in `.rs` paths (underscored).
const CRATE_IDENTS: &[(&str, &str)] = &[
    ("ksegments_core", "ksegments-core"),
    ("ksegments_sim", "ksegments-sim"),
    ("ksegments_sched", "ksegments-sched"),
    ("ksegments_serve", "ksegments-serve"),
];

fn dep_ok(krate: &str, dep: &str) -> bool {
    dep == krate || allowed_deps(krate).is_some_and(|deps| deps.contains(&dep))
}

fn violation(ctx: &FileCtx<'_>, line: usize, dep: &str) -> Diagnostic {
    Diagnostic {
        rule: "layering",
        path: ctx.display_path.to_string(),
        line,
        message: format!(
            "{} must not reference {dep}: the crate DAG allows {:?} \
             (DESIGN.md \u{a7}13)",
            ctx.krate,
            allowed_deps(ctx.krate).unwrap_or(&[])
        ),
    }
}

pub struct Layering;

impl Rule for Layering {
    fn id(&self) -> &'static str {
        "layering"
    }

    fn check(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        for (idx, line) in ctx.file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for (ident, dep) in CRATE_IDENTS {
                if find_word(&line.code, ident).is_some() && !dep_ok(ctx.krate, dep) {
                    out.push(violation(ctx, idx + 1, dep));
                }
            }
            // the facade crate's ident is a prefix of the others, so
            // match `ksegments::` paths explicitly — and only as a
            // path ROOT: `predictors::ksegments::…` is core's own
            // k-segments module, not the facade crate
            let mut from = 0;
            while let Some(off) = find_word(&line.code[from..], "ksegments") {
                let pos = from + off;
                let after = &line.code[pos + "ksegments".len()..];
                let nested = pos > 0 && line.code.as_bytes()[pos - 1] == b':';
                if after.starts_with("::") && !nested && !dep_ok(ctx.krate, "ksegments") {
                    out.push(violation(ctx, idx + 1, "ksegments"));
                }
                from = pos + "ksegments".len();
            }
        }
    }
}

/// Check one crate manifest's `[dependencies]` section against the
/// DAG. `display_path` names the Cargo.toml in diagnostics.
pub fn check_manifest(krate: &str, display_path: &str, toml_src: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut in_deps = false;
    for (idx, raw) in toml_src.lines().enumerate() {
        let line = raw.trim();
        if line.starts_with('[') {
            in_deps = line == "[dependencies]";
            continue;
        }
        if !in_deps || line.starts_with('#') {
            continue;
        }
        // `ksegments-core.workspace = true` or `ksegments-core = {…}`
        let key: String = line
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if key.starts_with("ksegments") && !dep_ok(krate, &key) {
            out.push(Diagnostic {
                rule: "layering",
                path: display_path.to_string(),
                line: idx + 1,
                message: format!(
                    "{krate} must not depend on {key}: the crate DAG allows {:?} \
                     (DESIGN.md \u{a7}13)",
                    allowed_deps(krate).unwrap_or(&[])
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_sideways_edge_is_flagged() {
        let toml = "[package]\nname = \"ksegments-sched\"\n\n[dependencies]\n\
                    ksegments-core.workspace = true\nksegments-sim.workspace = true\n";
        let diags = check_manifest("ksegments-sched", "x/Cargo.toml", toml);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 6);
        assert!(diags[0].message.contains("ksegments-sim"));
    }

    #[test]
    fn manifest_dev_deps_are_exempt() {
        let toml = "[dependencies]\nksegments-core.workspace = true\n\n\
                    [dev-dependencies]\nksegments.workspace = true\n";
        assert!(check_manifest("ksegments-sim", "x/Cargo.toml", toml).is_empty());
    }
}
