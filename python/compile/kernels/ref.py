"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: ``pytest python/tests`` asserts the
Pallas kernels (run under ``interpret=True``) match these implementations
bit-for-bit (up to float tolerance) over a hypothesis-driven sweep of
shapes and dtypes.  The rust-native implementation in ``rust/src/ml``
mirrors the same math in f64 and is differential-tested against the AOT
artifact produced from the kernel path.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["segment_bounds", "segpeaks_ref", "linfit_ref", "fit_ref"]


def segment_bounds(t: int, k: int) -> list[tuple[int, int]]:
    """Paper §III-B change points: ``i = floor(T/k)``; segments are
    ``[s*i, (s+1)*i)`` for ``s < k-1`` and the last segment absorbs the
    remainder ``[(k-1)*i, T)``.

    Requires ``k <= t`` so every segment is non-empty.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if t < k:
        raise ValueError(f"series length {t} shorter than k={k}")
    i = t // k
    bounds = [(s * i, (s + 1) * i) for s in range(k - 1)]
    bounds.append(((k - 1) * i, t))
    return bounds


def segpeaks_ref(y: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-segment peak extraction: ``Y** = (max(s_1), ..., max(s_k))``.

    y: [N, T] batched memory-usage series.  Returns [N, k] peaks.
    """
    n, t = y.shape
    peaks = []
    for lo, hi in segment_bounds(t, k):
        peaks.append(jnp.max(y[:, lo:hi], axis=1))
    return jnp.stack(peaks, axis=1)


def linfit_ref(x: jnp.ndarray, targets: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Masked batched simple linear regression (closed form).

    Fits ``target[:, m] ~ a_m + b_m * x`` by least squares over rows where
    ``valid == 1``.  Degenerate designs (fewer than 2 valid rows, or all
    x identical) fall back to slope 0 / intercept = masked mean, which is
    what an online predictor should do with a single observation.

    x: [N], targets: [N, M], valid: [N] in {0, 1}.  Returns [M, 2] rows
    of ``(intercept a, slope b)``.
    """
    # Centered formulation: b = cov_w(x, y) / var_w(x).  The uncentered
    # normal equations (sw*sxy - sx*sy) cancel catastrophically in f32
    # when x values are large and close together; centering first keeps
    # the subtraction exact-ish.  The rust mirror (rust/src/ml/linreg.rs)
    # uses the identical formulation.
    w = valid.astype(targets.dtype)
    sw = jnp.sum(w)
    sw_safe = jnp.maximum(sw, 1.0)
    xbar = jnp.sum(w * x) / sw_safe
    ybar = jnp.sum(w[:, None] * targets, axis=0) / sw_safe  # [M]
    xc = x - xbar
    varx = jnp.sum(w * xc * xc)
    cov = jnp.sum((w * xc)[:, None] * targets, axis=0)  # [M] (ybar term cancels)

    # Degenerate when <2 valid rows or x (relatively) constant.
    thresh = 1e-7 * sw_safe * (xbar * xbar + 1.0)
    safe = (sw >= 1.5) & (varx > thresh)
    b = jnp.where(safe, cov / jnp.where(safe, varx, 1.0), 0.0)
    a = ybar - b * xbar
    return jnp.stack([a, b], axis=1)


def fit_ref(x, y_series, runtime, valid, k: int):
    """Full k-Segments fit (paper §III-B), pure jnp.

    Returns (rt_coef [2], rt_offset scalar, seg_coef [k,2], seg_off [k]).

    * runtime model: LR(input size -> runtime); offset = largest
      historical OVERprediction (subtracted at predict time so the
      runtime is under-predicted, per §III-B).
    * segment models: LR(input size -> segment peak); offset = largest
      historical UNDERprediction (added to the intercept at predict time
      so memory is over-predicted).
    """
    peaks = segpeaks_ref(y_series, k)  # [N, k]
    w = valid.astype(y_series.dtype)

    rt_coef = linfit_ref(x, runtime[:, None], valid)[0]  # [2]
    rt_pred = rt_coef[0] + rt_coef[1] * x
    # overprediction = predicted - actual; only valid rows contribute.
    rt_over = jnp.max(jnp.where(w > 0, rt_pred - runtime, -jnp.inf))
    rt_offset = jnp.maximum(rt_over, 0.0)

    seg_coef = linfit_ref(x, peaks, valid)  # [k, 2]
    seg_pred = seg_coef[:, 0][None, :] + seg_coef[:, 1][None, :] * x[:, None]
    # underprediction = actual - predicted
    under = jnp.where(w[:, None] > 0, peaks - seg_pred, -jnp.inf)
    seg_off = jnp.maximum(jnp.max(under, axis=0), 0.0)  # [k]

    return rt_coef, rt_offset, seg_coef, seg_off
