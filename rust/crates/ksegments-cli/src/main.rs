//! `ksegments` CLI — leader entrypoint for trace generation, the
//! evaluation harness, figure regeneration, and the prediction service
//! demo.
//!
//! Subcommands (run with no args for help):
//!
//! ```text
//! ksegments generate  --workflow eager|sarek --seed N --out FILE [--format jsonl|csv]
//! ksegments simulate  --method NAME --frac F [--seed N] [--xla]
//! ksegments fig7      [--seed N] [--xla]          # Fig. 7a/7b/7c + headline
//! ksegments fig8      [--seed N] [--xla]          # wastage vs k, both tasks
//! ksegments fig4      [--seed N] [--xla]          # step-function example
//! ksegments fig1      [--seed N]                  # optimization potential
//! ksegments validate-runtime                      # XLA fit vs native fit
//! ksegments serve     [--seed N]                  # prediction-service demo
//! ksegments schedule  [--nodes N] [--arrival S] [--policy P]  # cluster scheduler
//!                     [--fail-rate R] [--preempt] [--autoscale]
//!                     [--trace-out F] [--provenance-out F] [--metrics-out F]
//! ksegments bench     [--area A]... [--out-dir D] # BENCH_<area>.json snapshots
//! ksegments bench-sched [--out FILE]              # BENCH_sched.json snapshot
//! ksegments ingest    DIR [--out FILE]            # Nextflow trace -> jsonl
//! ksegments replay    --source PATH --method M    # streaming replay
//! ksegments serve-tcp [--addr H:P] [--shards N]   # TCP prediction service
//! ksegments loadgen   --source PATH [--qps Q]     # TCP load generator
//! ```
//!
//! (Arg parsing is hand-rolled: the offline crate cache has no clap;
//! the parser and the `schedule` argument bundle live in [`args`].)

mod args;

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use crate::args::{methods_arg, parse_sched_cli, Args};

use ksegments::bench_harness::{run_fig1, run_fig4, run_fig7_selected, run_fig8, FitterChoice};
use ksegments::coordinator::ShardedPredictionService;
use ksegments::ml::fitter::{KsegFitter, NativeFitter};
use ksegments::predictors::ksegments::{KSegmentsPredictor, RetryStrategy};
use ksegments::predictors::MemoryPredictor;
use ksegments::runtime::XlaFitter;
use ksegments::sim::{simulate_trace, SimConfig};
use ksegments::trace::{write_trace_csv, write_trace_jsonl, write_trace_jsonl_ordered};
use ksegments::workload::{eager_workflow, generate_workflow_trace, sarek_workflow};

const USAGE: &str = "\
ksegments — dynamic memory prediction for scientific workflow tasks
(reproduction of Bader et al., 2023)

USAGE:
  ksegments generate  --workflow eager|sarek [--seed N] --out FILE [--format jsonl|csv]
  ksegments simulate  --method METHOD [--frac F] [--seed N] [--workflow W] [--xla]
  ksegments fig7      [--seed N] [--xla] [--workers N] [--method SEL]
  ksegments fig8      [--seed N] [--xla] [--workers N]
  ksegments fig4      [--seed N] [--xla]
  ksegments fig1      [--seed N]
  ksegments ablate    [--seed N] [--workers N]
  ksegments report    [--seed N] [--xla] [--out FILE] [--workers N] [--method SEL]
  ksegments validate-runtime
  ksegments serve     [--seed N] [--shards N] [--workers N] [--source PATH]
                      [--trace-out FILE] [--metrics-out FILE]
  ksegments serve-tcp [--addr HOST:PORT] [--shards N] [--method METHOD]
                      [--max-frame BYTES] [--checkpoint FILE]
                      [--checkpoint-out FILE] [--port-file FILE]
                      [--metrics-out FILE]
  ksegments loadgen   --source PATH [--addr HOST:PORT] [--connections N]
                      [--qps Q] [--duration D] [--shutdown]
                      [--shards N] [--method METHOD] [--bench-out FILE]
  ksegments schedule  [--nodes N] [--node-gib G] [--arrival SECS]
                      [--policy static|segment|both] [--method METHOD]
                      [--frac F] [--seed N] [--workflow W]
                      [--fail-rate R] [--preempt] [--autoscale [LAG]]
                      [--dag W --instances N] [--sweep] [--fail-sweep]
                      [--workers N] [--trace-out FILE]
                      [--provenance-out FILE] [--metrics-out FILE]
  ksegments bench     [--area sched|replay|grid|service]... [--seed N]
                      [--workers N] [--out-dir DIR]
  ksegments bench-sched [--seed N] [--workers N] [--out FILE]
  ksegments ingest    DIR [--out FILE] [--format jsonl|csv]
  ksegments replay    --source PATH [--method SEL] [--workers N]
                      [--checkpoint FILE] [--checkpoint-out FILE]
                      [--warmup N] [--chunk N] [--trace-out FILE]
                      [--metrics-out FILE]

METHODS: default | ppm | ppm-improved | lr | ksegments-selective |
         ksegments-partial | ksegments-adaptive | ensemble | dynseg |
         condor

For fig7/report, --method SEL selects the comparison rows: "all" (the
default — the whole predictor zoo) or a comma list of method names,
e.g. --method ksegments-selective,ensemble,dynseg.

--workers defaults to the available cores. For fig7/fig8/ablate/report
it sizes the evaluation pool and results are identical for any worker
count; for serve it is the number of SWMS client threads driving demo
traffic. --shards is the number of model threads the prediction
service partitions task types across (default 4).

schedule runs the discrete-event cluster scheduler: tasks arrive as a
timed stream (mean inter-arrival --arrival seconds, exponential) onto
--nodes nodes of --node-gib GiB each, reserved per --policy
(static-peak vs segment-wise step functions; both = comparison).
--sweep renders the throughput tables over several arrival rates on
the parallel grid instead. --dag W switches to dependency-gated
workflow mode: --instances N concurrent executions of workflow W's
DAG, each task released only when its parents complete (OOM retries
of a parent delay its whole subtree); combined with --sweep it
renders the workflow-makespan tables over instance counts.

schedule also injects cluster adversity: --fail-rate R kills a random
up node R times per second on average (resident tasks requeue
blamelessly — same allocation, no predictor escalation), --preempt
lets high-priority arrivals evict low-priority tasks, --autoscale
grows/shrinks the roster with the queue (optional provisioning LAG in
seconds, default 30). --fail-sweep renders the failure-rate x
autoscale-lag tables on the parallel grid.

Observability (off by default; enabling it never changes results):
--trace-out FILE writes a Chrome/Perfetto trace (schedule: simulated
task spans; replay: per-run instants; serve: wall-clock wakeup spans
— open at https://ui.perfetto.dev), --provenance-out FILE (schedule)
writes one JSONL record per prediction/failure escalation with the
chosen sub-model and scores, --metrics-out FILE writes a metrics
snapshot (Prometheus text for .prom/.txt, JSON otherwise). With
--policy both, trace/provenance record the first policy only.

bench runs the perf areas (sched | replay | grid | service; repeat
--area for several) and writes one BENCH_<area>.json snapshot each to
--out-dir — the committed perf trajectory CI diffs against.
bench-sched is the sched area under its original name (engine
events/s).

serve-tcp binds the prediction service behind the length-prefixed
JSONL TCP protocol (DESIGN.md §14): predict / complete /
report_failure / replay / stats / shutdown frames, pipelined per
connection with in-order responses. --addr defaults to 127.0.0.1:0
(ephemeral; the bound address is printed, and --port-file FILE writes
the port for scripts). --checkpoint warm-starts the predictors;
--checkpoint-out saves the (restored + newly observed) state on
drain, byte-identical to an uninterrupted run. The process exits when
a client sends a shutdown frame.

loadgen replays a trace source against a server over --connections
TCP connections at an aggregate --qps (0 = unthrottled), reporting
p50/p99/p999 predict latency and served throughput. --duration D
(e.g. 2s, 500ms) rewinds the source until D has elapsed; --shutdown
drains the server afterwards; --bench-out FILE writes a
BENCH_serve.json perf snapshot. Without --addr it spawns an
in-process serve-tcp (--shards/--method apply) and drains it when
done. Task types are routed to connections with the service's own
shard hash, so a TCP replay's predictions and final stats are
bit-identical to the in-process replay of the same source.

ingest normalizes a Nextflow trace directory (trace.txt [+ samples/])
into the crate's replay-ordered JSONL trace format.

replay streams a trace source (a .jsonl/.csv file or a Nextflow trace
dir) through a predictor online, sharded by task type across --workers
threads (results are bit-identical for any worker count). --checkpoint
warm-starts from a saved predictor state; --checkpoint-out persists
the state after the replay; --warmup N (default 2) is the per-type
unscored warm-up for previously unseen task types. serve --source
replays the same sources through the sharded prediction service.
";

fn workflow_by_name(name: &str) -> Result<ksegments::workload::WorkflowSpec> {
    match name {
        "eager" => Ok(eager_workflow()),
        "sarek" => Ok(sarek_workflow()),
        other => bail!("unknown workflow {other:?} (eager|sarek)"),
    }
}

fn method_by_name(name: &str, choice: FitterChoice) -> Result<Box<dyn MemoryPredictor>> {
    // One source of truth for key → predictor: the bench harness
    // roster (the same construction the fig7 grid and the scheduling
    // sweep use), so every CLI surface sees the same zoo.
    ksegments::bench_harness::make_method(name, choice)
        .ok_or_else(|| anyhow!("unknown method {name:?} (see METHODS in --help)"))
}

/// Build a run's telemetry from `--trace-out` (Chrome/Perfetto trace
/// JSON) and `--provenance-out` (per-decision JSONL). Off by default —
/// the hot path then never allocates for telemetry.
fn telemetry_from_args(args: &Args) -> Result<ksegments::telemetry::RunTelemetry> {
    use ksegments::telemetry::{ChromeTraceSink, ProvenanceLog, RunTelemetry};
    let mut tel = RunTelemetry::off();
    if let Some(path) = args.kv.get("trace-out") {
        tel.trace = Box::new(ChromeTraceSink::create(path).with_context(|| path.clone())?);
    }
    if let Some(path) = args.kv.get("provenance-out") {
        tel.provenance = Some(ProvenanceLog::create(path).with_context(|| path.clone())?);
    }
    Ok(tel)
}

/// Close the sinks and report where the artifacts went.
fn finish_telemetry(args: &Args, tel: &mut ksegments::telemetry::RunTelemetry) -> Result<()> {
    let n_decisions = tel.provenance.as_ref().map(|p| p.len()).unwrap_or(0);
    tel.finish().context("flushing telemetry sinks")?;
    if let Some(path) = args.kv.get("trace-out") {
        println!("wrote trace to {path} (open at https://ui.perfetto.dev)");
    }
    if let Some(path) = args.kv.get("provenance-out") {
        println!("wrote {n_decisions} provenance records to {path}");
    }
    Ok(())
}

/// Write a metrics registry to `path`: Prometheus text exposition for
/// `.prom`/`.txt`, the JSON snapshot otherwise.
fn write_metrics(reg: &ksegments::telemetry::Registry, path: &str) -> Result<()> {
    let text = if path.ends_with(".prom") || path.ends_with(".txt") {
        reg.to_prometheus()
    } else {
        format!("{}\n", reg.to_json())
    };
    std::fs::write(path, text).with_context(|| path.to_string())?;
    println!("wrote metrics to {path}");
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let wf_name = args.kv.get("workflow").context("--workflow required")?;
    let out = PathBuf::from(args.kv.get("out").context("--out required")?);
    let format = args.kv.get("format").map(String::as_str).unwrap_or("jsonl");
    let wf = workflow_by_name(wf_name)?;
    let trace = generate_workflow_trace(&wf, args.seed());
    match format {
        "jsonl" => write_trace_jsonl(&trace, &out)?,
        "csv" => write_trace_csv(&trace, &out)?,
        other => bail!("unknown format {other:?} (jsonl|csv)"),
    }
    println!(
        "wrote {} runs of {} task types ({} evaluated) to {}",
        trace.n_runs(),
        trace.n_types(),
        trace.evaluated_types(ksegments::workload::EVAL_MIN_RUNS).len(),
        out.display()
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let method = args.kv.get("method").context("--method required")?;
    let frac: f64 = args
        .kv
        .get("frac")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.5);
    let mut predictor = method_by_name(method, args.fitter())?;
    let cfg = SimConfig::with_training_frac(frac);
    let wf_names: Vec<&str> = match args.kv.get("workflow") {
        Some(w) => vec![w.as_str()],
        None => vec!["eager", "sarek"],
    };
    println!(
        "method={} frac={frac} seed={} fitter={:?}",
        predictor.name(),
        args.seed(),
        args.fitter()
    );
    for wf_name in wf_names {
        let wf = workflow_by_name(wf_name)?;
        let trace = generate_workflow_trace(&wf, args.seed());
        let rep = simulate_trace(&trace, predictor.as_mut(), &cfg);
        println!(
            "\n[{}] {} evaluated tasks — avg wastage {:.3} GB·s, avg retries {:.3}",
            wf_name,
            rep.tasks.len(),
            rep.avg_wastage_gbs(),
            rep.avg_retries()
        );
        for t in &rep.tasks {
            println!(
                "  {:<32} runs {:>4}  wastage {:>10.3} GB·s  retries {:>6.3}",
                t.task_type,
                t.n_scored,
                t.avg_wastage_gbs(),
                t.avg_retries()
            );
        }
    }
    Ok(())
}

fn cmd_fig7(args: &Args) -> Result<()> {
    let methods = methods_arg(args)?;
    let results = run_fig7_selected(args.seed(), args.fitter(), args.workers(), &methods);
    println!("{}", results.render_wastage());
    println!("{}", results.render_wins());
    println!("{}", results.render_retries());
    println!("{}", results.headline(0.75));
    Ok(())
}

fn cmd_fig8(args: &Args) -> Result<()> {
    let ks: Vec<usize> = (1..=15).collect();
    for task in ["eager/qualimap", "eager/adapter_removal"] {
        let r = run_fig8(args.seed(), args.fitter(), task, &ks, args.workers());
        println!("{}", r.render());
    }
    Ok(())
}

fn cmd_validate_runtime(args: &Args) -> Result<()> {
    use ksegments::ml::fitter::FitInput;
    let mut xla = XlaFitter::load_default()?;
    let (n_hist, t_max) = (xla.manifest().n_hist, xla.manifest().t_max);
    println!(
        "artifacts: n_hist={n_hist} t_max={t_max} ks={:?}",
        xla.manifest().fits.keys().collect::<Vec<_>>()
    );
    let mut native = NativeFitter;
    // rng-discipline: roots come from --seed, streams from fork()
    let mut rng = ksegments::rng::Rng::new(args.seed()).fork("validate-runtime");
    let mut worst: f64 = 0.0;
    for k in [1usize, 2, 4, 8, 16] {
        let mut input = FitInput::default();
        for _ in 0..24 {
            let x = rng.uniform(100.0, 4000.0);
            let peak = 50.0 + 0.8 * x * rng.uniform(0.9, 1.1);
            input.x.push(x);
            input.runtime.push(30.0 + 0.05 * x);
            input
                .series
                .push((0..t_max).map(|j| peak * (j + 1) as f64 / t_max as f64).collect());
        }
        let a = xla.fit(&input, k);
        let b = native.fit(&input, k);
        let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1.0);
        let mut err = rel(a.rt.a, b.rt.a).max(rel(a.rt.b, b.rt.b));
        for s in 0..k {
            err = err.max(rel(a.seg[s].a, b.seg[s].a)).max(rel(a.seg[s].b, b.seg[s].b));
            err = err.max(rel(a.seg_off[s], b.seg_off[s]));
        }
        worst = worst.max(err);
        println!("k={k:>2}: max relative deviation xla-vs-native = {err:.2e}");
    }
    println!("xla fits: {}, native fallbacks: {}", xla.xla_fits, xla.native_fits);
    if xla.native_fits > 0 {
        bail!("some fits fell back to native — artifacts incomplete?");
    }
    if worst > 1e-3 {
        bail!("deviation {worst:.2e} exceeds 1e-3 — backends diverged");
    }
    println!("VALIDATION OK (worst deviation {worst:.2e})");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let shards = args.shards();
    let factory = |_: usize| -> Box<dyn MemoryPredictor> {
        Box::new(KSegmentsPredictor::native(4, RetryStrategy::Selective))
    };
    // `--trace-out` records per-shard wakeup spans (wall clock — the
    // service is real threads, not simulation)
    let svc = if args.kv.contains_key("trace-out") {
        ShardedPredictionService::spawn_traced(shards, factory)
    } else {
        ShardedPredictionService::spawn(shards, factory)
    };
    let h = svc.handle();
    if let Some(path) = args.kv.get("source") {
        // Replay an ingested trace source through the service — the
        // streaming deployment path (no materialized trace).
        let mut src = ksegments::ingest::open_source(&PathBuf::from(path))?;
        let fed = h.replay_source(src.as_mut(), ksegments::ingest::DEFAULT_CHUNK)?;
        println!("replayed {} runs from {}", fed, src.origin());
    } else {
        // Demo: run the eager workflow through the sharded prediction
        // service from multiple SWMS worker threads.
        let trace = generate_workflow_trace(&eager_workflow(), args.seed());
        let n_clients = args.workers();
        for ty in trace.task_types() {
            if let Some(mem) = trace.default_alloc(ty) {
                h.prime(ty, mem);
            }
        }
        let runs: Vec<_> = trace.all_runs_ordered().into_iter().cloned().collect();
        let chunk = runs.len().div_ceil(n_clients).max(1);
        let mut joins = Vec::new();
        for (w, part) in runs.chunks(chunk).enumerate() {
            let h = svc.handle();
            let part = part.to_vec();
            joins.push(std::thread::spawn(move || {
                for run in part {
                    let alloc = h.predict(&run.task_type, run.input_mib);
                    let _ = alloc.max_value();
                    h.complete(run);
                }
                println!("worker {w} done");
            }));
        }
        for j in joins {
            j.join().map_err(|_| anyhow!("worker panicked"))?;
        }
    }
    let (per_shard, wakeup_trace) = svc.shutdown_with_trace();
    for (s, stats) in per_shard.iter().enumerate() {
        println!(
            "shard {s}: {} predictions, {} completions, {} failures, {} wakeups",
            stats.predictions, stats.completions, stats.failures, stats.wakeups
        );
    }
    let total = ksegments::coordinator::ServiceStats::aggregated(&per_shard);
    println!(
        "service ({shards} shards) processed {} predictions, {} completions, {} failures",
        total.predictions, total.completions, total.failures
    );
    if let Some(path) = args.kv.get("trace-out") {
        ksegments::telemetry::write_chrome_trace(path, &wakeup_trace)
            .with_context(|| path.clone())?;
        println!(
            "wrote service trace ({} events) to {path} (open at https://ui.perfetto.dev)",
            wakeup_trace.len()
        );
    }
    if let Some(path) = args.kv.get("metrics-out") {
        let mut reg = ksegments::telemetry::Registry::new();
        ksegments::coordinator::export_service_metrics(&per_shard, &mut reg);
        write_metrics(&reg, path)?;
    }
    Ok(())
}

/// Parse a human duration: `2s`, `500ms`, or bare seconds (`1.5`).
fn parse_duration_s(s: &str) -> Result<f64> {
    let (num, scale) = if let Some(v) = s.strip_suffix("ms") {
        (v, 1e-3)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1.0)
    } else {
        (s, 1.0)
    };
    let v: f64 = num
        .trim()
        .parse()
        .with_context(|| format!("invalid duration {s:?} (expected e.g. 2s, 500ms)"))?;
    if !v.is_finite() || v < 0.0 {
        bail!("duration must be finite and non-negative, got {s:?}");
    }
    Ok(v * scale)
}

/// Resolve `--method` (validated now, so a typo fails before binding)
/// into a per-shard predictor factory.
fn shard_factory(
    args: &Args,
) -> Result<(String, impl Fn(usize) -> Box<dyn MemoryPredictor>)> {
    let method = args
        .kv
        .get("method")
        .map(String::as_str)
        .unwrap_or("ksegments-selective")
        .to_string();
    method_by_name(&method, args.fitter())?;
    let choice = args.fitter();
    let key = method.clone();
    let factory =
        move |_: usize| method_by_name(&key, choice).expect("method validated at startup");
    Ok((method, factory))
}

fn cmd_serve_tcp(args: &Args) -> Result<()> {
    use ksegments::net::{export_net_metrics, NetServer, NetServerConfig};

    let addr = args.kv.get("addr").map(String::as_str).unwrap_or("127.0.0.1:0");
    let shards = args.shards();
    let (method, factory) = shard_factory(args)?;
    let mut cfg = NetServerConfig::default();
    if let Some(v) = args.kv.get("max-frame") {
        cfg.max_frame = v.parse::<usize>().context("--max-frame (bytes)")?.max(64);
    }
    if let Some(p) = args.kv.get("checkpoint") {
        let ck = ksegments::ingest::Checkpoint::load(&PathBuf::from(p))?;
        println!(
            "warm start: {} task types, {} runs seen, from {p}",
            ck.n_types(),
            ck.total_seen()
        );
        cfg.restore = Some(ck);
    }
    cfg.checkpoint_out = args.kv.get("checkpoint-out").map(PathBuf::from);
    let svc = ShardedPredictionService::spawn(shards, factory);
    let server = NetServer::spawn(addr, svc, cfg)?;
    let local = server.local_addr();
    println!("serving on {local} ({shards} shards, method {method}); drain with a shutdown frame");
    if let Some(p) = args.kv.get("port-file") {
        std::fs::write(p, format!("{}\n", local.port())).with_context(|| p.clone())?;
    }
    let report = server.wait()?;
    for (s, stats) in report.per_shard.iter().enumerate() {
        println!(
            "shard {s}: {} predictions, {} completions, {} failures, {} wakeups",
            stats.predictions, stats.completions, stats.failures, stats.wakeups
        );
    }
    let total = report.total();
    println!(
        "drained: {} predictions, {} completions, {} failures over {} connections \
         ({} frames, {} protocol errors)",
        total.predictions,
        total.completions,
        total.failures,
        report.net.connections,
        report.net.frames,
        report.net.errors
    );
    if let Some(p) = &report.checkpoint_out {
        println!("checkpoint -> {}", p.display());
    }
    if let Some(path) = args.kv.get("metrics-out") {
        let mut reg = ksegments::telemetry::Registry::new();
        ksegments::coordinator::export_service_metrics(&report.per_shard, &mut reg);
        export_net_metrics(&report.net, &mut reg);
        write_metrics(&reg, path)?;
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    use ksegments::net::{run_loadgen, LoadgenConfig, NetServer, NetServerConfig};

    let src_path = PathBuf::from(
        args.kv
            .get("source")
            .context("--source required (a .jsonl/.csv trace or a Nextflow trace dir)")?,
    );
    let mut src = ksegments::ingest::open_source(&src_path)?;
    let mut cfg = LoadgenConfig::default();
    if let Some(c) = args.kv.get("connections") {
        cfg.connections = c.parse::<usize>().context("--connections")?.max(1);
    }
    if let Some(q) = args.kv.get("qps") {
        cfg.qps = q.parse::<f64>().context("--qps")?;
        if !cfg.qps.is_finite() || cfg.qps < 0.0 {
            bail!("--qps must be finite and >= 0 (0 = unthrottled)");
        }
    }
    if let Some(d) = args.kv.get("duration") {
        cfg.duration_s = Some(parse_duration_s(d)?);
    }
    cfg.send_shutdown = args.flag("shutdown");

    // target an external server, or spawn one in-process
    let (addr, spawned) = match args.kv.get("addr") {
        Some(a) => (a.clone(), None),
        None => {
            let shards = args.shards();
            let (method, factory) = shard_factory(args)?;
            let svc = ShardedPredictionService::spawn(shards, factory);
            let server = NetServer::spawn("127.0.0.1:0", svc, NetServerConfig::default())?;
            let a = server.local_addr().to_string();
            println!("spawned in-process server on {a} ({shards} shards, method {method})");
            (a, Some(server))
        }
    };
    let report = run_loadgen(&addr, src.as_mut(), &cfg)?;
    println!(
        "loadgen: {} runs over {} connections in {:.2}s wall — {:.0} predictions/s",
        report.runs_fed, report.connections, report.wall_s, report.predict_rps
    );
    println!(
        "predict latency: p50 {:.3} ms  p99 {:.3} ms  p999 {:.3} ms ({} errors)",
        report.p50_ms, report.p99_ms, report.p999_ms, report.errors
    );
    println!(
        "server totals: {} predictions, {} completions, {} failures",
        report.stats.predictions, report.stats.completions, report.stats.failures
    );
    if let Some(server) = spawned {
        // the shutdown frame (if sent) already set the stop flag;
        // stop() is idempotent on top of it and joins either way
        let sreport = server.stop()?;
        println!(
            "in-process server drained ({} connections, {} frames, {} protocol errors)",
            sreport.net.connections, sreport.net.frames, sreport.net.errors
        );
    }
    if let Some(path) = args.kv.get("bench-out") {
        let snap = ksegments::bench_harness::BenchSnapshot {
            area: "serve",
            seed: args.seed(),
            workers: report.connections,
            counts: vec![
                ("runs_fed", report.runs_fed),
                ("predictions", report.stats.predictions),
                ("completions", report.stats.completions),
                ("errors", report.errors),
            ],
            wall_s: report.wall_s,
            throughput: report.predict_rps,
            throughput_unit: "predictions_per_s",
        };
        std::fs::write(path, format!("{}\n", snap.to_json())).with_context(|| path.clone())?;
        println!("wrote serving benchmark snapshot to {path}");
    }
    if report.errors > 0 {
        bail!("{} request errors during loadgen", report.errors);
    }
    Ok(())
}

fn cmd_ingest(args: &Args) -> Result<()> {
    let dir = args
        .pos
        .first()
        .cloned()
        .or_else(|| args.kv.get("dir").cloned())
        .context("usage: ksegments ingest <dir> [--out FILE] [--format jsonl|csv]")?;
    let dir = PathBuf::from(dir);
    let mut src = ksegments::ingest::NextflowDirSource::open(&dir)?;
    let (indexed, skipped) = (src.n_rows(), src.skipped_rows());
    let trace = ksegments::ingest::materialize(&mut src)?;
    let format = args.kv.get("format").map(String::as_str).unwrap_or("jsonl");
    // default to the working directory — never write into the source
    // trace dir (it may be a pristine capture or a checked-in fixture)
    let out = args
        .kv
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("trace.jsonl"));
    match format {
        "jsonl" => write_trace_jsonl_ordered(&trace, &out)?,
        "csv" => write_trace_csv(&trace, &out)?,
        other => bail!("unknown format {other:?} (jsonl|csv)"),
    }
    let n_defaults = trace
        .task_types()
        .filter(|ty| trace.default_alloc(ty).is_some())
        .count();
    println!(
        "ingested {}: {} runs over {} task types ({} non-COMPLETED rows skipped, \
         defaults for {} types)",
        dir.display(),
        indexed,
        trace.n_types(),
        skipped,
        n_defaults
    );
    println!("wrote {}", out.display());
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    use ksegments::ingest::{open_source, replay_source, Checkpoint, ReplayConfig};

    let path = PathBuf::from(
        args.kv
            .get("source")
            .context("--source required (a .jsonl/.csv trace or a Nextflow trace dir)")?,
    );
    let sel = args
        .kv
        .get("method")
        .map(String::as_str)
        .unwrap_or("ksegments-selective");
    let keys = ksegments::bench_harness::resolve_methods(sel).map_err(|e| anyhow!(e))?;
    let mut cfg = ReplayConfig::default();
    if let Some(w) = args.kv.get("warmup") {
        cfg.warmup_per_type = w.parse().context("--warmup")?;
    }
    if let Some(c) = args.kv.get("chunk") {
        cfg.chunk = c.parse::<usize>().context("--chunk")?.max(1);
    }
    let workers = args.workers();
    let start = args
        .kv
        .get("checkpoint")
        .map(|p| Checkpoint::load(&PathBuf::from(p)))
        .transpose()?;
    let ckpt_out = args.kv.get("checkpoint-out").map(PathBuf::from);
    if (start.is_some() || ckpt_out.is_some()) && keys.len() > 1 {
        bail!(
            "checkpointing needs a single --method (selection resolved to {} methods)",
            keys.len()
        );
    }
    let trace_out = args.kv.get("trace-out");
    if trace_out.is_some() && keys.len() > 1 {
        println!("note: --trace-out records the first method only\n");
    }
    let mut reg = ksegments::telemetry::Registry::new();
    let mut src = open_source(&path)?;
    println!(
        "replay: source={} methods={} workers={workers} warmup={} chunk={}\n",
        src.origin(),
        keys.join(","),
        cfg.warmup_per_type,
        cfg.chunk
    );
    for (i, &key) in keys.iter().enumerate() {
        if i > 0 {
            src.rewind()?;
        }
        cfg.collect_trace = trace_out.is_some() && i == 0;
        let choice = args.fitter();
        let make =
            move || ksegments::bench_harness::make_method(key, choice).expect("resolved key");
        let out = replay_source(src.as_mut(), &make, &cfg, workers, start.as_ref())?;
        out.report.export_metrics(&mut reg);
        if let (0, Some(path)) = (i, trace_out) {
            ksegments::telemetry::write_chrome_trace(path, &out.trace_events)
                .with_context(|| path.clone())?;
            println!(
                "wrote replay trace ({} events) to {path} (open at https://ui.perfetto.dev)",
                out.trace_events.len()
            );
        }
        println!(
            "[{}] {} runs replayed ({} warm-up) over {} task types — avg wastage {:.3} GB·s, \
             avg retries {:.3}",
            out.report.method,
            out.runs_replayed,
            out.runs_warmup,
            out.report.tasks.len(),
            out.report.avg_wastage_gbs(),
            out.report.avg_retries()
        );
        for t in &out.report.tasks {
            println!(
                "  {:<32} scored {:>4}  wastage {:>10.3} GB·s  retries {:>6.3}",
                t.task_type,
                t.n_scored,
                t.avg_wastage_gbs(),
                t.avg_retries()
            );
        }
        if let Some(p) = &ckpt_out {
            out.checkpoint.save(p)?;
            println!(
                "checkpoint ({} task types, {} runs seen) -> {}",
                out.checkpoint.n_types(),
                out.checkpoint.total_seen(),
                p.display()
            );
        }
    }
    if let Some(path) = args.kv.get("metrics-out") {
        write_metrics(&reg, path)?;
    }
    Ok(())
}

const SCHEDULE_USAGE: &str = "\
ksegments schedule — discrete-event cluster scheduling simulator

  --nodes N       cluster size (default 2)
  --node-gib G    memory per node in GiB (default 32)
  --arrival SECS  mean inter-arrival gap of the task (or workflow
                  instance) stream (default 5)
  --policy P      static | segment | both (default both)
  --method M      predictor driving the reservations
                  (default ksegments-selective; any METHODS entry from
                  `ksegments --help`, incl. ensemble and dynseg)
  --frac F        warm-up training fraction (default 0.5; ignored in
                  --dag mode, which always learns online)
  --seed N        trace + arrival seed (default 42)
  --workflow W    eager | sarek (default eager)
  --dag W         dependency-gated workflow mode: schedule N concurrent
                  instances of workflow W's DAG, releasing a task only
                  when its parents have completed
  --instances N   concurrent workflow instances for --dag (default 4;
                  with --sweep, the swept axis: N or N1,N2,...,
                  default 2,4,8)
  --fail-rate R   inject node failures at R per second (mean; Poisson);
                  resident tasks requeue blamelessly with their
                  allocation unchanged, and the node rejoins after a
                  60 s downtime (default 0 = no failures)
  --preempt       draw task priorities and let a high-priority arrival
                  that cannot place evict younger low-priority tasks
                  (evictees requeue blamelessly)
  --autoscale [LAG]
                  scale the roster with queue pressure: add a node
                  (joining after LAG seconds, default 30) when the
                  queue outgrows the live roster, retire idle
                  autoscaled nodes when it drains
  --sweep         render throughput tables on the parallel grid over
                  several arrival rates (or, with --dag, over the
                  --instances counts); the sweep itself runs the fixed
                  roster on a fixed 2 x 32 GiB cluster — --nodes,
                  --node-gib, --arrival and --method apply to the
                  single-run modes only
  --fail-sweep    render the failure-domain tables (method x failure
                  rate x autoscale lag) on the parallel grid
  --workers N     worker threads for --sweep/--fail-sweep (default:
                  cores)
  --trace-out FILE
                  write the run as Chrome trace-event JSON (task spans
                  on node tracks, kills/arrivals as instants; open at
                  https://ui.perfetto.dev). Purely observational —
                  reports stay bit-identical
  --provenance-out FILE
                  write one JSONL record per prediction (chosen
                  sub-model, RAQ scores, offset, segment bounds,
                  window length) and per failure escalation
  --metrics-out FILE
                  write scheduler counters/gauges/queue-wait histogram
                  (Prometheus text for .prom/.txt, JSON otherwise)

With --policy both, --trace-out/--provenance-out record the first
policy only; --metrics-out labels every policy's series.
";

/// `schedule --dag W`: dependency-gated workflow instances.
fn cmd_schedule_dag(args: &Args, wf_name: &str) -> Result<()> {
    use ksegments::cluster::NodeSpec;
    use ksegments::sched::{
        schedule_workflows, schedule_workflows_telemetry, SchedConfig, WorkflowSource,
    };
    use ksegments::units::{MemMiB, Seconds};

    let wf = workflow_by_name(wf_name)?;
    if args.flag("sweep") {
        // the sweep's instance-count axis: --instances N or N1,N2,...
        // (the cluster/method axes are fixed, like the arrival sweep)
        let counts: Vec<usize> = match args.kv.get("instances") {
            Some(s) => {
                let v = s
                    .split(',')
                    .map(|t| t.trim().parse::<usize>())
                    .collect::<Result<Vec<_>, _>>()
                    .context("--instances (sweep mode takes N or a comma list, e.g. 2,4,8)")?;
                if v.is_empty() || v.contains(&0) {
                    bail!("--instances counts must be positive");
                }
                v
            }
            None => vec![2, 4, 8],
        };
        let sweep = ksegments::bench_harness::run_dag_throughput(
            &wf,
            args.seed(),
            &counts,
            args.workers(),
        );
        println!("{}", sweep.render_workflow_makespan());
        println!("{}", sweep.render_stretch());
        println!("{}", sweep.render_stragglers());
        println!("{}", sweep.render_summaries());
        return Ok(());
    }
    let cli = parse_sched_cli(args)?;
    let instances: usize = args
        .kv
        .get("instances")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4);
    if instances == 0 {
        bail!("--instances must be at least 1");
    }
    println!(
        "schedule --dag: workflow={wf_name} instances={instances} method={} \
         nodes={}x{}GiB arrival={}s seed={}{}\n",
        cli.method,
        cli.n_nodes,
        cli.node_gib,
        cli.arrival,
        args.seed(),
        cli.adversity_summary(),
    );
    let mut tel = telemetry_from_args(args)?;
    let telemetry_on = tel.trace.enabled() || tel.provenance.is_some();
    if telemetry_on && cli.policies.len() > 1 {
        println!(
            "note: --trace-out/--provenance-out record the first policy ({}) only\n",
            cli.policies[0].name()
        );
    }
    let mut reports = Vec::new();
    for (i, policy) in cli.policies.iter().enumerate() {
        let mut cfg = SchedConfig {
            policy: *policy,
            nodes: vec![NodeSpec { mem: MemMiB::from_gib(cli.node_gib), cores: 32 }; cli.n_nodes],
            mean_interarrival: Seconds(cli.arrival),
            seed: args.seed(),
            ..SchedConfig::default()
        };
        cli.apply_failure_domains(&mut cfg);
        let src = WorkflowSource::from_spec(&wf, args.seed(), instances);
        let mut predictor = method_by_name(&cli.method, args.fitter())?;
        let rep = if i == 0 {
            schedule_workflows_telemetry(src, predictor.as_mut(), &cfg, &mut tel).0
        } else {
            schedule_workflows(src, predictor.as_mut(), &cfg)
        };
        println!("{}", rep.summary());
        reports.push(rep);
    }
    finish_telemetry(args, &mut tel)?;
    if let Some(path) = args.kv.get("metrics-out") {
        let mut reg = ksegments::telemetry::Registry::new();
        for rep in &reports {
            rep.export_metrics(&mut reg);
        }
        write_metrics(&reg, path)?;
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    use ksegments::cluster::NodeSpec;
    use ksegments::sched::{schedule_trace, schedule_trace_telemetry, SchedConfig};
    use ksegments::units::{MemMiB, Seconds};

    if args.flag("help") {
        print!("{SCHEDULE_USAGE}");
        return Ok(());
    }
    if let Some(dag_wf) = args.kv.get("dag").cloned() {
        return cmd_schedule_dag(args, &dag_wf);
    }
    if args.flag("sweep") {
        let sweep = ksegments::bench_harness::run_throughput(
            args.seed(),
            &[2.0, 5.0, 10.0],
            args.workers(),
        );
        println!("{}", sweep.render_makespan());
        println!("{}", sweep.render_queue_wait());
        println!("{}", sweep.render_packing());
        println!("{}", sweep.render_summaries());
        return Ok(());
    }
    if args.flag("fail-sweep") {
        let sweep = ksegments::bench_harness::run_failure_sweep(args.seed(), args.workers());
        println!("{}", sweep.render_makespan());
        println!("{}", sweep.render_disruption());
        println!("{}", sweep.render_wastage());
        println!("{}", sweep.render_summaries());
        return Ok(());
    }

    let cli = parse_sched_cli(args)?;
    let frac: f64 = args
        .kv
        .get("frac")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.5);
    if !(0.0..1.0).contains(&frac) {
        bail!("--frac must be in [0, 1)");
    }
    let wf_name = args.kv.get("workflow").map(String::as_str).unwrap_or("eager");
    let trace = generate_workflow_trace(&workflow_by_name(wf_name)?, args.seed());

    println!(
        "schedule: workflow={wf_name} method={} nodes={}x{}GiB \
         arrival={}s frac={frac} seed={}{}\n",
        cli.method,
        cli.n_nodes,
        cli.node_gib,
        cli.arrival,
        args.seed(),
        cli.adversity_summary(),
    );
    let mut tel = telemetry_from_args(args)?;
    let telemetry_on = tel.trace.enabled() || tel.provenance.is_some();
    if telemetry_on && cli.policies.len() > 1 {
        println!(
            "note: --trace-out/--provenance-out record the first policy ({}) only\n",
            cli.policies[0].name()
        );
    }
    let mut reports = Vec::new();
    for (i, policy) in cli.policies.iter().enumerate() {
        let mut cfg = SchedConfig {
            policy: *policy,
            nodes: vec![NodeSpec { mem: MemMiB::from_gib(cli.node_gib), cores: 32 }; cli.n_nodes],
            mean_interarrival: Seconds(cli.arrival),
            seed: args.seed(),
            training_frac: frac,
            ..SchedConfig::default()
        };
        cli.apply_failure_domains(&mut cfg);
        let mut predictor = method_by_name(&cli.method, args.fitter())?;
        let rep = if i == 0 {
            schedule_trace_telemetry(&trace, predictor.as_mut(), &cfg, &mut tel).0
        } else {
            schedule_trace(&trace, predictor.as_mut(), &cfg)
        };
        println!("{}", rep.summary());
        reports.push(rep);
    }
    finish_telemetry(args, &mut tel)?;
    if let Some(path) = args.kv.get("metrics-out") {
        let mut reg = ksegments::telemetry::Registry::new();
        for rep in &reports {
            rep.export_metrics(&mut reg);
        }
        write_metrics(&reg, path)?;
    }
    if let [stat, segw] = reports.as_slice() {
        if stat.makespan.0 > 0.0 && segw.makespan.0 > 0.0 {
            println!(
                "\nsegment-wise vs static-peak: makespan x{:.3}, mean wait x{:.3}, \
                 peak concurrency {} -> {}",
                segw.makespan.0 / stat.makespan.0,
                (segw.mean_queue_wait_s() / stat.mean_queue_wait_s().max(1e-9)),
                stat.peak_running,
                segw.peak_running,
            );
        }
    }
    Ok(())
}

/// `ksegments bench`: run perf areas and write `BENCH_<area>.json`
/// snapshots — the numbers CI diffs against the committed trajectory.
fn cmd_bench(args: &Args) -> Result<()> {
    let mut areas = args.all("area");
    if areas.is_empty() {
        areas.push("sched".to_string());
    }
    let out_dir = PathBuf::from(args.kv.get("out-dir").map(String::as_str).unwrap_or("."));
    std::fs::create_dir_all(&out_dir).with_context(|| out_dir.display().to_string())?;
    for area in &areas {
        let snap = ksegments::bench_harness::run_bench_area(area, args.seed(), args.workers())
            .map_err(|e| anyhow!(e))?;
        let path = out_dir.join(snap.file_name());
        std::fs::write(&path, format!("{}\n", snap.to_json()))
            .with_context(|| path.display().to_string())?;
        println!(
            "[{area}] {:.0} {} over {:.2}s wall -> {}",
            snap.throughput,
            snap.throughput_unit,
            snap.wall_s,
            path.display()
        );
    }
    Ok(())
}

fn real_main() -> Result<()> {
    let args = Args::parse();
    if !args.pos.is_empty() && args.cmd != "ingest" {
        bail!("unexpected positional argument {:?}", args.pos[0]);
    }
    match args.cmd.as_str() {
        "generate" => cmd_generate(&args),
        "ingest" => cmd_ingest(&args),
        "replay" => cmd_replay(&args),
        "simulate" => cmd_simulate(&args),
        "fig7" => cmd_fig7(&args),
        "fig8" => cmd_fig8(&args),
        "fig4" => {
            println!("{}", run_fig4(args.seed(), args.fitter()));
            Ok(())
        }
        "fig1" => {
            println!("{}", run_fig1(args.seed()));
            Ok(())
        }
        "ablate" => {
            println!(
                "{}",
                ksegments::bench_harness::ablation::run_all(args.seed(), args.workers())
            );
            Ok(())
        }
        "report" => {
            let methods = methods_arg(&args)?;
            let text = ksegments::bench_harness::report::full_report(
                args.seed(),
                args.fitter(),
                args.workers(),
                &methods,
            );
            match args.kv.get("out") {
                Some(path) => {
                    std::fs::write(path, &text)?;
                    println!("wrote report to {path}");
                }
                None => println!("{text}"),
            }
            Ok(())
        }
        "validate-runtime" => cmd_validate_runtime(&args),
        "serve" => cmd_serve(&args),
        "serve-tcp" => cmd_serve_tcp(&args),
        "loadgen" => cmd_loadgen(&args),
        "schedule" => cmd_schedule(&args),
        "bench" => cmd_bench(&args),
        "bench-sched" => {
            let json = ksegments::bench_harness::bench_sched_json(args.seed(), args.workers());
            match args.kv.get("out") {
                Some(path) => {
                    std::fs::write(path, format!("{json}\n"))?;
                    println!("wrote scheduler benchmark snapshot to {path}");
                }
                None => println!("{json}"),
            }
            Ok(())
        }
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
