//! One-shot report generation: every figure + the ablation suite +
//! runtime validation, rendered into a single markdown document
//! (`ksegments report --out FILE`). Useful for regenerating the data
//! section of EXPERIMENTS.md after any change.

use crate::bench_harness::ablation::run_all as run_ablations;
use crate::bench_harness::figures::{run_fig1, run_fig4, run_fig7, run_fig8, FitterChoice};

/// Build the complete experiments report (may take ~seconds).
pub fn full_report(seed: u64, choice: FitterChoice) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "# ksegments experiment report\n\nseed = {seed}, fitter = {choice:?}\n\n"
    ));

    out.push_str(&run_fig1(seed));
    out.push('\n');
    out.push_str(&run_fig4(seed, choice));
    out.push('\n');

    let fig7 = run_fig7(seed, choice);
    out.push_str(&fig7.render_wastage());
    out.push('\n');
    out.push_str(&fig7.render_wins());
    out.push('\n');
    out.push_str(&fig7.render_retries());
    out.push('\n');
    out.push_str("```\n");
    out.push_str(&fig7.headline(0.75));
    out.push_str(&fig7.headline(0.5));
    out.push_str("```\n\n");

    let ks: Vec<usize> = (1..=15).collect();
    for task in ["eager/qualimap", "eager/adapter_removal"] {
        out.push_str(&run_fig8(seed, choice, task, &ks).render());
        out.push('\n');
    }

    out.push_str(&run_ablations(seed));
    out
}

#[cfg(test)]
mod tests {
    // full_report is exercised end-to-end by the CLI; keep a cheap
    // structural test here so regressions in any section surface fast.
    use super::*;

    #[test]
    #[ignore = "runs the full grid (~10 s); covered by `ksegments report` in CI-style runs"]
    fn report_contains_every_section() {
        let r = full_report(42, FitterChoice::Native);
        for needle in [
            "Fig 1",
            "Fig 4",
            "Fig 7a",
            "Fig 7b",
            "Fig 7c",
            "Fig 8",
            "Ablation — error offsets",
            "fixed vs adaptive k",
        ] {
            assert!(r.contains(needle), "missing section {needle}");
        }
    }
}
