//! Task-type catalogs calibrated to the paper's §IV-B workload
//! description.
//!
//! * **eager** (ancient-DNA reconstruction): 18 task types, average
//!   runtimes 8 s – 4 h, peaks 19 MB – 14 GB, up to 136 executions of
//!   the same task.
//! * **sarek** (variant calling): 29 task types, average runtimes
//!   2 s – 1 h, peaks 10 MB – 23 GB, up to 1512 executions of the same
//!   task.
//!
//! Of the 47 types, exactly **33** have at least [`EVAL_MIN_RUNS`]
//! executions and form the evaluated set (the paper evaluates "33
//! different tasks in total"). Task names follow the real nf-core
//! pipelines; scaling-law parameters are synthetic but keep each type
//! inside the paper's reported ranges.

use crate::units::{MemMiB, Seconds};
use crate::workload::profiles::ProfileShape;
use crate::workload::spec::{TaskTypeSpec, WorkflowSpec};

/// Minimum executions for a type to enter the evaluated set.
pub const EVAL_MIN_RUNS: usize = 20;

#[allow(clippy::too_many_arguments)]
fn t(
    wf: &str,
    name: &str,
    profile: ProfileShape,
    rt_base_s: f64,
    rt_per_mib: f64,
    peak_base_mib: f64,
    peak_per_mib: f64,
    input_mu: f64,
    input_sigma: f64,
    n_executions: usize,
    default_gib: f64,
) -> TaskTypeSpec {
    TaskTypeSpec {
        name: format!("{wf}/{name}"),
        profile,
        rt_base: Seconds(rt_base_s),
        rt_per_mib,
        peak_base: MemMiB(peak_base_mib),
        peak_per_mib,
        noise_sigma: 0.12,
        // genomics tools routinely show data-dependent memory blowups;
        // the tail is what separates quantile-style allocators (PPM)
        // from mean+σ offsetting (LR) — see DESIGN.md §3
        spike_prob: 0.05,
        wiggle_sigma: 0.03,
        input_mu,
        input_sigma,
        n_executions,
        default_mem: MemMiB::from_gib(default_gib),
    }
}

/// The 18-type eager-like workflow.
pub fn eager_workflow() -> WorkflowSpec {
    use ProfileShape as P;
    let w = "eager";
    let tasks = vec![
        // 0: input QC — tiny, short, many runs
        t(w, "fastqc", P::Plateau { rise_frac: 0.55 }, 8.0, 0.02, 180.0, 0.05, 6.2, 0.6, 136, 4.0),
        // 1: the Fig. 4 / Fig. 8b task — smooth ramp, wastage falls with k
        t(w, "adapter_removal", P::RampUp { alpha: 0.8 }, 60.0, 0.35, 250.0, 0.55, 7.0, 0.5, 136, 8.0),
        // 2: long aligner — the 4 h-scale type, large memory; grows in
        // stages as read buffers and index pages accumulate
        t(w, "bwa_align", P::Staged { levels: &[0.3, 0.55, 0.8, 1.0] }, 900.0, 1.9, 6000.0, 0.4, 7.6, 0.4, 34, 48.0),
        // 3
        t(w, "samtools_filter", P::Bell { center: 0.45, width: 0.22 }, 30.0, 0.10, 300.0, 0.35, 7.2, 0.5, 68, 6.0),
        // 4
        t(w, "samtools_flagstat", P::RampUp { alpha: 1.0 }, 10.0, 0.015, 64.0, 0.02, 7.2, 0.5, 68, 2.0),
        // 5: dedup — staged
        t(w, "dedup", P::Staged { levels: &[0.25, 0.7, 1.0, 0.55] }, 120.0, 0.30, 800.0, 0.75, 7.3, 0.45, 68, 12.0),
        // 6: markduplicates — late spike (sort/write phase)
        t(w, "markduplicates", P::LateSpike { spike_start: 0.75, base: 0.3 }, 150.0, 0.40, 1200.0, 0.9, 7.3, 0.45, 34, 14.0),
        // 7: damage profiler — bell
        t(w, "damageprofiler", P::Bell { center: 0.55, width: 0.25 }, 90.0, 0.22, 600.0, 0.5, 7.0, 0.5, 34, 8.0),
        // 8: the Fig. 8a task — sawtooth ⇒ zigzag wastage vs k
        t(w, "qualimap", P::Sawtooth { cycles: 7.3, base: 0.35 }, 180.0, 0.5, 900.0, 0.85, 7.0, 0.45, 68, 12.0),
        // 9
        t(w, "preseq", P::RampUp { alpha: 1.4 }, 45.0, 0.12, 350.0, 0.30, 6.8, 0.5, 34, 4.0),
        // 10: genotyper — the biggest-memory eager task (≈14 GiB peaks);
        // ramps as the variant graph is built
        t(w, "genotyping_ug", P::RampUp { alpha: 0.7 }, 600.0, 1.1, 6000.0, 2.2, 7.4, 0.4, 24, 56.0),
        // 11
        t(w, "mtnucratio", P::Bell { center: 0.5, width: 0.3 }, 12.0, 0.01, 96.0, 0.03, 7.0, 0.5, 34, 2.0),
        // 12
        t(w, "sexdeterrmine", P::Bell { center: 0.4, width: 0.3 }, 25.0, 0.05, 200.0, 0.12, 7.0, 0.5, 24, 3.0),
        // ---- below the evaluation threshold (rare tasks) ----
        t(w, "endorspy", P::Constant, 9.0, 0.008, 48.0, 0.02, 6.5, 0.5, 12, 1.0),
        t(w, "bcftools_stats", P::RampUp { alpha: 1.0 }, 20.0, 0.03, 150.0, 0.08, 6.6, 0.5, 12, 2.0),
        t(w, "multiqc", P::RampUp { alpha: 1.8 }, 60.0, 0.05, 700.0, 0.25, 6.8, 0.4, 2, 8.0),
        t(w, "fastp", P::Plateau { rise_frac: 0.25 }, 40.0, 0.1, 400.0, 0.3, 6.9, 0.5, 8, 6.0),
        t(w, "kraken2", P::RampDown { alpha: 0.6 }, 300.0, 0.6, 8000.0, 1.2, 7.2, 0.4, 6, 64.0),
    ];
    // A plausible eager DAG: QC → trimming → alignment → filtering →
    // dedup/markdup → downstream stats & genotyping → reporting.
    let edges = vec![
        (0, 1),   // fastqc -> adapter_removal
        (16, 1),  // fastp -> adapter_removal
        (1, 2),   // adapter_removal -> bwa_align
        (2, 3),   // bwa -> samtools_filter
        (3, 4),   // -> flagstat
        (3, 5),   // -> dedup
        (3, 6),   // -> markduplicates
        (5, 7),   // dedup -> damageprofiler
        (5, 8),   // dedup -> qualimap
        (6, 8),   // markduplicates -> qualimap
        (5, 9),   // -> preseq
        (6, 10),  // markduplicates -> genotyping
        (10, 14), // genotyping -> bcftools_stats
        (5, 11),  // -> mtnucratio
        (5, 12),  // -> sexdeterrmine
        (12, 13), // -> endorspy
        (1, 17),  // adapter_removal -> kraken2
        (4, 15),  // everything reports into multiqc
        (8, 15),
        (14, 15),
    ];
    WorkflowSpec { name: "eager".into(), tasks, edges }
}

/// The 29-type sarek-like workflow.
pub fn sarek_workflow() -> WorkflowSpec {
    use ProfileShape as P;
    let w = "sarek";
    let tasks = vec![
        // ---- high-frequency scatter tasks (the 1512-execution scale) ----
        // 0
        t(w, "fastqc", P::Plateau { rise_frac: 0.55 }, 6.0, 0.015, 170.0, 0.04, 6.0, 0.6, 512, 4.0),
        // 1
        t(w, "fastp", P::Plateau { rise_frac: 0.45 }, 15.0, 0.06, 350.0, 0.25, 6.4, 0.5, 512, 6.0),
        // 2: scattered base recalibration — the 1512-execution task
        t(w, "gatk4_baserecalibrator", P::Bell { center: 0.5, width: 0.25 }, 25.0, 0.08, 900.0, 0.45, 6.0, 0.5, 1512, 8.0),
        // 3: scattered BQSR apply
        t(w, "gatk4_applybqsr", P::RampUp { alpha: 1.1 }, 20.0, 0.07, 700.0, 0.4, 6.0, 0.5, 1024, 8.0),
        // 4: the big aligner — staged growth like eager's bwa
        t(w, "bwamem2_mem", P::Staged { levels: &[0.35, 0.6, 0.85, 1.0] }, 400.0, 1.2, 12000.0, 0.4, 7.4, 0.4, 96, 96.0),
        // 5: markduplicates — biggest sarek memory (≈23 GB peaks)
        t(w, "gatk4_markduplicates", P::LateSpike { spike_start: 0.7, base: 0.35 }, 200.0, 0.5, 10000.0, 2.9, 7.4, 0.4, 96, 96.0),
        // 6
        t(w, "samtools_convert", P::RampUp { alpha: 0.9 }, 12.0, 0.03, 150.0, 0.06, 7.0, 0.5, 192, 2.0),
        // 7
        t(w, "samtools_stats", P::Bell { center: 0.5, width: 0.3 }, 18.0, 0.02, 130.0, 0.05, 7.0, 0.5, 192, 2.0),
        // 8
        t(w, "mosdepth", P::RampUp { alpha: 0.9 }, 40.0, 0.07, 420.0, 0.22, 7.0, 0.5, 96, 4.0),
        // 9: variant callers
        t(w, "strelka_germline", P::Bell { center: 0.55, width: 0.2 }, 300.0, 0.5, 2400.0, 0.9, 7.2, 0.4, 48, 24.0),
        // 10
        t(w, "manta_germline", P::Staged { levels: &[0.3, 0.8, 1.0, 0.6] }, 350.0, 0.55, 3200.0, 1.0, 7.2, 0.4, 48, 24.0),
        // 11: deepvariant — make_examples ramps, call_variants plateaus
        t(w, "deepvariant", P::RampUp { alpha: 0.55 }, 500.0, 0.9, 8000.0, 0.3, 7.2, 0.4, 32, 64.0),
        // 12: scattered haplotypecaller
        t(w, "haplotypecaller", P::Sawtooth { cycles: 5.7, base: 0.4 }, 60.0, 0.15, 1800.0, 0.7, 6.4, 0.5, 768, 16.0),
        // 13
        t(w, "genotypegvcfs", P::RampUp { alpha: 1.2 }, 90.0, 0.2, 1500.0, 0.6, 6.6, 0.5, 96, 12.0),
        // 14
        t(w, "mutect2", P::Sawtooth { cycles: 4.3, base: 0.45 }, 80.0, 0.18, 2000.0, 0.8, 6.4, 0.5, 384, 16.0),
        // 15
        t(w, "getpileupsummaries", P::RampUp { alpha: 1.0 }, 30.0, 0.05, 500.0, 0.2, 6.4, 0.5, 96, 4.0),
        // 16
        t(w, "calculatecontamination", P::RampUp { alpha: 1.2 }, 15.0, 0.01, 220.0, 0.06, 6.0, 0.5, 48, 2.0),
        // 17
        t(w, "filtermutectcalls", P::Bell { center: 0.5, width: 0.3 }, 25.0, 0.04, 600.0, 0.25, 6.2, 0.5, 48, 6.0),
        // 18: annotation — front-loaded cache load
        t(w, "vep", P::RampDown { alpha: 0.4 }, 120.0, 0.25, 4200.0, 0.15, 6.8, 0.4, 64, 32.0),
        // 19
        t(w, "snpeff", P::RampDown { alpha: 0.5 }, 90.0, 0.2, 3300.0, 0.15, 6.8, 0.4, 64, 24.0),
        // ---- below the evaluation threshold ----
        t(w, "bcftools_sort", P::LateSpike { spike_start: 0.8, base: 0.25 }, 20.0, 0.04, 300.0, 0.15, 6.4, 0.5, 16, 4.0),
        t(w, "tabix_bgziptabix", P::Constant, 5.0, 0.005, 24.0, 0.01, 6.0, 0.5, 16, 0.5),
        t(w, "vcftools", P::RampUp { alpha: 1.0 }, 25.0, 0.03, 180.0, 0.08, 6.2, 0.5, 12, 2.0),
        t(w, "multiqc", P::RampUp { alpha: 1.7 }, 90.0, 0.06, 900.0, 0.3, 6.8, 0.4, 2, 8.0),
        t(w, "msisensorpro", P::Bell { center: 0.5, width: 0.25 }, 60.0, 0.1, 700.0, 0.3, 6.6, 0.4, 12, 8.0),
        t(w, "tiddit_sv", P::Staged { levels: &[0.4, 1.0, 0.7] }, 200.0, 0.3, 2600.0, 0.8, 7.0, 0.4, 12, 24.0),
        t(w, "ascat", P::Plateau { rise_frac: 0.3 }, 300.0, 0.4, 3400.0, 1.0, 7.0, 0.4, 8, 32.0),
        t(w, "freebayes", P::Sawtooth { cycles: 3.6, base: 0.5 }, 100.0, 0.2, 1600.0, 0.6, 6.6, 0.5, 16, 16.0),
        t(w, "cnvkit_batch", P::Bell { center: 0.6, width: 0.2 }, 150.0, 0.25, 1900.0, 0.7, 6.8, 0.4, 12, 16.0),
    ];
    let edges = vec![
        (0, 1),   // fastqc -> fastp
        (1, 4),   // fastp -> bwamem2
        (4, 5),   // -> markduplicates
        (5, 2),   // -> baserecalibrator (scattered)
        (2, 3),   // -> applybqsr
        (3, 6),   // -> samtools_convert
        (3, 7),   // -> samtools_stats
        (3, 8),   // -> mosdepth
        (3, 9),   // -> strelka
        (3, 10),  // -> manta
        (3, 11),  // -> deepvariant
        (3, 12),  // -> haplotypecaller
        (12, 13), // -> genotypegvcfs
        (3, 14),  // -> mutect2
        (3, 15),  // -> getpileupsummaries
        (15, 16), // -> calculatecontamination
        (14, 17), // mutect2 -> filtermutectcalls
        (16, 17),
        (13, 18), // genotypegvcfs -> vep
        (13, 19), // -> snpeff
        (17, 18),
        (9, 20),  // strelka -> bcftools_sort
        (20, 21), // -> tabix
        (18, 22), // vep -> vcftools
        (3, 24),  // -> msisensorpro
        (3, 25),  // -> tiddit
        (3, 26),  // -> ascat
        (3, 27),  // -> freebayes
        (3, 28),  // -> cnvkit
        (7, 23),  // stats -> multiqc
        (8, 23),
        (22, 23),
    ];
    WorkflowSpec { name: "sarek".into(), tasks, edges }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogs_validate() {
        eager_workflow().validate().unwrap();
        sarek_workflow().validate().unwrap();
    }

    #[test]
    fn type_counts_match_paper() {
        assert_eq!(eager_workflow().tasks.len(), 18);
        assert_eq!(sarek_workflow().tasks.len(), 29);
    }

    #[test]
    fn exactly_33_evaluated_types() {
        let n_eval = |wf: &WorkflowSpec| {
            wf.tasks.iter().filter(|t| t.n_executions >= EVAL_MIN_RUNS).count()
        };
        let eager = n_eval(&eager_workflow());
        let sarek = n_eval(&sarek_workflow());
        assert_eq!(eager + sarek, 33, "eager={eager} sarek={sarek}");
    }

    #[test]
    fn execution_count_bounds_match_paper() {
        let eager_max = eager_workflow().tasks.iter().map(|t| t.n_executions).max().unwrap();
        let sarek_max = sarek_workflow().tasks.iter().map(|t| t.n_executions).max().unwrap();
        assert_eq!(eager_max, 136);
        assert_eq!(sarek_max, 1512);
    }

    #[test]
    fn nominal_ranges_match_paper() {
        // eager: runtimes up to the hours scale, peaks up to ~14 GiB
        let eager = eager_workflow();
        let max_rt = eager.tasks.iter().map(|t| t.nominal_runtime().0).fold(0.0, f64::max);
        let max_peak = eager.tasks.iter().map(|t| t.nominal_peak().0).fold(0.0, f64::max);
        assert!(max_rt > 3600.0, "eager max nominal runtime {max_rt}");
        assert!(max_peak > 8000.0 && max_peak < 20000.0, "eager max peak {max_peak} MiB");

        // sarek: peaks up to ~23 GB, short-to-1 h runtimes
        let sarek = sarek_workflow();
        let max_peak = sarek.tasks.iter().map(|t| t.nominal_peak().0).fold(0.0, f64::max);
        assert!(max_peak > 12000.0 && max_peak < 26000.0, "sarek max peak {max_peak} MiB");
        let min_rt = sarek.tasks.iter().map(|t| t.nominal_runtime().0).fold(f64::MAX, f64::min);
        assert!(min_rt < 30.0, "sarek min nominal runtime {min_rt}");
    }

    #[test]
    fn dags_are_acyclic() {
        // levels() panics on cycles
        assert!(eager_workflow().levels().len() > 2);
        assert!(sarek_workflow().levels().len() > 2);
    }

    #[test]
    fn fig8_tasks_present_with_right_profiles() {
        let eager = eager_workflow();
        let q = &eager.tasks[eager.task_index("eager/qualimap").unwrap()];
        assert!(matches!(q.profile, ProfileShape::Sawtooth { .. }));
        let a = &eager.tasks[eager.task_index("eager/adapter_removal").unwrap()];
        assert!(matches!(a.profile, ProfileShape::RampUp { .. }));
    }

    #[test]
    fn defaults_overprovision_generously() {
        for wf in [eager_workflow(), sarek_workflow()] {
            for t in &wf.tasks {
                assert!(
                    t.default_mem.0 >= 2.0 * t.nominal_peak().0,
                    "{}: default {} < 2x nominal peak {}",
                    t.name,
                    t.default_mem,
                    t.nominal_peak()
                );
            }
        }
    }
}
