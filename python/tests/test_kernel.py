"""Kernel-vs-reference correctness: the CORE signal for Layer 1.

The Pallas kernels (interpret=True) must match the pure-jnp oracles in
``compile.kernels.ref`` over a hypothesis-driven sweep of shapes, dtypes
and value distributions, plus hand-picked edge cases.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.linfit import linfit
from compile.kernels.ref import linfit_ref, segment_bounds, segpeaks_ref
from compile.kernels.segpeaks import segpeaks

# ---------------------------------------------------------------------------
# segment_bounds (the paper's change-point formula)
# ---------------------------------------------------------------------------


class TestSegmentBounds:
    def test_even_split(self):
        assert segment_bounds(8, 4) == [(0, 2), (2, 4), (4, 6), (6, 8)]

    def test_remainder_goes_to_last_segment(self):
        # j=10, k=4 -> i=2; last segment is [6, 10)
        assert segment_bounds(10, 4) == [(0, 2), (2, 4), (4, 6), (6, 10)]

    def test_k_equals_one_is_whole_series(self):
        assert segment_bounds(17, 1) == [(0, 17)]

    def test_k_equals_t(self):
        bounds = segment_bounds(5, 5)
        assert bounds == [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]

    def test_covers_series_exactly(self):
        for t in (4, 7, 16, 100, 256):
            for k in range(1, min(t, 16) + 1):
                bounds = segment_bounds(t, k)
                assert bounds[0][0] == 0
                assert bounds[-1][1] == t
                for (_, hi), (lo2, _) in zip(bounds, bounds[1:]):
                    assert hi == lo2  # contiguous, no gaps/overlap
                assert all(hi > lo for lo, hi in bounds)  # non-empty

    def test_rejects_bad_k(self):
        with pytest.raises(ValueError):
            segment_bounds(10, 0)
        with pytest.raises(ValueError):
            segment_bounds(3, 4)


# ---------------------------------------------------------------------------
# segpeaks kernel vs reference
# ---------------------------------------------------------------------------


@st.composite
def series_batch(draw):
    n = draw(st.sampled_from([1, 2, 4, 8, 16, 64]))
    t = draw(st.sampled_from([4, 8, 17, 31, 64, 256]))
    k = draw(st.integers(min_value=1, max_value=min(t, 16)))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    y = rng.uniform(0.0, 24_000.0, size=(n, t)).astype(np.float32)
    return y, k


class TestSegpeaksKernel:
    @settings(max_examples=40, deadline=None)
    @given(series_batch())
    def test_matches_reference(self, case):
        y, k = case
        got = segpeaks(jnp.asarray(y), k)
        want = segpeaks_ref(jnp.asarray(y), k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_known_values(self):
        y = jnp.asarray([[1.0, 5.0, 2.0, 3.0, 9.0, 0.0]], dtype=jnp.float32)
        # t=6, k=3 -> segments [0,2) [2,4) [4,6)
        got = np.asarray(segpeaks(y, 3))
        np.testing.assert_array_equal(got, [[5.0, 3.0, 9.0]])

    def test_k1_is_global_peak(self):
        rng = np.random.default_rng(7)
        y = rng.uniform(0, 100, size=(8, 33)).astype(np.float32)
        got = np.asarray(segpeaks(jnp.asarray(y), 1))[:, 0]
        np.testing.assert_array_equal(got, y.max(axis=1))

    def test_negative_values_safe_vs_mask(self):
        # masked lanes use -inf, so all-negative rows must still work
        y = -jnp.abs(jnp.asarray(np.random.default_rng(0).normal(size=(4, 16)), dtype=jnp.float32))
        got = segpeaks(y, 4)
        want = segpeaks_ref(y, 4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_blocked_grid_matches_single_block(self):
        rng = np.random.default_rng(3)
        y = jnp.asarray(rng.uniform(0, 50, size=(64, 64)), dtype=jnp.float32)
        a = segpeaks(y, 5, block_n=16)
        b = segpeaks(y, 5, block_n=64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rejects_nondivisible_block(self):
        y = jnp.zeros((6, 8), dtype=jnp.float32)
        with pytest.raises(ValueError):
            segpeaks(y, 2, block_n=4)


# ---------------------------------------------------------------------------
# linfit kernel vs reference (and vs numpy lstsq on clean designs)
# ---------------------------------------------------------------------------


@st.composite
def regression_case(draw):
    n = draw(st.sampled_from([2, 3, 8, 16, 64]))
    m = draw(st.integers(min_value=1, max_value=17))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    n_valid = draw(st.integers(min_value=0, max_value=n))
    rng = np.random.default_rng(seed)
    x = rng.uniform(1.0, 10_000.0, size=n).astype(np.float32)
    t = rng.uniform(0.0, 20_000.0, size=(n, m)).astype(np.float32)
    valid = np.zeros(n, dtype=np.float32)
    valid[:n_valid] = 1.0
    return x, t, valid


class TestLinfitKernel:
    @settings(max_examples=40, deadline=None)
    @given(regression_case())
    def test_matches_reference(self, case):
        x, t, valid = map(jnp.asarray, case)
        got = np.asarray(linfit(x, t, valid))
        want = np.asarray(linfit_ref(x, t, valid))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_recovers_exact_line(self):
        x = jnp.asarray([1.0, 2.0, 3.0, 4.0], dtype=jnp.float32)
        t = (3.0 + 2.0 * x)[:, None]
        coef = np.asarray(linfit(x, t, jnp.ones(4, dtype=jnp.float32)))
        np.testing.assert_allclose(coef, [[3.0, 2.0]], rtol=1e-5, atol=1e-4)

    def test_matches_numpy_lstsq(self):
        rng = np.random.default_rng(11)
        x = rng.uniform(0, 100, size=32).astype(np.float32)
        t = (5.0 + 0.7 * x + rng.normal(0, 3, size=32)).astype(np.float32)[:, None]
        coef = np.asarray(
            linfit(jnp.asarray(x), jnp.asarray(t), jnp.ones(32, dtype=jnp.float32))
        )[0]
        a_mat = np.stack([np.ones_like(x), x], axis=1)
        want, *_ = np.linalg.lstsq(a_mat.astype(np.float64), t[:, 0].astype(np.float64))
        np.testing.assert_allclose(coef, want, rtol=1e-3, atol=1e-2)

    def test_single_valid_row_falls_back_to_mean(self):
        x = jnp.asarray([5.0, 99.0], dtype=jnp.float32)
        t = jnp.asarray([[42.0], [7.0]], dtype=jnp.float32)
        valid = jnp.asarray([1.0, 0.0], dtype=jnp.float32)
        coef = np.asarray(linfit(x, t, valid))
        np.testing.assert_allclose(coef, [[42.0, 0.0]], rtol=1e-6)

    def test_identical_x_falls_back_to_mean(self):
        x = jnp.asarray([3.0, 3.0, 3.0], dtype=jnp.float32)
        t = jnp.asarray([[1.0], [2.0], [3.0]], dtype=jnp.float32)
        coef = np.asarray(linfit(x, t, jnp.ones(3, dtype=jnp.float32)))
        np.testing.assert_allclose(coef, [[2.0, 0.0]], rtol=1e-6)

    def test_invalid_rows_are_ignored(self):
        # Garbage in masked rows must not change the fit.
        x = jnp.asarray([1.0, 2.0, 3.0, 1e6], dtype=jnp.float32)
        t = jnp.asarray([[2.0], [4.0], [6.0], [-1e9]], dtype=jnp.float32)
        valid = jnp.asarray([1.0, 1.0, 1.0, 0.0], dtype=jnp.float32)
        coef = np.asarray(linfit(x, t, valid))
        np.testing.assert_allclose(coef, [[0.0, 2.0]], rtol=1e-4, atol=1e-3)

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError):
            linfit(
                jnp.zeros(3, dtype=jnp.float32),
                jnp.zeros((4, 2), dtype=jnp.float32),
                jnp.zeros(3, dtype=jnp.float32),
            )
