//! `panic-policy`: request paths in the TCP front-end
//! (`ksegments-serve/src/net/**`) must never panic — a panicking
//! connection thread poisons shared state and silently drops every
//! queued frame, where the protocol demands a typed error response
//! (`bad_request`, `unavailable`, …). Banned in non-test code there:
//! `unwrap`/`expect`, the panicking macros, and slice/array indexing
//! (each `[i]` is an implicit assert). Guarded indexing that a human
//! has proven in-bounds carries a `lint:allow(panic-policy)` with the
//! proof in a comment.

use super::{FileCtx, Rule};
use crate::diag::Diagnostic;

const CALLS: &[&str] = &[".unwrap()", ".expect("];
const MACROS: &[&str] = &["panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// Keywords that may directly precede `[` in type or expression
/// position without forming an index expression (`&mut [u8]`, …).
const NON_INDEX_WORDS: &[&str] = &[
    "mut", "dyn", "ref", "as", "in", "return", "else", "match", "impl", "where", "move", "const",
    "static", "break", "continue", "if", "while", "loop", "for", "let", "box", "unsafe", "async",
    "await", "yield", "true", "false",
];

fn in_scope(ctx: &FileCtx<'_>) -> bool {
    ctx.krate == "ksegments-serve" && ctx.rel_path.starts_with("src/net/")
}

/// Find index expressions: a `[` whose previous non-space character
/// ends an identifier, `]`, or `)` — excluding keyword prefixes.
fn has_indexing(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    for (i, &c) in chars.iter().enumerate() {
        if c != '[' {
            continue;
        }
        let mut j = i;
        while j > 0 && chars[j - 1] == ' ' {
            j -= 1;
        }
        if j == 0 {
            continue;
        }
        let prev = chars[j - 1];
        if prev == ']' || prev == ')' {
            return true;
        }
        if prev.is_alphanumeric() || prev == '_' {
            // back up over the identifier and screen out keywords
            let end = j;
            let mut start = j;
            while start > 0 && (chars[start - 1].is_alphanumeric() || chars[start - 1] == '_') {
                start -= 1;
            }
            let word: String = chars[start..end].iter().collect();
            if !NON_INDEX_WORDS.contains(&word.as_str()) {
                return true;
            }
        }
    }
    false
}

pub struct PanicPolicy;

impl Rule for PanicPolicy {
    fn id(&self) -> &'static str {
        "panic-policy"
    }

    fn check(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        if !in_scope(ctx) {
            return;
        }
        for (idx, line) in ctx.file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let mut hits: Vec<String> = Vec::new();
            for pat in CALLS.iter().chain(MACROS) {
                if line.code.contains(pat) {
                    hits.push(format!("`{}`", pat.trim_end_matches('(')));
                }
            }
            if has_indexing(&line.code) {
                hits.push("slice/array indexing".to_string());
            }
            for what in hits {
                out.push(Diagnostic {
                    rule: self.id(),
                    path: ctx.display_path.to_string(),
                    line: idx + 1,
                    message: format!(
                        "{what} on a request path; answer with a typed protocol error \
                         (bad_request/unavailable) instead of panicking"
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_detector_basics() {
        assert!(has_indexing("let x = buf[0];"));
        assert!(has_indexing("let s = &pending[4..n];"));
        assert!(has_indexing("f()[1]"));
        assert!(has_indexing("m[k][j]"));
        assert!(!has_indexing("fn f(p: &[u8]) -> &mut [u8] {"));
        assert!(!has_indexing("let a = [0u8; 4];"));
        assert!(!has_indexing("#[derive(Debug)]"));
        assert!(!has_indexing("vec![1, 2]"));
        assert!(!has_indexing("let v: Vec<[f64; 3]> = Vec::new();"));
    }
}
