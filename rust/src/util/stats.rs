//! Small descriptive-statistics helpers shared by metrics and the
//! bench harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation (0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via nearest-rank on a sorted copy; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
    s[rank.min(s.len() - 1)]
}

/// Pearson correlation (0 when degenerate).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.len() < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(x), mean(y));
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        cov += (a - mx) * (b - my);
        vx += (a - mx) * (a - mx);
        vy += (b - my) * (b - my);
    }
    if vx <= 0.0 || vy <= 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(std(&[5.0]), 0.0);
        assert!((std(&[1.0, -1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn pearson_perfect_and_degenerate() {
        let x = [1.0, 2.0, 3.0];
        let y = [10.0, 20.0, 30.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [30.0, 20.0, 10.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }
}
