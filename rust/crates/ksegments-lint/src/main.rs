//! `ksegments-lint` — run the invariant passes over the workspace.
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use ksegments_lint::{render_human, render_json, rules};

const USAGE: &str = "\
ksegments-lint: in-repo invariant linter (DESIGN.md \u{a7}15)

USAGE:
    cargo run -p ksegments-lint [--] [OPTIONS]

OPTIONS:
    --root <dir>       workspace root holding crates/ (default: auto-
                       detect from the working directory upward)
    --format <fmt>     human (default) or json (ksegments-lint-v1)
    --list-rules       print the rule ids and exit
    --help             this text

Suppress a finding with a trailing `// lint:allow(rule)` comment, or
one on a standalone comment line directly above, with the reason
alongside. The meta-test in crates/ksegments-lint/tests/engine.rs
pins which rules may carry suppressions at all.
";

struct Args {
    root: Option<PathBuf>,
    json: bool,
    list_rules: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args { root: None, json: false, list_rules: false };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                let v = it.next().ok_or("--root needs a directory")?;
                args.root = Some(PathBuf::from(v));
            }
            "--format" => match it.next().as_deref() {
                Some("human") => args.json = false,
                Some("json") => args.json = true,
                other => return Err(format!("--format expects human|json, got {other:?}")),
            },
            "--list-rules" => args.list_rules = true,
            "--help" | "-h" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument {other:?} (see --help)")),
        }
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };
    if args.list_rules {
        for id in rules::RULE_IDS {
            println!("{id}");
        }
        return ExitCode::SUCCESS;
    }
    let root = match args.root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            match ksegments_lint::engine::find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!("error: no crates/ directory found; pass --root");
                    return ExitCode::from(2);
                }
            }
        }
    };
    let report = match ksegments_lint::run_workspace(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: scanning {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if args.json {
        println!("{}", render_json(&report));
    } else {
        print!("{}", render_human(&report));
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
