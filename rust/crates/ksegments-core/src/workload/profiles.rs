//! Temporal memory-usage profiles for synthetic task types.
//!
//! A profile maps normalized task phase `p ∈ [0, 1]` to relative memory
//! usage `∈ (0, 1]` (1 = the run's peak). Shapes are chosen to span the
//! behaviours seen in the paper's published traces: the adapter-removal
//! ramp of Fig. 4, plateau-heavy aligners, bell-shaped variant callers,
//! staged multi-tool wrappers, and periodic (sawtooth) scan/merge tasks
//! whose wastage-vs-k curve zigzags (Fig. 8a).

/// Relative usage as a function of normalized phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ProfileShape {
    /// Near-constant usage from the start (e.g. fixed-buffer tools).
    Constant,
    /// Smooth monotone ramp `p^alpha` to the peak at the end — the
    /// adapter-removal shape of Fig. 4.
    RampUp { alpha: f64 },
    /// Fast rise then flat plateau at the peak.
    Plateau { rise_frac: f64 },
    /// Bell: grows to a mid-run maximum, then releases (Fig. 1's shape).
    Bell { center: f64, width: f64 },
    /// Discrete phases with increasing levels (multi-tool wrappers).
    Staged { levels: &'static [f64] },
    /// Low usage for most of the run, spike near the end (merge/sort
    /// finalization) — the adversarial case for runtime underprediction.
    LateSpike { spike_start: f64, base: f64 },
    /// Periodic sawtooth riding on a base level (chunked scans). The
    /// period intentionally mis-aligns with segment boundaries for most
    /// k, producing the zigzag wastage-vs-k of Fig. 8a.
    Sawtooth { cycles: f64, base: f64 },
    /// Ramp down from an early peak (front-loaded index loads).
    RampDown { alpha: f64 },
}

impl ProfileShape {
    /// Relative usage at phase `p ∈ [0,1]`; clamped outside. Guaranteed
    /// to return a value in `(0, 1]` and to reach 1.0 at some phase.
    pub fn value(&self, p: f64) -> f64 {
        let p = p.clamp(0.0, 1.0);
        let v = match *self {
            ProfileShape::Constant => 1.0,
            ProfileShape::RampUp { alpha } => p.powf(alpha).max(0.02),
            ProfileShape::Plateau { rise_frac } => {
                if p < rise_frac {
                    (p / rise_frac).max(0.02)
                } else {
                    1.0
                }
            }
            ProfileShape::Bell { center, width } => {
                let z = (p - center) / width;
                (-0.5 * z * z).exp().max(0.02)
            }
            ProfileShape::Staged { levels } => {
                debug_assert!(!levels.is_empty());
                let idx = ((p * levels.len() as f64) as usize).min(levels.len() - 1);
                levels[idx].max(0.02)
            }
            ProfileShape::LateSpike { spike_start, base } => {
                if p < spike_start {
                    base
                } else {
                    // linear blow-up from base to 1 over the spike window
                    let q = (p - spike_start) / (1.0 - spike_start).max(1e-9);
                    base + (1.0 - base) * q.min(1.0)
                }
            }
            ProfileShape::Sawtooth { cycles, base } => {
                let saw = (p * cycles).fract();
                base + (1.0 - base) * saw
            }
            ProfileShape::RampDown { alpha } => (1.0 - p).powf(alpha).max(0.02),
        };
        v.clamp(0.001, 1.0)
    }

    /// Phase at which the profile attains (approximately) its maximum —
    /// used in tests and Fig. 1 rendering.
    pub fn argmax(&self) -> f64 {
        match *self {
            ProfileShape::Constant => 0.0,
            ProfileShape::RampUp { .. } => 1.0,
            ProfileShape::Plateau { rise_frac } => rise_frac,
            ProfileShape::Bell { center, .. } => center,
            ProfileShape::Staged { levels } => {
                let (idx, _) = levels
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                (idx as f64 + 0.5) / levels.len() as f64
            }
            ProfileShape::LateSpike { .. } => 1.0,
            // just before the end of the last complete cycle the
            // sawtooth's fract() approaches 1
            ProfileShape::Sawtooth { cycles, .. } => (cycles.floor() / cycles - 1e-9).min(1.0),
            ProfileShape::RampDown { .. } => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_shapes() -> Vec<ProfileShape> {
        vec![
            ProfileShape::Constant,
            ProfileShape::RampUp { alpha: 0.5 },
            ProfileShape::RampUp { alpha: 2.0 },
            ProfileShape::Plateau { rise_frac: 0.2 },
            ProfileShape::Bell { center: 0.5, width: 0.2 },
            ProfileShape::Staged { levels: &[0.2, 0.6, 1.0, 0.4] },
            ProfileShape::LateSpike { spike_start: 0.8, base: 0.15 },
            ProfileShape::Sawtooth { cycles: 5.3, base: 0.3 },
            ProfileShape::RampDown { alpha: 1.0 },
        ]
    }

    #[test]
    fn values_in_unit_range() {
        for shape in all_shapes() {
            for i in 0..=1000 {
                let p = i as f64 / 1000.0;
                let v = shape.value(p);
                assert!((0.0..=1.0).contains(&v), "{shape:?} at {p}: {v}");
                assert!(v > 0.0, "{shape:?} at {p} not positive");
            }
        }
    }

    #[test]
    fn reaches_peak_near_one() {
        for shape in all_shapes() {
            let peak = (0..=2000)
                .map(|i| shape.value(i as f64 / 2000.0))
                .fold(f64::MIN, f64::max);
            assert!(peak > 0.95, "{shape:?}: peak only {peak}");
        }
    }

    #[test]
    fn clamps_out_of_range_phase() {
        let s = ProfileShape::RampUp { alpha: 1.0 };
        assert_eq!(s.value(-1.0), s.value(0.0));
        assert_eq!(s.value(2.0), s.value(1.0));
    }

    #[test]
    fn ramp_is_monotone() {
        let s = ProfileShape::RampUp { alpha: 1.3 };
        let mut prev = 0.0;
        for i in 0..=100 {
            let v = s.value(i as f64 / 100.0);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
    }

    #[test]
    fn bell_peaks_at_center() {
        let s = ProfileShape::Bell { center: 0.4, width: 0.15 };
        assert!(s.value(0.4) > s.value(0.1));
        assert!(s.value(0.4) > s.value(0.9));
        assert!((s.value(0.4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn late_spike_stays_low_then_rises() {
        let s = ProfileShape::LateSpike { spike_start: 0.8, base: 0.1 };
        assert!((s.value(0.5) - 0.1).abs() < 1e-12);
        assert!(s.value(0.9) > 0.5);
        assert!((s.value(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sawtooth_oscillates() {
        let s = ProfileShape::Sawtooth { cycles: 4.0, base: 0.2 };
        // within one cycle it rises then resets
        let a = s.value(0.1);
        let b = s.value(0.24);
        let c = s.value(0.26); // just past the 1/4 reset
        assert!(b > a);
        assert!(c < b);
    }

    #[test]
    fn argmax_consistent_with_values() {
        for shape in all_shapes() {
            let am = shape.argmax();
            let v = shape.value(am);
            assert!(v > 0.9, "{shape:?}: value at argmax {am} = {v}");
        }
    }
}
