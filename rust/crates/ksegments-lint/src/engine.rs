//! The engine: walk the workspace, scrub every `.rs` file, run every
//! rule, apply `lint:allow` filtering, and collect a [`Report`].
//!
//! Files under `tests/`, `benches/` and `examples/` are test context
//! wholesale (same standing as `#[cfg(test)]` spans): the invariants
//! police shipped code paths, not harnesses. The walk order is
//! sorted, so reports are byte-identical across runs and machines.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::diag::{sort_diags, sort_suppressions, Diagnostic, Suppression};
use crate::lexer::scrub;
use crate::rules::{all_rules, layering, FileCtx};

/// Outcome of a lint run.
#[derive(Debug, Default)]
pub struct Report {
    /// Violations, sorted by (path, line, rule).
    pub diags: Vec<Diagnostic>,
    /// Findings waived by `lint:allow`, same order.
    pub suppressed: Vec<Suppression>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diags.is_empty()
    }
}

/// Lint one in-memory source file. `force_test` marks the whole file
/// as test context (what the walker does for `tests/`, `benches/`,
/// `examples/`). Returns (violations, suppressions), sorted.
pub fn check_source(
    krate: &str,
    rel_path: &str,
    src: &str,
    force_test: bool,
) -> (Vec<Diagnostic>, Vec<Suppression>) {
    let mut file = scrub(src);
    if force_test {
        for line in &mut file.lines {
            line.in_test = true;
        }
    }
    let display = format!("crates/{krate}/{rel_path}");
    let ctx = FileCtx { krate, rel_path, display_path: &display, file: &file };
    let mut raw = Vec::new();
    for rule in all_rules() {
        rule.check(&ctx, &mut raw);
    }
    let mut diags = Vec::new();
    let mut sups = Vec::new();
    for d in raw {
        let allowed = d
            .line
            .checked_sub(1)
            .and_then(|i| file.lines.get(i))
            .is_some_and(|l| l.allows_rule(d.rule));
        if allowed {
            sups.push(Suppression { rule: d.rule, path: d.path, line: d.line });
        } else {
            diags.push(d);
        }
    }
    sort_diags(&mut diags);
    sort_suppressions(&mut sups);
    (diags, sups)
}

/// Locate the workspace root (the directory holding `crates/`):
/// `start` itself, `start/rust`, or the nearest ancestor of either.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(dir) = cur {
        if dir.join("crates/ksegments-core").is_dir() {
            return Some(dir);
        }
        if dir.join("rust/crates/ksegments-core").is_dir() {
            return Some(dir.join("rust"));
        }
        cur = dir.parent().map(Path::to_path_buf);
    }
    None
}

/// Lint the whole workspace under `root` (the directory holding
/// `crates/`).
pub fn run_workspace(root: &Path) -> io::Result<Report> {
    let mut report = Report::default();
    let crates_dir = root.join("crates");
    for crate_dir in sorted_dirs(&crates_dir)? {
        let manifest_path = crate_dir.join("Cargo.toml");
        let Ok(manifest) = fs::read_to_string(&manifest_path) else {
            continue;
        };
        let dir_name = file_name(&crate_dir);
        let krate = package_name(&manifest).unwrap_or_else(|| dir_name.clone());
        let display_manifest = format!("crates/{dir_name}/Cargo.toml");
        report
            .diags
            .extend(layering::check_manifest(&krate, &display_manifest, &manifest));
        for (sub, force_test) in
            [("src", false), ("tests", true), ("benches", true), ("examples", true)]
        {
            let sub_dir = crate_dir.join(sub);
            if !sub_dir.is_dir() {
                continue;
            }
            for path in rust_files(&sub_dir)? {
                let src = fs::read_to_string(&path)?;
                let rel = format!("{sub}/{}", rel_to(&path, &sub_dir));
                let (diags, sups) = check_source(&krate, &rel, &src, force_test);
                report.files_scanned += 1;
                report.diags.extend(diags);
                report.suppressed.extend(sups);
            }
        }
    }
    sort_diags(&mut report.diags);
    sort_suppressions(&mut report.suppressed);
    Ok(report)
}

fn file_name(p: &Path) -> String {
    p.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default()
}

fn rel_to(path: &Path, base: &Path) -> String {
    path.strip_prefix(base)
        .unwrap_or(path)
        .to_string_lossy()
        .replace(std::path::MAIN_SEPARATOR, "/")
}

/// `name = "..."` from the `[package]` section.
fn package_name(manifest: &str) -> Option<String> {
    let mut in_package = false;
    for raw in manifest.lines() {
        let line = raw.trim();
        if line.starts_with('[') {
            in_package = line == "[package]";
            continue;
        }
        if in_package && line.starts_with("name") {
            let (_, rest) = line.split_once('=')?;
            return Some(rest.trim().trim_matches('"').to_string());
        }
    }
    None
}

fn sorted_dirs(dir: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_dir())
        .collect();
    out.sort();
    Ok(out)
}

/// All `.rs` files under `dir`, recursively, sorted.
fn rust_files(dir: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(&d)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn package_name_parses() {
        let toml = "[package]\nname = \"ksegments-core\"\nversion = \"0.1.0\"\n";
        assert_eq!(package_name(toml).as_deref(), Some("ksegments-core"));
        assert_eq!(package_name("[workspace]\nmembers = []\n"), None);
    }

    #[test]
    fn force_test_waives_all_rules() {
        let src = "fn t() { let _ = std::time::Instant::now(); }\n";
        let (diags, _) = check_source("ksegments-sim", "tests/x.rs", src, true);
        assert!(diags.is_empty());
        let (diags, _) = check_source("ksegments-sim", "src/x.rs", src, false);
        assert_eq!(diags.len(), 1);
    }
}
